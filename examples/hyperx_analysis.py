"""The paper's Sections 5-7 study rerun on a HyperX machine.

    PYTHONPATH=src python examples/hyperx_analysis.py

Cano et al. (PAPERS.md) pose the paper's edge-isoperimetric question on
Hamming graphs — per-dimension cliques instead of rings — and the answer
*flips*: an aligned box's cut ``t * sum_k K_k (S_k - c_k)`` falls as a
side grows, so covering a whole dimension is unbeatable and *elongated*
partitions minimise internal contention, the exact opposite of the torus
preference the Mira/JUQUEEN tables pin.  This walk-through derives that
end to end on ``H(16, 4)``: the ranked bisection table, the certified
partition advisor, the flow-simulated worst/best gap, what DAL routing
recovers (and cannot recover), the allocation-policy queue replay, the
planner on a HyperX pod, and the structural zero of cross-box contention.

Every headline number is golden-pinned with asserts, so CI running this
example is a regression gate, like the Mira/JUQUEEN tables.
"""

import os

import numpy as np

from repro.launch.planner import format_table, plan_model
from repro.network import (
    HyperXFabric,
    IsoperimetricPolicy,
    JobRequest,
    ListPolicy,
    MachineState,
    advise_partition,
    bisection_table,
    compare_fabric_routing,
    hyperx_all_to_all_max_load,
    optimal_cuboid,
    simulate_fabric_traffic,
    simulate_queue,
)
from repro.network.patterns import all_to_all, bisection_pairing, hotspot_line
from repro.obs.contention import attribute_contention, render_dashboard

POD = HyperXFabric((16, 4))
UNITS = 16


# ---------------------------------------------------------------------------
# Section 5 analogue: geometry ranking by internal bisection.
# ---------------------------------------------------------------------------
print(f"== H{POD.dims} bisection table, {UNITS}-unit boxes (Lindsey-exact) ==")
ranked = bisection_table(POD, UNITS).ranked()
for g, bis in ranked:
    sub = POD.sub_fabric(g)
    print(
        f"  {str(g):>8}: bisection {bis:3d} links   "
        f"all-to-all max load {hyperx_all_to_all_max_load(sub):5.1f}"
    )
assert ranked == [((16, 1), 64), ((4, 4), 16), ((8, 2), 8)], ranked
print(
    "  -> the elongated (16, 1) line wins: covering a dimension removes it\n"
    "     from the bottleneck — the OPPOSITE of the torus preference"
)

opt = optimal_cuboid(POD, UNITS)
assert (opt.geometry, opt.cut, opt.bound, opt.tight) == ((16, 1), 48, 48, True)
print(
    f"  optimal cuboid {opt.geometry}: cut {opt.cut} == Lindsey bound "
    f"{opt.bound:.0f} [certified tight]"
)


# ---------------------------------------------------------------------------
# Section 6 analogue: the partition advisor, certified and simulated.
# ---------------------------------------------------------------------------
print(f"\n== Partition advisor: worst {UNITS}-unit geometry vs optimum ==")
adv = advise_partition(POD, UNITS, (8, 2), simulate=True)
print(
    f"  current (8, 2) bisection {adv.current_bisection} -> optimal "
    f"{adv.optimal_geometry} bisection {adv.optimal_bisection}\n"
    f"  predicted speedup x{adv.predicted_speedup:.1f}  "
    f"simulated x{adv.simulated_speedup:.1f}  certified={adv.certified}"
)
assert adv.optimal_geometry == (16, 1)
assert adv.certified and not adv.is_current_optimal
assert adv.predicted_speedup == 8.0 and adv.simulated_speedup == 8.0


# ---------------------------------------------------------------------------
# Section 7 analogue: predicted == simulated on the steady pattern, and the
# worst/best netsim gap.
# ---------------------------------------------------------------------------
print("\n== Flow-simulated all-to-all per geometry (netsim over fabric.links) ==")
makespans = {}
for g, _ in ranked:
    sub = POD.sub_fabric(g)
    sim = simulate_fabric_traffic(sub, all_to_all(sub.dims))
    pred = hyperx_all_to_all_max_load(sub)
    makespans[g] = sim.makespan
    assert sim.makespan == pred, (g, sim.makespan, pred)
    print(f"  {str(g):>8}: predicted x{pred:5.1f}  simulated x{sim.makespan:5.1f}")
gap = makespans[(8, 2)] / makespans[(16, 1)]
assert gap == 8.0
print(f"  -> worst/best simulated gap x{gap:.1f} (>= 1.5: geometry dominates)")


print("\n== What DAL routing recovers (minimal vs dimension-adaptive) ==")
pairing_cmp = compare_fabric_routing(POD, bisection_pairing(POD.dims))
hotspot_cmp = compare_fabric_routing(POD, hotspot_line(POD.dims))
print(
    f"  pairing on H{POD.dims}: makespan {pairing_cmp.dor_makespan:.2f} -> "
    f"{pairing_cmp.adaptive_makespan:.2f}, recovered "
    f"{100 * pairing_cmp.recovered_fraction:.0f}% "
    f"(steady pattern: routing cannot help — fix the partition)"
)
print(
    f"  hotspot line:      makespan {hotspot_cmp.dor_makespan:.2f} -> "
    f"{hotspot_cmp.adaptive_makespan:.2f}, recovered "
    f"{100 * hotspot_cmp.recovered_fraction:.0f}% "
    f"(skew-induced contention: routing helps)"
)
assert pairing_cmp.recovered_fraction == 0.0
assert hotspot_cmp.dor_makespan == 2.0
assert abs(hotspot_cmp.recovered_fraction - 2.0 / 7.0) < 1e-12


# ---------------------------------------------------------------------------
# The allocation-policy queue replay (Section 6's Table-6 setting).
# ---------------------------------------------------------------------------
def policy_replay(n_jobs: int, seed: int = 0):
    """Synthetic workload on H(16, 4): the isoperimetric policy (elongated
    boxes on HyperX) vs Mira-style fixed compact geometries."""
    rng = np.random.default_rng(seed)
    sizes = np.array([4, 8, 16])
    compact = ListPolicy({4: (2, 2), 8: (4, 2), 16: (4, 4)})
    rows = []
    size = rng.choice(sizes, size=n_jobs)
    arrival = np.cumsum(rng.exponential(0.3, size=n_jobs))
    duration = rng.lognormal(mean=0.0, sigma=0.5, size=n_jobs) + 0.3
    jobs = [
        JobRequest(i, int(size[i]), True, float(duration[i]), float(arrival[i]))
        for i in range(n_jobs)
    ]
    for pol in (IsoperimetricPolicy(), compact):
        res = simulate_queue(POD, jobs, pol, backfill=True)
        rows.append(
            {
                "policy": res.policy,
                "scheduled": len(res.jobs),
                "rejected": len(res.rejected),
                "mean_comm_time": res.mean_comm_time,
                "makespan": res.makespan,
            }
        )
    return rows


n_jobs = int(os.environ.get("REPLAY_JOBS", "200"))
print(f"\n== H{POD.dims} queue replay ({n_jobs} jobs, arrivals + EASY backfill) ==")
rows = policy_replay(n_jobs)
for r in rows:
    print(
        f"  {r['policy']:>14}: scheduled {r['scheduled']:4d}  "
        f"rejected {r['rejected']:3d}  comm {r['mean_comm_time']:.3f}  "
        f"makespan {r['makespan']:.1f}"
    )
iso, compact = rows
avoidable = compact["mean_comm_time"] / iso["mean_comm_time"]
print(
    f"  -> compact geometries cost x{avoidable:.2f} predicted comm time: "
    f"the avoidable contention an elongated-box policy removes on HyperX"
)
# The exact multiple depends on the size mix (x2 for 4-unit boxes up to x8
# for 16-unit ones); any mix must land strictly above 1.
assert avoidable >= 1.2, avoidable


# ---------------------------------------------------------------------------
# Cross-box contention is structurally zero (box closure).
# ---------------------------------------------------------------------------
print("\n== Per-job contention attribution (obs dashboard) ==")
machine = MachineState(POD)
machine.allocate(1, (16, 1))
machine.allocate(2, (8, 2))
report = attribute_contention(machine)
print(render_dashboard(report))
for job in report.jobs:
    assert job.cross_load == 0.0, job
print(
    "  -> cross-box load is exactly zero for every job: minimal/DAL paths\n"
    "     never leave an aligned box, so placement isolation is structural\n"
    "     on HyperX (no electrical partitioning needed)"
)


# ---------------------------------------------------------------------------
# The planner on a HyperX pod.
# ---------------------------------------------------------------------------
print("\n== Fleet planner on the HyperX pod ==")
plan = plan_model("mixtral-8x7b", UNITS, pod=POD, shape="decode_32k",
                  simulate_top_k=1)
print(format_table(plan, top=4))
assert plan.best.simulated_slowdown >= 1.0
assert {c.geometry for c in plan.table} == {(16, 1), (8, 2), (4, 4)}

print("\nAll HyperX goldens hold.")
