"""The paper's core analysis as a library walk-through: Blue Gene/Q partition
tables, contention predictions, the TPU-slice adaptation, and a Mira-scale
queue replay comparing allocation policies.

    PYTHONPATH=src python examples/partition_analysis.py

The replay size defaults to 400 jobs; set REPLAY_JOBS to scale it (the
vectorized placement engine handles thousands — the historical brute-force
scan could not).  ``--backend xla`` runs the closing candidate-scoring
study through the compiled dispatch path (requires jax; see DESIGN.md
"Compiled backends"), making the example a smoke test for it.
"""

import argparse
import os
import time

import numpy as np

from repro.core import (
    MIRA, JUQUEEN, TorusFabric, best_slice_geometry, worst_slice_geometry,
    mira_partition_table, pairing_speedup,
)
from repro.core.bgq import (
    MIDPLANE_DIMS,
    MIRA_SCHEDULER_PARTITIONS,
    node_dims_of_midplane_geometry as nd,
)
from repro.launch.mesh import plan_slice, pod_fabric
from repro.network import (
    HAVE_JAX,
    ContentionScoredPolicy,
    ElongatedPolicy,
    IsoperimetricPolicy,
    JobRequest,
    ListPolicy,
    bisection_pairing,
    compare_routing,
    hotspot_line,
    map_ranks,
    score_candidates,
    simulate_queue,
    simulate_traffic,
)
from repro.network.mapping import pattern_traffic, score_mapping
from repro.network.isoperimetry import advise_partition, advise_policy_table
from repro.network.placement import placement_all_to_all_traffic
from repro.network.routing import predict_pairing_time

print("== Mira partitions (paper Table 6): current vs isoperimetric-optimal ==")
for r in mira_partition_table():
    mark = f" -> {r['proposed_geometry']} bw={r['proposed_bw']}" if r["proposed_bw"] else ""
    print(f"  {r['midplanes']:3d} midplanes: {r['current_geometry']} bw={r['current_bw']}{mark}")

print("\n== Predicted contention speedups (paper Fig 3) ==")
for mp, cur, prop in [(4, (4,1,1,1), (2,2,1,1)), (16, (4,4,1,1), (2,2,2,2))]:
    s = pairing_speedup(nd(cur), nd(prop))
    print(f"  {mp} midplanes: x{s:.2f}")

print("\n== TPU v5e slice planning (the adaptation) ==")
for chips in (16, 32, 64):
    plan = plan_slice(chips)
    print(f"  {chips:3d} chips: best {plan.slice_geometry} (bisection {plan.slice_bisection_links}) "
          f"vs worst {plan.worst_geometry} ({plan.worst_bisection_links}) "
          f"-> avoidable contention x{plan.avoidable_contention:.1f}")


# ---------------------------------------------------------------------------
# The partition advisor (paper Tables 4-6 as a decision aid): for every size
# of Mira's scheduler list — and JUQUEEN's worst-vs-best baseline — the
# current geometry vs the isoperimetric optimum, the Theorem 3.1 optimality
# certificate, the predicted contention-bound speedup, and (for the sizes
# drained through the flow simulator) the simulated cross-check: steady
# pairing traffic makes simulated == predicted exactly, so the x2 geometry
# improvements are measured, not asserted.
# ---------------------------------------------------------------------------
SIMULATED_ADVISOR_SIZES = (4, 8, 16)  # node counts 2k-8k: seconds to drain


def _advice_line(name: str, a) -> str:
    line = (
        f"  {name:>8} {a.units:3d} midplanes: {a.current_geometry} "
        f"bw={a.current_bisection}"
    )
    if a.is_current_optimal:
        return line + "  (already optimal)"
    line += (
        f" -> {a.optimal_geometry} bw={a.optimal_bisection}"
        f"  efficiency {a.bisection_efficiency:.2f}"
        f"  predicted x{a.predicted_speedup:.2f}"
    )
    if a.simulated_speedup is not None:
        line += f"  simulated x{a.simulated_speedup:.2f}"
    if a.certified:
        line += "  [Thm 3.1 certified]"
    return line


print("\n== Partition advisor (paper Tables 4-6): policy table vs optimum ==")
for a in advise_policy_table(
    MIRA.midplane_dims, MIRA_SCHEDULER_PARTITIONS, unit_node_dims=MIDPLANE_DIMS
):
    if not a.is_current_optimal and a.units in SIMULATED_ADVISOR_SIZES:
        a = advise_partition(
            MIRA.midplane_dims, a.units, MIRA_SCHEDULER_PARTITIONS[a.units],
            unit_node_dims=MIDPLANE_DIMS, simulate=True,
        )
    print(_advice_line("Mira", a))
juqueen_advice = advise_partition(
    JUQUEEN.midplane_dims, 8, unit_node_dims=MIDPLANE_DIMS, simulate=True
)
print(_advice_line("JUQUEEN", juqueen_advice) + "  (worst-geometry baseline)")


# ---------------------------------------------------------------------------
# Rank mapping vs partition geometry: the allocator controls which cuboid a
# job gets; the mapping controls which rank runs on which cell of it.  For a
# fixed logical process grid, compare row-major rank order against the
# mapping engine's best embedding on the best and the worst slice geometry —
# how much of a bad partition's contention does a good mapping recover?
# ---------------------------------------------------------------------------
def mapping_recovery_study(pattern: str = "halo"):
    """Three regimes of a 16-chip job on the pod, fixed logical halo grid:
    the isoperimetric-best (4, 4) slice (row-major already optimal), the
    worst (16, 1) line (no relabeling can fix a line — the geometry itself
    must change: the paper's allocator-side claim), and a transposed
    (2, 8) landing of the logical (8, 2) grid (occupancy forced the
    orientation; the mapping engine recovers the loss entirely)."""
    pod = pod_fabric()
    plan = plan_slice(16)
    cases = [
        ("best", plan.slice_geometry, (4, 4)),
        ("worst", plan.worst_geometry, (4, 4)),
        ("transposed", (2, 8), (8, 2)),
    ]
    rows = []
    for label, oriented, logical in cases:
        oriented = tuple(oriented) + (1,) * (len(pod.dims) - len(oriented))
        m = map_ranks(
            pod.dims, oriented, (0,) * len(pod.dims),
            logical_dims=logical, pattern=pattern,
            double_link_on_2=pod.double_link_on_2,
        )
        rows.append(
            {
                "which": label,
                "geometry": tuple(oriented[:2]),
                "logical": logical,
                "identity_congestion": m.identity_score.congestion,
                "mapped_congestion": m.score.congestion,
                "strategy": m.strategy,
            }
        )
    return rows


# ---------------------------------------------------------------------------
# Predicted vs simulated contention (the paper's §7 validation leg): route
# the pairing benchmark through the flow-level simulator and compare the
# measured slowdown against the static max-link-load prediction — for
# steady patterns they coincide, so the x2 geometry gap is *derived* from
# dynamics, not asserted.
# ---------------------------------------------------------------------------
def netsim_validation_table():
    """Pairing-benchmark slowdowns, predicted and simulated, for 512-node
    (one-midplane's-worth) cuboid tori on Mira's node fabric plus the
    scheduler-vs-proposed partition pairs of both machines at node level."""
    rows = []
    for label, node_dims in [
        ("512-node best (8,8,8)", (8, 8, 8)),
        ("512-node mid (16,8,4)", (16, 8, 4)),
        ("512-node worst (16,16,2)", (16, 16, 2)),
    ]:
        predicted = predict_pairing_time(node_dims, 1.0, 1.0).max_link_load
        sim = simulate_traffic(node_dims, bisection_pairing(node_dims))
        rows.append(
            {
                "which": label,
                "node_dims": node_dims,
                "predicted": predicted,
                "simulated": sim.slowdown,
                "steps": sim.steps,
            }
        )
    pairs = []
    for machine, midplanes in [(MIRA, 4), (JUQUEEN, 8)]:
        worst = machine.worst_partition(midplanes)[0]
        best = machine.best_partition(midplanes)[0]
        ratio = {}
        for which, geom in [("worst", worst), ("best", best)]:
            node_dims = nd(geom)
            sim = simulate_traffic(node_dims, bisection_pairing(node_dims))
            ratio[which] = {
                "geometry": geom,
                "node_dims": node_dims,
                "predicted": predict_pairing_time(node_dims, 1.0, 1.0).max_link_load,
                "simulated": sim.slowdown,
            }
        pairs.append({"machine": machine.name, "midplanes": midplanes, **ratio})
    return rows, pairs


def routing_recovery_study():
    """DOR vs minimal-adaptive on two kinds of contention: the pairing
    benchmark's geometry-induced load (uniform — routing recovers nothing,
    the paper's case for fixing partition shape) and a skewed hotspot line
    (routing recovers half)."""
    pairing = compare_routing((16, 16, 2), bisection_pairing((16, 16, 2)))
    hotspot = compare_routing((8, 8), hotspot_line((8, 8)))
    return pairing, hotspot


def simulated_contention_replay(n_jobs: int):
    """Mira + JUQUEEN queue replays under contention="simulated": every
    placed job's traffic drains through the flow simulator against the
    placements live at its start, and the per-job completion is compared
    with the static max-load bound.  On cuboid-allocated BG/Q tori the
    simulated slowdown is ~1.0 on every job — the paper's partition-
    isolation property, confirmed dynamically — while a forced span-5
    spill sharing JUQUEEN's 7-ring corridor shows the simulator charging
    real completion time when isolation is violated."""
    rows = []
    cases = [
        ("Mira", MIRA.midplane_dims, [2, 4, 6, 8, 12, 16, 24]),
        ("JUQUEEN", JUQUEEN.midplane_dims, [2, 4, 6, 8, 12, 16, 20]),
    ]
    for name, dims, sizes in cases:
        rng = np.random.default_rng(0)
        sizes = np.array(sizes)
        size = rng.choice(sizes, size=n_jobs)
        arrival = np.cumsum(rng.exponential(0.3, size=n_jobs))
        duration = rng.lognormal(mean=0.0, sigma=0.5, size=n_jobs) + 0.3
        jobs = [
            JobRequest(i, int(size[i]), True, float(duration[i]), float(arrival[i]))
            for i in range(n_jobs)
        ]
        res = simulate_queue(
            dims, jobs, IsoperimetricPolicy(), MIDPLANE_DIMS,
            backfill=True, contention="simulated",
        )
        slowdowns = [j.simulated_slowdown for j in res.jobs]
        rows.append(
            {
                "machine": name,
                "scheduled": len(res.jobs),
                "all_bounded": all(
                    j.simulated_comm_time + 1e-9 >= j.comm_lower_bound
                    for j in res.jobs
                ),
                "mean_slowdown": float(np.mean(slowdowns)) if slowdowns else 1.0,
                "max_slowdown": float(np.max(slowdowns)) if slowdowns else 1.0,
            }
        )
    # The violation demo: a span-5 job spills over the 7-ring; a 2-wide
    # job lives in the corridor it routes through.
    demo_dims = (7, 2, 2)
    big = placement_all_to_all_traffic(demo_dims, (5, 2, 2), (0, 0, 0))
    small = placement_all_to_all_traffic(demo_dims, (2, 2, 2), (5, 0, 0))
    joint = tuple(np.concatenate(parts) for parts in zip(big, small))
    res = simulate_traffic(demo_dims, joint)
    t_small = float(res.completion[big[2].shape[0]:].max())
    solo_small = simulate_traffic(demo_dims, small).makespan
    demo = {"dims": demo_dims, "slowdown": t_small / solo_small}
    return rows, demo


# ---------------------------------------------------------------------------
# Mira-scale queue replay (paper Section 6 / Table 6 setting): a synthetic
# job stream over Mira's 4x4x3x2 midplane torus, replayed under four
# allocation policies with arrivals and EASY backfill.
# ---------------------------------------------------------------------------
def mira_queue_replay(n_jobs: int, seed: int = 0):
    """Synthetic Mira workload: sizes from the scheduler's partition list,
    Poisson-ish arrivals, heavy-tailed durations.  Deterministic per seed."""
    rng = np.random.default_rng(seed)
    sizes = np.array([1, 2, 4, 8, 16, 24, 32, 48])
    weights = np.array([0.25, 0.2, 0.18, 0.15, 0.1, 0.05, 0.04, 0.03])
    weights = weights / weights.sum()
    size = rng.choice(sizes, size=n_jobs, p=weights)
    arrival = np.cumsum(rng.exponential(0.25, size=n_jobs))
    duration = rng.lognormal(mean=0.0, sigma=0.6, size=n_jobs) + 0.2
    return [
        JobRequest(i, int(size[i]), True, float(duration[i]), float(arrival[i]))
        for i in range(n_jobs)
    ]


def replay_policies(n_jobs: int, seed: int = 0, backfill: bool = True):
    jobs = mira_queue_replay(n_jobs, seed)
    policies = [
        ElongatedPolicy(),
        ListPolicy(MIRA_SCHEDULER_PARTITIONS),
        IsoperimetricPolicy(),
        ContentionScoredPolicy(),
    ]
    rows = []
    for pol in policies:
        t0 = time.perf_counter()
        res = simulate_queue(
            MIRA.midplane_dims, jobs, pol, MIDPLANE_DIMS,
            backfill=backfill, measure_contention=True,
        )
        dt = time.perf_counter() - t0
        rows.append(
            {
                "policy": res.policy,
                "scheduled": len(res.jobs),
                "rejected": len(res.rejected),
                "mean_comm_time": res.mean_comm_time,
                "mean_wait": res.mean_wait,
                "makespan": res.makespan,
                "mean_contention": res.mean_contention,
                "replay_s": dt,
            }
        )
    return rows


def juqueen_shared_fabric_replay(n_jobs: int, seeds=(0, 1, 2, 3)):
    """Interference replay on JUQUEEN's 7x2x2x2 midplane torus, treating the
    fabric as *shared* (no electrical partition isolation).  Long spans on
    the 7-ring route all-to-all traffic through foreign midplanes, so
    placements can measurably load links that neighbours use; the
    contention-scored policy minimises exactly that.  Two findings:
    interference is *rare* even without isolation (the paper's
    partition-isolation assumption is robust — on Mira's 4x4x3x2 torus it
    is structurally zero, since no span exceeds half its ring), and where
    it occurs the scored policy carries the least of it, so results are
    averaged over several workload seeds.
    """
    rows = {}
    for seed in seeds:
        rng = np.random.default_rng(seed)
        sizes = np.array([4, 5, 6, 8, 10, 12, 20, 24])
        size = rng.choice(sizes, size=n_jobs)
        arrival = np.cumsum(rng.exponential(0.3, size=n_jobs))
        duration = rng.lognormal(mean=0.0, sigma=0.5, size=n_jobs) + 0.3
        jobs = [
            JobRequest(i, int(size[i]), True, float(duration[i]), float(arrival[i]))
            for i in range(n_jobs)
        ]
        for pol in (ElongatedPolicy(), IsoperimetricPolicy(), ContentionScoredPolicy()):
            res = simulate_queue(
                JUQUEEN.midplane_dims, jobs, pol, MIDPLANE_DIMS,
                backfill=True, measure_contention=True,
            )
            row = rows.setdefault(
                res.policy,
                {"policy": res.policy, "scheduled": 0, "comm": [], "contention": []},
            )
            row["scheduled"] += len(res.jobs)
            row["comm"].append(res.mean_comm_time)
            row["contention"].append(res.mean_contention)
    return [
        {
            "policy": r["policy"],
            "scheduled": r["scheduled"],
            "mean_comm_time": float(np.mean(r["comm"])),
            "mean_contention": float(np.mean(r["contention"])),
        }
        for r in rows.values()
    ]


def replay_mapping_study(n_jobs: int, pattern: str = "ring"):
    """Mira + JUQUEEN queue replays with per-job rank mapping applied: every
    placed job's ring-collective traffic is embedded by the mapping engine,
    and the replay reports the mean intra-job congestion of row-major rank
    order vs the chosen mapping — the contention a scheduler-side remap
    recovers without moving a single allocation."""
    rows = []
    for name, dims in [("Mira", MIRA.midplane_dims), ("JUQUEEN", JUQUEEN.midplane_dims)]:
        rng = np.random.default_rng(0)
        sizes = np.array([2, 4, 6, 8, 12, 16])
        size = rng.choice(sizes, size=n_jobs)
        arrival = np.cumsum(rng.exponential(0.3, size=n_jobs))
        duration = rng.lognormal(mean=0.0, sigma=0.5, size=n_jobs) + 0.3
        jobs = [
            JobRequest(i, int(size[i]), True, float(duration[i]), float(arrival[i]))
            for i in range(n_jobs)
        ]
        res = simulate_queue(
            dims, jobs, IsoperimetricPolicy(), MIDPLANE_DIMS,
            backfill=True, measure_contention=True, mapping_pattern=pattern,
        )
        mapped = [j.mapping for j in res.jobs if j.mapping is not None]
        rows.append(
            {
                "machine": name,
                "scheduled": len(res.jobs),
                "identity_congestion": float(
                    np.mean([m.identity_score.congestion for m in mapped])
                ) if mapped else 0.0,
                "mapped_congestion": float(
                    np.mean([m.score.congestion for m in mapped])
                ) if mapped else 0.0,
                "remapped_jobs": sum(1 for m in mapped if m.strategy != "identity"),
            }
        )
    return rows


def scoring_throughput_study(backend: str, batch: int = 512):
    """Time advisor-scale candidate scoring: the sequential ``score_mapping``
    loop vs one batched ``score_candidates`` call under the selected
    backend — the example's smoke test for the compiled dispatch path."""
    dims, ranks, logical = (4, 4, 3, 2), 24, (4, 3, 2)
    traffic = pattern_traffic(logical, "pairing")
    rng = np.random.default_rng(0)
    n_cells = int(np.prod(dims))
    cells = np.stack([rng.choice(n_cells, ranks, replace=False) for _ in range(batch)])
    coords = np.stack(np.unravel_index(cells, dims), axis=-1).astype(np.int64)

    t0 = time.perf_counter()
    seq = [score_mapping(dims, coords[i], traffic) for i in range(batch)]
    t_seq = time.perf_counter() - t0

    if backend == "xla":  # warm the jit cache at the production batch shape
        score_candidates(dims, coords, traffic, backend=backend)
    t0 = time.perf_counter()
    cong, dil = score_candidates(dims, coords, traffic, backend=backend)
    t_batch = time.perf_counter() - t0

    assert all(cong[i] == s.congestion and dil[i] == s.dilation
               for i, s in enumerate(seq)), "batched scores diverge"
    return {
        "backend": backend,
        "batch": batch,
        "seq_per_s": batch / t_seq,
        "batch_per_s": batch / t_batch,
        "speedup": t_seq / t_batch,
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--backend", choices=("numpy", "xla"), default="numpy",
        help="network-engine backend for the candidate-scoring study",
    )
    cli = ap.parse_args()
    if cli.backend == "xla" and not HAVE_JAX:
        raise SystemExit("--backend xla requires jax (pip install 'jax[cpu]')")
    n_jobs = int(os.environ.get("REPLAY_JOBS", "400"))
    print(f"\n== Mira queue replay ({n_jobs} jobs, arrivals + EASY backfill) ==")
    rows = replay_policies(n_jobs)
    for r in rows:
        print(
            f"  {r['policy']:>18}: scheduled {r['scheduled']:4d}  rejected {r['rejected']:3d}  "
            f"comm {r['mean_comm_time']:.3f}  wait {r['mean_wait']:.2f}  "
            f"makespan {r['makespan']:.1f}  shared-link {r['mean_contention']:.1f}  "
            f"({r['replay_s']:.2f}s)"
        )
    iso = next(r for r in rows if r["policy"] == "isoperimetric")
    elo = next(r for r in rows if r["policy"] == "elongated")
    print(
        f"  -> isoperimetric vs elongated predicted comm time: "
        f"x{elo['mean_comm_time'] / iso['mean_comm_time']:.2f} avoidable"
    )

    print(f"\n== JUQUEEN shared-fabric replay ({n_jobs // 3} jobs x 4 seeds) ==")
    for r in juqueen_shared_fabric_replay(n_jobs // 3):
        print(
            f"  {r['policy']:>18}: scheduled {r['scheduled']:4d}  "
            f"comm {r['mean_comm_time']:.3f}  shared-link {r['mean_contention']:.4f}"
        )

    print("\n== Rank mapping vs partition geometry (16 chips, halo traffic) ==")
    study = mapping_recovery_study()
    for r in study:
        print(
            f"  {r['which']:>10} {r['geometry']} <- logical {r['logical']}: "
            f"row-major congestion {r['identity_congestion']:.1f} -> mapped "
            f"{r['mapped_congestion']:.1f} ({r['strategy']})"
        )
    best, worst, transposed = study
    recovered = transposed["identity_congestion"] - transposed["mapped_congestion"]
    print(
        f"  -> a transposed landing costs x"
        f"{transposed['identity_congestion'] / best['identity_congestion']:.1f} under "
        f"row-major; the mapping engine recovers {recovered:.1f} of it "
        f"(back to x{transposed['mapped_congestion'] / best['identity_congestion']:.1f}) — "
        f"but no relabeling fixes the {worst['geometry']} line: partition geometry "
        f"is the allocator's job (the paper), mapping recovers what the landing lost"
    )

    print(f"\n== Queue replay with per-job rank mapping ({n_jobs // 4} jobs, ring traffic) ==")
    for r in replay_mapping_study(n_jobs // 4):
        print(
            f"  {r['machine']:>8}: scheduled {r['scheduled']:4d}  "
            f"row-major congestion {r['identity_congestion']:.2f} -> mapped "
            f"{r['mapped_congestion']:.2f}  (remapped {r['remapped_jobs']} jobs)"
        )

    print("\n== Predicted vs simulated contention (flow-level netsim, pairing benchmark) ==")
    rows, pairs = netsim_validation_table()
    for r in rows:
        print(
            f"  {r['which']:>26}: predicted x{r['predicted']:.1f}  "
            f"simulated x{r['simulated']:.2f}  ({r['steps']} sim steps)"
        )
    best, _, worst = rows
    print(
        f"  -> 512-node worst/best simulated ratio: "
        f"x{worst['simulated'] / best['simulated']:.2f} "
        f"(the paper's ~2x avoidable-contention gap, derived dynamically)"
    )
    for p in pairs:
        print(
            f"  {p['machine']:>8} {p['midplanes']}-midplane "
            f"worst {p['worst']['geometry']} vs best {p['best']['geometry']}: "
            f"predicted x{p['worst']['predicted'] / p['best']['predicted']:.2f}, "
            f"simulated x{p['worst']['simulated'] / p['best']['simulated']:.2f}"
        )

    print("\n== What routing alone recovers (DOR vs minimal-adaptive) ==")
    pairing_cmp, hotspot_cmp = routing_recovery_study()
    print(
        f"  pairing on (16, 16, 2): makespan {pairing_cmp.dor_makespan:.1f} -> "
        f"{pairing_cmp.adaptive_makespan:.1f}, recovered "
        f"{100 * pairing_cmp.recovered_fraction:.0f}% "
        f"(geometry-induced contention: routing cannot help — fix the partition)"
    )
    print(
        f"  hotspot line on (8, 8): makespan {hotspot_cmp.dor_makespan:.1f} -> "
        f"{hotspot_cmp.adaptive_makespan:.1f}, recovered "
        f"{100 * hotspot_cmp.recovered_fraction:.0f}% "
        f"(skew-induced contention: routing helps)"
    )

    print(f"\n== Queue replay with simulated contention ({n_jobs // 2} jobs) ==")
    sim_rows, demo = simulated_contention_replay(n_jobs // 2)
    for r in sim_rows:
        print(
            f"  {r['machine']:>8}: scheduled {r['scheduled']:4d}  "
            f"all jobs >= static bound: {r['all_bounded']}  "
            f"mean slowdown x{r['mean_slowdown']:.3f}  max x{r['max_slowdown']:.3f}"
        )
    print(
        f"  -> cuboid allocation keeps simulated slowdowns at ~1.0 (partition "
        f"isolation, now derived); forcing a span-5 spill beside a corridor job "
        f"on {demo['dims']} slows the small job x{demo['slowdown']:.2f}"
    )

    print(f"\n== Candidate-scoring throughput (backend={cli.backend}) ==")
    thr = scoring_throughput_study(cli.backend)
    print(
        f"  {thr['batch']} candidate mappings, 24-rank pairing job on (4, 4, 3, 2): "
        f"sequential loop {thr['seq_per_s']:,.0f} candidates/s -> "
        f"score_candidates[{thr['backend']}] {thr['batch_per_s']:,.0f} candidates/s "
        f"(x{thr['speedup']:.1f})"
    )
