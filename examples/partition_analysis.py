"""The paper's core analysis as a library walk-through: Blue Gene/Q partition
tables, contention predictions, and the TPU-slice adaptation.

    PYTHONPATH=src python examples/partition_analysis.py
"""

from repro.core import (
    MIRA, JUQUEEN, TorusFabric, best_slice_geometry, worst_slice_geometry,
    mira_partition_table, pairing_speedup,
)
from repro.core.bgq import node_dims_of_midplane_geometry as nd
from repro.launch.mesh import plan_slice

print("== Mira partitions (paper Table 6): current vs isoperimetric-optimal ==")
for r in mira_partition_table():
    mark = f" -> {r['proposed_geometry']} bw={r['proposed_bw']}" if r["proposed_bw"] else ""
    print(f"  {r['midplanes']:3d} midplanes: {r['current_geometry']} bw={r['current_bw']}{mark}")

print("\n== Predicted contention speedups (paper Fig 3) ==")
for mp, cur, prop in [(4, (4,1,1,1), (2,2,1,1)), (16, (4,4,1,1), (2,2,2,2))]:
    s = pairing_speedup(nd(cur), nd(prop))
    print(f"  {mp} midplanes: x{s:.2f}")

print("\n== TPU v5e slice planning (the adaptation) ==")
for chips in (16, 32, 64):
    plan = plan_slice(chips)
    print(f"  {chips:3d} chips: best {plan.slice_geometry} (bisection {plan.slice_bisection_links}) "
          f"vs worst {plan.worst_geometry} ({plan.worst_bisection_links}) "
          f"-> avoidable contention x{plan.avoidable_contention:.1f}")
