"""Telemetry walk-through: trace a scheduler replay, derive metrics from
its event log, and attribute per-link contention on the live machine.

Demonstrates the ``repro.obs`` subsystem end-to-end:

1. tracing is enabled (it is off by default) and a seeded bursty
   scenario on a 16^3 torus runs through the event-sourced service —
   every ``scheduler.step`` / ``scheduler.place`` / ``placement.search``
   boundary becomes a span, exported as a Chrome trace-event JSON
   (load it at ``chrome://tracing`` or https://ui.perfetto.dev);
2. ``scheduler_metrics`` derives counters, gauges, and latency
   histograms purely from the event log, so a replayed service would
   reproduce the snapshot exactly;
3. ``attribute_contention`` decomposes the machine's all-to-all link
   field by owning job and prices each placement against the
   isoperimetry engine's certified optimum — the avoidable-contention
   gauge of the paper.  A deliberately bad (16,16,2) slab next to the
   optimal (8,8,8) cube shows the 2x avoidable pairing load of
   Theorem 3.1 in the dashboard.

Run: PYTHONPATH=src python examples/telemetry_dashboard.py
(TELEM_JOBS scales the workload, default 80; writes trace.json,
metrics.json, and contention.json to TELEM_OUT_DIR, default cwd.)
"""

from __future__ import annotations

import json
import os
from pathlib import Path

import repro.obs as obs
from repro.network import MachineState
from repro.network.allocation import ContentionScoredPolicy
from repro.network.scheduler import generate_scenario, run_scenario
from repro.obs.contention import attribute_contention, render_dashboard
from repro.obs.metrics import scheduler_metrics

DIMS = (16, 16, 16)
N_JOBS = int(os.environ.get("TELEM_JOBS", "80"))
OUT_DIR = Path(os.environ.get("TELEM_OUT_DIR", "."))


def main() -> None:
    scenario = generate_scenario(
        DIMS,
        N_JOBS,
        seed=11,
        burst_gap=30.0,
        mean_duration=80.0,
        failure_rate=0.002,
        repair_delay=150.0,
    )

    OUT_DIR.mkdir(parents=True, exist_ok=True)

    # 1. traced run -> Chrome trace
    obs.enable_tracing(clear=True)
    service = run_scenario(scenario, ContentionScoredPolicy(), backfill=True)
    obs.disable_tracing()
    trace_path = OUT_DIR / "trace.json"
    obs.export_chrome_trace(trace_path)
    events = obs.TRACER.events()
    names = sorted({e["name"] for e in events})
    print(f"machine {DIMS}, {N_JOBS} jobs -> {len(events)} spans "
          f"({', '.join(names)})")
    print(f"chrome trace: {trace_path} (open in chrome://tracing)")

    # 2. metrics derived from the event log
    registry = scheduler_metrics(service)
    snap = registry.snapshot()
    metrics_path = OUT_DIR / "metrics.json"
    registry.export(metrics_path)
    waits = snap["histograms"]["scheduler.wait_time"]
    print(f"metrics: {len(snap['counters'])} counters, "
          f"{len(snap['gauges'])} gauges, {len(snap['histograms'])} histograms "
          f"-> {metrics_path}")
    print(f"  utilization {snap['gauges']['scheduler.utilization']:.3f}, "
          f"waits n={waits['count']} mean={waits['sum'] / max(waits['count'], 1):.1f}")

    # 3. avoidable-contention attribution on a live machine: the paper's
    #    (8,8,8)-vs-(16,16,2) pair — same 512 units, 2x the pairing load.
    machine = MachineState(DIMS)
    machine.allocate(0, (8, 8, 8))
    machine.allocate(1, (16, 16, 2))
    report = attribute_contention(machine)
    print()
    print(render_dashboard(report))
    contention_path = OUT_DIR / "contention.json"
    contention_path.write_text(report.to_json())
    print(f"contention report: {contention_path}")

    by_id = {j.job_id: j for j in report.jobs}
    assert abs(by_id[0].avoidable_excess) < 1e-9
    assert abs(by_id[1].avoidable_ratio - 2.0) < 1e-9
    doc = json.loads(trace_path.read_text())
    assert doc["traceEvents"], "trace export is empty"


if __name__ == "__main__":
    main()
