"""Streaming-scheduler walk-through: a seeded bursty scenario with node
failures driven through the event-sourced service, then replayed.

Demonstrates the PR-7 subsystem end-to-end:

1. ``generate_scenario`` draws a bursty, heavy-tailed workload for a
   16^3 torus (Pareto job sizes snapped to axis-divisor cuboid volumes,
   log-normal durations) plus Poisson cell failures with delayed repairs.
2. ``SchedulerService`` schedules it online under the isoperimetric
   policy with backfill, logging every event; failures evacuate and
   requeue their victims, repairs return cells to the pool.
3. ``replay_events`` re-drives a fresh service from the log's *input*
   records and must reproduce the run record-for-record.

Run: PYTHONPATH=src python examples/streaming_scheduler.py
(SCHED_JOBS scales the workload; default 150.)
"""

from __future__ import annotations

import os
import time
from collections import Counter

from repro.network import IsoperimetricPolicy, replay_events
from repro.network.scheduler import generate_scenario, run_scenario

DIMS = (16, 16, 16)
N_JOBS = int(os.environ.get("SCHED_JOBS", "150"))


def main() -> None:
    scenario = generate_scenario(
        DIMS,
        N_JOBS,
        seed=11,
        burst_gap=30.0,
        mean_duration=80.0,
        failure_rate=0.002,
        repair_delay=150.0,
    )
    policy = IsoperimetricPolicy()

    t0 = time.perf_counter()
    service = run_scenario(scenario, policy, backfill=True)
    elapsed = time.perf_counter() - t0

    kinds = Counter(e.kind for e in service.log)
    print(f"machine {DIMS}, {N_JOBS} jobs, {len(scenario.failures)} failure events")
    print(f"processed {service.events_processed} events in {elapsed:.2f}s "
          f"({service.events_processed / elapsed:.0f} events/s)")
    print("log breakdown:", dict(sorted(kinds.items())))
    print(f"scheduled segments: {len(service.scheduled)}, "
          f"rejected: {len(service.rejected)}, shed: {len(service.shed)}")

    makespan = max((j.end for j in service.scheduled), default=0.0)
    print(f"makespan: {makespan:.1f}")

    replayed = replay_events(DIMS, policy, service.log, backfill=True)
    assert replayed.log == service.log, "replay diverged from the original run"
    print(f"replay: {len(replayed.log)} records reproduced record-for-record")


if __name__ == "__main__":
    main()
