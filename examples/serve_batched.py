"""Batched serving example: prefill + greedy decode on a reduced model.

    PYTHONPATH=src python examples/serve_batched.py
"""

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    tps = serve_main(["--arch", "granite-3-8b", "--requests", "8",
                      "--prompt-len", "16", "--gen-len", "24"])
    assert tps > 0
    print("serve example OK")
