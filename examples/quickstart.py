"""Quickstart: train a reduced LM for 60 steps and watch the loss drop.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.launch.train import main as train_main

if __name__ == "__main__":
    first, last = train_main(
        ["--arch", "granite-3-8b", "--steps", "60", "--batch", "8",
         "--seq", "64", "--lr", "3e-3", "--log-every", "10"]
    )
    assert last < first, "loss did not decrease"
    print(f"quickstart OK: {first:.3f} -> {last:.3f}")
