"""Fault-tolerant training: checkpoint, injected failure, restore, resume.

    PYTHONPATH=src python examples/fault_tolerant_training.py
"""

import tempfile

from repro.launch.train import main as train_main

if __name__ == "__main__":
    with tempfile.TemporaryDirectory() as d:
        first, last = train_main(
            ["--arch", "granite-3-8b", "--steps", "60", "--batch", "4",
             "--seq", "32", "--ckpt-dir", d, "--ckpt-every", "10",
             "--simulate-failure-at", "25", "--log-every", "10"]
        )
        # resume from the final checkpoint and continue
        first2, last2 = train_main(
            ["--arch", "granite-3-8b", "--steps", "80", "--batch", "4",
             "--seq", "32", "--ckpt-dir", d, "--resume", "--log-every", "10"]
        )
    assert last < first
    print(f"fault-tolerant run OK: {first:.3f} -> {last:.3f}, resumed -> {last2:.3f}")
