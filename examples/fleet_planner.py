"""Fleet planning walk-through: the joint geometry x mapping x sharding
search over every registered architecture, with each plan's communication
prediction reproduced *standalone* from the public primitives.

    PYTHONPATH=src python examples/fleet_planner.py

For every config in ``repro.configs`` the planner enumerates partition
geometries (``ranked_slice_geometries``), rank mappings (``map_ranks``'s
catalogue), and (data, fsdp, tensor, expert) sharding rules, prices each
triple against the roofline + collective cost models, and emits a ranked
:class:`SlicePlan`.  The example then re-derives each winner's comm time
outside the planner — ``assign_axes(mapping=)`` + ``COLLECTIVE_TIME`` for
the ring collectives, the flow simulator on the bisection pairing pattern
for the data-parallel pairing — and asserts exact agreement, which is the
paper's "static prediction == steady-state simulation" property applied
to the whole model fleet.
"""

import math

from repro.configs import all_archs
from repro.launch.planner import format_table, plan_fleet
from repro.network.collectives import COLLECTIVE_TIME, assign_axes
from repro.network.netsim import simulate_traffic
from repro.network.patterns import bisection_pairing


def reproduce_comm(cand) -> None:
    """Re-derive one plan row's comm time from the public primitives."""
    assignment = assign_axes(
        cand.fabric, cand.rule.mesh_shape,
        order_hint=cand.rule.order_hint, mapping=cand.mapping,
    )
    ring = 0.0
    for axis, collective, vol in cand.traffic:
        ring += COLLECTIVE_TIME[collective](
            vol, assignment.embedding(axis), cand.fabric.link_bw
        )
    assert ring == cand.ring_time, (ring, cand.ring_time)
    pairing = 0.0
    if cand.pair_volume_node > 0.0:
        sim = simulate_traffic(
            cand.node_dims,
            bisection_pairing(cand.node_dims),
            link_bw=cand.fabric.link_bw,
            double_link_on_2=cand.fabric.double_link_on_2,
        )
        pairing = cand.pair_volume_node * sim.makespan
    assert math.isclose(pairing, cand.pairing_time, rel_tol=1e-9, abs_tol=0.0) or (
        pairing == cand.pairing_time == 0.0
    ), (pairing, cand.pairing_time)


def main():
    plans = plan_fleet(simulate_top_k=1)
    assert len(plans) == len(all_archs())
    print("=== Fleet plans: one ranked table per registered architecture ===\n")
    for plan in plans:
        print(format_table(plan, top=4))
        reproduce_comm(plan.best)
        assert plan.simulated_slowdown >= 1.0
        print(
            f"  comm reproduced standalone: ring {plan.best.ring_time * 1e3:.3f} ms"
            f" + pairing {plan.best.pairing_time * 1e3:.3f} ms (exact)\n"
        )
    print(f"all {len(plans)} plans verified: planner comm == assign_axes(mapping=)"
          " + netsim, simulated slowdown >= 1")
    return plans


if __name__ == "__main__":
    main()
