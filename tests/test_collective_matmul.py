"""Collective-matmul tests: degenerate 1-device mesh inline, 8-device mesh in
a subprocess (the session's jax is pinned to 1 CPU device)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.collective_matmul import allgather_matmul, matmul_reducescatter


def test_single_device_degenerate():
    mesh = jax.make_mesh((1,), ("model",))
    k1, k2 = jax.random.split(jax.random.key(0))
    x = jax.random.normal(k1, (8, 4))
    w = jax.random.normal(k2, (4, 6))
    np.testing.assert_allclose(
        np.asarray(allgather_matmul(x, w, mesh, "model")), np.asarray(x @ w), rtol=1e-5
    )
    x2 = jax.random.normal(k1, (8, 16))
    w2 = jax.random.normal(k2, (16, 6))
    np.testing.assert_allclose(
        np.asarray(matmul_reducescatter(x2, w2, mesh, "model")),
        np.asarray(x2 @ w2),
        rtol=1e-5,
    )


SUBPROCESS_PROG = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.collective_matmul import allgather_matmul, matmul_reducescatter
    mesh = jax.make_mesh((8,), ("model",))
    k1, k2 = jax.random.split(jax.random.key(0))
    x = jax.random.normal(k1, (32, 16)); w = jax.random.normal(k2, (16, 24))
    np.testing.assert_allclose(np.asarray(allgather_matmul(x, w, mesh, "model")),
                               np.asarray(x @ w), rtol=1e-5)
    x2 = jax.random.normal(k1, (32, 64)); w2 = jax.random.normal(k2, (64, 24))
    np.testing.assert_allclose(np.asarray(matmul_reducescatter(x2, w2, mesh, "model")),
                               np.asarray(x2 @ w2), rtol=1e-4, atol=1e-4)
    print("OK8")
    """
)


def test_eight_device_ring_subprocess():
    import repro

    src = str(__import__("pathlib").Path(repro.__file__).resolve().parents[1])
    out = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_PROG],
        capture_output=True,
        text=True,
        timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": src, "XLA_FLAGS": ""},
    )
    assert "OK8" in out.stdout, out.stderr[-2000:]


def test_ring_emits_collective_permutes_not_allgather():
    """The point of the pattern: permutes (overlappable) replace the
    monolithic gather."""
    mesh = jax.make_mesh((1,), ("model",))
    x = jnp.zeros((8, 16))
    w = jnp.zeros((16, 6))
    txt = jax.jit(lambda a, b: matmul_reducescatter(a, b, mesh, "model")).lower(x, w).as_text()
    assert "all_gather" not in txt and "all-gather" not in txt
