"""Tier-1 enforcement of the docs gate (`tools/check_docs.py`): doctests
over the audited ``repro.network`` modules, docstring coverage of every
exported symbol, README/DESIGN python code blocks executing, and the
README quickstart commands matching `.github/workflows/ci.yml` verbatim.

The CI ``docs`` job runs the same script standalone; running it under
pytest too means a drifted docstring or README block fails the tier-1
suite locally, before CI."""

import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def test_docs_gate_passes():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py")],
        capture_output=True,
        text=True,
        env=env,
        timeout=300,
    )
    assert proc.returncode == 0, f"docs gate failed:\n{proc.stderr}\n{proc.stdout}"
    assert "all OK" in proc.stdout
