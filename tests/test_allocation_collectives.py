"""Tests for the allocation policy engine, TPU collective cost model and
topology extensions."""

import itertools
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.bgq import MIDPLANE_DIMS, MIRA_SCHEDULER_PARTITIONS
from repro.network import (
    AxisEmbedding,
    CollectiveCostModel,
    ElongatedPolicy,
    HintedPolicy,
    IsoperimetricPolicy,
    JobRequest,
    ListPolicy,
    MachineState,
    TorusFabric,
    assign_axes,
    avoidable_contention_ratio,
    best_slice_geometry,
    ring_all_gather_time,
    ring_all_reduce_time,
    simulate_queue,
    slice_fabric,
    worst_slice_geometry,
)
from repro.core.topology import (
    DragonflyGroup,
    HyperX,
    hypercube_bisection,
    hypercube_harper_bound,
    _harper_rec,
)


# ---------------------------------------------------------------------------
# Allocation
# ---------------------------------------------------------------------------
def test_machine_state_allocate_release():
    m = MachineState((4, 2, 2, 2))
    p = m.allocate(1, (2, 2, 1, 1))
    assert p is not None and m.free_units == 32 - 4
    m.release(1)
    assert m.free_units == 32


def test_placement_respects_occupancy():
    m = MachineState((2, 2, 1, 1))
    assert m.allocate(1, (2, 1, 1, 1)) is not None
    assert m.allocate(2, (2, 1, 1, 1)) is not None
    assert m.allocate(3, (1, 1, 1, 1)) is None  # machine full


def test_isoperimetric_policy_prefers_balanced_geometry():
    m = MachineState((7, 2, 2, 2))
    prefs = IsoperimetricPolicy().geometry_preferences(m, 8)
    assert prefs[0] == (2, 2, 2, 1)
    worst = ElongatedPolicy().geometry_preferences(m, 8)
    assert worst[0][0] == 7 or worst[0][0] == 4  # most elongated that fits
    # elongated prefers the longest first dimension available
    assert worst[0][0] >= prefs[0][0]


def test_queue_simulation_policies_differ_in_comm_time():
    jobs = [JobRequest(i, 8, True, 1.0) for i in range(3)]
    iso = simulate_queue((7, 2, 2, 2), jobs, IsoperimetricPolicy(), MIDPLANE_DIMS)
    elo = simulate_queue((7, 2, 2, 2), jobs, ElongatedPolicy(), MIDPLANE_DIMS)
    assert not iso.rejected and not elo.rejected
    assert iso.mean_comm_time < elo.mean_comm_time
    # paper's x2: best (2,2,2,1) vs worst (4,2,1,1) pairing time
    assert elo.mean_comm_time / iso.mean_comm_time == pytest.approx(2.0)


def test_list_policy_matches_mira():
    jobs = [JobRequest(0, 16, True, 1.0)]
    res = simulate_queue(
        (4, 4, 3, 2), jobs, ListPolicy(MIRA_SCHEDULER_PARTITIONS), MIDPLANE_DIMS
    )
    assert res.jobs[0].placement.geometry == (4, 4, 1, 1)


def test_hinted_policy_uses_iso_only_for_contention_bound():
    m = MachineState((7, 2, 2, 2))
    pol = HintedPolicy()
    iso_prefs = pol.geometry_preferences(m, 8, True)
    any_prefs = pol.geometry_preferences(m, 8, False)
    assert iso_prefs[0] == (2, 2, 2, 1)
    assert any_prefs[0] != iso_prefs[0]


def test_avoidable_contention_ratio_juqueen8():
    assert avoidable_contention_ratio((7, 2, 2, 2), 8, MIDPLANE_DIMS) == pytest.approx(
        2.0
    )


def test_queue_waits_for_release_when_full():
    jobs = [JobRequest(i, 4, True, 1.0) for i in range(3)]
    res = simulate_queue((2, 2, 1, 1), jobs, IsoperimetricPolicy())
    assert not res.rejected
    assert res.jobs[1].start >= res.jobs[0].end  # second job waited


# ---------------------------------------------------------------------------
# TPU fabric / collectives
# ---------------------------------------------------------------------------
def test_slice_fabric_wrap_semantics():
    pod = TorusFabric((16, 16), (True, True))
    s = slice_fabric(pod, (16, 4))
    assert s.dims == (16, 4)
    assert s.wrap == (True, False)  # only the full dimension keeps wrap
    s2 = slice_fabric(pod, (8, 8))
    assert s2.wrap == (False, False)


def test_tpu_slice_geometry_16_chips():
    """On a 16x16 wrapped pod, a 4x4 slice beats 16x1 and 8x2 (x2 bisection)."""
    pod = TorusFabric((16, 16), (True, True))
    best = best_slice_geometry(pod, 16)
    worst = worst_slice_geometry(pod, 16)
    assert best == ((4, 4), 4)
    assert worst[1] == 2


def test_tpu_3d_pod_partial_dim_effect():
    """v4-style 3D pod: full-ring wrap can double a slice's bisection."""
    pod = TorusFabric((16, 16, 8), (True, True, True))
    good = slice_fabric(pod, (8, 4, 4))  # covers the 8-dim: wrapped
    assert good.wrap.count(True) == 1
    assert good.bisection_links() == 32
    bad = slice_fabric(pod, (16, 8, 1))
    assert bad.bisection_links() == 16


def test_bgq_fabric_matches_paper_bisection():
    fab = TorusFabric((16, 4, 4, 4, 2), (True,) * 5, double_link_on_2=True)
    assert fab.bisection_links() == 256  # Mira 4-midplane (4,1,1,1) partition


def test_ring_collective_times():
    emb = AxisEmbedding(size=16, wrapped=True)
    bw = 50e9
    # all-gather 1 GB output: (15/16 GB) / (2 * 50 GB/s)
    t = ring_all_gather_time(1e9, emb, bw)
    assert t == pytest.approx((15 / 16) * 1e9 / (2 * 50e9))
    # all-reduce = 2x reduce-scatter-equivalent
    t2 = ring_all_reduce_time(1e9, emb, bw)
    assert t2 == pytest.approx(2 * t)
    # chain (no wrap) is 2x slower
    chain = AxisEmbedding(size=16, wrapped=False)
    assert ring_all_gather_time(1e9, chain, bw) == pytest.approx(2 * t)


def test_assign_axes_prefers_wrapped_dims():
    fab = TorusFabric((16, 16, 2), (True, False, False))
    asg = assign_axes(fab, {"data": 16, "model": 16, "pod": 2})
    # the bigger-pressure axes get dims; 'data' (first in default order) gets
    # the wrapped 16.
    data_group = asg.phys_groups[asg.axis_names.index("data")]
    assert fab.wrap[data_group[0]]


def test_assign_axes_multi_dim_axis():
    fab = TorusFabric((16, 16), (True, True))
    asg = assign_axes(fab, {"model": 256})
    assert asg.embedding("model").size == 256
    model_group = asg.phys_groups[asg.axis_names.index("model")]
    assert len(model_group) == 2


def test_cost_model_all_reduce_vs_axis():
    fab = TorusFabric((16, 16), (True, True))
    asg = assign_axes(fab, {"data": 16, "model": 16})
    cm = CollectiveCostModel(fab, asg)
    t = cm.time("all-reduce", "data", 1e9)
    assert t > 0
    assert cm.effective_axis_bandwidth("data") > 0


# ---------------------------------------------------------------------------
# Topology extensions
# ---------------------------------------------------------------------------
def test_hypercube_harper_bisection():
    for d in range(1, 10):
        assert _harper_rec(d, 2 ** (d - 1)) == hypercube_bisection(d)


def test_harper_bound_brute_force_small():
    import itertools as it

    d = 4
    verts = list(it.product((0, 1), repeat=d))
    edges = [
        (u, v)
        for u in verts
        for v in verts
        if u < v and sum(a != b for a, b in zip(u, v)) == 1
    ]
    for t in range(1, 2 ** (d - 1) + 1):
        best = min(
            sum(1 for (u, v) in edges if (u in s) != (v in s))
            for s in map(set, it.combinations(verts, t))
        )
        assert best == hypercube_harper_bound(d, t)


def test_hyperx_lindsey_vs_subproducts():
    hx = HyperX((4, 3, 2))
    n = hx.num_vertices
    for t in [2, 4, 6, 12]:
        lex = hx.lindsey_optimal_cut(t)
        sub = hx.best_subproduct(t)
        if sub is not None:
            assert lex <= sub[1]  # Lindsey order is optimal
    assert hx.bisection_links() == hx.lindsey_optimal_cut(n // 2)


def test_hyperx_brute_force_small():
    import itertools as it

    hx = HyperX((3, 2))
    verts = list(it.product(range(3), range(2)))
    edges = []
    for u in verts:
        for v in verts:
            if u < v and (
                (u[0] == v[0] and u[1] != v[1]) or (u[1] == v[1] and u[0] != v[0])
            ):
                edges.append((u, v))
    for t in range(1, 4):
        best = min(
            sum(1 for (u, v) in edges if (u in s) != (v in s))
            for s in map(set, it.combinations(verts, t))
        )
        assert best == hx.lindsey_optimal_cut(t)


def test_dragonfly_weighted_partition():
    g = DragonflyGroup()
    best = g.best_subgroup(16)
    assert best is not None
    (sa, sb), cut = best
    assert sa * sb == 16
    # splitting within K16 only (sb=6 impossible for 16) — check weighted logic
    assert cut <= g.weighted_cut(16, 1)
