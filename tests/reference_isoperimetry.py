"""The historical per-cuboid isoperimetry loops, kept as the property-test
oracle for the vectorized ``repro.network.isoperimetry`` engine.

This is the pre-vectorization implementation (one Python loop over
``sub_cuboids``, one ``cuboid_cut`` call per geometry) with the PR-5
semantics applied so engine == oracle can be asserted exactly:

* the Theorem 3.1 bound uses complement symmetry for ``t > n/2``
  (``cut(S) == cut(S̄)``, so the bound at ``n - t`` applies — the old code
  set ``bound = cut`` there, making tightness vacuous);
* ``optimal``/``worst`` validation is aligned (``ValueError`` outside
  ``(0, n]`` for both);
* ties break deterministically: the lexicographically-*smallest*
  canonical geometry among the min cuts, the *largest* among the max —
  the same tie-breaks as ``repro.core.bgq``'s best/worst partitions.

``benchmarks/bench_isoperimetry.py`` times these loops against the engine.
"""

from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

from repro.network.geometry import (
    Geometry,
    bisection_links,
    canonical,
    cuboid_cut,
    cuboid_interior,
    sub_cuboids,
    theorem31_bound,
    volume,
)


def _dims_of(torus_or_dims) -> Geometry:
    return canonical(getattr(torus_or_dims, "dims", torus_or_dims))


def reference_cut_table(torus_or_dims, t: int) -> List[Tuple[Geometry, int]]:
    """(geometry, cut) for every fitting cuboid of volume t, lexicographically
    ascending — the per-cuboid counterpart of ``cut_table(...).items()``."""
    a = _dims_of(torus_or_dims)
    return sorted((c, cuboid_cut(a, c)) for c in sub_cuboids(a, t))


def _subset_bound(a: Geometry, n: int, t: int) -> float:
    return theorem31_bound(a, min(t, n - t))


def reference_optimal_cuboid(
    torus_or_dims, t: int
) -> Optional[Tuple[Geometry, int, float]]:
    """(geometry, cut, bound) of the min-cut cuboid, or None if none fits."""
    a = _dims_of(torus_or_dims)
    n = volume(a)
    if t <= 0 or t > n:
        raise ValueError(f"t must be in (0, {n}], got {t}")
    best_geom, best_cut = None, None
    for c in sorted(sub_cuboids(a, t)):
        cut = cuboid_cut(a, c)
        if best_cut is None or cut < best_cut:
            best_geom, best_cut = c, cut
    if best_geom is None:
        return None
    return best_geom, best_cut, _subset_bound(a, n, t)


def reference_worst_cuboid(
    torus_or_dims, t: int
) -> Optional[Tuple[Geometry, int, float]]:
    """(geometry, cut, bound) of the max-cut cuboid, or None if none fits."""
    a = _dims_of(torus_or_dims)
    n = volume(a)
    if t <= 0 or t > n:
        raise ValueError(f"t must be in (0, {n}], got {t}")
    worst_geom, worst_cut = None, None
    for c in sorted(sub_cuboids(a, t)):
        cut = cuboid_cut(a, c)
        if worst_cut is None or cut >= worst_cut:
            worst_geom, worst_cut = c, cut
    if worst_geom is None:
        return None
    return worst_geom, worst_cut, _subset_bound(a, n, t)


def reference_small_set_expansion(torus_or_dims, t: int) -> float:
    """h_t over cuboid witnesses by the full double loop (sizes x cuboids),
    computing the interior explicitly per cuboid."""
    a = _dims_of(torus_or_dims)
    best = math.inf
    for size in range(1, t + 1):
        for c in sub_cuboids(a, size):
            cut = cuboid_cut(a, c)
            interior = cuboid_interior(a, c)
            denom = interior + cut
            if denom == 0:
                continue
            best = min(best, cut / denom)
    return best


def _scaled_node_dims(
    geometry: Geometry, unit_node_dims: Optional[Sequence[int]]
) -> Geometry:
    if unit_node_dims is None:
        return geometry
    unit = tuple(int(u) for u in unit_node_dims)
    scaled = tuple(g * u for g, u in zip(geometry, unit[: len(geometry)]))
    return canonical(scaled + unit[len(geometry):])


def reference_bisection_table(
    torus_or_dims, units: int, unit_node_dims: Optional[Sequence[int]] = None
) -> List[Tuple[Geometry, int]]:
    """(geometry, internal bisection links) per fitting geometry of a size,
    lexicographically ascending, via one ``bisection_links`` call each."""
    a = _dims_of(torus_or_dims)
    return sorted(
        (c, bisection_links(_scaled_node_dims(c, unit_node_dims)))
        for c in sub_cuboids(a, units)
    )
