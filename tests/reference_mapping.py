"""Brute-force per-hop rank-mapping scorer, kept as a test oracle.

Scores a rank mapping (an (n, D) rank -> machine-cell coordinate array)
under rank-space traffic by walking every message hop by hop with the
historical per-hop DOR walker (``reference_dor.ReferenceLinkLoads``) and
counting dilation one dimension at a time in Python.  It exists only to
validate the vectorized scorer in ``repro.network.mapping`` — the property
tests pin congestion, dilation and the full load tensor — and to anchor
the mapping micro-benchmark's speedup claim.  Do not use it in library
code.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

from reference_dor import ReferenceLinkLoads


def reference_hops(dims: Sequence[int], src: Sequence[int], dst: Sequence[int]) -> int:
    """Minimal toroidal hop count of one message, one dimension at a time."""
    hops = 0
    for k, a in enumerate(dims):
        delta = (int(dst[k]) - int(src[k])) % int(a)
        hops += min(delta, int(a) - delta)
    return hops


def reference_score_mapping(
    dims: Sequence[int],
    coords: np.ndarray,
    traffic: Tuple[np.ndarray, np.ndarray, np.ndarray],
    split_ties: bool = True,
    double_link_on_2: bool = True,
) -> Tuple[float, float, np.ndarray]:
    """(congestion, dilation, load tensor) of a mapping, per-hop.

    Mirrors ``repro.network.mapping.score_mapping`` semantics exactly:
    congestion is the max per-physical-link load (BG/Q double links halve
    when ``double_link_on_2``), dilation the total volume-weighted hop
    count; the (D, 2, *dims) load tensor is returned for full-tensor
    equality checks against the vectorized engine.
    """
    dims = tuple(int(a) for a in dims)
    rsrc, rdst, vol = traffic
    walker = ReferenceLinkLoads(dims, split_ties=split_ties)
    dilation = 0.0
    for m in range(len(rsrc)):
        s = tuple(int(x) for x in coords[int(rsrc[m])])
        d = tuple(int(x) for x in coords[int(rdst[m])])
        v = float(vol[m])
        walker.add_path(s, d, v)
        dilation += v * reference_hops(dims, s, d)
    congestion = 0.0
    for k, a in enumerate(dims):
        if a == 1:
            continue
        scale = 0.5 if (a == 2 and double_link_on_2) else 1.0
        for d in range(2):
            congestion = max(congestion, scale * float(walker.loads[k][d].max()))
    return congestion, dilation, walker.load_array()
