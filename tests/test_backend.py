"""Property and behaviour tests for the compiled network backends.

Pins the tentpole's exactness contracts:

    numpy route_dor          ==  xla route_dor        (bit-exact loads)
    numpy simulate_flows     ~=  xla drain            (<= 1e-9 rel rates)
    sequential score_mapping ==  batched score_candidates   (row-exact)
    numpy cut_table          ==  xla cut_table        (int64-exact)

plus the dispatch machinery (env variable, explicit argument, error
paths) and the golden Mira / JUQUEEN partition parity the acceptance
criteria name.  Property tests sample random fabrics up to 4D with
integer volumes (where exactness is meaningful) and skip cleanly when
jax is not installed; the dispatch tests run everywhere.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

import repro.network.backend as backend_mod
from repro.network import (
    HAVE_JAX,
    bisection_pairing,
    cut_table,
    dor_paths,
    resolve_backend,
    route_dor,
    score_candidates,
    simulate_flows,
    simulate_traffic,
)
from repro.network.backend import drain, drain_batch, prepare_drain
from repro.network.mapping import map_ranks, pattern_traffic, score_mapping

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")

# Small random fabrics: exact parity is shape-independent, and tiny dims
# keep the per-example jit compiles cheap.
dims_strategy = st.lists(st.integers(1, 5), min_size=1, max_size=4).map(tuple)


def _random_messages(rng_seed, dims, n_msgs):
    rng = np.random.default_rng(rng_seed)
    src = np.stack([rng.integers(0, a, n_msgs) for a in dims], axis=1)
    dst = np.stack([rng.integers(0, a, n_msgs) for a in dims], axis=1)
    vol = rng.integers(1, 5, n_msgs).astype(np.float64)
    return src, dst, vol


# ---------------------------------------------------------------------------
# Dispatch (runs with or without jax).
# ---------------------------------------------------------------------------
def test_default_backend_is_numpy(monkeypatch):
    monkeypatch.delenv("REPRO_NETWORK_BACKEND", raising=False)
    assert resolve_backend() == "numpy"
    assert resolve_backend(None) == "numpy"


def test_env_variable_selects_backend(monkeypatch):
    monkeypatch.setenv("REPRO_NETWORK_BACKEND", "numpy")
    assert resolve_backend() == "numpy"
    monkeypatch.setenv("REPRO_NETWORK_BACKEND", "")
    assert resolve_backend() == "numpy"  # empty value falls back to default
    if HAVE_JAX:
        monkeypatch.setenv("REPRO_NETWORK_BACKEND", "xla")
        assert resolve_backend() == "xla"
        assert resolve_backend("numpy") == "numpy"  # explicit argument wins


def test_unknown_backend_raises(monkeypatch):
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("cuda")
    monkeypatch.setenv("REPRO_NETWORK_BACKEND", "nonsense")
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend()


def test_pallas_slot_reserved():
    with pytest.raises(NotImplementedError, match="pallas"):
        resolve_backend("pallas")


def test_xla_without_jax_raises(monkeypatch):
    monkeypatch.setattr(backend_mod, "HAVE_JAX", False)
    with pytest.raises(RuntimeError, match="requires jax"):
        resolve_backend("xla")


@needs_jax
def test_record_utilization_is_numpy_only():
    paths = dor_paths((4, 4), *bisection_pairing((4, 4)))
    with pytest.raises(ValueError, match="record_utilization"):
        simulate_flows(paths, record_utilization=True, backend="xla")


# ---------------------------------------------------------------------------
# Route-load exactness.
# ---------------------------------------------------------------------------
@needs_jax
@settings(max_examples=10, deadline=None)
@given(
    dims=dims_strategy,
    seed=st.integers(0, 2**31 - 1),
    n_msgs=st.integers(1, 24),
    split_ties=st.booleans(),
)
def test_route_loads_exact(dims, seed, n_msgs, split_ties):
    src, dst, vol = _random_messages(seed, dims, n_msgs)
    loads_np = route_dor(dims, src, dst, vol, split_ties=split_ties)
    loads_x = route_dor(dims, src, dst, vol, split_ties=split_ties, backend="xla")
    assert loads_np.shape == loads_x.shape
    assert np.array_equal(loads_np, loads_x)


@needs_jax
def test_route_loads_empty_and_scalar_vol():
    empty = np.zeros((0, 2), dtype=np.int64)
    out = route_dor((4, 3), empty, empty, np.zeros(0), backend="xla")
    assert out.shape == (2, 2, 4, 3) and not out.any()
    src, dst, _ = _random_messages(7, (4, 3), 5)
    assert np.array_equal(
        route_dor((4, 3), src, dst, 2.0),
        route_dor((4, 3), src, dst, 2.0, backend="xla"),
    )


# ---------------------------------------------------------------------------
# Max-min drain parity.
# ---------------------------------------------------------------------------
@needs_jax
@settings(max_examples=6, deadline=None)
@given(
    dims=st.lists(st.integers(2, 4), min_size=2, max_size=3).map(tuple),
    seed=st.integers(0, 2**31 - 1),
    n_msgs=st.integers(1, 12),
)
def test_simulate_flows_rates_match(dims, seed, n_msgs):
    src, dst, vol = _random_messages(seed, dims, n_msgs)
    paths = dor_paths(dims, src, dst, vol)
    res_np = simulate_flows(paths)
    res_x = simulate_flows(paths, backend="xla")
    assert np.array_equal(res_np.link_loads, res_x.link_loads)
    scale = max(res_np.makespan, 1.0)
    assert abs(res_np.makespan - res_x.makespan) <= 1e-9 * scale
    np.testing.assert_allclose(
        res_np.flow_completion, res_x.flow_completion, rtol=1e-9, atol=1e-12
    )
    assert res_np.steps == res_x.steps


@needs_jax
def test_drain_batch_lanes_match_single_drains():
    paths = dor_paths((4, 4, 2), *bisection_pairing((4, 4, 2)))
    plan = prepare_drain(paths)
    rng = np.random.default_rng(3)
    vols = rng.integers(1, 4, size=(4, plan.n_flows)).astype(np.float64)
    fc_b, steps_b = drain_batch(plan, vols)
    for i in range(vols.shape[0]):
        fc_i, steps_i = drain(plan, vols[i])
        assert np.array_equal(fc_b[i], fc_i)
        assert steps_b[i] == steps_i


@needs_jax
def test_drain_input_validation():
    paths = dor_paths((4, 4), *bisection_pairing((4, 4)))
    with pytest.raises(ValueError, match="link_bw"):
        prepare_drain(paths, link_bw=0.0)
    plan = prepare_drain(paths)
    with pytest.raises(ValueError, match="shape"):
        drain(plan, np.ones(plan.n_flows + 1))
    with pytest.raises(ValueError, match="shape"):
        drain_batch(plan, np.ones((2, plan.n_flows + 1)))


# ---------------------------------------------------------------------------
# Batched candidate scoring.
# ---------------------------------------------------------------------------
@needs_jax
@settings(max_examples=6, deadline=None)
@given(
    dims=st.lists(st.integers(2, 4), min_size=2, max_size=3).map(tuple),
    seed=st.integers(0, 2**31 - 1),
    batch=st.integers(1, 6),
)
def test_score_candidates_rows_match_sequential(dims, seed, batch):
    rng = np.random.default_rng(seed)
    n_cells = int(np.prod(dims))
    n_ranks = min(6, n_cells)
    traffic = pattern_traffic((n_ranks,), "ring")
    cells = np.stack(
        [rng.choice(n_cells, n_ranks, replace=False) for _ in range(batch)]
    )
    coords = np.stack(np.unravel_index(cells, dims), axis=-1).astype(np.int64)
    cong_x, dil_x = score_candidates(dims, coords, traffic, backend="xla")
    for i in range(batch):
        ref = score_mapping(dims, coords[i], traffic)
        assert cong_x[i] == ref.congestion
        assert dil_x[i] == ref.dilation


@needs_jax
def test_score_candidates_edge_shapes():
    traffic = pattern_traffic((4,), "ring")
    coords = np.stack(np.unravel_index(np.arange(4), (2, 2)), axis=-1)
    cong2d, dil2d = score_candidates((2, 2), coords, traffic, backend="xla")
    assert cong2d.shape == (1,) and dil2d.shape == (1,)
    empty = np.zeros(0, dtype=np.int64)
    cong0, dil0 = score_candidates(
        (2, 2), coords, (empty, empty.copy(), np.zeros(0)), backend="xla"
    )
    assert cong0.shape == (1,) and cong0[0] == 0.0 and dil0[0] == 0.0
    with pytest.raises(ValueError, match="coords"):
        score_candidates((2, 2), np.zeros((3,), dtype=np.int64), traffic)


@needs_jax
def test_map_ranks_backend_parity():
    m_np = map_ranks((4, 8), (2, 8), (0, 0), logical_dims=(8, 2), pattern="halo")
    m_x = map_ranks(
        (4, 8), (2, 8), (0, 0), logical_dims=(8, 2), pattern="halo", backend="xla"
    )
    assert m_np.strategy == m_x.strategy
    assert m_np.score == m_x.score
    assert m_np.identity_score == m_x.identity_score
    assert np.array_equal(m_np.coords, m_x.coords)


# ---------------------------------------------------------------------------
# Cut scoring.
# ---------------------------------------------------------------------------
@needs_jax
@settings(max_examples=10, deadline=None)
@given(
    dims=dims_strategy,
    t=st.integers(1, 32),
)
def test_cut_table_backend_parity(dims, t):
    t_np = cut_table(dims, t)
    t_x = cut_table(dims, t, backend="xla")
    assert t_np.items() == t_x.items()
    assert t_x.cuts.dtype == np.int64


# ---------------------------------------------------------------------------
# Golden partition pairs (the acceptance criterion's concrete fabrics).
# ---------------------------------------------------------------------------
@needs_jax
@pytest.mark.parametrize(
    "dims", [(16, 4, 4, 4, 2), (8, 8, 4, 4, 2)], ids=["mira-4mp", "juqueen-4mp"]
)
def test_golden_partition_parity(dims):
    src, dst, vol = bisection_pairing(dims)
    assert np.array_equal(
        route_dor(dims, src, dst, vol),
        route_dor(dims, src, dst, vol, backend="xla"),
    )
    res_np = simulate_traffic(dims, (src, dst, vol))
    res_x = simulate_traffic(dims, (src, dst, vol), backend="xla")
    assert abs(res_np.makespan - res_x.makespan) <= 1e-9 * res_np.makespan


# ---------------------------------------------------------------------------
# Env-variable dispatch end to end.
# ---------------------------------------------------------------------------
@needs_jax
def test_env_backend_reaches_engines(monkeypatch):
    src, dst, vol = _random_messages(11, (4, 3), 8)
    expected = route_dor((4, 3), src, dst, vol)
    monkeypatch.setenv("REPRO_NETWORK_BACKEND", "xla")
    assert np.array_equal(route_dor((4, 3), src, dst, vol), expected)
    res = simulate_traffic((4, 4), bisection_pairing((4, 4)))
    monkeypatch.setenv("REPRO_NETWORK_BACKEND", "numpy")
    ref = simulate_traffic((4, 4), bisection_pairing((4, 4)))
    assert abs(res.makespan - ref.makespan) <= 1e-9 * max(ref.makespan, 1.0)


# ---------------------------------------------------------------------------
# Fleet-planner backend parity: the ranked table is bit-identical whether
# candidate mappings are scored sequentially (numpy) or batched (xla).
# ---------------------------------------------------------------------------
@needs_jax
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_planner_table_backend_parity(shape):
    from repro.configs import ArchConfig, MoEConfig
    from repro.launch.planner import plan_model
    from repro.network.fabric import TorusFabric

    tiny = ArchConfig(
        name="tiny-moe-backend", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
        moe=MoEConfig(num_experts=4, top_k=2),
    )
    pod = TorusFabric.tpu((4, 4))
    p_np = plan_model(tiny, 8, pod=pod, shape=shape, backend="numpy")
    p_x = plan_model(tiny, 8, pod=pod, shape=shape, backend="xla")
    assert [c.row() for c in p_np.table] == [c.row() for c in p_x.table]


@needs_jax
def test_planner_env_backend_dispatch(monkeypatch):
    from repro.launch.planner import plan_model
    from repro.network.fabric import TorusFabric

    pod = TorusFabric.tpu((4, 4))
    ref = plan_model("mixtral-8x7b", 8, pod=pod, shape="decode_32k")
    monkeypatch.setenv("REPRO_NETWORK_BACKEND", "xla")
    env = plan_model("mixtral-8x7b", 8, pod=pod, shape="decode_32k")
    assert [c.row() for c in ref.table] == [c.row() for c in env.table]
