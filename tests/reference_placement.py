"""The historical brute-force cuboid-placement scan, kept as a test oracle.

This is the pre-refactor ``MachineState.find_placement`` loop from
``repro.network.allocation`` (one Python iteration per orientation x torus
offset, a meshgrid cell check per candidate), restructured so tests and the
allocation micro-benchmark can ask for the *full* feasibility set, not just
the first hit.  It exists only to validate the vectorized engine in
``repro.network.placement`` — the equivalence property tests compare free
sets and first-fit choices on random occupancy grids — and to anchor the
allocation benchmark's speedup claim.  Do not use it in library code.

The one intentional divergence from the historical code: a geometry with
more non-trivial dimensions than the machine raises ``ValueError`` (the old
scan silently truncated it; see the regression test), so oracle and engine
agree on every input they accept.
"""

from __future__ import annotations

import itertools
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.network.geometry import canonical

Coord = Tuple[int, ...]


def reference_pad_geometry(geometry: Sequence[int], ndim: int) -> Tuple[int, ...]:
    g = canonical(geometry)
    while len(g) > ndim and g[-1] == 1:
        g = g[:-1]
    if len(g) > ndim:
        raise ValueError(
            f"geometry {canonical(geometry)} has {len(g)} non-trivial dims; "
            f"machine has only {ndim}"
        )
    return g + (1,) * (ndim - len(g))


def reference_orientations(
    geometry: Sequence[int], dims: Sequence[int]
) -> List[Tuple[int, ...]]:
    """The scan's orientation order: sorted distinct permutations that fit."""
    dims = tuple(dims)
    g = reference_pad_geometry(geometry, len(dims))
    return [
        perm
        for perm in sorted(set(itertools.permutations(g)))
        if not any(s > a for s, a in zip(perm, dims))
    ]


def reference_cells(
    dims: Sequence[int], oriented: Sequence[int], offset: Coord
) -> Tuple[np.ndarray, ...]:
    """The historical meshgrid cell index for a placement."""
    slices = [
        np.array([(offset[k] + i) % dims[k] for i in range(oriented[k])])
        for k in range(len(dims))
    ]
    mesh = np.meshgrid(*slices, indexing="ij")
    return tuple(m.ravel() for m in mesh)


def reference_free_offsets(grid: np.ndarray, oriented: Sequence[int]) -> List[Coord]:
    """Every offset where the oriented cuboid covers only free cells, in the
    scan's lexicographic (C) order."""
    dims = grid.shape
    out = []
    for offset in itertools.product(*(range(a) for a in dims)):
        cells = reference_cells(dims, oriented, offset)
        if not grid[cells].any():
            out.append(offset)
    return out


def reference_first_fit(
    grid: np.ndarray, geometry: Sequence[int]
) -> Optional[Tuple[Tuple[int, ...], Coord]]:
    """First free translate of any orientation — the historical
    ``find_placement`` body, verbatim semantics."""
    dims = grid.shape
    for perm in reference_orientations(geometry, dims):
        for offset in itertools.product(*(range(a) for a in dims)):
            cells = reference_cells(dims, perm, offset)
            if not grid[cells].any():
                return perm, offset
    return None
