"""Brute-force fleet-planner oracle, kept as a test reference.

Re-derives the whole planner pipeline sequentially — sharding-rule
enumeration, per-rule traffic volumes, rank-space messages, the mapping
catalogue walk, the greedy axis->dimension grouping, and the final
``(step_time, geometry rank, axis sizes)`` ranking — with plain Python
loops, duplicating the closed-form volume formulas of
``repro.launch.planner`` *verbatim* (an edit to a formula must be made in
both places to keep the differential harness green).  Pricing calls the
same public primitives the planner itself promises to be reproducible
from (``AxisEmbedding.from_mapping``, :data:`COLLECTIVE_TIME`,
``predict_pairing_time``, ``cell_cost``), summed in the same order so the
floats are bit-identical.  Do not use in library code.
"""

from __future__ import annotations

import itertools
import math
from types import SimpleNamespace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.analytic import BF16, cell_cost
from repro.analysis.roofline import HBM_BW, PEAK_FLOPS
from repro.configs import SHAPES, ArchConfig, ShapeConfig
from repro.launch.planner import AXES, HBM_BYTES, ORDER_HINT
from repro.network.collectives import COLLECTIVE_TIME, AxisEmbedding
from repro.network.fabric import TorusFabric, ranked_slice_geometries, slice_fabric
from repro.network.geometry import canonical, volume
from repro.network.isoperimetry import ranked_geometries, scaled_node_dims
from repro.network.mapping import (
    axis_order_coords,
    axis_permutation_orders,
    identity_mapping,
    score_mapping,
    snake_mapping,
)
from repro.network.routing import predict_pairing_time


def reference_rules(cfg: ArchConfig, chips: int) -> List[Tuple[int, int, int, int]]:
    """Candidate (data, fsdp, tensor, expert) splits, sequential loops."""
    n_experts = cfg.moe.num_experts if cfg.moe is not None else 1
    param_bytes = float(BF16) * cfg.param_count()
    rules = []
    for t in range(1, chips + 1):
        if chips % t or cfg.n_heads % t:
            continue
        for e in range(1, chips // t + 1):
            if (chips // t) % e or n_experts % e:
                continue
            rest = chips // (t * e)
            for f in range(1, rest + 1):
                if rest % f:
                    continue
                rules.append((rest // f, f, t, e))
    feasible = [r for r in rules if param_bytes / (r[1] * r[2] * r[3]) <= HBM_BYTES]
    return feasible if feasible else rules


def reference_traffic(
    cfg: ArchConfig, shape: ShapeConfig, axis_sizes: Tuple[int, int, int, int]
) -> List[Tuple[str, str, float]]:
    """(axis, collective, per-chip bytes) entries — formulas duplicated."""
    d, f, t, e = axis_sizes
    L = cfg.n_layers
    B, S = shape.global_batch, shape.seq_len
    params = float(cfg.param_count())
    p_shard = BF16 * params / (t * e)
    tokens = float(B * S) if shape.kind in ("train", "prefill") else float(B)
    tokens_local = tokens / (d * f)
    act = tokens_local * cfg.d_model * BF16
    entries: List[Tuple[str, str, float]] = []
    if t > 1:
        mult = 3.0 if shape.kind == "train" else 1.0
        entries.append(("tensor", "all-gather", 2.0 * L * mult * act))
        entries.append(("tensor", "reduce-scatter", 2.0 * L * mult * act))
    if e > 1 and cfg.moe is not None:
        n_exchanges = 4.0 if shape.kind == "train" else 2.0
        a2a = (
            n_exchanges * L * tokens_local * cfg.moe.top_k
            * cfg.moe.capacity_factor * cfg.d_model * BF16
        )
        entries.append(("expert", "all-to-all", a2a))
    if f > 1:
        if shape.kind == "train":
            entries.append(("fsdp", "all-gather", 2.0 * p_shard))
            entries.append(("fsdp", "reduce-scatter", p_shard))
        else:
            entries.append(("fsdp", "all-gather", p_shard))
    if d > 1 and shape.kind == "train":
        entries.append(("data", "all-reduce", p_shard / f))
    return entries


def reference_pair_volume(entries, axis_sizes) -> float:
    e = axis_sizes[3]
    vol = 0.0
    for axis, collective, v in entries:
        if axis == "data" and collective == "all-reduce":
            vol += 0.5 * v
        if axis == "expert" and collective == "all-to-all":
            vol += v / e
    return vol


def reference_rank_traffic(axis_sizes, entries, pair_volume):
    """Rank-space messages, per-rank Python loops (planner order)."""
    shape = tuple(axis_sizes)
    n = int(np.prod(shape))
    per_axis = {a: 0.0 for a in AXES}
    a2a_volume = 0.0
    for axis, collective, v in entries:
        if axis == "expert" and collective == "all-to-all":
            a2a_volume += v
        else:
            per_axis[axis] += v
    coords = [tuple(np.unravel_index(r, shape)) for r in range(n)]
    ravel = {c: r for r, c in enumerate(coords)}
    srcs, dsts, vols = [], [], []

    def send(k: int, step: int, v: float) -> None:
        for r, c in enumerate(coords):
            nb = list(c)
            nb[k] = (nb[k] + step) % shape[k]
            srcs.append(r)
            dsts.append(ravel[tuple(nb)])
            vols.append(v)

    for k, axis in enumerate(AXES):
        s, v = shape[k], per_axis[axis]
        if s <= 1 or v <= 0.0:
            continue
        send(k, 1, v / 2.0)
        send(k, -1, v / 2.0)
    e = shape[3]
    if e > 1 and a2a_volume > 0.0:
        for off in range(1, e):
            send(3, off, a2a_volume / e)
    d = shape[0]
    if d > 1 and pair_volume > 0.0:
        send(0, d // 2, pair_volume)
    if not srcs:
        return None
    return (
        np.array(srcs, dtype=np.int64),
        np.array(dsts, dtype=np.int64),
        np.array(vols, dtype=np.float64),
    )


def reference_choose_mapping(fabric: TorusFabric, traffic):
    """Sequential catalogue walk: identity, axis permutations, gray-snake;
    first (congestion, dilation) minimum wins (``map_ranks`` semantics,
    ``refine=False``)."""
    dims, oriented = fabric.dims, fabric.dims
    offset = (0,) * len(dims)
    cands = [("identity", identity_mapping(dims, oriented, offset))]
    for perm, rev in axis_permutation_orders(oriented):
        if all(p == i for i, p in enumerate(perm)) and not any(rev):
            continue
        cands.append(
            ("axis-permutation", axis_order_coords(dims, oriented, offset, perm, rev))
        )
    cands.append(("gray-snake", snake_mapping(dims, oriented, offset)))
    scored = [
        (name, c, score_mapping(dims, c, traffic, True, fabric.double_link_on_2))
        for name, c in cands
    ]
    return min(scored, key=lambda t: t[2].key())


def reference_dim_groups(
    fabric: TorusFabric, axis_sizes: Tuple[int, int, int, int]
) -> Optional[Dict[str, Tuple[int, ...]]]:
    """The greedy whole-dimension grouping of ``assign_axes`` (ORDER_HINT
    priority, smallest group, wrapped dims preferred); None = inadmissible."""
    remaining = list(range(len(fabric.dims)))
    groups: Dict[str, Tuple[int, ...]] = {}
    sizes = dict(zip(AXES, axis_sizes))
    for name in ORDER_HINT:
        size = sizes[name]
        if size == 1:
            groups[name] = ()
            continue
        got = None
        for k in range(1, len(remaining) + 1):
            options = []
            for combo in itertools.combinations(remaining, k):
                if math.prod(fabric.dims[i] for i in combo) == size:
                    n_wrapped = sum(bool(fabric.wrap[i]) for i in combo)
                    options.append((-n_wrapped, combo))
            if options:
                got = min(options)[1]
                break
        if got is None:
            return None
        groups[name] = got
        for i in got:
            remaining.remove(i)
    return groups


def reference_price(
    cfg: ArchConfig,
    shape: ShapeConfig,
    fabric: TorusFabric,
    node_dims,
    n_compute: int,
    axis_sizes: Tuple[int, int, int, int],
):
    """Sequentially price one (fabric, rule) pair; None when inadmissible.

    Returns the oracle row ``(geometry-free fields): (axis_sizes, strategy,
    ring, pairing, compute, memory, step)``.
    """
    if reference_dim_groups(fabric, axis_sizes) is None:
        return None
    entries = reference_traffic(cfg, shape, axis_sizes)
    pair_chip = reference_pair_volume(entries, axis_sizes)
    traffic = reference_rank_traffic(axis_sizes, entries, pair_chip)
    strategy = "none"
    ring_time = 0.0
    if traffic is not None:
        strategy, coords, _score = reference_choose_mapping(fabric, traffic)
        mapping_ns = SimpleNamespace(
            dims=fabric.dims, coords=coords, wrap=fabric.wrap
        )
        for axis, collective, vol in entries:
            emb = AxisEmbedding.from_mapping(
                mapping_ns, tuple(axis_sizes), AXES.index(axis)
            )
            ring_time += COLLECTIVE_TIME[collective](vol, emb, fabric.link_bw)
    pair_node = pair_chip * fabric.num_chips / volume(node_dims)
    pairing_time = 0.0
    if pair_node > 0.0:
        pred = predict_pairing_time(
            node_dims, 1.0, fabric.link_bw,
            double_link_on_2=fabric.double_link_on_2,
        )
        pairing_time = pair_node * pred.time_per_volume
    cache = 0.0
    if shape.kind == "decode" and not cfg.is_attention_free:
        cache = (
            2.0 * cfg.n_layers * shape.global_batch * shape.seq_len
            * cfg.n_kv_heads * cfg.resolved_head_dim * BF16
        )
    cost = cell_cost(cfg, shape, float(cfg.param_count()), cache_bytes=cache)
    compute_time = cost.flops_compiled / (n_compute * PEAK_FLOPS)
    memory_time = cost.bytes_hbm / (n_compute * HBM_BW)
    step = max(compute_time, memory_time) + (ring_time + pairing_time)
    return (
        tuple(axis_sizes), strategy, ring_time, pairing_time,
        compute_time, memory_time, step,
    )


def reference_plan(
    cfg: ArchConfig,
    chips: int,
    pod: TorusFabric,
    shape,
    wrap_mode: str = "slice",
    unit_node_dims: Optional[Sequence[int]] = None,
) -> List[Tuple]:
    """The oracle's ranked table: rows in the planner's ``row()`` layout,
    every (geometry, rule) triple priced sequentially and sorted by the
    documented ``(step_time, geometry rank, axis sizes)`` key."""
    shape_cfg = shape if isinstance(shape, ShapeConfig) else SHAPES[shape]
    if wrap_mode == "slice":
        ranked = ranked_slice_geometries(pod, chips)
        fabs = [(g, slice_fabric(pod, g)) for g, _ in ranked]
        nodes = [fab.dims for _, fab in fabs]
    else:
        ranked = ranked_geometries(pod.dims, chips, unit_node_dims)
        fabs = [
            (g, TorusFabric(g, (True,) * len(g), pod.link_bw,
                            double_link_on_2=pod.double_link_on_2))
            for g, _ in ranked
        ]
        nodes = [scaled_node_dims(g, unit_node_dims) for g, _ in ranked]
    rows = []
    for gi, ((geom, fabric), node_dims) in enumerate(zip(fabs, nodes)):
        for rule in reference_rules(cfg, chips):
            priced = reference_price(
                cfg, shape_cfg, fabric, node_dims, volume(node_dims), rule
            )
            if priced is None:
                continue
            axes, strategy, ring, pairing, compute, memory, step = priced
            rows.append(
                (step, gi, axes,
                 (canonical(geom), axes, strategy, ring, pairing,
                  compute, memory, step))
            )
    rows.sort(key=lambda r: (r[0], r[1], r[2]))
    return [r[3] for r in rows]
