"""Tests for the link-level contention model (paper Section 4.1)."""

import itertools

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.bgq import node_dims_of_midplane_geometry as node_dims
from repro.network import (
    LinkLoads,
    all_to_all_max_load,
    furthest_offset,
    pairing_pairs,
    pairing_speedup,
    predict_pairing_time,
    uniform_offset_max_load,
)


def _exact_pairing_load(dims, split=True):
    ll = LinkLoads(dims, split_ties=split)
    for (u, v) in pairing_pairs(dims):
        ll.add_path(u, v, 1.0)
        ll.add_path(v, u, 1.0)
    return ll.max_load()


@pytest.mark.parametrize("dims", [(8, 4), (4, 4, 2), (8, 4, 2), (6, 4, 2)])
def test_exact_simulator_matches_analytic_pairing(dims):
    exact = _exact_pairing_load(dims)
    analytic = uniform_offset_max_load(dims, furthest_offset(dims))
    assert exact == pytest.approx(analytic)


@pytest.mark.parametrize("dims", [(8, 4), (4, 4, 2)])
def test_exact_simulator_matches_analytic_unsplit(dims):
    exact = _exact_pairing_load(dims, split=False)
    analytic = uniform_offset_max_load(dims, furthest_offset(dims), split_ties=False)
    assert exact == pytest.approx(analytic)


# Paper Figure 3 (Mira) & Figure 4 (JUQUEEN): predicted speedups.
PAPER_SPEEDUPS = [
    # (worst/current geometry, best/proposed geometry, predicted speedup)
    ((4, 1, 1, 1), (2, 2, 1, 1), 2.0),  # Mira & JUQUEEN, 4 midplanes
    ((4, 2, 1, 1), (2, 2, 2, 1), 2.0),  # 8 midplanes
    ((4, 4, 1, 1), (2, 2, 2, 2), 2.0),  # Mira, 16 midplanes
    ((4, 2, 2, 1), (2, 2, 2, 2), 2.0),  # JUQUEEN, 16 midplanes
    ((6, 1, 1, 1), (3, 2, 1, 1), 2.0),  # JUQUEEN, 6 midplanes
    ((6, 2, 1, 1), (3, 2, 2, 1), 2.0),  # JUQUEEN, 12 midplanes
    ((6, 2, 2, 1), (3, 2, 2, 2), 2.0),  # JUQUEEN, 24 midplanes
]


@pytest.mark.parametrize("worst,best,expected", PAPER_SPEEDUPS)
def test_paper_predicted_pairing_speedups(worst, best, expected):
    s = pairing_speedup(node_dims(worst), node_dims(best))
    assert s == pytest.approx(expected)


def test_mira_24_midplane_prediction():
    """24 midplanes is the exception: geometry speedup is 4/3 (not 2), and
    the paper's quoted 1.50 is the 16->24 proposed-partition time scaling
    (x1.5 nodes at equal bisection)."""
    s = pairing_speedup(node_dims((4, 3, 2, 1)), node_dims((3, 2, 2, 2)))
    assert s == pytest.approx(4.0 / 3.0)
    from repro.core.bgq import partition_bisection_links as bw

    t16 = 16 * 512 / (2.0 * bw((2, 2, 2, 2)))
    t24 = 24 * 512 / (2.0 * bw((3, 2, 2, 2)))
    assert t24 / t16 == pytest.approx(1.5)


def test_juqueen_per_node_bisection_figure4_note():
    """Fig 4 caption: per-node bisection identical for 4 and 8 midplanes,
    50% smaller for 6 midplanes — visible in pairing times."""
    t4 = predict_pairing_time(node_dims((4, 1, 1, 1)), 1.0, 1.0)
    t6 = predict_pairing_time(node_dims((6, 1, 1, 1)), 1.0, 1.0)
    t8 = predict_pairing_time(node_dims((4, 2, 1, 1)), 1.0, 1.0)
    assert t4.time_per_volume == pytest.approx(t8.time_per_volume)
    assert t6.time_per_volume == pytest.approx(1.5 * t4.time_per_volume)


def test_pairing_time_physical_units():
    """One round with 0.1342 GB messages on the worst 4-midplane partition:
    max link load = 4 x message over 2 GB/s links -> ~0.27 s/round."""
    p = predict_pairing_time(node_dims((4, 1, 1, 1)), 0.1342e9, 2.0e9)
    assert p.max_link_load == pytest.approx(4.0)
    t_round = p.time_per_volume * 0.1342e9
    assert 0.2 < t_round < 0.3


@settings(max_examples=30, deadline=None)
@given(
    dims=st.lists(st.sampled_from([2, 4, 6, 8]), min_size=1, max_size=3).map(tuple)
)
def test_property_pairing_load_halves_when_longest_dim_halves(dims):
    """Splitting the longest dimension in two (doubling another) never
    increases the pairing bottleneck — the paper's monotonicity."""
    dims = tuple(sorted(dims, reverse=True))
    if dims[0] < 4:
        return
    improved = (dims[0] // 2,) + dims[1:] + (2,)
    a = uniform_offset_max_load(dims, furthest_offset(dims))
    b = uniform_offset_max_load(improved, furthest_offset(improved))
    assert b <= a + 1e-12


def test_total_hop_volume_conservation():
    dims = (4, 4, 2)
    ll = LinkLoads(dims)
    pairs = pairing_pairs(dims)
    for (u, v) in pairs:
        ll.add_path(u, v, 1.0)
        ll.add_path(v, u, 1.0)
    # every node sends one message over sum(min-hop distances) hops
    hops = sum(min(o, a - o) for a, o in zip(dims, furthest_offset(dims)))
    n = 4 * 4 * 2
    assert ll.total_hop_volume() == pytest.approx(n * hops)


def test_all_to_all_max_load_positive_and_scales():
    small = all_to_all_max_load((4, 4))
    big = all_to_all_max_load((8, 8))
    assert small > 0 and big > small
