"""Per-hop Python reference for HyperX minimal routing.

Walks each message coordinate by coordinate in canonical dimension order
— the semantics ``repro.network.routing.route_hyperx(mode="minimal")``
vectorizes — accumulating loads in the dense link-id layout of
``HyperXFabric.links`` (slot ``base_k + flat(cell) * S_k + dst_coord``).
Loads are exact sums, so engine and oracle agree bit for bit; the
benchmark harness times the two against each other.
"""

import numpy as np

from repro.network.geometry import volume


def oracle_minimal_loads(fabric, src, dst, vol):
    """Dense per-link loads of a message batch, one Python hop at a time."""
    dims = fabric.dims
    n = volume(dims)
    bases, base = [], 0
    for a in dims:
        bases.append(base)
        base += n * a
    loads = np.zeros(base)
    for s, d, v in zip(np.atleast_2d(src), np.atleast_2d(dst), np.atleast_1d(vol)):
        cur = [int(x) for x in s]
        for k in range(len(dims)):
            if cur[k] != d[k]:
                u = int(np.ravel_multi_index(tuple(cur), dims))
                loads[bases[k] + u * dims[k] + int(d[k])] += v
                cur[k] = int(d[k])
    return loads
