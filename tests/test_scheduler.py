"""Event-sourced scheduler service: exact incremental fields, deterministic
event ordering, failures/preemption/reclaim, backpressure, and replay
determinism (PR 7's tentpole + satellites)."""

import numpy as np
import pytest

from repro.network import (
    IsoperimetricPolicy,
    JobRequest,
    ListPolicy,
    MachineState,
    SchedulerService,
    apply_monitor_failures,
    generate_scenario,
    replay_events,
    run_scenario,
    simulate_queue,
)
from repro.network.placement import int_base_loads, placement_loads
from repro.network.scheduler import time_close, time_eps, time_le
from repro.runtime.fault_tolerance import HeartbeatMonitor


# ---------------------------------------------------------------------------
# Satellite 1: exact incremental traffic fields.
# ---------------------------------------------------------------------------
def test_int_base_loads_is_exact_integer_scaling():
    for dims, oriented in [
        ((4, 4, 4), (2, 2, 2)),
        ((4, 4, 4), (4, 2, 1)),
        ((8, 4, 4), (2, 2, 2)),
        ((4, 4), (2, 2)),
    ]:
        n = int(np.prod(oriented))
        int_field = int_base_loads(dims, oriented)
        assert int_field.dtype == np.int64
        float_field = placement_loads(dims, oriented, (0,) * len(dims))
        # Same support exactly, same values up to one float rounding.
        assert ((int_field > 0) == (float_field > 0)).all()
        assert np.allclose(int_field / (2.0 * n), float_field)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_incremental_field_equals_fresh_recompute(seed):
    """Random alloc/release stream: the incrementally maintained background
    is bit-identical to a fresh machine recombining only the survivors, and
    allclose to the float per-placement sum with identical support."""
    rng = np.random.default_rng(seed)
    dims = (4, 4, 4)
    geoms = [(1, 1, 1), (2, 1, 1), (2, 2, 1), (2, 2, 2), (4, 2, 1), (4, 2, 2)]
    m = MachineState(dims)
    live = []
    jid = 0
    for step in range(120):
        if live and rng.random() < 0.45:
            k = live.pop(int(rng.integers(len(live))))
            m.release(k)
        else:
            p = m.allocate(jid, geoms[int(rng.integers(len(geoms)))])
            if p is not None:
                live.append(jid)
                jid += 1
        incremental = m.traffic_loads()
        fresh = MachineState(dims)
        for k in live:
            p = m.placements[k]
            fresh.commit(k, p.geometry, p.oriented, p.offset)
        assert np.array_equal(incremental, fresh.traffic_loads()), step
        float_sum = np.zeros_like(incremental)
        for k in live:
            p = m.placements[k]
            float_sum += placement_loads(dims, p.oriented, p.offset)
        assert np.allclose(incremental, float_sum)
        assert ((incremental > 0) == (float_sum > 0)).all()


def test_traffic_loads_exclude_is_exact():
    m = MachineState((4, 4, 4))
    for jid, g in enumerate([(2, 2, 2), (4, 2, 1), (2, 2, 1)]):
        assert m.allocate(jid, g) is not None
    background = m.traffic_loads(exclude=1)
    fresh = MachineState((4, 4, 4))
    for jid in (0, 2):
        p = m.placements[jid]
        fresh.commit(jid, p.geometry, p.oriented, p.offset)
    assert np.array_equal(background, fresh.traffic_loads())


# ---------------------------------------------------------------------------
# Satellite 2: deterministic (time, kind, seq) ordering, scale-aware clock.
# ---------------------------------------------------------------------------
def test_time_eps_is_scale_aware():
    # At t ~ 2^26 one ulp is ~1.5e-8: the historical fixed 1e-12 cannot
    # merge adjacent floats there, the scale-aware tolerance can.
    t = float(2**26) + 0.125
    below = np.nextafter(t, 0.0)
    assert abs(t - below) > 1e-12
    assert time_close(t, below)
    assert time_le(t, below) and time_le(below, t)
    # Small clocks keep a tight absolute guard.
    assert time_eps(0.0) < 1e-13
    assert not time_close(1.0, 1.0 + 1e-9)


def test_tie_ordering_regression_100k_events():
    """>=1e5-event stream ending in an engineered tie: a completion and the
    next arrivals land one ulp apart at t ~ 2^26, where the old fixed-eps
    clock saw two instants (the arrival first — letting a zero-duration
    probe backfill ahead of the full-machine head).  The deterministic
    (time, kind, seq) ordering merges them and processes the completion
    first, so the head starts and the probe cannot jump it."""
    policy = ListPolicy({1: (1, 1, 1), 8: (2, 2, 2)})
    svc = SchedulerService((2, 2, 2), policy, backfill=True)
    n_filler = 33_400
    for k in range(n_filler):
        svc.submit(JobRequest(k, 1, duration=1.0, arrival=2.0 * k))
    scale = float(2**26)
    end_a = scale + 0.125  # exactly representable
    arr_b = float(np.nextafter(end_a, 0.0))  # one ulp before the completion
    assert abs(end_a - arr_b) > 1e-12  # the old absolute eps saw two instants
    assert time_close(end_a, arr_b)  # the scale-aware clock sees one
    job_a, job_b, job_c = n_filler, n_filler + 1, n_filler + 2
    svc.submit(JobRequest(job_a, 1, duration=10.125, arrival=scale - 10.0))
    svc.submit(JobRequest(job_b, 8, duration=7.0, arrival=arr_b))
    svc.submit(JobRequest(job_c, 1, duration=0.0, arrival=arr_b))
    svc.run()

    assert len(svc.log) >= 100_000
    starts = {
        e.job_id: e.seq for e in svc.log if e.kind == "start" and e.job_id >= n_filler
    }
    by_id = {j.request.job_id: j for j in svc.scheduled}
    # Complete(A) resolved before the tied arrivals: B holds the whole
    # machine from the tie instant, and the zero-duration probe C did not
    # backfill ahead of it.
    assert starts[job_b] < starts[job_c]
    assert time_close(by_id[job_b].start, end_a)
    assert time_close(by_id[job_c].start, by_id[job_b].end)
    assert not svc.rejected


# ---------------------------------------------------------------------------
# Satellite 3 + failure semantics.
# ---------------------------------------------------------------------------
def test_failure_unblocks_head_early_and_repair_revives_victim():
    policy = ListPolicy({4: (2, 2), 2: (2, 1)})
    svc = SchedulerService((2, 2), policy)
    svc.submit(JobRequest(0, 4, duration=100.0))  # fills the machine
    svc.submit(JobRequest(1, 2, duration=5.0, arrival=1.0))  # blocked head
    svc.inject_failure(10.0, [(0, 1)])  # evacuates job 0, kills one cell
    svc.inject_reclaim(50.0, cells=[(0, 1)])  # repair
    svc.run()

    segments = [(j.request.job_id, j.start, j.end) for j in svc.scheduled]
    # Job 0's first segment is truncated at the failure.
    assert segments[0] == (0, 0.0, 10.0)
    # The failure freed cells mid-run: job 1's stale reservation (t=100)
    # was invalidated and it started at the failure instant, not at 100.
    assert segments[1] == (1, 10.0, 15.0)
    # Job 0 requeued with its remaining 90 units, but (2,2) cannot fit a
    # 3-cell degraded machine: it waits for the scheduled repair.
    assert segments[2] == (0, 50.0, 140.0)
    assert not svc.rejected
    assert svc.failed_cells == set()  # repaired
    kinds = [e.kind for e in svc.log]
    assert "fail" in kinds and "preempt" in kinds and "reclaim" in kinds


def test_failure_without_repair_rejects_impossible_victim():
    policy = ListPolicy({4: (2, 2)})
    svc = SchedulerService((2, 2), policy)
    svc.submit(JobRequest(0, 4, duration=100.0))
    svc.inject_failure(10.0, [(1, 1)])
    svc.run()
    # No pending repair: the evacuated job can never fit the degraded
    # machine and is rejected rather than blocking the queue forever.
    assert svc.rejected == [0]
    assert svc.failed_cells == {(1, 1)}
    assert svc.machine.free_units == 3


# ---------------------------------------------------------------------------
# Satellite 4: edge cases.
# ---------------------------------------------------------------------------
def test_zero_duration_jobs_chain_at_one_instant():
    policy = ListPolicy({4: (2, 2)})
    svc = SchedulerService((2, 2), policy)
    for jid in range(3):
        svc.submit(JobRequest(jid, 4, duration=0.0))
    svc.run()
    assert [(j.request.job_id, j.start, j.end) for j in svc.scheduled] == [
        (0, 0.0, 0.0),
        (1, 0.0, 0.0),
        (2, 0.0, 0.0),
    ]
    assert svc.machine.free_units == 4


def test_arrival_exactly_at_completion_instant():
    policy = ListPolicy({4: (2, 2)})
    svc = SchedulerService((2, 2), policy)
    svc.submit(JobRequest(0, 4, duration=5.0))
    svc.submit(JobRequest(1, 4, duration=1.0, arrival=5.0))
    svc.run()
    # Complete ranks before Arrival inside one instant: job 1 starts
    # immediately at t=5 instead of waiting for a later wake.
    assert [(j.request.job_id, j.start) for j in svc.scheduled] == [(0, 0.0), (1, 5.0)]
    complete0 = next(e for e in svc.log if e.kind == "complete" and e.job_id == 0)
    arrival1 = next(e for e in svc.log if e.kind == "arrival" and e.job_id == 1)
    assert complete0.seq < arrival1.seq


def test_backfill_candidates_tied_at_reservation():
    policy = ListPolicy({1: (1, 1), 2: (2, 1), 4: (2, 2)})
    svc = SchedulerService((2, 2), policy, backfill=True)
    svc.submit(JobRequest(0, 2, duration=10.0))
    svc.submit(JobRequest(1, 4, duration=1.0, arrival=1.0))  # blocked, t_res=10
    # Both candidates end exactly at the reservation — both are admitted.
    svc.submit(JobRequest(2, 1, duration=9.0, arrival=1.0))
    svc.submit(JobRequest(3, 1, duration=9.0, arrival=1.0))
    svc.run()
    by_id = {j.request.job_id: j for j in svc.scheduled}
    assert by_id[2].start == 1.0 and by_id[3].start == 1.0
    assert by_id[1].start == 10.0  # the head was never delayed


def test_impossible_request_rejected_mid_stream():
    policy = IsoperimetricPolicy()
    svc = SchedulerService((2, 2), policy)
    svc.submit(JobRequest(0, 2, duration=2.0))
    svc.submit(JobRequest(1, 8, duration=1.0, arrival=0.5))  # > machine
    svc.submit(JobRequest(2, 2, duration=1.0, arrival=1.0))
    svc.run()
    assert svc.rejected == [1]
    reject = next(e for e in svc.log if e.kind == "reject")
    assert reject.reason == "impossible"
    assert {j.request.job_id for j in svc.scheduled} == {0, 2}


def test_preempt_then_reclaim_round_trip():
    policy = ListPolicy({2: (2, 1)})
    svc = SchedulerService((2, 2), policy)
    svc.submit(JobRequest(0, 2, duration=10.0))
    svc.inject_preempt(4.0, 0)
    svc.inject_reclaim(20.0, job_id=0)
    svc.run()
    segments = [(j.start, j.end) for j in svc.scheduled]
    # Suspended with 6 units remaining, resumed at the reclaim.
    assert segments == [(0.0, 4.0), (20.0, 26.0)]
    assert svc.machine.free_units == 4
    assert not svc._suspended


def test_event_log_replay_determinism():
    scenario = generate_scenario(
        (4, 4, 4), 40, seed=7, failure_rate=0.002, repair_delay=150.0
    )
    svc = run_scenario(scenario, IsoperimetricPolicy(), backfill=True)
    assert svc.scheduled  # the scenario actually exercises the machine
    replayed = replay_events((4, 4, 4), IsoperimetricPolicy(), svc.log, backfill=True)
    assert replayed.log == svc.log
    a, b = replayed.result(), svc.result()
    assert a.rejected == b.rejected
    assert [
        (j.request.job_id, j.start, j.end, j.placement) for j in a.jobs
    ] == [(j.request.job_id, j.start, j.end, j.placement) for j in b.jobs]


# ---------------------------------------------------------------------------
# Backpressure, priorities, the monitor bridge, scenarios.
# ---------------------------------------------------------------------------
def test_backpressure_sheds_past_bound():
    policy = ListPolicy({4: (2, 2)})
    svc = SchedulerService((2, 2), policy, max_waiting=1)
    svc.submit(JobRequest(0, 4, duration=10.0))
    svc.submit(JobRequest(1, 4, duration=1.0, arrival=1.0))  # waits
    svc.submit(JobRequest(2, 4, duration=1.0, arrival=2.0))  # shed
    svc.run()
    assert svc.shed == [2]
    assert svc.rejected == [2]
    shed = next(e for e in svc.log if e.kind == "reject")
    assert shed.reason == "backpressure"
    assert {j.request.job_id for j in svc.scheduled} == {0, 1}


def test_priority_preemption_and_requeue():
    policy = ListPolicy({4: (2, 2)})
    svc = SchedulerService((2, 2), policy, preempt_priority=True)
    svc.submit(JobRequest(0, 4, duration=100.0), priority=0)
    svc.submit(JobRequest(1, 4, duration=5.0, arrival=10.0), priority=5)
    svc.run()
    segments = [(j.request.job_id, j.start, j.end) for j in svc.scheduled]
    # The high-priority job evicts the running one and starts immediately;
    # the victim resumes its remaining 90 units after.
    assert segments == [(0, 0.0, 10.0), (1, 10.0, 15.0), (0, 15.0, 105.0)]
    evict = next(e for e in svc.log if e.kind == "preempt")
    assert evict.reason == "priority"


def test_heartbeat_monitor_feeds_failures():
    clock = [0.0]
    monitor = HeartbeatMonitor(["w00", "w01"], timeout=10.0, clock=lambda: clock[0])
    worker_cells = {"w00": (0, 0), "w01": (0, 1)}
    policy = ListPolicy({4: (2, 2), 2: (2, 1)})
    svc = SchedulerService((2, 2), policy)
    svc.submit(JobRequest(0, 4, duration=100.0))
    clock[0] = 25.0
    monitor.beat("w00")  # w01 went silent
    clock[0] = 31.0
    failed = apply_monitor_failures(svc, monitor, worker_cells, time=31.0)
    assert failed == [(0, 1)]
    svc.inject_reclaim(60.0, cells=failed)
    svc.run()
    by_start = [(j.request.job_id, j.start) for j in svc.scheduled]
    assert by_start == [(0, 0.0), (0, 60.0)]  # evacuated at 31, revived at 60


def test_scenario_generator_is_deterministic_and_feasible():
    a = generate_scenario((4, 4, 4), 30, seed=3, failure_rate=0.005)
    b = generate_scenario((4, 4, 4), 30, seed=3, failure_rate=0.005)
    assert a == b
    assert len(a.jobs) == 30
    assert all(1 <= j.units <= 16 for j in a.jobs)  # <= max_fraction * 64
    assert all(j.duration > 0 for j in a.jobs)
    c = generate_scenario((4, 4, 4), 30, seed=4, failure_rate=0.005)
    assert c != a


# ---------------------------------------------------------------------------
# Replay equivalence: the batch driver IS the service.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backfill", [False, True])
def test_simulate_queue_matches_manual_service(backfill):
    rng = np.random.default_rng(11)
    table = {1: (1, 1, 1, 1), 2: (2, 1, 1, 1), 4: (2, 2, 1, 1), 8: (4, 2, 1, 1)}
    sizes = list(table)
    jobs = [
        JobRequest(
            i,
            sizes[int(rng.integers(len(sizes)))],
            duration=float(rng.uniform(1.0, 20.0)),
            arrival=float(rng.uniform(0.0, 60.0)),
        )
        for i in range(60)
    ]
    res = simulate_queue((4, 4, 1, 1), jobs, ListPolicy(table), backfill=backfill)
    svc = SchedulerService((4, 4, 1, 1), ListPolicy(table), backfill=backfill)
    for _, req in sorted(enumerate(jobs), key=lambda t: (t[1].arrival, t[0])):
        svc.submit(req)
    direct = svc.run().result()
    assert [
        (j.request.job_id, j.start, j.end, j.placement.oriented, j.placement.offset)
        for j in res.jobs
    ] == [
        (j.request.job_id, j.start, j.end, j.placement.oriented, j.placement.offset)
        for j in direct.jobs
    ]
    assert res.rejected == direct.rejected
