"""Tests for the edge-isoperimetric core: exact reproduction of the paper's
tables plus brute-force validation on small explicit tori."""

import itertools
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.network import Torus
from repro.network.geometry import ExplicitTorus, canonical, factorizations, volume
from repro.network.isoperimetry import (
    bollobas_leader_bound,
    theorem31_bound,
    lemma32_cut,
    optimal_cuboid,
    worst_cuboid,
    small_set_expansion,
)


# ---------------------------------------------------------------------------
# Torus basics
# ---------------------------------------------------------------------------
def test_canonical_sorts_descending():
    assert canonical((2, 4, 1, 3)) == (4, 3, 2, 1)


def test_degree_and_edges_cubic():
    t = Torus((4, 4, 4))
    assert t.degree == 6
    assert t.num_edges == 3 * 4 * 4 * 4  # D * N edges for a > 2


def test_double_link_convention():
    t = Torus((4, 2))
    # dim 4: 2 lines... N=8; dim of length 4: 8/4=2 rings of 4 edges = 8
    # dim of length 2: 8/2=4 pairs with double links = 8 edges
    assert t.num_edges == 8 + 8
    assert t.degree == 4


def test_eq1_regularity_identity():
    # k|A| = 2|E(A,A)| + |E(A, comp)| for cuboids
    t = Torus((6, 4, 2))
    for c in [(3, 2, 1), (6, 2, 2), (2, 2, 2), (1, 1, 1)]:
        size = volume(c)
        assert t.degree * size == 2 * t.cuboid_interior(c) + t.cuboid_cut(c)


def test_cuboid_cut_against_explicit_torus():
    dims = (4, 4, 2)
    t = Torus(dims)
    et = ExplicitTorus(dims)
    assert t.num_edges == et.num_edges
    for c in [(2, 2, 1), (4, 2, 2), (4, 4, 1), (2, 1, 1), (4, 1, 1), (2, 1, 2)]:
        verts = et.cuboid_vertices(c)
        # exact: explicit placement == aligned formula
        assert et.cut(verts) == t.cuboid_cut_aligned(c)
        # canonical cut = min over placements <= any aligned placement
        assert t.cuboid_cut(c) <= t.cuboid_cut_aligned(c)
        # Eq. 1 for the aligned placement too
        assert t.degree * len(verts) == 2 * et.interior(verts) + et.cut(verts)


@settings(max_examples=60, deadline=None)
@given(
    dims=st.lists(st.integers(2, 5), min_size=1, max_size=3).map(tuple),
    data=st.data(),
)
def test_property_cut_interior_identity_explicit(dims, data):
    """Eq. 1 holds for arbitrary subsets of small explicit tori."""
    et = ExplicitTorus(dims)
    n = et.num_vertices
    verts = list(itertools.product(*(range(a) for a in dims)))
    k = Torus(dims).degree
    subset_size = data.draw(st.integers(1, n))
    subset = data.draw(st.permutations(verts)).__getitem__(slice(subset_size))
    subset = list(subset)
    assert k * len(subset) == 2 * et.interior(subset) + et.cut(subset)


@settings(max_examples=40, deadline=None)
@given(
    dims=st.lists(st.integers(2, 6), min_size=2, max_size=3).map(tuple),
    data=st.data(),
)
def test_property_theorem31_lower_bounds_arbitrary_subsets(dims, data):
    """The Theorem 3.1 bound holds for every (random) subset of small tori —
    evidence for the paper's conjecture beyond cuboids."""
    et = ExplicitTorus(dims)
    n = et.num_vertices
    t = data.draw(st.integers(1, n // 2))
    verts = list(itertools.product(*(range(a) for a in dims)))
    subset = data.draw(st.permutations(verts))[:t]
    bound = theorem31_bound(dims, t)
    assert et.cut(list(subset)) >= bound - 1e-9


def test_theorem31_reduces_to_bollobas_leader_on_cubic():
    for n, D in [(4, 3), (6, 2), (8, 2)]:
        for t in range(1, n**D // 2 + 1):
            assert math.isclose(
                theorem31_bound((n,) * D, t), bollobas_leader_bound(n, D, t)
            )


def test_lemma32_construction_matches_bound_when_integral():
    dims = (8, 4, 4, 2)
    for r in range(4):
        k = math.prod(sorted(dims)[:r]) if r else 1
        # choose t so that (t/k)^(1/(D-r)) is an integer and fits
        side = 2
        t = k * side ** (4 - r)
        if t > volume(dims) // 2:
            continue
        got = lemma32_cut(dims, t, r)
        if got is None:
            continue
        geom, cut = got
        assert cut == Torus(dims).cuboid_cut(geom)


def test_optimal_cuboid_is_min_and_bound_holds():
    t = Torus((8, 4, 4, 2))
    for size in [4, 8, 16, 32, 64, 128]:
        opt = optimal_cuboid(t, size)
        assert opt is not None
        # bound <= optimum
        assert opt.cut >= theorem31_bound(t.dims, size) - 1e-9
        # every other cuboid is no better
        for g in t.sub_cuboids(size):
            assert t.cuboid_cut(g) >= opt.cut
        w = worst_cuboid(t, size)
        assert w.cut >= opt.cut


@settings(max_examples=30, deadline=None)
@given(
    dims=st.lists(st.sampled_from([2, 4, 6, 8]), min_size=2, max_size=4).map(tuple),
)
def test_property_bisection_equals_2N_over_L(dims):
    """For even-longest-dimension tori, bisection = 2N/L (the BG/Q formula)."""
    t = Torus(dims)
    L = t.dims[0]
    assert t.bisection_links() == 2 * t.num_vertices // L


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_property_factorizations_complete_and_correct(data):
    n = data.draw(st.integers(1, 64))
    D = data.draw(st.integers(1, 4))
    geoms = set(factorizations(n, D))
    for g in geoms:
        assert len(g) == D and volume(g) == n and g == canonical(g)
    # brute-force count for small n
    brute = set()
    for combo in itertools.product(range(1, n + 1), repeat=D):
        if math.prod(combo) == n:
            brute.add(canonical(combo))
    assert geoms == brute


def test_small_set_expansion_monotone_nonincreasing():
    t = Torus((4, 4, 2))
    vals = [small_set_expansion(t, k) for k in (2, 4, 8, 16)]
    assert all(a >= b - 1e-12 for a, b in zip(vals, vals[1:]))


# ---------------------------------------------------------------------------
# Regressions: the t > n/2 bound and the optimal/worst validation split.
# ---------------------------------------------------------------------------
def test_worst_cuboid_tightness_not_vacuous_above_half():
    """Regression: for t > n/2 the bound must be the complement-symmetry
    Theorem 3.1 bound, not the measured cut — the (3, 3, 2) cuboid of
    (4, 4, 2) cuts 24 links against a bound of 16, so ``tight`` is False
    (the historical code set bound = cut and reported the adversarial
    geometry as isoperimetrically optimal)."""
    t = Torus((4, 4, 2))
    w = worst_cuboid(t, 18)  # n = 32, t > 16
    assert w.geometry == (3, 3, 2) and w.cut == 24
    assert w.bound == pytest.approx(theorem31_bound(t.dims, 32 - 18))
    assert not w.tight


def test_bound_above_half_uses_complement_symmetry():
    t = Torus((4, 4, 2))
    o = optimal_cuboid(t, 24)
    # cut(S) == cut(S̄): the (4, 3, 2) cuboid's complement is the optimal
    # 8-vertex cuboid, so the bound at n - t certifies it exactly.
    assert o.geometry == (4, 3, 2) and o.cut == 16
    assert o.bound == pytest.approx(theorem31_bound(t.dims, 8))
    assert o.tight
    full = optimal_cuboid(t, 32)  # the whole torus: cut 0, bound 0, tight
    assert full.cut == 0 and full.bound == 0.0 and full.tight


@settings(max_examples=40, deadline=None)
@given(
    dims=st.lists(st.integers(2, 5), min_size=1, max_size=3).map(tuple),
    data=st.data(),
)
def test_property_cut_complement_symmetry_explicit(dims, data):
    """cut(S) == cut(S̄) for arbitrary subsets — the identity behind the
    t > n/2 bound."""
    et = ExplicitTorus(dims)
    verts = list(itertools.product(*(range(a) for a in dims)))
    size = data.draw(st.integers(0, len(verts)))
    perm = data.draw(st.permutations(verts))
    subset = list(perm[:size])
    complement = list(perm[size:])
    assert et.cut(subset) == et.cut(complement)


def test_optimal_and_worst_validation_aligned():
    """Regression: worst_cuboid silently returned None for out-of-range t
    while optimal_cuboid raised — both must raise now."""
    t = Torus((4, 2))
    for bad in (0, -3, 9):
        with pytest.raises(ValueError):
            optimal_cuboid(t, bad)
        with pytest.raises(ValueError):
            worst_cuboid(t, bad)
