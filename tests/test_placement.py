"""Equivalence and property tests for the vectorized placement engine.

Pins the agreement at the heart of the allocation refactor:

    vectorized engine  ==  brute-force reference scan

on random occupancy grids up to 4D — same feasibility set per orientation,
identical first-fit choice under the reference's orientation/offset ordering
— plus MachineState invariants under random allocate/release streams, the
dimension-truncation regression, the contention scorer, and the online
queue simulator (arrivals + EASY backfill).
"""

import itertools

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from reference_placement import (
    reference_first_fit,
    reference_free_offsets,
    reference_orientations,
)

from repro.network import (
    ContentionScoredPolicy,
    ElongatedPolicy,
    IsoperimetricPolicy,
    JobRequest,
    MachineState,
    simulate_queue,
)
from repro.network.geometry import volume
from repro.network.placement import (
    best_placement,
    contention_field,
    fabric_can_interfere,
    first_fit,
    free_offset_mask,
    interference_mask,
    is_spilling,
    orientations,
    pad_geometry,
    placement_cells,
    placement_loads,
    shared_link_contention,
    shell_contact,
)


def _random_case(rng):
    """A random torus (<= 4D, <= ~120 cells), occupancy grid and geometry."""
    nd = int(rng.integers(1, 5))
    while True:
        dims = tuple(int(rng.integers(1, 7)) for _ in range(nd))
        if volume(dims) <= 120:
            break
    grid = rng.random(dims) < rng.random()
    gdims = int(rng.integers(1, nd + 1))
    geometry = tuple(int(rng.integers(1, max(dims) + 1)) for _ in range(gdims))
    return dims, grid, geometry


# ---------------------------------------------------------------------------
# Engine == brute-force reference.
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_property_feasibility_set_matches_reference(seed):
    """For every fitting orientation, the engine's free-offset set equals the
    reference scan's, in the same (C) order."""
    rng = np.random.default_rng(seed)
    dims, grid, geometry = _random_case(rng)
    ors = orientations(geometry, dims)
    assert ors == reference_orientations(geometry, dims)
    for o in ors:
        free = free_offset_mask(grid, o)
        got = [tuple(int(x) for x in idx) for idx in np.argwhere(free)]
        assert got == reference_free_offsets(grid, o)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_property_first_fit_identical_to_reference(seed):
    rng = np.random.default_rng(seed)
    dims, grid, geometry = _random_case(rng)
    assert first_fit(grid, geometry) == reference_first_fit(grid, geometry)


def test_first_fit_none_when_full():
    grid = np.ones((3, 3), dtype=bool)
    assert first_fit(grid, (2, 1)) is None
    grid[1, 1] = False  # a single free cell
    assert first_fit(grid, (1, 1)) == ((1, 1), (1, 1))
    assert first_fit(grid, (2, 1)) is None


def test_free_offsets_wrap_around():
    """Torus wraparound falls out of the circular correlation."""
    grid = np.zeros(5, dtype=bool)
    grid[1:4] = True  # free cells: 4, 0 (cyclic pair)
    free = free_offset_mask(grid, (2,))
    assert list(np.flatnonzero(free)) == [4]


# ---------------------------------------------------------------------------
# MachineState invariants under random allocate/release streams.
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10**6), scored=st.sampled_from([False, True]))
def test_property_machine_state_invariants(seed, scored):
    """No cell double-booked, release exactly restores the grid, free_units
    conserved, no placement overlaps an existing one."""
    rng = np.random.default_rng(seed)
    nd = int(rng.integers(1, 4))
    dims = tuple(int(rng.integers(2, 6)) for _ in range(nd))
    m = MachineState(dims)
    live = {}
    next_id = 0
    for _ in range(30):
        if live and rng.random() < 0.4:
            job = int(rng.choice(list(live)))
            expect = live.pop(job)
            m.release(job)
            assert not m.grid[expect].any()  # release restored those cells
        else:
            geometry = tuple(int(rng.integers(1, d + 1)) for d in dims)
            if scored:
                p = m.allocate_scored(next_id, geometry)
            else:
                p = m.allocate(next_id, geometry)
            if p is not None:
                cells = placement_cells(dims, p.oriented, p.offset)
                # the placement covers exactly the requested volume and did
                # not overlap any live placement
                covered = np.zeros(dims, dtype=bool)
                covered[cells] = True
                assert int(covered.sum()) == volume(geometry)
                for other in live.values():
                    prev = np.zeros(dims, dtype=bool)
                    prev[other] = True
                    assert not (covered & prev).any()
                live[next_id] = cells
                next_id += 1
        # global invariants after every step
        union = np.zeros(dims, dtype=bool)
        for cells in live.values():
            union[cells] = True
        assert np.array_equal(m.grid, union)
        assert m.free_units == volume(dims) - int(union.sum())
    for job in list(live):
        m.release(job)
    assert m.free_units == volume(dims)
    assert not m.grid.any()


# ---------------------------------------------------------------------------
# Regression: dimension truncation bug.
# ---------------------------------------------------------------------------
def test_geometry_with_more_dims_than_machine_raises():
    """The historical scan silently truncated extra axes (the trailing-1 pad
    is a no-op for negative counts), allocating fewer cells than requested;
    now it raises."""
    m = MachineState((4, 4))
    with pytest.raises(ValueError):
        m.find_placement((2, 2, 2))
    with pytest.raises(ValueError):
        m.allocate(0, (2, 2, 2))
    with pytest.raises(ValueError):
        m.allocate_scored(0, (2, 2, 2))
    with pytest.raises(ValueError):
        pad_geometry((2, 2, 2), 2)
    from reference_placement import reference_pad_geometry

    with pytest.raises(ValueError):
        reference_pad_geometry((2, 2, 2), 2)


def test_commit_validates_orientation_and_volume():
    """MachineState.commit must reject orientations that wrap-alias (w > a)
    or that are not an arrangement of the declared geometry — the same
    silent-truncation class as the find_placement bug."""
    m = MachineState((4, 4))
    with pytest.raises(ValueError):
        m.commit(0, (6, 1), (6, 1), (0, 0))  # 6 > 4: cells would alias
    with pytest.raises(ValueError):
        m.commit(0, (2, 2), (2, 1), (0, 0))  # volume mismatch
    with pytest.raises(ValueError):
        m.commit(0, (4, 1), (2, 2), (0, 0))  # same volume, different multiset
    p = m.commit(0, (2, 2), (2, 2), (1, 1))
    assert p is not None and m.free_units == 12
    with pytest.raises(ValueError):
        m.commit(1, (2, 2), (2, 2), (0, 0))  # overlaps
    with pytest.raises(ValueError):
        m.commit(0, (1, 1), (1, 1), (0, 0))  # job already placed


def test_plan_slice_job_id_requires_state():
    from repro.launch.mesh import plan_slice

    with pytest.raises(ValueError):
        plan_slice(16, job_id=7)


def test_trailing_ones_are_stripped_not_errors():
    m = MachineState((4, 4))
    assert pad_geometry((2, 2, 1, 1), 2) == (2, 2)
    p = m.allocate(0, (2, 2, 1, 1))
    assert p is not None and m.free_units == 12
    # and padding up still works
    assert pad_geometry((3,), 2) == (3, 1)


# ---------------------------------------------------------------------------
# Scoring: contact, contention field, the isolation theorem.
# ---------------------------------------------------------------------------
def test_shell_contact_counts_occupied_shell():
    grid = np.zeros((5, 5), dtype=bool)
    grid[0, :2] = True  # a 1x2 block at the origin
    contact = shell_contact(grid, (2, 2))
    # placing a 2x2 at (1, 0) touches both occupied cells from below
    assert contact[1, 0] == 2
    # a placement whose (wrapping) shell avoids row 0 touches nothing
    assert contact[2, 2] == 0
    # the shell wraps: a 2x2 at (3, 3) reaches row 0 via the torus edge
    assert contact[3, 3] == 1


def test_pairing_traffic_is_isolated():
    """Under minimal DOR, intra-cuboid pairing traffic of disjoint cuboid
    placements never shares a link: pairing distances never exceed half a
    ring, so routes stay on the placement's own cells (the paper's
    partition-isolation property, recovered by the model)."""
    rng = np.random.default_rng(0)
    for _ in range(40):
        nd = int(rng.integers(1, 4))
        dims = tuple(int(rng.integers(2, 8)) for _ in range(nd))
        m = MachineState(dims)
        placements = []
        for job in range(4):
            geometry = tuple(int(rng.integers(1, d + 1)) for d in dims)
            p = m.allocate(job, geometry)
            if p is not None:
                placements.append(p)
        loads = [
            placement_loads(dims, p.oriented, p.offset, pattern="pairing")
            for p in placements
        ]
        for i, j in itertools.combinations(range(len(loads)), 2):
            assert shared_link_contention(loads[i], loads[j]) == 0.0
            assert shared_link_contention(loads[j], loads[i]) == 0.0


def test_all_to_all_spill_shares_links():
    """Beyond-half-ring spans route all-to-all traffic through foreign
    territory: a 5-strip on JUQUEEN's 7-ring genuinely shares links with a
    neighbour in its spill corridor (this is what the scorer minimises)."""
    dims = (7, 2, 2, 2)
    strip = placement_loads(dims, (5, 2, 2, 2), (0, 0, 0, 0))
    neighbour = placement_loads(dims, (2, 2, 2, 2), (5, 0, 0, 0))
    assert shared_link_contention(neighbour, strip) > 0.0


def test_is_spilling_and_fabric_can_interfere():
    assert is_spilling((5, 1), (7, 2))
    assert not is_spilling((7, 1), (7, 2))  # full ring wraps internally
    assert not is_spilling((4, 2), (7, 2))  # 2*4-2 = 6 < 7
    # exactly-half spans spill too: split ties route half the volume the
    # long way around (2*5-2 == 8)
    assert is_spilling((5, 2), (8, 2))
    assert fabric_can_interfere((7, 2, 2, 2))
    assert fabric_can_interfere((8, 2))
    assert not fabric_can_interfere((4, 4, 3, 2))  # Mira: isolated, all jobs
    # a 5-ring can spill (w=4) but never share: only one free position
    assert is_spilling((4, 1), (5, 2))
    assert not fabric_can_interfere((5, 4))


def test_even_ring_tie_spill_shares_links():
    """The 2w-2 == a boundary: on an 8-ring a 5-span's split-tie traffic
    routes through the 3 free positions, where a disjoint 3-span neighbour
    has its own dim-0 traffic — they share links."""
    dims = (8, 2)
    A = placement_loads(dims, (5, 2), (0, 0))
    B = placement_loads(dims, (3, 2), (5, 0))
    assert shared_link_contention(A, B) > 0.0
    assert shared_link_contention(B, A) > 0.0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_property_contention_field_matches_direct_sum(seed):
    """The FFT cross-correlation equals the direct per-offset computation:
    job loads at that offset summed over the interference mask."""
    rng = np.random.default_rng(seed)
    nd = int(rng.integers(1, 4))
    dims = tuple(int(rng.integers(2, 6)) for _ in range(nd))
    if volume(dims) > 100:
        return
    m = MachineState(dims)
    for job in range(3):
        geometry = tuple(int(rng.integers(1, d + 1)) for d in dims)
        m.allocate(job, geometry)
    mask = interference_mask(m.grid, m.traffic_loads())
    oriented = tuple(int(rng.integers(1, d + 1)) for d in dims)
    field = contention_field(dims, oriented, mask)
    for _ in range(5):
        offset = tuple(int(rng.integers(0, d)) for d in dims)
        direct = float(placement_loads(dims, oriented, offset)[mask].sum())
        assert field[offset] == pytest.approx(direct, abs=1e-8)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_property_placement_loads_translation_invariant(seed):
    """placement_loads rolls a memoised origin field; the roll must equal
    routing the translated traffic directly (DOR is translation-covariant,
    including split ties)."""
    from repro.network.placement import placement_all_to_all_traffic
    from repro.network.routing import route_dor

    rng = np.random.default_rng(seed)
    nd = int(rng.integers(1, 4))
    dims = tuple(int(rng.integers(2, 7)) for _ in range(nd))
    oriented = tuple(int(rng.integers(1, d + 1)) for d in dims)
    offset = tuple(int(rng.integers(0, d)) for d in dims)
    rolled = placement_loads(dims, oriented, offset)
    src, dst, vol = placement_all_to_all_traffic(dims, oriented, offset)
    if src.shape[0]:
        direct = route_dor(dims, src, dst, vol)
    else:
        direct = np.zeros_like(rolled)
    assert np.allclose(rolled, direct, atol=1e-9)


def test_scored_placement_avoids_spill_corridor():
    """With a 5-strip at the origin of JUQUEEN's torus, the scorer routes a
    new job onto untouched lines instead of the strip's spill corridor."""
    dims = (7, 2, 2, 2)
    m = MachineState(dims)
    assert m.allocate(0, (5, 1, 1, 1)) is not None  # strip on line (0,0,0)
    p = m.allocate_scored(1, (2, 1, 1, 1))
    assert p is not None
    assert p.predicted_contention == pytest.approx(0.0, abs=1e-9)
    # the chosen line is not the strip's spill corridor
    assert p.offset[1:] != (0, 0, 0)


def test_best_placement_deterministic_and_respects_occupancy():
    rng = np.random.default_rng(5)
    grid = rng.random((6, 6)) < 0.2
    bg = np.zeros((2, 2, 6, 6))
    a = best_placement(grid, (3, 2), bg)
    b = best_placement(grid, (3, 2), bg)
    assert a == b
    assert a is not None
    assert not grid[placement_cells(grid.shape, a.oriented, a.offset)].any()


# ---------------------------------------------------------------------------
# Online queue simulator: arrivals + EASY backfill.
# ---------------------------------------------------------------------------
def test_arrivals_delay_start():
    res = simulate_queue((4, 4), [JobRequest(0, 4, duration=1.0, arrival=5.0)],
                         IsoperimetricPolicy())
    assert res.jobs[0].start == 5.0
    assert res.mean_wait == 0.0


def test_backfill_jumps_short_job_without_delaying_head():
    jobs = [
        JobRequest(0, 12, duration=4.0),  # fills 12 of 16
        JobRequest(1, 8, duration=2.0),   # blocked head (only 4 free)
        JobRequest(2, 4, duration=3.0),   # fits now, ends before reservation
        JobRequest(3, 4, duration=9.0),   # fits now but would overrun -> held
    ]
    plain = simulate_queue((4, 4), jobs, IsoperimetricPolicy())
    eased = simulate_queue((4, 4), jobs, IsoperimetricPolicy(), backfill=True)
    s_plain = {j.request.job_id: j.start for j in plain.jobs}
    s_eased = {j.request.job_id: j.start for j in eased.jobs}
    assert s_plain[2] > 0.0 and s_eased[2] == 0.0  # short job backfilled
    assert s_eased[1] == s_plain[1]  # head not delayed
    assert s_eased[3] >= s_eased[1]  # long job correctly held back


def test_impossible_job_rejected_queue_continues():
    res = simulate_queue(
        (2, 2), [JobRequest(0, 5), JobRequest(1, 2)], IsoperimetricPolicy()
    )
    assert res.rejected == [0]
    assert [j.request.job_id for j in res.jobs] == [1]


def test_fcfs_order_preserved_without_backfill():
    jobs = [JobRequest(i, 4, duration=1.0) for i in range(4)]
    res = simulate_queue((2, 2), jobs, IsoperimetricPolicy())
    starts = [j.start for j in res.jobs]
    assert starts == sorted(starts)
    assert [j.request.job_id for j in res.jobs] == [0, 1, 2, 3]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_property_queue_simulation_is_consistent(seed):
    """Random streams: placements of concurrently running jobs never overlap
    and every scheduled job respects its arrival time."""
    rng = np.random.default_rng(seed)
    dims = (4, 3, 2)
    jobs = [
        JobRequest(
            i,
            int(rng.integers(1, 13)),
            True,
            float(rng.random() + 0.1),
            float(rng.random() * 5),
        )
        for i in range(20)
    ]
    policy = ContentionScoredPolicy() if seed % 2 else ElongatedPolicy()
    res = simulate_queue(dims, jobs, policy, backfill=bool(seed % 3))
    assert len(res.jobs) + len(res.rejected) == len(jobs)
    for job in res.jobs:
        assert job.start + 1e-9 >= job.request.arrival
    intervals = [
        (j.start, j.end, placement_cells(dims, j.placement.oriented, j.placement.offset))
        for j in res.jobs
    ]
    for (s1, e1, c1), (s2, e2, c2) in itertools.combinations(intervals, 2):
        if s1 < e2 - 1e-9 and s2 < e1 - 1e-9:  # concurrent
            g1 = np.zeros(dims, dtype=bool)
            g1[c1] = True
            g2 = np.zeros(dims, dtype=bool)
            g2[c2] = True
            assert not (g1 & g2).any()
