"""Property tests pinning the vectorized isoperimetry engine to the
historical per-cuboid oracle (``tests/reference_isoperimetry.py``), plus the
stack wiring: policy ranking, queue-replay bisection efficiency, slice
planning, and the partition advisor's paper-table reproduction."""

import math

import pytest
from _hypothesis_compat import given, settings, st

from reference_isoperimetry import (
    reference_bisection_table,
    reference_cut_table,
    reference_optimal_cuboid,
    reference_small_set_expansion,
    reference_worst_cuboid,
)
from repro.core.bgq import (
    JUQUEEN,
    MIDPLANE_DIMS,
    MIRA,
    MIRA_PROPOSED_PARTITIONS,
    MIRA_SCHEDULER_PARTITIONS,
)
from repro.launch.mesh import plan_slice
from repro.network import (
    ContentionScoredPolicy,
    IsoperimetricPolicy,
    JobRequest,
    MachineState,
    TorusFabric,
    simulate_queue,
)
from repro.network.fabric import ranked_slice_geometries, slice_fabric
from repro.network.geometry import bisection_links, sub_cuboids, volume
from repro.network.isoperimetry import (
    advise_partition,
    advise_policy_table,
    best_bisection_geometry,
    bisection_of_geometry,
    bisection_table,
    bollobas_leader_bound,
    cut_table,
    fitting_geometries,
    is_isoperimetrically_optimal,
    lemma32_cut,
    optimal_cuboid,
    ranked_geometries,
    small_set_expansion,
    theorem31_bound,
    worst_cuboid,
)

dims_upto_4d = st.lists(st.integers(1, 6), min_size=1, max_size=4).map(tuple)


# ---------------------------------------------------------------------------
# Engine == oracle.
# ---------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(dims=dims_upto_4d, data=st.data())
def test_property_cut_table_equals_oracle(dims, data):
    """The batched cut table equals the per-cuboid loop exactly: same
    geometry set, same minimum cut per geometry, same row order."""
    n = volume(dims)
    t = data.draw(st.integers(1, n))
    assert cut_table(dims, t).items() == reference_cut_table(dims, t)


@settings(max_examples=60, deadline=None)
@given(dims=dims_upto_4d, data=st.data())
def test_property_optimal_and_worst_equal_oracle(dims, data):
    """optimal/worst cuboid match the oracle including the deterministic
    tie-breaks and the complement-symmetry bound."""
    n = volume(dims)
    t = data.draw(st.integers(1, n))
    opt, ref_opt = optimal_cuboid(dims, t), reference_optimal_cuboid(dims, t)
    wst, ref_wst = worst_cuboid(dims, t), reference_worst_cuboid(dims, t)
    if ref_opt is None:
        assert opt is None and wst is None and ref_wst is None
        return
    assert (opt.geometry, opt.cut) == ref_opt[:2]
    assert opt.bound == pytest.approx(ref_opt[2])
    assert (wst.geometry, wst.cut) == ref_wst[:2]
    assert wst.bound == pytest.approx(ref_wst[2])


@settings(max_examples=20, deadline=None)
@given(dims=st.lists(st.integers(1, 5), min_size=1, max_size=3).map(tuple), data=st.data())
def test_property_small_set_expansion_equals_oracle(dims, data):
    """The regularity-identity shortcut (only min cuts needed) equals the
    full double loop over sizes x cuboids with explicit interiors."""
    n = volume(dims)
    t = data.draw(st.integers(1, min(n, 12)))
    assert small_set_expansion(dims, t) == pytest.approx(
        reference_small_set_expansion(dims, t)
    )


@settings(max_examples=40, deadline=None)
@given(dims=st.lists(st.integers(2, 8), min_size=2, max_size=4).map(tuple), data=st.data())
def test_property_lemma32_consistent_with_batched_cuts(dims, data):
    """Wherever the Lemma 3.2 construction S_r exists, its geometry appears
    in the batched cut table with the identical cut, and the batched
    minimum never exceeds it (S_r is a witness, the table is exhaustive)."""
    n = volume(dims)
    t = data.draw(st.integers(1, n // 2))
    tbl = dict(cut_table(dims, t).items())
    for r in range(len(dims)):
        got = lemma32_cut(dims, t, r)
        if got is None:
            continue
        geom, cut = got
        assert tbl[geom] == cut
        assert min(tbl.values()) <= cut


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_property_bollobas_leader_equals_theorem31_on_cubic(data):
    """On cubic tori [n]^D the generalized Theorem 3.1 bound reduces to the
    Bollobás-Leader Theorem 2.1 bound for every t."""
    n = data.draw(st.sampled_from([2, 3, 4, 5, 6, 8]))
    D = data.draw(st.integers(1, 3))
    t = data.draw(st.integers(0, n**D // 2))
    assert math.isclose(
        theorem31_bound((n,) * D, t), bollobas_leader_bound(n, D, t)
    )


# ---------------------------------------------------------------------------
# Bisection tables and rankings.
# ---------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(dims=dims_upto_4d, data=st.data())
def test_property_bisection_table_matches_reference(dims, data):
    """Batched internal bisections equal per-geometry ``bisection_links``
    (closed form and odd-longest-dimension search alike), and the ranked
    ordering equals the historical sorted-by-bisection preference list."""
    n = volume(dims)
    t = data.draw(st.integers(1, n))
    ref = reference_bisection_table(dims, t)
    if not ref:
        with pytest.raises(ValueError):
            bisection_table(dims, t)
        return
    tbl = bisection_table(dims, t)
    got = [(tuple(int(x) for x in g), int(b)) for g, b in zip(tbl.geometries, tbl.bisections)]
    assert got == ref
    old_ranking = sorted(sub_cuboids(dims, t), key=lambda g: (-bisection_links(g), g))
    assert [g for g, _ in ranked_geometries(dims, t)] == old_ranking


@settings(max_examples=40, deadline=None)
@given(dims=dims_upto_4d)
def test_property_bisection_of_geometry_matches_geometry_module(dims):
    assert bisection_of_geometry(dims) == bisection_links(dims)


def test_bisection_table_node_level_matches_bgq_partitions():
    """Node-level tables reproduce the paper machines' best/worst partition
    choices (geometry and bandwidth, including tie-breaks) for every size."""
    for machine in (MIRA, JUQUEEN):
        for mp in machine.partition_sizes():
            tbl = bisection_table(machine.midplane_dims, mp, MIDPLANE_DIMS)
            assert tbl.best() == machine.best_partition(mp)
            assert tbl.worst() == machine.worst_partition(mp)


def test_is_isoperimetrically_optimal_certificate():
    # Mira's 4-midplane scheduler geometry is *not* optimal; the proposed is.
    assert not is_isoperimetrically_optimal(
        MIRA.midplane_dims, (4, 1, 1, 1), MIDPLANE_DIMS
    )
    assert is_isoperimetrically_optimal(
        MIRA.midplane_dims, (2, 2, 1, 1), MIDPLANE_DIMS
    )
    with pytest.raises(ValueError):
        is_isoperimetrically_optimal(MIRA.midplane_dims, (5, 1, 1, 1), MIDPLANE_DIMS)


def test_fitting_geometries_empty_when_nothing_fits():
    assert fitting_geometries((4, 2), 5).shape[0] == 0
    with pytest.raises(ValueError):
        best_bisection_geometry((4, 2), 5)


def test_bisection_table_rejects_short_unit_node_dims():
    """A unit_node_dims with fewer dims than the machine would silently drop
    allocation dimensions — it must be a descriptive error, not a numpy
    broadcast failure."""
    from repro.network.isoperimetry import scaled_node_dims

    with pytest.raises(ValueError, match="fewer dims"):
        bisection_table((4, 4, 3, 2), 4, unit_node_dims=(2, 2))
    with pytest.raises(ValueError, match="fewer dims"):
        scaled_node_dims((2, 2, 1, 1), (2, 2))


def test_bisection_of_handles_dim_count_mismatches():
    tbl = bisection_table((4, 4), 4)
    # unit dims normalise away: (2, 2, 1) on the 2-D machine is the (2, 2) row
    assert tbl.bisection_of((2, 2, 1)) == tbl.bisection_of((2, 2))
    # a genuinely 3-D geometry of matching volume is a descriptive error
    with pytest.raises(ValueError, match="not a fitting"):
        bisection_table((4, 4), 8).bisection_of((2, 2, 2))
    with pytest.raises(ValueError, match="not a fitting"):
        advise_partition((4, 4), 8, (2, 2, 2))


# ---------------------------------------------------------------------------
# The partition advisor (paper Tables 4-6).
# ---------------------------------------------------------------------------
def test_advisor_reproduces_mira_proposed_partitions():
    """For every size where the paper proposes an improvement (Table 1 /
    Table 6), the advisor's optimum is exactly the proposed geometry, the
    predicted speedup is the bisection ratio (x2 for the Fig-3 pairs), and
    the current geometry is certified non-optimal."""
    advice = advise_policy_table(
        MIRA.midplane_dims, MIRA_SCHEDULER_PARTITIONS, unit_node_dims=MIDPLANE_DIMS
    )
    by_size = {a.units: a for a in advice}
    for mp, proposed in MIRA_PROPOSED_PARTITIONS.items():
        a = by_size[mp]
        assert a.optimal_geometry == proposed
        assert not a.is_current_optimal
        assert a.predicted_speedup == pytest.approx(
            a.optimal_bisection / a.current_bisection
        )
        assert 0.5 <= a.bisection_efficiency < 1.0
    for mp in (4, 8, 16):  # the Fig-3 pairs: exactly x2
        assert by_size[mp].predicted_speedup == pytest.approx(2.0)
    # sizes with no proposal are already optimal (no improvement exists)
    for mp, a in by_size.items():
        if mp not in MIRA_PROPOSED_PARTITIONS:
            assert a.is_current_optimal and a.predicted_speedup == pytest.approx(1.0)


def test_advisor_simulated_cross_check_matches_prediction():
    """simulate=True cross-checks the static pairing prediction against the
    flow simulator: for these translation-invariant patterns the two agree
    exactly (the §7 validation property at the advisor level)."""
    a = advise_partition(
        MIRA.midplane_dims, 4, (4, 1, 1, 1), unit_node_dims=MIDPLANE_DIMS,
        simulate=True,
    )
    assert a.simulated_speedup == pytest.approx(a.predicted_speedup, rel=1e-9)
    assert a.predicted_speedup == pytest.approx(2.0)
    assert a.certified  # Theorem 3.1 pins the optimum's bisection exactly


def test_advisor_defaults_to_worst_geometry_baseline():
    a = advise_partition(JUQUEEN.midplane_dims, 8, unit_node_dims=MIDPLANE_DIMS)
    worst = JUQUEEN.worst_partition(8)
    best = JUQUEEN.best_partition(8)
    assert (a.current_geometry, a.current_bisection) == worst
    assert (a.optimal_geometry, a.optimal_bisection) == best
    assert a.predicted_speedup == pytest.approx(2.0)


def test_advisor_validates_current_geometry():
    with pytest.raises(ValueError):
        advise_partition(MIRA.midplane_dims, 4, (2, 2, 2, 1), unit_node_dims=MIDPLANE_DIMS)


# ---------------------------------------------------------------------------
# Stack wiring: policies, queue replay, slice planning.
# ---------------------------------------------------------------------------
def test_contention_scored_floor_validation():
    with pytest.raises(ValueError):
        ContentionScoredPolicy(min_bisection_efficiency=1.5)


def test_contention_scored_floor_prunes_inefficient_geometries():
    m = MachineState((4, 4, 3, 2))
    default = ContentionScoredPolicy()
    strict = ContentionScoredPolicy(min_bisection_efficiency=1.0)
    assert default.geometry_preferences(m, 4) == [(2, 2, 1, 1), (4, 1, 1, 1)]
    assert strict.geometry_preferences(m, 4) == [(2, 2, 1, 1)]
    # the optimum always meets the floor, so no size becomes impossible
    for units in (1, 2, 4, 8, 16, 24):
        assert strict.geometry_preferences(m, units)


def test_contention_scored_floor_waits_instead_of_degrading():
    """On a fragmented machine the floored policy delays a job rather than
    granting an elongated partition: a (4, 3) resident leaves only a
    (4, 1) line free, which the relaxed policy grants at half efficiency
    while the floored policy waits for a (2, 2)."""
    jobs = [
        JobRequest(0, 12, duration=4.0),  # (4, 3): leaves a (4, 1) line free
        JobRequest(1, 4, duration=1.0, arrival=0.5),
    ]
    relaxed = simulate_queue((4, 4), jobs, ContentionScoredPolicy(), backfill=False)
    strict = simulate_queue(
        (4, 4), jobs,
        ContentionScoredPolicy(min_bisection_efficiency=1.0), backfill=False,
    )
    r_job = next(j for j in relaxed.jobs if j.request.job_id == 1)
    s_job = next(j for j in strict.jobs if j.request.job_id == 1)
    assert r_job.placement.geometry == (4, 1)
    assert r_job.bisection_efficiency == pytest.approx(0.5)
    assert s_job.placement.geometry == (2, 2)
    assert s_job.bisection_efficiency == pytest.approx(1.0)
    assert s_job.start > r_job.start  # efficiency is bought with waiting
    assert strict.mean_bisection_efficiency > relaxed.mean_bisection_efficiency


def test_simulate_queue_records_bisection_efficiency():
    jobs = [JobRequest(i, 4, duration=1.0) for i in range(6)]
    res = simulate_queue(MIRA.midplane_dims, jobs, IsoperimetricPolicy())
    assert all(0.0 < j.bisection_efficiency <= 1.0 for j in res.jobs)
    # the first job lands on an empty machine: the optimal geometry fits
    assert res.jobs[0].bisection_efficiency == pytest.approx(1.0)
    assert 0.0 < res.mean_bisection_efficiency <= 1.0


def test_plan_slice_reports_bisection_efficiency():
    assert plan_slice(16).bisection_efficiency == pytest.approx(1.0)
    state = MachineState((16, 16))
    state.grid[0:16:2, :] = True  # only 1-wide stripes free: (4, 4) cannot fit
    plan = plan_slice(16, state=state)
    assert plan.slice_geometry == (16, 1)
    assert plan.bisection_efficiency == pytest.approx(0.5)


@settings(max_examples=25, deadline=None)
@given(
    dims=st.lists(st.integers(1, 6), min_size=1, max_size=3).map(tuple),
    data=st.data(),
)
def test_property_ranked_slice_geometries_engine_backed_unchanged(dims, data):
    """The engine-backed candidate enumeration leaves the slice ranking
    bit-identical to the historical sub_cuboids-based ranking, for both
    fabric conventions."""
    n = volume(dims)
    chips = data.draw(st.integers(1, n))
    bgq = TorusFabric.bgq(dims)
    tpu = TorusFabric.tpu(dims)
    for pod in (bgq, tpu):
        old = sorted(
            (
                (g, slice_fabric(pod, g).bisection_links())
                for g in sub_cuboids(pod.dims, chips)
            ),
            key=lambda t: (-t[1], t[0]),
        )
        if not old:
            with pytest.raises(ValueError):
                ranked_slice_geometries(pod, chips)
            continue
        assert ranked_slice_geometries(pod, chips) == old
