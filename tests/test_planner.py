"""Differential test harness for the fleet planner.

The planner (``repro.launch.planner``) promises three reproducibility
contracts, each pinned here:

1. its ranked table is **row-identical** to the brute-force oracle
   (``reference_planner``) that prices every (geometry, mapping, rule)
   triple sequentially — floats compared bit-exact, on random fabrics up
   to 4D with small configs;
2. every emitted comm time is reproduced **standalone**: the ring part by
   re-running ``assign_axes(mapping=)`` + ``COLLECTIVE_TIME`` outside the
   planner, the pairing part by draining the bisection-pairing pattern
   through the flow simulator (the section-7 static==dynamic property);
3. ``simulated_slowdown >= 1`` on every emitted plan, by conservation
   (a flow simulation can never beat the zero-contention bound).

Plus the scheduler/mesh wiring: a plan's ``to_request`` carries its
geometry through every allocation policy, and ``plan_slice(arch=...)``
attaches the full table.
"""

import math

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from reference_planner import reference_plan, reference_rules
from repro.configs import SHAPES, ArchConfig, MoEConfig
from repro.launch.mesh import plan_slice
from repro.launch.planner import (
    AXES,
    PlanCandidate,
    default_chip_budget,
    enumerate_rules,
    format_table,
    plan_fleet,
    plan_model,
    rule_traffic,
)
from repro.network.allocation import (
    ContentionScoredPolicy,
    HintedPolicy,
    IsoperimetricPolicy,
    JobRequest,
    MachineState,
)
from repro.network.collectives import COLLECTIVE_TIME, assign_axes
from repro.network.fabric import TorusFabric
from repro.network.netsim import simulate_traffic
from repro.network.patterns import bisection_pairing

TINY_DENSE = ArchConfig(
    name="tiny-dense", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256,
)
TINY_MOE = ArchConfig(
    name="tiny-moe", family="moe", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256,
    moe=MoEConfig(num_experts=4, top_k=2),
)

# (pod dims, chips) pools: fabrics <= 4D, every chip count admits a cuboid.
SLICE_CASES = [
    ((4, 2), 4), ((4, 2), 8), ((4, 4), 4), ((4, 4), 8),
    ((2, 2, 2), 4), ((2, 2, 2), 8), ((4, 2, 2), 8), ((6, 2), 4),
    ((2, 2, 2, 2), 8), ((2, 2, 2, 2), 16),
]
TORUS_CASES = [
    ((2, 2, 2), 4), ((4, 2, 2), 8), ((4, 4, 2), 8), ((2, 2, 2, 2), 4),
]
SHAPE_NAMES = ["train_4k", "prefill_32k", "decode_32k"]


def _rows(plan):
    return [c.row() for c in plan.table]


# ---------------------------------------------------------------------------
# 1. planner == brute-force oracle (row-identical, floats bit-exact).
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    case=st.sampled_from(SLICE_CASES),
    cfg=st.sampled_from([TINY_DENSE, TINY_MOE]),
    shape=st.sampled_from(SHAPE_NAMES),
)
def test_planner_matches_oracle_slice(case, cfg, shape):
    dims, chips = case
    pod = TorusFabric.tpu(dims)
    plan = plan_model(cfg, chips, pod=pod, shape=shape)
    oracle = reference_plan(cfg, chips, pod, shape, wrap_mode="slice")
    assert _rows(plan) == oracle


@settings(max_examples=15, deadline=None)
@given(
    case=st.sampled_from(TORUS_CASES),
    cfg=st.sampled_from([TINY_DENSE, TINY_MOE]),
    shape=st.sampled_from(SHAPE_NAMES),
)
def test_planner_matches_oracle_torus(case, cfg, shape):
    dims, chips = case
    pod = TorusFabric.bgq(dims, link_bw=2e9)
    plan = plan_model(cfg, chips, pod=pod, shape=shape, wrap_mode="torus")
    oracle = reference_plan(cfg, chips, pod, shape, wrap_mode="torus")
    assert _rows(plan) == oracle


def test_planner_deterministic():
    a = plan_model(TINY_MOE, 8, pod=TorusFabric.tpu((4, 4)), shape="train_4k")
    b = plan_model(TINY_MOE, 8, pod=TorusFabric.tpu((4, 4)), shape="train_4k")
    assert _rows(a) == _rows(b)


# ---------------------------------------------------------------------------
# 2. comm time reproduced standalone: assign_axes(mapping=) + netsim.
# ---------------------------------------------------------------------------
def _assert_comm_reproduced(cand: PlanCandidate):
    assignment = assign_axes(
        cand.fabric, cand.rule.mesh_shape,
        order_hint=cand.rule.order_hint, mapping=cand.mapping,
    )
    ring = 0.0
    for axis, collective, vol in cand.traffic:
        ring += COLLECTIVE_TIME[collective](
            vol, assignment.embedding(axis), cand.fabric.link_bw
        )
    assert ring == cand.ring_time
    if cand.pair_volume_node > 0.0:
        sim = simulate_traffic(
            cand.node_dims,
            bisection_pairing(cand.node_dims),
            link_bw=cand.fabric.link_bw,
            double_link_on_2=cand.fabric.double_link_on_2,
        )
        assert math.isclose(
            cand.pairing_time, cand.pair_volume_node * sim.makespan,
            rel_tol=1e-9,
        )
    else:
        assert cand.pairing_time == 0.0
    assert cand.comm_time == cand.ring_time + cand.pairing_time


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(
    case=st.sampled_from(SLICE_CASES[:6]),
    cfg=st.sampled_from([TINY_DENSE, TINY_MOE]),
    shape=st.sampled_from(SHAPE_NAMES),
)
def test_comm_time_standalone_reproduction(case, cfg, shape):
    dims, chips = case
    plan = plan_model(cfg, chips, pod=TorusFabric.tpu(dims), shape=shape)
    for cand in plan.table:
        _assert_comm_reproduced(cand)


@pytest.mark.slow
def test_comm_time_standalone_reproduction_torus():
    plan = plan_model(
        TINY_MOE, 8, pod=TorusFabric.bgq((4, 2, 2), link_bw=2e9),
        shape="train_4k", wrap_mode="torus",
    )
    for cand in plan.table:
        _assert_comm_reproduced(cand)


# ---------------------------------------------------------------------------
# 3. simulated slowdown >= 1 by conservation, on every emitted row.
# ---------------------------------------------------------------------------
@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(
    case=st.sampled_from([((4, 2), 4), ((4, 2), 8), ((2, 2, 2), 8)]),
    cfg=st.sampled_from([TINY_DENSE, TINY_MOE]),
    shape=st.sampled_from(SHAPE_NAMES),
)
def test_simulated_slowdown_at_least_one(case, cfg, shape):
    dims, chips = case
    plan = plan_model(
        cfg, chips, pod=TorusFabric.tpu(dims), shape=shape,
        simulate_top_k=10**9,  # every row
    )
    for cand in plan.table:
        assert cand.simulated_slowdown >= 1.0 - 1e-9


def test_analytic_default_is_one():
    plan = plan_model(TINY_MOE, 8, pod=TorusFabric.tpu((4, 2)), shape="train_4k")
    assert all(c.simulated_slowdown == 1.0 for c in plan.table)


# ---------------------------------------------------------------------------
# Rule enumeration and budgets.
# ---------------------------------------------------------------------------
def test_enumerate_rules_divisibility():
    rules = enumerate_rules(TINY_MOE, 8)
    assert rules  # tiny model: everything fits, nothing filtered
    seen = set()
    for r in rules:
        d, f, t, e = r.axis_sizes
        assert d * f * t * e == 8
        assert TINY_MOE.n_heads % t == 0
        assert TINY_MOE.moe.num_experts % e == 0
        assert r.axis_sizes not in seen
        seen.add(r.axis_sizes)
    assert [r.axis_sizes for r in rules] == [r for r in reference_rules(TINY_MOE, 8)]


def test_enumerate_rules_memory_filter():
    big = ArchConfig(
        name="big-dense", family="dense", n_layers=80, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=28672, vocab_size=128256,
    )
    rules = enumerate_rules(big, 16)
    shard_bytes = 2.0 * big.param_count()
    for r in rules:
        d, f, t, e = r.axis_sizes
        assert shard_bytes / (f * t * e) <= 16e9


def test_default_chip_budget_monotone():
    assert default_chip_budget(TINY_DENSE) == 4
    big = ArchConfig(
        name="big", family="dense", n_layers=96, d_model=18432, n_heads=96,
        n_kv_heads=8, d_ff=73728, vocab_size=256000,
    )
    assert default_chip_budget(big) >= 32


def test_rule_traffic_axes_subset():
    for rule_axes in [(8, 1, 1, 1), (1, 8, 1, 1), (2, 2, 2, 1), (1, 1, 2, 4)]:
        entries = rule_traffic(TINY_MOE, SHAPES["train_4k"], rule_axes)
        sizes = dict(zip(AXES, rule_axes))
        for axis, collective, vol in entries:
            assert sizes[axis] > 1
            assert collective in COLLECTIVE_TIME
            assert vol > 0.0


# ---------------------------------------------------------------------------
# Scheduler and mesh wiring.
# ---------------------------------------------------------------------------
def test_to_request_carries_geometry_through_policies():
    plan = plan_model(TINY_MOE, 8, pod=TorusFabric.tpu((4, 4)), shape="train_4k")
    req = plan.to_request(job_id=3)
    assert req.units == 8
    assert req.geometry == plan.geometry
    for policy in (IsoperimetricPolicy(), HintedPolicy(), ContentionScoredPolicy()):
        machine = MachineState((4, 4))
        prefs = policy.preferences_for(machine, req)
        assert prefs[0] == plan.geometry
        placed = policy.allocate(machine, req)
        assert placed is not None and placed.geometry == plan.geometry


def test_job_request_geometry_validation():
    with pytest.raises(ValueError):
        JobRequest(1, 8, geometry=(3, 3))
    req = JobRequest(1, 8, geometry=(2, 4))
    assert req.geometry == (4, 2)  # canonicalised


def test_plan_slice_planner_backed():
    plan = plan_slice(8, pod=TorusFabric.tpu((4, 4)), arch="mixtral-8x7b")
    assert plan.slice_plan is not None
    assert plan.slice_geometry == plan.slice_plan.geometry
    assert plan.slice_plan.best.step_time == plan.slice_plan.table[0].step_time
    # planner-backed logical axes come from the winning sharding rule
    assert set(plan.assignment.axis_names) <= set(AXES)
    assert plan_slice(8, pod=TorusFabric.tpu((4, 4))).slice_plan is None


def test_plan_slice_planner_backed_occupancy():
    pod = TorusFabric.tpu((4, 4))
    state = MachineState((4, 4))
    first = plan_slice(8, pod=pod, state=state, job_id=1, arch="mixtral-8x7b")
    second = plan_slice(8, pod=pod, state=state, job_id=2, arch="mixtral-8x7b")
    assert first.placement is not None and second.placement is not None
    assert state.grid.sum() == 16


def test_plan_fleet_and_format():
    plans = plan_fleet([TINY_DENSE, TINY_MOE], chips=4, pod=TorusFabric.tpu((4, 2)))
    assert [p.arch for p in plans] == ["tiny-dense", "tiny-moe"]
    text = format_table(plans[1], top=3)
    assert "tiny-moe" in text and "geometry" in text


def test_bisection_efficiency_and_ranking_fields():
    plan = plan_model(TINY_MOE, 8, pod=TorusFabric.tpu((4, 4)), shape="train_4k")
    keys = [c.sort_key() for c in plan.table]
    assert keys == sorted(keys)
    for cand in plan.table:
        assert 0.0 < cand.bisection_efficiency <= 1.0
        assert cand.step_time >= max(cand.compute_time, cand.memory_time)
    assert any(np.isclose(c.bisection_efficiency, 1.0) for c in plan.table)


# ---------------------------------------------------------------------------
# The example walk-through runs end to end over all registered archs.
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_fleet_planner_example_end_to_end():
    import os
    import subprocess
    import sys
    from pathlib import Path

    from repro.configs import all_archs

    repo = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(repo / "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, str(repo / "examples" / "fleet_planner.py")],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert proc.returncode == 0, proc.stderr
    n = len(all_archs())
    assert f"all {n} plans verified" in proc.stdout
    assert proc.stdout.count("comm reproduced standalone") == n
