"""Property tests: sharding rules produce valid, divisible PartitionSpecs for
every architecture x mesh size combination (the dry-run's core invariant)."""

import jax
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import all_archs, get_arch
from repro.distributed.sharding import ShardingRules, axis_size
from repro.models import build_model


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESHES = [
    {"data": 16, "model": 16},
    {"pod": 2, "data": 16, "model": 16},
    {"data": 4, "model": 8},
    {"data": 1, "model": 1},
]

ARCHS = sorted(all_archs())


def _check_specs(arch_name, mesh_shape):
    arch = get_arch(arch_name)
    mesh = FakeMesh(mesh_shape)
    rules = ShardingRules(arch, mesh)
    model = build_model(arch)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    specs = rules.params_specs(params)
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "index"))
    assert len(flat_p) == len(flat_s)
    for (path, leaf), spec in zip(flat_p, flat_s):
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)
        for dim, axis in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if axis is None:
                continue
            sz = axis_size(mesh, axis)
            assert dim % sz == 0, (path, leaf.shape, spec)


@pytest.mark.parametrize("arch_name", ARCHS)
@pytest.mark.parametrize("mesh_shape", MESHES, ids=lambda m: "x".join(map(str, m.values())))
def test_param_specs_valid_and_divisible(arch_name, mesh_shape):
    _check_specs(arch_name, mesh_shape)


@settings(max_examples=25, deadline=None)
@given(
    arch_name=st.sampled_from(ARCHS),
    data=st.sampled_from([1, 2, 4, 8, 16]),
    model=st.sampled_from([1, 2, 4, 8, 16, 32]),
)
def test_property_specs_for_random_mesh_sizes(arch_name, data, model):
    _check_specs(arch_name, {"data": data, "model": model})


@pytest.mark.parametrize("arch_name", ARCHS)
def test_cache_specs_valid(arch_name):
    arch = get_arch(arch_name)
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = ShardingRules(arch, mesh)
    model = build_model(arch)
    cache = jax.eval_shape(lambda: model.init_cache(128, 1024))
    specs = rules.cache_specs(cache)
    flat_c = jax.tree_util.tree_flatten_with_path(cache)[0]
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "index"))
    for (path, leaf), spec in zip(flat_c, flat_s):
        for dim, axis in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if axis is None:
                continue
            assert dim % axis_size(mesh, axis) == 0, (path, leaf.shape, spec)


def test_pure_dp_layout_no_duplicate_axes():
    """opt4 layout: model axis disabled, batch/moments over both mesh axes —
    the ZeRO-1 opt_specs path must not emit duplicate axis entries."""
    arch = get_arch("rwkv6-3b")
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = ShardingRules(
        arch, mesh, fsdp_axes=("data", "model"), model_axis="none", zero_stage=1
    )
    model = build_model(arch)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    pspecs = rules.params_specs(params)
    for spec in jax.tree.leaves(pspecs, is_leaf=lambda x: hasattr(x, "index")):
        assert all(s is None for s in spec)  # ZeRO-1 + no TP: replicated params
    ospecs = rules.opt_specs(params)
    used = set()
    for spec in jax.tree.leaves(ospecs, is_leaf=lambda x: hasattr(x, "index")):
        flat = []
        for entry in spec:
            if entry is None:
                continue
            flat.extend(entry if isinstance(entry, tuple) else (entry,))
        assert len(flat) == len(set(flat)), spec  # no duplicate mesh axes
        used |= set(flat)
    assert used  # moments are actually sharded

# ---------------------------------------------------------------------------
# Constructor + spec validation (the planner's sharding-rule contract).
# ---------------------------------------------------------------------------
from repro.distributed.sharding import validate_partition_spec  # noqa: E402


def test_unknown_fsdp_axis_rejected():
    arch = get_arch("rwkv6-3b")
    mesh = FakeMesh({"data": 16, "model": 16})
    with pytest.raises(ValueError, match="fsdp"):
        ShardingRules(arch, mesh, fsdp_axes=("data", "replica"))


def test_model_axis_in_fsdp_axes_rejected():
    arch = get_arch("rwkv6-3b")
    mesh = FakeMesh({"data": 16, "model": 16})
    with pytest.raises(ValueError, match="model"):
        ShardingRules(arch, mesh, fsdp_axes=("data", "model"), model_axis="model")


def test_duplicate_fsdp_axes_rejected():
    arch = get_arch("rwkv6-3b")
    mesh = FakeMesh({"data": 16, "model": 16})
    with pytest.raises(ValueError, match="repeat"):
        ShardingRules(arch, mesh, fsdp_axes=("data", "data"))


def test_validate_partition_spec_accepts_valid():
    validate_partition_spec(["data", "model", None], FakeMesh({"data": 4, "model": 8}))
    validate_partition_spec([("data", "model"), None], {"data": 4, "model": 8})
    validate_partition_spec([None, None], ["data", "model"])


def test_validate_partition_spec_rejects_absent_axis():
    with pytest.raises(ValueError, match="absent"):
        validate_partition_spec(["data", "expert"], {"data": 4, "model": 8})


def test_validate_partition_spec_rejects_reused_axis():
    with pytest.raises(ValueError, match="reuse|more than once|duplicate"):
        validate_partition_spec(["model", ("data", "model")], {"data": 4, "model": 8})
