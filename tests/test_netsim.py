"""Property and behaviour tests for the flow-level simulator.

Pins the three agreements at the heart of the netsim subsystem:

    DOR path enumeration   ==  route_dor's load tensor (link for link)
    vectorized simulator   ==  per-flow Python reference oracle
    simulated makespan     ==  analytic max_link_load for steady patterns
                           >=  it for every pattern (conservation)

plus the consumers: phased ring all-reduce cross-checking the collective
closed form, the minimal-adaptive router (recovers nothing on
translation-invariant patterns, a real fraction on hotspots), the
``simulate_queue(contention="simulated")`` wiring (per-job slowdowns
bounded below by the static max-load proxy on every job), the forced
corridor-interference pair the static model only scores, and the
``plan_slice(simulate=True)`` bridge.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from reference_netsim import paths_to_reference, reference_simulate

from repro.launch.mesh import plan_slice
from repro.network import (
    AxisEmbedding,
    ElongatedPolicy,
    IsoperimetricPolicy,
    JobRequest,
    MachineState,
    TorusFabric,
    adaptive_paths,
    assign_axes,
    bisection_pairing,
    compare_routing,
    dor_paths,
    hotspot_line,
    link_capacities,
    nearest_neighbor_halo,
    random_permutation,
    ring_all_reduce_phases,
    ring_all_reduce_time,
    simulate_flows,
    simulate_phases,
    simulate_queue,
    simulate_traffic,
    simulated_ring_all_reduce_time,
    uniform_shift,
    validate_prediction,
)
from repro.network.geometry import volume
from repro.network.placement import placement_all_to_all_traffic, placement_loads
from repro.network.routing import max_link_load, route_dor


def _random_fabric(rng, max_cells=100):
    """Random torus dims <= 4D with a bounded cell count."""
    nd = int(rng.integers(1, 5))
    while True:
        dims = tuple(int(rng.integers(1, 9)) for _ in range(nd))
        if volume(dims) <= max_cells:
            return dims


def _random_traffic(rng, dims, max_messages=40):
    m = int(rng.integers(1, max_messages))
    src = np.stack([rng.integers(0, a, m) for a in dims], axis=1)
    dst = np.stack([rng.integers(0, a, m) for a in dims], axis=1)
    vol = rng.random(m) + 0.05
    return src, dst, vol


def _random_pattern(rng, dims):
    """A random named pattern or random explicit traffic."""
    kind = int(rng.integers(0, 4))
    if kind == 0:
        off = tuple(int(rng.integers(0, a)) for a in dims)
        return uniform_shift(dims, off)
    if kind == 1:
        return nearest_neighbor_halo(dims)
    if kind == 2:
        return random_permutation(dims, seed=int(rng.integers(0, 10**6)))
    return _random_traffic(rng, dims)


# ---------------------------------------------------------------------------
# Path enumeration == route_dor.
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_property_dor_paths_match_route_dor(seed):
    """The simulator's DOR link enumeration reproduces route_dor's load
    tensor exactly — both tie policies — and the adaptive router conserves
    the total (minimal) hop volume."""
    rng = np.random.default_rng(seed)
    dims = _random_fabric(rng)
    src, dst, vol = _random_traffic(rng, dims)
    for split in (True, False):
        paths = dor_paths(dims, src, dst, vol, split_ties=split)
        expected = route_dor(dims, src, dst, vol, split_ties=split)
        np.testing.assert_allclose(paths.link_loads(), expected, atol=1e-12)
        adaptive = adaptive_paths(dims, src, dst, vol, split_ties=split)
        assert adaptive.link_loads().sum() == pytest.approx(expected.sum())


# ---------------------------------------------------------------------------
# Vectorized simulator == per-flow reference.
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_property_simulator_matches_reference(seed):
    """Per-flow completion times and the makespan agree with the pure-
    Python fluid oracle on random fabrics, patterns and conventions."""
    rng = np.random.default_rng(seed)
    dims = _random_fabric(rng, max_cells=60)
    traffic = _random_pattern(rng, dims)
    double = bool(rng.integers(0, 2))
    paths = dor_paths(dims, *traffic)
    res = simulate_flows(paths, double_link_on_2=double)
    links_of_flow, capacity = paths_to_reference(paths, 1.0, double)
    ref_completion, ref_makespan = reference_simulate(
        paths.vol.tolist(), links_of_flow, capacity
    )
    assert res.makespan == pytest.approx(ref_makespan, rel=1e-6, abs=1e-9)
    np.testing.assert_allclose(
        res.flow_completion, np.asarray(ref_completion), rtol=1e-6, atol=1e-9
    )


# ---------------------------------------------------------------------------
# The paper's validation property (satellite: hypothesis-tested).
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_property_steady_patterns_match_prediction(seed):
    """On random fabrics <= 4D with unit bandwidth, the simulated makespan
    of any uniform-shift pattern equals the analytic max_link_load (the
    contention-free/steady case of the paper's validation experiment)."""
    rng = np.random.default_rng(seed)
    dims = _random_fabric(rng)
    off = tuple(int(rng.integers(0, a)) for a in dims)
    double = bool(rng.integers(0, 2))
    traffic = uniform_shift(dims, off)
    v = validate_prediction(dims, traffic, double_link_on_2=double)
    predicted = max_link_load(dims, route_dor(dims, *traffic), double)
    assert v.predicted_time == pytest.approx(predicted)
    if predicted == 0.0:
        assert v.simulated_time == 0.0
    else:
        assert v.matched, (dims, off, v.predicted_time, v.simulated_time)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_property_makespan_never_beats_prediction(seed):
    """No pattern ever finishes faster than max_link_load / link_bw —
    conservation through the most loaded link — on random fabrics,
    random patterns, both link conventions."""
    rng = np.random.default_rng(seed)
    dims = _random_fabric(rng, max_cells=60)
    traffic = _random_pattern(rng, dims)
    double = bool(rng.integers(0, 2))
    v = validate_prediction(dims, traffic, double_link_on_2=double)
    assert v.bounded, (dims, v.predicted_time, v.simulated_time)


def test_validation_concrete_pairing_cases():
    """The 512-node geometries of the example's table: simulated pairing
    slowdown 2.0 on the (8,8,8) cube vs 4.0 on the (16,16,2) slab — the
    paper's x2 avoidable-contention gap, derived dynamically."""
    for dims, expected in [((8, 8, 8), 2.0), ((16, 8, 4), 4.0), ((16, 16, 2), 4.0)]:
        res = simulate_traffic(dims, bisection_pairing(dims))
        assert res.makespan == pytest.approx(expected)
        assert res.slowdown == pytest.approx(expected)
        v = validate_prediction(dims, bisection_pairing(dims))
        assert v.matched and v.ratio == pytest.approx(1.0)


def test_simulator_reports_utilization_timeline():
    dims = (6, 4)
    res = simulate_traffic(
        dims, random_permutation(dims, seed=3), record_utilization=True
    )
    assert res.steps == len(res.timeline) >= 1
    last_end = 0.0
    for sample in res.timeline:
        assert sample.start == pytest.approx(last_end)
        assert 0.0 < sample.max_utilization <= 1.0 + 1e-9
        assert sample.utilization.shape == (2, 2) + dims
        last_end = sample.end
    assert last_end == pytest.approx(res.makespan)


def test_double_link_capacity_convention():
    """A length-2 dimension drains twice as fast under the BG/Q double-link
    convention, matching the analytic halving in max_link_load."""
    dims = (2, 4)
    traffic = uniform_shift(dims, (1, 0))
    bgq = simulate_traffic(dims, traffic, double_link_on_2=True)
    tpu = simulate_traffic(dims, traffic, double_link_on_2=False)
    assert bgq.makespan == pytest.approx(tpu.makespan / 2.0)
    cap = link_capacities(dims, 1.0, True)
    assert cap[0].max() == 2.0 and cap[1].max() == 1.0


# ---------------------------------------------------------------------------
# Phased collectives cross-check the closed forms.
# ---------------------------------------------------------------------------
def test_ring_all_reduce_phases_match_closed_form():
    """2(n-1) simulated neighbour-shift phases reproduce the analytic
    bidirectional ring all-reduce time exactly on a wrapped ring."""
    dims = (8, 4)
    bytes_in = 64.0
    analytic = ring_all_reduce_time(
        bytes_in, AxisEmbedding(size=8, stride=1, wrapped=True), 1.0
    )
    phases = ring_all_reduce_phases(dims, 0, bytes_in)
    assert len(phases) == 14
    sim = simulate_phases(dims, phases)
    assert sim.total_time == pytest.approx(analytic)
    assert simulated_ring_all_reduce_time(dims, 0, bytes_in) == pytest.approx(analytic)


def test_assign_axes_cost_cross_checks_dynamically():
    """The price assign_axes hands the roofline for a physically-aligned
    axis equals the flow-simulated phase schedule on the same fabric."""
    fabric = TorusFabric.tpu((8, 4))
    assignment = assign_axes(fabric, {"model": 8, "data": 4})
    emb = assignment.embedding("model")
    analytic = ring_all_reduce_time(1024.0, emb, fabric.link_bw)
    axis = assignment.phys_groups[assignment.axis_names.index("model")][0]
    simulated = simulated_ring_all_reduce_time(
        fabric.dims, axis, 1024.0, fabric.link_bw, fabric.double_link_on_2
    )
    assert simulated == pytest.approx(analytic)


# ---------------------------------------------------------------------------
# Routing-mode comparison: what routing alone can(not) recover.
# ---------------------------------------------------------------------------
def test_adaptive_recovers_nothing_on_translation_invariant_patterns():
    """Minimal-adaptive routing leaves every translation-invariant pattern
    at exactly the DOR makespan: the avoidable contention of the paper is
    a *geometry* property no minimal router can remove."""
    for dims, traffic in [
        ((16, 16, 2), bisection_pairing((16, 16, 2))),
        ((8, 8, 8), bisection_pairing((8, 8, 8))),
        ((8, 8), uniform_shift((8, 8), (2, 3))),
        ((8, 4, 2), nearest_neighbor_halo((8, 4, 2))),
    ]:
        c = compare_routing(dims, traffic)
        assert c.adaptive_makespan == pytest.approx(c.dor_makespan)
        assert c.recovered_fraction == pytest.approx(0.0)


def test_adaptive_recovers_hotspot_contention():
    """On the deliberately skewed hotspot workload the adaptive dimension
    order routes the cross-traffic around the congested line and recovers
    a real fraction of the DOR makespan."""
    dims = (8, 8)
    c = compare_routing(dims, hotspot_line(dims))
    assert c.dor_makespan == pytest.approx(6.0)
    assert c.adaptive_makespan == pytest.approx(3.0)
    assert c.recovered_fraction == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# simulate_queue(contention="simulated").
# ---------------------------------------------------------------------------
def _replay_jobs(rng, n, sizes):
    arrival = np.cumsum(rng.exponential(0.25, size=n))
    return [
        JobRequest(
            i,
            int(rng.choice(sizes)),
            True,
            float(rng.lognormal(0.0, 0.5) + 0.3),
            float(arrival[i]),
        )
        for i in range(n)
    ]


@pytest.mark.parametrize("mapping_pattern", [None, "ring"])
def test_simulated_contention_replay_respects_static_bound(mapping_pattern):
    """The Mira replay runs end-to-end under contention="simulated" and
    every job's simulated completion is bounded below by the static
    max-load proxy (the acceptance criterion; conservation makes anything
    else a simulator bug)."""
    rng = np.random.default_rng(0)
    jobs = _replay_jobs(rng, 25, [1, 2, 4, 8, 16, 24])
    res = simulate_queue(
        (4, 4, 3, 2),
        jobs,
        IsoperimetricPolicy(),
        backfill=True,
        contention="simulated",
        mapping_pattern=mapping_pattern,
    )
    assert len(res.jobs) == 25 and not res.rejected
    for job in res.jobs:
        assert job.simulated_comm_time is not None
        assert job.simulated_comm_time + 1e-9 >= job.comm_lower_bound
        assert job.simulated_slowdown >= 1.0 - 1e-9
    assert res.mean_simulated_slowdown >= 1.0 - 1e-9


def test_simulated_contention_juqueen_replay():
    """Same bound on the contended JUQUEEN torus (7-ring spills exist),
    under both a baseline and the paper's policy; the static fields keep
    matching the static-only run."""
    rng = np.random.default_rng(1)
    jobs = _replay_jobs(rng, 20, [4, 5, 6, 8, 10, 12, 20])
    for policy in (ElongatedPolicy(), IsoperimetricPolicy()):
        res = simulate_queue(
            (7, 2, 2, 2), jobs, policy, backfill=True, contention="simulated"
        )
        static = simulate_queue(
            (7, 2, 2, 2), jobs, policy, backfill=True, contention="static"
        )
        assert [j.placement for j in res.jobs] == [j.placement for j in static.jobs]
        for job in res.jobs:
            assert job.simulated_comm_time + 1e-9 >= job.comm_lower_bound


def test_simulated_contention_validates_args():
    with pytest.raises(ValueError, match="contention"):
        simulate_queue((2, 2), [], IsoperimetricPolicy(), contention="bogus")
    with pytest.raises(ValueError, match="mapping_pattern"):
        simulate_queue((2, 2), [], IsoperimetricPolicy(), mapping_pattern="ring")


def test_static_only_jobs_carry_no_simulated_fields():
    res = simulate_queue(
        (2, 2, 2),
        [JobRequest(0, 4, duration=1.0)],
        IsoperimetricPolicy(),
        measure_contention=True,
    )
    job = res.jobs[0]
    assert job.simulated_comm_time is None and job.comm_lower_bound == 0.0
    assert job.simulated_slowdown == 1.0
    assert res.mean_simulated_slowdown == 1.0


def test_forced_corridor_interference_slows_the_small_job():
    """The interference the static model only *scores* is derived as real
    completion-time loss: a span-5 job spilling over JUQUEEN's 7-ring
    slows a 2-wide corridor job by a measurable factor, while the big job
    stays at its own bound."""
    dims = (7, 2, 2)
    big = placement_all_to_all_traffic(dims, (5, 2, 2), (0, 0, 0))
    small = placement_all_to_all_traffic(dims, (2, 2, 2), (5, 0, 0))
    joint = tuple(np.concatenate(parts) for parts in zip(big, small))
    res = simulate_traffic(dims, joint)
    n_big = big[2].shape[0]
    t_big = float(res.completion[:n_big].max())
    t_small = float(res.completion[n_big:].max())
    solo_small = simulate_traffic(dims, small).makespan
    bound_big = max_link_load(dims, placement_loads(dims, (5, 2, 2), (0, 0, 0)))
    assert t_big == pytest.approx(bound_big)
    assert t_small > solo_small * 1.2  # measured 1.4x
    assert t_small == pytest.approx(0.7)


# ---------------------------------------------------------------------------
# plan_slice(simulate=True).
# ---------------------------------------------------------------------------
def test_plan_slice_simulate_records_slowdown():
    state = MachineState((16, 16))
    plan = plan_slice(16, state=state, job_id=0, simulate=True)
    assert plan.simulated_slowdown is not None
    assert plan.simulated_slowdown >= 1.0 - 1e-9
    # the mapped halo traffic is steady, so the dynamic multiplier equals
    # the mapping engine's predicted congestion
    assert plan.simulated_slowdown == pytest.approx(plan.mapping_congestion)
    geometry_only = plan_slice(16, simulate=True)
    assert geometry_only.simulated_slowdown is None


def test_mapping_machine_traffic_supports_explicit_patterns():
    """RankMapping.machine_traffic reuses the scored rank traffic, so it
    works for explicit (non-named) traffic too, and simulating it
    reproduces the mapping's own load tensor."""
    from repro.network import map_ranks
    from repro.network.routing import route_dor

    rank_traffic = (
        np.array([0, 1, 2, 3]),
        np.array([3, 2, 1, 0]),
        np.array([1.0, 2.0, 1.0, 2.0]),
    )
    m = map_ranks((4, 4), (2, 2), (1, 1), traffic=rank_traffic)
    assert m.pattern == "explicit"
    src, dst, vol = m.machine_traffic()
    np.testing.assert_allclose(route_dor((4, 4), src, dst, vol), m.loads)
    paths = dor_paths((4, 4), src, dst, vol)
    np.testing.assert_allclose(paths.link_loads(), m.loads)


def test_empty_and_degenerate_traffic():
    empty = (
        np.zeros((0, 2), dtype=np.int64),
        np.zeros((0, 2), dtype=np.int64),
        np.zeros(0),
    )
    res = simulate_traffic((4, 4), empty)
    assert res.makespan == 0.0 and res.slowdown == 1.0 and res.steps == 0
    # self-messages move nothing and complete at t=0
    self_tr = (np.array([[1, 1]]), np.array([[1, 1]]), np.array([5.0]))
    res = simulate_traffic((4, 4), self_tr)
    assert res.makespan == 0.0
    assert res.completion.tolist() == [0.0]
