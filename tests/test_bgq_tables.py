"""Exact reproduction of the paper's tables (1, 2, 5, 6, 7)."""

import pytest

from repro.core.bgq import (
    JUQUEEN,
    JUQUEEN48,
    JUQUEEN54,
    MIRA,
    SEQUOIA,
    MIDPLANE_NODES,
    juqueen_partition_table,
    machine_design_table,
    mira_partition_table,
    node_dims_of_midplane_geometry,
    partition_bisection_links,
)

# Paper Table 6 (Mira): (midplanes, current geometry, BW, proposed, proposed BW)
MIRA_TABLE6 = [
    (1, (1, 1, 1, 1), 256, None, None),
    (2, (2, 1, 1, 1), 256, None, None),
    (4, (4, 1, 1, 1), 256, (2, 2, 1, 1), 512),
    (8, (4, 2, 1, 1), 512, (2, 2, 2, 1), 1024),
    (16, (4, 4, 1, 1), 1024, (2, 2, 2, 2), 2048),
    (24, (4, 3, 2, 1), 1536, (3, 2, 2, 2), 2048),
    (32, (4, 4, 2, 1), 2048, None, None),
    (48, (4, 4, 3, 1), 3072, None, None),
    (64, (4, 4, 2, 2), 4096, None, None),
    (96, (4, 4, 3, 2), 6144, None, None),
]

# Paper Table 7 (JUQUEEN): (midplanes, worst geometry, worst BW, best, best BW)
JUQUEEN_TABLE7 = [
    (1, (1, 1, 1, 1), 256, None, None),
    (2, (2, 1, 1, 1), 256, None, None),
    (3, (3, 1, 1, 1), 256, None, None),
    (4, (4, 1, 1, 1), 256, (2, 2, 1, 1), 512),
    (5, (5, 1, 1, 1), 256, None, None),
    (6, (6, 1, 1, 1), 256, (3, 2, 1, 1), 512),
    (7, (7, 1, 1, 1), 256, None, None),
    (8, (4, 2, 1, 1), 512, (2, 2, 2, 1), 1024),
    (10, (5, 2, 1, 1), 512, None, None),
    (12, (6, 2, 1, 1), 512, (3, 2, 2, 1), 1024),
    (14, (7, 2, 1, 1), 512, None, None),
    (16, (4, 2, 2, 1), 1024, (2, 2, 2, 2), 2048),
    (20, (5, 2, 2, 1), 1024, None, None),
    (24, (6, 2, 2, 1), 1024, (3, 2, 2, 2), 2048),
    (28, (7, 2, 2, 1), 1024, None, None),
    (32, (4, 2, 2, 2), 2048, None, None),
    (40, (5, 2, 2, 2), 2048, None, None),
    (48, (6, 2, 2, 2), 2048, None, None),
    (56, (7, 2, 2, 2), 2048, None, None),
]

# Paper Table 5 subset: midplanes -> (J-54 geometry, BW), (J-48 geometry, BW)
TABLE5_J54 = {
    9: ((3, 3, 1, 1), 768),
    18: ((3, 3, 2, 1), 1536),
    27: ((3, 3, 3, 1), 2304),
    36: ((3, 3, 2, 2), 3072),
    54: ((3, 3, 3, 2), 4608),
}
TABLE5_J48 = {
    9: ((3, 3, 1, 1), 768),
    36: ((3, 3, 2, 2), 3072),
    48: ((4, 3, 2, 2), 3072),
}


def test_machine_definitions():
    assert MIRA.num_nodes == 49152 and MIRA.node_dims == (16, 16, 12, 8, 2)
    assert JUQUEEN.num_nodes == 28672 and JUQUEEN.node_dims == (28, 8, 8, 8, 2)
    assert SEQUOIA.num_nodes == 98304 and SEQUOIA.node_dims == (16, 16, 16, 12, 2)
    assert JUQUEEN54.num_midplanes == 54 and JUQUEEN48.num_midplanes == 48


def test_midplane_is_512_nodes():
    assert MIDPLANE_NODES == 512
    assert node_dims_of_midplane_geometry((1, 1, 1, 1)) == (4, 4, 4, 4, 2)


@pytest.mark.parametrize("mp,cur,bw,prop,prop_bw", MIRA_TABLE6)
def test_mira_table6_rows(mp, cur, bw, prop, prop_bw):
    rows = {r["midplanes"]: r for r in mira_partition_table()}
    r = rows[mp]
    assert r["current_geometry"] == cur
    assert r["current_bw"] == bw
    assert r["proposed_geometry"] == prop
    assert r["proposed_bw"] == prop_bw
    assert r["nodes"] == mp * 512


@pytest.mark.parametrize("mp,worst,wbw,best,bbw", JUQUEEN_TABLE7)
def test_juqueen_table7_rows(mp, worst, wbw, best, bbw):
    rows = {r["midplanes"]: r for r in juqueen_partition_table()}
    r = rows[mp]
    assert r["worst_geometry"] == worst
    assert r["worst_bw"] == wbw
    assert r["best_geometry"] == best
    assert r["best_bw"] == bbw


def test_table5_hypothetical_machines():
    rows = {r["midplanes"]: r for r in machine_design_table()}
    for mp, (geom, bw) in TABLE5_J54.items():
        assert rows[mp]["j54_geometry"] == geom
        assert rows[mp]["j54_bw"] == bw
    for mp, (geom, bw) in TABLE5_J48.items():
        assert rows[mp]["j48_geometry"] == geom
        assert rows[mp]["j48_bw"] == bw
    # JUQUEEN-48 improves the 48-midplane partition over JUQUEEN (2048 -> 3072)
    assert rows[48]["juqueen_bw"] == 2048 and rows[48]["j48_bw"] == 3072


def test_paper_intro_example_6_midplane_system():
    """Section 2 example: 3x2x1x1 system, best 1536-node partition is
    12x4x4x4x2 with 256 links; the 8x6x4x4x2 alternative would have 384."""
    from repro.network import Torus

    part = Torus((12, 4, 4, 4, 2))
    assert part.num_vertices == 1536
    assert part.bisection_links() == 256
    alt = Torus((8, 6, 4, 4, 2))
    assert alt.num_vertices == 1536
    assert alt.bisection_links() == 384


def test_machine_bisection_formula():
    # 2 N / L for the full machines
    assert MIRA.machine_bisection_links() == 2 * 49152 // 16
    assert JUQUEEN.machine_bisection_links() == 2 * 28672 // 28


def test_sequoia_supports_suboptimal_and_optimal_partitions():
    # e.g. 16 midplanes: best (2,2,2,2) = 2048, elongated (4,4,1,1) = 1024
    best = SEQUOIA.best_partition(16)
    worst = SEQUOIA.worst_partition(16)
    assert best[0] == (2, 2, 2, 2) and best[1] == 2048
    assert worst[1] < best[1]
