"""Conformance suite for the abstract Fabric interface.

Every implementation (TorusFabric, HyperXFabric) must expose the same
contract — an explicit ``links()`` incidence table the rest of the stack
programs against — so the checks here run identically over both:

    link-id hygiene        unique ids inside the dense slot space
    links <-> neighbors    neighbors() derivable from the table, symmetric
    capacity symmetry      src->dst trunk capacity == dst->src
    netsim incidence       fabric_paths routes only over links() slots,
                           with the table's own per-slot capacities
    route_pattern          bit-for-bit route_dor on every torus spelling

plus the regression for the slice planners' clear TypeError on non-ring
fabrics (wrap semantics are meaningless on cliques).
"""

import numpy as np
import pytest

from repro.network import (
    HyperXFabric,
    Torus,
    TorusFabric,
    fabric_paths,
    ranked_slice_geometries,
    route_dor,
    route_pattern,
    simulate_fabric_traffic,
    simulate_traffic,
    slice_fabric,
    worst_slice_geometry,
)
from repro.network.geometry import volume
from repro.network.netsim import link_capacities

FABRICS = [
    pytest.param(TorusFabric.bgq((4, 4, 2)), id="torus-bgq-4x4x2"),
    pytest.param(TorusFabric.tpu((4, 2), wrap=(True, False)), id="torus-tpu-4x2-chain"),
    pytest.param(TorusFabric.tpu((8, 1)), id="torus-tpu-8x1"),
    pytest.param(HyperXFabric((4, 4)), id="hyperx-4x4"),
    pytest.param(HyperXFabric((6, 3, 2)), id="hyperx-6x3x2"),
    pytest.param(HyperXFabric((4, 3), link_multiplicity=(2, 3)), id="hyperx-trunked"),
    pytest.param(HyperXFabric((5, 1)), id="hyperx-5x1"),
]


def _random_traffic(fabric, rng, n_msgs=40):
    """Random (src, dst, vol) coordinate traffic with no self-messages."""
    dims = fabric.dims
    n = volume(dims)
    src = rng.integers(0, n, size=n_msgs)
    dst = (src + rng.integers(1, n, size=n_msgs)) % n
    vol = rng.uniform(0.5, 2.0, size=n_msgs)
    to_coords = lambda flat: np.stack(np.unravel_index(flat, dims), axis=1)
    return to_coords(src), to_coords(dst), vol


# ---------------------------------------------------------------------------
# The links() table itself.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fabric", FABRICS)
def test_link_ids_unique_and_in_slot_space(fabric):
    table = fabric.links()
    assert len(np.unique(table.link)) == len(table)
    if len(table):
        assert table.link.min() >= 0
        assert table.link.max() < table.n_slots
    assert np.all(table.capacity > 0.0)
    n = fabric.num_cells
    assert np.all((table.src >= 0) & (table.src < n))
    assert np.all((table.dst >= 0) & (table.dst < n))
    assert np.all(table.src != table.dst)


@pytest.mark.parametrize("fabric", FABRICS)
def test_neighbors_match_table_and_are_symmetric(fabric):
    table = fabric.links()
    n = fabric.num_cells
    adj = {c: set() for c in range(n)}
    for s, d in zip(table.src, table.dst):
        adj[int(s)].add(int(d))
    for cell in range(n):
        nbrs = fabric.neighbors(cell)
        assert list(nbrs) == sorted(adj[cell])
        assert np.all(nbrs != cell)
        for other in nbrs:
            assert cell in adj[int(other)]  # directed table covers both ways


@pytest.mark.parametrize("fabric", FABRICS)
def test_capacity_symmetric_per_cell_pair(fabric):
    table = fabric.links()
    cap = {}
    for s, d, c in zip(table.src, table.dst, table.capacity):
        cap[(int(s), int(d))] = cap.get((int(s), int(d)), 0.0) + float(c)
    for (s, d), c in cap.items():
        assert cap[(d, s)] == pytest.approx(c)


@pytest.mark.parametrize("fabric", FABRICS)
def test_dense_capacities_zero_only_on_unused_slots(fabric):
    table = fabric.links()
    dense = table.dense_capacities()
    assert dense.shape == (table.n_slots,)
    np.testing.assert_allclose(dense[table.link], table.capacity)
    used = np.zeros(table.n_slots, dtype=bool)
    used[table.link] = True
    assert np.all(dense[~used] == 0.0)


def test_torus_link_table_matches_netsim_capacities():
    """The torus table folds BG/Q double links into capacity exactly as
    netsim's ``link_capacities`` tensor does, slot for slot."""
    for fab in (TorusFabric.bgq((4, 2, 2)), TorusFabric.tpu((4, 2))):
        dense = fab.links().dense_capacities()
        ref = link_capacities(
            fab.dims, link_bw=fab.link_bw, double_link_on_2=fab.double_link_on_2
        ).ravel()
        np.testing.assert_allclose(dense, ref)


def test_hyperx_degree_and_link_count():
    fab = HyperXFabric((4, 3), link_multiplicity=(2, 3))
    table = fab.links()
    # One directed table row per (cell, same-dim peer); trunking folds
    # into capacity, not row count.
    assert len(table) == fab.num_cells * sum(a - 1 for a in fab.dims)
    assert fab.degree == sum(k * (a - 1) for a, k in zip(fab.dims, fab.link_multiplicity))
    nbrs = fab.neighbors(0)
    assert len(nbrs) == sum(a - 1 for a in fab.dims)


# ---------------------------------------------------------------------------
# netsim builds its incidence from the same table.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fabric", FABRICS)
def test_netsim_routes_only_over_fabric_links(fabric):
    rng = np.random.default_rng(7)
    src, dst, vol = _random_traffic(fabric, rng)
    paths = fabric_paths(fabric, (src, dst, vol))
    table = fabric.links()
    assert np.all(np.isin(paths.link_ids, table.link))
    if isinstance(fabric, HyperXFabric):
        np.testing.assert_allclose(
            paths.capacities, table.dense_capacities() / fabric.link_bw
        )
    else:
        assert paths.capacities is None  # historical torus layout


def test_fabric_sim_bit_identical_to_torus_sim():
    fab = TorusFabric.bgq((4, 4))
    rng = np.random.default_rng(11)
    src, dst, vol = _random_traffic(fab, rng)
    a = simulate_fabric_traffic(
        fab, (src, dst, vol), link_bw=fab.link_bw, double_link_on_2=True
    )
    b = simulate_traffic(
        fab.dims, (src, dst, vol), link_bw=fab.link_bw, double_link_on_2=True
    )
    assert a.makespan == b.makespan
    assert a.slowdown == b.slowdown
    np.testing.assert_array_equal(a.completion, b.completion)
    np.testing.assert_array_equal(a.link_loads, b.link_loads)


# ---------------------------------------------------------------------------
# route_pattern dispatch.
# ---------------------------------------------------------------------------
def test_route_pattern_torus_bit_for_bit_every_spelling():
    dims = (4, 4, 2)
    rng = np.random.default_rng(3)
    fab = TorusFabric.bgq(dims)
    src, dst, vol = _random_traffic(fab, rng)
    want = route_dor(dims, src, dst, vol)
    for spelling in (fab, Torus(dims), dims):
        got = route_pattern(spelling, src, dst, vol)
        np.testing.assert_array_equal(got, want)


def test_route_pattern_rejects_foreign_modes():
    src = np.array([[0, 0]])
    dst = np.array([[1, 1]])
    with pytest.raises(ValueError, match="mode='dor' only"):
        route_pattern(TorusFabric.bgq((4, 4)), src, dst, 1.0, mode="dal")
    with pytest.raises(ValueError, match="numpy-only"):
        route_pattern(HyperXFabric((4, 4)), src, dst, 1.0, backend="xla")


def test_route_pattern_hyperx_returns_flat_loads():
    hx = HyperXFabric((4, 4))
    loads = route_pattern(hx, np.array([[0, 0]]), np.array([[2, 3]]), 1.0)
    assert loads.shape == (hx.links().n_slots,)
    assert float(loads.sum()) == 2.0  # Hamming distance 2, one unit each hop


# ---------------------------------------------------------------------------
# Slice planning stays ring-only (regression for the clear TypeError).
# ---------------------------------------------------------------------------
def test_slice_planners_reject_hyperx_with_clear_type_error():
    hx = HyperXFabric((4, 4))
    with pytest.raises(TypeError, match="ring"):
        slice_fabric(hx, (2, 2))
    with pytest.raises(TypeError, match="ring"):
        ranked_slice_geometries(hx, 4)
    with pytest.raises(TypeError, match="ring"):
        worst_slice_geometry(hx, 4)
