"""The historical per-hop DOR link-load walker, kept as a test reference.

This is the exact pre-refactor implementation of ``repro.core.contention.
LinkLoads`` (one Python loop iteration per hop).  It exists only to validate
the vectorized engine in ``repro.network.routing`` — the equivalence property
tests route identical traffic through both and compare the full load tensors
— and to anchor the routing micro-benchmark's speedup claim.  Do not use it
in library code.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

import numpy as np

Coord = Tuple[int, ...]


@dataclass
class ReferenceLinkLoads:
    """Exact directed-link load accounting on a torus under DOR routing."""

    dims: Tuple[int, ...]
    split_ties: bool = True
    # loads[k][d] has the torus shape; entry v = volume on the link leaving
    # vertex v in dimension k, direction d (0: +1, 1: -1).
    loads: List[List[np.ndarray]] = field(init=False)

    def __post_init__(self):
        self.dims = tuple(int(a) for a in self.dims)
        self.loads = [
            [np.zeros(self.dims, dtype=np.float64) for _ in range(2)]
            for _ in range(len(self.dims))
        ]

    def add_path(self, src: Coord, dst: Coord, vol: float) -> None:
        """Route vol from src to dst with dimension-ordered minimal routing."""
        cur = list(src)
        for k, a in enumerate(self.dims):
            if a == 1:
                continue
            delta = (dst[k] - cur[k]) % a
            if delta == 0:
                continue
            if delta < a - delta:
                self._walk(cur, k, +1, delta, vol)
            elif delta > a - delta:
                self._walk(cur, k, -1, a - delta, vol)
            else:  # tie: distance exactly a/2
                if self.split_ties:
                    self._walk(list(cur), k, +1, delta, vol / 2.0)
                    self._walk(cur, k, -1, delta, vol / 2.0)
                else:
                    self._walk(cur, k, +1, delta, vol)
            cur[k] = dst[k]

    def _walk(self, cur: List[int], k: int, direction: int, hops: int, vol: float) -> None:
        a = self.dims[k]
        pos = list(cur)
        for _ in range(hops):
            if direction > 0:
                self.loads[k][0][tuple(pos)] += vol
                pos[k] = (pos[k] + 1) % a
            else:
                self.loads[k][1][tuple(pos)] += vol
                pos[k] = (pos[k] - 1) % a

    def load_array(self) -> np.ndarray:
        """(D, 2, *dims) tensor, matching routing.route_dor's layout."""
        return np.stack([np.stack(pair) for pair in self.loads])

    def max_load(self) -> float:
        """Maximum load on any directed link (double links halve, BG/Q)."""
        m = 0.0
        for k, a in enumerate(self.dims):
            if a == 1:
                continue
            scale = 0.5 if a == 2 else 1.0
            for d in range(2):
                m = max(m, scale * float(self.loads[k][d].max()))
        return m

    def total_hop_volume(self) -> float:
        return float(sum(arr.sum() for pair in self.loads for arr in pair))
