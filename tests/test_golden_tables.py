"""Golden tests pinning the paper-reproduction numbers surfaced by
``mira_partition_table()`` and ``examples/partition_analysis.py``, so
allocation/placement refactors cannot silently drift the reproduction.

The tables themselves are asserted row-exact (paper Table 6 / Fig 3 /
the TPU slice-planning adaptation); the queue replay — which exercises the
placement engine end-to-end through the example script — is asserted
structurally (every job scheduled, the isoperimetric policy strictly beats
the elongated baseline), since its precise means are policy-heuristic
implementation detail rather than paper content.
"""

import os
import re
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core.bgq import (
    JUQUEEN,
    MIDPLANE_DIMS,
    MIRA,
    MIRA_SCHEDULER_PARTITIONS,
    mira_partition_table,
    node_dims_of_midplane_geometry,
)
from repro.launch.mesh import plan_slice
from repro.network import pairing_speedup
from repro.network.isoperimetry import advise_partition, advise_policy_table

REPO = Path(__file__).resolve().parents[1]

# Paper Table 6, verbatim: midplanes -> (current geometry, bw, proposed, bw).
GOLDEN_TABLE6 = {
    1: ((1, 1, 1, 1), 256, None, None),
    2: ((2, 1, 1, 1), 256, None, None),
    4: ((4, 1, 1, 1), 256, (2, 2, 1, 1), 512),
    8: ((4, 2, 1, 1), 512, (2, 2, 2, 1), 1024),
    16: ((4, 4, 1, 1), 1024, (2, 2, 2, 2), 2048),
    24: ((4, 3, 2, 1), 1536, (3, 2, 2, 2), 2048),
    32: ((4, 4, 2, 1), 2048, None, None),
    48: ((4, 4, 3, 1), 3072, None, None),
    64: ((4, 4, 2, 2), 4096, None, None),
    96: ((4, 4, 3, 2), 6144, None, None),
}

# The example's TPU slice-planning block: chips -> (best geometry, best bw,
# worst geometry, worst bw, avoidable-contention factor).
GOLDEN_TPU_PLANS = {
    16: ((4, 4), 4, (16, 1), 2, 2.0),
    32: ((8, 4), 4, (16, 2), 4, 1.0),
    64: ((8, 8), 8, (16, 4), 8, 1.0),
}


def test_mira_partition_table_golden():
    rows = {r["midplanes"]: r for r in mira_partition_table()}
    assert set(rows) == set(GOLDEN_TABLE6)
    for mp, (cur, bw, prop, pbw) in GOLDEN_TABLE6.items():
        r = rows[mp]
        assert (r["current_geometry"], r["current_bw"]) == (cur, bw)
        assert (r["proposed_geometry"], r["proposed_bw"]) == (prop, pbw)
        assert r["nodes"] == mp * 512


def test_fig3_pairing_speedups_golden():
    nd = node_dims_of_midplane_geometry
    assert pairing_speedup(nd((4, 1, 1, 1)), nd((2, 2, 1, 1))) == pytest.approx(2.0)
    assert pairing_speedup(nd((4, 4, 1, 1)), nd((2, 2, 2, 2))) == pytest.approx(2.0)


def test_tpu_slice_plans_golden():
    for chips, (geom, bis, wgeom, wbis, factor) in GOLDEN_TPU_PLANS.items():
        plan = plan_slice(chips)
        assert plan.slice_geometry == geom
        assert plan.slice_bisection_links == bis
        assert plan.worst_geometry == wgeom
        assert plan.worst_bisection_links == wbis
        assert plan.avoidable_contention == pytest.approx(factor)
        assert plan.placement is None  # geometry-only planning
        assert plan.bisection_efficiency == pytest.approx(1.0)  # empty pod


# Paper Tables 4-6 improvement pairs as the advisor reports them:
# midplanes -> (current geometry, bw, optimal geometry, bw, predicted x).
GOLDEN_ADVISOR_PAIRS = {
    4: ((4, 1, 1, 1), 256, (2, 2, 1, 1), 512, 2.0),
    8: ((4, 2, 1, 1), 512, (2, 2, 2, 1), 1024, 2.0),
    16: ((4, 4, 1, 1), 1024, (2, 2, 2, 2), 2048, 2.0),
    24: ((4, 3, 2, 1), 1536, (3, 2, 2, 2), 2048, 4.0 / 3.0),
}


def test_partition_advisor_golden():
    """The advisor reproduces the paper's Mira/JUQUEEN geometry-improvement
    pairs (Tables 4-6), and its predicted speedups are cross-checked against
    flow-simulated makespans within 10% (they are in fact exactly equal —
    the pairing pattern is steady, so simulated == predicted)."""
    advice = {
        a.units: a
        for a in advise_policy_table(
            MIRA.midplane_dims, MIRA_SCHEDULER_PARTITIONS, unit_node_dims=MIDPLANE_DIMS
        )
    }
    assert set(advice) == set(MIRA_SCHEDULER_PARTITIONS)
    for mp, (cur, cbw, opt, obw, pred) in GOLDEN_ADVISOR_PAIRS.items():
        a = advice[mp]
        assert (a.current_geometry, a.current_bisection) == (cur, cbw)
        assert (a.optimal_geometry, a.optimal_bisection) == (opt, obw)
        assert a.predicted_speedup == pytest.approx(pred)
        assert not a.is_current_optimal
    for mp in set(advice) - set(GOLDEN_ADVISOR_PAIRS):
        assert advice[mp].is_current_optimal
        assert advice[mp].predicted_speedup == pytest.approx(1.0)
    # The simulated cross-check (Mira 4-midplane pair; the example also
    # drains the 8- and 16-midplane pairs and JUQUEEN's 8-midplane pair).
    sim = advise_partition(
        MIRA.midplane_dims, 4, MIRA_SCHEDULER_PARTITIONS[4],
        unit_node_dims=MIDPLANE_DIMS, simulate=True,
    )
    assert sim.simulated_speedup is not None
    assert abs(sim.simulated_speedup / sim.predicted_speedup - 1.0) <= 0.1
    # JUQUEEN: no fixed scheduler list — the advisor's baseline is the
    # worst-geometry partition (paper Table 7's pair at 8 midplanes).
    jq = advise_partition(
        JUQUEEN.midplane_dims, 8, unit_node_dims=MIDPLANE_DIMS, simulate=True
    )
    assert (jq.current_geometry, jq.optimal_geometry) == ((4, 2, 1, 1), (2, 2, 2, 1))
    assert jq.predicted_speedup == pytest.approx(2.0)
    assert abs(jq.simulated_speedup / jq.predicted_speedup - 1.0) <= 0.1


def test_partition_analysis_example_end_to_end():
    """The example script runs clean and reproduces the golden lines; the
    queue replay schedules every job and the isoperimetric policy strictly
    beats the elongated baseline on predicted communication time."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    env["REPLAY_JOBS"] = "40"
    proc = subprocess.run(
        [sys.executable, str(REPO / "examples" / "partition_analysis.py")],
        capture_output=True,
        text=True,
        env=env,
        timeout=300,
    )
    assert proc.returncode == 0, proc.stderr
    out = proc.stdout
    # Table 6 golden lines (the paper's improved rows)
    assert "4 midplanes: (4, 1, 1, 1) bw=256 -> (2, 2, 1, 1) bw=512" in out
    assert "16 midplanes: (4, 4, 1, 1) bw=1024 -> (2, 2, 2, 2) bw=2048" in out
    # Fig 3 golden speedups
    assert "4 midplanes: x2.00" in out
    assert "16 midplanes: x2.00" in out
    # TPU adaptation golden line
    assert (
        "16 chips: best (4, 4) (bisection 4) vs worst (16, 1) (2) "
        "-> avoidable contention x2.0" in out
    )
    # Partition advisor table: the Tables 4-6 improvement pairs, with the
    # flow-simulated cross-check matching every prediction within 10%.
    assert "Partition advisor" in out
    assert (
        "Mira   4 midplanes: (4, 1, 1, 1) bw=256 -> (2, 2, 1, 1) bw=512  "
        "efficiency 0.50  predicted x2.00  simulated x2.00  [Thm 3.1 certified]"
        in out
    )
    assert (
        "Mira  16 midplanes: (4, 4, 1, 1) bw=1024 -> (2, 2, 2, 2) bw=2048  "
        "efficiency 0.50  predicted x2.00  simulated x2.00" in out
    )
    assert (
        "Mira  24 midplanes: (4, 3, 2, 1) bw=1536 -> (3, 2, 2, 2) bw=2048  "
        "efficiency 0.75  predicted x1.33" in out
    )
    assert "Mira  32 midplanes: (4, 4, 2, 1) bw=2048  (already optimal)" in out
    assert (
        "JUQUEEN   8 midplanes: (4, 2, 1, 1) bw=512 -> (2, 2, 2, 1) bw=1024"
        in out
    )
    advisor_pairs = re.findall(r"predicted x([\d.]+)  simulated x([\d.]+)", out)
    assert len(advisor_pairs) >= 4  # Mira 4/8/16 + JUQUEEN 8
    for pred, sim in advisor_pairs:
        assert abs(float(pred) / float(sim) - 1.0) <= 0.1
    # Queue replay: every policy schedules all 40 jobs, none rejected
    replay = re.findall(
        r"(elongated|list|isoperimetric|contention-scored): scheduled\s+(\d+)"
        r"\s+rejected\s+(\d+)\s+comm\s+([\d.]+)",
        out,
    )
    assert {name for name, *_ in replay} == {
        "elongated", "list", "isoperimetric", "contention-scored"
    }
    comm = {}
    for name, scheduled, rejected, comm_time in replay:
        assert int(scheduled) == 40 and int(rejected) == 0
        comm[name] = float(comm_time)
    assert comm["isoperimetric"] < comm["elongated"]
    assert comm["contention-scored"] <= comm["isoperimetric"] + 1e-9
    # JUQUEEN shared-fabric replay present with all three policies
    assert "JUQUEEN shared-fabric replay" in out
    # Mapping-vs-geometry study: golden rows (the (4, 4) slice is already
    # optimal under row-major; no mapping fixes the (16, 1) line; the
    # transposed landing is recovered entirely by the axis-permutation
    # search) and the per-job mapping replay never worsens row-major.
    assert "Rank mapping vs partition geometry" in out
    assert (
        "best (4, 4) <- logical (4, 4): row-major congestion 2.0 -> mapped 2.0"
        in out
    )
    assert (
        "worst (16, 1) <- logical (4, 4): row-major congestion 6.0 -> mapped 6.0"
        in out
    )
    assert (
        "transposed (2, 8) <- logical (8, 2): row-major congestion 6.0 -> "
        "mapped 2.0 (axis-permutation)" in out
    )
    mapped_replay = re.findall(
        r"(Mira|JUQUEEN): scheduled\s+(\d+)\s+row-major congestion\s+([\d.]+)"
        r" -> mapped\s+([\d.]+)",
        out,
    )
    assert {name for name, *_ in mapped_replay} == {"Mira", "JUQUEEN"}
    for _, scheduled, identity_c, mapped_c in mapped_replay:
        assert int(scheduled) > 0
        assert float(mapped_c) <= float(identity_c) + 1e-9
    # Netsim validation table: the static predictions are confirmed by the
    # flow simulator row by row, and the best/worst 512-node geometries'
    # simulated slowdown ratio reproduces the paper's ~2x gap within 10%.
    assert "Predicted vs simulated contention" in out
    assert "512-node best (8,8,8): predicted x2.0  simulated x2.00" in out
    assert "512-node worst (16,16,2): predicted x4.0  simulated x4.00" in out
    ratio = re.search(r"512-node worst/best simulated ratio: x([\d.]+)", out)
    assert ratio is not None
    assert abs(float(ratio.group(1)) - 2.0) <= 0.2  # the paper's gap, +-10%
    assert (
        "Mira 4-midplane worst (4, 1, 1, 1) vs best (2, 2, 1, 1): "
        "predicted x2.00, simulated x2.00" in out
    )
    assert (
        "JUQUEEN 8-midplane worst (4, 2, 1, 1) vs best (2, 2, 2, 1): "
        "predicted x2.00, simulated x2.00" in out
    )
    # Routing study: minimal-adaptive recovers nothing of the pairing
    # benchmark's geometry-induced contention but half of the hotspot's.
    assert "pairing on (16, 16, 2): makespan 4.0 -> 4.0, recovered 0%" in out
    assert "hotspot line on (8, 8): makespan 6.0 -> 3.0, recovered 50%" in out
    # Simulated-contention replay: both machines run end-to-end and every
    # job's simulated completion respects the static max-load bound; the
    # forced corridor pair shows real interference when isolation breaks.
    sim_replay = re.findall(
        r"(Mira|JUQUEEN): scheduled\s+(\d+)\s+all jobs >= static bound: (\w+)"
        r"\s+mean slowdown x([\d.]+)\s+max x([\d.]+)",
        out,
    )
    assert {name for name, *_ in sim_replay} == {"Mira", "JUQUEEN"}
    for _, scheduled, bounded, mean_s, max_s in sim_replay:
        assert int(scheduled) > 0
        assert bounded == "True"
        assert float(max_s) >= float(mean_s) >= 1.0
    assert "slows the small job x1.40" in out


# ---------------------------------------------------------------------------
# Fleet-planner golden plans on Mira's node torus (16 midplanes, train_4k).
# arch -> (best (d,f,t,e), best mapping, step seconds, comm seconds,
#          worst/best step ratio, table rows).
# ---------------------------------------------------------------------------
GOLDEN_FLEET_PLANS = {
    "mixtral-8x7b": (
        (1, 16, 1, 1), "gray-snake", 65.76192673719228, 65.67542784,
        68.97977716257631, 52,
    ),
    "qwen1.5-110b": (
        (1, 16, 1, 1), "gray-snake", 156.98542122669093, 156.38593536000002,
        24.626936833096032, 13,
    ),
    "nemotron-4-340b": (
        (16, 1, 1, 1), "gray-snake", 322.1977022487287, 320.374259712,
        32.39810184542654, 36,
    ),
}


def test_fleet_planner_mira_golden():
    """The joint geometry x mapping x sharding search lands on the paper's
    certified-optimal (2, 2, 2, 2) cube for every flagship model, the chosen
    geometry's bisection matches ``advise_partition``'s optimum exactly, and
    the worst table row pays well over the paper's 1.3x avoidable-contention
    floor relative to the best."""
    from repro.launch.planner import plan_model
    from repro.network.fabric import TorusFabric

    pod = TorusFabric.bgq(MIRA.midplane_dims, link_bw=2e9)
    for arch, (axes, strategy, step, comm, ratio, rows) in GOLDEN_FLEET_PLANS.items():
        plan = plan_model(
            arch, 16, pod=pod, shape="train_4k",
            wrap_mode="torus", unit_node_dims=MIDPLANE_DIMS,
        )
        best, worst = plan.table[0], plan.table[-1]
        assert plan.geometry == (2, 2, 2, 2)
        assert plan.bisection_efficiency == pytest.approx(1.0)
        adv = advise_partition(
            MIRA.midplane_dims, 16, plan.geometry, unit_node_dims=MIDPLANE_DIMS
        )
        assert adv.optimal_geometry == plan.geometry
        assert adv.current_bisection == adv.optimal_bisection
        assert best.axis_sizes == axes
        assert best.mapping_strategy == strategy
        assert best.step_time == pytest.approx(step, rel=1e-9)
        assert best.comm_time == pytest.approx(comm, rel=1e-9)
        assert worst.step_time / best.step_time == pytest.approx(ratio, rel=1e-9)
        assert worst.step_time / best.step_time >= 1.3
        assert len(plan.table) == rows
