"""Per-architecture smoke tests: reduced configs, one forward + train step on
CPU, asserting output shapes and absence of NaNs (assignment requirement)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_archs, get_arch, SHAPES, cells
from repro.models import build_model, synthetic_batch

ARCH_NAMES = sorted(all_archs())


@pytest.fixture(scope="module")
def rng():
    return jax.random.key(0)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_no_nans(name, rng):
    cfg = get_arch(name).reduced()
    model = build_model(cfg)
    params = model.init(rng)
    B, S = 2, 16
    batch = synthetic_batch(cfg, B, S)
    logits, aux = jax.jit(model.forward)(params, batch)
    V = cfg.padded_vocab_size
    if cfg.n_codebooks > 1:
        assert logits.shape == (B, S, cfg.n_codebooks, V)
    elif cfg.frontend == "vlm":
        assert logits.shape == (B, S + cfg.num_patches, V)
    else:
        assert logits.shape == (B, S, V)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_one_train_step_no_nans(name, rng):
    cfg = get_arch(name).reduced()
    model = build_model(cfg)
    params = model.init(rng)
    batch = synthetic_batch(cfg, 2, 16)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(p, b)
        p2 = jax.tree.map(lambda x, g: x - 1e-3 * g.astype(x.dtype), p, grads)
        return loss, p2

    loss, new_params = step(params, batch)
    assert jnp.isfinite(loss)
    for leaf in jax.tree.leaves(new_params):
        assert not bool(jnp.isnan(leaf).any())


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_step_shapes(name, rng):
    cfg = get_arch(name).reduced()
    model = build_model(cfg)
    params = model.init(rng)
    B, S = 2, 8
    cache = model.init_cache(B, S)
    if cfg.frontend == "audio":
        batch = {"frame_embeds": jnp.zeros((B, 1, cfg.d_model), cfg.activation_dtype)}
    else:
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
        if cfg.frontend == "vlm":
            batch["patch_embeds"] = jnp.zeros((B, cfg.num_patches, cfg.d_model), cfg.activation_dtype)
    logits, new_cache = jax.jit(model.decode_step)(params, cache, batch, jnp.array(0))
    assert logits.shape[-1] == cfg.padded_vocab_size
    assert not bool(jnp.isnan(logits).any())
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


TOKEN_ARCHS = [
    n for n in ARCH_NAMES if get_arch(n).frontend == "none"
]


@pytest.mark.parametrize("name", TOKEN_ARCHS)
def test_decode_matches_prefill(name, rng):
    """Teacher-forced decode must reproduce the full-sequence logits."""
    cfg = get_arch(name).reduced()
    cfg = dataclasses.replace(cfg, param_dtype="float32", activation_dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    model = build_model(cfg)
    params = model.init(rng)
    S = 12
    batch = synthetic_batch(cfg, 2, S)
    logits_full, _ = model.forward(params, batch)
    cache = model.init_cache(2, S)
    step = jax.jit(model.decode_step)
    for t in range(S):
        logits_t, cache = step(params, cache, {"tokens": batch["tokens"][:, t : t + 1]}, jnp.array(t))
        assert jnp.abs(logits_t[:, 0] - logits_full[:, t]).max() < 3e-4


def test_cells_assignment():
    """long_500k applies only to sub-quadratic archs; all archs have >= 3 cells."""
    long_archs = {n for n in ARCH_NAMES if "long_500k" in cells(get_arch(n))}
    assert long_archs == {"rwkv6-3b", "zamba2-2.7b", "mixtral-8x7b"}
    for n in ARCH_NAMES:
        assert len(cells(get_arch(n))) >= 3


def test_param_counts_match_published_sizes():
    expect = {
        "nemotron-4-340b": (320e9, 360e9),
        "qwen1.5-110b": (100e9, 120e9),
        "command-r-35b": (28e9, 40e9),
        "granite-3-8b": (7e9, 9e9),
        "mixtral-8x7b": (44e9, 49e9),
        "phi3.5-moe-42b-a6.6b": (39e9, 44e9),
        "rwkv6-3b": (2.5e9, 5e9),
        "zamba2-2.7b": (2e9, 3.5e9),
        "musicgen-large": (1.5e9, 3.5e9),
        "internvl2-1b": (0.3e9, 1.2e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_arch(name).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.1f}B outside [{lo/1e9}, {hi/1e9}]"


def test_moe_capacity_drops_are_reported():
    cfg = get_arch("mixtral-8x7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = synthetic_batch(cfg, 2, 32)
    _, metrics = jax.jit(model.loss)(params, batch)
    assert "moe_aux_loss" in metrics and "moe_drop_rate" in metrics
    assert 0.0 <= float(metrics["moe_drop_rate"]) <= 1.0
    assert float(metrics["moe_aux_loss"]) >= 0.99  # ~1 for uniform routing


def test_mixtral_sliding_window_masks_distant_tokens():
    """A distant-past token must not influence logits beyond the window."""
    cfg = get_arch("mixtral-8x7b").reduced()
    cfg = dataclasses.replace(
        cfg,
        n_layers=1,
        param_dtype="float32",
        activation_dtype="float32",
        # capacity drops couple distant tokens through the router; remove
        # them so attention is the only cross-token channel
        moe=dataclasses.replace(cfg.moe, capacity_factor=16.0),
    )
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    S = 32  # window is 8 in the reduced config
    b1 = synthetic_batch(cfg, 1, S)
    tokens2 = b1["tokens"].at[0, 0].set((b1["tokens"][0, 0] + 1) % cfg.vocab_size)
    l1, _ = model.forward(params, b1)
    l2, _ = model.forward(params, {"tokens": tokens2})
    # last position is > window away from position 0: logits must match
    assert jnp.abs(l1[0, -1] - l2[0, -1]).max() < 1e-5


@pytest.mark.parametrize("name", ["granite-3-8b", "musicgen-large", "internvl2-1b", "rwkv6-3b"])
def test_chunked_loss_matches_full_loss(name):
    """The chunked-CE perf path must be numerically identical to full CE."""
    cfg = dataclasses.replace(
        get_arch(name).reduced(), param_dtype="float32", activation_dtype="float32"
    )
    m1 = build_model(cfg)
    m2 = dataclasses.replace(m1, loss_chunk=8)
    params = m1.init(jax.random.key(0))
    batch = synthetic_batch(cfg, 2, 20)  # 19 positions: 2 chunks + remainder 3
    l1, _ = m1.loss(params, batch)
    l2, _ = m2.loss(params, batch)
    assert abs(float(l1 - l2)) < 1e-5
    # gradients agree too
    g1 = jax.grad(lambda p: m1.loss(p, batch)[0])(params)
    g2 = jax.grad(lambda p: m2.loss(p, batch)[0])(params)
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), g1, g2)
    assert max(jax.tree.leaves(diffs)) < 1e-5
