"""Hypothesis with a random-sampling fallback.

The property-based tests use a small subset of the hypothesis API.  When
hypothesis is installed (the ``[test]`` extra in pyproject.toml) it is used
directly — shrinking, the example database and the full strategy language
all work.  When it is not, this module provides a deterministic
random-sampling stand-in covering exactly the strategies the suite uses
(``integers``, ``sampled_from``, ``lists``, ``permutations``, ``data``,
``.map``), so the properties still execute with N random examples instead of
silently skipping entire test modules.

Usage in tests:

    from _hypothesis_compat import given, settings, st
"""

from __future__ import annotations

import random

try:  # pragma: no cover - exercised implicitly by which env runs the suite
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng: random.Random):
            return self._sample(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._sample(rng)))

    class _DataStrategy(_Strategy):
        def __init__(self):
            super().__init__(lambda rng: _DataObject(rng))

    class _DataObject:
        """Stand-in for hypothesis's interactive ``data`` fixture."""

        def __init__(self, rng: random.Random):
            self._rng = rng

        def draw(self, strategy: _Strategy):
            return strategy.sample(self._rng)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda rng: rng.choice(items))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def sample(rng):
                size = rng.randint(min_size, max_size)
                return [elements.sample(rng) for _ in range(size)]

            return _Strategy(sample)

        @staticmethod
        def permutations(seq):
            items = list(seq)

            def sample(rng):
                out = list(items)
                rng.shuffle(out)
                return out

            return _Strategy(sample)

        @staticmethod
        def data():
            return _DataStrategy()

        @staticmethod
        def tuples(*strategies):
            return _Strategy(lambda rng: tuple(s.sample(rng) for s in strategies))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

    st = _St()

    _DEFAULT_MAX_EXAMPLES = 20

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kwargs):
        """Records max_examples on the wrapped (given-decorated) test."""

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        """Run the test body over deterministic random samples.

        The RNG is seeded per test function name, so failures reproduce.
        """

        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                rng = random.Random(f"fallback:{fn.__module__}.{fn.__qualname__}")
                for i in range(n):
                    drawn_args = tuple(s.sample(rng) for s in arg_strategies)
                    drawn_kw = {k: s.sample(rng) for k, s in kw_strategies.items()}
                    try:
                        fn(*drawn_args, **drawn_kw)
                    except Exception as e:  # re-raise with the failing example
                        raise AssertionError(
                            f"fallback property run failed on example {i}: "
                            f"args={drawn_args} kwargs={drawn_kw}"
                        ) from e

            # No functools.wraps: pytest must see a zero-argument signature,
            # not the strategy parameters (it would resolve them as fixtures).
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            wrapper._max_examples = _DEFAULT_MAX_EXAMPLES
            return wrapper

        return deco
