"""Equivalence and property tests for the repro.network subsystem.

Pins the three-way agreement at the heart of the refactor:

    vectorized engine  ==  per-hop reference walker  ==  closed forms

on tori up to 4D including length-2 (double-link) dimensions, plus the
traffic-pattern library, the unified fabric conventions, and the
deprecation shims.
"""

import itertools

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from reference_dor import ReferenceLinkLoads

from repro.network import (
    LinkLoads,
    Torus,
    TorusFabric,
    all_to_all_max_load,
    pairing_speedup,
    route_dor,
    simulate_pattern,
    uniform_offset_max_load,
)
from repro.network import patterns
from repro.network.collectives import AxisEmbedding, ring_all_gather_time
from repro.network.fabric import slice_fabric


def _route_reference(dims, src, dst, vol, split_ties=True):
    ref = ReferenceLinkLoads(tuple(dims), split_ties=split_ties)
    for s, d, v in zip(src, dst, vol):
        ref.add_path(tuple(int(x) for x in s), tuple(int(x) for x in d), float(v))
    return ref


# ---------------------------------------------------------------------------
# Engine == per-hop walker.
# ---------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(
    dims=st.lists(st.sampled_from([1, 2, 3, 4, 5, 6]), min_size=1, max_size=4).map(tuple),
    seed=st.integers(0, 10**6),
    split=st.booleans() if hasattr(st, "booleans") else st.sampled_from([True, False]),
)
def test_property_engine_matches_walker(dims, seed, split):
    """Full load-tensor equivalence on random traffic, random tori <= 4D."""
    if int(np.prod(dims)) == 1:
        return
    rng = np.random.default_rng(seed)
    verts = patterns.vertices(dims)
    m = int(rng.integers(1, 50))
    src = verts[rng.integers(0, len(verts), m)]
    dst = verts[rng.integers(0, len(verts), m)]
    vol = rng.random(m) + 0.1
    got = route_dor(dims, src, dst, vol, split_ties=split)
    ref = _route_reference(dims, src, dst, vol, split_ties=split)
    assert np.allclose(got, ref.load_array(), atol=1e-9)


@pytest.mark.parametrize(
    "dims", [(4, 2), (2, 2), (8, 4, 2), (5, 3), (3, 3, 2, 2), (6, 4, 2, 2)]
)
def test_linkloads_matches_walker_on_pairing(dims):
    """The paper's benchmark traffic: identical max loads and hop volumes,
    including length-2 double-link dimensions."""
    ll = LinkLoads(dims)
    ref = ReferenceLinkLoads(dims)
    for (u, v) in patterns.pairing_pairs(dims):
        ll.add_path(u, v, 1.0)
        ll.add_path(v, u, 1.0)
        ref.add_path(u, v, 1.0)
        ref.add_path(v, u, 1.0)
    assert ll.max_load() == pytest.approx(ref.max_load())
    assert ll.total_hop_volume() == pytest.approx(ref.total_hop_volume())
    assert np.allclose(ll.load_array(), ref.load_array())


def test_incremental_add_path_equals_batch():
    dims = (4, 3, 2)
    verts = patterns.vertices(dims)
    rng = np.random.default_rng(7)
    src = verts[rng.integers(0, len(verts), 20)]
    dst = verts[rng.integers(0, len(verts), 20)]
    vol = rng.random(20)
    a = LinkLoads(dims)
    for s, d, v in zip(src, dst, vol):
        a.add_path(tuple(s), tuple(d), float(v))
    b = LinkLoads(dims)
    b.add_batch(src, dst, vol)
    assert np.allclose(a.load_array(), b.load_array())


# ---------------------------------------------------------------------------
# Engine == closed forms.
# ---------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(
    dims=st.lists(st.sampled_from([1, 2, 3, 4, 5, 6]), min_size=1, max_size=4).map(tuple),
    seed=st.integers(0, 10**6),
)
def test_property_uniform_offset_closed_form(dims, seed):
    """Translation-invariant patterns: engine max == O(D) closed form,
    on tori up to 4D including length-2 double-link dims."""
    if int(np.prod(dims)) == 1:
        return
    rng = np.random.default_rng(seed)
    offset = tuple(int(rng.integers(0, a)) for a in dims)
    s, d, v = patterns.uniform_shift(dims, offset)
    ll = LinkLoads(dims)
    ll.add_batch(s, d, v)
    assert ll.max_load() == pytest.approx(uniform_offset_max_load(dims, offset))


def test_uniform_offset_single_link_convention():
    """The closed form honours double_link_on_2=False (TPU) consistently
    with the engine's max_link_load normalisation."""
    dims = (4, 2)
    off = (0, 1)
    s, d, v = patterns.uniform_shift(dims, off)
    ll = LinkLoads(dims, double_link_on_2=False)
    ll.add_batch(s, d, v)
    expect = uniform_offset_max_load(dims, off, double_link_on_2=False)
    assert ll.max_load() == pytest.approx(expect)
    # BG/Q halves it via the parallel link
    assert uniform_offset_max_load(dims, off) == pytest.approx(expect / 2)


@pytest.mark.parametrize("dims", [(3,), (5,), (3, 3), (5, 3), (3, 2, 2), (5, 3, 2)])
@pytest.mark.parametrize("split", [True, False])
def test_all_to_all_closed_form_exact_on_odd_tori(dims, split):
    """The direction-asymmetry satellite: + and - hop volumes are counted
    explicitly and the closed form matches the exact simulator on small odd
    tori (where the historical code merely assumed symmetry)."""
    s, d, v = patterns.all_to_all(dims)
    ll = LinkLoads(dims, split_ties=split)
    ll.add_batch(s, d, v)
    assert ll.max_load() == pytest.approx(all_to_all_max_load(dims, split_ties=split))


def test_all_to_all_unsplit_directions_differ():
    """With ties unsplit, the forward direction carries the whole antipodal
    volume — the two directions genuinely differ and the closed form tracks
    the loaded one."""
    dims = (4, 4)
    assert all_to_all_max_load(dims, split_ties=False) > all_to_all_max_load(
        dims, split_ties=True
    )


def test_all_to_all_max_load_positive_and_scales():
    small = all_to_all_max_load((4, 4))
    big = all_to_all_max_load((8, 8))
    assert small > 0 and big > small


# ---------------------------------------------------------------------------
# Pattern library.
# ---------------------------------------------------------------------------
def test_bisection_pairing_equals_furthest_shift():
    dims = (6, 4, 2)
    s, d, v = patterns.bisection_pairing(dims)
    ll = simulate_pattern(dims, zip(map(tuple, s), map(tuple, d), v))
    assert ll.max_load() == pytest.approx(
        uniform_offset_max_load(dims, patterns.furthest_offset(dims))
    )


def test_halo_exchange_unit_load():
    """±1 shifts load every link with exactly the per-message volume."""
    dims = (4, 4, 4)
    s, d, v = patterns.nearest_neighbor_halo(dims, vol=3.0)
    ll = LinkLoads(dims)
    ll.add_batch(s, d, v)
    arr = ll.load_array()
    assert np.allclose(arr, 3.0)


def test_ring_shift_loads_only_one_dimension():
    dims = (4, 4)
    s, d, v = patterns.ring_shift(dims, axis=1, steps=1)
    arr = route_dor(dims, s, d, v)
    assert arr[0].max() == 0.0
    assert arr[1, 0].min() == 1.0  # + direction uniformly loaded
    assert arr[1, 1].max() == 0.0


def test_random_permutation_is_permutation():
    dims = (4, 3, 2)
    s, d, v = patterns.random_permutation(dims, seed=3)
    n = int(np.prod(dims))
    assert len(v) == n
    assert len({tuple(x) for x in d}) == n  # destinations all distinct


def test_transpose_pattern():
    dims = (4, 4)
    s, d, v = patterns.transpose(dims)
    assert all(tuple(b) == (a[1], a[0]) for a, b in zip(s, d))
    ll = LinkLoads(dims)
    ll.add_batch(s, d, v)
    assert ll.max_load() > 0


def test_ring_all_gather_traffic_matches_cost_model():
    """Routing the all-gather's neighbour traffic reproduces the closed-form
    collective time: max link load / bw == ring_all_gather_time."""
    dims = (8, 4)
    bytes_out = 1e9
    s, d, v = patterns.ring_all_gather(dims, axis=0, bytes_out=bytes_out)
    ll = LinkLoads(dims, double_link_on_2=False)
    ll.add_batch(s, d, v)
    link_bw = 50e9
    emb = AxisEmbedding(size=8, wrapped=True)
    assert ll.max_load() / link_bw == pytest.approx(
        ring_all_gather_time(bytes_out, emb, link_bw)
    )


# ---------------------------------------------------------------------------
# Unified fabric conventions.
# ---------------------------------------------------------------------------
def test_bgq_fabric_equals_torus_bisection():
    for dims in [(16, 4, 4, 4, 2), (8, 4, 2), (4, 4), (7, 2, 2, 2), (5, 1)]:
        assert TorusFabric.bgq(dims).bisection_links() == Torus(dims).bisection_links()


def test_tpu_vs_bgq_length2_convention():
    # On a 2x2, halving a length-2 dimension cuts 2 chip pairs: BG/Q counts
    # two parallel links per pair (4), TPU a single link per pair (2).
    assert TorusFabric.bgq((2, 2)).bisection_links() == 4
    assert TorusFabric.tpu((2, 2), (True, True)).bisection_links() == 2
    # With a longer even dimension present the two conventions agree: the
    # minimum cut halves the 4-ring either way.
    assert TorusFabric.bgq((4, 2)).bisection_links() == 4
    assert TorusFabric.tpu((4, 2), (True, True)).bisection_links() == 4


def test_slice_fabric_wrap_and_double_link_inherited():
    pod = TorusFabric.bgq((4, 4))
    s = slice_fabric(pod, (4, 2))
    assert s.double_link_on_2 and s.wrap == (True, False)


def test_odd_longest_dim_exact_bisection():
    """(7,2,2) fully wrapped: no cuboid halves the 7-ring, so the exact
    search over floor(n/2) cuboids applies (the plane formula would claim 8)."""
    assert Torus((7, 2, 2)).bisection_links() == 28
    assert TorusFabric.bgq((7, 2, 2)).bisection_links() == 28


def test_pairing_speedup_consistency_via_network_namespace():
    assert pairing_speedup((16, 4, 4, 4, 2), (8, 8, 4, 4, 2)) == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# Deprecation shims.
# ---------------------------------------------------------------------------
def _import_shims():
    """Import the five repro.core shim modules with their one-shot import
    warning suppressed: tier-1 escalates the shim DeprecationWarning to an
    error (pyproject filterwarnings), so only these dedicated shim tests
    may import them — and must do so under an ignore filter."""
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core import allocation, collectives, contention, isoperimetry, torus
    return torus, contention, collectives, allocation, isoperimetry


def test_core_shims_reexport_network_objects():
    c_torus, c_contention, c_collectives, c_allocation, c_isoperimetry = _import_shims()
    import repro.network.allocation as n_allocation
    import repro.network.isoperimetry as n_isoperimetry
    import repro.network.routing as n_routing

    assert c_torus.Torus is Torus
    assert c_contention.LinkLoads is n_routing.LinkLoads
    assert c_collectives.TorusFabric is TorusFabric
    assert c_allocation.MachineState is n_allocation.MachineState
    assert c_isoperimetry.optimal_cuboid is n_isoperimetry.optimal_cuboid
    assert c_isoperimetry.CuboidOptimum is n_isoperimetry.CuboidOptimum
    # the historical constructor signature still works
    fab = c_collectives.TorusFabric((16, 16), (True, True))
    assert fab.bisection_links() == 32


def test_core_shims_emit_one_shot_deprecation_warning():
    """Each re-export shim warns at import pointing at repro.network.

    Module caching makes the warning one-shot per process, so the test
    re-executes each (already imported) shim module with importlib.reload
    inside pytest.warns; a fresh import of the sibling package module must
    stay silent."""
    import importlib
    import subprocess
    import sys

    for shim in _import_shims():
        with pytest.warns(DeprecationWarning, match="repro.network"):
            importlib.reload(shim)
    # The replacement subsystem — and the repro.core package itself, which
    # re-exports the isoperimetry names from their new home rather than via
    # the shim — import clean even with DeprecationWarning promoted to an
    # error (fresh interpreter: no module cache to mask it).
    import os
    from pathlib import Path

    env = dict(os.environ)
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    for module in ("repro.network", "repro.core"):
        proc = subprocess.run(
            [sys.executable, "-W", "error::DeprecationWarning", "-c", f"import {module}"],
            capture_output=True,
            text=True,
            env=env,
        )
        assert proc.returncode == 0, (module, proc.stderr)
