"""Roofline machinery tests: HLO collective parsing, the XLA while-loop
counting pitfall, and validation of the analytic FLOP model against
cost_analysis on unrolled configs (where XLA's count is exact)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import analytic, roofline
from repro.configs import get_arch
from repro.configs.base import SHAPES, ShapeConfig
from repro.models import build_model, synthetic_batch


def test_xla_counts_while_bodies_once():
    """The documented pitfall that motivates the analytic model."""

    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None

        h, _ = jax.lax.scan(body, x, None, length=10)
        return h

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    flops = roofline.xla_cost_analysis(jax.jit(f).lower(x, w).compile())["flops"]
    one_iter = 2 * 64 * 128 * 128
    assert flops == pytest.approx(one_iter, rel=0.01)  # NOT 10x


def test_collective_stats_parser():
    hlo = """
  %ag = bf16[16,512,128]{2,1,0} all-gather(%x), replica_groups={{0,1}}
  %ar.1 = f32[256]{0} all-reduce(%y), to_apply=%add
  %rs = (f32[8,8]{1,0}, f32[8,8]{1,0}) reduce-scatter(%a, %b), dimensions={0}
  %cp = u8[4]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = bf16[32,32]{1,0} all-to-all(%w), dimensions={1}
  %not_a_collective = f32[2]{0} add(%p, %q)
"""
    stats = roofline.collective_stats(hlo)
    assert stats["all-gather"]["count"] == 1
    assert stats["all-gather"]["bytes"] == 16 * 512 * 128 * 2
    assert stats["all-reduce"]["bytes"] == 256 * 4
    assert stats["reduce-scatter"]["bytes"] == 2 * 8 * 8 * 4
    assert stats["collective-permute"]["bytes"] == 4
    assert stats["all-to-all"]["bytes"] == 32 * 32 * 2
    total = roofline.total_collective_bytes(stats)
    assert total == sum(v["bytes"] for v in stats.values())


def _measured_flops(model, arch, B, S, kind="prefill"):
    batch = jax.eval_shape(lambda: synthetic_batch(arch, B, S))
    if kind == "prefill":
        fn = lambda p, b: model.forward(p, b)[0]
        params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        return (
            roofline.xla_cost_analysis(jax.jit(fn).lower(params, batch).compile())["flops"]
        )
    raise ValueError(kind)


@pytest.mark.parametrize(
    "name,S",
    [("granite-3-8b", 128), ("qwen1.5-110b", 128), ("musicgen-large", 128)],
)
def test_analytic_forward_flops_vs_xla(name, S):
    """Unrolled 2-layer forward: analytic model within 15% of XLA's count."""
    arch = dataclasses.replace(get_arch(name), n_layers=2)
    model = build_model(arch, unroll=True)
    B = 1
    measured = _measured_flops(model, arch, B, S)
    expected = sum(analytic.forward_flops(arch, B, S, compiled=True).values())
    assert measured == pytest.approx(expected, rel=0.15), (measured, expected)


def test_analytic_rwkv_flops_vs_xla():
    """RWKV6 with a single chunk (S = chunk) so the chunk scan is exact."""
    arch = dataclasses.replace(get_arch("rwkv6-3b"), n_layers=2)
    model = build_model(arch, unroll=True)
    B, S = 1, 32  # == RWKV_CHUNK: one chunk -> exact XLA count
    measured = _measured_flops(model, arch, B, S)
    expected = sum(analytic.forward_flops(arch, B, S, compiled=True).values())
    assert measured == pytest.approx(expected, rel=0.2), (measured, expected)


def test_roofline_report_terms_and_bottleneck():
    r = roofline.RooflineReport(
        arch="x", shape="train_4k", mesh="single", chips=256,
        hlo_flops=197e12,  # exactly 1 second of compute per chip
        hlo_bytes=819e9 / 2,  # 0.5 s memory
        collective_bytes=50e9 * 2,  # 2 s collective
        collectives={}, model_flops=0.5 * 197e12 * 256,
    )
    assert r.compute_term == pytest.approx(1.0)
    assert r.memory_term == pytest.approx(0.5)
    assert r.collective_term == pytest.approx(2.0)
    assert r.bottleneck == "collective"
    assert r.useful_flops_ratio == pytest.approx(0.5)
    # roofline fraction: useful flops over bound-time * peak
    assert r.roofline_fraction == pytest.approx(0.5 / 2.0)


def test_small_mesh_dryrun_lowering():
    """The dry-run path (shardings + lower + compile + analyses) on the
    session's single CPU device with a trivial (1,1) mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.sharding import ShardingRules
    from repro.optim import AdamWConfig, adamw
    from repro.train import make_train_step

    arch = get_arch("granite-3-8b").reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    model = build_model(arch)
    rules = ShardingRules(arch, mesh)
    params_shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    specs = rules.params_specs(params_shapes)
    shd = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
    batch = jax.eval_shape(lambda: synthetic_batch(arch, 2, 16))
    batch_shd = {k: NamedSharding(mesh, s) for k, s in rules.batch_specs(batch).items()}
    opt_shapes = jax.eval_shape(adamw.init, params_shapes)
    opt_shd = adamw.AdamWState(
        step=NamedSharding(mesh, P()),
        m=jax.tree.map(lambda s: NamedSharding(mesh, s), specs),
        v=jax.tree.map(lambda s: NamedSharding(mesh, s), specs),
    )
    step = make_train_step(model, AdamWConfig(), microbatches=2)
    lowered = jax.jit(
        step, in_shardings=(shd, opt_shd, batch_shd), out_shardings=(shd, opt_shd, None)
    ).lower(params_shapes, opt_shapes, batch)
    compiled = lowered.compile()
    assert roofline.xla_cost_analysis(compiled)["flops"] > 0
    stats = roofline.collective_stats(compiled.as_text())
    assert isinstance(stats, dict)


def test_sharding_rules_divisibility_degradation():
    """14 heads on a 16-way model axis must replicate, not crash."""
    from repro.distributed.sharding import ShardingRules

    arch = get_arch("internvl2-1b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class Fake:  # pretend the axis is 16 wide without needing 16 devices
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    rules = ShardingRules(arch, Fake())
    spec = rules.param_spec(
        tuple(), (arch.d_model, arch.n_heads, arch.resolved_head_dim)
    )
    # no path info -> fallback; now check wq directly
    import jax.tree_util as jtu

    wq_path = (jtu.DictKey("layers"), jtu.DictKey("attn"), jtu.DictKey("wq"))
    spec = rules.param_spec(wq_path, (24, arch.d_model, 14, 64))
    assert spec[2] is None  # 14 heads not divisible by 16 -> replicated
    assert spec[1] is not None  # d=896 divisible by 256 -> FSDP sharded


def test_analytic_cell_cost_decode_memory_bound():
    arch = get_arch("granite-3-8b")
    shape = SHAPES["decode_32k"]
    n = 8.2e9
    cost = analytic.cell_cost(arch, shape, n, cache_bytes=2.6e12)
    # decode must be memory-dominated: bytes/flops ratio >> peak ratio
    assert cost.bytes_hbm > cost.flops_compiled / 100
