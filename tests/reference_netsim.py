"""Per-flow pure-Python reference for the flow-level simulator.

Implements the same fluid model as ``repro.network.netsim.simulate_flows``
— max-min fair link sharing by progressive filling, time advancing to the
next subflow completion — but with per-flow/per-link Python loops and
dictionaries instead of vectorized incidence sweeps.  The property tests
pin the vectorized simulator's completion times to this oracle, and
``benchmarks/bench_netsim.py`` anchors the >= 10x speedup claim.

Deliberately independent: no NumPy in the inner loops, a separate
progressive-filling implementation, so a shared bug is unlikely.
"""

from __future__ import annotations

import math
from typing import Dict, List, Sequence, Tuple


def reference_max_min_rates(
    flows: Sequence[int],
    links_of_flow: Dict[int, List[int]],
    capacity: Dict[int, float],
) -> Dict[int, float]:
    """Max-min fair rates for the given flows by progressive filling."""
    rate = {f: 0.0 for f in flows}
    growing = {f for f in flows if links_of_flow[f]}
    cap_rem = dict(capacity)
    while growing:
        counts: Dict[int, int] = {}
        for f in growing:
            for l in links_of_flow[f]:
                counts[l] = counts.get(l, 0) + 1
        inc = math.inf
        for l, c in counts.items():
            inc = min(inc, cap_rem[l] / c)
        for f in growing:
            rate[f] += inc
        saturated = set()
        for l, c in counts.items():
            cap_rem[l] -= inc * c
            if cap_rem[l] / capacity[l] <= 1e-9 or cap_rem[l] <= inc * c * 1e-9:
                saturated.add(l)
        frozen = {f for f in growing if any(l in saturated for l in links_of_flow[f])}
        if not frozen:  # float safety: freeze the tightest link's flows
            tight = min(counts, key=lambda l: cap_rem[l])
            frozen = {f for f in growing if tight in links_of_flow[f]}
        growing -= frozen
    return rate


def reference_simulate(
    vols: Sequence[float],
    links_of_flow: Dict[int, List[int]],
    capacity: Dict[int, float],
) -> Tuple[List[float], float]:
    """Drain the flows; returns (per-flow completion times, makespan)."""
    n = len(vols)
    remaining = [float(v) for v in vols]
    completion = [0.0] * n
    active = [
        f for f in range(n) if remaining[f] > 1e-12 and links_of_flow.get(f)
    ]
    t = 0.0
    while active:
        rates = reference_max_min_rates(active, links_of_flow, capacity)
        dt = min(remaining[f] / rates[f] for f in active)
        t += dt
        still = []
        for f in active:
            remaining[f] -= rates[f] * dt
            if remaining[f] <= max(abs(vols[f]), 1.0) * 1e-9:
                completion[f] = t
            else:
                still.append(f)
        if len(still) == len(active):  # float safety: finish the tightest
            tightest = min(active, key=lambda f: remaining[f] / rates[f])
            completion[tightest] = t
            still.remove(tightest)
        active = still
    makespan = max(completion) if completion else 0.0
    return completion, makespan


def paths_to_reference(
    paths, link_bw: float = 1.0, double_link_on_2: bool = True
) -> Tuple[Dict[int, List[int]], Dict[int, float]]:
    """Convert a ``repro.network.netsim.FlowPaths`` into the per-flow link
    lists and per-link capacity dict the reference consumes."""
    from repro.network.netsim import link_capacities

    cap_full = link_capacities(paths.dims, link_bw, double_link_on_2).ravel()
    links_of_flow: Dict[int, List[int]] = {f: [] for f in range(paths.n_flows)}
    for link, flow in zip(paths.link_ids.tolist(), paths.flow_ids.tolist()):
        links_of_flow[flow].append(link)
    capacity = {
        int(l): float(cap_full[l]) for l in set(paths.link_ids.tolist())
    }
    return links_of_flow, capacity
