"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.ops import flash_attention
from repro.kernels.attention.ref import attention_reference
from repro.kernels.rwkv6.ops import rwkv6_mix
from repro.kernels.rwkv6.ref import rwkv6_reference
from repro.kernels.ssd.ops import ssd_scan
from repro.kernels.ssd.ref import ssd_reference

TR = lambda t: t.transpose(0, 2, 1, 3)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "B,S,H,K,hd,blk",
    [
        (1, 128, 4, 4, 32, 64),  # MHA
        (2, 256, 4, 2, 64, 64),  # GQA 2:1
        (1, 256, 8, 2, 16, 128),  # GQA 4:1, small head dim
        (1, 64, 2, 1, 128, 32),  # MQA
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, K, hd, blk, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    out = flash_attention(q, k, v, causal=True, blk_q=blk, blk_k=blk, interpret=True)
    ref = TR(attention_reference(TR(q), TR(k), TR(v), causal=True))
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("window", [32, 96, 1024])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.key(1), 3)
    B, S, H, K, hd = 1, 256, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    out = flash_attention(q, k, v, causal=True, window=window, blk_q=64, blk_k=64, interpret=True)
    ref = TR(attention_reference(TR(q), TR(k), TR(v), causal=True, window=window))
    np.testing.assert_allclose(out, ref, atol=2e-4, rtol=2e-4)


def test_flash_attention_asymmetric_blocks():
    ks = jax.random.split(jax.random.key(2), 3)
    B, S, H, K, hd = 1, 256, 2, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    out = flash_attention(q, k, v, causal=True, blk_q=128, blk_k=32, interpret=True)
    ref = TR(attention_reference(TR(q), TR(k), TR(v), causal=True))
    np.testing.assert_allclose(out, ref, atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# RWKV6 chunked scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "B,S,H,P,chunk",
    [(1, 64, 2, 16, 16), (2, 128, 3, 16, 32), (1, 96, 1, 32, 32), (1, 32, 2, 8, 32)],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_kernel_sweep(B, S, H, P, chunk, dtype):
    ks = jax.random.split(jax.random.key(3), 5)
    r = jax.random.normal(ks[0], (B, S, H, P), dtype)
    k = jax.random.normal(ks[1], (B, S, H, P), dtype)
    v = jax.random.normal(ks[2], (B, S, H, P), dtype)
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, P)) - 1.0)
    u = jax.random.normal(ks[4], (H, P)) * 0.1
    out, st = rwkv6_mix(r, k, v, logw, u, chunk=chunk, interpret=True)
    oref, sref = rwkv6_reference(TR(r), TR(k), TR(v), TR(logw), u)
    np.testing.assert_allclose(TR(out), oref, **_tol(dtype))
    np.testing.assert_allclose(st, sref, **_tol(dtype))


def test_rwkv6_strong_decay_no_overflow():
    """Strong decay (the regime where the factorized form overflows)."""
    ks = jax.random.split(jax.random.key(4), 5)
    B, S, H, P = 1, 128, 2, 16
    r = jax.random.normal(ks[0], (B, S, H, P))
    k = jax.random.normal(ks[1], (B, S, H, P))
    v = jax.random.normal(ks[2], (B, S, H, P))
    logw = jnp.full((B, S, H, P), -5.0)  # very strong decay
    u = jax.random.normal(ks[4], (H, P)) * 0.1
    out, st = rwkv6_mix(r, k, v, logw, u, chunk=32, interpret=True)
    assert bool(jnp.isfinite(out).all()) and bool(jnp.isfinite(st).all())
    oref, _ = rwkv6_reference(TR(r), TR(k), TR(v), TR(logw), u)
    np.testing.assert_allclose(TR(out), oref, atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# SSD chunked scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "B,S,H,P,G,N,chunk",
    [
        (1, 64, 2, 16, 1, 8, 16),
        (2, 128, 4, 16, 2, 8, 32),  # grouped B/C
        (1, 128, 4, 32, 1, 16, 64),
        (1, 256, 8, 16, 4, 8, 32),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_sweep(B, S, H, P, G, N, chunk, dtype):
    ks = jax.random.split(jax.random.key(5), 5)
    xh = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    bm = jax.random.normal(ks[3], (B, S, G, N), dtype)
    cm = jax.random.normal(ks[4], (B, S, G, N), dtype)
    y, st = ssd_scan(xh, dt, A, bm, cm, chunk=chunk, interpret=True)
    xw = (xh.astype(jnp.float32) * dt[..., None]).transpose(0, 2, 1, 3)
    la = (dt * A).transpose(0, 2, 1)[..., None]
    yref, sref = ssd_reference(
        xw, la, bm.astype(jnp.float32).transpose(0, 2, 1, 3),
        cm.astype(jnp.float32).transpose(0, 2, 1, 3),
    )
    np.testing.assert_allclose(TR(y), yref, **_tol(dtype))
    np.testing.assert_allclose(st, sref, **_tol(dtype))


def test_ssd_kernel_matches_model_chunked_path():
    """Kernel agrees with the jnp chunked implementation used by the model."""
    from repro.models.mamba2 import ssd_chunked

    ks = jax.random.split(jax.random.key(6), 5)
    B, S, H, P, G, N = 1, 64, 2, 16, 1, 8
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    bm = jax.random.normal(ks[3], (B, S, G, N))
    cm = jax.random.normal(ks[4], (B, S, G, N))
    y_k, _ = ssd_scan(xh, dt, A, bm, cm, chunk=16, interpret=True)
    y_m, _ = ssd_chunked(xh, dt, A, bm, cm, jnp.zeros((B, H, N, P)), 16)
    np.testing.assert_allclose(y_k, y_m, atol=2e-4, rtol=2e-4)
