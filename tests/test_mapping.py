"""Property and behaviour tests for the rank-mapping engine.

Pins the agreement at the heart of the mapping subsystem:

    vectorized scorer  ==  per-hop reference oracle

on random placements up to 4D (congestion, dilation, and the full load
tensor), plus the strategy catalogue's guarantees: all-to-all is
mapping-invariant, the gray-snake order is a Hamiltonian path, a concrete
pattern+placement pair where a non-identity mapping strictly lowers the
max link load while row-major does not, greedy refinement never worsens
the seed, the mesh-axis measurement bridge, and the ``plan_slice`` /
``simulate_queue`` wiring (including the edge cases mapping exposes:
1-cell geometries, unit-dim orientation dedupe, and occupied grids where
the scored placement and the mapping disagree on orientation).
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from reference_mapping import reference_score_mapping

from repro.launch.mesh import plan_slice
from repro.network import (
    AxisEmbedding,
    JobRequest,
    IsoperimetricPolicy,
    MachineState,
    MAPPING_PATTERNS,
    assign_axes,
    map_ranks,
    mapping_loads,
    mesh_axis_hops,
    pattern_traffic,
    simulate_queue,
)
from repro.network.fabric import TorusFabric
from repro.network.geometry import volume
from repro.network.mapping import (
    axis_order_coords,
    axis_permutation_orders,
    greedy_refine,
    identity_mapping,
    placement_cell_coords,
    score_mapping,
    snake_mapping,
    toroidal_hops,
)


def _random_placement(rng):
    """Random machine (<= 4D, <= ~120 cells), fitting cuboid, offset."""
    nd = int(rng.integers(1, 5))
    while True:
        dims = tuple(int(rng.integers(1, 7)) for _ in range(nd))
        if volume(dims) <= 120:
            break
    oriented = tuple(int(rng.integers(1, a + 1)) for a in dims)
    offset = tuple(int(rng.integers(0, a)) for a in dims)
    return dims, oriented, offset


def _random_mapping(rng, dims, oriented, offset):
    """A random bijection of ranks onto the placement's cells."""
    cells = placement_cell_coords(dims, oriented, offset)
    return cells[rng.permutation(cells.shape[0])]


# ---------------------------------------------------------------------------
# Vectorized scorer == per-hop oracle.
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_property_scorer_matches_reference(seed):
    """Congestion, dilation and the full load tensor agree between the
    vectorized scorer and the per-hop oracle on random placements up to 4D,
    random mappings, every pattern, both tie policies."""
    rng = np.random.default_rng(seed)
    dims, oriented, offset = _random_placement(rng)
    coords = _random_mapping(rng, dims, oriented, offset)
    pattern = MAPPING_PATTERNS[int(rng.integers(0, len(MAPPING_PATTERNS)))]
    if pattern == "all-to-all" and volume(oriented) > 40:
        pattern = "halo"  # keep the per-hop oracle tractable
    split = bool(rng.integers(0, 2))
    dbl = bool(rng.integers(0, 2))
    traffic = pattern_traffic(oriented, pattern)
    got = score_mapping(dims, coords, traffic, split_ties=split, double_link_on_2=dbl)
    want_c, want_d, want_loads = reference_score_mapping(
        dims, coords, traffic, split_ties=split, double_link_on_2=dbl
    )
    assert got.congestion == pytest.approx(want_c, abs=1e-9)
    assert got.dilation == pytest.approx(want_d, abs=1e-9)
    np.testing.assert_allclose(
        mapping_loads(dims, coords, traffic, split_ties=split), want_loads, atol=1e-9
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_property_all_to_all_is_mapping_invariant(seed):
    """Every bijection routes identical all-to-all loads: the pattern sends
    equal volume between every ordered cell pair regardless of labels."""
    rng = np.random.default_rng(seed)
    while True:
        dims, oriented, offset = _random_placement(rng)
        if volume(oriented) <= 30:
            break
    traffic = pattern_traffic(oriented, "all-to-all")
    base = score_mapping(dims, identity_mapping(dims, oriented, offset), traffic)
    other = score_mapping(dims, _random_mapping(rng, dims, oriented, offset), traffic)
    assert other.congestion == pytest.approx(base.congestion, abs=1e-9)
    assert other.dilation == pytest.approx(base.dilation, abs=1e-9)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_property_map_ranks_never_worse_than_identity(seed):
    """The chosen mapping's (congestion, dilation) key never exceeds the
    row-major baseline's — identity is always in the candidate set."""
    rng = np.random.default_rng(seed)
    dims, oriented, offset = _random_placement(rng)
    pattern = ("halo", "pairing", "ring")[int(rng.integers(0, 3))]
    m = map_ranks(dims, oriented, offset, pattern=pattern)
    assert m.score.key() <= m.identity_score.key()
    assert m.recovered_congestion >= -1e-9
    # the attached load tensor is the chosen mapping's routed traffic
    np.testing.assert_allclose(
        m.loads, mapping_loads(dims, m.coords, pattern_traffic(oriented, pattern)),
        atol=1e-12,
    )
    # coords is a bijection onto the placement's cells
    cells = placement_cell_coords(dims, oriented, offset)
    assert sorted(map(tuple, m.coords.tolist())) == sorted(map(tuple, cells.tolist()))


# ---------------------------------------------------------------------------
# Strategy catalogue guarantees.
# ---------------------------------------------------------------------------
def test_snake_is_hamiltonian_path():
    """Consecutive gray-snake ranks always occupy adjacent cells."""
    for dims, oriented in [((5, 5, 5), (3, 4, 2)), ((7, 2, 2, 2), (4, 2, 2, 2)),
                           ((16, 16), (2, 8)), ((4,), (3,))]:
        coords = snake_mapping(dims, oriented, (0,) * len(dims))
        hops = toroidal_hops(dims, coords[:-1], coords[1:])
        assert (hops == 1).all(), (dims, oriented)


def test_snake_beats_identity_on_ring_dilation():
    """Ring-collective traffic over a 4x4 block: row-major pays the row-jump
    hops, the snake's neighbours are all adjacent."""
    m = map_ranks((16, 16), (4, 4), (0, 0), pattern="ring")
    assert m.strategy == "gray-snake"
    assert m.score.dilation < m.identity_score.dilation


def test_non_identity_mapping_strictly_lowers_max_link_load():
    """The acceptance example: a logical (8, 2) halo grid across a (2, 8)
    slice.  Row-major folds the logical 8-ring onto the 2-extent axis and
    stacks its traffic on the row links (max load 4); the axis-permutation
    embedding aligns the 8-ring with the 8-extent axis (max load 2)."""
    m = map_ranks((4, 8), (2, 8), (0, 0), logical_dims=(8, 2), pattern="halo")
    assert m.identity_score.congestion == pytest.approx(4.0)
    assert m.score.congestion == pytest.approx(2.0)
    assert m.strategy != "identity"
    # and the oracle agrees with both numbers
    traffic = pattern_traffic((8, 2), "halo")
    ref_id = reference_score_mapping(
        (4, 8), identity_mapping((4, 8), (2, 8), (0, 0)), traffic
    )
    ref_best = reference_score_mapping((4, 8), m.coords, traffic)
    assert ref_id[0] == pytest.approx(4.0)
    assert ref_best[0] == pytest.approx(2.0)


def test_axis_permutation_orders_dedupe_unit_dims():
    """Unit dims neither reorder nor reverse: (1, 4, 1) has exactly the
    2 enumerations of its single non-trivial axis, (2, 3) the full 8,
    (1, 1, 1) collapses to the single trivial enumeration."""
    assert len(list(axis_permutation_orders((1, 4, 1)))) == 2
    assert len(list(axis_permutation_orders((2, 3)))) == 8
    assert len(list(axis_permutation_orders((1, 1, 1)))) == 1
    # distinct keys produce distinct coordinate arrays on a big-enough torus
    dims = (8, 8, 8)
    seen = set()
    for perm, rev in axis_permutation_orders((1, 4, 1)):
        c = axis_order_coords(dims, (1, 4, 1), (0, 0, 0), perm, rev)
        seen.add(tuple(map(tuple, c.tolist())))
    assert len(seen) == 2


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_property_greedy_refine_never_worsens(seed):
    """Greedy refinement returns a mapping no worse than its seed, and its
    reported score matches a from-scratch re-score."""
    rng = np.random.default_rng(seed)
    while True:
        dims, oriented, offset = _random_placement(rng)
        if 2 <= volume(oriented) <= 36:
            break
    traffic = pattern_traffic(oriented, "pairing")
    seed_coords = _random_mapping(rng, dims, oriented, offset)
    seed_score = score_mapping(dims, seed_coords, traffic)
    refined, score, improved = greedy_refine(dims, seed_coords, traffic)
    assert score.key() <= seed_score.key()
    assert improved == (score.key() < seed_score.key()) or not improved
    re = score_mapping(dims, refined, traffic)
    assert re.congestion == pytest.approx(score.congestion, abs=1e-9)
    assert re.dilation == pytest.approx(score.dilation, abs=1e-9)


def test_greedy_repairs_a_scrambled_mapping():
    """Seeded with a deliberately scrambled mapping (worst of a fixed shuffle
    set), greedy swaps strictly reduce pairing congestion."""
    dims, oriented, offset = (8, 8), (4, 4), (0, 0)
    traffic = pattern_traffic(oriented, "pairing")
    rng = np.random.default_rng(3)
    worst = max(
        (_random_mapping(rng, dims, oriented, offset) for _ in range(8)),
        key=lambda c: score_mapping(dims, c, traffic).key(),
    )
    before = score_mapping(dims, worst, traffic)
    _, after, improved = greedy_refine(dims, worst, traffic)
    assert improved
    assert after.key() < before.key()


def test_map_ranks_validates_inputs():
    with pytest.raises(ValueError):
        map_ranks((4, 4), (5, 1), (0, 0))  # does not fit
    with pytest.raises(ValueError):
        map_ranks((4, 4), (2, 2), (0, 0), logical_dims=(3, 1))  # volume mismatch
    with pytest.raises(ValueError):
        pattern_traffic((2, 2), "no-such-pattern")


def test_explicit_traffic_overrides_pattern():
    """Explicit rank traffic is scored as-is and recorded as such."""
    rsrc = np.array([0, 1], dtype=np.int64)
    rdst = np.array([1, 0], dtype=np.int64)
    vol = np.ones(2)
    m = map_ranks((4, 4), (2, 1), (0, 0), traffic=(rsrc, rdst, vol))
    assert m.pattern == "explicit"
    assert m.score.congestion == pytest.approx(1.0)
    assert m.score.dilation == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# Mesh-axis measurement bridge.
# ---------------------------------------------------------------------------
def test_mesh_axis_hops_measures_stride_and_wrap():
    """A (4, 4) mesh identity-mapped onto a full (4, 4) torus: both axes
    step 1 hop and close their rings in 1 hop (machine wrap); on a (4, 4)
    corner of a (16, 16) pod the closing step costs 3 hops."""
    coords = identity_mapping((4, 4), (4, 4), (0, 0))
    assert mesh_axis_hops((4, 4), coords, (4, 4), 0) == (1, 1)
    assert mesh_axis_hops((4, 4), coords, (4, 4), 1) == (1, 1)
    coords = identity_mapping((16, 16), (4, 4), (0, 0))
    assert mesh_axis_hops((16, 16), coords, (4, 4), 0) == (1, 3)
    assert mesh_axis_hops((16, 16), coords, (4, 4), 1) == (1, 3)
    assert mesh_axis_hops((16, 16), coords, (16, 1), 1) == (0, 0)  # size-1 axis


def test_mesh_axis_hops_honours_missing_wrap_links():
    """A mesh axis spanning a full machine dimension closes its ring in 1
    hop only when the wrap link physically exists; on an unwrapped fabric
    the closing step pays the whole chain and the embedding is a chain."""
    coords = identity_mapping((4, 8), (4, 1), (0, 0))
    assert mesh_axis_hops((4, 8), coords, (4,), 0) == (1, 1)
    assert mesh_axis_hops((4, 8), coords, (4,), 0, wrap=(False, True)) == (1, 3)
    m_wrapped = map_ranks((4, 8), (4, 1), (0, 0), logical_dims=(4,), pattern="ring")
    m_chain = map_ranks(
        (4, 8), (4, 1), (0, 0), logical_dims=(4,), pattern="ring",
        wrap=(False, False),
    )
    assert m_chain.wrap == (False, False)
    emb_w = AxisEmbedding.from_mapping(m_wrapped, (4,), 0)
    emb_c = AxisEmbedding.from_mapping(m_chain, (4,), 0)
    assert emb_w.wrapped is True
    assert emb_c.wrapped is False  # no wrap link -> collective prices a chain
    assert emb_c.stride == emb_w.stride == 1


def test_simulate_queue_mapping_respects_link_convention():
    """The per-job mapping congestion follows the machine's length-2 link
    convention: BG/Q double links halve the metric, TPU single links do
    not — exactly a factor 2 on a full-machine ring job."""
    jobs = [JobRequest(0, 4, duration=1.0)]
    kw = dict(backfill=False, measure_contention=True, mapping_pattern="ring")
    bgq = simulate_queue((2, 2), jobs, IsoperimetricPolicy(), **kw)
    tpu = simulate_queue(
        (2, 2), jobs, IsoperimetricPolicy(), double_link_on_2=False, **kw
    )
    c_bgq = bgq.jobs[0].mapping.identity_score.congestion
    c_tpu = tpu.jobs[0].mapping.identity_score.congestion
    assert c_tpu == pytest.approx(2 * c_bgq)


def test_axis_embedding_from_mapping_and_assign_axes():
    """assign_axes(mapping=...) replaces the assumed stride-1/wrapped
    embedding with the measured one."""
    fabric = TorusFabric.tpu((4, 4), wrap=(False, False))
    m = map_ranks((16, 16), (4, 4), (0, 0), logical_dims=(4, 4), pattern="halo")
    asn = assign_axes(fabric, {"data": 4, "model": 4}, mapping=m)
    for emb in asn.embeddings:
        assert emb.stride >= 1
        assert isinstance(emb.wrapped, bool)
    # a snaked 16-rank ring on a (4, 4) block: interior steps are 1 hop, so
    # the measured embedding is stride 1; the ring-closing step costs 3.
    snake = snake_mapping((16, 16), (4, 4), (0, 0))
    emb = AxisEmbedding.from_mapping(
        type("M", (), {"dims": (16, 16), "coords": snake})(), (16,), 0
    )
    assert emb == AxisEmbedding(size=16, stride=1, wrapped=False)


# ---------------------------------------------------------------------------
# plan_slice edge cases the mapping exposes.
# ---------------------------------------------------------------------------
def test_plan_slice_one_cell_geometry():
    """chips=1: a single-rank mesh plans, maps (trivially) and commits."""
    plan = plan_slice(1)
    assert plan.slice_geometry == (1, 1)
    assert plan.mapping is None  # geometry-only
    state = MachineState((16, 16))
    plan = plan_slice(1, state=state, job_id=0)
    assert plan.placement is not None
    assert plan.mapping is not None
    assert plan.mapping.num_ranks == 1
    assert plan.mapping.score.congestion == 0.0
    assert plan.mapping_congestion == 0.0
    assert state.placements[0].geometry == (1, 1)


def test_plan_slice_unit_dim_geometry_dedupes_orientation_search():
    """A Nx1 slice has exactly 2 distinct enumerations in the mapping
    search (forward/reverse of the single non-trivial axis), not D!·2^D —
    and the planner handles it end to end."""
    state = MachineState((16, 16))
    plan = plan_slice(2, state=state, job_id=0)  # best 2-chip slice: (2, 1)
    assert plan.placement is not None
    oriented = plan.placement.oriented
    assert sorted(oriented, reverse=True) == [2, 1]
    assert len(list(axis_permutation_orders(oriented))) == 2
    assert plan.mapping is not None
    assert plan.mapping.num_ranks == 2
    assert plan.mapping.score.key() <= plan.mapping.identity_score.key()


def test_plan_slice_occupied_grid_mapping_follows_scored_orientation():
    """On a grid where occupancy forces the scored placement into one
    orientation, the mapping embeds the logical mesh onto *that* oriented
    cuboid — the two may disagree on axis order, and the mapping search
    must recover the aligned embedding rather than inherit row-major."""
    state = MachineState((16, 16))
    # Occupy all but a 2-row band: an 8-chip slice must land as (2, 4)/(2, 8)
    # style wide-short, never tall.
    state.grid[2:, :] = True
    plan = plan_slice(8, state=state, job_id=9)
    oriented = plan.placement.oriented
    assert oriented[0] <= 2  # forced short along dim 0
    assert plan.mapping is not None
    # mesh shape is (data, model) = (4, 2): logical 4-axis must run along
    # the physical 4+ extent, which identity row-major already does here —
    # the point is the engine proves it: no candidate is better.
    assert plan.mapping.score.key() <= plan.mapping.identity_score.key()
    assert volume(plan.mapping.logical_dims) == 8
    # the committed placement and the mapping agree on the cell set
    cells = placement_cell_coords((16, 16), oriented, plan.placement.offset)
    assert sorted(map(tuple, plan.mapping.coords.tolist())) == sorted(
        map(tuple, cells.tolist())
    )


def test_plan_slice_occupied_grid_orientation_disagreement_recovers():
    """Force a genuinely transposed landing: free space only admits the
    (2, 8) orientation while the logical mesh wants (8, 2).  The engine
    must beat row-major by re-aligning the logical 8-axis."""
    state = MachineState((4, 8))
    state.grid[2:, :] = True  # only rows 0-1 free -> oriented (2, 8)
    plan = plan_slice(16, pod=TorusFabric.tpu((4, 8)), state=state, job_id=1)
    assert plan.placement.oriented == (2, 8)
    m = plan.mapping
    assert m is not None
    # mesh shape (data, model) = (8, 2) vs oriented (2, 8): transposed.
    assert m.logical_dims == (8, 2)
    assert m.score.congestion < m.identity_score.congestion
    assert m.strategy != "identity"


# ---------------------------------------------------------------------------
# simulate_queue wiring.
# ---------------------------------------------------------------------------
def test_simulate_queue_mapping_pattern_requires_measurement():
    with pytest.raises(ValueError):
        simulate_queue(
            (2, 2), [JobRequest(0, 2)], IsoperimetricPolicy(), mapping_pattern="ring"
        )


def test_simulate_queue_applies_per_job_mapping():
    """With mapping_pattern set, every scheduled job carries a mapping no
    worse than row-major and the measured contention uses mapped loads."""
    rng = np.random.default_rng(1)
    jobs = [
        JobRequest(i, int(rng.choice([4, 6, 8, 12])), True,
                   float(rng.lognormal(0.0, 0.5) + 0.3), float(i * 0.3))
        for i in range(24)
    ]
    res = simulate_queue(
        (7, 2, 2, 2), jobs, IsoperimetricPolicy(), backfill=True,
        measure_contention=True, mapping_pattern="ring",
    )
    assert res.jobs and not res.rejected
    for j in res.jobs:
        assert j.mapping is not None
        assert j.mapping.pattern == "ring"
        assert j.mapping.score.key() <= j.mapping.identity_score.key()
        assert j.placement.predicted_contention >= 0.0
    # without a pattern, no mappings are attached (historical behaviour)
    res0 = simulate_queue(
        (7, 2, 2, 2), jobs, IsoperimetricPolicy(), backfill=True,
        measure_contention=True,
    )
    assert all(j.mapping is None for j in res0.jobs)
