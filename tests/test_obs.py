"""Telemetry subsystem (repro.obs): tracer semantics, metrics registry,
non-perturbation of the instrumented engines, Chrome-trace validity, and
the contention-attribution acceptance numbers.

The load-bearing properties pinned here:

* telemetry is **off by default** and its disabled path is a no-op —
  enabling tracing must not change a single scheduler event, netsim
  makespan, or planner table (observe, never perturb);
* the exported Chrome trace is valid JSON whose spans nest properly
  (per thread, intervals are disjoint or contained — never partially
  overlapping) and contains the scheduler / placement / netsim spans;
* ``scheduler_metrics`` is derived purely from the event log + schedule,
  so a replayed service reproduces the metrics snapshot exactly and the
  per-job gauges equal the ``SimulationResult`` fields bit-for-bit;
* contention attribution reproduces the paper's avoidable-contention
  pair on a 16^3 torus: a (8,8,8) placement has no avoidable contention
  while (16,16,2) carries 2x avoidable load (Theorem 3.1-certified).
"""

from __future__ import annotations

import json
import threading

import numpy as np
import pytest

import repro.obs as obs
from repro.obs import TRACER
from repro.obs.contention import attribute_contention, attribute_traffic, render_dashboard
from repro.obs.metrics import MetricsRegistry, scheduler_metrics
from repro.network import IsoperimetricPolicy, MachineState
from repro.network.allocation import ContentionScoredPolicy, JobRequest, simulate_queue
from repro.network.netsim import build_paths, simulate_flows
from repro.network.placement import placement_loads
from repro.network.scheduler import generate_scenario, replay_events, run_scenario


@pytest.fixture(autouse=True)
def _clean_telemetry():
    obs.clear_telemetry()
    yield
    obs.clear_telemetry()


def _log_key(service):
    return [
        (e.seq, e.time, e.kind, e.job_id, e.cells, e.placement,
         e.priority, e.reason, e.source)
        for e in service.log
    ]


# ---------------------------------------------------------------------------
# Tracer semantics.
# ---------------------------------------------------------------------------
def test_tracer_disabled_by_default():
    assert not TRACER.enabled
    with TRACER.span("x", a=1) as sp:
        sp.annotate(b=2)
        sp.incr("c")
    assert TRACER.events() == []


def test_span_nesting_and_args():
    TRACER.enable(clear=True)
    with TRACER.span("outer", k=1):
        with TRACER.span("inner") as sp:
            sp.annotate(found=True)
    TRACER.disable()
    events = TRACER.events()
    assert [e["name"] for e in sorted(events, key=lambda e: e["ts"])] == [
        "outer", "inner",
    ]
    outer = next(e for e in events if e["name"] == "outer")
    inner = next(e for e in events if e["name"] == "inner")
    assert outer["args"] == {"k": 1}
    assert inner["args"] == {"found": True}
    # containment: inner lies inside outer
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1


def test_timer_measures_even_disabled():
    assert not TRACER.enabled
    with obs.timer("t") as tm:
        sum(range(1000))
    assert tm.elapsed > 0.0
    assert TRACER.events() == []
    TRACER.enable(clear=True)
    with obs.timer("t") as tm:
        pass
    TRACER.disable()
    assert tm.elapsed >= 0.0
    assert [e["name"] for e in TRACER.events()] == ["t"]


def test_tracer_thread_safety():
    TRACER.enable(clear=True)
    barrier = threading.Barrier(4)  # overlap lifetimes so tids are distinct

    def worker(i):
        barrier.wait()
        for j in range(50):
            with TRACER.span("w", i=i, j=j):
                pass

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    TRACER.disable()
    assert len(TRACER.events()) == 200
    tids = {e["tid"] for e in TRACER.events()}
    assert len(tids) == 4


def _assert_proper_nesting(trace_events):
    """Per tid, spans must be disjoint or nested — no partial overlap."""
    by_tid = {}
    for e in trace_events:
        by_tid.setdefault(e["tid"], []).append(e)
    for tid, evs in by_tid.items():
        evs.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack = []
        for e in evs:
            start, end = e["ts"], e["ts"] + e["dur"]
            while stack and start >= stack[-1]:
                stack.pop()
            if stack:
                assert end <= stack[-1] + 1, (
                    f"span {e['name']} partially overlaps its parent (tid {tid})"
                )
            stack.append(end)


# ---------------------------------------------------------------------------
# Non-perturbation + Chrome trace from a Mira-style replay.
# ---------------------------------------------------------------------------
def test_scheduler_log_identical_with_tracing():
    scenario = generate_scenario((8, 8, 8), 30, seed=5, failure_rate=0.002)
    s_off = run_scenario(scenario, ContentionScoredPolicy())
    TRACER.enable(clear=True)
    s_on = run_scenario(scenario, ContentionScoredPolicy())
    TRACER.disable()
    assert _log_key(s_off) == _log_key(s_on)
    names = {e["name"] for e in TRACER.events()}
    assert {"scheduler.step", "scheduler.place", "placement.search"} <= names


def test_netsim_makespan_identical_with_tracing():
    rng = np.random.default_rng(0)
    src = rng.integers(0, 4, (40, 3))
    dst = rng.integers(0, 4, (40, 3))
    vol = rng.random(40) + 0.1
    paths = build_paths((4, 4, 4), (src, dst, vol))
    r_off = simulate_flows(paths)
    TRACER.enable(clear=True)
    r_on = simulate_flows(paths)
    TRACER.disable()
    assert r_on.makespan == r_off.makespan
    assert np.array_equal(r_on.completion, r_off.completion)
    assert any(e["name"] == "netsim.drain" for e in TRACER.events())


def test_planner_table_identical_with_tracing():
    from repro.launch.planner import format_table, plan_model

    p_off = plan_model("granite-3-8b", 64, shape="decode_32k", simulate_top_k=0)
    TRACER.enable(clear=True)
    p_on = plan_model("granite-3-8b", 64, shape="decode_32k", simulate_top_k=0)
    TRACER.disable()
    assert format_table(p_off) == format_table(p_on)
    assert any(e["name"] == "planner.price" for e in TRACER.events())


def test_chrome_trace_round_trip_and_nesting():
    jobs = [
        JobRequest(i, 64, duration=2.0, arrival=0.5 * i) for i in range(12)
    ]
    TRACER.enable(clear=True)
    simulate_queue((16, 16, 16), jobs, ContentionScoredPolicy(),
                   contention="simulated")
    TRACER.disable()
    doc = json.loads(json.dumps(obs.export_chrome_trace()))
    events = doc["traceEvents"]
    assert events and all(e["ph"] == "X" for e in events)
    names = {e["name"] for e in events}
    assert {"scheduler.step", "scheduler.place", "placement.search",
            "netsim.drain"} <= names
    _assert_proper_nesting(events)
    # the scheduler.place spans nest inside scheduler.step wall-clock
    steps = [e for e in events if e["name"] == "scheduler.step"]
    places = [e for e in events if e["name"] == "scheduler.place"]
    for p in places:
        assert any(
            s["ts"] <= p["ts"] and p["ts"] + p["dur"] <= s["ts"] + s["dur"] + 1
            for s in steps
        )


def test_export_chrome_trace_to_file(tmp_path):
    TRACER.enable(clear=True)
    with TRACER.span("a"):
        pass
    TRACER.disable()
    path = tmp_path / "trace.json"
    obs.export_chrome_trace(path)
    doc = json.loads(path.read_text())
    assert [e["name"] for e in doc["traceEvents"]] == ["a"]


# ---------------------------------------------------------------------------
# Metrics registry + scheduler metrics.
# ---------------------------------------------------------------------------
def test_registry_basics():
    reg = MetricsRegistry()
    reg.counter("hits", route="a").incr()
    reg.counter("hits", route="a").incr(2)
    reg.counter("hits", route="b").incr()
    reg.gauge("temp").set(3.5)
    h = reg.histogram("lat")
    for v in (0.002, 0.02, 5.0):
        h.observe(v)
    snap = json.loads(json.dumps(reg.snapshot()))
    assert snap["counters"]["hits{route=a}"] == 3
    assert snap["counters"]["hits{route=b}"] == 1
    assert snap["gauges"]["temp"] == 3.5
    assert snap["histograms"]["lat"]["count"] == 3
    assert snap["histograms"]["lat"]["sum"] == pytest.approx(5.022)


def test_scheduler_metrics_match_result_exactly():
    scenario = generate_scenario((8, 8, 8), 40, seed=9, failure_rate=0.003)
    service = run_scenario(scenario, IsoperimetricPolicy(), backfill=True)
    reg = scheduler_metrics(service)
    snap = reg.snapshot()
    # per-job gauges equal the SimulationResult fields bit-for-bit
    # (last segment wins for re-queued jobs, as in the snapshot)
    last = {}
    for job in service.result().jobs:
        last[job.placement.job_id] = job
    assert last, "scenario scheduled no jobs"
    for job_id, job in last.items():
        key = f"scheduler.job.bisection_efficiency{{job={job_id}}}"
        assert snap["gauges"][key] == job.bisection_efficiency
    events = sum(
        v for k, v in snap["counters"].items()
        if k.startswith("scheduler.events{")
    )
    assert events == len(service.log)
    assert snap["histograms"]["scheduler.wait_time"]["count"] > 0
    assert 0.0 < snap["gauges"]["scheduler.utilization"] <= 1.0


def test_replay_reproduces_metrics_snapshot():
    scenario = generate_scenario((8, 8, 8), 30, seed=11, failure_rate=0.002)
    service = run_scenario(scenario, IsoperimetricPolicy())
    replayed = replay_events((8, 8, 8), IsoperimetricPolicy(), service.log)
    snap_a = scheduler_metrics(service).snapshot()
    snap_b = scheduler_metrics(replayed).snapshot()
    assert snap_a == snap_b


# ---------------------------------------------------------------------------
# Contention attribution: the paper's avoidable-contention pair.
# ---------------------------------------------------------------------------
def test_avoidable_contention_acceptance_pair():
    machine = MachineState((16, 16, 16))
    assert machine.allocate(0, (8, 8, 8)) is not None
    assert machine.allocate(1, (16, 16, 2)) is not None
    report = attribute_contention(machine)
    by_id = {j.job_id: j for j in report.jobs}
    good, bad = by_id[0], by_id[1]
    # (8,8,8) is the isoperimetric optimum: nothing avoidable, certified
    assert good.avoidable_ratio == pytest.approx(1.0)
    assert good.avoidable_excess == pytest.approx(0.0)
    assert good.certified
    # (16,16,2) carries 2x the optimal pairing load (paper Theorem 3.1)
    assert bad.avoidable_ratio == pytest.approx(2.0)
    assert bad.avoidable_excess == pytest.approx(1.0)
    assert bad.certified
    assert bad.optimal_geometry is not None
    assert sorted(bad.optimal_geometry) == [8, 8, 8]


def test_attribution_sums_to_machine_field():
    machine = MachineState((16, 16, 16))
    machine.allocate(0, (8, 8, 8))
    machine.allocate(1, (16, 16, 2))
    report = attribute_contention(machine)
    per_job = sum(j.self_load + j.cross_load for j in report.jobs)
    assert per_job == pytest.approx(float(machine.traffic_loads().sum()))
    assert report.total_load == pytest.approx(float(machine.traffic_loads().sum()))
    assert report.hotspots
    # hotspot shares attribute load to the spilling job
    top = report.hotspots[0]
    assert top.load == pytest.approx(report.max_link_load)


def test_attribute_traffic_validates_shapes():
    with pytest.raises(ValueError):
        attribute_traffic((4, 4), {0: np.zeros((2, 2, 4, 4, 9))})


def test_dashboard_renders():
    machine = MachineState((16, 16, 16))
    machine.allocate(0, (8, 8, 8))
    machine.allocate(1, (16, 16, 2))
    report = attribute_contention(machine)
    text = render_dashboard(report)
    assert "job" in text and "avoid" in text
    assert "(16, 16, 2)" in text or "16x16x2" in text
    doc = json.loads(report.to_json())
    assert doc["dims"] == [16, 16, 16]
    assert len(doc["jobs"]) == 2
