"""Tests: data pipeline, optimizer, compression, checkpointing, fault
tolerance, sharding rules, and an end-to-end loss-goes-down training run."""

import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_arch
from repro.data import DataConfig, DataPipeline, make_batch
from repro.models import build_model, synthetic_batch
from repro.optim import AdamWConfig, adamw
from repro.optim import compression as comp
from repro.train import make_train_step
from repro.checkpoint import CheckpointManager
from repro.runtime import (
    ElasticPlan,
    HeartbeatMonitor,
    StragglerTracker,
    TrainingSupervisor,
    plan_mesh,
)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------
def test_data_determinism_and_resume():
    arch = get_arch("granite-3-8b").reduced()
    cfg = DataConfig(seed=7, global_batch=4, seq_len=32)
    b1 = make_batch(arch, cfg, step=5)
    b2 = make_batch(arch, cfg, step=5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(arch, cfg, step=6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_host_sharding_partitions_batch():
    arch = get_arch("granite-3-8b").reduced()
    full = make_batch(arch, DataConfig(seed=1, global_batch=4, seq_len=16), 0)
    shard0 = make_batch(
        arch, DataConfig(seed=1, global_batch=4, seq_len=16, num_hosts=2, host_index=0), 0
    )
    assert shard0["tokens"].shape[0] == 2
    assert full["tokens"].shape[0] == 4


def test_pipeline_prefetch_and_resume():
    arch = get_arch("granite-3-8b").reduced()
    cfg = DataConfig(seed=3, global_batch=2, seq_len=16)
    p = DataPipeline(arch, cfg, start_step=0)
    s0, b0 = next(p)
    s1, b1 = next(p)
    p.close()
    assert (s0, s1) == (0, 1)
    # resume at step 1 reproduces batch 1
    p2 = DataPipeline(arch, cfg, start_step=1)
    s1b, b1b = next(p2)
    p2.close()
    assert s1b == 1
    np.testing.assert_array_equal(b1["tokens"], b1b["tokens"])


def test_data_tokens_in_vocab_range():
    arch = get_arch("mixtral-8x7b").reduced()
    b = make_batch(arch, DataConfig(global_batch=2, seq_len=64), 0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < arch.vocab_size


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------
def test_adamw_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
    assert float(adamw.schedule(cfg, jnp.array(0))) == 0.0
    assert float(adamw.schedule(cfg, jnp.array(10))) == pytest.approx(1.0)
    assert float(adamw.schedule(cfg, jnp.array(110))) == pytest.approx(0.1)


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adamw_skips_decay_on_norms():
    cfg = AdamWConfig(lr=0.0, weight_decay=1.0, warmup_steps=0, clip_norm=None)
    params = {"scale": jnp.ones((4,)), "w": jnp.ones((4, 4))}
    state = adamw.init(params)
    grads = jax.tree.map(jnp.zeros_like, params)
    new_params, _, _ = adamw.update(cfg, grads, state, params)
    np.testing.assert_array_equal(new_params["scale"], params["scale"])


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, clip_norm=1.0)
    params = {"w": jnp.zeros((3,))}
    state = adamw.init(params)
    grads = {"w": jnp.array([1e6, -1e6, 1e6])}
    _, _, metrics = adamw.update(cfg, grads, state, params)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


# ---------------------------------------------------------------------------
# Compression
# ---------------------------------------------------------------------------
def test_int8_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.key(0), (256,))
    q, s = comp.quantize_int8(x)
    err = jnp.abs(comp.dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates_residual():
    grads = {"w": jnp.array([0.004, 1.0])}
    state = comp.init_state(grads)
    sent, state, _ = comp.compress_with_feedback(grads, state, "int8")
    # small component mostly lost to quantization this step...
    assert abs(float(state.residual["w"][0])) > 0
    # ...but over repeated steps the cumulative transmitted mass converges
    total = jnp.zeros(2)
    state = comp.init_state(grads)
    for _ in range(50):
        sent, state, _ = comp.compress_with_feedback(grads, state, "int8")
        total = total + sent["w"]
    np.testing.assert_allclose(total / 50, grads["w"], atol=2e-3)


def test_topk_keeps_largest():
    x = {"w": jnp.array([0.1, -5.0, 0.2, 3.0])}
    state = comp.init_state(x)
    sent, _, _ = comp.compress_with_feedback(x, state, "topk", topk_frac=0.5)
    assert float(sent["w"][1]) == -5.0 and float(sent["w"][3]) == 3.0
    assert float(sent["w"][0]) == 0.0


def test_wire_bytes_int8_is_quarter():
    g = {"w": jnp.zeros((1024,), jnp.float32)}
    state = comp.init_state(g)
    _, _, wire = comp.compress_with_feedback(g, state, "int8")
    assert comp.wire_bytes(wire) < 1024 * 4 / 3.5


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------
def _tree():
    return {
        "a": jnp.arange(13, dtype=jnp.float32).reshape(13, 1),
        "b": {"c": jnp.ones((4, 4), jnp.bfloat16), "d": jnp.array(3, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = _tree()
    mgr.save(7, tree)
    step, restored = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = _tree()
    futs = [mgr.save_async(s, tree) for s in (1, 2, 3)]
    for f in futs:
        f.result()
    assert mgr.all_steps() == [2, 3]


def test_checkpoint_ignores_uncommitted(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    tree = _tree()
    mgr.save(1, tree)
    # simulate crash mid-save: directory without COMMIT
    (tmp_path / "step_000000002").mkdir()
    assert mgr.latest_step() == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"a": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        mgr.restore({"a": jnp.zeros((5,))})


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------
def test_heartbeat_detects_timeout():
    t = [0.0]
    mon = HeartbeatMonitor(["w0", "w1"], timeout=5.0, clock=lambda: t[0])
    t[0] = 3.0
    mon.beat("w0")
    t[0] = 7.0
    assert mon.check() == ["w1"]
    assert mon.alive == ["w0"]


def test_straggler_tracker_advice():
    s = StragglerTracker(alpha=1.0, factor=1.5, evict_factor=3.0)
    for w, dt in [("a", 1.0), ("b", 1.0), ("c", 2.0), ("d", 4.0)]:
        s.record(w, dt)
    adv = s.stragglers()
    assert adv == {"c": "rebalance", "d": "evict"}
    shares = s.rebalanced_shares(["a", "c"])
    assert shares["a"] > shares["c"]
    assert abs(sum(shares.values()) - 1.0) < 1e-9


def test_elastic_plan_shrinks_data_axis():
    p = plan_mesh(512, model_parallel=16, pod_size=256)
    assert p == ElasticPlan(pods=2, data=16, model=16)
    p2 = plan_mesh(496, model_parallel=16, pod_size=256)  # lost 16 chips
    assert p2.chips <= 496 and p2.model == 16
    with pytest.raises(ValueError):
        plan_mesh(8, model_parallel=16)


def test_supervisor_restores_after_failure(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5)
    log = []

    def step_fn(state, i):
        log.append(i)
        return state + 1

    def save_fn(step, state):
        mgr.save(step, {"s": jnp.array(state)})

    def restore_fn():
        step, tree = mgr.restore({"s": jnp.array(0)})
        return step, int(tree["s"])

    t = [0.0]
    mon = HeartbeatMonitor(["w0", "w1"], timeout=1e9, clock=lambda: t[0])
    sup = TrainingSupervisor(
        step_fn, save_fn, restore_fn, mon, checkpoint_every=5,
        failure_schedule={12: ["w1"]},
    )
    state, report = sup.run(0, 0, 20)
    assert report.failures_handled == 1 and report.restores == 1
    assert report.final_step == 20
    # restored at the step-10 checkpoint (state 10), then ran to 20:
    assert state == 20
    # steps 10 and 11 were executed twice (before and after the failure)
    assert report.steps_run == 20 + 2


def test_supervisor_failed_worker_can_rejoin(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5)

    def step_fn(state, i):
        return state + 1

    def save_fn(step, state):
        mgr.save(step, {"s": jnp.array(state)})

    def restore_fn():
        step, tree = mgr.restore({"s": jnp.array(0)})
        return step, int(tree["s"])

    t = [0.0]
    mon = HeartbeatMonitor(["w0", "w1"], timeout=1e9, clock=lambda: t[0])
    sup = TrainingSupervisor(
        step_fn, save_fn, restore_fn, mon, checkpoint_every=4,
        failure_schedule={6: ["w1"]},
    )
    state, report = sup.run(0, 0, 10)
    assert "w1" in mon.failed
    mon.rejoin("w1")
    assert mon.alive == ["w0", "w1"]
    assert state == 10


# ---------------------------------------------------------------------------
# End-to-end: loss decreases on the reduced config
# ---------------------------------------------------------------------------
def test_training_loss_decreases():
    arch = get_arch("granite-3-8b").reduced()
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60, weight_decay=0.0)
    opt_state = adamw.init(params)
    step = jax.jit(make_train_step(model, opt_cfg, microbatches=2))
    data_cfg = DataConfig(seed=0, global_batch=4, seq_len=32)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in make_batch(arch, data_cfg, i).items()}
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]


def test_microbatched_step_matches_full_batch():
    arch = get_arch("granite-3-8b").reduced()
    import dataclasses

    arch = dataclasses.replace(arch, param_dtype="float32", activation_dtype="float32")
    model = build_model(arch)
    params = model.init(jax.random.key(1))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, clip_norm=None, weight_decay=0.0)
    batch = synthetic_batch(arch, 4, 16)
    s1 = make_train_step(model, opt_cfg, microbatches=1)
    s2 = make_train_step(model, opt_cfg, microbatches=2)
    p1, _, m1 = jax.jit(s1)(params, adamw.init(params), batch)
    p2, _, m2 = jax.jit(s2)(params, adamw.init(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree.leaves(diffs)) < 1e-4  # f32 accumulation-order noise
