"""HyperX (Hamming graph) behaviour: closed forms, routing, and the stack.

Pins the four levels of the HyperX story against brute force and each
other:

    hamming closed forms   ==  edge counting over explicitly enumerated
                               cells / subsets (cuts, Lindsey bisection)
    route_hyperx minimal   ==  per-hop Python reference oracle, link for
                               link, and DAL conserves minimal hop volume
    all-to-all max load    ==  closed form == netsim makespan (steady
                               pattern, so static == simulated exactly)
    the stack              ==  advisor certification, box-closure (zero
                               cross-box links), scheduler/planner/obs
                               integration goldens

The geometry preference flips against the torus: on HyperX, covering a
dimension removes it from the bottleneck, so *elongated* boxes win.
"""

import itertools

import numpy as np
import pytest

from repro.network import (
    HyperXFabric,
    IsoperimetricPolicy,
    JobRequest,
    MachineState,
    advise_partition,
    bisection_table,
    compare_fabric_routing,
    cut_table,
    hamming_bisection_links,
    hamming_cut_aligned,
    hamming_cut_of_set,
    hamming_subset_bound,
    hyperx_all_to_all_max_load,
    hyperx_max_link_load,
    optimal_cuboid,
    route_hyperx,
    simulate_fabric_traffic,
    simulate_queue,
)
from repro.network.backend import HAVE_JAX
from repro.network.geometry import volume
from repro.network.patterns import all_to_all, bisection_pairing, hotspot_line
from repro.obs.contention import attribute_contention


# ---------------------------------------------------------------------------
# Hamming closed forms vs brute force.
# ---------------------------------------------------------------------------
def _brute_cut(dims, cells):
    """Edges (unit multiplicity) leaving ``cells`` — direct enumeration."""
    inside = set(map(tuple, cells))
    cut = 0
    for c in inside:
        for k, a in enumerate(dims):
            for j in range(a):
                if j == c[k]:
                    continue
                nb = list(c)
                nb[k] = j
                if tuple(nb) not in inside:
                    cut += 1
    return cut


def _box_cells(dims, sides):
    return list(itertools.product(*(range(s) for s in sides)))


@pytest.mark.parametrize("dims", [(4, 4), (6, 3), (5, 2, 2)])
def test_aligned_cut_closed_form_matches_enumeration(dims):
    for sides in itertools.product(*(range(1, a + 1) for a in dims)):
        cells = _box_cells(dims, sides)
        want = _brute_cut(dims, cells)
        assert hamming_cut_aligned(dims, sides) == want
        got = hamming_cut_of_set(dims, np.array(cells))
        assert got == want


def test_lindsey_bound_sound_and_tight_by_brute_force():
    """On small uniform Hamming graphs the lex bound equals the true
    minimum over *every* n-subset (Lindsey's theorem), not just boxes."""
    dims = (4, 2)
    n_cells = volume(dims)
    cells = list(itertools.product(*(range(a) for a in dims)))
    for n in range(1, n_cells):
        best = min(
            _brute_cut(dims, subset)
            for subset in itertools.combinations(cells, n)
        )
        assert hamming_subset_bound(dims, n) == best


def test_bisection_links_exact_on_h8x2():
    """H(8,2) half-set: brute force over all C(16,8) subsets."""
    dims = (8, 2)
    cells = list(itertools.product(range(8), range(2)))
    best = min(
        _brute_cut(dims, subset) for subset in itertools.combinations(cells, 8)
    )
    assert best == hamming_bisection_links(dims) == 8


def test_trunked_cut_scales_by_multiplicity():
    base = hamming_cut_aligned((4, 4), (2, 2))
    assert hamming_cut_aligned((4, 4), (2, 2), mult=(3, 3)) == 3 * base


# ---------------------------------------------------------------------------
# Routing engine vs per-hop reference oracle.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dims", [(4, 4), (6, 3, 2), (8, 2)])
def test_route_hyperx_minimal_matches_oracle(dims):
    fab = HyperXFabric(dims)
    rng = np.random.default_rng(5)
    n = volume(dims)
    flat_s = rng.integers(0, n, size=60)
    flat_d = (flat_s + rng.integers(1, n, size=60)) % n
    src = np.stack(np.unravel_index(flat_s, dims), axis=1)
    dst = np.stack(np.unravel_index(flat_d, dims), axis=1)
    vol = rng.uniform(0.5, 2.0, size=60)
    from reference_hyperx import oracle_minimal_loads

    got = route_hyperx(fab, src, dst, vol, mode="minimal")
    np.testing.assert_allclose(got, oracle_minimal_loads(fab, src, dst, vol))


@pytest.mark.parametrize("mode", ["minimal", "dal"])
def test_route_hyperx_conserves_hop_volume(mode):
    """Every DAL order still corrects each differing dim exactly once, so
    total routed volume is vol x Hamming distance for both modes."""
    dims = (8, 4)
    fab = HyperXFabric(dims)
    rng = np.random.default_rng(9)
    n = volume(dims)
    flat_s = rng.integers(0, n, size=50)
    flat_d = (flat_s + rng.integers(1, n, size=50)) % n
    src = np.stack(np.unravel_index(flat_s, dims), axis=1)
    dst = np.stack(np.unravel_index(flat_d, dims), axis=1)
    vol = rng.uniform(0.5, 2.0, size=50)
    loads = route_hyperx(fab, src, dst, vol, mode=mode)
    dist = (src != dst).sum(axis=1)
    assert float(loads.sum()) == pytest.approx(float((vol * dist).sum()))
    assert np.all(loads >= 0.0)


def test_dal_equals_minimal_on_steady_pairing():
    """Hysteresis keeps the canonical order when per-order costs balance:
    on the translation-invariant pairing the DAL load field is
    bit-identical to minimal routing (routing recovers nothing)."""
    dims = (8, 4)
    fab = HyperXFabric(dims)
    src, dst, vol = bisection_pairing(dims)
    a = route_hyperx(fab, src, dst, vol, mode="minimal")
    b = route_hyperx(fab, src, dst, vol, mode="dal")
    np.testing.assert_array_equal(a, b)
    cmp = compare_fabric_routing(fab, (src, dst, vol))
    assert cmp.recovered_fraction == 0.0


@pytest.mark.parametrize("dims", [(8, 8), (16, 4), (8, 4)])
def test_dal_beats_minimal_on_hotspot(dims):
    fab = HyperXFabric(dims)
    cmp = compare_fabric_routing(fab, hotspot_line(dims))
    assert cmp.dor_makespan == pytest.approx(2.0)
    assert cmp.adaptive_makespan == pytest.approx(10.0 / 7.0)
    assert cmp.recovered_fraction == pytest.approx(2.0 / 7.0)


# ---------------------------------------------------------------------------
# All-to-all: engine == closed form == simulated makespan.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "dims", [(4, 4), (16, 1), (8, 2), (6, 3), (4, 2, 2)]
)
def test_all_to_all_closed_form_matches_engine_and_netsim(dims):
    fab = HyperXFabric(dims)
    src, dst, vol = all_to_all(dims)
    loads = route_hyperx(fab, src, dst, vol)
    want = hyperx_all_to_all_max_load(fab)
    assert hyperx_max_link_load(fab, loads) == pytest.approx(want)
    sim = simulate_fabric_traffic(fab, (src, dst, vol))
    assert sim.makespan == pytest.approx(want)  # steady: static == simulated


def test_all_to_all_trunking_divides_load():
    fab = HyperXFabric((4, 4), link_multiplicity=(2, 2))
    assert hyperx_all_to_all_max_load(fab) == pytest.approx(2.0)


def test_elongated_boxes_win_on_hyperx():
    """Same volume, opposite preference to the torus: the geometry
    covering a full dimension has the strictly smallest all-to-all load."""
    pod = HyperXFabric((16, 4))
    loads = {
        g: hyperx_all_to_all_max_load(pod.sub_fabric(g))
        for g in [(16, 1), (8, 2), (4, 4)]
    }
    assert loads[(16, 1)] == 1.0
    assert loads[(16, 1)] < loads[(4, 4)] < loads[(8, 2)]
    assert loads[(8, 2)] / loads[(16, 1)] == pytest.approx(8.0)


@pytest.mark.skipif(not HAVE_JAX, reason="jax backend unavailable")
def test_hyperx_netsim_xla_parity():
    dims = (8, 4)
    fab = HyperXFabric(dims)
    src, dst, vol = hotspot_line(dims)
    a = simulate_fabric_traffic(fab, (src, dst, vol), backend="numpy")
    b = simulate_fabric_traffic(fab, (src, dst, vol), backend="xla")
    assert a.makespan == pytest.approx(b.makespan)
    np.testing.assert_allclose(a.completion, b.completion)


# ---------------------------------------------------------------------------
# Isoperimetry: tables, optimum, advisor certification.
# ---------------------------------------------------------------------------
def test_cut_table_golden():
    assert list(cut_table(HyperXFabric((4, 4)), 4).items()) == [
        ((2, 2), 16),
        ((4, 1), 12),
    ]


def test_bisection_table_golden():
    assert bisection_table(HyperXFabric((16, 4)), 16).ranked() == [
        ((16, 1), 64),
        ((4, 4), 16),
        ((8, 2), 8),
    ]
    with pytest.raises(ValueError, match="unit_node_dims"):
        bisection_table(HyperXFabric((16, 4)), 16, unit_node_dims=(2, 2))


def test_optimal_cuboid_certified():
    opt = optimal_cuboid(HyperXFabric((16, 4)), 16)
    assert opt.geometry == (16, 1)
    assert opt.cut == 48
    assert opt.bound == 48
    assert opt.tight


def test_advise_partition_certifies_and_simulates():
    adv = advise_partition(
        HyperXFabric((16, 4)), 16, (8, 2), simulate=True
    )
    assert adv.optimal_geometry == (16, 1)
    assert (adv.current_bisection, adv.optimal_bisection) == (8, 64)
    assert adv.predicted_speedup == pytest.approx(8.0)
    assert adv.simulated_speedup == pytest.approx(8.0)  # steady pattern: exact
    assert adv.certified
    assert not adv.is_current_optimal


# ---------------------------------------------------------------------------
# Allocation / scheduler / obs / planner on a HyperX machine.
# ---------------------------------------------------------------------------
def test_box_closure_disjoint_placements_share_no_links():
    """Minimal (and DAL) paths between cells of an aligned box never leave
    the box, so two disjoint jobs' load fields touch disjoint link sets —
    inter-job contention is structurally zero on HyperX."""
    pod = HyperXFabric((16, 4))
    machine = MachineState(pod)
    p1 = machine.allocate(1, (4, 2))
    p2 = machine.allocate(2, (8, 2))
    assert p1 is not None and p2 is not None
    fields = []
    for placement in (p1, p2):
        mesh = machine.cells(placement.oriented, placement.offset)
        grids = np.meshgrid(*(np.asarray(c).ravel() for c in mesh), indexing="ij")
        coords = np.stack([g.ravel() for g in grids], axis=1)
        n = coords.shape[0]
        si = np.repeat(np.arange(n), n)
        di = np.tile(np.arange(n), n)
        keep = si != di
        fields.append(
            route_hyperx(pod, coords[si[keep]], coords[di[keep]], 1.0)
        )
    overlap = (fields[0] > 0) & (fields[1] > 0)
    assert not overlap.any()


def test_traffic_loads_rejects_hyperx():
    machine = MachineState(HyperXFabric((8, 4)))
    machine.allocate(1, (4, 2))
    with pytest.raises(TypeError, match="share no links"):
        machine.traffic_loads()


def test_simulate_queue_on_hyperx_machine():
    pod = HyperXFabric((16, 4))
    jobs = [JobRequest(job_id=i, units=16, duration=1.0) for i in range(3)]
    res = simulate_queue(pod, jobs, IsoperimetricPolicy())
    assert len(res.jobs) == 3 and not res.rejected
    # The isoperimetric preference on HyperX is the *elongated* box.
    assert res.jobs[0].placement.geometry == (16, 1)
    with pytest.raises(ValueError):
        simulate_queue(pod, jobs, IsoperimetricPolicy(), measure_contention=True)
    with pytest.raises(ValueError):
        simulate_queue(pod, jobs, IsoperimetricPolicy(), unit_node_dims=(2, 2))


def test_scheduler_predicted_time_uses_hyperx_closed_form():
    pod = HyperXFabric((16, 4))
    jobs = [
        JobRequest(job_id=0, units=16, duration=1.0, geometry=(16, 1)),
        JobRequest(job_id=1, units=16, duration=1.0, geometry=(8, 2)),
    ]
    res = simulate_queue(pod, jobs, IsoperimetricPolicy())
    by_id = {j.request.job_id: j for j in res.jobs}
    t_good = by_id[0].predicted_comm_time
    t_bad = by_id[1].predicted_comm_time
    assert t_bad / t_good == pytest.approx(8.0)


def test_obs_attribution_cross_traffic_structurally_zero():
    pod = HyperXFabric((16, 4))
    machine = MachineState(pod)
    machine.allocate(1, (4, 2))
    machine.allocate(2, (8, 2))
    report = attribute_contention(machine)
    for job in report.jobs:
        assert job.cross_load == pytest.approx(0.0)
        assert job.self_load > 0.0


def test_planner_accepts_hyperx_pod():
    from repro.launch.planner import plan_model

    pod = HyperXFabric((16, 4))
    plan = plan_model("mixtral-8x7b", 16, pod=pod, shape="decode_32k",
                      simulate_top_k=1)
    assert plan.chips == 16
    assert plan.best.simulated_slowdown >= 1.0
    geoms = {c.geometry for c in plan.table}
    assert geoms == {(16, 1), (8, 2), (4, 4)}
    with pytest.raises(ValueError, match="unit_node_dims"):
        plan_model("mixtral-8x7b", 16, pod=pod, wrap_mode="torus",
                   unit_node_dims=(2, 2))
