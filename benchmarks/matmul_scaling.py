"""Benchmarks for paper Experiments B (Figure 5) and C (Figure 6)."""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bgq import partition_bisection_links
from repro.core.strassen import caps_comm_model, strassen_winograd


def fig5_matmul() -> Tuple[List[dict], str]:
    """Figure 5: Strassen-Winograd matmul on Mira partitions.

    (a) the compute kernel: depth-2 Strassen-Winograd in JAX validated
        against jnp.dot (the per-node kernel of CAPS);
    (b) the partition-aware comm model: predicted comm-time ratios between
        current and proposed geometries must land in the paper's measured
        x1.37–x1.52 band, wallclock in x1.08–x1.22.
    """
    # (a) kernel correctness + timing
    key = jax.random.key(0)
    ka, kb = jax.random.split(key)
    n = 512
    a = jax.random.normal(ka, (n, n), jnp.float32)
    b = jax.random.normal(kb, (n, n), jnp.float32)
    fast = jax.jit(lambda x, y: strassen_winograd(x, y, depth=2))
    ref = jax.jit(jnp.dot)
    out = fast(a, b)
    err = float(jnp.abs(out - ref(a, b)).max() / jnp.abs(ref(a, b)).max())
    assert err < 1e-4, err
    for f in (fast, ref):
        f(a, b).block_until_ready()
    t0 = time.perf_counter(); fast(a, b).block_until_ready(); t_fast = time.perf_counter() - t0
    t0 = time.perf_counter(); ref(a, b).block_until_ready(); t_ref = time.perf_counter() - t0

    # (b) comm model on the paper's four Mira cells
    cells = [
        (4, partition_bisection_links((4, 1, 1, 1)), partition_bisection_links((2, 2, 1, 1))),
        (8, partition_bisection_links((4, 2, 1, 1)), partition_bisection_links((2, 2, 2, 1))),
        (16, partition_bisection_links((4, 4, 1, 1)), partition_bisection_links((2, 2, 2, 2))),
        (24, partition_bisection_links((4, 3, 2, 1)), partition_bisection_links((3, 2, 2, 2))),
    ]
    # comm_over_comp=0.5: the paper reports comm ~ half of compute after
    # communication-hiding ("costs offset by communication-hiding are not
    # presented"), which is exactly what the wallclock band 1.08-1.22 vs the
    # comm band 1.37-1.52 implies: (C + 0.5*1.45*C)/(C + 0.5*C) = 1.15.
    preds = caps_comm_model(cells, phi=0.45, comm_over_comp=0.5)
    rows = []
    for p in preds:
        rows.append(
            {
                "midplanes": p.midplanes,
                "bisection_ratio": round(p.bisection_ratio, 3),
                "pred_comm_ratio": round(p.comm_ratio, 3),
                "pred_wallclock_ratio": round(p.wallclock_ratio, 3),
                "paper_comm_band": "[1.37, 1.52]",
                "paper_wallclock_band": "[1.08, 1.22]",
            }
        )
    for p in preds[:3]:  # the x2-bisection cells
        assert 1.37 <= p.comm_ratio <= 1.52
        assert 1.08 <= p.wallclock_ratio <= 1.22
    rows.append(
        {
            "midplanes": "kernel",
            "bisection_ratio": f"strassen_err={err:.2e}",
            "pred_comm_ratio": f"t_strassen_ms={t_fast*1e3:.1f}",
            "pred_wallclock_ratio": f"t_dot_ms={t_ref*1e3:.1f}",
            "paper_comm_band": "",
            "paper_wallclock_band": "",
        }
    )
    return rows, f"comm_ratio_x2cells={preds[0].comm_ratio:.2f},kernel_err={err:.1e}"


def fig6_strong_scaling() -> Tuple[List[dict], str]:
    """Figure 6: strong-scaling simulation (2 -> 4 -> 8 midplanes, n=9408).

    Bisection-bound comm with fixed total cross-volume: proposed geometries
    scale linearly (T ~ 1/BW doubles each doubling); the current geometries
    stall between 2 and 4 midplanes — the paper's 'false sub-linear scaling'
    hazard for scaling studies."""
    cells = [
        (2, (2, 1, 1, 1), (2, 1, 1, 1)),
        (4, (4, 1, 1, 1), (2, 2, 1, 1)),
        (8, (4, 2, 1, 1), (2, 2, 2, 1)),
    ]
    rows = []
    base_bw = 2.0 * partition_bisection_links((2, 1, 1, 1))
    for mp, cur, prop in cells:
        bw_c = 2.0 * partition_bisection_links(cur)
        bw_p = 2.0 * partition_bisection_links(prop)
        rows.append(
            {
                "midplanes": mp,
                "current_geometry": cur,
                "proposed_geometry": prop,
                "comm_time_current": round(base_bw / bw_c, 3),  # normalized to 2mp
                "comm_time_proposed": round(base_bw / bw_p, 3),
            }
        )
    # proposed: linear scaling 2 -> 8 (4x less comm time at 4x nodes)
    assert rows[0]["comm_time_proposed"] / rows[2]["comm_time_proposed"] == 4.0
    # current: stalls at 4 midplanes (same bisection as 2)
    assert rows[0]["comm_time_current"] == rows[1]["comm_time_current"]
    assert rows[0]["comm_time_current"] / rows[2]["comm_time_current"] == 2.0
    return rows, "proposed=linear(4x),current=sublinear(2x)"
