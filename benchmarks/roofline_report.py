"""Roofline report: reads the dry-run JSON cache and prints the per-cell
three-term table (EXPERIMENTS.md §Roofline is generated from this)."""

from __future__ import annotations

import json
from pathlib import Path
from typing import List, Optional, Tuple

RESULTS_DIR = Path(__file__).resolve().parent / "results" / "dryrun"


def load_records(mesh: Optional[str] = None, include_variants: bool = False) -> List[dict]:
    recs = []
    if not RESULTS_DIR.exists():
        return recs
    for f in sorted(RESULTS_DIR.glob("*.json")):
        if not include_variants and f.stem.count("__") > 2:
            continue  # perf-iteration variants (arch__shape__mesh__tag)
        r = json.loads(f.read_text())
        if mesh is None or r.get("mesh") == mesh:
            recs.append(r)
    return recs


def roofline_table(mesh: str = "single") -> Tuple[List[dict], str]:
    """§Roofline: all three terms per (arch x shape), single-pod mesh."""
    recs = load_records(mesh)
    rows = []
    for r in recs:
        rows.append(
            {
                "arch": r["arch"],
                "shape": r["shape"],
                "compute_s": round(r["compute_term"], 4),
                "memory_s": round(r["memory_term"], 4),
                "collective_s": round(r["collective_term"], 4),
                "bottleneck": r["bottleneck"],
                "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
                "roofline_fraction": round(r["roofline_fraction"], 4),
                "hbm_per_device_gb": round((r.get("bytes_per_device") or 0) / 1e9, 2),
            }
        )
    if not rows:
        return rows, "no dry-run records (run python -m repro.launch.dryrun --all)"
    worst = min(rows, key=lambda r: r["roofline_fraction"])
    bnecks = {}
    for r in rows:
        bnecks[r["bottleneck"]] = bnecks.get(r["bottleneck"], 0) + 1
    return rows, f"cells={len(rows)},bottlenecks={bnecks},worst={worst['arch']}/{worst['shape']}"


def dryrun_matrix() -> Tuple[List[dict], str]:
    """§Dry-run: compile status for every (arch x shape x mesh) cell."""
    recs = load_records()
    rows = [
        {
            "arch": r["arch"],
            "shape": r["shape"],
            "mesh": r["mesh"],
            "chips": r["chips"],
            "compile_s": r.get("compile_seconds"),
            "ok": r.get("ok", False),
        }
        for r in recs
    ]
    n_ok = sum(1 for r in rows if r["ok"])
    return rows, f"compiled={n_ok}/{len(rows)}"
