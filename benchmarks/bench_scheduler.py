"""Scheduler-service micro-benchmark: exact incremental traffic fields vs
the historical full recompute, plus end-to-end event throughput.

Acceptance benchmark for the event-sourced scheduler (PR 7): on a 16^3
machine a release + scored-background refresh must be >= 10x faster with
the exact int64 delta updates than with the pre-refactor behaviour
(``release`` discarding the cached field and ``traffic_loads`` re-routing
every live placement), with the resulting background tensors allclose and
their supports identical.  Events/sec figures for full service runs at
16^3 and 32^3 (seeded bursty scenario, failures injected) show the online
throughput the delta updates enable.

Run standalone (writes BENCH_scheduler.json):

    PYTHONPATH=src python benchmarks/bench_scheduler.py [--json PATH]

or via the harness (`PYTHONPATH=src python -m benchmarks.run`), which
registers :func:`scheduler_microbench`.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path
from typing import List, Tuple

import numpy as np

from repro.network import IsoperimetricPolicy, MachineState
from repro.network.placement import placement_loads
from repro.network.scheduler import generate_scenario, scheduler_throughput

GRID_DIMS = (16, 16, 16)
OCCUPANCY = 0.5
EVENTS = 30  # release/refresh/allocate/refresh cycles timed per variant
# The acceptance bar is 10x; BENCH_SCHEDULER_MIN_SPEEDUP lets loaded CI
# runners relax the timing gate without weakening the equality check.
TARGET_SPEEDUP = float(os.environ.get("BENCH_SCHEDULER_MIN_SPEEDUP", "10"))


class _FullRecomputeMachine(MachineState):
    """The pre-refactor baseline, kept verbatim for the comparison:
    ``release`` drops the cached float field ("subtraction would drift")
    and the next ``traffic_loads`` re-routes every live placement —
    O(live jobs x grid) per event."""

    def traffic_loads(self, exclude=None):
        if exclude is not None:  # historical callers subtracted floats
            return self.traffic_loads() - placement_loads(
                self.dims,
                self.placements[exclude].oriented,
                self.placements[exclude].offset,
            )
        if self._loads is None:
            total = np.zeros((len(self.dims), 2) + self.dims)
            for p in self.placements.values():
                total += placement_loads(self.dims, p.oriented, p.offset)
            self._loads = total
        return self._loads

    def _commit(self, *args, **kwargs):
        placed = super()._commit(*args, **kwargs)
        # Keep the historical warm-cache add (the int accumulators the
        # parent maintains are unused here — traffic_loads is overridden).
        self._loads = None
        return placed

    def release(self, job_id):
        p = self.placements.pop(job_id)
        self.grid[self.cells(p.oriented, p.offset)] = False
        self._loads = None  # recompute lazily; subtraction would drift


def _fill(machine: MachineState, seed: int = 42) -> List[int]:
    """Cuboid placements up to ~OCCUPANCY fill, the way an allocator would
    leave a busy machine (the live-job count is what the baseline's
    recompute scales with)."""
    rng = np.random.default_rng(seed)
    total = machine.free_units
    live: List[int] = []
    job = 0
    while (total - machine.free_units) / total < OCCUPANCY:
        geometry = tuple(int(2 ** rng.integers(0, 3)) for _ in machine.dims)
        if machine.allocate(job, geometry) is not None:
            live.append(job)
        job += 1
    return live


def _event_loop_time(machine: MachineState, live: List[int], seed: int = 7) -> float:
    """Time EVENTS release -> background refresh -> allocate -> refresh
    cycles — the per-event field work of the scheduler service."""
    rng = np.random.default_rng(seed)
    machine.traffic_loads()  # warm
    next_id = max(live) + 1
    t0 = time.perf_counter()
    for _ in range(EVENTS):
        victim = live.pop(int(rng.integers(len(live))))
        geometry = machine.placements[victim].geometry
        machine.release(victim)
        machine.traffic_loads()
        if machine.allocate(next_id, geometry) is not None:
            live.append(next_id)
            next_id += 1
        machine.traffic_loads()
    return time.perf_counter() - t0


def _field_update_speedup() -> Tuple[float, float, float, int]:
    incremental = MachineState(GRID_DIMS)
    baseline = _FullRecomputeMachine(GRID_DIMS)
    live_inc = _fill(incremental)
    live_base = _fill(baseline)
    assert live_inc == live_base
    # Identical event streams; equality of the maintained fields first.
    t_inc = _event_loop_time(incremental, list(live_inc))
    t_base = _event_loop_time(baseline, list(live_base))
    a, b = incremental.traffic_loads(), baseline.traffic_loads()
    assert np.allclose(a, b), "incremental field drifted from full recompute"
    assert ((a > 0) == (b > 0)).all(), "incremental support differs"
    return t_base / t_inc, t_inc / EVENTS, t_base / EVENTS, len(live_inc)


def _service_throughput(dims, n_jobs: int, seed: int) -> Tuple[float, int, int]:
    scenario = generate_scenario(
        dims,
        n_jobs,
        seed=seed,
        burst_gap=30.0,
        mean_duration=80.0,
        failure_rate=0.002,
        repair_delay=150.0,
    )
    service, events_per_s = scheduler_throughput(
        scenario, IsoperimetricPolicy(), backfill=True
    )
    return events_per_s, service.events_processed, len(service.result().jobs)


def scheduler_microbench() -> Tuple[List[dict], str]:
    speedup, inc_s, base_s, live = _field_update_speedup()
    eps16, events16, jobs16 = _service_throughput((16, 16, 16), 250, seed=1)
    eps32, events32, jobs32 = _service_throughput((32, 32, 32), 120, seed=2)
    assert speedup >= TARGET_SPEEDUP, f"speedup {speedup:.1f}x < {TARGET_SPEEDUP}x"
    rows = [
        {
            "grid": list(GRID_DIMS),
            "occupancy": OCCUPANCY,
            "live_jobs": live,
            "events": EVENTS,
            "incremental_s_per_event": round(inc_s, 6),
            "full_recompute_s_per_event": round(base_s, 5),
            "speedup": round(speedup, 1),
        },
        {
            "grid": [16, 16, 16],
            "scenario_jobs": 250,
            "events_processed": events16,
            "scheduled": jobs16,
            "events_per_s": round(eps16, 1),
        },
        {
            "grid": [32, 32, 32],
            "scenario_jobs": 120,
            "events_processed": events32,
            "scheduled": jobs32,
            "events_per_s": round(eps32, 1),
        },
    ]
    derived = (
        f"field_speedup={speedup:.0f}x,"
        f"16^3={eps16:.0f}ev/s,32^3={eps32:.0f}ev/s"
    )
    return rows, derived


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_scheduler.json", help="output path")
    args = ap.parse_args()
    rows, derived = scheduler_microbench()
    out = Path(args.json)
    out.write_text(
        json.dumps({"benchmark": "scheduler_microbench", "rows": rows}, indent=1)
    )
    print(f"scheduler_microbench: {derived} -> {out}")


if __name__ == "__main__":
    main()
