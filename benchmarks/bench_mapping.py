"""Rank-mapping micro-benchmark: vectorized scorer vs the per-hop oracle.

Acceptance benchmark for the mapping subsystem: scoring a 256-rank halo
workload on an 8x8x8 machine through the vectorized engine must produce
*identical* congestion/dilation numbers as the per-hop reference walker
(kept under ``tests/reference_mapping.py``) and be >= 20x faster; a second
row times the full ``map_ranks`` strategy search end-to-end and records
how much congestion the chosen mapping recovers vs row-major on a
transposed logical grid.

Run standalone (writes BENCH_mapping.json):

    PYTHONPATH=src python benchmarks/bench_mapping.py [--json PATH]

or via the harness (`PYTHONPATH=src python -m benchmarks.run`), which
registers :func:`mapping_microbench`.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path
from typing import List, Tuple

import numpy as np

from repro.network import map_ranks, pattern_traffic, score_mapping
from repro.network.mapping import placement_cell_coords

_REPO = Path(__file__).resolve().parents[1]

DIMS = (8, 8, 8)
ORIENTED = (8, 8, 4)
PATTERN = "halo"
SEARCH_CASE = dict(dims=(16, 16), oriented=(2, 8), offset=(3, 5),
                   logical_dims=(8, 2), pattern="halo")
# The subsystem's acceptance bar is 20x; BENCH_MAPPING_MIN_SPEEDUP lets
# loaded CI runners relax the timing gate without weakening the
# score-identity check (mirroring BENCH_ROUTING_MIN_SPEEDUP).
TARGET_SPEEDUP = float(os.environ.get("BENCH_MAPPING_MIN_SPEEDUP", "20"))


def _reference_module():
    """Import the per-hop oracle lazily — it lives with the tests, and the
    harness must not mutate sys.path unless this benchmark actually runs."""
    tests_dir = str(_REPO / "tests")
    if tests_dir not in sys.path:
        sys.path.insert(0, tests_dir)
    import reference_mapping

    return reference_mapping


def _scrambled_mapping() -> np.ndarray:
    cells = placement_cell_coords(DIMS, ORIENTED, (0, 0, 0))
    rng = np.random.default_rng(7)
    return cells[rng.permutation(cells.shape[0])]


def _time_vectorized(coords, traffic, repeats: int = 5) -> Tuple[float, Tuple[float, float]]:
    best = float("inf")
    score = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        score = score_mapping(DIMS, coords, traffic)
        best = min(best, time.perf_counter() - t0)
    return best, (score.congestion, score.dilation)


def _time_oracle(coords, traffic) -> Tuple[float, Tuple[float, float]]:
    ref = _reference_module()  # import outside the timed region
    t0 = time.perf_counter()
    c, d, _ = ref.reference_score_mapping(DIMS, coords, traffic)
    return time.perf_counter() - t0, (c, d)


def mapping_microbench() -> Tuple[List[dict], str]:
    coords = _scrambled_mapping()
    traffic = pattern_traffic(ORIENTED, PATTERN)
    t_fast, score_fast = _time_vectorized(coords, traffic)
    t_slow, score_slow = _time_oracle(coords, traffic)
    speedup = t_slow / t_fast
    assert abs(score_fast[0] - score_slow[0]) < 1e-9, (score_fast, score_slow)
    assert abs(score_fast[1] - score_slow[1]) < 1e-9, (score_fast, score_slow)
    assert speedup >= TARGET_SPEEDUP, f"speedup {speedup:.1f}x < {TARGET_SPEEDUP}x"

    t0 = time.perf_counter()
    m = map_ranks(**SEARCH_CASE)
    t_search = time.perf_counter() - t0
    assert m.score.congestion < m.identity_score.congestion, (
        "strategy search failed to beat row-major on the transposed grid"
    )
    rows = [
        {
            "case": "scorer",
            "dims": list(DIMS),
            "oriented": list(ORIENTED),
            "pattern": PATTERN,
            "messages": int(len(traffic[2])),
            "vectorized_s": round(t_fast, 5),
            "oracle_s": round(t_slow, 4),
            "speedup": round(speedup, 1),
            "congestion": score_fast[0],
            "dilation": score_fast[1],
        },
        {
            "case": "map_ranks",
            **{k: (list(v) if isinstance(v, tuple) else v) for k, v in SEARCH_CASE.items()},
            "search_s": round(t_search, 4),
            "strategy": m.strategy,
            "identity_congestion": m.identity_score.congestion,
            "mapped_congestion": m.score.congestion,
            "recovered_congestion": m.recovered_congestion,
        },
    ]
    derived = (
        f"speedup={speedup:.0f}x,recovered="
        f"{m.recovered_congestion:g}/{m.identity_score.congestion:g}"
    )
    return rows, derived


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_mapping.json", help="output path")
    args = ap.parse_args()
    rows, derived = mapping_microbench()
    out = Path(args.json)
    out.write_text(json.dumps({"benchmark": "mapping_microbench", "rows": rows}, indent=1))
    print(f"mapping_microbench: {derived} -> {out}")


if __name__ == "__main__":
    main()
