"""Telemetry-overhead micro-benchmark: the disabled-path cost of
``repro.obs`` instrumentation on the scheduler hot loop.

The tracer is globally off by default, so the cost the instrumentation
adds to every production run is the *disabled* path: one
``TRACER.enabled`` attribute check per instrumented boundary (span call
sites in the scheduler duplicate the un-traced branch, wrapper call
sites pay one extra function hop).  This benchmark bounds that cost on
the ``bench_scheduler`` reference scenario (16^3 machine, 250 bursty
jobs with failures):

* runs the scenario with telemetry disabled and times it;
* counts the spans an enabled run of the same scenario emits (every
  count is a disabled-path check in the production run);
* times the *worst-case* disabled call site — a full
  ``with TRACER.span(...)`` no-op context — over many iterations;
* gates ``overhead_fraction = n_spans * t_noop_span / t_scenario`` at
  ``BENCH_OBS_MAX_OVERHEAD`` (default 0.02, i.e. <= 2%).

An enabled-vs-disabled A/B wall-clock ratio is reported as an
informational row (it is noisy at this scenario size), and the event
logs of the two runs are asserted bit-identical — telemetry must
observe, never perturb.

Run standalone (writes BENCH_obs.json):

    PYTHONPATH=src python benchmarks/bench_obs.py [--json PATH]

or via the harness (`PYTHONPATH=src python -m benchmarks.run`), which
registers :func:`obs_microbench`.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path
from typing import List, Tuple

from repro.network import IsoperimetricPolicy
from repro.network.scheduler import generate_scenario, run_scenario
from repro.obs import TRACER

GRID_DIMS = (16, 16, 16)
N_JOBS = 250
NOOP_ITERS = 200_000
# The acceptance bar is <= 2% disabled-path overhead; BENCH_OBS_MAX_OVERHEAD
# lets loaded CI runners relax the timing gate without weakening the
# log-equality check.
MAX_OVERHEAD = float(os.environ.get("BENCH_OBS_MAX_OVERHEAD", "0.02"))


def _scenario():
    return generate_scenario(
        GRID_DIMS,
        N_JOBS,
        seed=1,
        burst_gap=30.0,
        mean_duration=80.0,
        failure_rate=0.002,
        repair_delay=150.0,
    )


def _log_key(service) -> list:
    return [
        (e.seq, e.time, e.kind, e.job_id, e.cells, e.placement,
         e.priority, e.reason, e.source)
        for e in service.log
    ]


def _run(enabled: bool) -> Tuple[float, object]:
    scenario = _scenario()
    if enabled:
        TRACER.enable(clear=True)
    else:
        TRACER.disable()
    t0 = time.perf_counter()
    service = run_scenario(scenario, IsoperimetricPolicy(), backfill=True)
    dt = time.perf_counter() - t0
    TRACER.disable()
    return dt, service


def _noop_span_cost() -> float:
    """Seconds per disabled ``TRACER.span`` call (the worst-case call
    site; the scheduler's guarded sites pay only the attribute check)."""
    TRACER.disable()
    span = TRACER.span  # bind once, like a hot call site would
    t0 = time.perf_counter()
    for _ in range(NOOP_ITERS):
        with span("bench.noop", a=1):
            pass
    return (time.perf_counter() - t0) / NOOP_ITERS


def obs_microbench() -> Tuple[List[dict], str]:
    t_off, svc_off = _run(enabled=False)
    t_off = min(t_off, _run(enabled=False)[0])  # best-of-2 vs scheduler jitter
    t_on, svc_on = _run(enabled=True)
    assert _log_key(svc_off) == _log_key(svc_on), (
        "telemetry perturbed the scheduler event log"
    )
    n_spans = len(TRACER.events())
    assert n_spans > 0, "enabled run emitted no spans"
    t_noop = _noop_span_cost()
    overhead = n_spans * t_noop / t_off
    enabled_overhead = max(0.0, t_on / t_off - 1.0)
    assert overhead <= MAX_OVERHEAD, (
        f"disabled-path overhead {overhead:.2%} > {MAX_OVERHEAD:.0%} gate"
    )
    rows = [
        {
            "grid": list(GRID_DIMS),
            "scenario_jobs": N_JOBS,
            "events_processed": svc_off.events_processed,
            "spans_per_run": n_spans,
            "noop_span_ns": round(t_noop * 1e9, 1),
            "scenario_s": round(t_off, 4),
            "overhead_fraction": round(overhead, 6),
            "max_overhead": MAX_OVERHEAD,
        },
        {
            "informational": "enabled A/B",
            "enabled_s": round(t_on, 4),
            "disabled_s": round(t_off, 4),
            "enabled_overhead_fraction": round(enabled_overhead, 4),
        },
    ]
    derived = (
        f"disabled_overhead={overhead:.3%},spans={n_spans},"
        f"noop={t_noop*1e9:.0f}ns"
    )
    return rows, derived


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_obs.json", help="output path")
    args = ap.parse_args()
    rows, derived = obs_microbench()
    out = Path(args.json)
    out.write_text(
        json.dumps({"benchmark": "obs_microbench", "rows": rows}, indent=1)
    )
    print(f"obs_microbench: {derived} -> {out}")


if __name__ == "__main__":
    main()
