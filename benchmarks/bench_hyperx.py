"""HyperX routing-engine micro-benchmark: vectorized vs per-hop walker.

Acceptance benchmark for the fabric-interface refactor: an all-to-all on
``H(8, 8)`` routed through the vectorized ``route_hyperx`` engine must
produce *identical* per-link loads to the per-hop Python reference
(``tests/reference_hyperx.py``), match the closed-form max load, and be
>= 10x faster.

Run standalone (writes BENCH_hyperx.json):

    PYTHONPATH=src python benchmarks/bench_hyperx.py [--json PATH]

or via the harness (`PYTHONPATH=src python -m benchmarks.run`), which
registers :func:`hyperx_microbench`.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path
from typing import List, Tuple

import numpy as np

from repro.network import (
    HyperXFabric,
    hyperx_all_to_all_max_load,
    hyperx_max_link_load,
    patterns,
    route_hyperx,
)

_REPO = Path(__file__).resolve().parents[1]

DIMS = (8, 8)
# 10x is the refactor's acceptance bar; BENCH_HYPERX_MIN_SPEEDUP lets loaded
# CI runners relax the timing gate without weakening the load-identity check.
TARGET_SPEEDUP = float(os.environ.get("BENCH_HYPERX_MIN_SPEEDUP", "10"))


def _reference_oracle():
    """Import the per-hop walker lazily — it lives with the tests, and the
    harness must not mutate sys.path unless this benchmark actually runs."""
    tests_dir = str(_REPO / "tests")
    if tests_dir not in sys.path:
        sys.path.insert(0, tests_dir)
    from reference_hyperx import oracle_minimal_loads

    return oracle_minimal_loads


def _time_vectorized(fab, src, dst, vol, repeats: int = 5) -> Tuple[float, np.ndarray]:
    best = float("inf")
    loads = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        loads = route_hyperx(fab, src, dst, vol, mode="minimal")
        best = min(best, time.perf_counter() - t0)
    return best, loads


def hyperx_microbench() -> Tuple[List[dict], str]:
    fab = HyperXFabric(DIMS)
    src, dst, vol = patterns.all_to_all(DIMS)
    t_fast, loads_fast = _time_vectorized(fab, src, dst, vol)
    oracle = _reference_oracle()  # import outside the timed region
    t0 = time.perf_counter()
    loads_slow = oracle(fab, src, dst, vol)
    t_slow = time.perf_counter() - t0
    speedup = t_slow / t_fast
    np.testing.assert_array_equal(loads_fast, loads_slow)
    max_load = hyperx_max_link_load(fab, loads_fast)
    closed_form = hyperx_all_to_all_max_load(fab)
    assert abs(max_load - closed_form) < 1e-9, (max_load, closed_form)
    assert speedup >= TARGET_SPEEDUP, f"speedup {speedup:.1f}x < {TARGET_SPEEDUP}x"
    rows = [
        {
            "dims": list(DIMS),
            "pattern": "all-to-all",
            "messages": int(len(vol)),
            "vectorized_s": round(t_fast, 4),
            "walker_s": round(t_slow, 4),
            "speedup": round(speedup, 1),
            "max_link_load": max_load,
            "closed_form_load": closed_form,
        }
    ]
    return rows, f"speedup={speedup:.0f}x,max_load={max_load:g}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_hyperx.json", help="output path")
    args = ap.parse_args()
    rows, derived = hyperx_microbench()
    out = Path(args.json)
    out.write_text(json.dumps({"benchmark": "hyperx_microbench", "rows": rows}, indent=1))
    print(f"hyperx_microbench: {derived} -> {out}")


if __name__ == "__main__":
    main()
