"""§Perf hillclimb driver: re-measure the three chosen cells under variants.

Variants (hypothesis -> change):
  baseline   paper-faithful configuration (FSDP+TP rules, remat=block,
             per-arch microbatches, unchunked CE) — bilinear-calibrated.
  opt1       memory/collective trade: fewer microbatches (weight gathers
             scale per-microbatch), remat='dots' (no re-gather in the remat
             recompute), chunked CE (no (B,S,V) logits materialization).

Run: PYTHONPATH=src python -m benchmarks.perf_hillclimb [--variant opt1]
"""

import argparse
import json

CELLS = [
    ("nemotron-4-340b", "train_4k", "single"),
    ("rwkv6-3b", "train_4k", "single"),
    ("mixtral-8x7b", "train_4k", "single"),
]

VARIANTS = {
    "baseline2": {"tag": "baseline2"},  # re-measure with bilinear calibration
    "opt1": {
        "nemotron-4-340b": {"tag": "opt1", "microbatches": 2, "remat": "dots", "loss_chunk": 512},
        "rwkv6-3b": {"tag": "opt1", "microbatches": 1, "remat": "dots", "loss_chunk": 512},
        "mixtral-8x7b": {"tag": "opt1", "microbatches": 2, "remat": "dots", "loss_chunk": 512},
    },
    # opt2: ZeRO-1 for archs whose bf16 params fit per-device after TP
    # (kills the FSDP weight/activation gathers); nemotron cannot (42 GB/dev)
    # so it keeps ZeRO-3 with remat=block (undo the opt1 memory explosion)
    # and chunked CE.
    "opt2": {
        "nemotron-4-340b": {"tag": "opt2", "microbatches": 4, "remat": "block", "loss_chunk": 512},
        "rwkv6-3b": {"tag": "opt2", "microbatches": 1, "remat": "block", "loss_chunk": 512, "zero_stage": 1},
        "mixtral-8x7b": {"tag": "opt2", "microbatches": 2, "remat": "block", "loss_chunk": 512, "zero_stage": 1},
    },
    # opt3: best-of combinations — nemotron: opt1's microbatch cut without
    # the remat=dots memory explosion; mixtral: back to ZeRO-3 with the
    # microbatch cut + chunked CE.
    "opt3": {
        "nemotron-4-340b": {"tag": "opt3", "microbatches": 2, "remat": "block", "loss_chunk": 512},
        "rwkv6-3b": {"tag": "opt3", "microbatches": 1, "remat": "block", "loss_chunk": 512, "zero_stage": 1},
        "mixtral-8x7b": {"tag": "opt3", "microbatches": 2, "remat": "block", "loss_chunk": 512},
    },
    # opt4 (rwkv6 only): the arch is attention-free and fits per device —
    # tensor parallelism is pure overhead.  Pure 256-way DP (batch over both
    # mesh axes), ZeRO-1 params, sharded moments: the model-axis collectives
    # disappear; only the gradient all-reduce remains.
    "opt4": {
        "rwkv6-3b": {"tag": "opt4", "microbatches": 1, "remat": "block",
                      "loss_chunk": 512, "zero_stage": 1,
                      "model_axis": "none", "fsdp_axes": ["data", "model"]},
        "nemotron-4-340b": {"tag": "opt4", "microbatches": 2, "remat": "block", "loss_chunk": 512},
        "mixtral-8x7b": {"tag": "opt4", "microbatches": 2, "remat": "block", "loss_chunk": 512},
    },
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="baseline2")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    from repro.launch.dryrun import run_cell

    for arch, shape, mesh in CELLS:
        v = VARIANTS[args.variant]
        if arch in v:
            v = v[arch]
        rec = run_cell(arch, shape, mesh, force=args.force, variant=dict(v))
        print(f"[{args.variant}] {arch} x {shape}: "
              f"C={rec['compute_term']:.1f}s M={rec['memory_term']:.1f}s "
              f"K={rec['collective_term']:.1f}s frac={rec['roofline_fraction']:.4f} "
              f"temp={rec['memory_analysis'].get('temp_size_in_bytes',0)/1e9:.1f}GB")
        for ax, st in sorted(rec.get("per_axis_collectives", {}).items()):
            if st["bytes"] > 1e9:
                print(f"     axis {ax:12s} bytes={st['bytes']:.3e} ({st['bytes']/50e9:.1f}s @1link)")


if __name__ == "__main__":
    main()
