"""Compiled-backend micro-benchmark: jax.jit engines vs the NumPy oracles.

Acceptance benchmark for :mod:`repro.network.backend` (the tentpole claims):

* **netsim** — draining 2048 pairing scenarios of 512-node jobs on the
  full 32^3 torus (~10^6 subflows total) through the compiled fixed-shape
  simulator (:func:`prepare_drain` + :func:`drain_batch`, one plan per job
  geometry) must beat the public per-scenario NumPy path
  (``dor_paths`` + ``simulate_flows``) by >= 10x, with sampled-lane
  makespans within 1e-9 relative.
* **scorer** — ``vmap``-batched candidate scoring
  (:func:`repro.network.backend.score_candidates`, 4096 advisor-scale
  candidate mappings of a 24-rank pairing job in one compiled call) must
  beat the sequential ``score_mapping`` loop by >= 10x with **exactly**
  equal congestion and dilation on every row.
* **golden parity** (asserted, not timed) — numpy and xla produce the
  identical DOR link-load tensor (exact) and matching pairing makespans
  on golden Mira / JUQUEEN node-geometry pairs.

Run standalone (writes BENCH_backend.json):

    PYTHONPATH=src python benchmarks/bench_backend.py [--json PATH]

or via the harness (`PYTHONPATH=src python -m benchmarks.run`), which
registers :func:`backend_microbench`.  Requires jax; the gate can be
relaxed on loaded CI runners with BENCH_BACKEND_MIN_SPEEDUP (the parity
assertions never weaken).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from pathlib import Path
from typing import List, Tuple

import numpy as np

from repro.network import (
    bisection_pairing,
    dor_paths,
    drain_batch,
    prepare_drain,
    route_dor,
    score_candidates,
    simulate_flows,
)
from repro.network.mapping import pattern_traffic

MACHINE = (32, 32, 32)
# Four 512-node job geometries (spans <= 16 < 32/2: no machine-ring ties,
# so subflows == messages and one drain plan serves every volume lane).
JOB_GEOMETRIES = ((8, 8, 8), (16, 8, 4), (4, 16, 8), (8, 4, 16))
LANES_PER_GEOMETRY = 512
NUMPY_SAMPLE_LANES = 16  # numpy baseline is timed on this documented subsample

SCORER_DIMS = (4, 4, 3, 2)  # Mira midplane torus, 96 cells
SCORER_RANKS = 24
SCORER_LOGICAL = (4, 3, 2)  # the 24 ranks' logical grid (pairing traffic)
SCORER_BATCH = 4096

GOLDEN_PAIRS = (  # (name, node dims) — 512-node Mira vs JUQUEEN partitions
    ("mira-4mp", (16, 4, 4, 4, 2)),
    ("juqueen-4mp", (8, 8, 4, 4, 2)),
)

# The acceptance bar is 10x; BENCH_BACKEND_MIN_SPEEDUP lets loaded CI
# runners relax the timing gate without weakening the exact-parity checks
# (mirroring BENCH_NETSIM_MIN_SPEEDUP).
TARGET_SPEEDUP = float(os.environ.get("BENCH_BACKEND_MIN_SPEEDUP", "10"))


def _lane_volumes(rng: np.random.Generator, n_msgs: int, lanes: int) -> np.ndarray:
    """(lanes, n_msgs) integer message volumes (two size classes), so the
    numpy/xla makespan comparison is over dyadic-exact arithmetic."""
    return rng.integers(1, 3, size=(lanes, n_msgs)).astype(np.float64)


def _netsim_case(rng: np.random.Generator) -> Tuple[dict, float]:
    total_flows = 0
    t_xla = 0.0
    t_numpy_sampled = 0.0
    sampled = 0
    max_rel = 0.0
    for geom in JOB_GEOMETRIES:
        src, dst, _ = bisection_pairing(geom)
        paths = dor_paths(MACHINE, src, dst, np.ones(src.shape[0]))
        assert paths.n_flows == src.shape[0], "unexpected tie split"
        vols = _lane_volumes(rng, paths.n_flows, LANES_PER_GEOMETRY)
        total_flows += paths.n_flows * LANES_PER_GEOMETRY

        t0 = time.perf_counter()
        plan = prepare_drain(paths)
        fc, _ = drain_batch(plan, vols)
        t_xla += time.perf_counter() - t0

        # NumPy baseline: the public per-scenario path, timed on a
        # documented subsample and scaled to the full lane count.
        for i in range(NUMPY_SAMPLE_LANES):
            lane_paths = dataclasses.replace(paths, vol=vols[i])
            t0 = time.perf_counter()
            res = simulate_flows(lane_paths)
            t_numpy_sampled += time.perf_counter() - t0
            sampled += 1
            rel = abs(res.makespan - float(fc[i].max())) / res.makespan
            max_rel = max(max_rel, rel)
    assert max_rel <= 1e-9, f"netsim makespan drift {max_rel:.2e}"
    lanes_total = len(JOB_GEOMETRIES) * LANES_PER_GEOMETRY
    t_numpy_est = t_numpy_sampled / sampled * lanes_total
    speedup = t_numpy_est / t_xla
    row = {
        "case": "netsim-batched-drain",
        "machine": list(MACHINE),
        "job_geometries": [list(g) for g in JOB_GEOMETRIES],
        "scenarios": lanes_total,
        "total_subflows": int(total_flows),
        "xla_total_s": round(t_xla, 3),
        "numpy_sampled_lanes": sampled,
        "numpy_est_total_s": round(t_numpy_est, 3),
        "max_makespan_rel_diff": max_rel,
        "speedup": round(speedup, 1),
    }
    return row, speedup


def _scorer_case(rng: np.random.Generator) -> Tuple[dict, float]:
    n_cells = int(np.prod(SCORER_DIMS))
    traffic = pattern_traffic(SCORER_LOGICAL, "pairing")
    cells = np.stack(
        [rng.choice(n_cells, SCORER_RANKS, replace=False) for _ in range(SCORER_BATCH)]
    )
    coords = np.stack(np.unravel_index(cells, SCORER_DIMS), axis=-1).astype(np.int64)

    # Warm the compile cache at the production batch shape (the jitted
    # scorer specialises on B), then time the steady-state batched call.
    score_candidates(SCORER_DIMS, coords, traffic, backend="xla")
    t0 = time.perf_counter()
    cong_x, dil_x = score_candidates(SCORER_DIMS, coords, traffic, backend="xla")
    t_xla = time.perf_counter() - t0

    t0 = time.perf_counter()
    cong_np, dil_np = score_candidates(SCORER_DIMS, coords, traffic, backend="numpy")
    t_numpy = time.perf_counter() - t0

    assert np.array_equal(cong_np, cong_x), "batched congestion not exact"
    assert np.array_equal(dil_np, dil_x), "batched dilation not exact"
    speedup = t_numpy / t_xla
    row = {
        "case": "vmap-candidate-scoring",
        "machine": list(SCORER_DIMS),
        "candidates": SCORER_BATCH,
        "ranks": SCORER_RANKS,
        "messages": int(traffic[0].shape[0]),
        "numpy_loop_s": round(t_numpy, 3),
        "xla_batched_s": round(t_xla, 3),
        "exact": True,
        "speedup": round(speedup, 1),
    }
    return row, speedup


def _golden_parity_case() -> dict:
    checked = []
    for name, dims in GOLDEN_PAIRS:
        src, dst, vol = bisection_pairing(dims)
        loads_np = route_dor(dims, src, dst, vol)
        loads_x = route_dor(dims, src, dst, vol, backend="xla")
        assert np.array_equal(loads_np, loads_x), f"{name}: loads not exact"
        paths = dor_paths(dims, src, dst, vol)
        m_np = simulate_flows(paths).makespan
        m_x = simulate_flows(paths, backend="xla").makespan
        rel = abs(m_np - m_x) / m_np
        assert rel <= 1e-9, f"{name}: makespan drift {rel:.2e}"
        checked.append({"name": name, "dims": list(dims), "makespan": m_np})
    return {"case": "golden-parity", "loads": "exact", "pairs": checked}


def backend_microbench() -> Tuple[List[dict], str]:
    rng = np.random.default_rng(0)
    scorer_row, scorer_speedup = _scorer_case(rng)
    netsim_row, netsim_speedup = _netsim_case(rng)
    parity_row = _golden_parity_case()
    gated = min(netsim_speedup, scorer_speedup)
    assert gated >= TARGET_SPEEDUP, (
        f"backend speedup {gated:.1f}x (netsim {netsim_speedup:.1f}x, "
        f"scorer {scorer_speedup:.1f}x) < {TARGET_SPEEDUP}x"
    )
    rows = [netsim_row, scorer_row, parity_row]
    derived = (
        f"netsim={netsim_speedup:.0f}x,scorer={scorer_speedup:.0f}x,parity=exact"
    )
    return rows, derived


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_backend.json", help="output path")
    args = ap.parse_args()
    from repro.utils.env import set_platform

    set_platform("cpu")
    rows, derived = backend_microbench()
    out = Path(args.json)
    out.write_text(
        json.dumps({"benchmark": "backend_microbench", "rows": rows}, indent=1)
    )
    print(f"backend_microbench: {derived} -> {out}")


if __name__ == "__main__":
    main()
