"""Fleet-planner micro-benchmark: batched candidate pricing vs sequential.

Acceptance benchmark for :mod:`repro.launch.planner`'s hot loop — scoring
the mapping catalogue for every (geometry, sharding-rule) pair.  The
planner hands the whole candidate stack of one rule's rank traffic to the
``vmap``-batched :func:`repro.network.backend.score_candidates` in a
single compiled call; the baseline is the sequential ``score_mapping``
loop the numpy path runs.  The batched pricing must be >= 10x faster and
**row-exact**: identical congestion/dilation on every candidate, so the
chosen mapping — and therefore the planner's whole ranked table — is
backend-independent (pinned separately in ``tests/test_backend.py``).

The candidate stacks are the planner's own: the mapping catalogue
(identity, axis permutations, gray-snake) for every sharding rule of a
Mixtral-scale MoE job on a 4D torus, replicated to advisor scale.

Run standalone (writes BENCH_planner.json):

    PYTHONPATH=src python benchmarks/bench_planner.py [--json PATH]

or via the harness (`PYTHONPATH=src python -m benchmarks.run`), which
registers :func:`planner_microbench`.  Requires jax; the gate can be
relaxed on loaded CI runners with BENCH_PLANNER_MIN_SPEEDUP (the
row-identity assertions never weaken).
"""

from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path
from typing import List, Tuple

import numpy as np

from repro.configs import SHAPES, get_arch
from repro.launch.planner import (
    enumerate_rules,
    pairing_stress_volume,
    rule_rank_traffic,
    rule_traffic,
)
from repro.network import score_candidates
from repro.network.fabric import TorusFabric
from repro.network.mapping import (
    axis_order_coords,
    axis_permutation_orders,
    identity_mapping,
    score_mapping,
    snake_mapping,
)

ARCH = "mixtral-8x7b"
SHAPE = "decode_32k"
DIMS = (2, 2, 2, 2)  # one planner slice geometry: 16 chips, 4D
CHIPS = 16
REPLICAS = 24  # replicate the catalogue to advisor scale per rule
TARGET_SPEEDUP = float(os.environ.get("BENCH_PLANNER_MIN_SPEEDUP", "10"))


def _catalogue(fabric: TorusFabric) -> np.ndarray:
    """The planner's mapping candidates for one fabric, stacked."""
    dims = fabric.dims
    offset = (0,) * len(dims)
    cands = [identity_mapping(dims, dims, offset)]
    for perm, rev in axis_permutation_orders(dims):
        if all(p == i for i, p in enumerate(perm)) and not any(rev):
            continue
        cands.append(axis_order_coords(dims, dims, offset, perm, rev))
    cands.append(snake_mapping(dims, dims, offset))
    return np.stack(cands)


def _rule_stacks(fabric: TorusFabric) -> List[Tuple[Tuple[int, ...], tuple, np.ndarray]]:
    """(axis_sizes, rank traffic, candidate stack) per sharding rule with
    non-empty traffic, catalogue replicated to advisor scale."""
    cfg = get_arch(ARCH)
    shape = SHAPES[SHAPE]
    base = _catalogue(fabric)
    stacks = []
    for rule in enumerate_rules(cfg, CHIPS):
        entries = rule_traffic(cfg, shape, rule.axis_sizes)
        pair = pairing_stress_volume(entries, rule.axis_sizes)
        traffic = rule_rank_traffic(rule.axis_sizes, entries, pair)
        if traffic is None:
            continue
        stacks.append((rule.axis_sizes, traffic, np.tile(base, (REPLICAS, 1, 1))))
    return stacks


def planner_microbench() -> Tuple[List[dict], str]:
    fabric = TorusFabric.tpu(DIMS)
    stacks = _rule_stacks(fabric)
    assert stacks, "no sharding rules with traffic — benchmark is vacuous"
    n_cands = sum(s.shape[0] for _, _, s in stacks)

    # Batched pricing: one compiled call per rule stack (the planner's
    # shape). Warm up the jit cache first, then take the best of 3.
    for _, traffic, stack in stacks:
        score_candidates(fabric.dims, stack, traffic, backend="xla")
    t_fast = float("inf")
    batched = None
    for _ in range(3):
        t0 = time.perf_counter()
        batched = [
            score_candidates(fabric.dims, stack, traffic, backend="xla")
            for _, traffic, stack in stacks
        ]
        t_fast = min(t_fast, time.perf_counter() - t0)

    # Sequential baseline: the numpy score_mapping loop.
    t0 = time.perf_counter()
    sequential = [
        [score_mapping(fabric.dims, c, traffic) for c in stack]
        for _, traffic, stack in stacks
    ]
    t_slow = time.perf_counter() - t0

    # Row-exact identity on every candidate of every rule.
    for (cong_x, dil_x), refs in zip(batched, sequential):
        for i, ref in enumerate(refs):
            assert cong_x[i] == ref.congestion, (i, cong_x[i], ref.congestion)
            assert dil_x[i] == ref.dilation, (i, dil_x[i], ref.dilation)

    speedup = t_slow / t_fast
    assert speedup >= TARGET_SPEEDUP, f"speedup {speedup:.1f}x < {TARGET_SPEEDUP}x"

    rows = [
        {
            "case": "rule_catalogue_pricing",
            "arch": ARCH,
            "shape": SHAPE,
            "dims": list(DIMS),
            "rules": len(stacks),
            "candidates": int(n_cands),
            "batched_s": round(t_fast, 5),
            "sequential_s": round(t_slow, 4),
            "speedup": round(speedup, 1),
        }
    ]
    return rows, f"speedup={speedup:.0f}x,candidates={n_cands}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_planner.json", help="output path")
    args = ap.parse_args()
    rows, derived = planner_microbench()
    out = Path(args.json)
    out.write_text(json.dumps({"benchmark": "planner_microbench", "rows": rows}, indent=1))
    print(f"planner_microbench: {derived} -> {out}")


if __name__ == "__main__":
    main()
