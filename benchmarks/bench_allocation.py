"""Placement-engine micro-benchmark: vectorized correlation vs the
brute-force reference scan.

Acceptance benchmark for the placement refactor: on a 16x16x16 occupancy
grid the engine must produce the *identical* feasibility set (every free
translate of every orientation) as the historical per-offset Python scan
(kept under ``tests/reference_placement.py``) and be >= 10x faster; a
queue-replay throughput figure shows the end-to-end allocator speed the
engine enables (the reference scan made Mira-scale replays infeasible).

Run standalone (writes BENCH_allocation.json):

    PYTHONPATH=src python benchmarks/bench_allocation.py [--json PATH]

or via the harness (`PYTHONPATH=src python -m benchmarks.run`), which
registers :func:`allocation_microbench`.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path
from typing import List, Tuple

import numpy as np

from repro.network import IsoperimetricPolicy, JobRequest, simulate_queue
from repro.network.placement import first_fit, free_offset_mask, orientations

_REPO = Path(__file__).resolve().parents[1]

GRID_DIMS = (16, 16, 16)
FEASIBILITY_GEOMETRY = (8, 4, 4)
FIRST_FIT_GEOMETRIES = [(8, 4, 4), (16, 4, 2), (4, 4, 4), (8, 8, 2), (2, 2, 2)]
OCCUPANCY = 0.3
# The acceptance bar is 10x; BENCH_ALLOCATION_MIN_SPEEDUP lets loaded CI
# runners relax the timing gate without weakening the identity check
# (mirroring BENCH_ROUTING_MIN_SPEEDUP).
TARGET_SPEEDUP = float(os.environ.get("BENCH_ALLOCATION_MIN_SPEEDUP", "10"))


def _reference_module():
    """Import the brute-force scan lazily — it lives with the tests, and the
    harness must not mutate sys.path unless this benchmark actually runs."""
    tests_dir = str(_REPO / "tests")
    if tests_dir not in sys.path:
        sys.path.insert(0, tests_dir)
    import reference_placement

    return reference_placement


def _grid() -> np.ndarray:
    """Realistic fragmentation: cuboid placements (as an allocator would
    leave them) up to ~OCCUPANCY fill, not random scatter — random scatter
    at 30% leaves no free translate of a large cuboid at all."""
    from repro.network import MachineState

    rng = np.random.default_rng(42)
    m = MachineState(GRID_DIMS)
    total = m.free_units
    job = 0
    while (total - m.free_units) / total < OCCUPANCY:
        geometry = tuple(int(2 ** rng.integers(0, 4)) for _ in GRID_DIMS)
        m.allocate(job, geometry)
        job += 1
    return m.grid.copy()


def _feasibility_engine(grid) -> Tuple[float, dict]:
    t0 = time.perf_counter()
    sets = {}
    for o in orientations(FEASIBILITY_GEOMETRY, grid.shape):
        free = free_offset_mask(grid, o)
        sets[o] = [tuple(int(x) for x in idx) for idx in np.argwhere(free)]
    return time.perf_counter() - t0, sets


def _feasibility_reference(grid) -> Tuple[float, dict]:
    ref = _reference_module()
    t0 = time.perf_counter()
    sets = {}
    for o in ref.reference_orientations(FEASIBILITY_GEOMETRY, grid.shape):
        sets[o] = ref.reference_free_offsets(grid, o)
    return time.perf_counter() - t0, sets


def _first_fit_batch(grid) -> Tuple[float, float, List]:
    ref = _reference_module()
    t0 = time.perf_counter()
    engine = [first_fit(grid, g) for g in FIRST_FIT_GEOMETRIES]
    t_fast = time.perf_counter() - t0
    t0 = time.perf_counter()
    walker = [ref.reference_first_fit(grid, g) for g in FIRST_FIT_GEOMETRIES]
    t_slow = time.perf_counter() - t0
    assert engine == walker, (engine, walker)
    return t_fast, t_slow, engine


def _queue_replay_throughput(n_jobs: int = 200) -> Tuple[float, int]:
    rng = np.random.default_rng(0)
    sizes = np.array([1, 2, 4, 8, 16, 24, 32, 48])
    jobs = [
        JobRequest(
            i,
            int(rng.choice(sizes)),
            True,
            float(rng.lognormal(0.0, 0.6) + 0.2),
            float(i * 0.25),
        )
        for i in range(n_jobs)
    ]
    t0 = time.perf_counter()
    res = simulate_queue((4, 4, 3, 2), jobs, IsoperimetricPolicy(), backfill=True)
    dt = time.perf_counter() - t0
    return n_jobs / dt, len(res.jobs)


def allocation_microbench() -> Tuple[List[dict], str]:
    grid = _grid()
    t_fast, sets_fast = _feasibility_engine(grid)
    t_slow, sets_slow = _feasibility_reference(grid)
    assert sets_fast == sets_slow, "feasibility sets differ"
    speedup = t_slow / t_fast
    ff_fast, ff_slow, _ = _first_fit_batch(grid)
    throughput, scheduled = _queue_replay_throughput()
    assert speedup >= TARGET_SPEEDUP, f"speedup {speedup:.1f}x < {TARGET_SPEEDUP}x"
    n_candidates = sum(len(s) for s in sets_slow.values())
    rows = [
        {
            "grid": list(GRID_DIMS),
            "occupancy": OCCUPANCY,
            "geometry": list(FEASIBILITY_GEOMETRY),
            "free_translates": n_candidates,
            "engine_s": round(t_fast, 5),
            "reference_s": round(t_slow, 4),
            "speedup": round(speedup, 1),
            "first_fit_engine_s": round(ff_fast, 5),
            "first_fit_reference_s": round(ff_slow, 4),
            "queue_replay_jobs_per_s": round(throughput, 1),
            "queue_replay_scheduled": scheduled,
        }
    ]
    return rows, f"speedup={speedup:.0f}x,replay={throughput:.0f}jobs/s"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_allocation.json", help="output path")
    args = ap.parse_args()
    rows, derived = allocation_microbench()
    out = Path(args.json)
    out.write_text(json.dumps({"benchmark": "allocation_microbench", "rows": rows}, indent=1))
    print(f"allocation_microbench: {derived} -> {out}")


if __name__ == "__main__":
    main()
