"""Routing-engine micro-benchmark: vectorized DOR vs the per-hop walker.

Acceptance benchmark for the repro.network refactor: an 8x8x8 all-to-all
routed through the vectorized engine must produce the *identical*
max-link-load as the historical per-hop Python walker (kept under
``tests/reference_dor.py``) and be >= 20x faster.

Run standalone (writes BENCH_routing.json):

    PYTHONPATH=src python benchmarks/bench_routing.py [--json PATH]

or via the harness (`PYTHONPATH=src python -m benchmarks.run`), which
registers :func:`routing_microbench`.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path
from typing import List, Tuple

from repro.network import LinkLoads, all_to_all_max_load, patterns

_REPO = Path(__file__).resolve().parents[1]

DIMS = (8, 8, 8)
# The refactor's acceptance bar is 20x; BENCH_ROUTING_MIN_SPEEDUP lets loaded
# CI runners relax the timing gate without weakening the load-identity check.
TARGET_SPEEDUP = float(os.environ.get("BENCH_ROUTING_MIN_SPEEDUP", "20"))


def _reference_linkloads_cls():
    """Import the per-hop walker lazily — it lives with the tests, and the
    harness must not mutate sys.path or require tests/ unless this benchmark
    actually runs."""
    tests_dir = str(_REPO / "tests")
    if tests_dir not in sys.path:
        sys.path.insert(0, tests_dir)
    from reference_dor import ReferenceLinkLoads

    return ReferenceLinkLoads


def _time_vectorized(src, dst, vol, repeats: int = 5) -> Tuple[float, float]:
    best = float("inf")
    load = 0.0
    for _ in range(repeats):
        t0 = time.perf_counter()
        ll = LinkLoads(DIMS)
        ll.add_batch(src, dst, vol)
        load = ll.max_load()
        best = min(best, time.perf_counter() - t0)
    return best, load


def _time_walker(src, dst, vol) -> Tuple[float, float]:
    walker_cls = _reference_linkloads_cls()  # import outside the timed region
    t0 = time.perf_counter()
    ref = walker_cls(DIMS)
    for s, d, v in zip(src, dst, vol):
        ref.add_path(tuple(int(x) for x in s), tuple(int(x) for x in d), float(v))
    return time.perf_counter() - t0, ref.max_load()


def routing_microbench() -> Tuple[List[dict], str]:
    src, dst, vol = patterns.all_to_all(DIMS)
    t_fast, load_fast = _time_vectorized(src, dst, vol)
    t_slow, load_slow = _time_walker(src, dst, vol)
    speedup = t_slow / t_fast
    closed_form = all_to_all_max_load(DIMS)
    assert load_fast == load_slow, (load_fast, load_slow)
    assert abs(load_fast - closed_form) < 1e-9, (load_fast, closed_form)
    assert speedup >= TARGET_SPEEDUP, f"speedup {speedup:.1f}x < {TARGET_SPEEDUP}x"
    rows = [
        {
            "dims": list(DIMS),
            "pattern": "all-to-all",
            "messages": int(len(vol)),
            "vectorized_s": round(t_fast, 4),
            "walker_s": round(t_slow, 4),
            "speedup": round(speedup, 1),
            "max_link_load": load_fast,
            "closed_form_load": closed_form,
        }
    ]
    return rows, f"speedup={speedup:.0f}x,max_load={load_fast:g}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_routing.json", help="output path")
    args = ap.parse_args()
    rows, derived = routing_microbench()
    out = Path(args.json)
    out.write_text(json.dumps({"benchmark": "routing_microbench", "rows": rows}, indent=1))
    print(f"routing_microbench: {derived} -> {out}")


if __name__ == "__main__":
    main()
