"""Flow-simulator micro-benchmark: vectorized waterfilling vs the per-flow
Python reference.

Acceptance benchmark for the netsim subsystem: draining a 126k-subflow
all-to-all on an 8x6x6 torus through the vectorized simulator must produce
*identical* completion times to the per-flow fluid oracle (kept under
``tests/reference_netsim.py``) and be >= 10x faster; a second row runs the
paper's validation experiment (simulated pairing makespan == predicted
max link load) on the Fig-3 four-midplane node torus and records the
measured contention slowdown the static engine predicts.

Run standalone (writes BENCH_netsim.json):

    PYTHONPATH=src python benchmarks/bench_netsim.py [--json PATH]

or via the harness (`PYTHONPATH=src python -m benchmarks.run`), which
registers :func:`netsim_microbench`.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path
from typing import List, Tuple

import numpy as np

from repro.network import all_to_all, bisection_pairing, dor_paths, simulate_flows
from repro.network import validate_prediction

_REPO = Path(__file__).resolve().parents[1]

DIMS = (8, 6, 6)
VALIDATION_DIMS = (16, 4, 4, 4, 2)  # Mira 4-midplane partition, node level
# The acceptance bar is 10x; BENCH_NETSIM_MIN_SPEEDUP lets loaded CI
# runners relax the timing gate without weakening the completion-time
# identity check (mirroring BENCH_ROUTING_MIN_SPEEDUP).
TARGET_SPEEDUP = float(os.environ.get("BENCH_NETSIM_MIN_SPEEDUP", "10"))


def _reference_module():
    """Import the per-flow oracle lazily — it lives with the tests, and the
    harness must not mutate sys.path unless this benchmark actually runs."""
    tests_dir = str(_REPO / "tests")
    if tests_dir not in sys.path:
        sys.path.insert(0, tests_dir)
    import reference_netsim

    return reference_netsim


def _time_vectorized(paths, repeats: int = 3):
    best = float("inf")
    res = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = simulate_flows(paths)
        best = min(best, time.perf_counter() - t0)
    return best, res


def _time_reference(paths):
    ref = _reference_module()  # import outside the timed region
    links_of_flow, capacity = ref.paths_to_reference(paths)
    t0 = time.perf_counter()
    completion, makespan = ref.reference_simulate(
        paths.vol.tolist(), links_of_flow, capacity
    )
    return time.perf_counter() - t0, np.asarray(completion), makespan


def netsim_microbench() -> Tuple[List[dict], str]:
    n = int(np.prod(DIMS))
    paths = dor_paths(DIMS, *all_to_all(DIMS, 1.0 / n))
    t_fast, res = _time_vectorized(paths)
    t_slow, ref_completion, ref_makespan = _time_reference(paths)
    speedup = t_slow / t_fast
    assert abs(res.makespan - ref_makespan) < 1e-9, (res.makespan, ref_makespan)
    assert np.allclose(res.flow_completion, ref_completion, rtol=1e-6, atol=1e-9)
    assert speedup >= TARGET_SPEEDUP, f"speedup {speedup:.1f}x < {TARGET_SPEEDUP}x"

    t0 = time.perf_counter()
    v = validate_prediction(VALIDATION_DIMS, bisection_pairing(VALIDATION_DIMS))
    t_validate = time.perf_counter() - t0
    assert v.matched, (v.predicted_time, v.simulated_time)
    rows = [
        {
            "case": "waterfilling",
            "dims": list(DIMS),
            "pattern": "all-to-all",
            "flows": int(paths.n_flows),
            "incidence_entries": int(paths.link_ids.shape[0]),
            "steps": int(res.steps),
            "vectorized_s": round(t_fast, 5),
            "reference_s": round(t_slow, 4),
            "speedup": round(speedup, 1),
            "makespan": res.makespan,
        },
        {
            "case": "validate_prediction",
            "dims": list(VALIDATION_DIMS),
            "pattern": "bisection-pairing",
            "predicted_time": v.predicted_time,
            "simulated_time": v.simulated_time,
            "ratio": v.ratio,
            "simulate_s": round(t_validate, 4),
        },
    ]
    derived = f"speedup={speedup:.0f}x,validated_ratio={v.ratio:g}"
    return rows, derived


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_netsim.json", help="output path")
    args = ap.parse_args()
    rows, derived = netsim_microbench()
    out = Path(args.json)
    out.write_text(json.dumps({"benchmark": "netsim_microbench", "rows": rows}, indent=1))
    print(f"netsim_microbench: {derived} -> {out}")


if __name__ == "__main__":
    main()
