"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per benchmark), then the
full row dumps.  Run: PYTHONPATH=src python -m benchmarks.run

Options:

* ``--only NAME`` (repeatable) — run just the named benchmark(s); unknown
  names fail fast with the list of valid ones.
* ``--check`` — validate previously emitted ``BENCH_*.json`` files
  against their gates (speedup floors via ``BENCH_*_MIN_SPEEDUP``,
  default 10; overhead ceilings via ``BENCH_*_MAX_OVERHEAD``, default
  0.02) without re-running anything; useful for auditing CI artifacts.
  Prints a one-line summary table of every gate.
* ``--require-all`` — with ``--check``, a missing artifact is a failure
  instead of a skip (CI runs the full benchmark set, so a missing file
  means a benchmark silently did not run).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback
from pathlib import Path

from repro.utils.env import have_jax, set_platform

from benchmarks.paper_tables import (
    fig3_pairing_mira,
    fig4_pairing_juqueen,
    table1_6_mira,
    table2_7_juqueen,
    table5_machine_design,
    tpu_slice_geometry,
)
from benchmarks.bench_allocation import allocation_microbench
from benchmarks.bench_backend import backend_microbench
from benchmarks.bench_hyperx import hyperx_microbench
from benchmarks.bench_isoperimetry import isoperimetry_microbench
from benchmarks.bench_mapping import mapping_microbench
from benchmarks.bench_netsim import netsim_microbench
from benchmarks.bench_obs import obs_microbench
from benchmarks.bench_planner import planner_microbench
from benchmarks.bench_routing import routing_microbench
from benchmarks.bench_scheduler import scheduler_microbench
from benchmarks.matmul_scaling import fig5_matmul, fig6_strong_scaling
from benchmarks.roofline_report import dryrun_matrix, roofline_table

BENCHMARKS = [
    ("table1_6_mira", table1_6_mira),
    ("table2_7_juqueen", table2_7_juqueen),
    ("table5_machine_design", table5_machine_design),
    ("fig3_pairing_mira", fig3_pairing_mira),
    ("fig4_pairing_juqueen", fig4_pairing_juqueen),
    ("fig5_matmul", fig5_matmul),
    ("fig6_strong_scaling", fig6_strong_scaling),
    ("tpu_slice_geometry", tpu_slice_geometry),
    ("routing_microbench", routing_microbench),
    ("hyperx_microbench", hyperx_microbench),
    ("allocation_microbench", allocation_microbench),
    ("mapping_microbench", mapping_microbench),
    ("netsim_microbench", netsim_microbench),
    ("isoperimetry_microbench", isoperimetry_microbench),
    ("backend_microbench", backend_microbench),
    ("scheduler_microbench", scheduler_microbench),
    ("planner_microbench", planner_microbench),
    ("obs_microbench", obs_microbench),
    ("roofline_table", roofline_table),
    ("dryrun_matrix", dryrun_matrix),
]

# Gated micro-benchmarks: (emitted JSON file, relaxing environment
# variable, gate kind) — the registry --check audits artifacts against.
# ``min_speedup`` gates floor every ``speedup`` row field (default 10x);
# ``max_overhead`` gates ceil every ``overhead_fraction`` row field
# (default 0.02, i.e. <= 2%).
GATED = {
    "routing_microbench": ("BENCH_routing.json", "BENCH_ROUTING_MIN_SPEEDUP", "min_speedup"),
    "hyperx_microbench": ("BENCH_hyperx.json", "BENCH_HYPERX_MIN_SPEEDUP", "min_speedup"),
    "allocation_microbench": ("BENCH_allocation.json", "BENCH_ALLOCATION_MIN_SPEEDUP", "min_speedup"),
    "mapping_microbench": ("BENCH_mapping.json", "BENCH_MAPPING_MIN_SPEEDUP", "min_speedup"),
    "netsim_microbench": ("BENCH_netsim.json", "BENCH_NETSIM_MIN_SPEEDUP", "min_speedup"),
    "isoperimetry_microbench": ("BENCH_isoperimetry.json", "BENCH_ISOPERIMETRY_MIN_SPEEDUP", "min_speedup"),
    "backend_microbench": ("BENCH_backend.json", "BENCH_BACKEND_MIN_SPEEDUP", "min_speedup"),
    "scheduler_microbench": ("BENCH_scheduler.json", "BENCH_SCHEDULER_MIN_SPEEDUP", "min_speedup"),
    "planner_microbench": ("BENCH_planner.json", "BENCH_PLANNER_MIN_SPEEDUP", "min_speedup"),
    "obs_microbench": ("BENCH_obs.json", "BENCH_OBS_MAX_OVERHEAD", "max_overhead"),
}

_GATE_DEFAULTS = {"min_speedup": "10", "max_overhead": "0.02"}
_GATE_FIELDS = {"min_speedup": "speedup", "max_overhead": "overhead_fraction"}


def check_artifacts(search_dir: Path, require_all: bool = False) -> int:
    """Validate emitted ``BENCH_*.json`` files against their gates without
    re-running: ``min_speedup`` benchmarks must have every ``speedup`` row
    field at or above the gate, ``max_overhead`` ones every
    ``overhead_fraction`` at or below it.  Missing files are reported but
    not fatal unless ``require_all`` (a partial artifact set is auditable;
    a CI run of the full set is not allowed silent gaps).  Prints a
    one-line-per-gate summary table and returns the number of failures."""
    failures = 0
    summary = []
    for name, (fname, env_var, kind) in sorted(GATED.items()):
        gate = float(os.environ.get(env_var, _GATE_DEFAULTS[kind]))
        field = _GATE_FIELDS[kind]
        path = search_dir / fname
        if not path.exists():
            if require_all:
                print(f"{name}: {fname} missing — FAILED (--require-all)")
                failures += 1
                summary.append((name, kind, gate, None, "MISSING"))
            else:
                print(f"{name}: {fname} missing — skipped")
                summary.append((name, kind, gate, None, "skipped"))
            continue
        data = json.loads(path.read_text())
        values = [r[field] for r in data.get("rows", []) if field in r]
        if not values:
            print(f"{name}: {fname} has no {field} rows — FAILED")
            failures += 1
            summary.append((name, kind, gate, None, "FAILED"))
            continue
        if kind == "min_speedup":
            worst = min(values)
            ok = worst >= gate
            print(f"{name}: worst speedup {worst:.1f}x vs gate {gate:g}x — "
                  f"{'ok' if ok else 'FAILED'}")
        else:
            worst = max(values)
            ok = worst <= gate
            print(f"{name}: worst overhead {worst:.3%} vs gate {gate:.0%} — "
                  f"{'ok' if ok else 'FAILED'}")
        if not ok:
            failures += 1
        summary.append((name, kind, gate, worst, "ok" if ok else "FAILED"))
    print()
    print(f"{'benchmark':<26} {'gate':>18} {'worst':>12} {'status':>8}")
    for name, kind, gate, worst, status in summary:
        bound = f">= {gate:g}x" if kind == "min_speedup" else f"<= {gate:.0%}"
        shown = "-" if worst is None else (
            f"{worst:.1f}x" if kind == "min_speedup" else f"{worst:.3%}"
        )
        print(f"{name:<26} {bound:>18} {shown:>12} {status:>8}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--only", action="append", metavar="NAME",
        help="run only the named benchmark (repeatable)",
    )
    ap.add_argument(
        "--check", action="store_true",
        help="validate emitted BENCH_*.json files against their gates; runs nothing",
    )
    ap.add_argument(
        "--check-dir", default=".", metavar="DIR",
        help="directory holding the BENCH_*.json artifacts (default: cwd)",
    )
    ap.add_argument(
        "--require-all", action="store_true",
        help="with --check: fail on missing artifacts instead of skipping",
    )
    args = ap.parse_args()

    if args.check:
        failures = check_artifacts(Path(args.check_dir), args.require_all)
        if failures:
            raise SystemExit(f"{failures} benchmark artifact(s) below gate")
        return

    if have_jax():
        set_platform("cpu")  # keep timings off any stray accelerator

    selected = BENCHMARKS
    if args.only:
        known = {name for name, _ in BENCHMARKS}
        unknown = [n for n in args.only if n not in known]
        if unknown:
            raise SystemExit(
                f"unknown benchmark(s) {unknown}; valid: {sorted(known)}"
            )
        selected = [(n, fn) for n, fn in BENCHMARKS if n in set(args.only)]

    print("name,us_per_call,derived")
    details = []
    failed = []
    for name, fn in selected:
        try:
            t0 = time.perf_counter()
            rows, derived = fn()
            dt = (time.perf_counter() - t0) * 1e6
            print(f"{name},{dt:.0f},{derived}")
            details.append((name, rows))
        except Exception as e:
            failed.append(name)
            print(f"{name},FAILED,{e!r}")
            traceback.print_exc(file=sys.stderr)
    print()
    for name, rows in details:
        print(f"== {name} ==")
        for r in rows:
            print("  ", r)
        print()
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
