"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per benchmark), then the
full row dumps.  Run: PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import sys
import time
import traceback

from benchmarks.paper_tables import (
    fig3_pairing_mira,
    fig4_pairing_juqueen,
    table1_6_mira,
    table2_7_juqueen,
    table5_machine_design,
    tpu_slice_geometry,
)
from benchmarks.bench_allocation import allocation_microbench
from benchmarks.bench_isoperimetry import isoperimetry_microbench
from benchmarks.bench_mapping import mapping_microbench
from benchmarks.bench_netsim import netsim_microbench
from benchmarks.bench_routing import routing_microbench
from benchmarks.matmul_scaling import fig5_matmul, fig6_strong_scaling
from benchmarks.roofline_report import dryrun_matrix, roofline_table

BENCHMARKS = [
    ("table1_6_mira", table1_6_mira),
    ("table2_7_juqueen", table2_7_juqueen),
    ("table5_machine_design", table5_machine_design),
    ("fig3_pairing_mira", fig3_pairing_mira),
    ("fig4_pairing_juqueen", fig4_pairing_juqueen),
    ("fig5_matmul", fig5_matmul),
    ("fig6_strong_scaling", fig6_strong_scaling),
    ("tpu_slice_geometry", tpu_slice_geometry),
    ("routing_microbench", routing_microbench),
    ("allocation_microbench", allocation_microbench),
    ("mapping_microbench", mapping_microbench),
    ("netsim_microbench", netsim_microbench),
    ("isoperimetry_microbench", isoperimetry_microbench),
    ("roofline_table", roofline_table),
    ("dryrun_matrix", dryrun_matrix),
]


def main() -> None:
    print("name,us_per_call,derived")
    details = []
    failed = []
    for name, fn in BENCHMARKS:
        try:
            t0 = time.perf_counter()
            rows, derived = fn()
            dt = (time.perf_counter() - t0) * 1e6
            print(f"{name},{dt:.0f},{derived}")
            details.append((name, rows))
        except Exception as e:
            failed.append(name)
            print(f"{name},FAILED,{e!r}")
            traceback.print_exc(file=sys.stderr)
    print()
    for name, rows in details:
        print(f"== {name} ==")
        for r in rows:
            print("  ", r)
        print()
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
