"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run JSON
cache.  Usage: PYTHONPATH=src python -m benchmarks.render_experiments > out.md
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.roofline_report import load_records

HW = "TPU v5e-class: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI"


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.1f}"


def dryrun_section() -> str:
    recs = load_records()
    lines = [
        "### Dry-run matrix (lower + compile, ShapeDtypeStruct inputs, no allocation)",
        "",
        "| arch | shape | mesh | chips | compile s | state GB/dev | XLA temp GB/dev | collectives (prod schedule) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        coll = r.get("production_collectives", {})
        sched = ", ".join(
            f"{k.replace('collective-','c-')}:{int(v['count'])}"
            for k, v in coll.items()
            if v["count"]
        )
        mem = r.get("memory_analysis", {})
        lines.append(
            "| {arch} | {shape} | {mesh} | {chips} | {cs} | {state} | {temp} | {sched} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"], chips=r["chips"],
                cs=r.get("compile_seconds", "-"),
                state=fmt_bytes(r.get("bytes_per_device")),
                temp=fmt_bytes(mem.get("temp_size_in_bytes")),
                sched=sched or "-",
            )
        )
    n = len(recs)
    lines.append("")
    lines.append(f"{n} cells compiled OK (per-cell JSON in benchmarks/results/dryrun/).")
    return "\n".join(lines)


def roofline_section(mesh: str = "single") -> str:
    recs = load_records(mesh)
    lines = [
        f"### Roofline table — single-pod 16x16 mesh ({HW})",
        "",
        "| arch | shape | compute s | memory s | collective s | bottleneck | MODEL/HLO flops | roofline fraction | 1-line lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    LEVER = {
        "collective": "cut FSDP gather/grad traffic (bf16 reductions, better dW strategy, axis rings)",
        "memory": "fuse/stream the cache + logits traffic; bigger per-chip batch",
        "compute": "close the remat + masked-attention waste (flash kernel path)",
    }
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            "| {a} | {s} | {c:.3f} | {m:.3f} | {k:.3f} | {b} | {u:.2f} | {f:.4f} | {lev} |".format(
                a=r["arch"], s=r["shape"], c=r["compute_term"], m=r["memory_term"],
                k=r["collective_term"], b=r["bottleneck"],
                u=r["useful_flops_ratio"], f=r["roofline_fraction"],
                lev=LEVER.get(r["bottleneck"], ""),
            )
        )
    return "\n".join(lines)


def main():
    print(dryrun_section())
    print()
    print(roofline_section())


if __name__ == "__main__":
    main()
