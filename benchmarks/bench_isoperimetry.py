"""Isoperimetry-engine micro-benchmark: batched divisor-meshgrid cuts vs the
per-cuboid Python oracle.

Acceptance benchmark for the ``repro.network.isoperimetry`` subsystem:
sweeping ``optimal_cuboid`` + ``worst_cuboid`` over the paper's Mira
partition sizes on the node-level torus (16x16x12x8x2) must produce results
*identical* to the per-cuboid loop oracle (kept under
``tests/reference_isoperimetry.py``) and be >= 10x faster in aggregate; a
second row runs the partition advisor end-to-end (Mira scheduler table,
node level) and records the paper's predicted geometry speedups.

Run standalone (writes BENCH_isoperimetry.json):

    PYTHONPATH=src python benchmarks/bench_isoperimetry.py [--json PATH]

or via the harness (`PYTHONPATH=src python -m benchmarks.run`), which
registers :func:`isoperimetry_microbench`.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path
from typing import List, Tuple

from repro.network.isoperimetry import (
    advise_policy_table,
    cut_table,
    optimal_cuboid,
    worst_cuboid,
)

_REPO = Path(__file__).resolve().parents[1]

# Mira's node-level torus and the scheduler partition sizes in nodes.
DIMS = (16, 16, 12, 8, 2)
SIZES = [mp * 512 for mp in (1, 2, 4, 8, 16, 24, 32, 48)]
# The acceptance bar is 10x; BENCH_ISOPERIMETRY_MIN_SPEEDUP lets loaded CI
# runners relax the timing gate without weakening the result-identity check
# (mirroring BENCH_ROUTING_MIN_SPEEDUP).
TARGET_SPEEDUP = float(os.environ.get("BENCH_ISOPERIMETRY_MIN_SPEEDUP", "10"))


def _reference_module():
    """Import the per-cuboid oracle lazily — it lives with the tests, and the
    harness must not mutate sys.path unless this benchmark actually runs."""
    tests_dir = str(_REPO / "tests")
    if tests_dir not in sys.path:
        sys.path.insert(0, tests_dir)
    import reference_isoperimetry

    return reference_isoperimetry


def _engine_sweep() -> list:
    """Optimal + worst cuboid per size from ONE batched table each — the
    engine's design point: a single enumeration serves every consumer."""
    out = []
    for t in SIZES:
        tbl = cut_table(DIMS, t)
        out.append((tbl.min_cut_geometry(), tbl.max_cut_geometry()))
    return out


def _time_engine(repeats: int = 3) -> Tuple[float, list]:
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = _engine_sweep()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _time_reference() -> Tuple[float, list]:
    ref = _reference_module()  # import outside the timed region
    t0 = time.perf_counter()
    out = [
        (
            ref.reference_optimal_cuboid(DIMS, t),
            ref.reference_worst_cuboid(DIMS, t),
        )
        for t in SIZES
    ]
    return time.perf_counter() - t0, out


def isoperimetry_microbench() -> Tuple[List[dict], str]:
    t_fast, engine = _time_engine()
    t_slow, oracle = _time_reference()
    speedup = t_slow / t_fast
    for t, (opt, wst), (ref_opt, ref_wst) in zip(SIZES, engine, oracle):
        assert opt == ref_opt[:2], (t, opt, ref_opt)
        assert wst == ref_wst[:2], (t, wst, ref_wst)
        # the full CuboidOptimum API (with the Theorem 3.1 bound) agrees too
        o, w = optimal_cuboid(DIMS, t), worst_cuboid(DIMS, t)
        assert (o.geometry, o.cut) == ref_opt[:2] and abs(o.bound - ref_opt[2]) < 1e-9
        assert (w.geometry, w.cut) == ref_wst[:2] and abs(w.bound - ref_wst[2]) < 1e-9
    assert speedup >= TARGET_SPEEDUP, f"speedup {speedup:.1f}x < {TARGET_SPEEDUP}x"

    # The advisor end-to-end: Mira's scheduler table at node level (the
    # paper's Tables 4-6 quantity; predicted speedups only — the simulated
    # cross-check is exercised by the example and the golden tests).
    from repro.core.bgq import MIDPLANE_DIMS, MIRA, MIRA_SCHEDULER_PARTITIONS

    t0 = time.perf_counter()
    advice = advise_policy_table(
        MIRA.midplane_dims, MIRA_SCHEDULER_PARTITIONS, unit_node_dims=MIDPLANE_DIMS
    )
    t_advise = time.perf_counter() - t0
    improved = {a.units: a.predicted_speedup for a in advice if not a.is_current_optimal}

    geometries = sum(len(cut_table(DIMS, t)) for t in SIZES)
    rows = [
        {
            "case": "optimal+worst cuboid sweep",
            "dims": list(DIMS),
            "sizes": SIZES,
            "geometries": geometries,
            "vectorized_s": round(t_fast, 5),
            "reference_s": round(t_slow, 4),
            "speedup": round(speedup, 1),
        },
        {
            "case": "partition advisor (Mira scheduler table)",
            "machine": "Mira",
            "sizes": sorted(MIRA_SCHEDULER_PARTITIONS),
            "improved": {str(k): round(v, 3) for k, v in sorted(improved.items())},
            "advise_s": round(t_advise, 4),
        },
    ]
    derived = f"speedup={speedup:.0f}x,improved_sizes={len(improved)}"
    return rows, derived


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_isoperimetry.json", help="output path")
    args = ap.parse_args()
    rows, derived = isoperimetry_microbench()
    out = Path(args.json)
    out.write_text(
        json.dumps({"benchmark": "isoperimetry_microbench", "rows": rows}, indent=1)
    )
    print(f"isoperimetry_microbench: {derived} -> {out}")


if __name__ == "__main__":
    main()
