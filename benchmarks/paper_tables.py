"""Benchmarks reproducing the paper's tables and figures.

One function per table/figure; each returns (rows, derived) where derived is
a short scalar summary asserted against the paper's published numbers.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

from repro.core.bgq import (
    JUQUEEN,
    JUQUEEN48,
    JUQUEEN54,
    MIRA,
    SEQUOIA,
    juqueen_partition_table,
    machine_design_table,
    mira_partition_table,
    node_dims_of_midplane_geometry,
    partition_bisection_links,
)
from repro.network import (
    TorusFabric,
    best_slice_geometry,
    pairing_speedup,
    predict_pairing_time,
    worst_slice_geometry,
)


def table1_6_mira() -> Tuple[List[dict], str]:
    """Tables 1 & 6 / Figure 1: Mira current vs proposed partition bisection."""
    rows = mira_partition_table()
    improved = [r for r in rows if r["proposed_bw"]]
    gains = [r["proposed_bw"] / r["current_bw"] for r in improved]
    assert len(improved) == 4 and max(gains) == 2.0
    return rows, f"improved_rows={len(improved)},max_gain={max(gains):.2f}"


def table2_7_juqueen() -> Tuple[List[dict], str]:
    """Tables 2 & 7 / Figure 2: JUQUEEN worst vs best partition bisection."""
    rows = juqueen_partition_table()
    improved = [r for r in rows if r["best_bw"]]
    assert len(improved) == 6
    assert all(r["best_bw"] / r["worst_bw"] == 2.0 for r in improved)
    # the 'spiking' ring-shaped sizes (5, 7 midplanes) have BW 256
    spikes = [r for r in rows if r["midplanes"] in (5, 7)]
    assert all(r["worst_bw"] == 256 for r in spikes)
    return rows, f"improved_rows={len(improved)},gain=2.00"


def table5_machine_design() -> Tuple[List[dict], str]:
    """Table 5 / Figure 7: hypothetical JUQUEEN-54 / JUQUEEN-48 machines."""
    rows = machine_design_table()
    r48 = next(r for r in rows if r["midplanes"] == 48)
    r54 = next(r for r in rows if r["midplanes"] == 54)
    r56 = next(r for r in rows if r["midplanes"] == 56)
    # J-48 beats JUQUEEN at 48 midplanes by 1.5x; J-54 tops at 4608
    assert r48["j48_bw"] / r48["juqueen_bw"] == 1.5
    assert r54["j54_bw"] == 4608
    max_speedup = r54["j54_bw"] / r56["juqueen_bw"]
    return rows, f"j54_max_gain={max_speedup:.2f},j48_gain_at48={1.5}"


# Paper Figure 3/4 experimental observations (avg seconds for all rounds).
# Values transcribed from the figures' reported speedup factors.
MIRA_PAIRING_CELLS = [  # (midplanes, current geom, proposed geom, observed factor)
    (4, (4, 1, 1, 1), (2, 2, 1, 1), 1.96),
    (8, (4, 2, 1, 1), (2, 2, 2, 1), 1.92),
    (16, (4, 4, 1, 1), (2, 2, 2, 2), 1.95),
    (24, (4, 3, 2, 1), (3, 2, 2, 2), 1.44),
]
JUQUEEN_PAIRING_CELLS = [
    (4, (4, 1, 1, 1), (2, 2, 1, 1), 1.92),
    (6, (6, 1, 1, 1), (3, 2, 1, 1), 1.95),
    (8, (4, 2, 1, 1), (2, 2, 2, 1), 1.93),
    (12, (6, 2, 1, 1), (3, 2, 2, 1), 1.94),
]

MESSAGE_GB = 0.1342e9
LINK_BW = 2.0e9  # GB/s per direction (Chen et al. 2012)
ROUNDS = 26


def _pairing_rows(cells) -> List[dict]:
    rows = []
    for mp, cur, prop, observed in cells:
        pred_cur = predict_pairing_time(node_dims_of_midplane_geometry(cur), MESSAGE_GB, LINK_BW)
        pred_prop = predict_pairing_time(node_dims_of_midplane_geometry(prop), MESSAGE_GB, LINK_BW)
        t_cur = pred_cur.time_per_volume * MESSAGE_GB * ROUNDS
        t_prop = pred_prop.time_per_volume * MESSAGE_GB * ROUNDS
        rows.append(
            {
                "midplanes": mp,
                "current": cur,
                "proposed": prop,
                "pred_time_current_s": round(t_cur, 2),
                "pred_time_proposed_s": round(t_prop, 2),
                "pred_speedup": round(t_cur / t_prop, 3),
                "observed_speedup": observed,
            }
        )
    return rows


def fig3_pairing_mira() -> Tuple[List[dict], str]:
    """Figure 3: bisection-pairing on Mira — predicted vs observed speedups."""
    rows = _pairing_rows(MIRA_PAIRING_CELLS)
    # 4/8/16 midplanes: predicted exactly 2.0, observed >= 1.92
    for r in rows[:3]:
        assert r["pred_speedup"] == 2.0 and r["observed_speedup"] >= 1.92
    # 24 midplanes: geometry-only prediction is 4/3; the paper's quoted 1.50
    # is the 16->24 node-count scaling at constant bisection (checked below)
    assert rows[3]["pred_speedup"] == round(4 / 3, 3)
    t16 = 16 * 512 / (2.0 * partition_bisection_links((2, 2, 2, 2)))
    t24 = 24 * 512 / (2.0 * partition_bisection_links((3, 2, 2, 2)))
    assert round(t24 / t16, 2) == 1.50
    err = max(abs(r["pred_speedup"] - r["observed_speedup"]) / r["pred_speedup"] for r in rows[:3])
    return rows, f"max_rel_err_vs_observed={err:.3f}"


def fig4_pairing_juqueen() -> Tuple[List[dict], str]:
    """Figure 4: bisection-pairing on JUQUEEN (worst vs best geometries)."""
    rows = _pairing_rows(JUQUEEN_PAIRING_CELLS)
    for r in rows:
        assert r["pred_speedup"] == 2.0 and r["observed_speedup"] >= 1.92
    # Fig 4 caption: per-node bisection identical for 4 & 8 mp, 50% worse for 6
    t4 = predict_pairing_time(node_dims_of_midplane_geometry((4, 1, 1, 1)), 1, 1)
    t6 = predict_pairing_time(node_dims_of_midplane_geometry((6, 1, 1, 1)), 1, 1)
    t8 = predict_pairing_time(node_dims_of_midplane_geometry((4, 2, 1, 1)), 1, 1)
    assert t4.time_per_volume == t8.time_per_volume
    assert abs(t6.time_per_volume / t4.time_per_volume - 1.5) < 1e-9
    return rows, "all_cells_pred=2.00,observed>=1.92"


def tpu_slice_geometry() -> Tuple[List[dict], str]:
    """Beyond-paper: the same analysis on a TPU v5e pod (16x16, wrap-on-full-
    dim semantics) and a v4-style 3D pod — the hardware adaptation table."""
    rows = []
    pod2d = TorusFabric((16, 16), (True, True))
    for chips in (8, 16, 32, 64, 128):
        best = best_slice_geometry(pod2d, chips)
        worst = worst_slice_geometry(pod2d, chips)
        rows.append(
            {
                "pod": "v5e-16x16",
                "chips": chips,
                "best_geometry": best[0],
                "best_bisection": best[1],
                "worst_geometry": worst[0],
                "worst_bisection": worst[1],
                "gain": best[1] / max(worst[1], 1),
            }
        )
    pod3d = TorusFabric((16, 16, 8), (True, True, True))
    for chips in (64, 128, 256, 512):
        best = best_slice_geometry(pod3d, chips)
        worst = worst_slice_geometry(pod3d, chips)
        rows.append(
            {
                "pod": "v4-16x16x8",
                "chips": chips,
                "best_geometry": best[0],
                "best_bisection": best[1],
                "worst_geometry": worst[0],
                "worst_bisection": worst[1],
                "gain": best[1] / max(worst[1], 1),
            }
        )
    max_gain = max(r["gain"] for r in rows)
    assert max_gain >= 2.0  # the paper's x2 appears on TPU fabrics too
    return rows, f"max_gain={max_gain:.2f}"
