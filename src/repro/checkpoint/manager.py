"""Fault-tolerant checkpointing: atomic, async, reshard-on-restore.

Layout of a checkpoint directory::

    <root>/step_000123/
        metadata.json          # step, tree structure, shapes, dtypes
        shard_000.npz ...      # leaves chunked along their first axis
    <root>/step_000123.COMMIT  # written last: marks the checkpoint complete

Properties the training runtime relies on:

* **atomicity** — a checkpoint is visible only after its COMMIT marker;
  a crash mid-save leaves no half-checkpoint that restore would pick up.
* **async** — ``save_async`` snapshots to host memory synchronously (cheap)
  and writes in a background thread, overlapping I/O with the next steps.
* **reshard-on-restore** — leaves are stored unsharded (chunked for I/O
  parallelism, the multi-host analogue of per-host files); restore places
  them under *any* target sharding/mesh, so elastic rescaling (N -> M
  chips) is a restore with a different mesh.
* **retention** — keep the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "$"


def _flatten_with_names(tree: PyTree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3, chunks: int = 4):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.chunks = chunks
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._lock = threading.Lock()

    # -- save -------------------------------------------------------------------
    def save(self, step: int, tree: PyTree) -> None:
        host = [(n, np.asarray(jax.device_get(l))) for n, l in _flatten_with_names(tree)]
        self._write(step, host)

    def save_async(self, step: int, tree: PyTree) -> Future:
        host = [(n, np.asarray(jax.device_get(l))) for n, l in _flatten_with_names(tree)]
        return self._pool.submit(self._write, step, host)

    def _write(self, step: int, host: List[Tuple[str, np.ndarray]]) -> None:
        with self._lock:
            d = self.root / f"step_{step:09d}"
            tmp = self.root / f".tmp_step_{step:09d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir()
            meta = {"step": step, "leaves": []}
            shard_payloads: List[Dict[str, np.ndarray]] = [
                {} for _ in range(self.chunks)
            ]
            for name, arr in host:
                meta["leaves"].append(
                    {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
                )
                if arr.dtype.name == "bfloat16":
                    # npz has no native bf16: widen on disk, narrow on restore
                    arr = arr.astype(np.float32)
                if arr.ndim == 0 or arr.shape[0] < self.chunks:
                    shard_payloads[0][name] = arr
                    continue
                for ci, piece in enumerate(np.array_split(arr, self.chunks, axis=0)):
                    shard_payloads[ci][f"{name}{_SEP}chunk{ci}"] = piece
            for ci, payload in enumerate(shard_payloads):
                np.savez(tmp / f"shard_{ci:03d}.npz", **payload)
            (tmp / "metadata.json").write_text(json.dumps(meta))
            if d.exists():
                shutil.rmtree(d)
            os.rename(tmp, d)
            (self.root / f"step_{step:09d}.COMMIT").touch()
            self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.root / f"step_{s:09d}", ignore_errors=True)
            (self.root / f"step_{s:09d}.COMMIT").unlink(missing_ok=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self) -> List[int]:
        steps = []
        for f in self.root.glob("step_*.COMMIT"):
            steps.append(int(f.stem.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, target: PyTree, step: Optional[int] = None, shardings: Optional[PyTree] = None
    ) -> Tuple[int, PyTree]:
        """Restore into the structure of ``target`` (shapes validated).

        ``shardings``: optional pytree of Sharding matching target; leaves
        are device_put accordingly — this is the elastic-rescale path (the
        target mesh may differ from the mesh that saved the checkpoint).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {self.root}")
        d = self.root / f"step_{step:09d}"
        raw: Dict[str, np.ndarray] = {}
        for f in sorted(d.glob("shard_*.npz")):
            with np.load(f) as z:
                for k in z.files:
                    raw[k] = z[k]
        meta = json.loads((d / "metadata.json").read_text())
        arrays: Dict[str, np.ndarray] = {}
        for leaf in meta["leaves"]:
            name = leaf["name"]
            if name in raw:
                arrays[name] = raw[name]
            else:
                pieces = [
                    raw[f"{name}{_SEP}chunk{ci}"]
                    for ci in range(self.chunks)
                    if f"{name}{_SEP}chunk{ci}" in raw
                ]
                arrays[name] = np.concatenate(pieces, axis=0)
        names = [n for n, _ in _flatten_with_names(target)]
        leaves_target = jax.tree_util.tree_leaves(target)
        treedef = jax.tree_util.tree_structure(target)
        shard_leaves = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(names)
        )
        out = []
        for name, tgt, shd in zip(names, leaves_target, shard_leaves):
            arr = arrays[name]
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(f"{name}: checkpoint shape {arr.shape} != target {tgt.shape}")
            jarr = jnp.asarray(arr).astype(tgt.dtype)  # jnp handles bf16 casts
            out.append(jax.device_put(jarr, shd) if shd is not None else jarr)
        return step, jax.tree_util.tree_unflatten(treedef, out)
