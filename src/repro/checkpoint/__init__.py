from .manager import CheckpointManager
