"""Other network topologies (paper Section 5, "Application to other topologies").

* Hypercubes  — Harper's theorem (1964): isoperimetric sets are Hamming balls
  / subcubes; a Q_d hypercube is the torus [2]^d, so the torus machinery
  applies directly (with the double-link convention disabled: hypercube
  dimension-2 "rings" are single edges).
* HyperX      — Cartesian products of cliques K_{a_1} x ... x K_{a_D};
  Lindsey's theorem (1964) solves the edge-isoperimetric problem: take
  vertices of the product cliques in order of descending clique size.
* Dragonfly   — groups of K_16 x K_6 (Cray Aries) with weighted links;
  a weighted edge-isoperimetric formulation over the group graph.

These let the allocation policies of :mod:`repro.core.allocation` run on
non-torus machines.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.network.geometry import canonical, volume


# ---------------------------------------------------------------------------
# Hypercube (Q_d): torus [2]^d with single edges.
# ---------------------------------------------------------------------------
def hypercube_cuboid_cut(d: int, subcube_dims: Sequence[int]) -> int:
    """Cut of a subcube of Q_d: each uncovered dimension contributes |S| edges."""
    s = tuple(subcube_dims) + (1,) * (d - len(tuple(subcube_dims)))
    if len(s) != d or any(x not in (1, 2) for x in s):
        raise ValueError(f"subcube dims must be 1 or 2 per dimension, got {s}")
    size = volume(s)
    return sum(size for x in s if x == 1)


def hypercube_harper_bound(d: int, t: int) -> int:
    """Exact minimum cut for |S| = t in Q_d (Harper 1964), computed by the
    subcube + greedy-completion characterization for t a sum of powers of 2:
    cut(t) = sum over binary decomposition. For t = 2^k it equals
    2^k * (d - k)."""
    if not 0 <= t <= 2 ** d:
        raise ValueError("t out of range")
    # Harper: the minimal cut is attained by taking vertices in the
    # subcube-greedy order; standard recursive formula:
    return _harper_rec(d, t)


def _harper_rec(d: int, t: int) -> int:
    if t == 0 or t == 2 ** d:
        return 0
    half = 2 ** (d - 1)
    if t <= half:
        return _harper_rec(d - 1, t) + t
    return _harper_rec(d - 1, t - half) + (2 ** d - t)


def hypercube_bisection(d: int) -> int:
    return 2 ** (d - 1)


# ---------------------------------------------------------------------------
# HyperX: Cartesian product of cliques.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class HyperX:
    clique_sizes: Tuple[int, ...]  # a_1 >= a_2 >= ... (canonical)
    link_capacity: float = 1.0  # regular HyperX

    def __init__(self, clique_sizes: Sequence[int], link_capacity: float = 1.0):
        object.__setattr__(self, "clique_sizes", canonical(clique_sizes))
        object.__setattr__(self, "link_capacity", float(link_capacity))

    @property
    def num_vertices(self) -> int:
        return volume(self.clique_sizes)

    def cuboid_cut(self, sub: Sequence[int]) -> int:
        """Cut of a sub-product choosing s_i vertices from clique i.

        Each partially-covered clique dimension contributes, per line,
        s_i * (a_i - s_i) clique edges.
        """
        a = self.clique_sizes
        s = canonical(sub)
        s = s + (1,) * (len(a) - len(s))
        size = volume(s)
        best = None
        for perm in set(itertools.permutations(s)):
            if any(x > y for x, y in zip(perm, a)):
                continue
            cut = sum(
                (size // si) * si * (ai - si)  # lines * per-line cut
                for si, ai in zip(perm, a)
                if si != ai
            )
            best = cut if best is None else min(best, cut)
        if best is None:
            raise ValueError(f"{s} does not fit in HyperX {a}")
        return best

    def lindsey_optimal_cut(self, t: int) -> int:
        """Exact isoperimetric optimum (Lindsey 1964): take vertices of the
        product cliques in order of descending size (paper Section 5) — i.e.
        lexicographic order with the *largest* clique varying fastest, so
        whole copies of the biggest cliques are filled first.  The recursion
        therefore peels the smallest clique as the outermost coordinate."""
        a = tuple(sorted(self.clique_sizes))  # ascending: smallest outermost
        n = self.num_vertices
        if not 0 <= t <= n:
            raise ValueError("t out of range")
        if t in (0, n):
            return 0
        # cut(prefix of size t in lex order) computed recursively: let the
        # first coordinate (largest clique, size a1) split lex order into a1
        # consecutive blocks of size n/a1.
        def rec(sizes: Tuple[int, ...], t: int) -> int:
            if t == 0 or not sizes:
                return 0
            a1 = sizes[0]
            block = math.prod(sizes[1:]) if len(sizes) > 1 else 1
            q, rem = divmod(t, block)
            # q fully-chosen levels of the outermost (smallest) clique, one
            # partially-chosen level of size rem, u fully-unchosen levels.
            u = a1 - q - (1 if rem else 0)
            # dim-1 clique edges join equal suffixes across levels:
            cut = q * block * u  # full levels <-> fully-unchosen levels
            if rem:
                cut += q * (block - rem)  # full levels <-> partial level's unchosen part
                cut += rem * u  # partial level's chosen part <-> unchosen levels
                cut += rec(sizes[1:], rem)  # edges inside the partial level
            return cut

        return rec(a, t)

    def bisection_links(self) -> int:
        return self.lindsey_optimal_cut(self.num_vertices // 2)

    def best_subproduct(self, t: int) -> Optional[Tuple[Tuple[int, ...], int]]:
        """Minimum-cut sub-product of size t (allocation-friendly partitions)."""
        from repro.network.geometry import factorizations

        best = None
        for s in set(factorizations(t, len(self.clique_sizes))):
            try:
                cut = self.cuboid_cut(s)
            except ValueError:
                continue
            if best is None or cut < best[1]:
                best = (s, cut)
        return best


# ---------------------------------------------------------------------------
# Dragonfly (Cray Aries): weighted K_16 x K_6 groups.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class DragonflyGroup:
    """One Aries group: K_16 x K_6 with K_6 links 3x the K_16 capacity."""

    a: int = 16
    b: int = 6
    w_a: float = 1.0
    w_b: float = 3.0

    @property
    def num_routers(self) -> int:
        return self.a * self.b

    def weighted_cut(self, sa: int, sb: int) -> float:
        """Weighted cut of a sub-product of sa x sb routers."""
        if not (0 < sa <= self.a and 0 < sb <= self.b):
            raise ValueError("sub-product out of range")
        size = sa * sb
        cut = 0.0
        if sa < self.a:
            cut += (size / sa) * sa * (self.a - sa) * self.w_a
        if sb < self.b:
            cut += (size / sb) * sb * (self.b - sb) * self.w_b
        return cut

    def best_subgroup(self, t: int) -> Optional[Tuple[Tuple[int, int], float]]:
        best = None
        for sa in range(1, self.a + 1):
            if t % sa:
                continue
            sb = t // sa
            if sb > self.b:
                continue
            cut = self.weighted_cut(sa, sb)
            if best is None or cut < best[1]:
                best = ((sa, sb), cut)
        return best
