"""Strassen-Winograd fast matrix multiplication (paper Experiment B kernel).

The paper benchmarks the communication-avoiding parallel Strassen (CAPS) of
Ballard/Lipshitz et al. on Mira partitions.  Here:

* ``strassen_winograd`` — the sequential Strassen-Winograd recursion in JAX
  (7 multiplies, 15 additions per level), validated against ``jnp.dot``;
  this is the per-node compute kernel.
* ``caps_comm_model``   — the partition-aware communication model for the
  BFS/DFS parallel execution: a fraction ``phi`` of the traffic is
  bisection-bound (crosses the partition bisection), the rest is
  injection-bound.  The predicted current/proposed comm-time ratio on a
  partition pair with bisection ratio r is  (1 - phi) + phi * r  — the
  paper's measured x1.37–x1.52 for r = 2 corresponds to phi in [0.37, 0.52].
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp


def strassen_winograd(a: jax.Array, b: jax.Array, depth: int = 1) -> jax.Array:
    """Strassen-Winograd recursion to the given depth (then jnp.dot)."""
    if depth == 0:
        return a @ b
    n, m = a.shape
    p = b.shape[1]
    assert n % 2 == 0 and m % 2 == 0 and p % 2 == 0, "even dims required per level"
    a11, a12 = a[: n // 2, : m // 2], a[: n // 2, m // 2 :]
    a21, a22 = a[n // 2 :, : m // 2], a[n // 2 :, m // 2 :]
    b11, b12 = b[: m // 2, : p // 2], b[: m // 2, p // 2 :]
    b21, b22 = b[m // 2 :, : p // 2], b[m // 2 :, p // 2 :]

    s1 = a21 + a22
    s2 = s1 - a11
    s3 = a11 - a21
    s4 = a12 - s2
    t1 = b12 - b11
    t2 = b22 - t1
    t3 = b22 - b12
    t4 = t2 - b21

    rec = lambda x, y: strassen_winograd(x, y, depth - 1)
    m1 = rec(a11, b11)
    m2 = rec(a12, b21)
    m3 = rec(s4, b22)
    m4 = rec(a22, t4)
    m5 = rec(s1, t1)
    m6 = rec(s2, t2)
    m7 = rec(s3, t3)

    u1 = m1 + m2  # C11
    u2 = m1 + m6
    u3 = u2 + m7
    u4 = u2 + m5
    c12 = u4 + m3
    c21 = u3 - m4
    c22 = u3 + m5
    return jnp.concatenate(
        [jnp.concatenate([u1, c12], axis=1), jnp.concatenate([c21, c22], axis=1)],
        axis=0,
    )


def strassen_flops(n: int, depth: int) -> float:
    """FLOPs of depth-k Strassen on n x n (7^k multiplies of (n/2^k)^3)."""
    base = n // (2 ** depth)
    return 7 ** depth * 2.0 * base ** 3 + 15 * sum(
        7 ** i * 2 * (n // 2 ** (i + 1)) ** 2 for i in range(depth)
    )


# ---------------------------------------------------------------------------
# CAPS communication model on partitions
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CapsPrediction:
    midplanes: int
    bisection_ratio: float  # proposed / current
    comm_ratio: float  # T_comm(current) / T_comm(proposed)
    wallclock_ratio: float


def caps_comm_model(
    cells: List[Tuple[int, int, int]],  # (midplanes, current_bis, proposed_bis)
    phi: float = 0.45,
    comm_over_comp: float = 0.5,
) -> List[CapsPrediction]:
    """Predicted comm / wallclock ratios between partition geometries.

    ``phi``: bisection-bound traffic fraction of CAPS on these partitions
    (0.45 sits mid-band of the paper's measurements).  ``comm_over_comp``:
    unhidden communication time over computation time on the *proposed*
    partition (sets the wallclock dilution).
    """
    out = []
    for mp, cur, prop in cells:
        r = prop / cur
        comm_ratio = (1 - phi) + phi * r
        # wallclock = comp + comm; comm on proposed = comm_over_comp * comp
        comp = 1.0
        comm_prop = comm_over_comp
        comm_cur = comm_prop * comm_ratio
        wall = (comp + comm_cur) / (comp + comm_prop)
        out.append(CapsPrediction(mp, r, comm_ratio, wall))
    return out
