"""Deprecated shim — allocation policies now live in :mod:`repro.network`.

Re-exports ``repro.network.allocation``.  Existing imports keep working; new
code should import from ``repro.network`` directly.  See DESIGN.md.
"""

from __future__ import annotations

import warnings

# One-shot by module caching: Python executes this module (and hence the
# warning) once per process, however many times it is imported.
warnings.warn(
    "repro.core.allocation is a deprecated re-export shim; import from "
    "repro.network instead (see DESIGN.md)",
    DeprecationWarning,
    stacklevel=2,
)

from repro.network.allocation import (  # noqa: F401,E402
    AllocationPolicy,
    ContentionScoredPolicy,
    ElongatedPolicy,
    HintedPolicy,
    IsoperimetricPolicy,
    JobRequest,
    ListPolicy,
    MachineState,
    Placement,
    ScheduledJob,
    SimulationResult,
    avoidable_contention_ratio,
    simulate_queue,
)

__all__ = [
    "AllocationPolicy",
    "ContentionScoredPolicy",
    "ElongatedPolicy",
    "HintedPolicy",
    "IsoperimetricPolicy",
    "JobRequest",
    "ListPolicy",
    "MachineState",
    "Placement",
    "ScheduledJob",
    "SimulationResult",
    "avoidable_contention_ratio",
    "simulate_queue",
]
