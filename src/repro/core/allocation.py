"""Deprecated shim — allocation policies now live in :mod:`repro.network`.

Re-exports ``repro.network.allocation``.  Existing imports keep working; new
code should import from ``repro.network`` directly.  See DESIGN.md.
"""

from __future__ import annotations

from repro.network.allocation import (  # noqa: F401
    AllocationPolicy,
    ContentionScoredPolicy,
    ElongatedPolicy,
    HintedPolicy,
    IsoperimetricPolicy,
    JobRequest,
    ListPolicy,
    MachineState,
    Placement,
    ScheduledJob,
    SimulationResult,
    avoidable_contention_ratio,
    simulate_queue,
)

__all__ = [
    "AllocationPolicy",
    "ContentionScoredPolicy",
    "ElongatedPolicy",
    "HintedPolicy",
    "IsoperimetricPolicy",
    "JobRequest",
    "ListPolicy",
    "MachineState",
    "Placement",
    "ScheduledJob",
    "SimulationResult",
    "avoidable_contention_ratio",
    "simulate_queue",
]
