"""Deprecated shim — torus geometry now lives in :mod:`repro.network`.

This module re-exports the historical ``repro.core.torus`` API from its new
homes (``repro.network.geometry`` for the pure geometry primitives,
``repro.network.fabric`` for the :class:`Torus` wrapper).  Existing imports
keep working; new code should import from ``repro.network`` directly.
See DESIGN.md for the deprecation path.
"""

from __future__ import annotations

import warnings

# One-shot by module caching: Python executes this module (and hence the
# warning) once per process, however many times it is imported.
warnings.warn(
    "repro.core.torus is a deprecated re-export shim; import from "
    "repro.network instead (see DESIGN.md)",
    DeprecationWarning,
    stacklevel=2,
)

from repro.network.geometry import (  # noqa: F401,E402
    ExplicitTorus,
    Geometry,
    all_divisor_geometries,
    canonical,
    degree_contribution,
    enumerate_vertices,
    factorizations,
    volume,
)
from repro.network.fabric import Torus  # noqa: F401

__all__ = [
    "ExplicitTorus",
    "Geometry",
    "Torus",
    "all_divisor_geometries",
    "canonical",
    "degree_contribution",
    "enumerate_vertices",
    "factorizations",
    "volume",
]
