"""Torus network graphs and cuboid partition geometry.

This module models D-dimensional torus networks (the Blue Gene/Q 5D torus,
TPU 2D/3D ICI tori, ...) and the cuboid sub-torus partitions that processor
allocation policies carve out of them.  It provides exact edge counting for
cuboid subsets — the primitive underlying the edge-isoperimetric analysis of
Oltchik & Schwartz, "Network Partitioning and Avoidable Contention" (2020).

Conventions
-----------
* A torus is described by its dimension lengths ``dims = (a_1, ..., a_D)``.
* Geometries are canonicalised in *sorted descending* order, matching the
  paper's canonical representation (partitions identical up to rotation are
  treated as one).
* A dimension of length 2 is a *double link*: both the +1 and -1 neighbour
  coincide, contributing two parallel edges.  This matches the physical
  Blue Gene/Q construction and the edge-counting in the paper.
* Dimensions of length 1 contribute no edges (self-loops are excluded).
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Iterable, Iterator, Sequence, Tuple

Geometry = Tuple[int, ...]


def canonical(dims: Iterable[int]) -> Geometry:
    """Sorted-descending canonical form of a torus/cuboid geometry."""
    out = tuple(sorted((int(d) for d in dims), reverse=True))
    if any(d < 1 for d in out):
        raise ValueError(f"dimension lengths must be >= 1, got {out}")
    return out


def volume(dims: Iterable[int]) -> int:
    return math.prod(dims)


def degree_contribution(length: int) -> int:
    """Edges incident to a vertex along one torus dimension of given length."""
    if length == 1:
        return 0
    return 2  # length==2 is a double link; still two edge-endpoints per vertex.


@dataclass(frozen=True)
class Torus:
    """A D-dimensional torus graph with arbitrary dimension lengths."""

    dims: Geometry

    def __init__(self, dims: Iterable[int]):
        object.__setattr__(self, "dims", canonical(dims))

    # -- basic graph quantities ------------------------------------------------
    @property
    def D(self) -> int:
        return len(self.dims)

    @property
    def num_vertices(self) -> int:
        return volume(self.dims)

    @property
    def degree(self) -> int:
        return sum(degree_contribution(a) for a in self.dims)

    @property
    def num_edges(self) -> int:
        # Each dimension of length a>2 contributes a ring of `a` edges per line;
        # length 2 contributes a double edge (2 edges) per line; length 1 none.
        total = 0
        n = self.num_vertices
        for a in self.dims:
            if a == 1:
                continue
            lines = n // a
            edges_per_line = a if a > 2 else 2
            total += lines * edges_per_line
        return total

    # -- cuboid subsets ---------------------------------------------------------
    def contains_cuboid(self, cuboid: Sequence[int]) -> bool:
        """Whether a cuboid geometry fits in this torus (up to rotation)."""
        c = canonical(cuboid)
        if len(c) > self.D:
            return False
        c = c + (1,) * (self.D - len(c))
        # Greedy matching on sorted-descending lists is exact here: match the
        # largest cuboid side to the smallest torus side that still fits.
        avail = list(self.dims)
        for side in c:
            candidates = [i for i, a in enumerate(avail) if a >= side]
            if not candidates:
                return False
            # Use the tightest fit to keep larger torus dims free.
            best = min(candidates, key=lambda i: avail[i])
            avail.pop(best)
        return True

    def cuboid_cut(self, cuboid: Sequence[int]) -> int:
        """|E(S, S̄)| for a cuboid subset S, counting double links for a_i == 2.

        A cuboid side s_i embedded in torus dimension a_i contributes:
          * 0 edges if s_i == a_i (the dimension is fully covered; wrap-around
            links are internal),
          * 2 * |S| / s_i edges otherwise (one +face and one -face, which is
            also exact for s_i == 1 whether or not a_i == 2, by the
            double-link convention).

        The cut depends on which torus dimension each side is embedded in
        (only via full coverage); we return the minimum over all feasible
        embeddings, which is the cut of the canonical geometry.
        """
        c = list(canonical(cuboid))
        if len(c) > self.D:
            raise ValueError(f"cuboid {c} has more dims than torus {self.dims}")
        c = c + [1] * (self.D - len(c))
        if not self.contains_cuboid(c):
            raise ValueError(f"cuboid {tuple(c)} does not fit in torus {self.dims}")
        size = volume(c)
        best = None
        for perm in set(itertools.permutations(c)):
            if any(s > a for s, a in zip(perm, self.dims)):
                continue
            cut = sum(2 * size // s for s, a in zip(perm, self.dims) if s != a)
            best = cut if best is None else min(best, cut)
        assert best is not None
        return best

    def cuboid_cut_aligned(self, sides: Sequence[int]) -> int:
        """Cut of a cuboid with side i embedded along torus dimension i
        (no canonicalisation — for validation against explicit placements)."""
        s = tuple(sides) + (1,) * (self.D - len(tuple(sides)))
        if any(x > a for x, a in zip(s, self.dims)):
            raise ValueError(f"aligned cuboid {s} does not fit in {self.dims}")
        size = volume(s)
        return sum(2 * size // x for x, a in zip(s, self.dims) if x != a)

    def _assign(self, cuboid_sides: Sequence[int]) -> list[tuple[int, int]]:
        """Match each cuboid side to a torus dimension (tightest fit)."""
        avail = list(self.dims)
        out = []
        for side in sorted(cuboid_sides, reverse=True):
            candidates = [i for i, a in enumerate(avail) if a >= side]
            if not candidates:
                raise ValueError(f"cuboid {cuboid_sides} does not fit in {self.dims}")
            best = min(candidates, key=lambda i: avail[i])
            out.append((side, avail.pop(best)))
        return out

    def cuboid_interior(self, cuboid: Sequence[int]) -> int:
        """|E(S, S)| for a cuboid subset, via the regularity identity (Eq. 1):
        k*|S| = 2|E(S,S)| + |E(S, S̄)| for a k-regular graph."""
        c = canonical(tuple(cuboid) + (1,) * (self.D - len(tuple(cuboid))))
        size = volume(c)
        k = self.degree
        cut = self.cuboid_cut(c)
        twice_interior = k * size - cut
        assert twice_interior % 2 == 0
        return twice_interior // 2

    # -- enumeration -------------------------------------------------------------
    def sub_cuboids(self, size: int) -> Iterator[Geometry]:
        """All canonical cuboid geometries of a given vertex count that fit."""
        seen = set()
        for c in factorizations(size, self.D):
            if c in seen:
                continue
            seen.add(c)
            if self.contains_cuboid(c):
                yield c

    def bisection_links(self) -> int:
        """Internal bisection bandwidth of this torus in links (capacity 1).

        By the edge-isoperimetric bound the minimum bisection of a torus with
        an even-length longest dimension is attained by halving the longest
        dimension: 2 * N / L links (the paper's Blue Gene/Q formula).
        For an odd longest dimension we take floor(N/2)-sized near-halves and
        search cuboids exactly.
        """
        n = self.num_vertices
        if n == 1:
            return 0
        L = self.dims[0]
        if L % 2 == 0:
            return 2 * n // L
        if L == 1:
            return 0
        # Odd longest dimension: exact search over cuboids of size floor(n/2),
        # falling back to the analytic bound when no cuboid has that size.
        target = n // 2
        best = None
        for c in self.sub_cuboids(target):
            cut = self.cuboid_cut(c)
            best = cut if best is None else min(best, cut)
        if best is None:
            # No cuboid of size exactly floor(n/2) exists; use the analytic
            # isoperimetric lower bound (conservative for reporting).
            from .isoperimetry import theorem31_bound  # local import, no cycle at module load

            best = math.ceil(theorem31_bound(self.dims, target))
        return best


def factorizations(n: int, max_parts: int) -> Iterator[Geometry]:
    """All multisets of <= max_parts integers >= 1 whose product is n.

    Yields canonical (sorted descending) tuples padded to max_parts with 1s.
    """

    def rec(remaining: int, max_factor: int, parts: Tuple[int, ...]) -> Iterator[Tuple[int, ...]]:
        if len(parts) == max_parts:
            if remaining == 1:
                yield parts
            return
        # next factor f <= max_factor, f divides remaining
        for f in range(min(remaining, max_factor), 0, -1):
            if remaining % f == 0:
                yield from rec(remaining // f, f, parts + (f,))

    for combo in rec(n, n, ()):  # descending by construction
        yield combo


def all_divisor_geometries(n: int, D: int) -> list[Geometry]:
    return sorted(set(factorizations(n, D)), reverse=True)


def enumerate_vertices(dims: Sequence[int]) -> Iterator[Tuple[int, ...]]:
    yield from itertools.product(*(range(a) for a in dims))


@dataclass
class ExplicitTorus:
    """Small explicit torus used for brute-force validation in tests.

    Unlike :class:`Torus`, this builds vertex/edge sets explicitly, so that
    cut counting for *arbitrary* (non-cuboid) subsets can be cross-checked.
    Multi-edges for length-2 dimensions are honoured.
    """

    dims: Tuple[int, ...]
    _edges: list[tuple[Tuple[int, ...], Tuple[int, ...]]] = field(default_factory=list)

    def __post_init__(self):
        self.dims = tuple(int(d) for d in self.dims)
        edges = []
        for v in enumerate_vertices(self.dims):
            for k, a in enumerate(self.dims):
                if a == 1:
                    continue
                w = list(v)
                w[k] = (v[k] + 1) % a
                w = tuple(w)
                edges.append((v, w))
                if a == 2 and v[k] == 0:
                    edges.append((v, w))  # double link
        # every undirected edge appended once per +1 step; for a>2 this counts
        # each ring edge exactly once, for a==2 the pair (0,1) gets two edges.
        if any(a == 2 for a in self.dims):
            # For a==2 dims: v[k]=0 appends (0->1) twice, v[k]=1 appends (1->0)
            # once == duplicate of (0,1). Filter: keep edges from v[k]<w[k] side.
            filt = []
            for (v, w) in edges:
                ks = [k for k in range(len(self.dims)) if v[k] != w[k]]
                k = ks[0]
                if self.dims[k] == 2 and v[k] != 0:
                    continue
                filt.append((v, w))
            edges = filt
        self._edges = edges

    @property
    def num_vertices(self) -> int:
        return volume(self.dims)

    @property
    def num_edges(self) -> int:
        return len(self._edges)

    def cut(self, subset: Iterable[Tuple[int, ...]]) -> int:
        s = set(subset)
        return sum(1 for (v, w) in self._edges if (v in s) != (w in s))

    def interior(self, subset: Iterable[Tuple[int, ...]]) -> int:
        s = set(subset)
        return sum(1 for (v, w) in self._edges if v in s and w in s)

    def cuboid_vertices(self, cuboid: Sequence[int]) -> list[Tuple[int, ...]]:
        c = tuple(cuboid) + (1,) * (len(self.dims) - len(tuple(cuboid)))
        # place cuboid at origin, side i along dim i (caller aligns sides)
        for side, a in zip(c, self.dims):
            if side > a:
                raise ValueError(f"{c} does not fit in {self.dims} as aligned")
        return list(itertools.product(*(range(s) for s in c)))
