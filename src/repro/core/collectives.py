"""Deprecated shim — fabric + collective models now live in :mod:`repro.network`.

The unified :class:`TorusFabric` (per-dimension wrap flags, BG/Q double-link
vs TPU single-link conventions) is ``repro.network.fabric``; the collective
cost model and axis assignment are ``repro.network.collectives``.  Existing
imports keep working; new code should import from ``repro.network``
directly.  See DESIGN.md.
"""

from __future__ import annotations

import warnings

# One-shot by module caching: Python executes this module (and hence the
# warning) once per process, however many times it is imported.
warnings.warn(
    "repro.core.collectives is a deprecated re-export shim; import from "
    "repro.network instead (see DESIGN.md)",
    DeprecationWarning,
    stacklevel=2,
)

from repro.network.fabric import (  # noqa: F401,E402
    DEFAULT_LINK_BW,
    POD_DCI_BW,
    TorusFabric,
    best_slice_geometry,
    slice_fabric,
    worst_slice_geometry,
)
from repro.network.collectives import (  # noqa: F401
    COLLECTIVE_TIME,
    AxisAssignment,
    AxisEmbedding,
    CollectiveCostModel,
    assign_axes,
    collective_permute_time,
    ring_all_gather_time,
    ring_all_reduce_time,
    ring_all_to_all_time,
    ring_reduce_scatter_time,
)

__all__ = [
    "COLLECTIVE_TIME",
    "DEFAULT_LINK_BW",
    "POD_DCI_BW",
    "AxisAssignment",
    "AxisEmbedding",
    "CollectiveCostModel",
    "TorusFabric",
    "assign_axes",
    "best_slice_geometry",
    "collective_permute_time",
    "ring_all_gather_time",
    "ring_all_reduce_time",
    "ring_all_to_all_time",
    "ring_reduce_scatter_time",
    "slice_fabric",
    "worst_slice_geometry",
]
