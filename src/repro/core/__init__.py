"""repro.core — Network Partitioning and Avoidable Contention (Oltchik &
Schwartz, 2020) as a composable library.

Layers:
  torus / isoperimetry  — the edge-isoperimetric analysis (Theorem 3.1).
  bgq                   — Blue Gene/Q machine models (paper reproduction).
  contention            — link-level DOR routing / contention predictions.
  collectives           — TPU-adapted collective cost model + axis assignment.
  allocation            — partition allocation policies and queue simulator.
  topology              — hypercube / HyperX / Dragonfly (paper Section 5).
"""

from .torus import Torus, canonical, volume, factorizations
from .isoperimetry import (
    bollobas_leader_bound,
    theorem31_bound,
    lemma32_cut,
    optimal_cuboid,
    worst_cuboid,
    small_set_expansion,
)
from .bgq import (
    MIRA,
    JUQUEEN,
    SEQUOIA,
    JUQUEEN48,
    JUQUEEN54,
    MACHINES,
    BlueGeneQ,
    partition_bisection_links,
    mira_partition_table,
    juqueen_partition_table,
    machine_design_table,
)
from .contention import (
    LinkLoads,
    predict_pairing_time,
    pairing_speedup,
    uniform_offset_max_load,
    furthest_offset,
)
from .collectives import (
    TorusFabric,
    slice_fabric,
    best_slice_geometry,
    worst_slice_geometry,
    assign_axes,
    CollectiveCostModel,
    AxisEmbedding,
)
from .allocation import (
    JobRequest,
    MachineState,
    ElongatedPolicy,
    IsoperimetricPolicy,
    ListPolicy,
    HintedPolicy,
    simulate_queue,
    avoidable_contention_ratio,
)
