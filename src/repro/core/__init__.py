"""repro.core — Network Partitioning and Avoidable Contention (Oltchik &
Schwartz, 2020) as a composable library.

Layers:
  bgq                   — Blue Gene/Q machine models (paper reproduction).
  topology              — hypercube / HyperX / Dragonfly (paper Section 5).

The fabric modeling that used to live here (torus geometry, DOR contention,
collective cost model, allocation policies, and now the edge-isoperimetric
analysis) moved to :mod:`repro.network`; the
``repro.core.{torus,contention,collectives,allocation,isoperimetry}``
modules are deprecated re-export shims (see DESIGN.md).  This package's
namespace keeps exporting the historical names.
"""

from repro.network import (
    Torus,
    canonical,
    volume,
    factorizations,
    LinkLoads,
    predict_pairing_time,
    pairing_speedup,
    uniform_offset_max_load,
    furthest_offset,
    TorusFabric,
    slice_fabric,
    best_slice_geometry,
    worst_slice_geometry,
    assign_axes,
    CollectiveCostModel,
    AxisEmbedding,
    JobRequest,
    MachineState,
    ElongatedPolicy,
    IsoperimetricPolicy,
    ListPolicy,
    HintedPolicy,
    simulate_queue,
    avoidable_contention_ratio,
)
# Imported from the new home directly (not via the repro.core.isoperimetry
# shim) so that `import repro.core` stays DeprecationWarning-clean.
from repro.network.isoperimetry import (
    bollobas_leader_bound,
    theorem31_bound,
    lemma32_cut,
    optimal_cuboid,
    worst_cuboid,
    small_set_expansion,
)
from .bgq import (
    MIRA,
    JUQUEEN,
    SEQUOIA,
    JUQUEEN48,
    JUQUEEN54,
    MACHINES,
    BlueGeneQ,
    partition_bisection_links,
    mira_partition_table,
    juqueen_partition_table,
    machine_design_table,
)
