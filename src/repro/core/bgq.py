"""IBM Blue Gene/Q machine models (paper Section 2 & 3.2).

A Blue Gene/Q system is a 5D torus of compute nodes whose 5th dimension has
length 2 and is internal to each *midplane* (a 4x4x4x4x2 block of 512 nodes).
Partitions are cuboids of whole midplanes and — crucially for the paper's
analysis — retain wrap-around links in every dimension even when they do not
span the full machine, so a partition of midplane geometry (m1, m2, m3, m4)
is itself a torus with node dimensions (4*m1, 4*m2, 4*m3, 4*m4, 2).

Bisection bandwidth of a Blue Gene/Q (sub-)torus is 2 * N / L * B where N is
the node count, L the longest node dimension and B the per-link capacity
(Chen et al. 2012).  All tables report normalized capacity B = 1.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.network.fabric import Torus
from repro.network.geometry import Geometry, canonical, factorizations, volume

MIDPLANE_DIMS: Geometry = (4, 4, 4, 4, 2)
MIDPLANE_NODES: int = volume(MIDPLANE_DIMS)  # 512
LINK_BANDWIDTH_GB_S: float = 2.0  # GB/s per direction per link (Chen et al. 2012)


def node_dims_of_midplane_geometry(midplanes: Sequence[int]) -> Geometry:
    """Node-level torus dims of a midplane cuboid (4x per dim, plus the
    internal 5th dimension of length 2)."""
    m = canonical(midplanes)
    if len(m) != 4:
        raise ValueError(f"midplane geometry must be 4-dimensional, got {m}")
    return canonical(tuple(4 * d for d in m) + (2,))


def partition_bisection_links(midplanes: Sequence[int]) -> int:
    """Internal bisection (links, capacity 1) of a midplane-cuboid partition."""
    return Torus(node_dims_of_midplane_geometry(midplanes)).bisection_links()


@dataclass(frozen=True)
class BlueGeneQ:
    """A Blue Gene/Q machine: a 4D torus of midplanes."""

    name: str
    midplane_dims: Geometry

    def __init__(self, name: str, midplane_dims: Sequence[int]):
        object.__setattr__(self, "name", name)
        object.__setattr__(self, "midplane_dims", canonical(midplane_dims))
        if len(self.midplane_dims) != 4:
            raise ValueError("Blue Gene/Q midplane torus is 4-dimensional")

    @property
    def num_midplanes(self) -> int:
        return volume(self.midplane_dims)

    @property
    def num_nodes(self) -> int:
        return self.num_midplanes * MIDPLANE_NODES

    @property
    def node_dims(self) -> Geometry:
        return node_dims_of_midplane_geometry(self.midplane_dims)

    @property
    def midplane_torus(self) -> Torus:
        return Torus(self.midplane_dims)

    @property
    def node_torus(self) -> Torus:
        return Torus(self.node_dims)

    def machine_bisection_links(self) -> int:
        return self.node_torus.bisection_links()

    # -- partitions ------------------------------------------------------------
    def partition_geometries(self, num_midplanes: int) -> List[Geometry]:
        """All canonical midplane-cuboid geometries of a given midplane count
        that fit inside the machine."""
        return sorted(self.midplane_torus.sub_cuboids(num_midplanes), reverse=True)

    def partition_sizes(self) -> List[int]:
        """All midplane counts for which at least one cuboid partition exists."""
        return [
            m
            for m in range(1, self.num_midplanes + 1)
            if any(True for _ in self.midplane_torus.sub_cuboids(m))
        ]

    def best_partition(self, num_midplanes: int) -> Optional[Tuple[Geometry, int]]:
        """Geometry with maximal internal bisection bandwidth (links)."""
        best: Optional[Tuple[Geometry, int]] = None
        for g in self.partition_geometries(num_midplanes):
            bw = partition_bisection_links(g)
            if best is None or bw > best[1] or (bw == best[1] and g < best[0]):
                best = (g, bw)
        return best

    def worst_partition(self, num_midplanes: int) -> Optional[Tuple[Geometry, int]]:
        """Geometry with minimal internal bisection bandwidth (links)."""
        worst: Optional[Tuple[Geometry, int]] = None
        for g in self.partition_geometries(num_midplanes):
            bw = partition_bisection_links(g)
            if worst is None or bw < worst[1] or (bw == worst[1] and g > worst[0]):
                worst = (g, bw)
        return worst


# ---------------------------------------------------------------------------
# The machines studied in the paper.
# ---------------------------------------------------------------------------
MIRA = BlueGeneQ("Mira", (4, 4, 3, 2))           # 49152 nodes, 16x16x12x8x2
JUQUEEN = BlueGeneQ("JUQUEEN", (7, 2, 2, 2))     # 28672 nodes, 28x8x8x8x2
SEQUOIA = BlueGeneQ("Sequoia", (4, 4, 4, 3))     # 98304 nodes, 16x16x16x12x2
# Hypothetical machines from Section 5 ("Machine design"):
JUQUEEN54 = BlueGeneQ("JUQUEEN-54", (3, 3, 3, 2))
JUQUEEN48 = BlueGeneQ("JUQUEEN-48", (4, 3, 2, 2))

MACHINES: Dict[str, BlueGeneQ] = {
    m.name: m for m in (MIRA, JUQUEEN, SEQUOIA, JUQUEEN54, JUQUEEN48)
}

# Mira's scheduler exposes a fixed list of partition geometries (paper
# Table 6, "Current Geometry"), keyed by midplane count.
MIRA_SCHEDULER_PARTITIONS: Dict[int, Geometry] = {
    1: (1, 1, 1, 1),
    2: (2, 1, 1, 1),
    4: (4, 1, 1, 1),
    8: (4, 2, 1, 1),
    16: (4, 4, 1, 1),
    24: (4, 3, 2, 1),
    32: (4, 4, 2, 1),
    48: (4, 4, 3, 1),
    64: (4, 4, 2, 2),
    96: (4, 4, 3, 2),
}

# The geometries proposed in the paper where an improvement exists (Table 1).
MIRA_PROPOSED_PARTITIONS: Dict[int, Geometry] = {
    4: (2, 2, 1, 1),
    8: (2, 2, 2, 1),
    16: (2, 2, 2, 2),
    24: (3, 2, 2, 2),
}


def mira_partition_table() -> List[dict]:
    """Reproduces paper Table 6 (and its improved-rows subset, Table 1)."""
    rows = []
    for mp, current in sorted(MIRA_SCHEDULER_PARTITIONS.items()):
        current_bw = partition_bisection_links(current)
        best = MIRA.best_partition(mp)
        assert best is not None
        proposed: Optional[Geometry] = None
        proposed_bw: Optional[int] = None
        if best[1] > current_bw:
            proposed, proposed_bw = best
        rows.append(
            {
                "nodes": mp * MIDPLANE_NODES,
                "midplanes": mp,
                "current_geometry": current,
                "current_bw": current_bw,
                "proposed_geometry": proposed,
                "proposed_bw": proposed_bw,
            }
        )
    return rows


def juqueen_partition_table(machine: BlueGeneQ = JUQUEEN) -> List[dict]:
    """Reproduces paper Table 7: best and worst geometry per midplane count."""
    rows = []
    for mp in machine.partition_sizes():
        worst = machine.worst_partition(mp)
        best = machine.best_partition(mp)
        assert worst is not None and best is not None
        rows.append(
            {
                "nodes": mp * MIDPLANE_NODES,
                "midplanes": mp,
                "worst_geometry": worst[0],
                "worst_bw": worst[1],
                "best_geometry": best[0] if best[1] > worst[1] else None,
                "best_bw": best[1] if best[1] > worst[1] else None,
            }
        )
    return rows


def machine_design_table() -> List[dict]:
    """Reproduces paper Table 5: best-case partitions of JUQUEEN vs the
    hypothetical JUQUEEN-54 and JUQUEEN-48."""
    rows: Dict[int, dict] = {}
    for machine, key in ((JUQUEEN, "juqueen"), (JUQUEEN54, "j54"), (JUQUEEN48, "j48")):
        for mp in machine.partition_sizes():
            best = machine.best_partition(mp)
            assert best is not None
            row = rows.setdefault(
                mp, {"nodes": mp * MIDPLANE_NODES, "midplanes": mp}
            )
            row[f"{key}_geometry"] = best[0]
            row[f"{key}_bw"] = best[1]
    return [rows[mp] for mp in sorted(rows)]
