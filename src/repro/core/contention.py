"""Deprecated shim — the contention model now lives in :mod:`repro.network`.

The link-load engine is ``repro.network.routing`` (vectorized; the old
per-hop walker survives only as a test reference under
``tests/reference_dor.py``) and the traffic builders are
``repro.network.patterns``.  Existing imports keep working; new code should
import from ``repro.network`` directly.  See DESIGN.md.
"""

from __future__ import annotations

import warnings

# One-shot by module caching: Python executes this module (and hence the
# warning) once per process, however many times it is imported.
warnings.warn(
    "repro.core.contention is a deprecated re-export shim; import from "
    "repro.network instead (see DESIGN.md)",
    DeprecationWarning,
    stacklevel=2,
)

from repro.network.routing import (  # noqa: F401,E402
    LinkLoads,
    PairingPrediction,
    all_to_all_max_load,
    max_link_load,
    pairing_speedup,
    predict_pairing_time,
    route_dor,
    simulate_pattern,
    uniform_offset_max_load,
)
from repro.network.patterns import (  # noqa: F401
    bisection_pairing,
    furthest_offset,
    pairing_pairs,
)

Coord = tuple

__all__ = [
    "Coord",
    "LinkLoads",
    "PairingPrediction",
    "all_to_all_max_load",
    "bisection_pairing",
    "furthest_offset",
    "max_link_load",
    "pairing_pairs",
    "pairing_speedup",
    "predict_pairing_time",
    "route_dor",
    "simulate_pattern",
    "uniform_offset_max_load",
]
