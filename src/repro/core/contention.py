"""Link-level contention model for torus partitions (paper Section 4.1).

Models dimension-ordered minimal routing (DOR) on a torus partition and
computes per-directed-link loads for a traffic pattern.  The completion time
of a bulk-synchronous communication phase is estimated as

    T = max_link_load / link_bandwidth

which is exact for the bisection-pairing benchmark of the paper (each node
exchanges fixed-size messages with the node at maximal hop distance) and a
good model for any contention-bound pattern.

Two implementations are provided:

* ``LinkLoads`` — exact per-link accounting for arbitrary (src, dst, volume)
  traffic, used for validation on small tori.
* ``uniform_offset_max_load`` — O(D) closed form for translation-invariant
  patterns (every node sends to ``node + offset``), exact by symmetry.
  The bisection-pairing pattern is the special case offset = dims/2.

Tie-breaking: when the hop distance along a ring is exactly half the ring
length, minimal routing may use either direction.  ``split_ties=True``
(default) splits the volume evenly — this models BG/Q's and TPU ICI's
adaptive/balanced routing and is what the paper's predictions assume.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

from .torus import canonical, volume

Coord = Tuple[int, ...]


@dataclass
class LinkLoads:
    """Exact directed-link load accounting on a torus under DOR routing."""

    dims: Tuple[int, ...]
    split_ties: bool = True
    # loads[k][d] has the torus shape; entry v = volume on the link leaving
    # vertex v in dimension k, direction d (0: +1, 1: -1).
    loads: List[List[np.ndarray]] = field(init=False)

    def __post_init__(self):
        self.dims = tuple(int(a) for a in self.dims)
        self.loads = [
            [np.zeros(self.dims, dtype=np.float64) for _ in range(2)]
            for _ in range(len(self.dims))
        ]

    def add_path(self, src: Coord, dst: Coord, vol: float) -> None:
        """Route vol from src to dst with dimension-ordered minimal routing."""
        cur = list(src)
        for k, a in enumerate(self.dims):
            if a == 1:
                continue
            delta = (dst[k] - cur[k]) % a
            if delta == 0:
                continue
            if delta < a - delta:
                self._walk(cur, k, +1, delta, vol)
            elif delta > a - delta:
                self._walk(cur, k, -1, a - delta, vol)
            else:  # tie: distance exactly a/2
                if self.split_ties:
                    self._walk(list(cur), k, +1, delta, vol / 2.0)
                    self._walk(cur, k, -1, delta, vol / 2.0)
                else:
                    self._walk(cur, k, +1, delta, vol)
            cur[k] = dst[k]

    def _walk(self, cur: List[int], k: int, direction: int, hops: int, vol: float) -> None:
        a = self.dims[k]
        pos = list(cur)
        for _ in range(hops):
            if direction > 0:
                self.loads[k][0][tuple(pos)] += vol
                pos[k] = (pos[k] + 1) % a
            else:
                self.loads[k][1][tuple(pos)] += vol
                pos[k] = (pos[k] - 1) % a

    def max_load(self) -> float:
        """Maximum load on any directed link.

        Dimensions of length 2 have *two* physical links between each vertex
        pair (the Blue Gene/Q double-link convention); traffic is balanced
        across them, halving the effective load.
        """
        m = 0.0
        for k, a in enumerate(self.dims):
            if a == 1:
                continue
            scale = 0.5 if a == 2 else 1.0
            for d in range(2):
                m = max(m, scale * float(self.loads[k][d].max()))
        return m

    def total_hop_volume(self) -> float:
        return float(sum(arr.sum() for pair in self.loads for arr in pair))


def uniform_offset_max_load(
    dims: Sequence[int], offset: Sequence[int], vol: float = 1.0, split_ties: bool = True
) -> float:
    """Max directed-link load when every vertex sends vol to vertex+offset.

    By translation symmetry the load is uniform per (dimension, direction):
    an offset of delta on a ring of length a loads each link of the chosen
    direction with ``vol * min(delta, a-delta)`` (halved when the tie is
    split, and halved again on double links, a == 2).
    """
    m = 0.0
    for a, off in zip(dims, offset):
        if a == 1:
            continue
        delta = off % a
        if delta == 0:
            continue
        d = min(delta, a - delta)
        load = vol * d
        if 2 * d == a and split_ties:
            load /= 2.0
        if a == 2:
            load /= 2.0  # double link
        m = max(m, load)
    return m


# ---------------------------------------------------------------------------
# Paper experiment A: the bisection-pairing benchmark.
# ---------------------------------------------------------------------------
def furthest_offset(dims: Sequence[int]) -> Tuple[int, ...]:
    """The maximal-hop-distance offset (pairs each node with its antipode)."""
    return tuple(a // 2 for a in dims)


def pairing_pairs(dims: Sequence[int]) -> List[Tuple[Coord, Coord]]:
    """Explicit furthest-node pairing (for the exact simulator)."""
    dims = tuple(dims)
    off = furthest_offset(dims)
    pairs = []
    seen = set()
    for v in itertools.product(*(range(a) for a in dims)):
        w = tuple((v[k] + off[k]) % a for k, a in enumerate(dims))
        key = frozenset((v, w))
        if key in seen:
            continue
        seen.add(key)
        pairs.append((v, w))
    return pairs


@dataclass(frozen=True)
class PairingPrediction:
    dims: Tuple[int, ...]
    max_link_load: float  # per unit message volume
    time_per_volume: float  # seconds per byte of per-pair message volume
    bisection_links: int


def predict_pairing_time(
    dims: Sequence[int],
    message_bytes: float,
    link_bw_bytes_s: float,
    split_ties: bool = True,
) -> PairingPrediction:
    """Predicted completion time of one round of the pairing benchmark."""
    from .torus import Torus

    dims = canonical(dims)
    off = furthest_offset(dims)
    load = uniform_offset_max_load(dims, off, 1.0, split_ties=split_ties)
    return PairingPrediction(
        dims=dims,
        max_link_load=load,
        time_per_volume=load / link_bw_bytes_s,
        bisection_links=Torus(dims).bisection_links(),
    )


def pairing_speedup(
    dims_a: Sequence[int], dims_b: Sequence[int], split_ties: bool = True
) -> float:
    """Predicted execution-time ratio T(a) / T(b) of the pairing benchmark
    between two equal-size partition geometries (paper Figures 3-4)."""
    a = predict_pairing_time(dims_a, 1.0, 1.0, split_ties)
    b = predict_pairing_time(dims_b, 1.0, 1.0, split_ties)
    return a.max_link_load / b.max_link_load


# ---------------------------------------------------------------------------
# Generic traffic patterns for policy evaluation.
# ---------------------------------------------------------------------------
def simulate_pattern(
    dims: Sequence[int],
    traffic: Iterable[Tuple[Coord, Coord, float]],
    split_ties: bool = True,
) -> LinkLoads:
    ll = LinkLoads(tuple(dims), split_ties=split_ties)
    for src, dst, vol in traffic:
        ll.add_path(src, dst, vol)
    return ll


def all_to_all_max_load(dims: Sequence[int], vol_per_pair: float = 1.0) -> float:
    """Max link load of a full all-to-all (every ordered pair exchanges
    vol_per_pair), computed analytically for DOR with balanced tie-splitting.

    On a ring of length a, all-to-all loads each directed link with
    a^2/8 * vol per ring (even a); embedded in a torus, multiply by the
    number of (src, dst) column pairs sharing the ring: prod of other dims
    for the source hyperplane times... we compute per dimension k:
        load_k = (number of messages whose dim-k segment uses a given link)
    For DOR, messages with arbitrary coordinates in dims > k (not yet
    routed) and dst coordinates in dims < k share dim-k rings uniformly.
    Total messages crossing a dim-k directed link: N^2/(a_k) * (a_k^2/8)/N
    ... by symmetry the max is identical for all links in a dimension, so we
    compute it exactly by counting hop-volume per dimension.
    """
    dims = tuple(dims)
    n = volume(dims)
    worst = 0.0
    for k, a in enumerate(dims):
        if a == 1:
            continue
        # Sum over delta of min-hop distance, ties split evenly.
        # hop_volume per (ring, direction) for one full all-to-all among the
        # a nodes of a ring = sum_delta dist(delta) * a / 2 per direction.
        per_ring_dir = 0.0
        for delta in range(1, a):
            d = min(delta, a - delta)
            if 2 * d == a:
                per_ring_dir += a * d / 2.0  # split across the two directions
            elif delta < a - delta:
                per_ring_dir += a * d  # + direction only; symmetric overall
        # Each ordered pair of "columns" (same ring) contributes; number of
        # messages sharing a given dim-k ring = n^2 / (a * n) * ... simpler:
        # every message routes its full dim-k distance on exactly one ring;
        # total dim-k hop volume = n^2 * avg_dist_k; divided evenly over
        # (n/a) rings * a links * 2 directions.
        total_pairs = n * n
        avg_dist = sum(min(d, a - d) for d in range(a)) / a
        total_hop_volume = total_pairs * avg_dist * vol_per_pair
        links = (n // a) * a * 2  # directed links in dimension k
        load = total_hop_volume / links
        if a == 2:
            load /= 2.0  # double links
        worst = max(worst, load)
    return worst
