"""Edge-isoperimetric analysis of torus graphs (paper Section 3.1).

Implements:

* ``bollobas_leader_bound`` — Theorem 2.1 (cubic tori, Bollobás & Leader 1991).
* ``theorem31_bound``       — Theorem 3.1, the paper's novel generalisation of
  the edge-isoperimetric inequality to tori with *arbitrary* dimension sizes.
* ``lemma32_cut``           — the explicit optimal-cuboid construction S_r of
  Lemma 3.2 and its exact cut size.
* ``optimal_cuboid``        — exact minimiser over all cuboid subsets (by
  Lemma 3.3 this is the isoperimetric optimum among cuboids, conjectured
  optimal among arbitrary subsets).
* ``small_set_expansion``   — h_t(G) restricted to cuboid witnesses, the
  quantity used by Ballard et al. (2016) to derive contention lower bounds.

All cut sizes are in links, with unit capacity per link ("normalized
bisection bandwidth" in the paper's tables).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

from repro.network.fabric import Torus
from repro.network.geometry import Geometry, canonical, theorem31_bound, volume


def bollobas_leader_bound(n: int, D: int, t: int) -> float:
    """Theorem 2.1: lower bound on |E(S, S̄)| for |S| = t in the cubic torus [n]^D."""
    if t < 0 or t > n**D // 2:
        raise ValueError("t must satisfy 0 <= t <= |V|/2")
    if t == 0:
        return 0.0
    best = math.inf
    for r in range(D):
        val = 2.0 * (D - r) * n ** (r / (D - r)) * t ** ((D - r - 1) / (D - r))
        best = min(best, val)
    return best


# theorem31_bound is implemented once in repro.network.geometry (it also
# backs the odd-dimension bisection fallback there) and re-exported here.


def lemma32_cut(dims: Sequence[int], t: int, r: int) -> Optional[Tuple[Geometry, int]]:
    """Lemma 3.2: the explicit cuboid S_r and its exact cut, if it exists.

    S_r fully covers the r smallest dimensions and is a cube of side
    s = (t / k)^(1/(D-r)) in the remaining D-r dimensions, where k is the
    product of the r smallest dims.  Returns ``None`` when s is not an
    integer or S_r does not fit.
    """
    a = canonical(dims)
    D = len(a)
    if not 0 <= r < D:
        raise ValueError(f"r must be in [0, {D}), got {r}")
    k = math.prod(a[D - r:]) if r > 0 else 1
    if t % k != 0:
        return None
    q = t // k
    s = round(q ** (1.0 / (D - r)))
    if s ** (D - r) != q:
        return None
    if s > min(a[: D - r]):
        return None  # the cube side must fit in each uncovered dimension
    geometry = canonical((s,) * (D - r) + tuple(a[D - r:]))
    torus = Torus(a)
    return geometry, torus.cuboid_cut(geometry)


@dataclass(frozen=True)
class CuboidOptimum:
    geometry: Geometry
    cut: int
    bound: float

    @property
    def tight(self) -> bool:
        return math.isclose(self.cut, self.bound, rel_tol=1e-9)


def optimal_cuboid(torus: Torus, t: int) -> Optional[CuboidOptimum]:
    """Exact minimum-cut cuboid of size t inside the torus (Lemma 3.3 optimum)."""
    n = torus.num_vertices
    if t <= 0 or t > n:
        raise ValueError(f"t must be in (0, {n}], got {t}")
    best_geom, best_cut = None, None
    for c in torus.sub_cuboids(t):
        cut = torus.cuboid_cut(c)
        if best_cut is None or cut < best_cut:
            best_geom, best_cut = c, cut
    if best_geom is None:
        return None
    bound = theorem31_bound(torus.dims, t) if t <= n // 2 else float(best_cut)
    return CuboidOptimum(best_geom, best_cut, bound)


def worst_cuboid(torus: Torus, t: int) -> Optional[CuboidOptimum]:
    """Maximum-cut cuboid of size t — the adversarial partition geometry."""
    best_geom, best_cut = None, None
    for c in torus.sub_cuboids(t):
        cut = torus.cuboid_cut(c)
        if best_cut is None or cut > best_cut:
            best_geom, best_cut = c, cut
    if best_geom is None:
        return None
    n = torus.num_vertices
    bound = theorem31_bound(torus.dims, t) if t <= n // 2 else float(best_cut)
    return CuboidOptimum(best_geom, best_cut, bound)


def small_set_expansion(torus: Torus, t: int) -> float:
    """h_t(G) over cuboid witnesses: min_{|A|<=t} cut(A) / (interior(A)+cut(A)).

    For the regular tori considered here the minimiser is attained at the
    bisection (paper, Section 2), so cuboid witnesses suffice.
    """
    best = math.inf
    for size in range(1, t + 1):
        for c in torus.sub_cuboids(size):
            cut = torus.cuboid_cut(c)
            interior = torus.cuboid_interior(c)
            denom = interior + cut
            if denom == 0:
                continue
            best = min(best, cut / denom)
    return best


def bisection_of_geometry(dims: Sequence[int]) -> int:
    """Internal bisection (links) of a torus partition with the given dims."""
    return Torus(dims).bisection_links()
