"""Deprecated shim — the isoperimetric analysis now lives in
:mod:`repro.network.isoperimetry`.

The per-cuboid Python loops that used to live here were replaced by the
vectorized divisor-meshgrid engine (batched cuts of every same-volume
geometry in one NumPy pass); the historical implementation survives as the
property-test oracle under ``tests/reference_isoperimetry.py``.  Existing
imports keep working; new code should import from
``repro.network.isoperimetry`` (or ``repro.network``) directly.  See
DESIGN.md for the deprecation path.
"""

from __future__ import annotations

import warnings

# One-shot by module caching: Python executes this module (and hence the
# warning) once per process, however many times it is imported.
warnings.warn(
    "repro.core.isoperimetry is a deprecated re-export shim; import from "
    "repro.network instead (see DESIGN.md)",
    DeprecationWarning,
    stacklevel=2,
)

from repro.network.isoperimetry import (  # noqa: F401,E402
    CuboidOptimum,
    bisection_of_geometry,
    bollobas_leader_bound,
    lemma32_cut,
    optimal_cuboid,
    small_set_expansion,
    theorem31_bound,
    worst_cuboid,
)

__all__ = [
    "CuboidOptimum",
    "bisection_of_geometry",
    "bollobas_leader_bound",
    "lemma32_cut",
    "optimal_cuboid",
    "small_set_expansion",
    "theorem31_bound",
    "worst_cuboid",
]
