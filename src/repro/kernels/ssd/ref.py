"""Pure-jnp oracle for the SSD kernel: direct sequential recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_reference(xw, la, bm, cm):
    """Head-major oracle.  xw: (B,H,S,P); la: (B,H,S,1); bm/cm: (B,G,S,N).
    Returns (y (B,H,S,P) f32, final state (B,H,N,P))."""
    B, H, S, P = xw.shape
    G, N = bm.shape[1], bm.shape[3]
    bh = jnp.repeat(bm, H // G, axis=1)
    ch = jnp.repeat(cm, H // G, axis=1)
    xf = xw.astype(jnp.float32)
    laf = la.astype(jnp.float32)[..., 0]
    state0 = jnp.zeros((B, H, N, P), jnp.float32)

    def step(h, t):
        a = jnp.exp(laf[:, :, t])  # (B,H)
        h = h * a[..., None, None] + bh[:, :, t][..., None] * xf[:, :, t][:, :, None, :]
        y = jnp.einsum("bhn,bhnp->bhp", ch[:, :, t], h)
        return h, y

    state, ys = jax.lax.scan(step, state0, jnp.arange(S))
    return ys.transpose(1, 2, 0, 3), state
