"""Mamba2 SSD chunked-scan Pallas kernel (TPU target).

Grid = (B, H, n_chunks); sequential chunk dimension carries the (N, P)
per-head SSM state in VMEM scratch.  Unlike RWKV6, the SSD decay is a
*scalar* per head per step, so every intra-chunk term is an MXU matmul:

    L[t,s]   = exp(cla_t - cla_s)   (s <= t; (Q,Q), bounded: cla decreasing)
    scores   = (C B^T) ⊙ L          (Q,Q)   MXU + VPU mask
    y_intra  = scores @ (dt ⊙ x)    (Q,P)   MXU
    y_inter  = (C ⊙ e^{cla}) @ S    (Q,N)x(N,P) MXU
    S'       = e^{cla_Q} S + (B ⊙ e^{cla_Q-cla})^T (dt ⊙ x)   MXU

B/C group handling (n_groups < heads) is done in the BlockSpec index map
(head h reads group h // (H/G)) — no materialised repetition in HBM.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _ssd_kernel(
    xw_ref, la_ref, b_ref, c_ref,  # (Q,P), (Q,1), (Q,N), (Q,N) tiles
    y_ref, sf_ref,  # outputs: (Q,P), (N,P) final state
    state_scr,  # VMEM scratch (N,P)
    *,
    Q: int,
):
    c = pl.program_id(2)
    n_c = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    xw = xw_ref[...].astype(jnp.float32)  # dt-weighted inputs (Q,P)
    la = la_ref[...].astype(jnp.float32)[:, 0]  # (Q,) log decay per step
    bm = b_ref[...].astype(jnp.float32)  # (Q,N)
    cm = c_ref[...].astype(jnp.float32)  # (Q,N)

    cla = jnp.cumsum(la)  # (Q,) cumulative log decay (includes t)
    state = state_scr[...]
    # inter-chunk
    y_inter = jax.lax.dot_general(
        cm * jnp.exp(cla)[:, None], state, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # intra-chunk
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q,Q) = C B^T
    diff = cla[:, None] - cla[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (Q, Q), 1
    )
    L = jnp.where(mask, jnp.exp(diff), 0.0)
    y_intra = jax.lax.dot_general(
        scores * L, xw, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    y_ref[...] = (y_inter + y_intra).astype(y_ref.dtype)
    # state update
    dec_all = jnp.exp(cla[-1])
    carry_b = bm * jnp.exp(cla[-1] - cla)[:, None]  # (Q,N)
    state_new = state * dec_all + jax.lax.dot_general(
        carry_b, xw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    state_scr[...] = state_new

    @pl.when(c == n_c - 1)
    def _final():
        sf_ref[...] = state_new.astype(sf_ref.dtype)


def ssd_chunked_hmajor(
    xw: jax.Array,  # (B, H, S, P) dt-weighted inputs
    la: jax.Array,  # (B, H, S, 1) per-step log decay (dt * A)
    bm: jax.Array,  # (B, G, S, N)
    cm: jax.Array,  # (B, G, S, N)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    B, H, S, P = xw.shape
    G, N = bm.shape[1], bm.shape[3]
    assert H % G == 0
    hg = H // G
    Q = min(chunk, S)
    assert S % Q == 0
    n_c = S // Q
    kernel = functools.partial(_ssd_kernel, Q=Q)
    y, state = pl.pallas_call(
        kernel,
        grid=(B, H, n_c),
        in_specs=[
            pl.BlockSpec((None, None, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, None, Q, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, None, Q, N), lambda b, h, c: (b, h // hg, c, 0)),
            pl.BlockSpec((None, None, Q, N), lambda b, h, c: (b, h // hg, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, None, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xw, la, bm, cm)
    return y, state
