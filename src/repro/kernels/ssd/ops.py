"""Jitted wrapper for the SSD chunked kernel ((B,S,...) model layout)."""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .chunked import ssd_chunked_hmajor


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    xh: jax.Array,  # (B, S, H, P) raw head inputs
    dt: jax.Array,  # (B, S, H) positive step sizes
    A: jax.Array,  # (H,) negative decay rates
    bm: jax.Array,  # (B, S, G, N)
    cm: jax.Array,  # (B, S, G, N)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    xw = (xh * dt[..., None]).transpose(0, 2, 1, 3)  # (B,H,S,P)
    la = (dt * A[None, None, :]).transpose(0, 2, 1)[..., None]  # (B,H,S,1)
    bmh = bm.transpose(0, 2, 1, 3)  # (B,G,S,N)
    cmh = cm.transpose(0, 2, 1, 3)
    y, state = ssd_chunked_hmajor(xw, la, bmh, cmh, chunk=chunk, interpret=interpret)
    return y.transpose(0, 2, 1, 3), state
