"""Version compatibility for Pallas TPU APIs shared by all kernel modules."""

from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; take
# whichever this version provides.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
