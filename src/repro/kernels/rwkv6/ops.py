"""Jitted wrapper for the RWKV6 chunked kernel ((B,S,H,P) model layout)."""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .chunked import rwkv6_chunked_hmajor


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_mix(
    r: jax.Array,  # (B, S, H, P)
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,
    u: jax.Array,  # (H, P)
    *,
    chunk: int = 32,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    tr = lambda t: t.transpose(0, 2, 1, 3)
    out, state = rwkv6_chunked_hmajor(
        tr(r), tr(k), tr(v), tr(logw), u, chunk=chunk, interpret=interpret
    )
    return tr(out), state
