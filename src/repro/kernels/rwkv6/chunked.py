"""RWKV6 chunked-scan Pallas kernel (TPU target).

Grid = (B, H, n_chunks); the chunk dimension is sequential ("arbitrary") and
carries the (P, P) per-head WKV state in VMEM scratch.  Within a chunk of
length Q the contribution of earlier tokens is

    o_t = r_t ⊙ e^{clw_{t-1}} · S_0
        + sum_{s<t} (r_t ⊙ e^{clw_{t-1}-clw_s}) · k_s v_s^T
        + (r_t ⊙ u ⊙ k_t) v_t

Numerics: all exponents are differences clw_{t-1} - clw_s <= 0 (clw is the
per-channel cumulative log decay, non-increasing), evaluated in the direct
(Q, Q, P) form — never the overflow-prone factorized e^{clw} · e^{-clw}
product.  The (Q, Q, P) intra tensor is VPU work; Q=32, P=64 keeps it at
256 KiB in VMEM.  (Production refinement: 16-token sub-chunk anchoring
turns the off-diagonal blocks into MXU matmuls — see DESIGN.md §Kernels.)

The state-in/state-out terms are (Q,P)x(P,P) matmuls on the MXU.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _rwkv6_kernel(
    r_ref, k_ref, v_ref, lw_ref, u_ref,  # (Q,P) tiles; u: (P,)
    o_ref, sf_ref,  # outputs: (Q,P) tile; (P,P) final state
    state_scr,  # VMEM scratch (P,P)
    *,
    Q: int,
    P: int,
):
    c = pl.program_id(2)
    n_c = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    lw = lw_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)

    clw = jnp.cumsum(lw, axis=0)  # (Q,P)
    dec_in = jnp.exp(clw - lw)  # e^{clw_{t-1}} <= 1
    state = state_scr[...]
    # inter-chunk (MXU): (Q,P) @ (P,P)
    o_inter = jax.lax.dot_general(
        r * dec_in, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # intra-chunk, direct bounded-exponent form (VPU)
    diff = (clw - lw)[:, None, :] - clw[None, :, :]  # (Q,Q,P), t x s
    mask = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) > jax.lax.broadcasted_iota(
        jnp.int32, (Q, Q), 1
    )
    expdiff = jnp.where(mask[:, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("tp,sp,tsp->ts", r, k, expdiff)
    diag = jnp.sum(r * u[None, :] * k, axis=1)  # (Q,)
    o_intra = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_intra = o_intra + diag[:, None] * v
    o_ref[...] = (o_inter + o_intra).astype(o_ref.dtype)
    # state update (MXU): S' = diag(e^{clw_Q}) S + (k ⊙ e^{clw_Q-clw})^T v
    dec_all = jnp.exp(clw[-1])  # (P,)
    carry_k = k * jnp.exp(clw[-1][None, :] - clw)  # (Q,P)
    state_new = state * dec_all[:, None] + jax.lax.dot_general(
        carry_k, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    state_scr[...] = state_new

    @pl.when(c == n_c - 1)
    def _final():
        sf_ref[...] = state_new.astype(sf_ref.dtype)


def rwkv6_chunked_hmajor(
    r: jax.Array,  # (B, H, S, P)
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,  # (B, H, S, P) log decay <= 0
    u: jax.Array,  # (H, P)
    *,
    chunk: int = 32,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    B, H, S, P = r.shape
    Q = min(chunk, S)
    assert S % Q == 0
    n_c = S // Q
    kernel = functools.partial(_rwkv6_kernel, Q=Q, P=P)
    out, state = pl.pallas_call(
        kernel,
        grid=(B, H, n_c),
        in_specs=[
            pl.BlockSpec((None, None, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, None, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, None, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, None, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, P), lambda b, h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, None, P, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, P), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, logw, u)
    return out, state
