"""Pure-jnp oracle for the RWKV6 kernel: direct sequential recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_reference(r, k, v, logw, u):
    """Head-major oracle.  r,k,v,logw: (B,H,S,P); u: (H,P).
    Returns (out (B,H,S,P) f32, final state (B,H,P,P) f32)."""
    B, H, S, P = r.shape
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    lw = logw.astype(jnp.float32)
    state0 = jnp.zeros((B, H, P, P), jnp.float32)

    def step(state, t):
        rt, kt, vt, wt = rf[:, :, t], kf[:, :, t], vf[:, :, t], jnp.exp(lw[:, :, t])
        att = state + u[None, :, :, None] * kt[..., None] * vt[..., None, :]
        ot = jnp.einsum("bhp,bhpo->bho", rt, att)
        state = state * wt[..., None] + kt[..., None] * vt[..., None, :]
        return state, ot

    state, outs = jax.lax.scan(step, state0, jnp.arange(S))
    return outs.transpose(1, 2, 0, 3), state
