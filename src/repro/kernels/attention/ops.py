"""Jitted public wrapper for the flash attention kernel.

Accepts the model's (B, S, H, hd) layout, dispatches to the head-major
Pallas kernel (TPU target; ``interpret=True`` executes the same kernel body
on CPU for validation).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .flash import flash_attention_hmajor


@partial(jax.jit, static_argnames=("causal", "window", "blk_q", "blk_k", "interpret"))
def flash_attention(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S, K, hd)
    v: jax.Array,  # (B, S, K, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    out = flash_attention_hmajor(
        qh, kh, vh,
        causal=causal, window=window, blk_q=blk_q, blk_k=blk_k,
        interpret=interpret,
    )
    return out.transpose(0, 2, 1, 3)
