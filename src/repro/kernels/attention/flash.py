"""Flash attention Pallas kernel (TPU target).

Tiling: grid = (batch, q_heads, n_q_blocks, n_kv_blocks); the last grid
dimension is the reduction ("arbitrary" semantics) — running max / sum /
accumulator live in VMEM scratch and persist across the kv iterations.
Block shapes are (blk_q, head_dim) / (blk_k, head_dim) tiles in VMEM, MXU
aligned (blk_* multiples of 128 at full scale; head_dim is the lane dim).

Causality is *structural*: fully-masked kv blocks are skipped with pl.when,
so the kernel does ~S^2/2 work (the XLA fallback cannot skip — this is the
kernel's roofline win, alongside fusion of the softmax pipeline).
GQA is handled in the BlockSpec index maps (q head h reads kv head
h // (H // K)) — no materialised KV repetition (HBM traffic win).
Sliding windows additionally skip blocks below the band.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # VMEM tiles
    o_ref,  # output tile (blk_q, hd)
    m_scr, l_scr, acc_scr,  # VMEM scratch
    *,
    scale: float,
    blk_q: int,
    blk_k: int,
    seq: int,
    causal: bool,
    window: Optional[int],
):
    i = pl.program_id(2)  # q block
    j = pl.program_id(3)  # kv block
    n_k = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i * blk_q
    k_start = j * blk_k

    # structural block skipping: above the diagonal / below the window band
    if causal or window is not None:
        live = jnp.bool_(True)
        if causal:
            live = jnp.logical_and(live, k_start <= q_start + blk_q - 1)
        if window is not None:
            live = jnp.logical_and(live, k_start + blk_k - 1 >= q_start - window + 1)
    else:
        live = jnp.bool_(True)

    @pl.when(live)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * scale  # (blk_q, hd), block dims squeezed
        k = k_ref[...].astype(jnp.float32)  # (blk_k, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (blk_q, blk_k)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        mask = q_pos >= k_pos if causal else jnp.full((blk_q, blk_k), True)
        if window is not None:
            mask = jnp.logical_and(mask, q_pos < k_pos + window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new

    @pl.when(j == n_k - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[...] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_hmajor(
    q: jax.Array,  # (B, H, S, hd)
    k: jax.Array,  # (B, K, S, hd)
    v: jax.Array,  # (B, K, S, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, hd = q.shape
    K = k.shape[1]
    assert H % K == 0, "GQA requires n_heads % n_kv_heads == 0"
    group = H // K
    blk_q = min(blk_q, S)
    blk_k = min(blk_k, S)
    assert S % blk_q == 0 and S % blk_k == 0
    n_q, n_k = S // blk_q, S // blk_k
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        blk_q=blk_q,
        blk_k=blk_k,
        seq=S,
        causal=causal,
        window=window,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((None, None, blk_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((None, None, blk_k, hd), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((None, None, blk_k, hd), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, blk_q, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
