"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_reference(
    q: jax.Array,  # (B, H, S, hd)
    k: jax.Array,  # (B, K, S, hd)
    v: jax.Array,  # (B, K, S, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    B, H, S, hd = q.shape
    K = k.shape[1]
    k = jnp.repeat(k, H // K, axis=1)
    v = jnp.repeat(v, H // K, axis=1)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk",
        q.astype(jnp.float32) * scale,
        k.astype(jnp.float32),
    )
    pos_q = jnp.arange(S)[:, None]
    pos_k = jnp.arange(S)[None, :]
    mask = jnp.full((S, S), True)
    if causal:
        mask &= pos_q >= pos_k
    if window is not None:
        mask &= pos_q < pos_k + window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
