"""Core neural layers: norms, rotary, GQA attention (XLA + Pallas paths), MLPs.

All layers are pure functions over explicit parameter pytrees (dicts), so
sharding rules (distributed/sharding.py) can address leaves by path, and
layer stacks can be scanned (params stacked on a leading layer axis).

Attention paths:
* ``xla``        — memory-efficient online-softmax attention, scanning over
                   KV blocks (O(S * block) memory).  Computes the full S^2
                   score matrix under the causal mask (XLA cannot skip
                   blocks); the causal over-count is corrected analytically
                   in the roofline (see EXPERIMENTS.md).
* ``banded``     — sliding-window attention: each query block attends a
                   static band of size (window + block); sub-quadratic.
* ``pallas``     — the flash kernel in repro.kernels (TPU target; validated
                   on CPU via interpret mode).
* decode         — single-token attention against a KV cache.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

PyTree = Any
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, in_dim: int, shape, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms (computed in f32, cast back)
# ---------------------------------------------------------------------------
def init_norm(cfg: ArchConfig, dim: Optional[int] = None) -> Dict[str, jax.Array]:
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: Dict[str, jax.Array], x: jax.Array, cfg: ArchConfig) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mean = xf.mean(-1, keepdims=True)
        var = ((xf - mean) ** 2).mean(-1, keepdims=True)
        out = (xf - mean) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"]
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP activations
# ---------------------------------------------------------------------------
def mlp_param_shapes(cfg: ArchConfig, d_ff: Optional[int] = None) -> Dict[str, Tuple[int, ...]]:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_act.endswith("_glu"):
        return {"wi": (d, ff), "wg": (d, ff), "wo": (ff, d)}
    return {"wi": (d, ff), "wo": (ff, d)}


def init_mlp(key, cfg: ArchConfig, d_ff: Optional[int] = None, dtype=None) -> PyTree:
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    shapes = mlp_param_shapes(cfg, d_ff)
    keys = jax.random.split(key, len(shapes))
    return {
        name: dense_init(k, shape[0], shape, dtype)
        for (name, shape), k in zip(sorted(shapes.items()), keys)
    }


def apply_mlp(p: PyTree, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    h = x @ p["wi"]
    if cfg.mlp_act == "silu_glu":
        h = jax.nn.silu(h) * (x @ p["wg"])
    elif cfg.mlp_act == "gelu_glu":
        h = jax.nn.gelu(h) * (x @ p["wg"])
    elif cfg.mlp_act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    elif cfg.mlp_act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(f"unknown mlp_act {cfg.mlp_act}")
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ArchConfig, dtype=None) -> PyTree:
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, d, (d, H, hd), dtype),
        "wk": dense_init(kk, d, (d, K, hd), dtype),
        "wv": dense_init(kv, d, (d, K, hd), dtype),
        "wo": dense_init(ko, H * hd, (H, hd, d), dtype),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((K, hd), dtype)
        p["bv"] = jnp.zeros((K, hd), dtype)
    return p


def qkv_project(p: PyTree, x: jax.Array, cfg: ArchConfig, positions: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.attn_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _largest_divisor_at_most(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (block sizes must tile exactly;
    e.g. the VLM's patch-extended sequence 4352 = 2^8 * 17 tiles at 544)."""
    for d in range(cap, 0, -1):
        if n % d == 0:
            return d
    return 1


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, K, hd) -> (B, S, H, hd) by repeating each KV head H/K times."""
    B, S, K, hd = k.shape
    reps = n_heads // K
    if reps == 1:
        return k
    return jnp.repeat(k, reps, axis=2)


def attention_xla(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cfg: ArchConfig,
    kv_block: int = 1024,
) -> jax.Array:
    """Online-softmax causal attention scanning over KV blocks.

    Memory O(S * kv_block); computes masked full scores (see module note).
    q: (B, S, H, hd); k, v: (B, S, K, hd).  Returns (B, S, H, hd).
    """
    B, S, H, hd = q.shape
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    kv_block = _largest_divisor_at_most(S, min(kv_block, S))
    n_blocks = S // kv_block
    scale = 1.0 / math.sqrt(hd)
    qf = q * scale
    kb = k.reshape(B, n_blocks, kv_block, H, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, kv_block, H, hd).transpose(1, 0, 2, 3, 4)
    q_pos = jnp.arange(S)

    def step(carry, inputs):
        m, l, acc = carry  # (B,S,H), (B,S,H), (B,S,H,hd) running stats (f32)
        j, kj, vj = inputs  # block idx, (B,kv_block,H,hd) x2
        kv_pos = j * kv_block + jnp.arange(kv_block)
        s = jnp.einsum(
            "bqhk,bshk->bqsh", qf, kj, preferred_element_type=jnp.float32
        )  # scores, f32 accumulation
        mask = q_pos[:, None] >= kv_pos[None, :]
        if cfg.sliding_window is not None:
            mask &= q_pos[:, None] < kv_pos[None, :] + cfg.sliding_window
        s = jnp.where(mask[None, :, :, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=2))
        p = jnp.exp(s - m_new[:, :, None, :])
        correction = jnp.exp(m - m_new)
        l_new = l * correction + p.sum(axis=2)
        pv = jnp.einsum("bqsh,bshk->bqhk", p.astype(kj.dtype), vj).astype(jnp.float32)
        acc_new = acc * correction[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, H), jnp.float32)
    acc0 = jnp.zeros((B, S, H, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (jnp.arange(n_blocks), kb, vb)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def attention_banded(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cfg: ArchConfig,
    q_block: int = 1024,
) -> jax.Array:
    """Sliding-window attention with a static band per query block.

    Each query block of length Bq attends keys in
    [blk_start - window, blk_start + Bq): a slice of static length
    window + Bq (clamped at 0).  Sub-quadratic: O(S * (window + Bq)).
    """
    window = cfg.sliding_window
    assert window is not None
    B, S, H, hd = q.shape
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    q_block = _largest_divisor_at_most(S, min(q_block, S))
    n_blocks = S // q_block
    band = min(window + q_block, S)
    scale = 1.0 / math.sqrt(hd)

    def block_fn(i, q_i):
        # q_i: (B, q_block, H, hd)
        start = i * q_block - window
        start_c = jnp.clip(start, 0, S - band)
        k_band = jax.lax.dynamic_slice_in_dim(k, start_c, band, axis=1)
        v_band = jax.lax.dynamic_slice_in_dim(v, start_c, band, axis=1)
        q_pos = i * q_block + jnp.arange(q_block)
        kv_pos = start_c + jnp.arange(band)
        s = jnp.einsum(
            "bqhk,bshk->bqsh", q_i * scale, k_band,
            preferred_element_type=jnp.float32,
        )
        mask = (q_pos[:, None] >= kv_pos[None, :]) & (
            q_pos[:, None] < kv_pos[None, :] + window
        )
        s = jnp.where(mask[None, :, :, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=2)
        return jnp.einsum("bqsh,bshk->bqhk", p.astype(v_band.dtype), v_band)

    qb = q.reshape(B, n_blocks, q_block, H, hd).transpose(1, 0, 2, 3, 4)
    out = jax.lax.map(lambda args: block_fn(*args), (jnp.arange(n_blocks), qb))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd).astype(q.dtype)


def attention_decode(
    q: jax.Array,  # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, S, K, hd)
    v_cache: jax.Array,
    length: jax.Array,  # (B,) or scalar: number of valid cache entries
    cfg: ArchConfig,
) -> jax.Array:
    B, S, K, hd = k_cache.shape
    H = q.shape[2]
    reps = H // K
    scale = 1.0 / math.sqrt(hd)
    qg = (q * scale).reshape(B, 1, K, reps, hd)
    s = jnp.einsum("bqkrh,bskh->bqksr", qg, k_cache).astype(jnp.float32)
    pos = jnp.arange(S)
    valid = pos[None, :] < jnp.broadcast_to(jnp.asarray(length), (B,))[:, None]
    if cfg.sliding_window is not None and S > cfg.sliding_window:
        # linear (non-ring) cache longer than the window: mask old entries
        lo = jnp.broadcast_to(jnp.asarray(length), (B,))[:, None] - cfg.sliding_window
        valid &= pos[None, :] >= lo
    s = jnp.where(valid[:, None, None, :, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=3)
    out = jnp.einsum("bqksr,bskh->bqkrh", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def attention_output(p: PyTree, ctx: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])


def run_attention(
    p: PyTree,
    x: jax.Array,
    cfg: ArchConfig,
    positions: jax.Array,
    impl: str = "xla",
) -> jax.Array:
    """Full attention sublayer for train/prefill."""
    q, k, v = qkv_project(p, x, cfg, positions)
    if cfg.sliding_window is not None and x.shape[1] > cfg.sliding_window:
        ctx = attention_banded(q, k, v, cfg)
    elif impl == "pallas" or impl == "pallas_interpret":
        from repro.kernels.attention import ops as flash_ops

        ctx = flash_ops.flash_attention(
            q, k, v,
            causal=True,
            window=cfg.sliding_window,
            interpret=(impl == "pallas_interpret"),
        )
    else:
        ctx = attention_xla(q, k, v, cfg)
    return attention_output(p, ctx)


def run_attention_decode(
    p: PyTree,
    x: jax.Array,  # (B, 1, d)
    cfg: ArchConfig,
    cache: Dict[str, jax.Array],
    position: jax.Array,  # scalar int: true sequence position (for rope)
    write_pos: Optional[jax.Array] = None,  # cache write index (ring buffers)
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    write_pos = position if write_pos is None else write_pos
    q, k, v = qkv_project(p, x, cfg, position[None] if position.ndim == 0 else position)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), write_pos, axis=1
    )
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), write_pos, axis=1
    )
    length = jnp.minimum(position + 1, k_cache.shape[1])
    ctx = attention_decode(q, k_cache, v_cache, length, cfg)
    return attention_output(p, ctx), {"k": k_cache, "v": v_cache}
