"""RWKV6 "Finch" blocks (arXiv:2404.05892): attention-free time mix with
data-dependent per-channel decay, plus squared-ReLU channel mix.

Simplifications vs the public checkpoint (noted in DESIGN.md):
  * token-shift interpolation coefficients are static learned vectors (the
    paper's ddlerp adds a data-dependent low-rank term to these as well);
  * the decay keeps the Finch signature feature: a low-rank data-dependent
    component  w_t = exp(-exp(w0 + tanh(x W_a) W_b)).

The sequence mix is computed in *chunked* form (chunk length Q):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
which gives, with cumulative log-decay  lw_t = sum_{j<=t} log w_j:
    o_t = r_t ⊙ exp(lw_{t-1}) · S_chunk0  +  sum_{s<t} (r_t ⊙ e^{lw_{t-1}-lw_s}) · k_s v_s^T
          + (r_t ⊙ u ⊙ k_t) v_t
The chunked form is O(S * Q) and is also the blueprint of the Pallas kernel
(repro/kernels/rwkv6)."""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import apply_norm, dense_init, init_norm

PyTree = Dict[str, jax.Array]


def init_time_mix(key, cfg: ArchConfig, dtype) -> PyTree:
    d = cfg.d_model
    H = d // cfg.rwkv.head_dim
    lora = cfg.rwkv.decay_lora
    ks = jax.random.split(key, 8)
    return {
        "mix_rkvg": jnp.full((4, d), 0.5, jnp.float32),  # token-shift mixes
        "wr": dense_init(ks[0], d, (d, d), dtype),
        "wk": dense_init(ks[1], d, (d, d), dtype),
        "wv": dense_init(ks[2], d, (d, d), dtype),
        "wg": dense_init(ks[3], d, (d, d), dtype),
        "wo": dense_init(ks[4], d, (d, d), dtype),
        "w0": jnp.full((d,), -4.0, jnp.float32),  # base decay (slow)
        "wa": dense_init(ks[5], d, (d, lora), jnp.float32),
        "wb": dense_init(ks[6], lora, (lora, d), jnp.float32),
        "u": (jax.random.normal(ks[7], (d,), jnp.float32) * 0.1),
        "ln_x": jnp.ones((H, cfg.rwkv.head_dim), jnp.float32),  # per-head groupnorm
    }


def init_channel_mix(key, cfg: ArchConfig, dtype) -> PyTree:
    d, ff = cfg.d_model, cfg.d_ff
    k1, k2 = jax.random.split(key)
    return {
        "mix_k": jnp.full((d,), 0.5, jnp.float32),
        "wk": dense_init(k1, d, (d, ff), dtype),
        "wv": dense_init(k2, ff, (ff, d), dtype),
    }


def token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """Shift sequence right by one; position 0 gets `prev` (decode carry)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _chunked_wkv(
    r, k, v, logw, u, state0, chunk: int
) -> Tuple[jax.Array, jax.Array]:
    """Chunked RWKV6 sequence mix.

    r,k,v: (B, S, H, P); logw: (B, S, H, P) (log decay, <= 0);
    u: (H, P); state0: (B, H, P, P) mapping key-dim -> value-dim.
    Returns (out (B,S,H,P), final state).
    """
    B, S, H, P = r.shape
    Q = min(chunk, S)
    S_orig = S
    if S % Q:
        # pad with logw=0 (decay 1) and zero r/k/v -> state unaffected
        pad = Q - S % Q
        padfn = lambda t: jnp.pad(t, [(0, 0), (0, pad), (0, 0), (0, 0)])
        r, k, v, logw = padfn(r), padfn(k), padfn(v), padfn(logw)
        S = S + pad
    n = S // Q

    def chunk_step(state, inp):
        rc, kc, vc, lwc = inp  # (B, Q, H, P) each
        clw = jnp.cumsum(lwc, axis=1)  # cumulative log decay inside chunk
        # decay from chunk start to just BEFORE t: exp(clw_{t-1}) <= 1
        dec_in = jnp.exp(clw - lwc)  # (B,Q,H,P) = exp(clw_{t-1})
        # inter-chunk: o_inter[t] = (r_t * dec_in[t]) . state0
        o_inter = jnp.einsum("bqhp,bhpo->bqho", rc * dec_in, state)
        # intra-chunk: M[t,s] = sum_p r_t[p] e^{clw_{t-1}[p]-clw_s[p]} k_s[p], s<t.
        # Computed in the numerically-safe direct form: every exponent is
        # clw_{t-1} - clw_s <= 0 for s < t (clw is non-increasing), so exp
        # never overflows.  (The factorized matmul form e^{clw}·e^{-clw}
        # overflows for strong decay — this is also why the Pallas kernel
        # tiles (t, s) blocks; see kernels/rwkv6.)
        diff = (clw - lwc)[:, :, None] - clw[:, None, :]  # (B,Q,Q,H,P), t x s
        mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
        expdiff = jnp.exp(jnp.where(mask[None, :, :, None, None], diff, -jnp.inf))
        scores = jnp.einsum("bqhp,bshp,bqshp->bqsh", rc, kc, expdiff)
        # current-token bonus: (r_t ⊙ u ⊙ k_t) v_t
        diag = jnp.einsum("bqhp,bqhp->bqh", rc, u[None, None] * kc)
        o_intra = jnp.einsum("bqsh,bsho->bqho", scores, vc)
        o_intra = o_intra + diag[..., None] * vc
        # state update: S' = diag(e^{clw_Q}) S + sum_s (k_s e^{clw_Q-clw_s}) v_s^T
        # (both factors <= 1: safe.)
        decay_all = jnp.exp(clw[:, -1])  # (B,H,P)
        carry_k = kc * jnp.exp(clw[:, -1][:, None] - clw)  # (B,Q,H,P)
        state_new = state * decay_all[..., None] + jnp.einsum(
            "bqhp,bqho->bhpo", carry_k, vc
        )
        return state_new, o_inter + o_intra

    def split(t):
        return t.reshape(B, n, Q, H, P).transpose(1, 0, 2, 3, 4)

    state, outs = jax.lax.scan(
        chunk_step, state0, (split(r), split(k), split(v), split(logw))
    )
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)[:, :S_orig], state


def apply_time_mix(
    p: PyTree,
    x: jax.Array,
    cfg: ArchConfig,
    prev_token: jax.Array,  # (B, d): last token of previous segment
    state0: jax.Array,  # (B, H, P, P)
    chunk: int = 128,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (out, new_state, new_prev_token)."""
    B, S, d = x.shape
    P = cfg.rwkv.head_dim
    H = d // P
    xs = token_shift(x, prev_token)
    mix = p["mix_rkvg"].astype(x.dtype)
    xr = x * mix[0] + xs * (1 - mix[0])
    xk = x * mix[1] + xs * (1 - mix[1])
    xv = x * mix[2] + xs * (1 - mix[2])
    xg = x * mix[3] + xs * (1 - mix[3])
    r = (xr @ p["wr"]).reshape(B, S, H, P)
    k = (xk @ p["wk"]).reshape(B, S, H, P)
    v = (xv @ p["wv"]).reshape(B, S, H, P)
    g = jax.nn.silu(xg @ p["wg"])
    # Finch data-dependent decay (f32 for stability)
    dd = jnp.tanh(xk.astype(jnp.float32) @ p["wa"]) @ p["wb"]
    logw = -jnp.exp(p["w0"] + dd)  # (B,S,d), <= 0
    logw = logw.reshape(B, S, H, P)
    u = p["u"].reshape(H, P)
    out, state = _chunked_wkv(
        r.astype(jnp.float32),
        k.astype(jnp.float32),
        v.astype(jnp.float32),
        logw,
        u,
        state0,
        chunk,
    )
    # per-head group norm
    mean = out.mean(-1, keepdims=True)
    var = ((out - mean) ** 2).mean(-1, keepdims=True)
    out = (out - mean) * jax.lax.rsqrt(var + 1e-5) * p["ln_x"]
    out = out.reshape(B, S, d).astype(x.dtype) * g
    return out @ p["wo"], state, x[:, -1, :]


def apply_channel_mix(
    p: PyTree, x: jax.Array, prev_token: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    xs = token_shift(x, prev_token)
    mix = p["mix_k"].astype(x.dtype)
    xk = x * mix + xs * (1 - mix)
    h = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return h @ p["wv"], x[:, -1, :]


def init_rwkv_block(key, cfg: ArchConfig, dtype) -> PyTree:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": init_norm(cfg),
        "time_mix": init_time_mix(k1, cfg, dtype),
        "norm2": init_norm(cfg),
        "channel_mix": init_channel_mix(k2, cfg, dtype),
    }


def apply_rwkv_block(
    p: PyTree, x: jax.Array, cfg: ArchConfig, state: Dict[str, jax.Array], chunk: int = 32
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """state: {"wkv": (B,H,P,P), "shift_t": (B,d), "shift_c": (B,d)}."""
    h = apply_norm(p["norm1"], x, cfg)
    out, wkv, shift_t = apply_time_mix(
        p["time_mix"], h, cfg, state["shift_t"], state["wkv"], chunk
    )
    x = x + out
    h = apply_norm(p["norm2"], x, cfg)
    out, shift_c = apply_channel_mix(p["channel_mix"], h, state["shift_c"])
    x = x + out
    return x, {"wkv": wkv, "shift_t": shift_t, "shift_c": shift_c}


def init_rwkv_state(cfg: ArchConfig, batch: int) -> Dict[str, jax.Array]:
    d = cfg.d_model
    P = cfg.rwkv.head_dim
    H = d // P
    return {
        "wkv": jnp.zeros((batch, H, P, P), jnp.float32),
        "shift_t": jnp.zeros((batch, d), jnp.dtype(cfg.activation_dtype)),
        "shift_c": jnp.zeros((batch, d), jnp.dtype(cfg.activation_dtype)),
    }


def reference_wkv(r, k, v, logw, u, state0):
    """O(S) sequential oracle for tests: direct recurrence."""
    B, S, H, P = r.shape

    def step(state, t):
        rt, kt, vt, wt = r[:, t], k[:, t], v[:, t], jnp.exp(logw[:, t])
        att = state + u[None, :, :, None] * kt[..., None] * vt[..., None, :]
        ot = jnp.einsum("bhp,bhpo->bho", rt, att)
        state = state * wt[..., None] + kt[..., None] * vt[..., None, :]
        return state, ot

    state, outs = jax.lax.scan(step, state0, jnp.arange(S))
    return outs.transpose(1, 0, 2, 3), state
