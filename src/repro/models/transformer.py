"""Decoder-only transformer LM assembly (dense, MoE, audio, VLM families).

The layer stack is a ``jax.lax.scan`` over parameters stacked on a leading
layer axis — this keeps the compiled HLO O(1) in depth (critical for the
340B/96L dry-runs) and makes the remat policy a single knob.  MoE blocks
replace the MLP per config.  Modality frontends are stubs per the
assignment: the audio/vlm ``input_specs`` provide precomputed frame/patch
embeddings which are consumed here as (B, S, d) / (B, P, d) inputs.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import moe as moe_lib
from .layers import (
    apply_mlp,
    apply_norm,
    attention_output,
    attention_decode,
    embed_init,
    init_attention,
    init_mlp,
    init_norm,
    qkv_project,
    run_attention,
)

PyTree = Any


# ---------------------------------------------------------------------------
# Block
# ---------------------------------------------------------------------------
def init_block(key, cfg: ArchConfig) -> PyTree:
    dtype = jnp.dtype(cfg.param_dtype)
    ka, km = jax.random.split(key)
    p = {
        "norm_attn": init_norm(cfg),
        "attn": init_attention(ka, cfg, dtype),
    }
    if not cfg.parallel_block:
        p["norm_mlp"] = init_norm(cfg)
    if cfg.moe is not None:
        p["moe"] = moe_lib.init_moe(km, cfg, dtype)
    else:
        p["mlp"] = init_mlp(km, cfg, dtype=dtype)
    return p


def apply_block(
    p: PyTree,
    x: jax.Array,
    cfg: ArchConfig,
    positions: jax.Array,
    attn_impl: str,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    aux: Dict[str, jax.Array] = {}
    if cfg.parallel_block:
        # Command-R style: one pre-norm, attention and MLP in parallel.
        h = apply_norm(p["norm_attn"], x, cfg)
        attn_out = run_attention(p["attn"], h, cfg, positions, attn_impl)
        if cfg.moe is not None:
            mlp_out, aux = moe_lib.apply_moe(p["moe"], h, cfg)
        else:
            mlp_out = apply_mlp(p["mlp"], h, cfg)
        return x + attn_out + mlp_out, aux
    h = apply_norm(p["norm_attn"], x, cfg)
    x = x + run_attention(p["attn"], h, cfg, positions, attn_impl)
    h = apply_norm(p["norm_mlp"], x, cfg)
    if cfg.moe is not None:
        mlp_out, aux = moe_lib.apply_moe(p["moe"], h, cfg)
    else:
        mlp_out = apply_mlp(p["mlp"], h, cfg)
    return x + mlp_out, aux


def apply_block_decode(
    p: PyTree,
    x: jax.Array,  # (B, 1, d)
    cfg: ArchConfig,
    cache: Dict[str, jax.Array],
    position: jax.Array,
    write_pos: jax.Array,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    from .layers import run_attention_decode

    if cfg.parallel_block:
        h = apply_norm(p["norm_attn"], x, cfg)
        attn_out, cache = run_attention_decode(
            p["attn"], h, cfg, cache, position, write_pos
        )
        if cfg.moe is not None:
            mlp_out, _ = moe_lib.apply_moe(p["moe"], h, cfg)
        else:
            mlp_out = apply_mlp(p["mlp"], h, cfg)
        return x + attn_out + mlp_out, cache
    h = apply_norm(p["norm_attn"], x, cfg)
    attn_out, cache = run_attention_decode(
        p["attn"], h, cfg, cache, position, write_pos
    )
    x = x + attn_out
    h = apply_norm(p["norm_mlp"], x, cfg)
    if cfg.moe is not None:
        mlp_out, _ = moe_lib.apply_moe(p["moe"], h, cfg)
    else:
        mlp_out = apply_mlp(p["mlp"], h, cfg)
    return x + mlp_out, cache


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------
def init_params(key, cfg: ArchConfig) -> PyTree:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kl, ko = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: init_block(k, cfg))(layer_keys)
    p: Dict[str, PyTree] = {
        "layers": layers,
        "final_norm": init_norm(cfg),
    }
    if cfg.frontend != "audio":
        p["embed"] = embed_init(ke, (cfg.padded_vocab_size, cfg.d_model), dtype)
    if cfg.n_codebooks > 1:
        heads = jax.random.split(ko, cfg.n_codebooks)
        p["lm_heads"] = jnp.stack(
            [embed_init(k, (cfg.d_model, cfg.padded_vocab_size), dtype) for k in heads]
        )
    elif not cfg.tied_embeddings:
        p["lm_head"] = embed_init(ko, (cfg.d_model, cfg.padded_vocab_size), dtype)
    return p


def embed_inputs(
    p: PyTree, cfg: ArchConfig, batch: Dict[str, jax.Array], decode: bool = False
) -> jax.Array:
    """Token / frontend embedding.  Returns (B, S, d) activations."""
    dtype = jnp.dtype(cfg.activation_dtype)
    if cfg.frontend == "audio":
        # STUB frontend: precomputed EnCodec frame embeddings.
        return batch["frame_embeds"].astype(dtype)
    x = jnp.take(p["embed"], batch["tokens"], axis=0).astype(dtype)
    if cfg.frontend == "vlm" and not decode:
        # STUB frontend: precomputed InternViT patch embeddings prepended
        # (prefill only — decode consumes single tokens, patches are already
        # in the KV cache).
        x = jnp.concatenate([batch["patch_embeds"].astype(dtype), x], axis=1)
    return x


def logits_from_hidden(p: PyTree, cfg: ArchConfig, h: jax.Array) -> jax.Array:
    if cfg.n_codebooks > 1:
        return jnp.einsum("bsd,qdv->bsqv", h, p["lm_heads"])
    head = p["embed"].T if cfg.tied_embeddings else p["lm_head"]
    return h @ head


def forward(
    p: PyTree,
    cfg: ArchConfig,
    batch: Dict[str, jax.Array],
    attn_impl: str = "xla",
    remat: str = "block",
    unroll: bool = False,
    return_hidden: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Training / prefill forward pass.  Returns (logits, aux).
    ``unroll`` unrolls the layer scan (dry-run cost calibration only)."""
    x = embed_inputs(p, cfg, batch)
    B, S, _ = x.shape
    positions = jnp.arange(S)

    def body(h, layer_p):
        out, aux = apply_block(layer_p, h, cfg, positions, attn_impl)
        return out, aux

    if remat == "block":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    x, aux = jax.lax.scan(body, x, p["layers"], unroll=True if unroll else 1)
    x = apply_norm(p["final_norm"], x, cfg)
    aux_mean = {k: v.mean() for k, v in aux.items()} if aux else {}
    if return_hidden:
        return x, aux_mean
    return logits_from_hidden(p, cfg, x), aux_mean


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> PyTree:
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dtype = jnp.dtype(cfg.activation_dtype)
    cache_len = max_len
    if cfg.sliding_window is not None:
        cache_len = min(max_len, cfg.sliding_window)
    shape = (cfg.n_layers, batch, cache_len, K, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(
    p: PyTree,
    cfg: ArchConfig,
    cache: PyTree,
    batch: Dict[str, jax.Array],  # tokens: (B, 1) (or frame_embeds (B,1,d))
    position: jax.Array,  # scalar: current write index
    unroll: bool = False,
) -> Tuple[jax.Array, PyTree]:
    """One token of autoregressive decoding with a per-layer KV cache."""
    x = embed_inputs(p, cfg, batch, decode=True)
    if cfg.sliding_window is not None:
        write_pos = jnp.mod(position, cache["k"].shape[2])  # ring buffer
    else:
        write_pos = position

    def body(h, inputs):
        layer_p, k_cache, v_cache = inputs
        out, new_cache = apply_block_decode(
            layer_p, h, cfg, {"k": k_cache, "v": v_cache}, position, write_pos
        )
        return out, (new_cache["k"], new_cache["v"])

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (p["layers"], cache["k"], cache["v"]), unroll=True if unroll else 1
    )
    x = apply_norm(p["final_norm"], x, cfg)
    logits = logits_from_hidden(p, cfg, x)
    return logits, {"k": k_new, "v": v_new}
