"""RWKV6 language model assembly (attention-free family)."""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import apply_norm, embed_init, init_norm
from .rwkv import apply_rwkv_block, init_rwkv_block, init_rwkv_state
from .transformer import logits_from_hidden

PyTree = Any


def init_params(key, cfg: ArchConfig) -> PyTree:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kl, ko = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: init_rwkv_block(k, cfg, dtype))(layer_keys)
    p = {
        "embed": embed_init(ke, (cfg.padded_vocab_size, cfg.d_model), dtype),
        "embed_norm": init_norm(cfg),  # RWKV normalises the embedding
        "layers": layers,
        "final_norm": init_norm(cfg),
    }
    if not cfg.tied_embeddings:
        p["lm_head"] = embed_init(ko, (cfg.d_model, cfg.padded_vocab_size), dtype)
    return p


def forward(
    p: PyTree,
    cfg: ArchConfig,
    batch: Dict[str, jax.Array],
    attn_impl: str = "xla",
    remat: str = "block",
    unroll: bool = False,
    return_hidden: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    del attn_impl  # attention-free
    dtype = jnp.dtype(cfg.activation_dtype)
    x = jnp.take(p["embed"], batch["tokens"], axis=0).astype(dtype)
    x = apply_norm(p["embed_norm"], x, cfg)
    B = x.shape[0]

    def body(h, layer_p):
        state = init_rwkv_state(cfg, B)  # fresh zero state: full sequence pass
        out, _ = apply_rwkv_block(layer_p, h, cfg, state)
        return out, None

    if remat == "block":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, p["layers"], unroll=True if unroll else 1)
    x = apply_norm(p["final_norm"], x, cfg)
    if return_hidden:
        return x, {}
    return logits_from_hidden(p, cfg, x), {}


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> PyTree:
    del max_len  # O(1) state — the point of the architecture
    d = cfg.d_model
    P = cfg.rwkv.head_dim
    H = d // P
    dtype = jnp.dtype(cfg.activation_dtype)
    L = cfg.n_layers
    return {
        "wkv": jnp.zeros((L, batch, H, P, P), jnp.float32),
        "shift_t": jnp.zeros((L, batch, d), dtype),
        "shift_c": jnp.zeros((L, batch, d), dtype),
    }


def decode_step(
    p: PyTree,
    cfg: ArchConfig,
    cache: PyTree,
    batch: Dict[str, jax.Array],
    position: jax.Array,
    unroll: bool = False,
) -> Tuple[jax.Array, PyTree]:
    del position  # recurrent state carries all positional information
    dtype = jnp.dtype(cfg.activation_dtype)
    x = jnp.take(p["embed"], batch["tokens"], axis=0).astype(dtype)
    x = apply_norm(p["embed_norm"], x, cfg)

    def body(h, inputs):
        layer_p, wkv, st, sc = inputs
        out, ns = apply_rwkv_block(
            layer_p, h, cfg, {"wkv": wkv, "shift_t": st, "shift_c": sc}
        )
        return out, (ns["wkv"], ns["shift_t"], ns["shift_c"])

    x, (wkv_n, st_n, sc_n) = jax.lax.scan(
        body, x, (p["layers"], cache["wkv"], cache["shift_t"], cache["shift_c"]),
        unroll=True if unroll else 1,
    )
    x = apply_norm(p["final_norm"], x, cfg)
    logits = logits_from_hidden(p, cfg, x)
    return logits, {"wkv": wkv_n, "shift_t": st_n, "shift_c": sc_n}
