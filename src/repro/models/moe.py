"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

Design (MaxText/switch-style, static shapes, GSPMD-friendly):
  * tokens are grouped by batch row (groups stay aligned with the data
    shards, so routing is local until the expert einsum);
  * per group, (token, slot) pairs are sorted by expert id; each expert
    takes its first C = ceil(T * k / E * capacity_factor) tokens, the rest
    are dropped (their combine weight is zeroed — standard capacity drop);
  * expert FFNs run as one batched einsum over the (E, C, d) buckets, so the
    expert dimension can be sharded ("expert parallelism") when E divides
    the model axis, else the FFN hidden dim is sharded (TP-in-expert);
  * router uses top-k softmax (Mixtral normalization) + switch aux loss.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import dense_init


def expert_capacity(cfg: ArchConfig, tokens_per_group: int) -> int:
    moe = cfg.moe
    c = math.ceil(tokens_per_group * moe.top_k / moe.num_experts * moe.capacity_factor)
    return max(4, (c + 3) // 4 * 4)  # pad to a multiple of 4


def init_moe(key, cfg: ArchConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    moe = cfg.moe
    d, ff, E = cfg.d_model, cfg.d_ff, moe.num_experts
    kr, ki, kg, ko = jax.random.split(key, 4)
    p = {
        "router": dense_init(kr, d, (d, E), jnp.float32),
        "wi": dense_init(ki, d, (E, d, ff), dtype),
        "wo": dense_init(ko, ff, (E, ff, d), dtype),
    }
    if cfg.mlp_act.endswith("_glu"):
        p["wg"] = dense_init(kg, d, (E, d, ff), dtype)
    return p


def _expert_ffn(p, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """x: (E, C, d) -> (E, C, d), batched over experts."""
    h = jnp.einsum("ecd,edf->ecf", x, p["wi"])
    if cfg.mlp_act == "silu_glu":
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", x, p["wg"])
    elif cfg.mlp_act == "gelu_glu":
        h = jax.nn.gelu(h) * jnp.einsum("ecd,edf->ecf", x, p["wg"])
    elif cfg.mlp_act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, p["wo"])


def apply_moe(p, x: jax.Array, cfg: ArchConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d) -> (B, S, d), aux metrics (load-balance loss, drop rate)."""
    moe = cfg.moe
    B, S, d = x.shape
    E, k = moe.num_experts, moe.top_k
    C = expert_capacity(cfg, S)

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, k)  # (B,S,k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)  # Mixtral renorm

    # Switch aux loss: E * sum_e (fraction of tokens to e) * (mean prob of e)
    assign1 = jax.nn.one_hot(top_ids[..., 0], E, dtype=jnp.float32)
    frac = assign1.mean(axis=(0, 1))
    mean_prob = probs.mean(axis=(0, 1))
    aux_loss = E * jnp.sum(frac * mean_prob)

    def group_dispatch(xg, idsg, wg):
        # xg: (S, d); idsg: (S, k); wg: (S, k)
        ids = idsg.reshape(-1)  # (S*k,)
        tok = jnp.repeat(jnp.arange(S), k)
        w = wg.reshape(-1)
        order = jnp.argsort(ids, stable=True)
        ids_s, tok_s, w_s = ids[order], tok[order], w[order]
        # rank of each entry within its expert
        starts = jnp.searchsorted(ids_s, jnp.arange(E), side="left")  # (E,)
        rank = jnp.arange(S * k) - starts[ids_s]
        keep = rank < C
        slot = jnp.where(keep, ids_s * C + rank, E * C)  # dropped -> overflow slot
        bucket = jnp.zeros((E * C + 1, d), x.dtype)
        bucket = bucket.at[slot].add(xg[tok_s] * keep[:, None].astype(x.dtype))
        return bucket[:-1].reshape(E, C, d), (tok_s, w_s, keep, slot)

    buckets, scatter_info = jax.vmap(group_dispatch)(x, top_ids, top_w)
    # buckets: (B, E, C, d) -> merge groups into the capacity dim for one
    # big expert einsum: (E, B*C, d)
    eb = buckets.transpose(1, 0, 2, 3).reshape(E, B * C, d)
    eo = _expert_ffn(p, eb, cfg)
    out_buckets = eo.reshape(E, B, C, d).transpose(1, 0, 2, 3)  # (B,E,C,d)

    def group_combine(ob, info):
        tok_s, w_s, keep, slot = info
        obf = jnp.concatenate([ob.reshape(E * C, d), jnp.zeros((1, d), ob.dtype)])
        vals = obf[slot] * (w_s * keep)[:, None].astype(ob.dtype)
        return jnp.zeros((S, d), ob.dtype).at[tok_s].add(vals)

    y = jax.vmap(group_combine)(out_buckets, scatter_info)
    drop_rate = 1.0 - jnp.mean(
        jax.vmap(lambda info: info[2].astype(jnp.float32).mean())(scatter_info)
    )
    return y, {"moe_aux_loss": aux_loss, "moe_drop_rate": drop_rate}
