"""Model zoo: dense/GQA transformers, MoE, RWKV6, Mamba2/Zamba2 hybrid."""

from .model import Model, build_model, synthetic_batch, cross_entropy
