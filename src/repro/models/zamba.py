"""Zamba2-style hybrid: Mamba2 backbone with a *shared* attention block.

Layer layout: groups of ``shared_attn_every`` Mamba2 layers, each group
followed by one application of a single shared transformer block (attention
+ MLP, same weights every application — the Zamba2 weight-sharing trick).
The shared block consumes the concatenated [hidden, initial-embedding]
stream in the public model; we feed the hidden stream (simplification noted
in DESIGN.md §Arch-applicability).

Scan structure: outer scan over groups (the shared block's weights are
closed over, not scanned), inner scan over the group's Mamba2 layers.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import apply_mlp, apply_norm, embed_init, init_mlp, init_norm
from .mamba2 import apply_mamba2, init_mamba2, init_mamba2_state
from .transformer import (
    apply_block,
    apply_block_decode,
    init_block,
    logits_from_hidden,
)

PyTree = Any


def n_groups(cfg: ArchConfig) -> int:
    assert cfg.n_layers % cfg.shared_attn_every == 0
    return cfg.n_layers // cfg.shared_attn_every


def init_params(key, cfg: ArchConfig) -> PyTree:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, km, ks, ko = jax.random.split(key, 4)
    layer_keys = jax.random.split(km, cfg.n_layers)
    G, L = n_groups(cfg), cfg.shared_attn_every
    mamba = jax.vmap(lambda k: {"norm": init_norm(cfg), "mamba": init_mamba2(k, cfg, dtype)})(
        layer_keys
    )
    # reshape stacked layers to (G, L, ...)
    mamba = jax.tree.map(lambda a: a.reshape((G, L) + a.shape[1:]), mamba)
    p = {
        "embed": embed_init(ke, (cfg.padded_vocab_size, cfg.d_model), dtype),
        "mamba_layers": mamba,
        "shared_attn": init_block(ks, cfg),
        "final_norm": init_norm(cfg),
    }
    if not cfg.tied_embeddings:
        p["lm_head"] = embed_init(ko, (cfg.d_model, cfg.padded_vocab_size), dtype)
    return p


def _group_forward(cfg, shared_p, positions, attn_impl, remat, unroll=False):
    def mamba_body(h, layer_p):
        # fresh zero state per layer: the full sequence is processed at once
        states = init_mamba2_state(cfg, h.shape[0])
        out, _ = apply_mamba2(
            layer_p["mamba"], apply_norm(layer_p["norm"], h, cfg), cfg, states
        )
        return h + out, None

    if remat == "block":
        mamba_body = jax.checkpoint(mamba_body)

    def group_body(h, group_p):
        h, _ = jax.lax.scan(mamba_body, h, group_p, unroll=True if unroll else 1)
        h, _ = apply_block(shared_p, h, cfg, positions, attn_impl)
        return h, None

    return group_body


def forward(
    p: PyTree,
    cfg: ArchConfig,
    batch: Dict[str, jax.Array],
    attn_impl: str = "xla",
    remat: str = "block",
    unroll: bool = False,
    return_hidden: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    dtype = jnp.dtype(cfg.activation_dtype)
    x = jnp.take(p["embed"], batch["tokens"], axis=0).astype(dtype)
    positions = jnp.arange(x.shape[1])
    group_body = _group_forward(
        cfg, p["shared_attn"], positions, attn_impl, remat, unroll
    )
    x, _ = jax.lax.scan(group_body, x, p["mamba_layers"], unroll=True if unroll else 1)
    x = apply_norm(p["final_norm"], x, cfg)
    if return_hidden:
        return x, {}
    return logits_from_hidden(p, cfg, x), {}


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> PyTree:
    from .mamba2 import ssm_dims

    G = n_groups(cfg)
    L = cfg.shared_attn_every
    s = cfg.ssm
    d_in, H, P, N = ssm_dims(cfg)
    dtype = jnp.dtype(cfg.activation_dtype)
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "conv": jnp.zeros((G, L, batch, s.conv_width - 1, d_in + 2 * s.n_groups * N), jnp.float32),
        "ssm": jnp.zeros((G, L, batch, H, N, P), jnp.float32),
        # one KV cache per shared-attention application
        "k": jnp.zeros((G, batch, max_len, K, hd), dtype),
        "v": jnp.zeros((G, batch, max_len, K, hd), dtype),
    }


def decode_step(
    p: PyTree,
    cfg: ArchConfig,
    cache: PyTree,
    batch: Dict[str, jax.Array],
    position: jax.Array,
    unroll: bool = False,
) -> Tuple[jax.Array, PyTree]:
    dtype = jnp.dtype(cfg.activation_dtype)
    x = jnp.take(p["embed"], batch["tokens"], axis=0).astype(dtype)

    def mamba_body(h, inputs):
        layer_p, conv, ssm = inputs
        out, ns = apply_mamba2(
            layer_p["mamba"],
            apply_norm(layer_p["norm"], h, cfg),
            cfg,
            {"conv": conv, "ssm": ssm},
        )
        return h + out, (ns["conv"], ns["ssm"])

    def group_body(h, inputs):
        group_p, conv, ssm, k_cache, v_cache = inputs
        h, (conv_n, ssm_n) = jax.lax.scan(
            mamba_body, h, (group_p, conv, ssm), unroll=True if unroll else 1
        )
        h, attn_cache = apply_block_decode(
            p["shared_attn"], h, cfg, {"k": k_cache, "v": v_cache}, position, position
        )
        return h, (conv_n, ssm_n, attn_cache["k"], attn_cache["v"])

    x, (conv_n, ssm_n, k_n, v_n) = jax.lax.scan(
        group_body,
        x,
        (p["mamba_layers"], cache["conv"], cache["ssm"], cache["k"], cache["v"]),
        unroll=True if unroll else 1,
    )
    x = apply_norm(p["final_norm"], x, cfg)
    logits = logits_from_hidden(p, cfg, x)
    return logits, {"conv": conv_n, "ssm": ssm_n, "k": k_n, "v": v_n}
