"""Unified model interface: init / forward / loss / cache / decode per family.

``build_model(cfg)`` returns a :class:`Model` whose methods dispatch to the
family-specific assembly (transformer / rwkv / zamba hybrid).  The loss
handles the modality quirks (VLM patch prefix, MusicGen codebook heads).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import rwkv_lm, transformer, zamba

PyTree = Any


def _family_module(cfg: ArchConfig):
    if cfg.family == "ssm" and cfg.rwkv is not None:
        return rwkv_lm
    if cfg.family == "hybrid":
        return zamba
    return transformer


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean next-token CE in f32.  logits: (..., V), targets: (...) int.

    Partition-friendly formulation: both the logsumexp and the gold-logit
    term are reductions over the vocab axis, so a vocab-sharded (tensor-
    parallel) lm_head needs only tiny (B, S) cross-shard reductions — no
    full-logits all-gather (a take_along_axis gather here costs a 100+ GB
    collective on the 256k-vocab archs)."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    logz = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    return (logz - gold).mean()


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    attn_impl: str = "xla"
    remat: str = "block"
    unroll: bool = False  # unroll layer scans (dry-run cost calibration)
    # optional callable ndim -> Sharding: constrains logits in loss() so the
    # vocab-parallel CE stays sharded under pjit (set by the launchers)
    logits_sharding: Optional[Callable[[int], Any]] = None
    # chunked cross-entropy: compute logits + CE over sequence chunks of this
    # size inside a rematerialized scan — the full (B, S, V) logits tensor is
    # never materialized (perf lever: memory term / logits temp buffers)
    loss_chunk: Optional[int] = None

    # -- parameters ----------------------------------------------------------
    def init(self, key) -> PyTree:
        return _family_module(self.cfg).init_params(key, self.cfg)

    def init_shapes(self) -> PyTree:
        """Parameter ShapeDtypeStructs without allocation (for dry-runs)."""
        return jax.eval_shape(self.init, jax.random.key(0))

    # -- forward / loss --------------------------------------------------------
    def forward(self, params: PyTree, batch: Dict[str, jax.Array]):
        return _family_module(self.cfg).forward(
            params, self.cfg, batch, self.attn_impl, self.remat, self.unroll
        )

    def _targets_and_hidden_slice(self, batch, seq_len: int):
        """(hidden slice bounds, targets) aligned for next-token prediction."""
        cfg = self.cfg
        if cfg.n_codebooks > 1:
            return (0, seq_len - 1), batch["targets"][:, 1:]
        if cfg.frontend == "vlm":
            P = cfg.num_patches
            S = batch["tokens"].shape[1]
            return (P - 1, P - 1 + S - 1), batch["tokens"][:, 1:]
        return (0, seq_len - 1), batch["tokens"][:, 1:]

    def loss(self, params: PyTree, batch: Dict[str, jax.Array]):
        cfg = self.cfg
        if self.loss_chunk is not None:
            return self._chunked_loss(params, batch)
        logits, aux = self.forward(params, batch)
        if self.logits_sharding is not None:
            logits = jax.lax.with_sharding_constraint(
                logits, self.logits_sharding(logits.ndim)
            )
        if cfg.n_codebooks > 1:
            # MusicGen: logits (B,S,nq,V), next-frame targets (B,S,nq)
            ce = cross_entropy(logits[:, :-1], batch["targets"][:, 1:])
        elif cfg.frontend == "vlm":
            # patch prefix: prediction of token i sits at index P - 1 + i
            P = cfg.num_patches
            S = batch["tokens"].shape[1]
            token_logits = logits[:, P - 1 : P - 1 + S - 1]
            ce = cross_entropy(token_logits, batch["tokens"][:, 1:])
        else:
            ce = cross_entropy(logits[:, :-1], batch["tokens"][:, 1:])
        loss = ce
        if "moe_aux_loss" in aux:
            loss = loss + 0.01 * aux["moe_aux_loss"]
        metrics = {"ce": ce, **aux}
        return loss, metrics

    def _chunked_loss(self, params: PyTree, batch: Dict[str, jax.Array]):
        """CE via a rematerialized scan over sequence chunks: the (B, S, V)
        logits are never materialized at once (only (B, chunk, V))."""
        cfg = self.cfg
        h, aux = _family_module(cfg).forward(
            params, cfg, batch, self.attn_impl, self.remat, self.unroll,
            return_hidden=True,
        )
        (lo, hi) = self._targets_and_hidden_slice(batch, h.shape[1])[0]
        targets = self._targets_and_hidden_slice(batch, h.shape[1])[1]
        h = h[:, lo:hi]
        T = h.shape[1]
        C = min(self.loss_chunk, T)
        n = T // C
        rem = T - n * C

        from .transformer import logits_from_hidden

        def head_ce(h_c, t_c):
            logits = logits_from_hidden(params, cfg, h_c)
            if self.logits_sharding is not None:
                logits = jax.lax.with_sharding_constraint(
                    logits, self.logits_sharding(logits.ndim)
                )
            logits = logits.astype(jnp.float32)
            m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
            logz = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
            onehot = jax.nn.one_hot(t_c, logits.shape[-1], dtype=logits.dtype)
            gold = jnp.sum(logits * onehot, axis=-1)
            return jnp.sum(logz - gold)

        head_ce = jax.checkpoint(head_ce)

        def body(acc, inp):
            h_c, t_c = inp
            return acc + head_ce(h_c, t_c), None

        hs = h[:, : n * C].reshape(h.shape[0], n, C, h.shape[-1]).transpose(1, 0, 2, 3)
        ts = targets[:, : n * C]
        ts = ts.reshape((ts.shape[0], n, C) + ts.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, ts.ndim + 1))
        )
        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ts))
        count = targets.size if cfg.n_codebooks == 1 else targets.size
        if rem:
            total = total + head_ce(h[:, n * C :], targets[:, n * C :])
        ce = total / count
        loss = ce
        if "moe_aux_loss" in aux:
            loss = loss + 0.01 * aux["moe_aux_loss"]
        return loss, {"ce": ce, **aux}

    # -- serving ---------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> PyTree:
        return _family_module(self.cfg).init_cache(self.cfg, batch, max_len)

    def decode_step(
        self,
        params: PyTree,
        cache: PyTree,
        batch: Dict[str, jax.Array],
        position: jax.Array,
    ):
        return _family_module(self.cfg).decode_step(
            params, self.cfg, cache, batch, position, self.unroll
        )


def build_model(
    cfg: ArchConfig, attn_impl: str = "xla", remat: str = "block", unroll: bool = False
) -> Model:
    return Model(cfg, attn_impl, remat, unroll)


def synthetic_batch(cfg: ArchConfig, batch: int, seq: int, key=None) -> Dict[str, jax.Array]:
    """A synthetic batch with the right structure for the family (tests/benches)."""
    key = key if key is not None else jax.random.key(0)
    k1, k2 = jax.random.split(key)
    dtype = jnp.dtype(cfg.activation_dtype)
    if cfg.frontend == "audio":
        return {
            "frame_embeds": jax.random.normal(k1, (batch, seq, cfg.d_model), dtype),
            "targets": jax.random.randint(k2, (batch, seq, cfg.n_codebooks), 0, cfg.vocab_size),
        }
    out = {"tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size)}
    if cfg.frontend == "vlm":
        out["patch_embeds"] = jax.random.normal(
            k2, (batch, cfg.num_patches, cfg.d_model), dtype
        )
    return out
