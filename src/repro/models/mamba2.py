"""Mamba2 (SSD) blocks — the state-space backbone of Zamba2.

Implements the chunked SSD algorithm (Dao & Gu 2024): the selective SSM
    h_t = exp(A * dt_t) h_{t-1} + dt_t * B_t ⊗ x_t
    y_t = C_t · h_t + D * x_t
is evaluated chunk-parallel: within a chunk of length Q the causal decay
matrix L[t,s] = exp(sum_{j=s+1..t} A dt_j) turns the recurrence into two
matmuls (C B^T ⊙ L) x; across chunks a small (H, N, P) state is carried by a
scan.  This is also the blueprint of the Pallas kernel (repro/kernels/ssd).

Structure per block (Mamba2 paper / Zamba2 usage):
  in_proj -> [z | x | B | C | dt], causal depthwise conv on (x, B, C),
  SSD, gated (silu(z)) output norm, out_proj.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import apply_norm, dense_init, init_norm

PyTree = Dict[str, jax.Array]


def ssm_dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    """(d_inner, n_heads, head_dim, state_dim)."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    return d_in, H, s.head_dim, s.state_dim


def init_mamba2(key, cfg: ArchConfig, dtype) -> PyTree:
    s = cfg.ssm
    d = cfg.d_model
    d_in, H, P, N = ssm_dims(cfg)
    G = s.n_groups
    ks = jax.random.split(key, 5)
    proj_out = 2 * d_in + 2 * G * N + H  # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], d, (d, proj_out), dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, d_in + 2 * G * N), jnp.float32) * 0.1),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 1e-2, jnp.float32))),
        "norm": jnp.ones((d_in,), jnp.float32),  # gated RMSNorm scale
        "out_proj": dense_init(ks[2], d_in, (d_in, d), dtype),
    }


def causal_conv(x: jax.Array, w: jax.Array, state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.  x: (B,S,C), w: (K,C), state: (B,K-1,C)."""
    K = w.shape[0]
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1) :, :] if K > 1 else state
    return jax.nn.silu(out), new_state


def ssd_chunked(
    xh: jax.Array,  # (B, S, H, P) inputs per head
    dt: jax.Array,  # (B, S, H) positive step sizes
    A: jax.Array,  # (H,) negative decay rates
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    state0: jax.Array,  # (B, H, N, P)
    chunk: int,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Heads are assigned to B/C groups round-robin."""
    B, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:
        # pad with dt=0 steps: decay 1, zero input -> state unaffected
        pad = Q - S % Q
        padfn = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        xh, dt, Bm, Cm = padfn(xh), padfn(dt), padfn(Bm), padfn(Cm)
        S = S + pad
    n = S // Q
    h_per_g = H // G
    # expand groups to heads
    Bh = jnp.repeat(Bm, h_per_g, axis=2)  # (B,S,H,N)
    Ch = jnp.repeat(Cm, h_per_g, axis=2)

    la = dt * A[None, None, :]  # (B,S,H) log-decay per step (negative)
    xw = xh * dt[..., None]  # dt-weighted input

    def split(t, shape):
        return t.reshape((B, n, Q) + shape).transpose(1, 0, 2, *range(3, 3 + len(shape)))

    def chunk_step(state, inp):
        xc, lac, bc, cc = inp  # (B,Q,H,P), (B,Q,H), (B,Q,H,N), (B,Q,H,N)
        cla = jnp.cumsum(lac, axis=1)  # (B,Q,H) cumulative log decay (incl. t)
        # inter-chunk: y_inter[t] = exp(cla_t) * C_t . state0
        dec = jnp.exp(cla)  # <= 1
        y_inter = jnp.einsum("bqhn,bhnp->bqhp", cc * dec[..., None], state)
        # intra-chunk: L[t,s] = exp(cla_t - cla_s) for s <= t (scalar per head)
        diff = cla[:, :, None, :] - cla[:, None, :, :]  # (B,Q,Q,H)
        mask = jnp.tril(jnp.ones((Q, Q), bool))  # s <= t (includes diagonal)
        L = jnp.exp(jnp.where(mask[None, :, :, None], diff, -jnp.inf))
        scores = jnp.einsum("bqhn,bshn->bqsh", cc, bc) * L
        y_intra = jnp.einsum("bqsh,bshp->bqhp", scores, xc)
        # state: state' = exp(cla_Q) state + sum_s exp(cla_Q - cla_s) B_s x_s^T
        dec_all = jnp.exp(cla[:, -1])  # (B,H)
        carry = jnp.exp(cla[:, -1][:, None] - cla)  # (B,Q,H) <= 1
        state_new = state * dec_all[..., None, None] + jnp.einsum(
            "bqhn,bqhp->bhnp", bc * carry[..., None], xc
        )
        return state_new, y_inter + y_intra

    state, ys = jax.lax.scan(
        chunk_step,
        state0,
        (split(xw, (H, P)), split(la, (H,)), split(Bh, (H, N)), split(Ch, (H, N))),
    )
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y[:, :S_orig], state


def apply_mamba2(
    p: PyTree,
    x: jax.Array,  # (B, S, d)
    cfg: ArchConfig,
    state: Dict[str, jax.Array],
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    s = cfg.ssm
    B, S, d = x.shape
    d_in, H, P, N = ssm_dims(cfg)
    G = s.n_groups
    proj = x @ p["in_proj"]
    z, xs, bm, cm, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + G * N, 2 * d_in + 2 * G * N], axis=-1
    )
    conv_in = jnp.concatenate([xs, bm, cm], axis=-1)
    conv_out, conv_state = causal_conv(conv_in, p["conv_w"], state["conv"])
    xs, bm, cm = jnp.split(conv_out, [d_in, d_in + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,)
    xh = xs.reshape(B, S, H, P).astype(jnp.float32)
    y, ssm_state = ssd_chunked(
        xh,
        dt,
        A,
        bm.reshape(B, S, G, N).astype(jnp.float32),
        cm.reshape(B, S, G, N).astype(jnp.float32),
        state["ssm"],
        s.chunk,
    )
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_in)
    # gated RMSNorm (Mamba2)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    ms = (y * y).mean(-1, keepdims=True)
    y = (y * jax.lax.rsqrt(ms + 1e-6) * p["norm"]).astype(x.dtype)
    return y @ p["out_proj"], {"conv": conv_state, "ssm": ssm_state}


def init_mamba2_state(cfg: ArchConfig, batch: int) -> Dict[str, jax.Array]:
    s = cfg.ssm
    d_in, H, P, N = ssm_dims(cfg)
    G = s.n_groups
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, d_in + 2 * G * N), jnp.float32),
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
    }


def reference_ssd(xh, dt, A, Bm, Cm, state0):
    """O(S) sequential oracle for tests."""
    B, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Bh = jnp.repeat(Bm, H // G, axis=2)
    Ch = jnp.repeat(Cm, H // G, axis=2)

    def step(h, t):
        a = jnp.exp(dt[:, t] * A[None, :])  # (B,H)
        xw = xh[:, t] * dt[:, t][..., None]  # (B,H,P)
        h = h * a[..., None, None] + Bh[:, t][..., None] * xw[:, :, None, :]
        y = jnp.einsum("bhn,bhnp->bhp", Ch[:, t], h)
        return h, y

    h, ys = jax.lax.scan(step, state0, jnp.arange(S))
    return ys.transpose(1, 0, 2, 3), h
