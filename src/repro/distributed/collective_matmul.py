"""Collective matmul: overlap tensor-parallel communication with compute.

GSPMD emits all-gather -> matmul sequentially; the classic "collective
matmul" (Wang et al., ASPLOS'23) decomposes the gather into ring steps and
overlaps each shard's matmul with the next shard's collective-permute.  On
TPU the permute rides exactly the ICI rings the paper's axis planner
assigns, so the overlap efficiency is the ring quality — wrapped contiguous
rings (planned assignment) sustain 2 concurrent directions, strided/chain
embeddings stall the pipeline (the TPU analogue of elongated partitions).

Implemented with shard_map + jax.lax.ppermute:

* ``allgather_matmul(x, w, axis)``  — y = allgather(x, axis) @ w, with x
  sharded on its contracting rows and w sharded on the same rows; each ring
  step matmuls the resident shard while permuting the next one.
* ``matmul_reducescatter(x, w, axis)`` — y = reducescatter(x @ w) with w
  sharded on columns: partial products are accumulated around the ring.

Numerics are exact (same adds in a different order).  Tests validate on a
1-device degenerate mesh and on an 8-device subprocess mesh.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def _ring_perm(axis_size: int, shift: int = 1):
    return [(i, (i + shift) % axis_size) for i in range(axis_size)]


def allgather_matmul(x: jax.Array, w: jax.Array, mesh: Mesh, axis: str) -> jax.Array:
    """y = (all-gather of x along `axis`) @ w.

    x: (m_shard, k) sharded over rows on `axis`; w: (k, n) replicated.
    Returns y: (m, n) fully gathered — but computed so each ring step's
    ppermute overlaps the previous shard's matmul (no monolithic gather).
    """
    n_shards = mesh.shape[axis]

    def body(x_blk, w_full):
        idx = jax.lax.axis_index(axis)
        # unrolled python loop: static ring schedule (n_shards steps); each
        # ppermute is independent of the current step's matmul, so the
        # scheduler overlaps them
        blk = x_blk
        results = []
        for i in range(n_shards):
            src = (idx - i) % n_shards
            y_i = blk @ w_full  # compute current resident shard
            results.append((src, y_i))
            if i + 1 < n_shards:
                blk = jax.lax.ppermute(blk, axis, _ring_perm(n_shards))
        # place each partial into its row position
        m_shard = x_blk.shape[0]
        out = jnp.zeros((m_shard * n_shards, w_full.shape[1]), y_i.dtype)
        for src, y_i in results:
            out = jax.lax.dynamic_update_slice(
                out, y_i, (src * m_shard, jnp.int32(0))
            )
        return out

    spec_x = P(axis, None)
    return shard_map(
        body, mesh=mesh, in_specs=(spec_x, P(None, None)), out_specs=P(None, None),
        check_rep=False,
    )(x, w)


def matmul_reducescatter(x: jax.Array, w: jax.Array, mesh: Mesh, axis: str) -> jax.Array:
    """y = reduce-scatter(x @ w) along `axis` rows of the output.

    x: (m, k_shard) sharded on contracting dim; w: (k_shard, n) sharded on
    rows.  Each rank accumulates its output shard by rotating partials
    around the ring — each ppermute overlaps the next local matmul.
    Returns y: (m_shard, n) sharded over rows on `axis`.
    """
    n_shards = mesh.shape[axis]

    def body(x_blk, w_blk):
        idx = jax.lax.axis_index(axis)
        m = x_blk.shape[0]
        m_shard = m // n_shards

        def rows(b):
            return jax.lax.dynamic_slice_in_dim(x_blk, b * m_shard, m_shard, 0)

        # Ring reduce-scatter schedule: the accumulator that starts at rank s
        # carries output block (s-1); after t hops rank r holds block
        # (r - t - 1) and adds its own contribution; after n-1 hops rank r
        # holds its own block r, fully reduced.  Each hop's ppermute overlaps
        # the next local matmul.
        acc = rows((idx - 1) % n_shards) @ w_blk
        for t in range(1, n_shards):
            acc = jax.lax.ppermute(acc, axis, _ring_perm(n_shards))
            b = (idx - t - 1) % n_shards
            acc = acc + rows(b) @ w_blk
        return acc

    return shard_map(
        body, mesh=mesh, in_specs=(P(None, axis), P(axis, None)),
        out_specs=P(axis, None), check_rep=False,
    )(x, w)
