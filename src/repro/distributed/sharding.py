"""GSPMD sharding rules: parameter / batch / cache PartitionSpecs per arch.

Policy (Megatron-TP x ZeRO-FSDP hybrid, the standard large-model recipe):

* "model" axis — tensor parallelism: attention heads, FFN hidden, experts
  (expert parallelism when E divides the axis), vocab where divisible.
* fsdp axes ("pod","data" on the multi-pod mesh) — parameters and optimizer
  state sharded on a non-TP dimension (ZeRO-3); XLA inserts the all-gathers.
* batch is sharded over the fsdp axes (pure data parallelism for
  activations).

Every rule degrades gracefully: a dimension is sharded only when divisible
by the full axis size — otherwise it is replicated (e.g. InternVL2's 14
heads on a 16-way model axis).  KV caches fall back to sequence sharding
when kv_heads don't divide the model axis (nemotron: 8 kv heads, 16-way TP
-> the 32k cache shards over sequence instead).

The *physical* meaning of the mesh axes (which ICI rings they map to) is
decided by the paper-driven axis assignment in launch/mesh.py.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

PyTree = Any


def axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= mesh.shape[n]
        return out
    return mesh.shape[name]


def _shard_if(dim: int, axis, mesh: Mesh):
    return axis if axis is not None and dim % axis_size(mesh, axis) == 0 else None


def _flatten_spec_axes(spec) -> list:
    """Mesh-axis names referenced by one PartitionSpec-style entry tuple."""
    flat = []
    for entry in spec:
        if entry is None:
            continue
        flat.extend(entry if isinstance(entry, tuple) else (entry,))
    return flat


def validate_partition_spec(spec, mesh_axes) -> None:
    """Reject ill-formed PartitionSpec-style rules.

    ``spec`` is a sequence of per-dimension entries (``None``, an axis
    name, or a tuple of axis names); ``mesh_axes`` is the mesh's axis-name
    collection (a ``Mesh``, a dict of sizes, or an iterable of names).
    Raises ``ValueError`` when a mesh axis is reused across dimensions (or
    twice within one dimension group) — GSPMD would reject it at lowering,
    but a cost model fed such a rule silently double-counts the axis and
    prices wrong collective volumes — and when a rule references an axis
    that does not exist on the mesh.
    """
    names = getattr(mesh_axes, "axis_names", None)
    if names is None:
        names = tuple(mesh_axes)
    known = set(names)
    flat = _flatten_spec_axes(spec)
    unknown = [a for a in flat if a not in known]
    if unknown:
        raise ValueError(
            f"partition spec {tuple(spec)} references axes {unknown} absent "
            f"from mesh axes {tuple(names)}"
        )
    if len(flat) != len(set(flat)):
        dupes = sorted({a for a in flat if flat.count(a) > 1})
        raise ValueError(
            f"partition spec {tuple(spec)} reuses mesh axes {dupes} across "
            f"conflicting tensor dimensions"
        )


class ShardingRules:
    """Computes PartitionSpecs for a (cfg, mesh) pair."""

    def __init__(self, cfg: ArchConfig, mesh: Mesh, fsdp_axes: Optional[Tuple[str, ...]] = None,
                 model_axis: str = "model", zero_stage: int = 3):
        """``zero_stage``: 3 = params+optimizer FSDP-sharded (default);
        1 = params replicated over the data axes (TP-sharded only), optimizer
        moments still FSDP-sharded — eliminates per-layer weight/activation
        gathers at the price of replicated bf16 params (viable when
        params*2/TP fits HBM; the dW gradients then reduce locally)."""
        self.cfg = cfg
        self.mesh = mesh
        self.zero_stage = zero_stage
        names = mesh.axis_names
        if fsdp_axes is None:
            fsdp_axes = tuple(n for n in names if n != model_axis)
        unknown = [a for a in fsdp_axes if a not in names]
        if unknown:
            raise ValueError(
                f"fsdp_axes {tuple(fsdp_axes)} reference axes {unknown} absent "
                f"from mesh axes {tuple(names)}"
            )
        if model_axis in names and model_axis in fsdp_axes:
            raise ValueError(
                f"model_axis {model_axis!r} also appears in fsdp_axes "
                f"{tuple(fsdp_axes)}: one mesh axis cannot shard both a "
                f"tensor-parallel dimension and the FSDP dimension of the "
                f"same parameter (the rules would emit conflicting specs "
                f"with silently wrong collective volumes)"
            )
        if len(set(fsdp_axes)) != len(tuple(fsdp_axes)):
            raise ValueError(f"fsdp_axes {tuple(fsdp_axes)} repeat a mesh axis")
        self.fsdp: Tuple[str, ...] = tuple(fsdp_axes)
        self.model = model_axis if model_axis in names else None

    # -- helpers ---------------------------------------------------------------
    def fs(self, dim: int):
        """fsdp sharding for a dimension (whole group or nothing)."""
        if self.zero_stage < 3:
            return None
        return _shard_if(dim, self.fsdp, self.mesh)

    def fs_opt(self, dim: int):
        """Optimizer-state sharding (always FSDP — ZeRO-1 keeps moments sharded)."""
        return _shard_if(dim, self.fsdp, self.mesh)

    def opt_specs(self, params_shapes: PyTree) -> PyTree:
        """Optimizer-moment specs: FSDP-sharded regardless of zero stage."""
        if self.zero_stage >= 3:
            return self.params_specs(params_shapes)
        full = ShardingRules(
            self.cfg, self.mesh, self.fsdp,
            self.model if self.model is not None else "__none__",
            zero_stage=3,
        )
        return full.params_specs(params_shapes)

    def tp(self, dim: int):
        return _shard_if(dim, self.model, self.mesh)

    def dp_spec(self) -> Tuple[str, ...]:
        return self.fsdp

    # -- parameters ---------------------------------------------------------------
    def param_spec(self, path: Tuple[str, ...], shape: Tuple[int, ...]) -> P:
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        # leading stacked-layer dims are never sharded
        stack = 0
        if "layers" in names or "mamba_layers" in names:
            stack = 2 if "mamba_layers" in names else 1
        core = shape[stack:]
        leaf = names[-1] if names else ""
        spec = [None] * stack + list(self._core_spec(names, leaf, core))
        validate_partition_spec(spec, self.mesh)
        return P(*spec)

    def _core_spec(self, names, leaf, core) -> Sequence:
        cfg, mesh = self.cfg, self.mesh
        if len(core) <= 1:
            return [None] * len(core)
        # embeddings / heads
        if leaf == "embed":
            V, d = core
            return [self.tp(V), self.fs(d)]
        if leaf in ("lm_head",):
            d, V = core
            return [self.fs(d), self.tp(V)]
        if leaf == "lm_heads":  # (nq, d, V)
            _, d, V = core
            return [None, self.fs(d), self.tp(V)]
        # attention
        if leaf == "wq":
            if len(core) == 3:
                d, H, hd = core
                return [self.fs(d), self.tp(H), None]
        if leaf in ("wk", "wv") and len(core) == 3:
            d, K, hd = core
            return [self.fs(d), self.tp(K), None]
        if leaf == "wo" and len(core) == 3:
            H, hd, d = core
            return [self.tp(H), None, self.fs(d)]
        if leaf in ("bq", "bk", "bv"):
            return [self.tp(core[0]), None]
        # MoE
        if "moe" in names:
            if leaf == "router":
                return [self.fs(core[0]), None]
            E = core[0]
            ep = self.tp(E)
            if leaf in ("wi", "wg"):  # (E, d, ff)
                _, d, ff = core
                if ep is not None:
                    return [ep, self.fs(d), None]
                return [None, self.fs(d), self.tp(ff)]
            if leaf == "wo":  # (E, ff, d)
                _, ff, d = core
                if ep is not None:
                    return [ep, None, self.fs(d)]
                return [None, self.tp(ff), self.fs(d)]
        # dense MLP (and rwkv channel mix wk/wv with 2D shapes)
        if leaf in ("wi", "wg") and len(core) == 2:
            d, ff = core
            return [self.fs(d), self.tp(ff)]
        if leaf == "wo" and len(core) == 2:
            ff, d = core
            return [self.tp(ff), self.fs(d)]
        if leaf == "wk" and len(core) == 2 and "channel_mix" in names:
            d, ff = core
            return [self.fs(d), self.tp(ff)]
        if leaf == "wv" and len(core) == 2 and "channel_mix" in names:
            ff, d = core
            return [self.tp(ff), self.fs(d)]
        # rwkv time mix square projections
        if leaf in ("wr", "wk", "wv", "wg") and len(core) == 2:
            d, d2 = core
            return [self.fs(d), self.tp(d2)]
        if leaf == "wo" and len(core) == 2:
            d2, d = core
            return [self.tp(d2), self.fs(d)]
        if leaf in ("wa", "wb"):
            return [self.fs(core[0]), None]
        # mamba projections
        if leaf == "in_proj":
            d, po = core
            return [self.fs(d), self.tp(po)]
        if leaf == "out_proj":
            d_in, d = core
            return [self.tp(d_in), self.fs(d)]
        # fallback: fsdp on the largest dim
        big = max(range(len(core)), key=lambda i: core[i])
        spec = [None] * len(core)
        spec[big] = self.fs(core[big])
        return spec

    def params_specs(self, params_shapes: PyTree) -> PyTree:
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: self.param_spec(path, leaf.shape), params_shapes
        )

    # -- batches ---------------------------------------------------------------
    def batch_specs(self, batch_shapes: Dict[str, Any]) -> Dict[str, P]:
        out = {}
        for k, v in batch_shapes.items():
            shape = v.shape
            dp = _shard_if(shape[0], self.fsdp, self.mesh)
            out[k] = P(*([dp] + [None] * (len(shape) - 1)))
        return out

    def logits_spec(self, ndim: int) -> P:
        """Sharding for the lm logits: batch over dp, vocab over model
        (only when the padded vocab divides the model axis)."""
        v_axis = self.tp(self.cfg.padded_vocab_size)
        dp = self.fsdp
        return P(*([dp] + [None] * (ndim - 2) + [v_axis]))

    # -- caches ---------------------------------------------------------------
    def cache_specs(self, cache_shapes: PyTree) -> PyTree:
        def spec(path, leaf):
            names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
            leafname = names[-1]
            shape = leaf.shape
            if leafname in ("k", "v"):
                # (L, B, S, K, hd) or zamba (G, B, S, K, hd)
                L, B, S, K, hd = shape
                dp = _shard_if(B, self.fsdp, self.mesh)
                k_axis = self.tp(K)
                s_axis = self.tp(S) if k_axis is None else None
                return P(None, dp, s_axis, k_axis, None)
            if leafname == "wkv":  # (L, B, H, P, P)
                _, B, H, _, _ = shape
                dp = _shard_if(B, self.fsdp, self.mesh)
                return P(None, dp, self.tp(H), None, None)
            if leafname == "ssm":  # (G, L, B, H, N, P)
                dp = _shard_if(shape[2], self.fsdp, self.mesh)
                return P(None, None, dp, self.tp(shape[3]), None, None)
            if leafname == "conv":  # (G, L, B, K-1, C)
                dp = _shard_if(shape[2], self.fsdp, self.mesh)
                return P(None, None, dp, None, self.tp(shape[4]))
            if leafname in ("shift_t", "shift_c"):  # (L, B, d)
                dp = _shard_if(shape[1], self.fsdp, self.mesh)
                return P(None, dp, None)
            return P(*([None] * leaf.ndim))

        return jax.tree_util.tree_map_with_path(spec, cache_shapes)


def named(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)
