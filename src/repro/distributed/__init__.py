from .sharding import ShardingRules, named
