from .fault_tolerance import (
    HeartbeatMonitor,
    StragglerTracker,
    ElasticPlan,
    plan_mesh,
    TrainingSupervisor,
    SupervisorReport,
)
