"""Fault tolerance runtime: failure detection, restart, elastic rescale,
straggler mitigation.

On a real multi-pod deployment these hooks sit in the coordinator process
(jax.distributed); here the mechanisms are implemented against an injectable
clock / event source so they are fully testable on one CPU:

* :class:`HeartbeatMonitor`   — per-worker heartbeats, timeout -> failed.
* :class:`StragglerTracker`   — EWMA of per-worker step times; workers
  slower than ``factor`` x median are flagged; mitigation advice is either
  "rebalance" (shrink their data shard) or "evict" (treat as failed).
* :class:`ElasticPlan`        — given alive-worker count, choose the next
  mesh (largest feasible (pods, data, model) grid) — restore-with-reshard
  does the actual state movement (checkpoint/manager.py).
* :class:`TrainingSupervisor` — ties it together around a step function:
  run steps, checkpoint periodically, on failure restore the latest commit
  and continue (optionally on a shrunk mesh).
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

PyTree = Any


# ---------------------------------------------------------------------------
# Failure detection
# ---------------------------------------------------------------------------
class HeartbeatMonitor:
    def __init__(self, workers: List[str], timeout: float, clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        now = clock()
        self.last_seen: Dict[str, float] = {w: now for w in workers}
        self.failed: set = set()

    def beat(self, worker: str) -> None:
        if worker in self.failed:
            return  # a failed worker must rejoin via `rejoin`
        self.last_seen[worker] = self.clock()

    def rejoin(self, worker: str) -> None:
        self.failed.discard(worker)
        self.last_seen[worker] = self.clock()

    def check(self) -> List[str]:
        """Returns newly-failed workers."""
        now = self.clock()
        newly = [
            w
            for w, t in self.last_seen.items()
            if w not in self.failed and now - t > self.timeout
        ]
        self.failed.update(newly)
        return newly

    @property
    def alive(self) -> List[str]:
        return [w for w in self.last_seen if w not in self.failed]


def failure_cells(
    monitor: HeartbeatMonitor, worker_cells: Dict[str, Tuple[int, ...]]
) -> List[Tuple[int, ...]]:
    """Torus cells of the workers ``monitor.check()`` newly declares dead.

    The glue between heartbeat detection and the network scheduler: feed
    the returned cells to
    :meth:`repro.network.scheduler.SchedulerService.inject_failure` (via
    :func:`repro.network.scheduler.apply_monitor_failures`) and the
    scheduler evacuates the jobs running on them, requeues them with their
    remaining duration, and keeps the cells out of the free pool until a
    ``Reclaim`` repairs them.  Workers without a cell assignment (e.g.
    spares) are skipped."""
    return [tuple(worker_cells[w]) for w in monitor.check() if w in worker_cells]


# ---------------------------------------------------------------------------
# Straggler mitigation
# ---------------------------------------------------------------------------
@dataclass
class StragglerTracker:
    alpha: float = 0.3  # EWMA coefficient
    factor: float = 1.5  # flag threshold vs median
    evict_factor: float = 3.0
    ewma: Dict[str, float] = field(default_factory=dict)

    def record(self, worker: str, step_time: float) -> None:
        prev = self.ewma.get(worker)
        self.ewma[worker] = (
            step_time if prev is None else self.alpha * step_time + (1 - self.alpha) * prev
        )

    def median(self) -> float:
        """Lower median — robust when up to half the fleet is slow."""
        vals = sorted(self.ewma.values())
        if not vals:
            return 0.0
        return vals[(len(vals) - 1) // 2]

    def stragglers(self) -> Dict[str, str]:
        """worker -> advice ('rebalance' | 'evict')."""
        med = self.median()
        out = {}
        if med <= 0:
            return out
        for w, t in self.ewma.items():
            if t > self.evict_factor * med:
                out[w] = "evict"
            elif t > self.factor * med:
                out[w] = "rebalance"
        return out

    def rebalanced_shares(self, workers: List[str]) -> Dict[str, float]:
        """Data shares inversely proportional to speed (sum to 1)."""
        inv = {w: 1.0 / self.ewma.get(w, self.median() or 1.0) for w in workers}
        total = sum(inv.values())
        return {w: v / total for w, v in inv.items()}


# ---------------------------------------------------------------------------
# Elastic rescale planning
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ElasticPlan:
    pods: int
    data: int
    model: int

    @property
    def chips(self) -> int:
        return self.pods * self.data * self.model


def plan_mesh(alive_chips: int, model_parallel: int, pod_size: int = 256) -> ElasticPlan:
    """Largest (pods, data, model) grid fitting the alive chip count.

    Keeps model-parallel degree fixed (weights layouts stay valid) and
    shrinks data parallelism — the standard elastic policy.
    """
    if alive_chips < model_parallel:
        raise ValueError("fewer chips than the model-parallel degree")
    pods = max(1, alive_chips // pod_size)
    while pods > 1:
        per_pod = alive_chips // pods
        if per_pod * pods >= model_parallel and per_pod % model_parallel == 0:
            break
        pods -= 1
    per_pod = alive_chips // pods
    data = per_pod // model_parallel
    if data < 1:
        raise ValueError("cannot fit the model-parallel degree per pod")
    return ElasticPlan(pods=pods, data=data, model=model_parallel)


# ---------------------------------------------------------------------------
# Supervisor
# ---------------------------------------------------------------------------
@dataclass
class SupervisorReport:
    steps_run: int = 0
    failures_handled: int = 0
    restores: int = 0
    evictions: List[str] = field(default_factory=list)
    final_step: int = 0


class TrainingSupervisor:
    """Runs a (state, step) -> state step function under fault injection.

    ``step_fn(state, step_idx)`` must be pure on its inputs;
    ``save_fn(step, state)`` / ``restore_fn() -> (step, state)`` wrap the
    CheckpointManager.  ``failure_schedule`` maps step index -> list of
    workers that die right before that step (test injection).
    """

    def __init__(
        self,
        step_fn: Callable[[PyTree, int], PyTree],
        save_fn: Callable[[int, PyTree], None],
        restore_fn: Callable[[], Tuple[int, PyTree]],
        monitor: HeartbeatMonitor,
        checkpoint_every: int = 10,
        failure_schedule: Optional[Dict[int, List[str]]] = None,
    ):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.monitor = monitor
        self.checkpoint_every = checkpoint_every
        self.failure_schedule = failure_schedule or {}

    def run(self, state: PyTree, start_step: int, num_steps: int) -> Tuple[PyTree, SupervisorReport]:
        report = SupervisorReport()
        step = start_step
        end = start_step + num_steps
        while step < end:
            # injected failures: workers stop heartbeating
            for w in self.failure_schedule.get(step, []):
                self.monitor.last_seen[w] = -math.inf
            newly_failed = self.monitor.check()
            if newly_failed:
                report.failures_handled += len(newly_failed)
                report.evictions.extend(newly_failed)
                # restart from the last committed checkpoint
                step, state = self.restore_fn()
                report.restores += 1
                continue
            state = self.step_fn(state, step)
            step += 1
            report.steps_run += 1
            if step % self.checkpoint_every == 0:
                self.save_fn(step, state)
        report.final_step = step
        return state, report
