"""Fleet planner: joint geometry x mapping x sharding search per model.

The paper's thesis is that partition *geometry* — not routing or raw link
bandwidth — decides avoidable contention.  The repo derives that end to end
for synthetic workloads (isoperimetry advisor, netsim, scheduler); this
module carries the conclusion to the production question the ROADMAP
north-star asks: *which slice should serve Mixtral-8x7B?*

For one (config, chip budget) pair the planner jointly searches

* **partition geometry** — every admissible cuboid slice, enumerated and
  bisection-ranked by :func:`repro.network.fabric.ranked_slice_geometries`
  (TPU slice semantics) or :func:`repro.network.isoperimetry.ranked_geometries`
  (fully-wrapped node-torus semantics, the paper's Tables 4-6 setting);
* **sharding rule** — explicit PartitionSpec-style rule sets over the
  ``(data, fsdp, tensor, expert)`` logical axes, enumerated from the
  divisor lattice of the chip budget and validated by
  :func:`repro.distributed.sharding.validate_partition_spec`;
* **rank mapping** — :func:`repro.network.mapping.map_ranks` over the
  rule's own rank-space traffic (ring halos per collective axis, expert
  all-to-all groups, the gradient pairing stress), with the whole strategy
  catalogue scored in one ``score_candidates`` batched call when the
  ``xla`` backend is active,

and prices every (geometry, rule, mapping) triple with

* ring-collective times from ``assign_axes(mapping=)`` **measured**
  embeddings (:data:`repro.network.collectives.COLLECTIVE_TIME`),
* a bisection-stress term: the geometry-sensitive share of the traffic
  (the first halving-doubling exchange of the gradient all-reduce and the
  slice-spanning share of the expert all-to-all) priced as the paper's
  pairing benchmark on the node-level dims
  (:func:`repro.network.routing.predict_pairing_time`) — by the section-7
  validation property this static price is *exactly* what the flow
  simulator measures for the same pattern, so every emitted comm time is
  reproducible by standalone ``assign_axes(mapping=)`` + netsim,
* roofline compute/memory terms from
  :func:`repro.analysis.analytic.cell_cost`.

Rows are ranked by exact ``(step_time, geometry rank, axis sizes)`` — a
total order on floats the brute-force oracle (``tests/reference_planner.py``)
reproduces row-identically, and that is bit-identical between the numpy
and xla scoring backends (``score_candidates`` is exact).

>>> from repro.network.fabric import TorusFabric
>>> plan = plan_model("mixtral-8x7b", 8, pod=TorusFabric.tpu((4, 4)),
...                   shape="decode_32k")
>>> plan.geometry, plan.best.axis_sizes  # (data, fsdp, tensor, expert)
((4, 2), (1, 1, 8, 1))
>>> plan.best.simulated_slowdown >= 1.0
True
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.analysis.analytic import BF16, cell_cost
from repro.analysis.roofline import HBM_BW, PEAK_FLOPS
from repro.configs import SHAPES, ArchConfig, ShapeConfig, all_archs, get_arch
from repro.network.collectives import (
    COLLECTIVE_TIME,
    AxisAssignment,
    CollectiveCostModel,
    assign_axes,
)
from repro.network.fabric import (
    HyperXFabric,
    TorusFabric,
    ranked_slice_geometries,
    slice_fabric,
)
from repro.network.geometry import Geometry, canonical, volume
from repro.network.isoperimetry import ranked_geometries, scaled_node_dims
from repro.network.mapping import RankMapping, map_ranks
from repro.network.netsim import simulate_fabric_traffic, simulate_traffic
from repro.network.routing import predict_pairing_time
from repro.obs.trace import TRACER as _TRACER

__all__ = [
    "AXES",
    "HBM_BYTES",
    "ORDER_HINT",
    "PlanCandidate",
    "ShardingRuleSet",
    "SlicePlan",
    "default_chip_budget",
    "enumerate_rules",
    "format_table",
    "pairing_stress_volume",
    "plan_fleet",
    "plan_model",
    "price_candidate",
    "rule_rank_traffic",
    "rule_traffic",
]

#: Logical mesh axes of every candidate sharding rule, in the row-major
#: rank-ravel order used for the mapping (insertion order of
#: ``assign_axes``'s ``axis_sizes`` dict).
AXES: Tuple[str, ...] = ("data", "fsdp", "tensor", "expert")

#: Axis priority for the physical assignment: heaviest collective pressure
#: first (per-layer tensor exchanges > expert all-to-all > parameter
#: gather/scatter > once-per-step gradient reduce).
ORDER_HINT: Tuple[str, ...] = ("tensor", "expert", "fsdp", "data")

#: Usable HBM per chip (weights-only feasibility filter; v5e-class 16 GB).
HBM_BYTES = 16e9


# ---------------------------------------------------------------------------
# Sharding rules.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShardingRuleSet:
    """One candidate sharding of a config over the ``AXES`` logical mesh.

    ``axis_sizes`` is ``(data, fsdp, tensor, expert)`` parallelism degrees
    (product == chip budget); ``specs`` are the explicit PartitionSpec-style
    rules (name, per-dimension entries) the rule set stands for, validated
    against the mesh by ``repro.distributed.sharding.validate_partition_spec``.
    """

    axis_sizes: Tuple[int, int, int, int]
    specs: Tuple[Tuple[str, Tuple], ...]

    @property
    def mesh_shape(self) -> Dict[str, int]:
        """Logical axis sizes as the ``assign_axes`` dict (AXES order)."""
        return dict(zip(AXES, self.axis_sizes))

    @property
    def order_hint(self) -> List[str]:
        return list(ORDER_HINT)


def _rule_specs(axis_sizes: Tuple[int, int, int, int], moe: bool):
    """Explicit PartitionSpec-style rules of one parallelism split.

    Size-1 axes are dropped from the specs (a trivial axis shards nothing),
    matching how :class:`repro.distributed.sharding.ShardingRules` degrades.
    """
    d, f, t, e = axis_sizes
    D = "data" if d > 1 else None
    F = "fsdp" if f > 1 else None
    T = "tensor" if t > 1 else None
    E = "expert" if e > 1 else None
    batch = tuple(a for a in (D, F) if a is not None)
    specs = [
        ("embed", (T, F)),
        ("attn.wq", (F, T, None)),
        ("attn.wo", (T, None, F)),
        ("batch", (batch if batch else None, None)),
    ]
    if moe:
        specs.append(("moe.wi", (E, F, T)))
        specs.append(("moe.wo", (E, T, F)))
    else:
        specs.append(("mlp.wi", (F, T)))
        specs.append(("mlp.wo", (T, F)))
    return tuple(specs)


def _validate_specs(rule: ShardingRuleSet) -> None:
    """Cross-check the rule's specs with the distributed-layer validator.

    Lazy import: ``repro.distributed.sharding`` pulls in jax; the planner
    itself stays importable on numpy alone (the validator is pure Python,
    only its module needs jax, so a missing jax degrades to no check).
    """
    try:
        from repro.distributed.sharding import validate_partition_spec
    except ImportError:  # pragma: no cover - jax is present in CI
        return
    mesh_axes = list(AXES)
    for _name, spec in rule.specs:
        validate_partition_spec(spec, mesh_axes)


def _divisors(n: int) -> List[int]:
    return [k for k in range(1, n + 1) if n % k == 0]


def enumerate_rules(cfg: ArchConfig, chips: int) -> List[ShardingRuleSet]:
    """All candidate ``(data, fsdp, tensor, expert)`` splits of a budget.

    ``tensor`` must divide the head count (head-sharded attention),
    ``expert`` must divide the expert count (1 for non-MoE configs), and
    ``data``/``fsdp`` absorb the rest.  Splits whose per-chip weight
    residency ``2 * params / (tensor * expert * fsdp)`` exceeds
    :data:`HBM_BYTES` are filtered out (ZeRO-3 weights-only feasibility);
    if *nothing* survives — a budget too small for the model — the filter
    is waived so the planner still ranks the least-bad rules.  Enumeration
    order is deterministic: ascending ``tensor``, then ``expert``, then
    ``fsdp``.
    """
    n_experts = cfg.moe.num_experts if cfg.moe is not None else 1
    param_bytes = float(BF16) * cfg.param_count()
    rules: List[ShardingRuleSet] = []
    for t in _divisors(chips):
        if cfg.n_heads % t != 0:
            continue
        for e in _divisors(chips // t):
            if n_experts % e != 0:
                continue
            rest = chips // (t * e)
            for f in _divisors(rest):
                d = rest // f
                rules.append(
                    ShardingRuleSet((d, f, t, e), _rule_specs((d, f, t, e), cfg.moe is not None))
                )
    feasible = [
        r for r in rules
        if param_bytes / (r.axis_sizes[1] * r.axis_sizes[2] * r.axis_sizes[3]) <= HBM_BYTES
    ]
    chosen = feasible if feasible else rules
    for r in chosen:
        _validate_specs(r)
    return chosen


# ---------------------------------------------------------------------------
# Traffic model: per-axis collective volumes of one (config, shape, rule).
# ---------------------------------------------------------------------------
def rule_traffic(
    cfg: ArchConfig, shape: ShapeConfig, axis_sizes: Tuple[int, int, int, int]
) -> List[Tuple[str, str, float]]:
    """Per-chip collective bytes of one step, as ``(axis, collective, bytes)``.

    The closed forms (bf16 activations/params, GSPMD-standard schedule):

    * ``tensor``: per-layer activation all-gather + reduce-scatter pairs of
      the Megatron block (2 exchanges/layer; x3 in training for fwd + bwd +
      remat recompute);
    * ``expert``: token dispatch/combine all-to-all (top-k x capacity
      tokens, 2 exchanges per layer at inference, 4 in training);
    * ``fsdp``: ZeRO-3 parameter all-gather (+ gradient reduce-scatter and
      the backward re-gather in training) of the ``1/(tensor*expert)``
      weight shard;
    * ``data``: the once-per-step gradient all-reduce of the fsdp-sharded
      gradient (training only).

    The entry order is the fixed pricing order (tensor, expert, fsdp,
    data); the differential oracle duplicates these formulas verbatim, so
    an edit here must be made twice to pass the harness.
    """
    d, f, t, e = axis_sizes
    L = cfg.n_layers
    B, S = shape.global_batch, shape.seq_len
    params = float(cfg.param_count())
    p_shard = BF16 * params / (t * e)
    tokens = float(B * S) if shape.kind in ("train", "prefill") else float(B)
    tokens_local = tokens / (d * f)
    act = tokens_local * cfg.d_model * BF16
    entries: List[Tuple[str, str, float]] = []
    if t > 1:
        mult = 3.0 if shape.kind == "train" else 1.0
        entries.append(("tensor", "all-gather", 2.0 * L * mult * act))
        entries.append(("tensor", "reduce-scatter", 2.0 * L * mult * act))
    if e > 1 and cfg.moe is not None:
        n_exchanges = 4.0 if shape.kind == "train" else 2.0
        a2a = (
            n_exchanges * L * tokens_local * cfg.moe.top_k
            * cfg.moe.capacity_factor * cfg.d_model * BF16
        )
        entries.append(("expert", "all-to-all", a2a))
    if f > 1:
        if shape.kind == "train":
            entries.append(("fsdp", "all-gather", 2.0 * p_shard))
            entries.append(("fsdp", "reduce-scatter", p_shard))
        else:
            entries.append(("fsdp", "all-gather", p_shard))
    if d > 1 and shape.kind == "train":
        entries.append(("data", "all-reduce", p_shard / f))
    return entries


def pairing_stress_volume(
    entries: Sequence[Tuple[str, str, float]],
    axis_sizes: Tuple[int, int, int, int],
) -> float:
    """Per-chip bytes of the geometry-sensitive (bisection-crossing) share.

    Ring collectives see identical analytic times on every fully-wrapped
    geometry of one volume; what geometry *does* change is the bisection
    load of the non-ring phases — the first halving-doubling exchange of
    the gradient all-reduce (half the reduced bytes cross the bisection)
    and the slice-spanning share of the expert all-to-all (a ``1/e``
    fraction pairs with the far half).  This is exactly the paper's pairing
    benchmark, priced per node via
    :func:`repro.network.routing.predict_pairing_time`.
    """
    _, _, _, e = axis_sizes
    vol = 0.0
    for axis, collective, v in entries:
        if axis == "data" and collective == "all-reduce":
            vol += 0.5 * v
        if axis == "expert" and collective == "all-to-all":
            vol += v / e
    return vol


def rule_rank_traffic(
    axis_sizes: Tuple[int, int, int, int],
    entries: Sequence[Tuple[str, str, float]],
    pair_volume: float,
) -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Rank-space ``(src, dst, vol)`` messages of a rule's collectives.

    Ring collectives become bidirectional nearest-neighbour exchanges on
    their logical axis (half the axis volume each way), the expert
    all-to-all becomes literal all-pairs messages within each expert
    group, and the pairing stress pairs each rank with its data-axis
    antipode.  Message order is deterministic (AXES order, +1 before -1,
    ascending all-to-all offset, pairing last).  Returns ``None`` when the
    rule moves no bytes (single-chip or communication-free shapes).
    """
    shape = tuple(axis_sizes)
    n = int(np.prod(shape))
    per_axis: Dict[str, float] = {a: 0.0 for a in AXES}
    a2a_volume = 0.0
    for axis, collective, v in entries:
        if axis == "expert" and collective == "all-to-all":
            a2a_volume += v
        else:
            per_axis[axis] += v
    ranks = np.arange(n, dtype=np.int64)
    coords = np.stack(np.unravel_index(ranks, shape), axis=1)
    srcs: List[np.ndarray] = []
    dsts: List[np.ndarray] = []
    vols: List[np.ndarray] = []

    def _send(dst_coords: np.ndarray, v: float) -> None:
        dst = np.ravel_multi_index(tuple(dst_coords.T), shape)
        srcs.append(ranks)
        dsts.append(dst.astype(np.int64))
        vols.append(np.full(n, v, dtype=np.float64))

    for k, axis in enumerate(AXES):
        s, v = shape[k], per_axis[axis]
        if s <= 1 or v <= 0.0:
            continue
        for step in (1, -1):
            nb = coords.copy()
            nb[:, k] = (nb[:, k] + step) % s
            _send(nb, v / 2.0)
    e = shape[3]
    if e > 1 and a2a_volume > 0.0:
        for off in range(1, e):
            nb = coords.copy()
            nb[:, 3] = (nb[:, 3] + off) % e
            _send(nb, a2a_volume / e)
    d = shape[0]
    if d > 1 and pair_volume > 0.0:
        nb = coords.copy()
        nb[:, 0] = (nb[:, 0] + d // 2) % d
        _send(nb, pair_volume)
    if not srcs:
        return None
    return np.concatenate(srcs), np.concatenate(dsts), np.concatenate(vols)


# ---------------------------------------------------------------------------
# Candidate pricing.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PlanCandidate:
    """One priced (geometry, mapping, sharding rule) triple."""

    geometry: Geometry
    geometry_rank: int  # index in the bisection-ranked geometry list
    bisection_links: int
    bisection_efficiency: float  # this geometry's bisection / best rankable
    fabric: Union[TorusFabric, HyperXFabric]
    rule: ShardingRuleSet
    mapping: Optional[RankMapping]
    assignment: AxisAssignment
    traffic: Tuple[Tuple[str, str, float], ...]
    pair_volume_node: float  # node-level pairing-stress bytes
    node_dims: Geometry  # dims the pairing term is priced on
    ring_time: float
    pairing_time: float
    compute_time: float
    memory_time: float
    simulated_slowdown: float = 1.0

    @property
    def axis_sizes(self) -> Tuple[int, int, int, int]:
        return self.rule.axis_sizes

    @property
    def comm_time(self) -> float:
        """Total predicted communication seconds per step."""
        return self.ring_time + self.pairing_time

    @property
    def step_time(self) -> float:
        """Roofline step time: overlapped compute/memory + exposed comm."""
        return max(self.compute_time, self.memory_time) + self.comm_time

    @property
    def mapping_strategy(self) -> str:
        return self.mapping.strategy if self.mapping is not None else "none"

    def row(self) -> Tuple:
        """Comparable scalar row (what the differential oracle reproduces)."""
        return (
            self.geometry,
            self.axis_sizes,
            self.mapping_strategy,
            self.ring_time,
            self.pairing_time,
            self.compute_time,
            self.memory_time,
            self.step_time,
        )

    def sort_key(self) -> Tuple:
        """Exact deterministic ranking key (documented tie-break: predicted
        step time, then bisection-rank of the geometry, then axis sizes)."""
        return (self.step_time, self.geometry_rank, self.axis_sizes)


def _decode_cache_bytes(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Whole-fleet KV-cache bytes for decode shapes (attention archs)."""
    if shape.kind != "decode" or cfg.is_attention_free:
        return 0.0
    return (
        2.0 * cfg.n_layers * shape.global_batch * shape.seq_len
        * cfg.n_kv_heads * cfg.resolved_head_dim * BF16
    )


def price_candidate(
    cfg: ArchConfig,
    shape: ShapeConfig,
    fabric: Union[TorusFabric, HyperXFabric],
    node_dims: Geometry,
    n_compute: int,
    rule: ShardingRuleSet,
    backend: Optional[str] = None,
) -> Optional[Tuple[Optional[RankMapping], AxisAssignment, Tuple, float, float, float, float, float]]:
    """Price one (fabric, rule) pair; None when the rule cannot embed.

    The comm price has two parts, each standalone-reproducible:

    * ring time — ``assign_axes(fabric, mesh_shape, ORDER_HINT, mapping=)``
      then :data:`COLLECTIVE_TIME` per traffic entry, summed in entry
      order;
    * pairing time — the node-level stress volume times
      ``predict_pairing_time(node_dims).time_per_volume`` (equal to the
      netsim makespan of ``bisection_pairing(node_dims)`` at unit volume).

    When :mod:`repro.obs` tracing is enabled each pricing emits a
    ``planner.price`` span annotated with the fabric geometry, rule name,
    and whether the rule embedded.
    """
    if not _TRACER.enabled:
        return _price_candidate_impl(
            cfg, shape, fabric, node_dims, n_compute, rule, backend
        )
    with _TRACER.span(
        "planner.price", fabric=tuple(fabric.dims), rule=tuple(rule.axis_sizes)
    ) as sp:
        priced = _price_candidate_impl(
            cfg, shape, fabric, node_dims, n_compute, rule, backend
        )
        sp.annotate(embedded=priced is not None)
        return priced


def _ring_equivalent(fabric: HyperXFabric) -> TorusFabric:
    """Wrapped-torus stand-in for pricing ring schedules on a HyperX box.

    A ring pass along one dim of a clique uses one direct link per hop
    stage, exactly like a fully-wrapped torus dim — so ring-collective
    times on ``H(S)`` equal those on the wrapped torus of the same dims
    with per-link bandwidth ``K_k * link_bw`` (exact when the trunking is
    uniform; the min-multiplicity floor makes the price conservative
    otherwise).  ``double_link_on_2=False`` because a size-2 clique dim
    has its ``K_k`` trunked links already counted in the bandwidth scale,
    not the torus's two parallel wrap links.
    """
    bw = fabric.link_bw * min(fabric.link_multiplicity)
    return TorusFabric(
        fabric.dims, (True,) * len(fabric.dims), bw, double_link_on_2=False
    )


def _price_candidate_impl(
    cfg: ArchConfig,
    shape: ShapeConfig,
    fabric: Union[TorusFabric, HyperXFabric],
    node_dims: Geometry,
    n_compute: int,
    rule: ShardingRuleSet,
    backend: Optional[str] = None,
) -> Optional[Tuple[Optional[RankMapping], AxisAssignment, Tuple, float, float, float, float, float]]:
    chips = fabric.num_chips
    ring_fab = _ring_equivalent(fabric) if isinstance(fabric, HyperXFabric) else fabric
    entries = rule_traffic(cfg, shape, rule.axis_sizes)
    pair_chip = pairing_stress_volume(entries, rule.axis_sizes)
    traffic = rule_rank_traffic(rule.axis_sizes, entries, pair_chip)
    mesh_shape = rule.mesh_shape
    mapping = None
    try:
        if traffic is not None:
            mapping = map_ranks(
                ring_fab.dims,
                ring_fab.dims,
                logical_dims=tuple(rule.axis_sizes),
                traffic=traffic,
                double_link_on_2=ring_fab.double_link_on_2,
                refine=False,  # the catalogue is oracle-enumerable; greedy
                wrap=ring_fab.wrap,  # refinement is seeded local search
                backend=backend,
            )
        assignment = assign_axes(
            ring_fab, mesh_shape, order_hint=rule.order_hint, mapping=mapping
        )
    except ValueError:
        return None  # rule does not embed in this geometry
    cost_model = CollectiveCostModel(ring_fab, assignment)
    ring_time = 0.0
    for axis, collective, vol in entries:
        ring_time += cost_model.time(collective, axis, vol)
    # Node-level pairing stress: per-chip volume rescaled to the node torus
    # (identity on chip-level fabrics where volume(node_dims) == chips).
    pair_node = pair_chip * chips / volume(node_dims)
    pairing_time = 0.0
    if pair_node > 0.0 and isinstance(fabric, HyperXFabric):
        # Halving-doubling partners differ in one coordinate of the split
        # dim, so every pair has its own direct clique link: max link load
        # is 1 and the exchange drains in one contention-free stage over a
        # K_k-trunked link (netsim measures exactly this; see
        # tests/test_hyperx.py).
        sides = fabric.dims
        if max(sides) > 1:
            k = max(range(len(sides)), key=lambda i: sides[i])
            pairing_time = pair_node / (fabric.link_bw * fabric.link_multiplicity[k])
    elif pair_node > 0.0:
        pred = predict_pairing_time(
            node_dims, 1.0, fabric.link_bw,
            double_link_on_2=fabric.double_link_on_2,
        )
        pairing_time = pair_node * pred.time_per_volume
    cost = cell_cost(
        cfg, shape, float(cfg.param_count()),
        cache_bytes=_decode_cache_bytes(cfg, shape),
    )
    compute_time = cost.flops_compiled / (n_compute * PEAK_FLOPS)
    memory_time = cost.bytes_hbm / (n_compute * HBM_BW)
    return (
        mapping, assignment, tuple(entries), pair_node,
        ring_time, pairing_time, compute_time, memory_time,
    )


# ---------------------------------------------------------------------------
# The plan.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SlicePlan:
    """The planner's answer for one (config, chip budget): a ranked table
    of priced (geometry, mapping, rule) triples, best first."""

    arch: str
    shape: str
    chips: int
    pod_dims: Geometry
    wrap_mode: str
    table: Tuple[PlanCandidate, ...]

    @property
    def best(self) -> PlanCandidate:
        return self.table[0]

    @property
    def geometry(self) -> Geometry:
        return self.best.geometry

    @property
    def step_time(self) -> float:
        return self.best.step_time

    @property
    def bisection_efficiency(self) -> float:
        return self.best.bisection_efficiency

    @property
    def simulated_slowdown(self) -> float:
        return self.best.simulated_slowdown

    def geometry_preferences(self) -> List[Geometry]:
        """Distinct geometries in ranked-row order (for occupancy walks)."""
        seen, out = set(), []
        for cand in self.table:
            if cand.geometry not in seen:
                seen.add(cand.geometry)
                out.append(cand.geometry)
        return out

    def to_request(self, job_id: int, duration: float = 1.0, arrival: float = 0.0):
        """The plan as a scheduler :class:`repro.network.allocation.JobRequest`
        carrying the planner-chosen geometry."""
        from repro.network.allocation import JobRequest

        return JobRequest(
            job_id=job_id,
            units=self.chips,
            duration=duration,
            arrival=arrival,
            geometry=self.geometry,
        )


def default_chip_budget(cfg: ArchConfig) -> int:
    """Smallest power-of-two budget whose ZeRO-3 weight shards fit HBM
    (bf16 weights only; optimizer/cache headroom is the caller's concern)."""
    need = BF16 * cfg.param_count() / HBM_BYTES
    return max(4, 2 ** math.ceil(math.log2(max(need, 1.0))))


def plan_model(
    arch: Union[str, ArchConfig],
    chips: Optional[int] = None,
    *,
    pod: Optional[Union[TorusFabric, HyperXFabric]] = None,
    shape: Union[str, ShapeConfig] = "decode_32k",
    wrap_mode: str = "slice",
    unit_node_dims: Optional[Sequence[int]] = None,
    simulate_top_k: int = 0,
    backend: Optional[str] = None,
) -> SlicePlan:
    """Jointly search geometry x mapping x sharding for one config.

    ``wrap_mode="slice"`` (default) uses TPU slice semantics: geometries
    from :func:`ranked_slice_geometries`, wrap links only where a slice
    spans a full pod dimension.  ``wrap_mode="torus"`` uses the paper's
    Blue Gene/Q semantics: every partition is its own fully-wrapped torus
    (:func:`ranked_geometries`), with ``unit_node_dims`` scaling allocation
    units to the node level (Tables 4-6).

    ``simulate_top_k`` drains the top-k ranked rows' mapped traffic
    through the flow simulator and records the measured contention
    multiplier on ``simulated_slowdown`` (1.0 analytic default —
    tier-1 tests keep k=0 so no netsim runs on the hot path).

    A :class:`~repro.network.fabric.HyperXFabric` pod is also accepted.
    There the slice/torus wrap distinction collapses — an aligned sub-box
    of a clique dimension is itself a clique, so every partition has full
    wrap along every dim regardless of where it sits — and both
    ``wrap_mode`` values rank the same Lindsey-exact bisection table
    (:func:`repro.network.isoperimetry.ranked_geometries` on the fabric).
    ``unit_node_dims`` node scaling is the Blue Gene/Q torus convention
    and is rejected on HyperX pods.
    """
    cfg = arch if isinstance(arch, ArchConfig) else get_arch(arch)
    shape_cfg = shape if isinstance(shape, ShapeConfig) else SHAPES[shape]
    pod = pod or _default_pod()
    budget = chips if chips is not None else min(default_chip_budget(cfg), pod.num_chips)
    if isinstance(pod, HyperXFabric):
        if wrap_mode not in ("slice", "torus"):
            raise ValueError(f"wrap_mode must be 'slice' or 'torus', got {wrap_mode!r}")
        if unit_node_dims is not None:
            raise ValueError(
                "unit_node_dims is the BG/Q torus node-scaling convention; "
                "HyperX pods plan over allocation-unit boxes directly"
            )
        ranked = ranked_geometries(pod, budget)
        fabrics = [(g, bis, pod.sub_fabric(g)) for g, bis in ranked]
        nodes = [fab.dims for _, _, fab in fabrics]
    elif wrap_mode == "slice":
        ranked = ranked_slice_geometries(pod, budget)
        fabrics = [(g, bis, slice_fabric(pod, g)) for g, bis in ranked]
        nodes = [fab.dims for _, _, fab in fabrics]
    elif wrap_mode == "torus":
        ranked = ranked_geometries(pod.dims, budget, unit_node_dims)
        fabrics = [
            (g, bis, TorusFabric(g, (True,) * len(g), pod.link_bw,
                                 double_link_on_2=pod.double_link_on_2))
            for g, bis in ranked
        ]
        nodes = [scaled_node_dims(g, unit_node_dims) for g, _ in ranked]
    else:
        raise ValueError(f"wrap_mode must be 'slice' or 'torus', got {wrap_mode!r}")
    best_bis = ranked[0][1]
    rules = enumerate_rules(cfg, budget)
    rows: List[PlanCandidate] = []
    for gi, ((geom, bis, fabric), node_dims) in enumerate(zip(fabrics, nodes)):
        n_compute = volume(node_dims)
        for rule in rules:
            priced = price_candidate(
                cfg, shape_cfg, fabric, node_dims, n_compute, rule, backend=backend
            )
            if priced is None:
                continue
            mapping, assignment, entries, pair_node, ring, pairing, compute, memory = priced
            rows.append(
                PlanCandidate(
                    geometry=canonical(geom),
                    geometry_rank=gi,
                    bisection_links=int(bis),
                    bisection_efficiency=(bis / best_bis if best_bis else 1.0),
                    fabric=fabric,
                    rule=rule,
                    mapping=mapping,
                    assignment=assignment,
                    traffic=entries,
                    pair_volume_node=pair_node,
                    node_dims=canonical(node_dims),
                    ring_time=ring,
                    pairing_time=pairing,
                    compute_time=compute,
                    memory_time=memory,
                )
            )
    if not rows:
        raise ValueError(
            f"no (geometry, rule) candidate of {budget} chips embeds in pod "
            f"{pod.dims} for arch {cfg.name}"
        )
    rows.sort(key=PlanCandidate.sort_key)
    if simulate_top_k > 0:
        simulated = []
        for cand in rows[:simulate_top_k]:
            simulated.append(replace(cand, simulated_slowdown=_simulate(cand)))
        rows = simulated + rows[simulate_top_k:]
    return SlicePlan(
        arch=cfg.name,
        shape=shape_cfg.name,
        chips=budget,
        pod_dims=canonical(pod.dims),
        wrap_mode=wrap_mode,
        table=tuple(rows),
    )


def _simulate(cand: PlanCandidate) -> float:
    """Flow-simulated contention multiplier of one row's mapped traffic."""
    if cand.mapping is None:
        return 1.0
    src, dst, vol = cand.mapping.machine_traffic()
    if len(vol) == 0 or float(np.sum(vol)) <= 0.0:
        return 1.0
    if isinstance(cand.fabric, HyperXFabric):
        sim = simulate_fabric_traffic(
            cand.fabric, (src, dst, vol), link_bw=cand.fabric.link_bw
        )
        return max(1.0, float(sim.slowdown))
    sim = simulate_traffic(
        cand.fabric.dims, (src, dst, vol),
        link_bw=cand.fabric.link_bw,
        double_link_on_2=cand.fabric.double_link_on_2,
    )
    # netsim's zero-contention bound assumes a single link; on doubled
    # size-2 dims a contention-free pattern beats it (ratio 0.5).  The
    # planner reports a contention *multiplier*, floored at 1.
    return max(1.0, float(sim.slowdown))


def _default_pod() -> TorusFabric:
    from repro.launch.mesh import pod_fabric

    return pod_fabric()


def format_table(plan: SlicePlan, top: int = 8) -> str:
    """Human-readable ranked table of a plan (dry-run output)."""
    head = (
        f"{plan.arch} · {plan.shape} · {plan.chips} chips on pod "
        f"{plan.pod_dims} ({plan.wrap_mode})"
    )
    cols = (
        f"{'geometry':>12} {'d,f,t,e':>12} {'mapping':>16} {'comm(ms)':>9} "
        f"{'step(ms)':>9} {'bis.eff':>8} {'slowdown':>9}"
    )
    lines = [head, cols]
    for cand in plan.table[:top]:
        lines.append(
            f"{str(cand.geometry):>12} {str(cand.axis_sizes):>12} "
            f"{cand.mapping_strategy:>16} {cand.comm_time * 1e3:>9.3f} "
            f"{cand.step_time * 1e3:>9.3f} {cand.bisection_efficiency:>8.2f} "
            f"{cand.simulated_slowdown:>9.3f}"
        )
    if len(plan.table) > top:
        lines.append(f"... {len(plan.table) - top} more rows")
    return "\n".join(lines)


def plan_fleet(
    archs: Optional[Sequence[Union[str, ArchConfig]]] = None,
    **kwargs,
) -> List[SlicePlan]:
    """One :class:`SlicePlan` per config (default: every registered arch,
    name-sorted), each at its :func:`default_chip_budget` unless ``chips``
    is passed through ``kwargs``."""
    if archs is None:
        archs = sorted(all_archs())
    return [plan_model(a, **kwargs) for a in archs]
