import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first backend initialisation.  512 host devices back the
# production meshes (16x16 single-pod, 2x16x16 multi-pod) for compile-only
# dry-runs; nothing is ever allocated (ShapeDtypeStruct inputs only).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the model and the step function (train_step / forward / decode),
  2. jits it with the ShardingRules in/out shardings on the production mesh,
  3. ``.lower(**ShapeDtypeStruct inputs).compile()`` — success proves the
     distribution config is coherent (sharding divisibility, collective
     legality, memory layout); ``memory_analysis()`` proves it fits,
  4. derives roofline terms:
       - compute/memory: exact analytic model (analysis/analytic.py) —
         XLA's cost_analysis counts while-loop bodies once, so scanned
         programs are undercounted; the analytic model is validated against
         cost_analysis on unrolled configs (tests/test_roofline.py),
       - collectives: parsed from *calibration* compiles at two unrolled
         depths (L0, L1) and extrapolated linearly in depth — exact for
         homogeneous stacks, and collective-free inner scans make the
         unrolled counts exact.
  5. writes a JSON record consumed by benchmarks + EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import analytic, roofline
from repro.analysis.axis_attribution import per_axis_collectives
from repro.configs import SHAPES, all_archs, cells, get_arch
from repro.distributed.sharding import ShardingRules
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.optim import AdamWConfig, adamw
from repro.train import make_train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

# Activation-memory knob per arch for train_4k (microbatch count).
MICROBATCHES = {
    "nemotron-4-340b": 8,
    "qwen1.5-110b": 4,
    "command-r-35b": 4,
    "mixtral-8x7b": 4,
    "phi3.5-moe-42b-a6.6b": 4,
    "granite-3-8b": 2,
    "musicgen-large": 2,
    "zamba2-2.7b": 2,
    "rwkv6-3b": 2,
    "internvl2-1b": 1,
}


def batch_specs_struct(arch, shape):
    """ShapeDtypeStructs for the cell's inputs."""
    B, S = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    if shape.is_decode:
        if arch.frontend == "audio":
            return {"frame_embeds": jax.ShapeDtypeStruct((B, 1, arch.d_model), f32)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if arch.frontend == "audio":
        return {
            "frame_embeds": jax.ShapeDtypeStruct((B, S, arch.d_model), f32),
            "targets": jax.ShapeDtypeStruct((B, S, arch.n_codebooks), i32),
        }
    out = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    if arch.frontend == "vlm":
        out["patch_embeds"] = jax.ShapeDtypeStruct((B, arch.num_patches, arch.d_model), f32)
    return out


def shard_bytes(shardings, shapes) -> float:
    """Exact per-device bytes for a pytree of NamedShardings + structs."""
    total = 0
    for shd, struct in zip(jax.tree.leaves(shardings), jax.tree.leaves(shapes)):
        shard_shape = shd.shard_shape(struct.shape)
        n = 1
        for d in shard_shape:
            n *= d
        total += n * struct.dtype.itemsize
    return float(total)


def _lower_cell(arch, shape, mesh, *, microbatches, unroll, remat="block",
                loss_chunk=None, zero_stage=3, model_axis="model",
                fsdp_axes=None):
    """Build + lower one cell.  Returns (lowered, per-device state bytes)."""
    rules = ShardingRules(
        arch, mesh, zero_stage=zero_stage, model_axis=model_axis,
        fsdp_axes=tuple(fsdp_axes) if fsdp_axes else None,
    )
    model = build_model(
        arch, attn_impl="xla", remat=remat, unroll=unroll
    )
    model = dataclasses.replace(
        model,
        logits_sharding=lambda ndim: NamedSharding(mesh, rules.logits_spec(ndim)),
        loss_chunk=loss_chunk,
    )
    params_shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    param_specs = rules.params_specs(params_shapes)
    param_shd = jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs)
    batch_struct = batch_specs_struct(arch, shape)
    batch_shd = {
        k: NamedSharding(mesh, s) for k, s in rules.batch_specs(batch_struct).items()
    }

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(adamw.init, params_shapes)
        moment_specs = rules.opt_specs(params_shapes)
        opt_specs = adamw.AdamWState(step=P(), m=moment_specs, v=moment_specs)
        opt_shd = jax.tree.map(lambda s: NamedSharding(mesh, s), opt_specs)
        grad_shd = jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs)
        step_fn = make_train_step(
            model, AdamWConfig(), microbatches=microbatches, grad_shardings=grad_shd,
            unroll_loop=unroll,
        )
        jitted = jax.jit(
            step_fn,
            in_shardings=(param_shd, opt_shd, batch_shd),
            out_shardings=(param_shd, opt_shd, None),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_shapes, opt_shapes, batch_struct)
        # params + grads (bf16) + m + v (f32)
        state_bytes = 2 * shard_bytes(param_shd, params_shapes) + 2 * shard_bytes(
            jax.tree.map(lambda s: NamedSharding(mesh, s), opt_specs.m), opt_shapes.m
        )
        cache_bytes = 0.0
    elif shape.kind == "prefill":
        def prefill(params, batch):
            logits, _ = model.forward(params, batch)
            return logits[:, -1]

        jitted = jax.jit(prefill, in_shardings=(param_shd, batch_shd))
        lowered = jitted.lower(params_shapes, batch_struct)
        state_bytes = shard_bytes(param_shd, params_shapes)
        cache_bytes = 0.0
    else:  # decode
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len)
        )
        cache_specs = rules.cache_specs(cache_shapes)
        cache_shd = jax.tree.map(lambda s: NamedSharding(mesh, s), cache_specs)

        def decode(params, cache, batch, position):
            return model.decode_step(params, cache, batch, position)

        pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
        jitted = jax.jit(
            decode,
            in_shardings=(param_shd, cache_shd, batch_shd, NamedSharding(mesh, P())),
            out_shardings=(None, cache_shd),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params_shapes, cache_shapes, batch_struct, pos_struct)
        cache_bytes = shard_bytes(cache_shd, cache_shapes)
        state_bytes = shard_bytes(param_shd, params_shapes) + cache_bytes
    return lowered, state_bytes, cache_bytes, params_shapes


def _calib_depths(arch):
    if arch.shared_attn_every:
        step = arch.shared_attn_every
        return step, 2 * step, arch.n_layers // step, 1  # L0, L1, units_full, per
    return 2, 4, arch.n_layers, None


def run_cell(arch_name: str, shape_name: str, mesh_kind: str, force: bool = False,
             skip_calibration: bool = False, variant: dict = None) -> dict:
    """``variant``: optional perf-iteration overrides
    {tag, microbatches, remat, loss_chunk} — results cached under the tag."""
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    variant = variant or {}
    tag = f"__{variant['tag']}" if variant.get("tag") else ""
    out_path = RESULTS_DIR / f"{arch_name}__{shape_name}__{mesh_kind}{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    chips = mesh.devices.size
    mb = MICROBATCHES.get(arch_name, 1) if shape.kind == "train" else 1
    mb = variant.get("microbatches", mb)
    remat = variant.get("remat", "block")
    loss_chunk = variant.get("loss_chunk")
    zero_stage = variant.get("zero_stage", 3)
    model_axis = variant.get("model_axis", "model")
    fsdp_axes = variant.get("fsdp_axes")

    # -- 1) production compile: the coherence + memory proof ---------------------
    t0 = time.time()
    lowered, state_bytes, cache_bytes_dev, params_shapes = _lower_cell(
        arch, shape, mesh, microbatches=mb, unroll=False, remat=remat,
        loss_chunk=loss_chunk, zero_stage=zero_stage, model_axis=model_axis,
        fsdp_axes=fsdp_axes,
    )
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem_dict = {}
    mem = compiled.memory_analysis()
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
        "peak_memory_in_bytes",
    ):
        if mem is not None and hasattr(mem, attr):
            try:
                mem_dict[attr] = int(getattr(mem, attr))
            except Exception:
                pass
    raw_cost = roofline.xla_cost_analysis(compiled)
    prod_stats = roofline.collective_stats(compiled.as_text())

    # -- 2) collective calibration: unrolled depths L0 < L1 ----------------------
    if skip_calibration:
        coll_stats = prod_stats
        per_axis = {}
        coll_note = "production-scan counts (loop bodies counted once)"
    else:
        # Exact bilinear calibration: collective bytes/counts are
        # F(L, m) = a + b*L + c*m + d*L*m  (per-layer-per-microbatch weight
        # gathers, per-layer activation reductions, per-microbatch top-level
        # terms, constants).  Four unrolled compiles at (L0,1),(L1,1),(L0,2),
        # (L1,2) determine the coefficients exactly; prefill/decode cells use
        # the depth-only linear model (two compiles).
        L0, L1, units_full, _ = _calib_depths(arch)
        mbs = (1, 2) if (shape.kind == "train" and mb > 1) else (1,)
        meas = {}
        ax_meas = {}
        for m_i in mbs:
            for L in (L0, L1):
                sub = dataclasses.replace(arch, n_layers=L)
                lw, _, _, _ = _lower_cell(
                    sub, shape, mesh, microbatches=m_i, unroll=True, remat=remat,
                    loss_chunk=loss_chunk, zero_stage=zero_stage,
                    model_axis=model_axis, fsdp_axes=fsdp_axes,
                )
                txt = lw.compile().as_text()
                meas[(L, m_i)] = roofline.collective_stats(txt)
                ax_meas[(L, m_i)] = per_axis_collectives(txt, mesh_shape)

        Lf = arch.n_layers

        def bilinear(get) -> float:
            f00 = get(meas[(L0, 1)] if (L0, 1) in meas else {})
            f10 = get(meas[(L1, 1)])
            if len(mbs) == 1:
                slope = (f10 - f00) / (L1 - L0)
                return max(0.0, f00 + slope * (Lf - L0))
            f01 = get(meas[(L0, 2)])
            f11 = get(meas[(L1, 2)])
            d = (f11 - f01 - f10 + f00) / (L1 - L0)
            b = (f10 - f00) / (L1 - L0) - d
            c = f01 - f00 - d * L0
            a = f00 - b * L0 - c - d * L0
            return max(0.0, a + b * Lf + c * mb + d * Lf * mb)

        def bil_ax(table, ax, field) -> float:
            def get(stats):
                return stats.get(ax, {}).get(field, 0.0)

            f00 = get(table[(L0, 1)])
            f10 = get(table[(L1, 1)])
            if len(mbs) == 1:
                slope = (f10 - f00) / (L1 - L0)
                return max(0.0, f00 + slope * (Lf - L0))
            f01 = get(table[(L0, 2)])
            f11 = get(table[(L1, 2)])
            d = (f11 - f01 - f10 + f00) / (L1 - L0)
            b = (f10 - f00) / (L1 - L0) - d
            c = f01 - f00 - d * L0
            a = f00 - b * L0 - c - d * L0
            return max(0.0, a + b * Lf + c * mb + d * Lf * mb)

        coll_stats = {}
        for key in meas[(L0, 1)]:
            coll_stats[key] = {
                "bytes": bilinear(lambda s, k=key: s[k]["bytes"]),
                "count": bilinear(lambda s, k=key: s[k]["count"]),
            }
        axes = set()
        for t in ax_meas.values():
            axes |= set(t)
        per_axis = {
            ax: {
                "bytes": bil_ax(ax_meas, ax, "bytes"),
                "count": bil_ax(ax_meas, ax, "count"),
            }
            for ax in axes
        }
        coll_note = (
            f"bilinear calibration: depths {L0},{L1} x microbatches {list(mbs)}"
        )
    coll_bytes = roofline.total_collective_bytes(coll_stats)

    # -- 3) analytic compute/memory terms ---------------------------------------
    n_matmul = roofline.matmul_param_count(params_shapes)
    cost = analytic.cell_cost(
        arch, shape, n_matmul,
        cache_bytes=cache_bytes_dev * chips,
        microbatches=mb,
    )

    report = roofline.RooflineReport(
        arch=arch_name,
        shape=shape_name,
        mesh=mesh_kind,
        chips=chips,
        hlo_flops=cost.flops_compiled / chips,
        hlo_bytes=cost.bytes_hbm / chips,
        collective_bytes=coll_bytes,
        collectives=coll_stats,
        model_flops=cost.flops_useful,
        bytes_per_device=state_bytes,
        notes=f"microbatches={mb}; collectives: {coll_note}",
    )
    record = report.to_json()
    record.update(
        lower_seconds=round(t_lower, 1),
        compile_seconds=round(t_compile, 1),
        memory_analysis=mem_dict,
        raw_cost_analysis={k: raw_cost[k] for k in ("flops", "bytes accessed") if k in raw_cost},
        production_collectives=prod_stats,
        per_axis_collectives=per_axis,
        flops_breakdown=cost.breakdown,
        variant=variant,
        ok=True,
    )
    out_path.write_text(json.dumps(record, indent=1))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-calibration", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    jobs = []
    if args.all:
        for name, arch in sorted(all_archs().items()):
            for shape in cells(arch):
                for m in meshes:
                    jobs.append((name, shape, m))
    else:
        assert args.arch and args.shape
        jobs = [(args.arch, args.shape, m) for m in meshes]

    failures = []
    for arch_name, shape_name, mesh_kind in jobs:
        tag = f"{arch_name} x {shape_name} x {mesh_kind}"
        try:
            rec = run_cell(
                arch_name, shape_name, mesh_kind,
                force=args.force, skip_calibration=args.skip_calibration,
            )
            print(
                f"[OK] {tag}: flops/dev={rec['hlo_flops']:.3e} "
                f"bytes/dev={rec['hlo_bytes']:.3e} coll={rec['collective_bytes']:.3e} "
                f"bottleneck={rec['bottleneck']} "
                f"(compile {rec.get('compile_seconds', 0)}s)",
                flush=True,
            )
        except Exception as e:
            failures.append((tag, repr(e)))
            print(f"[FAIL] {tag}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: {[t for t, _ in failures]}")
    print(f"all {len(jobs)} dry-run cells compiled OK")


if __name__ == "__main__":
    main()
