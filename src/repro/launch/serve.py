"""Batched serving driver: prefill + decode with continuous batching slots.

CPU-scale with reduced configs; the production mesh path is exercised by
the dry-run (decode_32k / long_500k cells lower ``decode_step``).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --reduced \
      --requests 8 --prompt-len 16 --gen-len 24
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.obs import timer as obs_timer
from repro.models import build_model
from repro.train import make_decode_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--plan-chips", type=int, default=None,
        help="dry-run: print the fleet planner's ranked slice plan for this "
             "arch at the given chip budget, then exit (no model is built)",
    )
    ap.add_argument("--plan-shape", default="decode_32k")
    args = ap.parse_args(argv)

    if args.plan_chips is not None:
        from repro.launch.planner import format_table, plan_model

        plan = plan_model(
            args.arch, args.plan_chips, shape=args.plan_shape, simulate_top_k=1
        )
        print(format_table(plan))
        return plan

    arch = get_arch(args.arch)
    if args.reduced:
        arch = arch.reduced()
    if arch.frontend != "none":
        raise SystemExit("serve driver supports token LMs (use token archs)")
    model = build_model(arch)
    params = model.init(jax.random.key(args.seed))
    B = args.requests
    max_len = args.prompt_len + args.gen_len
    cache = model.init_cache(B, max_len)
    decode = jax.jit(model.decode_step)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, arch.vocab_size, (B, args.prompt_len), dtype=np.int32)

    # prefill via teacher-forced decode (exact cache population)
    logits = None
    with obs_timer("serve.prefill", requests=B, tokens=args.prompt_len) as tm:
        for t in range(args.prompt_len):
            logits, cache = decode(
                params, cache, {"tokens": jnp.asarray(prompts[:, t : t + 1])}, jnp.array(t)
            )
        jax.block_until_ready(logits)
    t_prefill = tm.elapsed

    # batched greedy decode
    out_tokens = []
    tok = jnp.argmax(logits[:, -1, : arch.vocab_size], axis=-1)[:, None].astype(jnp.int32)
    with obs_timer("serve.decode", requests=B, tokens=args.gen_len) as tm:
        for i in range(args.gen_len):
            out_tokens.append(np.asarray(tok))
            logits, cache = decode(
                params, cache, {"tokens": tok}, jnp.array(args.prompt_len + i)
            )
            tok = jnp.argmax(logits[:, -1, : arch.vocab_size], axis=-1)[:, None].astype(jnp.int32)
        jax.block_until_ready(tok)
    t_decode = tm.elapsed

    gen = np.concatenate(out_tokens, axis=1)
    tps = B * args.gen_len / t_decode
    print(f"arch={arch.name} requests={B} prompt={args.prompt_len} gen={args.gen_len}")
    print(f"prefill {t_prefill*1e3:.1f} ms; decode {t_decode*1e3:.1f} ms "
          f"({tps:.1f} tok/s aggregate)")
    print("sample generations (token ids):")
    for b in range(min(B, 3)):
        print(f"  req{b}: {gen[b, :12].tolist()}...")
    assert gen.shape == (B, args.gen_len)
    assert int(gen.max()) < arch.vocab_size
    return tps


if __name__ == "__main__":
    main()
