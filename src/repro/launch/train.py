"""End-to-end training driver.

Runs real training on the available devices (CPU-scale with reduced configs;
the same code path jits under the production mesh on TPU).  Integrates the
full substrate: deterministic sharded data pipeline, microbatched AdamW,
async checkpointing with restart, failure injection + supervisor restore,
straggler tracking, and optional cross-pod gradient compression.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b --reduced \
      --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b --reduced \
      --steps 50 --simulate-failure-at 23
"""

from __future__ import annotations

import argparse
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.data import DataConfig, DataPipeline
from repro.models import build_model
from repro.obs import timer as obs_timer
from repro.optim import AdamWConfig, adamw
from repro.optim import compression as comp
from repro.runtime import HeartbeatMonitor, StragglerTracker
from repro.train import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--simulate-failure-at", type=int, default=None)
    ap.add_argument("--compress", choices=["none", "int8", "topk"], default="none")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument(
        "--plan-chips", type=int, default=None,
        help="dry-run: print the fleet planner's ranked slice plan for this "
             "arch at the given chip budget, then exit (no model is built)",
    )
    ap.add_argument("--plan-shape", default="train_4k")
    args = ap.parse_args(argv)

    if args.plan_chips is not None:
        from repro.launch.planner import format_table, plan_model

        plan = plan_model(
            args.arch, args.plan_chips, shape=args.plan_shape, simulate_top_k=1
        )
        print(format_table(plan))
        return plan

    arch = get_arch(args.arch)
    if args.reduced:
        arch = arch.reduced()
    model = build_model(arch)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                          total_steps=args.steps, weight_decay=0.01)
    params = model.init(jax.random.key(args.seed))
    opt_state = adamw.init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={arch.name} params={n_params/1e6:.2f}M devices={jax.device_count()}")

    step_fn = jax.jit(make_train_step(model, opt_cfg, args.microbatches))

    mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    start_step = 0
    if mgr and args.resume and mgr.latest_step() is not None:
        start_step, (params, opt_state) = mgr.restore((params, opt_state))
        print(f"resumed from checkpoint step {start_step}")

    comp_state = comp.init_state(params) if args.compress != "none" else None

    data_cfg = DataConfig(seed=args.seed, global_batch=args.batch, seq_len=args.seq)
    pipeline = DataPipeline(arch, data_cfg, start_step=start_step)
    monitor = HeartbeatMonitor([f"w{i}" for i in range(jax.device_count())], timeout=60.0)
    straggler = StragglerTracker()

    losses = []
    pending_save = None
    try:
        for step, batch in pipeline:
            if step >= args.steps:
                break
            if args.simulate_failure_at is not None and step == args.simulate_failure_at:
                print(f"[fault] simulated worker failure at step {step}; restoring")
                monitor.last_seen["w0"] = -np.inf
                failed = monitor.check()
                assert failed == ["w0"]
                if mgr and mgr.latest_step() is not None:
                    restored_step, (params, opt_state) = mgr.restore((params, opt_state))
                    print(f"[fault] restored checkpoint step {restored_step}")
                monitor.rejoin("w0")
                args.simulate_failure_at = None  # don't loop
            with obs_timer("train.step", step=step) as tm:
                jbatch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt_state, metrics = step_fn(params, opt_state, jbatch)
                loss = float(metrics["loss"])
            dt = tm.elapsed
            straggler.record("w0", dt)
            losses.append(loss)
            if args.compress != "none":
                # demonstrate the cross-pod path: compress the params delta
                # that WOULD cross the DCI (accounting only on 1 host)
                pass
            if step % args.log_every == 0:
                print(
                    f"step {step:5d} loss {loss:7.4f} "
                    f"gnorm {float(metrics['grad_norm']):8.3f} "
                    f"lr {float(metrics['lr']):.2e} {dt*1e3:6.1f} ms"
                )
            if mgr and (step + 1) % args.ckpt_every == 0:
                if pending_save is not None:
                    pending_save.result()
                pending_save = mgr.save_async(step + 1, (params, opt_state))
    finally:
        pipeline.close()
        if pending_save is not None:
            pending_save.result()

    if mgr:
        mgr.save(args.steps, (params, opt_state))
    window = max(len(losses) // 5, 1)
    first, last = float(np.mean(losses[:window])), float(np.mean(losses[-window:]))
    print(f"done: loss {first:.4f} -> {last:.4f} over {len(losses)} steps")
    return first, last


if __name__ == "__main__":
    main()
