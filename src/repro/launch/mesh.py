"""Production mesh construction with paper-driven physical axis planning.

``make_production_mesh`` builds the required logical meshes:
  single-pod: (16, 16)      axes ("data", "model")
  multi-pod:  (2, 16, 16)   axes ("pod", "data", "model")

The paper's contribution enters in two places:

1. **Slice geometry** (``plan_slice``): when a job asks for C chips of a
   pod, the isoperimetric analysis picks the cuboid slice with maximal
   internal bisection (Theorem 3.1 / best_slice_geometry) — the TPU
   analogue of the Mira/JUQUEEN partition proposals.
2. **Axis assignment** (``plan_axes``): logical mesh axes are mapped onto
   physical torus dimensions so that the heaviest-traffic axis gets the
   best rings (wrapped > chain, contiguous > strided).  The resulting
   :class:`CollectiveCostModel` prices every jax.lax collective for the
   roofline's contention-aware term.

Note: importing this module never touches jax device state; all mesh
construction happens inside functions (dry-runs set
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax
import, see dryrun.py).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.network import (
    AxisAssignment,
    CollectiveCostModel,
    MachineState,
    Placement,
    RankMapping,
    TorusFabric,
    assign_axes,
    best_placement,
    best_slice_geometry,
    map_ranks,
    simulate_traffic,
    slice_fabric,
    worst_slice_geometry,
)
from repro.network.fabric import DEFAULT_LINK_BW, POD_DCI_BW, ranked_slice_geometries

# TPU v5e-class pod: 16x16 torus, wrapped in both dimensions.
POD_DIMS = (16, 16)
POD_WRAP = (True, True)


def pod_fabric(link_bw: float = DEFAULT_LINK_BW) -> TorusFabric:
    return TorusFabric(POD_DIMS, POD_WRAP, link_bw)


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# ---------------------------------------------------------------------------
# Paper-driven planning
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MeshPlan:
    """The physical plan behind a logical mesh."""

    slice_geometry: Tuple[int, ...]
    slice_bisection_links: int
    worst_geometry: Tuple[int, ...]
    worst_bisection_links: int
    assignment: AxisAssignment
    cost_model: CollectiveCostModel
    placement: Optional[Placement] = None  # set by occupancy-aware planning
    mapping: Optional[RankMapping] = None  # rank->chip embedding (with placement)
    #: Flow-simulated contention multiplier of the mapping's traffic on the
    #: pod (makespan over the zero-contention bound; None unless
    #: ``plan_slice(..., simulate=True)`` ran on an occupancy-aware plan).
    simulated_slowdown: Optional[float] = None
    #: Granted-over-best slice bisection: the chosen geometry's internal
    #: bisection over the best rankable geometry of this size on an *empty*
    #: pod — 1.0 for geometry-only plans; < 1.0 when occupancy forced the
    #: planner down the ranked list.
    bisection_efficiency: float = 1.0
    #: The fleet planner's full ranked table
    #: (:class:`repro.launch.planner.SlicePlan`) when the plan was built
    #: with ``plan_slice(..., arch=...)``; None for geometry-only planning.
    slice_plan: Optional[object] = None

    @property
    def avoidable_contention(self) -> float:
        """Bisection ratio best/worst: the paper's avoidable-contention factor."""
        if self.worst_bisection_links == 0:
            return 1.0
        return self.slice_bisection_links / self.worst_bisection_links

    @property
    def predicted_contention(self) -> float:
        """Shared-link contention score of the planned placement (0 when the
        plan was geometry-only or the pod was empty)."""
        return self.placement.predicted_contention if self.placement else 0.0

    @property
    def mapping_congestion(self) -> float:
        """Predicted intra-job max link load of the chosen rank mapping
        under the mesh's ring-collective (logical halo) traffic; 0.0 for
        geometry-only plans, which carry no concrete cells to map onto."""
        return self.mapping.score.congestion if self.mapping else 0.0


def plan_slice(
    chips: int,
    pod: Optional[TorusFabric] = None,
    state: Optional[MachineState] = None,
    job_id: Optional[int] = None,
    simulate: bool = False,
    arch: Optional[str] = None,
    shape: str = "decode_32k",
) -> MeshPlan:
    """Choose slice geometry + axis layout for a C-chip job on one pod.

    Without ``state`` the plan is geometry-only: the isoperimetric optimum
    among all cuboids of the requested size (the empty-pod answer).  With a
    ``state`` (a :class:`MachineState` occupancy grid over the pod's chips)
    the planner walks geometries in slice-bisection order and, for the first
    one with a free translate, scores every candidate placement with the
    routing engine — least predicted interference with the pod's existing
    placements, ties broken by the deterministic scan order (snug
    anti-fragmentation tie-breaking only activates on interference-free
    fabrics, which real pods, with their >= 6 rings, are not; see
    :func:`repro.network.placement.best_placement`).  Passing ``job_id``
    commits the chosen placement to ``state``.

    Occupancy-aware plans also carry a **rank mapping**
    (:func:`repro.network.map_ranks`): logical mesh ranks — raveled
    row-major over the (data, model) mesh shape — are embedded onto the
    placement's chips minimising ring-collective (logical halo)
    congestion, and the axis assignment prices collectives with the
    mapping's *measured* stride/wrap instead of assuming a contiguous
    wrapped ring.  Geometry-only plans keep ``mapping=None`` and the
    assumed embedding (the empty-pod answer is unchanged).

    Every plan reports ``MeshPlan.bisection_efficiency`` — the chosen
    slice's bisection over the best rankable geometry of this size on an
    empty pod (1.0 unless occupancy forced a worse geometry), the per-plan
    counterpart of the isoperimetry advisor's efficiency.

    ``simulate=True`` additionally drains the chosen mapping's traffic
    through the flow-level simulator (:mod:`repro.network.netsim`) and
    records the measured contention multiplier on
    ``MeshPlan.simulated_slowdown`` — the dynamic counterpart of
    ``mapping_congestion``, only available for occupancy-aware plans
    (geometry-only plans have no concrete cells to simulate on).

    ``arch`` switches on **planner-backed** mode: the fleet planner
    (:func:`repro.launch.planner.plan_model`) jointly searches geometry x
    mapping x sharding rule for that config under the given ``shape`` cell,
    the geometry walk follows the planner's ranked-table preference order
    (which may *deliberately* prefer a lower-bisection slice when a
    wrapped ring pays for it), the logical axes come from the winning
    sharding rule, and the full ranked table rides on
    ``MeshPlan.slice_plan``.
    """
    pod = pod or pod_fabric()
    slice_plan = None
    if arch is not None:
        from repro.launch.planner import plan_model  # lazy: mesh <- planner cycle

        slice_plan = plan_model(arch, chips, pod=pod, shape=shape)
    placement: Optional[Placement] = None
    best_bis: Optional[int] = None
    if state is None:
        if job_id is not None:
            raise ValueError("job_id requires a state (occupancy grid) to commit to")
        if slice_plan is not None:
            geom = slice_plan.geometry
            bis = slice_fabric(pod, geom).bisection_links()
            best_bis = ranked_slice_geometries(pod, chips)[0][1]
        else:
            geom, bis = best_slice_geometry(pod, chips)
            best_bis = bis
    else:
        if tuple(state.dims) != tuple(pod.dims):
            raise ValueError(
                f"occupancy grid dims {state.dims} != pod dims {pod.dims}"
            )
        geom = None
        bis = 0
        ranked = ranked_slice_geometries(pod, chips)
        best_bis = ranked[0][1]
        if slice_plan is not None:
            ranked = [
                (g, slice_fabric(pod, g).bisection_links())
                for g in slice_plan.geometry_preferences()
            ]
        for g, b in ranked:
            cand = best_placement(state.grid, g, state.traffic_loads())
            if cand is not None:
                geom, bis = g, b
                placement = Placement(
                    job_id=-1 if job_id is None else job_id,
                    geometry=g,
                    oriented=cand.oriented,
                    offset=cand.offset,
                    bisection_links=b,
                    predicted_contention=cand.contention,
                )
                break
        if geom is None:
            raise ValueError(
                f"no {chips}-chip cuboid slice fits the current occupancy of {pod.dims}"
            )
        if job_id is not None:
            placement = state.commit(
                job_id, geom, placement.oriented, placement.offset,
                placement.predicted_contention, bisection=bis,
            )
    wgeom, wbis = worst_slice_geometry(pod, chips)
    fabric = slice_fabric(pod, geom)
    # default logical axes for a single-pod job: data x model, sized by the
    # slice dims (largest dim -> data).
    dims = sorted(fabric.dims, reverse=True)
    axes = {"data": dims[0], "model": chips // dims[0]}
    order_hint = ["model", "data"]
    if slice_plan is not None:
        # Planner-backed: the winning sharding rule's non-trivial axes.
        from repro.launch.planner import AXES, ORDER_HINT

        planned = {
            name: size
            for name, size in zip(AXES, slice_plan.best.axis_sizes)
            if size > 1
        }
        if planned and _axes_embed(fabric, planned):
            axes = planned
            order_hint = [a for a in ORDER_HINT if a in axes]
    mapping = None
    if placement is not None:
        # Embed the logical mesh onto the placed chips: minimise
        # ring-collective congestion (logical halo traffic), then let the
        # axis assignment price collectives with the measured stride/wrap
        # of the chosen mapping.
        mapping = map_ranks(
            pod.dims,
            placement.oriented,
            placement.offset,
            logical_dims=tuple(axes.values()),
            pattern="halo",
            double_link_on_2=pod.double_link_on_2,
            wrap=pod.wrap,
        )
    assignment = assign_axes(fabric, axes, order_hint=order_hint, mapping=mapping)
    simulated_slowdown = None
    if simulate and mapping is not None:
        sim = simulate_traffic(
            pod.dims,
            mapping.machine_traffic(),
            link_bw=pod.link_bw,
            double_link_on_2=pod.double_link_on_2,
        )
        simulated_slowdown = sim.slowdown
    return MeshPlan(
        slice_geometry=geom,
        slice_bisection_links=bis,
        worst_geometry=wgeom,
        worst_bisection_links=wbis,
        assignment=assignment,
        cost_model=CollectiveCostModel(fabric, assignment),
        placement=placement,
        mapping=mapping,
        simulated_slowdown=simulated_slowdown,
        bisection_efficiency=(bis / best_bis if best_bis else 1.0),
        slice_plan=slice_plan,
    )


def _axes_embed(fabric: TorusFabric, axes: Dict[str, int]) -> bool:
    """Whether every logical axis can occupy whole physical dims of the
    fabric (the jax device-mesh reshape constraint assign_axes enforces)."""
    try:
        assign_axes(fabric, axes, order_hint=list(axes))
        return True
    except ValueError:
        return False


def plan_axes(
    axis_sizes: Dict[str, int],
    traffic_order: Optional[Tuple[str, ...]] = None,
    pod: Optional[TorusFabric] = None,
) -> CollectiveCostModel:
    """Map logical axes onto the full pod torus, heaviest traffic first.

    For LM training the heaviest-traffic axis is "model" (per-layer
    all-gathers/reduce-scatters of activations and weights); "data" sees a
    gradient all-reduce once per step.  The planner therefore gives "model"
    the wrapped contiguous rings by default — this *is* the paper's
    geometry-aware allocation, applied to mesh-axis layout.
    """
    pod = pod or pod_fabric()
    order = tuple(traffic_order) if traffic_order else ("model", "data")
    order = tuple([a for a in order if a in axis_sizes]) + tuple(
        a for a in axis_sizes if a not in (traffic_order or ())
        and a not in (order if traffic_order else ())
    )
    # dedupe, preserving order
    seen, final = set(), []
    for a in order:
        if a in axis_sizes and a not in seen:
            seen.add(a)
            final.append(a)
    assignment = assign_axes(pod, axis_sizes, order_hint=final)
    return CollectiveCostModel(pod, assignment)


def multi_pod_cost_model(axis_sizes: Dict[str, int]) -> Dict[str, CollectiveCostModel]:
    """Per-pod ICI model + a DCI model for the 'pod' axis.

    The pod axis rides the data-center interconnect: modelled as a chain
    (no wrap) with POD_DCI_BW per chip-pair share.
    """
    ici_axes = {k: v for k, v in axis_sizes.items() if k != "pod"}
    ici = plan_axes(ici_axes)
    dci_fabric = TorusFabric(
        (axis_sizes.get("pod", 1),), (False,), POD_DCI_BW
    )
    dci_assignment = assign_axes(dci_fabric, {"pod": axis_sizes.get("pod", 1)})
    return {"ici": ici, "dci": CollectiveCostModel(dci_fabric, dci_assignment)}
