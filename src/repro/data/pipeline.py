"""Deterministic, shardable synthetic data pipeline.

Production shape without production data: batches are generated from a
counter-based PRNG (stateless — batch ``i`` is a pure function of (seed, i)),
which gives the three properties a multi-pod pipeline needs:

* **determinism / resumability** — restart at step k reproduces batch k
  exactly (no state to checkpoint beyond the step counter);
* **host sharding** — each host materialises only its slice of the global
  batch (``host_slice``), no cross-host data traffic;
* **prefetch** — a background thread keeps ``prefetch`` batches ready.

The token distribution is a Zipfian mixture with short-range structure so
losses are non-degenerate (pure uniform tokens make CE trivially flat).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    num_hosts: int = 1
    host_index: int = 0
    prefetch: int = 2


def _rng_for(seed: int, step: int, host: int) -> np.random.Generator:
    # counter-based: independent stream per (seed, step, host)
    return np.random.default_rng(np.random.SeedSequence([seed, step, host]))


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    # Zipf-ish marginal + Markov-ish local structure
    base = rng.zipf(1.3, size=shape).astype(np.int64)
    tokens = (base - 1) % vocab
    # short-range structure: with p=0.3 repeat previous token + 1
    rep = rng.random(shape) < 0.3
    shifted = np.roll(tokens, 1, axis=-1)
    tokens = np.where(rep, (shifted + 1) % vocab, tokens)
    return tokens.astype(np.int32)


def host_slice(cfg: DataConfig) -> Tuple[int, int]:
    assert cfg.global_batch % cfg.num_hosts == 0
    per = cfg.global_batch // cfg.num_hosts
    return cfg.host_index * per, per


def make_batch(arch: ArchConfig, cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """The batch for a given step — this host's slice only."""
    start, per = host_slice(cfg)
    rng = _rng_for(cfg.seed, step, cfg.host_index)
    if arch.frontend == "audio":
        frames = rng.standard_normal((per, cfg.seq_len, arch.d_model)).astype(np.float32)
        targets = _zipf_tokens(rng, (per, cfg.seq_len, arch.n_codebooks), arch.vocab_size)
        return {"frame_embeds": frames, "targets": targets}
    out = {"tokens": _zipf_tokens(rng, (per, cfg.seq_len), arch.vocab_size)}
    if arch.frontend == "vlm":
        out["patch_embeds"] = rng.standard_normal(
            (per, arch.num_patches, arch.d_model)
        ).astype(np.float32)
    return out


class DataPipeline:
    """Prefetching iterator over deterministic batches."""

    def __init__(self, arch: ArchConfig, cfg: DataConfig, start_step: int = 0):
        self.arch = arch
        self.cfg = cfg
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=max(1, cfg.prefetch))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            batch = make_batch(self.arch, self.cfg, s)
            while not self._stop.is_set():
                try:
                    self._q.put((s, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator[Tuple[int, Dict[str, np.ndarray]]]:
        return self

    def __next__(self) -> Tuple[int, Dict[str, np.ndarray]]:
        step, batch = self._q.get()
        self.step = step + 1
        return step, batch

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
