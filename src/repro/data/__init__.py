from .pipeline import DataConfig, DataPipeline, make_batch, host_slice
