"""repro.network — the unified fabric modeling subsystem.

Single home of every geometry / fabric / routing primitive in the repo
(see DESIGN.md):

  geometry    — canonical geometries, factorizations, exact cuboid cut and
                interior counts, exact bisection search, ExplicitTorus.
  isoperimetry— vectorized edge-isoperimetric engine: batched cuts of every
                same-volume geometry via divisor meshgrids, Theorem 2.1/3.1
                bounds with tightness certificates, bisection tables, and
                the partition advisor (current-policy vs optimal geometry
                with predicted + simulated speedups); fabric-dispatching
                (Hamming cut closed forms + Lindsey bisections on HyperX).
  fabric      — the abstract Fabric interface (explicit ``links()``
                incidence tables) with TorusFabric (per-dimension wrap
                flags, BG/Q double-link vs TPU single-link conventions)
                and HyperXFabric (Hamming graph: per-dim cliques with
                trunked link multiplicities) implementations, Torus compat
                wrapper, slice planning.
  hamming     — Hamming-graph edge-isoperimetry closed forms: aligned-box
                cuts, Lindsey lex bound, packed-edges fallback, exact
                bisections.
  routing     — vectorized NumPy DOR link-load engine, closed-form
                translation-invariant fast paths, pairing predictions;
                HyperX minimal + DAL routing behind ``route_pattern``.
  patterns    — traffic-pattern library (bisection pairing, all-to-all,
                halo exchange, ring collectives, permutations, transpose).
  netsim      — vectorized flow-level simulator: max-min fair link
                sharing over DOR or minimal-adaptive paths, phased
                collective schedules, prediction validation.
  collectives — jax.lax collective cost model + mesh-axis assignment.
  placement   — vectorized cuboid-placement engine: all free translates via
                circular windowed sums, contention/contact scoring.
  allocation  — partition allocation policies and the online queue
                simulator (arrival streams, EASY backfill).
  scheduler   — event-sourced scheduler service over the allocation
                engine: append-only event log (Arrival/Start/Complete/
                Fail/Preempt/Reclaim), deterministic (time, kind, seq)
                ordering with a scale-aware clock tolerance, priority
                queues with preemption/reclaim, failure evacuation wired
                to runtime/fault_tolerance, backpressure shedding, and a
                seeded scenario generator; simulate_queue is a thin batch
                driver over it.
  mapping     — topology-aware rank mapping inside a placement: strategy
                catalogue (identity / axis-permutation / gray-snake /
                greedy refinement) scored by congestion + dilation.
  backend     — compiled (jax.jit) backends for the hot engines: DOR link
                loads, the progressive-filling drain, the FFT contention
                field, closed-form cut scoring, and the vmap-batched
                candidate scorer; numpy stays the default + exact oracle.

The historical
``repro.core.{torus,contention,collectives,allocation,isoperimetry}``
modules re-export from here and are deprecated.
"""

from .backend import (
    BACKENDS,
    HAVE_JAX,
    DrainPlan,
    drain,
    drain_batch,
    prepare_drain,
    resolve_backend,
    score_candidates,
    xla_contention_field,
    xla_cut_scores,
    xla_route_loads,
)
from .geometry import (
    ExplicitTorus,
    Geometry,
    all_divisor_geometries,
    canonical,
    contains_cuboid,
    cuboid_cut,
    cuboid_cut_aligned,
    cuboid_interior,
    degree_contribution,
    enumerate_vertices,
    factorizations,
    sub_cuboids,
    volume,
)
from .geometry import bisection_links as torus_bisection_links
from .isoperimetry import (
    BisectionTable,
    CuboidOptimum,
    CutTable,
    PartitionAdvice,
    advise_partition,
    advise_policy_table,
    best_bisection_geometry,
    bisection_of_geometry,
    bisection_table,
    bollobas_leader_bound,
    cut_table,
    fitting_geometries,
    is_isoperimetrically_optimal,
    lemma32_cut,
    optimal_cuboid,
    ranked_geometries,
    scaled_node_dims,
    small_set_expansion,
    theorem31_bound,
    worst_bisection_geometry,
    worst_cuboid,
)
from .fabric import (
    DEFAULT_LINK_BW,
    POD_DCI_BW,
    Fabric,
    HyperXFabric,
    LinkTable,
    Torus,
    TorusFabric,
    best_slice_geometry,
    ranked_slice_geometries,
    slice_fabric,
    worst_slice_geometry,
)
from .hamming import (
    hamming_bisection_links,
    hamming_cut_aligned,
    hamming_cut_of_set,
    hamming_subset_bound,
    lindsey_bound,
)
from .routing import (
    LinkLoads,
    PairingPrediction,
    all_to_all_max_load,
    hyperx_all_to_all_max_load,
    hyperx_max_link_load,
    max_link_load,
    pairing_speedup,
    predict_pairing_time,
    route_dor,
    route_hyperx,
    route_pattern,
    simulate_pattern,
    uniform_offset_max_load,
)
from .patterns import (
    all_to_all,
    bisection_pairing,
    furthest_offset,
    hotspot_line,
    nearest_neighbor_halo,
    pairing_pairs,
    random_permutation,
    ring_all_gather,
    ring_all_reduce_phases,
    ring_shift,
    transpose,
    uniform_shift,
    vertices,
)
from .netsim import (
    FlowPaths,
    FlowSimResult,
    PhasedSimResult,
    PredictionValidation,
    RoutingComparison,
    UtilizationSample,
    adaptive_paths,
    build_paths,
    compare_fabric_routing,
    compare_routing,
    dor_paths,
    fabric_paths,
    link_capacities,
    simulate_fabric_traffic,
    simulate_flows,
    simulate_phases,
    simulate_traffic,
    validate_prediction,
)
from .collectives import (
    AxisAssignment,
    AxisEmbedding,
    COLLECTIVE_TIME,
    CollectiveCostModel,
    assign_axes,
    collective_permute_time,
    ring_all_gather_time,
    ring_all_reduce_time,
    ring_all_to_all_time,
    ring_reduce_scatter_time,
    simulated_ring_all_reduce_time,
)
from .placement import (
    ScoredPlacement,
    best_placement,
    fabric_can_interfere,
    first_fit,
    free_offset_mask,
    is_spilling,
    iter_free_placements,
    pad_geometry,
    placement_cells,
    placement_loads,
    shell_contact,
)
from .mapping import (
    MAPPING_PATTERNS,
    MappingScore,
    RankMapping,
    axis_permutation_orders,
    identity_mapping,
    map_ranks,
    mapping_loads,
    mapping_traffic,
    mesh_axis_hops,
    pattern_traffic,
    placement_cell_coords,
    score_mapping,
    snake_mapping,
    toroidal_hops,
)
from .allocation import (
    AllocationPolicy,
    ContentionScoredPolicy,
    ElongatedPolicy,
    HintedPolicy,
    IsoperimetricPolicy,
    JobRequest,
    ListPolicy,
    MachineState,
    Placement,
    ScheduledJob,
    SimulationResult,
    avoidable_contention_ratio,
    simulate_queue,
)
from .scheduler import (
    Event,
    Scenario,
    SchedulerService,
    apply_monitor_failures,
    generate_scenario,
    replay_events,
    run_scenario,
    scheduler_throughput,
    time_close,
    time_eps,
)
