"""Processor-allocation policies, the placement engine wrapper, and an
online queue simulator with arrival streams and backfill.

This is the paper's contribution turned into a deployable scheduler
component: given a machine fabric (a torus of allocation units — midplanes
on Blue Gene/Q, chips on a TPU pod) and a stream of jobs, allocate cuboid
partitions.  Policies differ in which geometry they pick for a given size
and (for the scored policy) where it lands:

* ``ElongatedPolicy``     — worst-case baseline: most elongated cuboid that
  fits (models "fill dimension-by-dimension" schedulers; JUQUEEN worst case).
* ``ListPolicy``          — a fixed geometry per size (models Mira's
  predefined partition list).
* ``IsoperimetricPolicy`` — the paper's policy: the geometry of maximal
  internal bisection bandwidth that fits the current free space, preferring
  better-bisection geometries even when fragmentation makes them harder to
  place (falls back in bisection order).
* ``HintedPolicy``        — isoperimetric for jobs flagged contention-bound,
  first-fit otherwise (Section 5's scheduler-hint proposal).
* ``ContentionScoredPolicy`` — isoperimetric geometry choice plus *scored
  placement*: among all free translates, pick the candidate minimising
  predicted interference with existing placements (the job's intra-slice
  all-to-all traffic routed on the machine torus by the DOR engine —
  pairing traffic is provably isolated and would score zero everywhere),
  breaking ties toward snug, fragmentation-avoiding offsets on
  interference-free fabrics.

Placement is exact and vectorized: an occupancy grid over the machine torus
is correlated with the cuboid kernel (:mod:`repro.network.placement`), so
all free translates of all orientations come out of O(D·N) array work —
the historical Python scan survives as ``tests/reference_placement.py``.
Wrap-around placement is allowed, since torus partitions remain tori (BG/Q)
— for TPU-style fabrics the resulting slice's wrap flags are recomputed by
:func:`repro.network.fabric.slice_fabric`.

The queue simulator is event-driven: jobs carry ``arrival`` timestamps,
head-of-line blocking is FCFS-exact, and ``backfill=True`` enables
EASY-style conservative backfill — a later job may jump the blocked head
only if it terminates before the head's reservation (the earliest time the
head is guaranteed to fit, computed by replaying completions on a scratch
grid).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .fabric import HyperXFabric, TorusFabric
from .geometry import Geometry, bisection_links, canonical, sub_cuboids
from .isoperimetry import ranked_geometries, scaled_node_dims
from .mapping import RankMapping, map_ranks
from .netsim import dor_paths, simulate_flows
from .placement import (
    ScoredPlacement,
    best_placement,
    first_fit,
    int_placement_loads,
    pad_geometry,
    placement_all_to_all_traffic,
    placement_cells,
    placement_loads,
)
from .routing import max_link_load, predict_pairing_time

Coord = Tuple[int, ...]


@dataclass(frozen=True)
class JobRequest:
    """One job in the queue: ``units`` allocation units (midplanes/chips),
    an ``arrival`` timestamp and a ``duration``, both in the simulator's
    abstract time units; ``contention_bound`` is the Section-5 scheduler
    hint consumed by :class:`HintedPolicy`.

    ``geometry`` optionally carries a planner-chosen partition geometry
    (e.g. :meth:`repro.launch.planner.SlicePlan.to_request`): every policy
    tries it first and only then falls back to its own preference list, so
    a fleet-planner decision survives scheduling without a custom policy.
    """

    job_id: int
    units: int  # allocation units (midplanes / chips)
    contention_bound: bool = True
    duration: float = 1.0  # abstract time units, for the queue simulator
    arrival: float = 0.0  # submission time (0 = all queued up front)
    geometry: Optional[Geometry] = None  # planner-requested partition shape

    def __post_init__(self):
        if self.geometry is not None:
            g = canonical(self.geometry)
            n = 1
            for a in g:
                n *= a
            if n != self.units:
                raise ValueError(
                    f"requested geometry {tuple(self.geometry)} has volume "
                    f"{n}, but the request asks for {self.units} units"
                )
            object.__setattr__(self, "geometry", g)


@dataclass(frozen=True)
class Placement:
    """A committed allocation: canonical ``geometry``, the per-machine-dim
    ``oriented`` extents actually placed at ``offset`` (cells may wrap),
    its internal ``bisection_links`` (links, not bandwidth) and the
    ``predicted_contention`` shared-link score (traffic-volume units; 0.0
    for unscored policies)."""

    job_id: int
    geometry: Geometry  # canonical (sorted desc)
    oriented: Tuple[int, ...]  # per-machine-dimension extent actually placed
    offset: Coord
    bisection_links: int
    predicted_contention: float = 0.0  # shared-link score (scored policies)


class MachineState:
    """Occupancy grid over the machine's allocation-unit torus.

    A thin stateful wrapper around :mod:`repro.network.placement`: the grid,
    the live placement table, and a lazily maintained background-traffic
    load tensor (the sum of every placement's pairing traffic routed on the
    machine torus) used by contention-scored allocation.

    ``backend`` selects the compiled backend for the scored-allocation
    contention fields (:func:`repro.network.placement.best_placement`);
    the first-fit occupancy scans are integer windowed sums and always
    run in NumPy (see DESIGN.md "Compiled backends").
    """

    def __init__(self, dims: Sequence[int], backend: Optional[str] = None):
        # Accepts plain allocation-unit dims (torus semantics, historical
        # default) or a Fabric.  HyperX occupancy uses the same grid: a
        # clique dimension is invariant under coordinate relabeling, so a
        # wrapped translate of a box is just another valid aligned box.
        if isinstance(dims, (TorusFabric, HyperXFabric)):
            self.fabric: Optional[object] = dims
            self.dims = dims.dims
        else:
            self.fabric = None
            self.dims = tuple(int(d) for d in dims)
        self.grid = np.zeros(self.dims, dtype=bool)
        self.placements: Dict[int, Placement] = {}
        # Exact accumulator: per placement size n, the int64 sum of the
        # live placements' integer-scaled load fields (value 2·n·load, see
        # placement.int_base_loads).  Integer add/subtract is lossless, so
        # release subtracts a placement back out bit-exactly instead of
        # discarding the cache and re-correlating every live job.
        self._int_loads: Dict[int, np.ndarray] = {}
        self._loads: Optional[np.ndarray] = None  # lazy float recombination
        self.backend = backend

    @property
    def free_units(self) -> int:
        return int((~self.grid).sum())

    @property
    def fabric_or_dims(self):
        """The fabric this machine was built from, or its plain dims — the
        value fabric-dispatching engines (isoperimetry, routing) accept."""
        return self.fabric if self.fabric is not None else self.dims

    def _geometry_bisection(self, geometry: Geometry) -> int:
        """Internal bisection of a canonical geometry under this machine's
        fabric convention (Hamming sub-box on HyperX, wrapped torus else)."""
        if isinstance(self.fabric, HyperXFabric):
            return self.fabric.sub_fabric(geometry).bisection_links()
        return bisection_links(geometry)

    def cells(self, oriented: Sequence[int], offset: Coord) -> Tuple[np.ndarray, ...]:
        return placement_cells(self.dims, oriented, offset)

    def find_placement(self, geometry: Sequence[int]) -> Optional[Tuple[Tuple[int, ...], Coord]]:
        """First free translate of any orientation of the cuboid; None if
        full.  Identical choice to the brute-force reference scan; raises
        ``ValueError`` if the geometry has more non-trivial dims than the
        machine (the historical scan silently truncated it)."""
        return first_fit(self.grid, geometry)

    def _recombine(
        self,
        exclude_size: Optional[int] = None,
        exclude_field: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        total = np.zeros((len(self.dims), 2) + self.dims)
        for n in sorted(self._int_loads):
            acc = self._int_loads[n]
            if n == exclude_size:
                acc = acc - exclude_field
            total += acc / (2.0 * n)
        return total

    def traffic_loads(self, exclude: Optional[int] = None) -> np.ndarray:
        """(D, 2, *dims) link loads of all current placements' intra-job
        all-to-all traffic on the machine torus (the scored policies'
        background; see :func:`repro.network.placement.placement_loads`).

        Maintained *exactly*: commits add and releases subtract each
        placement's integer-scaled field
        (:func:`repro.network.placement.int_base_loads`) in int64, and
        this recombines the per-size sums as ``Σ_n S_n / (2n)`` — each
        int64 value converts to float without rounding (they stay far
        below 2**53), so the background after any alloc/release stream is
        bit-identical to a fresh recompute over the surviving placements
        (property-pinned) with no O(live jobs × grid) rebuild on release.

        ``exclude`` removes one live job's own field before recombining —
        again in the integer domain, hence exactly — which is the measured
        -contention background of that job (callers previously subtracted
        the float field after the fact and relied on the residue staying
        under the sharing threshold)."""
        if isinstance(self.fabric, HyperXFabric):
            raise TypeError(
                "traffic_loads is the torus-routed background field; on a "
                "HyperX fabric disjoint aligned boxes share no links (every "
                "minimal path stays inside its own box), so there is no "
                "cross-placement background to maintain"
            )
        if exclude is not None:
            p = self.placements[exclude]
            return self._recombine(
                int(np.prod(p.oriented)),
                int_placement_loads(self.dims, p.oriented, p.offset),
            )
        if self._loads is None:
            self._loads = self._recombine()
        return self._loads

    def _commit(
        self,
        job_id: int,
        geometry: Sequence[int],
        oriented: Tuple[int, ...],
        offset: Coord,
        predicted_contention: float = 0.0,
        bisection: Optional[int] = None,
    ) -> Placement:
        cells = self.cells(oriented, offset)
        self.grid[cells] = True
        p = Placement(
            job_id=job_id,
            geometry=canonical(geometry),
            oriented=oriented,
            offset=offset,
            bisection_links=(
                self._geometry_bisection(canonical(geometry))
                if bisection is None
                else bisection
            ),
            predicted_contention=predicted_contention,
        )
        self.placements[job_id] = p
        n = int(np.prod(oriented))
        delta = int_placement_loads(self.dims, oriented, offset)
        if delta.any():  # single-cell placements route no traffic
            acc = self._int_loads.get(n)
            if acc is None:
                self._int_loads[n] = np.array(delta)  # cached field is read-only
            else:
                acc += delta
        self._loads = None  # recombined lazily (exact, O(sizes · grid))
        return p

    def allocate(self, job_id: int, geometry: Sequence[int]) -> Optional[Placement]:
        """First-fit allocation (reference-identical choice)."""
        spot = self.find_placement(geometry)
        if spot is None:
            return None
        oriented, offset = spot
        return self._commit(job_id, geometry, oriented, offset)

    def allocate_scored(self, job_id: int, geometry: Sequence[int]) -> Optional[Placement]:
        """Contention/contact-scored allocation of one geometry.

        On a HyperX machine placement scoring is vacuous — minimal (and
        DAL) paths of an aligned box never leave the box's own links, so
        every free translate predicts exactly zero shared-link contention
        — and this degrades to first-fit with a 0.0 score."""
        if isinstance(self.fabric, HyperXFabric):
            return self.allocate(job_id, geometry)
        cand: Optional[ScoredPlacement] = best_placement(
            self.grid, geometry, self.traffic_loads(), backend=self.backend
        )
        if cand is None:
            return None
        return self._commit(
            job_id, geometry, cand.oriented, cand.offset, cand.contention
        )

    def commit(
        self,
        job_id: int,
        geometry: Sequence[int],
        oriented: Tuple[int, ...],
        offset: Coord,
        predicted_contention: float = 0.0,
        bisection: Optional[int] = None,
    ) -> Placement:
        """Commit an externally chosen placement (e.g. from
        :func:`repro.launch.mesh.plan_slice`), validating it first.

        ``bisection`` overrides the recorded ``bisection_links`` when the
        caller's fabric convention differs from the fully-wrapped torus
        default (e.g. wrap-aware TPU slice bisection)."""
        if job_id in self.placements:
            raise ValueError(f"job {job_id} already placed")
        oriented = tuple(int(w) for w in oriented)
        if len(oriented) != len(self.dims) or any(
            w < 1 or w > a for w, a in zip(oriented, self.dims)
        ):
            raise ValueError(f"orientation {oriented} does not fit machine {self.dims}")
        if tuple(sorted(oriented, reverse=True)) != pad_geometry(geometry, len(self.dims)):
            raise ValueError(
                f"orientation {oriented} is not an arrangement of geometry "
                f"{canonical(geometry)}"
            )
        if self.grid[self.cells(oriented, offset)].any():
            raise ValueError(
                f"placement {oriented}@{offset} overlaps occupied cells"
            )
        return self._commit(
            job_id, geometry, oriented, offset, predicted_contention, bisection
        )

    def release(self, job_id: int) -> None:
        """Free the job's cells and subtract its traffic field *exactly*
        (int64 accumulators — see :meth:`traffic_loads`), so the next
        scored allocation recombines a handful of per-size tensors instead
        of re-routing every live placement."""
        p = self.placements.pop(job_id)
        self.grid[self.cells(p.oriented, p.offset)] = False
        delta = int_placement_loads(self.dims, p.oriented, p.offset)
        if delta.any():
            n = int(np.prod(p.oriented))
            acc = self._int_loads[n]
            acc -= delta
            if not acc.any():
                # Nonnegative fields: a zero sum means every commit of this
                # size has been released — drop the bucket.
                del self._int_loads[n]
        self._loads = None  # recombined lazily (exact, O(sizes · grid))


# ---------------------------------------------------------------------------
# Policies.
# ---------------------------------------------------------------------------
def _honor_requested_geometry(
    prefs: List[Geometry], request: JobRequest
) -> List[Geometry]:
    """Move a request's planner-chosen geometry to the front of a policy's
    preference list (dropping the duplicate further down); identity when
    the request carries no geometry."""
    if request.geometry is None:
        return prefs
    g = request.geometry
    return [g] + [p for p in prefs if p != g]


class AllocationPolicy:
    """Base policy: a preference-ordered geometry list per request, placed
    first-fit down the list (scored policies override :meth:`allocate`)."""

    name = "base"

    def geometry_preferences(self, machine: MachineState, units: int) -> List[Geometry]:
        """Geometries to try, in preference order."""
        raise NotImplementedError

    def preferences_for(self, machine: MachineState, request: JobRequest) -> List[Geometry]:
        """Request-aware preference list (hinted policies override)."""
        return _honor_requested_geometry(
            self.geometry_preferences(machine, request.units), request
        )

    def allocate(self, machine: MachineState, request: JobRequest) -> Optional[Placement]:
        """Place the request on the machine, or return None.  Default:
        first-fit down the preference list."""
        for g in self.preferences_for(machine, request):
            placed = machine.allocate(request.job_id, g)
            if placed is not None:
                return placed
        return None


class ElongatedPolicy(AllocationPolicy):
    """Most elongated geometry first (adversarial / naive filler)."""

    name = "elongated"

    def geometry_preferences(self, machine: MachineState, units: int) -> List[Geometry]:
        geoms = list(sub_cuboids(machine.dims, units))
        return sorted(geoms, key=lambda g: (-g[0], g))


class IsoperimetricPolicy(AllocationPolicy):
    """The paper's policy: maximal internal bisection bandwidth first.

    The ranking comes from the isoperimetry engine's batched bisection
    table (:func:`repro.network.isoperimetry.ranked_geometries`) — one
    vectorized pass instead of a per-geometry ``bisection_links`` loop,
    with identical ordering (property-pinned)."""

    name = "isoperimetric"

    def geometry_preferences(self, machine: MachineState, units: int) -> List[Geometry]:
        try:
            return [g for g, _ in ranked_geometries(machine.fabric_or_dims, units)]
        except ValueError:
            return []  # no cuboid of this size fits (matches the old empty sort)


class ListPolicy(AllocationPolicy):
    """A fixed geometry per size (Mira's predefined scheduler list)."""

    name = "list"

    def __init__(self, table: Dict[int, Geometry]):
        self.table = dict(table)

    def geometry_preferences(self, machine: MachineState, units: int) -> List[Geometry]:
        if units not in self.table:
            return []
        return [canonical(self.table[units])]


class HintedPolicy(AllocationPolicy):
    """Contention-bound jobs get isoperimetric geometries; others first-fit."""

    name = "hinted"

    def __init__(self):
        self.iso = IsoperimetricPolicy()
        self.any = ElongatedPolicy()

    def geometry_preferences(
        self, machine: MachineState, units: int, contention_bound: bool = True
    ) -> List[Geometry]:
        pol = self.iso if contention_bound else self.any
        return pol.geometry_preferences(machine, units)

    def preferences_for(self, machine: MachineState, request: JobRequest) -> List[Geometry]:
        return _honor_requested_geometry(
            self.geometry_preferences(
                machine, request.units, request.contention_bound
            ),
            request,
        )


class ContentionScoredPolicy(AllocationPolicy):
    """Isoperimetric geometry choice + contention/contact-scored placement.

    Geometries are tried in bisection order (the paper's policy); within the
    first geometry that fits, the placement engine scores every free
    translate — predicted shared-link contention with existing placements
    first, snugness (anti-fragmentation contact) as the tie-break — instead
    of taking the first fit.

    ``min_bisection_efficiency`` adds a bisection-aware admissibility
    floor: geometries whose internal bisection falls below that fraction
    of the size-optimal bisection are dropped from the preference list
    entirely, so a contention-bound job *waits* for an efficient partition
    instead of accepting an elongated one when the machine is fragmented.
    The size-optimal geometry always meets the floor, so no request ever
    becomes impossible that was possible before — only later.  The default
    (0.0) keeps the historical behaviour exactly.
    """

    name = "contention-scored"

    def __init__(self, min_bisection_efficiency: float = 0.0):
        if not 0.0 <= min_bisection_efficiency <= 1.0:
            raise ValueError(
                f"min_bisection_efficiency must be in [0, 1], got "
                f"{min_bisection_efficiency}"
            )
        self.min_bisection_efficiency = float(min_bisection_efficiency)

    def geometry_preferences(self, machine: MachineState, units: int) -> List[Geometry]:
        try:
            ranked = ranked_geometries(machine.fabric_or_dims, units)
        except ValueError:
            return []
        if self.min_bisection_efficiency > 0.0 and ranked[0][1] > 0:
            floor = self.min_bisection_efficiency * ranked[0][1]
            ranked = [(g, b) for g, b in ranked if b >= floor - 1e-12]
        return [g for g, _ in ranked]

    def allocate(self, machine: MachineState, request: JobRequest) -> Optional[Placement]:
        for g in self.preferences_for(machine, request):
            placed = machine.allocate_scored(request.job_id, g)
            if placed is not None:
                return placed
        return None


# ---------------------------------------------------------------------------
# Queue simulator.
# ---------------------------------------------------------------------------
@dataclass
class ScheduledJob:
    request: JobRequest
    placement: Placement
    start: float
    end: float
    predicted_comm_time: float  # pairing-benchmark proxy, seconds/byte
    mapping: Optional[RankMapping] = None  # set when the simulator maps ranks
    #: Static max-load proxy on the job's own traffic alone — the lower
    #: bound no dynamic schedule can beat (contention="simulated" only).
    comm_lower_bound: float = 0.0
    #: Flow-simulated completion of the job's traffic against the
    #: placements live at start time (contention="simulated" only).
    simulated_comm_time: Optional[float] = None
    #: Internal bisection of the granted geometry over the best achievable
    #: bisection for this size on this machine (the isoperimetry engine's
    #: optimum) — 1.0 means the job got an isoperimetrically optimal
    #: partition, recorded for every scheduled job.
    bisection_efficiency: float = 1.0

    @property
    def simulated_slowdown(self) -> float:
        """Simulated completion over the static max-load lower bound
        (>= 1.0 by conservation; 1.0 when the job was not simulated or
        moves no traffic)."""
        if self.simulated_comm_time is None or self.comm_lower_bound <= 0.0:
            return 1.0
        return self.simulated_comm_time / self.comm_lower_bound


@dataclass
class SimulationResult:
    policy: str
    jobs: List[ScheduledJob] = field(default_factory=list)
    rejected: List[int] = field(default_factory=list)

    @property
    def mean_comm_time(self) -> float:
        """Mean predicted pairing-benchmark time over scheduled jobs
        (seconds per byte of per-pair message volume)."""
        if not self.jobs:
            return 0.0
        return float(np.mean([j.predicted_comm_time for j in self.jobs]))

    @property
    def makespan(self) -> float:
        """Completion time of the last job (simulator time units)."""
        return max((j.end for j in self.jobs), default=0.0)

    @property
    def mean_wait(self) -> float:
        """Mean queueing delay (start - arrival) over scheduled jobs."""
        if not self.jobs:
            return 0.0
        return float(np.mean([j.start - j.request.arrival for j in self.jobs]))

    @property
    def mean_contention(self) -> float:
        """Mean predicted shared-link contention score at placement time."""
        if not self.jobs:
            return 0.0
        return float(np.mean([j.placement.predicted_contention for j in self.jobs]))

    @property
    def mean_simulated_slowdown(self) -> float:
        """Mean flow-simulated slowdown over the static max-load bound
        (jobs scheduled under ``contention="simulated"``; 1.0 otherwise)."""
        simulated = [
            j.simulated_slowdown for j in self.jobs if j.simulated_comm_time is not None
        ]
        if not simulated:
            return 1.0
        return float(np.mean(simulated))

    @property
    def mean_bisection_efficiency(self) -> float:
        """Mean granted-over-optimal internal bisection across scheduled
        jobs (1.0 = every job got an isoperimetrically optimal geometry)."""
        if not self.jobs:
            return 1.0
        return float(np.mean([j.bisection_efficiency for j in self.jobs]))


# Traffic-sharing threshold of the measured-contention proxy (a load
# magnitude, not a time): a link is "shared" when the background carries
# more than this.  The event *clock* no longer uses a fixed epsilon — the
# scheduler service's scale-aware time_eps owns simultaneity (see
# repro.network.scheduler).
_EPS = 1e-12


def simulate_queue(
    machine_dims: Sequence[int],
    jobs: Iterable[JobRequest],
    policy: AllocationPolicy,
    unit_node_dims: Optional[Sequence[int]] = None,
    link_bw: float = 1.0,
    *,
    backfill: bool = False,
    measure_contention: bool = False,
    contention: Optional[str] = None,
    mapping_pattern: Optional[str] = None,
    double_link_on_2: bool = True,
    backend: Optional[str] = None,
) -> SimulationResult:
    """Online queue simulation with exact cuboid placement.

    Event-driven: jobs arrive at ``request.arrival`` (all-zero arrivals
    reproduce the historical FCFS batch semantics), are served head-of-line
    FCFS, and with ``backfill=True`` a later job may start while the head is
    blocked provided it completes before the head's reservation — EASY
    backfill, so the head is never delayed by a backfilled job.  The event
    loop itself lives in :class:`repro.network.scheduler.SchedulerService`
    — this function is a thin batch driver over the service (submit the
    sorted stream, run to quiescence, return the result), so simultaneity
    follows the service's deterministic ``(time, kind, seq)`` ordering
    with a scale-aware tolerance rather than the historical fixed
    ``1e-12``.

    A request is rejected only if it cannot be placed even on an empty
    machine (impossible geometry/size for this torus).

    ``unit_node_dims``: node dims per allocation unit (e.g. (4,4,4,4,2) for a
    BG/Q midplane); the contention proxy is evaluated at node level.

    ``measure_contention=True`` additionally routes every placed job's
    intra-job all-to-all traffic and records its volume on links shared
    with the other placements live at start time
    (``placement.predicted_contention``), so first-fit and scored policies
    report a comparable interference number.

    ``contention`` names the contention model explicitly: ``None`` (no
    measurement), ``"static"`` (identical to ``measure_contention=True``
    — the max-load proxy), or ``"simulated"`` — everything static does,
    plus a flow-level simulation (:mod:`repro.network.netsim`) of the
    job's traffic against the placements live at its start: the job's
    messages and every live job's messages drain together under max-min
    fair link sharing, and the job records its simulated completion
    (``ScheduledJob.simulated_comm_time``, seconds at ``link_bw``) next
    to the static lower bound ``ScheduledJob.comm_lower_bound`` (its own
    max link load alone — by conservation the simulation can never beat
    it, so ``simulated_slowdown >= 1`` on every job; the contention the
    static proxy only scores is here *derived* as extra completion time).

    Every scheduled job additionally records its
    ``ScheduledJob.bisection_efficiency`` — the granted geometry's internal
    bisection over the best achievable for that size (isoperimetry-engine
    optimum) — next to the simulated slowdown, so replays can report how
    much bisection a policy trades away
    (``SimulationResult.mean_bisection_efficiency``).

    ``mapping_pattern`` (requires ``measure_contention=True``) applies a
    per-job rank mapping when computing that measured number: each placed
    job's traffic is the named pattern (:data:`repro.network.mapping.
    MAPPING_PATTERNS`) on its logical grid, embedded by
    :func:`repro.network.map_ranks` (congestion-minimising), and the
    shared-link volume is measured against the *mapped* loads of the jobs
    live at start time — all-to-all is mapping-invariant, so this is how
    mapping-sensitive workloads (halo, ring, pairing) are replayed.  The
    chosen mapping is recorded on ``ScheduledJob.mapping``.
    ``double_link_on_2`` is the machine's link convention for the mapping
    engine's congestion metric: True (default) models BG/Q's two parallel
    links on length-2 dimensions; TPU-style single-link fabrics pass
    False.  ``backend`` selects the compiled backend for the
    ``"simulated"`` contention drains (identical schedules either way;
    see :mod:`repro.network.backend`).

    ``machine_dims`` may also be a :class:`~repro.network.fabric.
    TorusFabric` or :class:`~repro.network.fabric.HyperXFabric`; placements
    and bisection accounting then follow that fabric's convention.  The
    contention models are torus replays (on HyperX, disjoint aligned
    boxes structurally share no links — see
    :meth:`MachineState.allocate_scored`), so ``contention``/
    ``measure_contention``/``mapping_pattern`` raise ``ValueError`` on a
    HyperX machine instead of measuring a structural zero with torus
    routing.

    Example (two 4-midplane jobs on a tiny torus, FCFS, no backfill):

    >>> jobs = [JobRequest(0, 4, duration=1.0), JobRequest(1, 4, duration=1.0)]
    >>> res = simulate_queue((2, 2, 2), jobs, IsoperimetricPolicy())
    >>> [(j.placement.geometry, j.start) for j in res.jobs]
    [((2, 2, 1), 0.0), ((2, 2, 1), 0.0)]
    """
    if contention is None:
        contention = "static" if measure_contention else None
    elif contention not in ("static", "simulated"):
        raise ValueError(
            f"contention must be None, 'static' or 'simulated'; got {contention!r}"
        )
    measure = contention is not None
    if mapping_pattern is not None and not measure:
        raise ValueError(
            "mapping_pattern requires measure_contention=True (or contention=)"
        )
    # One event loop, not two: the batch simulation is a thin driver over
    # the event-sourced service (repro.network.scheduler) — jobs are
    # submitted in (arrival, submission-index) order and the contention
    # measurements ride on the service's start/release hooks.
    from .scheduler import SchedulerService

    fabric = (
        machine_dims
        if isinstance(machine_dims, (TorusFabric, HyperXFabric))
        else None
    )
    dims = fabric.dims if fabric is not None else tuple(int(d) for d in machine_dims)
    if isinstance(fabric, HyperXFabric) and (measure or mapping_pattern is not None):
        raise ValueError(
            "contention measurement replays torus routing; on a HyperX "
            "machine disjoint boxes share no links, so there is nothing to "
            "measure — run without contention=/measure_contention/"
            "mapping_pattern"
        )

    # Live per-job *mapped* loads (mapping_pattern only): the measured
    # shared-link background under a mapping is the running sum of these,
    # not the all-to-all tensor MachineState maintains for placement
    # scoring.  The total is maintained incrementally (add on start,
    # subtract on release); cancellation residue is ~1e-13 at replay
    # magnitudes, well under the _EPS=1e-12 sharing threshold.
    live_mapped: Dict[int, np.ndarray] = {}
    mapped_total = (
        np.zeros((len(dims), 2) + dims) if mapping_pattern is not None else None
    )
    # Live jobs' message-level traffic (contention="simulated" only): the
    # flow simulation at a job's start drains its messages together with
    # every live job's.
    live_traffic: Dict[int, Tuple[np.ndarray, np.ndarray, np.ndarray]] = {}

    def on_start(service, job: ScheduledJob) -> None:
        nonlocal mapped_total
        if not measure:
            return
        machine = service.machine
        placed = job.placement
        mapping: Optional[RankMapping] = None
        if mapping_pattern is not None:
            mapping = map_ranks(
                machine.dims, placed.oriented, placed.offset,
                pattern=mapping_pattern, double_link_on_2=double_link_on_2,
            )
            job_loads = mapping.loads
            background = np.maximum(mapped_total, 0.0)
            live_mapped[placed.job_id] = job_loads
            mapped_total += job_loads
        else:
            job_loads = placement_loads(machine.dims, placed.oriented, placed.offset)
            # The job's own field is excluded in the exact integer domain —
            # the historical float subtraction left a ~1e-16 residue that
            # only the _EPS threshold kept invisible.
            background = machine.traffic_loads(exclude=placed.job_id)
        job.mapping = mapping
        job.placement = dataclasses.replace(
            placed,
            predicted_contention=float(job_loads[background > _EPS].sum()),
        )
        if contention == "simulated":
            if mapping is not None:
                job_traffic = mapping.machine_traffic()
            else:
                job_traffic = placement_all_to_all_traffic(
                    machine.dims, placed.oriented, placed.offset
                )
            job.comm_lower_bound = (
                max_link_load(machine.dims, job_loads, double_link_on_2) / link_bw
            )
            background_traffic = list(live_traffic.values())
            n_bg = sum(t[2].shape[0] for t in background_traffic)
            if job_traffic[2].shape[0]:
                triples = background_traffic + [job_traffic]
                paths = dor_paths(
                    machine.dims,
                    np.concatenate([t[0] for t in triples]),
                    np.concatenate([t[1] for t in triples]),
                    np.concatenate([t[2] for t in triples]),
                )
                sim = simulate_flows(
                    paths,
                    link_bw=link_bw,
                    double_link_on_2=double_link_on_2,
                    backend=backend,
                )
                job.simulated_comm_time = float(sim.completion[n_bg:].max())
            else:
                job.simulated_comm_time = 0.0
            live_traffic[placed.job_id] = job_traffic

    def on_release(service, job_id: int) -> None:
        nonlocal mapped_total
        released = live_mapped.pop(job_id, None)
        if released is not None:
            mapped_total -= released
        live_traffic.pop(job_id, None)

    service = SchedulerService(
        fabric if fabric is not None else dims,
        policy,
        unit_node_dims=unit_node_dims,
        link_bw=link_bw,
        backfill=backfill,
        on_start=on_start,
        on_release=on_release,
    )
    for _, req in sorted(enumerate(jobs), key=lambda t: (t[1].arrival, t[0])):
        service.submit(req)
    return service.run().result()


def _node_dims(geometry: Geometry, unit_node_dims: Optional[Sequence[int]]) -> Geometry:
    # Each allocation-unit dim scales the node torus; extra unit dims (the
    # BG/Q internal 5th dimension) are appended — one implementation, shared
    # with the isoperimetry engine's node-level bisection tables.
    return scaled_node_dims(geometry, unit_node_dims)


def avoidable_contention_ratio(
    machine_dims: Sequence[int],
    units: int,
    unit_node_dims: Optional[Sequence[int]] = None,
) -> float:
    """Worst/best predicted pairing time over geometries of a given size —
    the paper's 'avoidable contention' factor (×2 for many BG/Q sizes)."""
    times = []
    for g in sub_cuboids(machine_dims, units):
        node_dims = _node_dims(g, unit_node_dims)
        times.append(predict_pairing_time(node_dims, 1.0, 1.0).time_per_volume)
    if not times:
        raise ValueError(f"no cuboid of {units} units fits in {machine_dims}")
    return max(times) / min(times)
