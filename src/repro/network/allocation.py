"""Processor-allocation policies with isoperimetric partition selection.

This is the paper's contribution turned into a deployable scheduler
component: given a machine fabric (a torus of allocation units — midplanes
on Blue Gene/Q, chips on a TPU pod) and a stream of jobs, allocate cuboid
partitions.  Policies differ in which geometry they pick for a given size:

* ``ElongatedPolicy``     — worst-case baseline: most elongated cuboid that
  fits (models "fill dimension-by-dimension" schedulers; JUQUEEN worst case).
* ``ListPolicy``          — a fixed geometry per size (models Mira's
  predefined partition list).
* ``IsoperimetricPolicy`` — the paper's policy: the geometry of maximal
  internal bisection bandwidth that fits the current free space, preferring
  better-bisection geometries even when fragmentation makes them harder to
  place (falls back in bisection order).
* ``HintedPolicy``        — isoperimetric for jobs flagged contention-bound,
  first-fit otherwise (Section 5's scheduler-hint proposal).

Placement is exact: an occupancy grid over the machine torus is scanned for a
translate of the (rotated) cuboid.  Wrap-around placement is allowed, since
torus partitions remain tori (BG/Q) — for TPU-style fabrics the resulting
slice's wrap flags are recomputed by :func:`repro.network.fabric.slice_fabric`.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .geometry import Geometry, bisection_links, canonical, sub_cuboids
from .routing import predict_pairing_time

Coord = Tuple[int, ...]


@dataclass(frozen=True)
class JobRequest:
    job_id: int
    units: int  # allocation units (midplanes / chips)
    contention_bound: bool = True
    duration: float = 1.0  # abstract time units, for the queue simulator


@dataclass(frozen=True)
class Placement:
    job_id: int
    geometry: Geometry  # canonical (sorted desc)
    oriented: Tuple[int, ...]  # per-machine-dimension extent actually placed
    offset: Coord
    bisection_links: int


class MachineState:
    """Occupancy grid over the machine's allocation-unit torus."""

    def __init__(self, dims: Sequence[int]):
        self.dims = tuple(int(d) for d in dims)
        self.grid = np.zeros(self.dims, dtype=bool)
        self.placements: Dict[int, Placement] = {}

    @property
    def free_units(self) -> int:
        return int((~self.grid).sum())

    def _cells(self, oriented: Sequence[int], offset: Coord) -> Tuple[np.ndarray, ...]:
        slices = [
            np.array([(offset[k] + i) % self.dims[k] for i in range(oriented[k])])
            for k in range(len(self.dims))
        ]
        mesh = np.meshgrid(*slices, indexing="ij")
        return tuple(m.ravel() for m in mesh)

    def find_placement(self, geometry: Sequence[int]) -> Optional[Tuple[Tuple[int, ...], Coord]]:
        """First free translate of any orientation of the cuboid; None if full."""
        g = canonical(geometry)
        g = g + (1,) * (len(self.dims) - len(g))
        for perm in sorted(set(itertools.permutations(g))):
            if any(s > a for s, a in zip(perm, self.dims)):
                continue
            for offset in itertools.product(*(range(a) for a in self.dims)):
                cells = self._cells(perm, offset)
                if not self.grid[cells].any():
                    return perm, offset
        return None

    def allocate(self, job_id: int, geometry: Sequence[int]) -> Optional[Placement]:
        spot = self.find_placement(geometry)
        if spot is None:
            return None
        oriented, offset = spot
        cells = self._cells(oriented, offset)
        self.grid[cells] = True
        p = Placement(
            job_id=job_id,
            geometry=canonical(geometry),
            oriented=oriented,
            offset=offset,
            bisection_links=bisection_links(canonical(geometry)),
        )
        self.placements[job_id] = p
        return p

    def release(self, job_id: int) -> None:
        p = self.placements.pop(job_id)
        cells = self._cells(p.oriented, p.offset)
        self.grid[cells] = False


# ---------------------------------------------------------------------------
# Policies.
# ---------------------------------------------------------------------------
class AllocationPolicy:
    name = "base"

    def geometry_preferences(self, machine: MachineState, units: int) -> List[Geometry]:
        """Geometries to try, in preference order."""
        raise NotImplementedError


class ElongatedPolicy(AllocationPolicy):
    """Most elongated geometry first (adversarial / naive filler)."""

    name = "elongated"

    def geometry_preferences(self, machine: MachineState, units: int) -> List[Geometry]:
        geoms = list(sub_cuboids(machine.dims, units))
        return sorted(geoms, key=lambda g: (-g[0], g))


class IsoperimetricPolicy(AllocationPolicy):
    """The paper's policy: maximal internal bisection bandwidth first."""

    name = "isoperimetric"

    def geometry_preferences(self, machine: MachineState, units: int) -> List[Geometry]:
        geoms = list(sub_cuboids(machine.dims, units))
        return sorted(geoms, key=lambda g: (-bisection_links(g), g))


class ListPolicy(AllocationPolicy):
    """A fixed geometry per size (Mira's predefined scheduler list)."""

    name = "list"

    def __init__(self, table: Dict[int, Geometry]):
        self.table = dict(table)

    def geometry_preferences(self, machine: MachineState, units: int) -> List[Geometry]:
        if units not in self.table:
            return []
        return [canonical(self.table[units])]


class HintedPolicy(AllocationPolicy):
    """Contention-bound jobs get isoperimetric geometries; others first-fit."""

    name = "hinted"

    def __init__(self):
        self.iso = IsoperimetricPolicy()
        self.any = ElongatedPolicy()

    def geometry_preferences(
        self, machine: MachineState, units: int, contention_bound: bool = True
    ) -> List[Geometry]:
        pol = self.iso if contention_bound else self.any
        return pol.geometry_preferences(machine, units)


# ---------------------------------------------------------------------------
# Queue simulator.
# ---------------------------------------------------------------------------
@dataclass
class ScheduledJob:
    request: JobRequest
    placement: Placement
    start: float
    end: float
    predicted_comm_time: float  # pairing-benchmark proxy, seconds/byte


@dataclass
class SimulationResult:
    policy: str
    jobs: List[ScheduledJob] = field(default_factory=list)
    rejected: List[int] = field(default_factory=list)

    @property
    def mean_comm_time(self) -> float:
        if not self.jobs:
            return 0.0
        return float(np.mean([j.predicted_comm_time for j in self.jobs]))

    @property
    def makespan(self) -> float:
        return max((j.end for j in self.jobs), default=0.0)


def simulate_queue(
    machine_dims: Sequence[int],
    jobs: Iterable[JobRequest],
    policy: AllocationPolicy,
    unit_node_dims: Optional[Sequence[int]] = None,
    link_bw: float = 1.0,
) -> SimulationResult:
    """FCFS queue simulation with exact cuboid placement.

    ``unit_node_dims``: node dims per allocation unit (e.g. (4,4,4,4,2) for a
    BG/Q midplane); the contention proxy is evaluated at node level.
    """
    machine = MachineState(machine_dims)
    result = SimulationResult(policy=policy.name)
    now = 0.0
    running: List[ScheduledJob] = []
    for req in jobs:
        placed: Optional[Placement] = None
        while placed is None:
            if isinstance(policy, HintedPolicy):
                prefs = policy.geometry_preferences(
                    machine, req.units, req.contention_bound
                )
            else:
                prefs = policy.geometry_preferences(machine, req.units)
            for g in prefs:
                placed = machine.allocate(req.job_id, g)
                if placed is not None:
                    break
            if placed is None:
                # advance time to the next completion and retry
                running.sort(key=lambda j: j.end)
                if not running:
                    result.rejected.append(req.job_id)
                    break
                done = running.pop(0)
                now = done.end
                machine.release(done.request.job_id)
        if placed is None:
            continue
        node_dims = _node_dims(placed.geometry, unit_node_dims)
        pred = predict_pairing_time(node_dims, 1.0, link_bw)
        job = ScheduledJob(
            request=req,
            placement=placed,
            start=now,
            end=now + req.duration,
            predicted_comm_time=pred.time_per_volume,
        )
        result.jobs.append(job)
        running.append(job)
    return result


def _node_dims(geometry: Geometry, unit_node_dims: Optional[Sequence[int]]) -> Geometry:
    if unit_node_dims is None:
        return geometry
    # Each allocation-unit dim scales the node torus; extra unit dims (the
    # BG/Q internal 5th dimension) are appended.
    unit = tuple(unit_node_dims)
    scaled = tuple(g * u for g, u in zip(geometry, unit[: len(geometry)]))
    return canonical(scaled + unit[len(geometry):])


def avoidable_contention_ratio(
    machine_dims: Sequence[int],
    units: int,
    unit_node_dims: Optional[Sequence[int]] = None,
) -> float:
    """Worst/best predicted pairing time over geometries of a given size —
    the paper's 'avoidable contention' factor (×2 for many BG/Q sizes)."""
    times = []
    for g in sub_cuboids(machine_dims, units):
        node_dims = _node_dims(g, unit_node_dims)
        times.append(predict_pairing_time(node_dims, 1.0, 1.0).time_per_volume)
    if not times:
        raise ValueError(f"no cuboid of {units} units fits in {machine_dims}")
    return max(times) / min(times)
