"""Vectorized flow-level torus network simulator — the dynamic validation leg.

The routing engine (:mod:`repro.network.routing`) *predicts* contention
statically: a bulk-synchronous phase takes ``T = max_link_load / link_bw``.
The paper's claims rest on a second leg — benchmarking experiments that
*validate* those predictions (§7 of the paper) — and this module is that leg
in simulation form: a discrete-time **flow-level** simulator that turns any
traffic pattern into per-flow completion times under max-min fair link
sharing, so speedup claims are derived from dynamics instead of pinned as
constants.

Model
-----
* Every message becomes one **flow** along a concrete minimal path.
  Antipodal ties (ring distance exactly half the ring) split into two
  half-volume subflows per tied dimension, matching the engine's
  ``split_ties=True`` accounting; a message completes when its last
  subflow drains.
* Paths come from one of two routers:

  - ``mode="dor"`` — dimension-ordered routing.  The enumerated links are
    load-identical to :func:`repro.network.routing.route_dor`
    (property-pinned in ``tests/test_netsim.py``).
  - ``mode="adaptive"`` — minimal-adaptive: each flow routes one whole
    dimension per round and picks, among its unrouted dimensions, the one
    whose first-hop link currently carries the least committed volume
    (directions stay minimal, so paths never lengthen).  This quantifies
    how much avoidable contention routing alone can recover: for
    translation-invariant patterns the answer is *none* — link loads are
    already uniform — which is the paper's argument for fixing partition
    geometry rather than the router.

* Each simulation step shares every link's bandwidth **max-min fairly**
  among the flows crossing it: progressive filling over the link x flow
  incidence with ``np.bincount`` sweeps — no per-packet (or per-flow)
  Python loops.  Time then advances to the next flow completion, flows
  drain, and the loop repeats; the step count is bounded by the number of
  distinct completion times, not by a fixed tick width.
* Link capacities follow the fabric convention: a length-2 dimension has
  two parallel physical links under BG/Q (``double_link_on_2=True``,
  doubling its capacity) and a single link on TPU ICI.

Outputs are per-flow and per-message completion times, the makespan, a
per-step link-utilization timeline, and the measured **slowdown** versus
the zero-contention bound (the line-rate time of the largest single
message, ``max_m vol_m / link_bw`` — so for unit-volume patterns the
slowdown is exactly the paper's contention multiplier).

:func:`validate_prediction` packages the paper's validation experiment as
a property: for steady (translation-invariant) patterns the simulated
makespan equals ``max_link_load / link_bw`` exactly, and it can never beat
it (conservation through the most loaded link) — both are enforced by the
hypothesis suite in ``tests/test_netsim.py``.  :func:`simulate_phases`
runs phased collective schedules (e.g. ring all-reduce as ``2(n-1)``
dependent phases) so the closed forms in
:mod:`repro.network.collectives` can be cross-checked dynamically.

The per-flow pure-Python reference lives in ``tests/reference_netsim.py``;
``benchmarks/bench_netsim.py`` pins the vectorized speedup (>= 10x,
``BENCH_netsim.json``).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..obs.trace import TRACER as _TRACER
from .backend import resolve_backend
from .fabric import HyperXFabric, Torus, TorusFabric
from .geometry import volume
from .routing import _hyperx_blocks, _hyperx_flows, max_link_load

Coord = Tuple[int, ...]
Traffic = Tuple[np.ndarray, np.ndarray, np.ndarray]

_EPS = 1e-12


# ---------------------------------------------------------------------------
# Flow expansion: messages -> minimal-path subflows.
# ---------------------------------------------------------------------------
def _expand_tie_flows(
    dims: Tuple[int, ...],
    src: np.ndarray,
    dst: np.ndarray,
    vol: np.ndarray,
    split_ties: bool,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Expand messages into minimal-path subflows.

    Returns ``(src, dst, vol, msg, fwd)``: per-subflow endpoints and
    volumes, the originating message index, and the chosen ring direction
    per dimension (``fwd[f, k]`` — True routes +1).  With ``split_ties``
    a message is duplicated once per antipodal-tie dimension, each copy
    carrying half the volume and one of the two directions (volume is
    conserved exactly); without, ties route forward, matching
    ``route_dor(split_ties=False)``.
    """
    d_arr = np.asarray(dims, dtype=np.int64)
    src = np.array(np.atleast_2d(np.asarray(src, dtype=np.int64)))
    dst = np.array(np.atleast_2d(np.asarray(dst, dtype=np.int64)))
    M = src.shape[0]
    vol = np.array(np.broadcast_to(np.asarray(vol, dtype=np.float64), (M,)))
    msg = np.arange(M, dtype=np.int64)
    delta = (dst - src) % d_arr
    fwd = delta * 2 <= d_arr  # ties start forward; duplicates flip below
    if split_ties:
        for k, a in enumerate(dims):
            if a <= 1:
                continue
            tie = ((dst[:, k] - src[:, k]) % a) * 2 == a
            if not tie.any():
                continue
            vol[tie] *= 0.5
            idx = np.flatnonzero(tie)
            src = np.concatenate([src, src[idx]])
            dst = np.concatenate([dst, dst[idx]])
            vol = np.concatenate([vol, vol[idx]])
            msg = np.concatenate([msg, msg[idx]])
            fwd = np.concatenate([fwd, fwd[idx]])
            fwd[-idx.shape[0]:, k] = False
    return src, dst, vol, msg, fwd


def _strides(dims: Tuple[int, ...]) -> np.ndarray:
    """C-order ravel strides of the vertex grid."""
    s = np.ones(len(dims), dtype=np.int64)
    for k in range(len(dims) - 2, -1, -1):
        s[k] = s[k + 1] * dims[k + 1]
    return s


def _segment_links(
    a: int,
    stride: int,
    plane_base: np.ndarray,
    base_vflat: np.ndarray,
    start: np.ndarray,
    hops: np.ndarray,
    fwd: np.ndarray,
    flow_idx: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray]:
    """Enumerate the directed links of a batch of ring segments.

    A forward segment from ring position ``s`` of ``h`` hops uses the '+'
    links leaving ``s, s+1, .., s+h-1``; a backward one the '-' links
    leaving ``s, s-1, .., s-h+1`` — the same link sets ``route_dor``
    accumulates.  Returns flat link ids and the owning flow per link.
    """
    tot = int(hops.sum())
    if tot == 0:
        empty = np.zeros(0, dtype=np.int64)
        return empty, empty.copy()
    rep = np.repeat(np.arange(hops.shape[0]), hops)
    j = np.arange(tot) - np.repeat(np.cumsum(hops) - hops, hops)
    sgn = np.where(fwd, 1, -1)[rep]
    pos = (start[rep] + sgn * j) % a
    links = plane_base[rep] + base_vflat[rep] + pos * stride
    return links, flow_idx[rep]


@dataclass(frozen=True)
class FlowPaths:
    """The routed form of a traffic pattern: one entry per (flow, link).

    ``msg[f]`` maps subflow f back to its originating message, ``vol[f]``
    is the subflow volume (tie splits halve), and the parallel arrays
    ``link_ids`` / ``flow_ids`` are the link x flow incidence the
    simulator waterfills over.  Link ids index the flattened
    ``(D, 2, *dims)`` load tensor layout of ``route_dor``.
    """

    dims: Tuple[int, ...]
    n_messages: int
    msg: np.ndarray  # (F,) originating message per subflow
    vol: np.ndarray  # (F,) subflow volumes
    link_ids: np.ndarray  # (P,) flat directed-link ids
    flow_ids: np.ndarray  # (P,) owning subflow per entry
    mode: str = "dor"
    # Non-torus fabrics carry their own dense per-slot capacities (in units
    # of link_bw — parallel trunked links fold in); None keeps the historical
    # torus layout, whose capacities come from ``link_capacities`` instead.
    capacities: Optional[np.ndarray] = None

    @property
    def n_flows(self) -> int:
        """Number of subflows (>= number of messages when ties split)."""
        return int(self.vol.shape[0])

    def link_loads(self) -> np.ndarray:
        """Total routed volume per directed link — shaped ``(D, 2, *dims)``
        for torus paths (for ``mode="dor"`` this is exactly
        ``route_dor``'s tensor), or flat ``(L,)`` in the fabric's own link
        layout when the paths carry explicit ``capacities``."""
        if self.capacities is not None:
            return np.bincount(
                self.link_ids,
                weights=self.vol[self.flow_ids],
                minlength=self.capacities.shape[0],
            )
        n = volume(self.dims)
        flat = np.bincount(
            self.link_ids,
            weights=self.vol[self.flow_ids],
            minlength=2 * len(self.dims) * n,
        )
        return flat.reshape((len(self.dims), 2) + self.dims)

    def max_link_load(self, double_link_on_2: bool = True) -> float:
        """Max per-physical-link routed volume (double links halve; on
        explicit-capacity fabrics each slot's load is normalized by its
        relative capacity instead)."""
        if self.capacities is not None:
            loads = self.link_loads()
            pos = self.capacities > 0.0
            if not pos.any():
                return 0.0
            return float((loads[pos] / self.capacities[pos]).max())
        return max_link_load(self.dims, self.link_loads(), double_link_on_2)


def _dor_links(
    dims: Tuple[int, ...],
    src: np.ndarray,
    dst: np.ndarray,
    fwd: np.ndarray,
    hops: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray]:
    """Link incidence of already-expanded flows under dimension order."""
    strides = _strides(dims)
    n = volume(dims)
    cur = src.copy()
    all_links: List[np.ndarray] = []
    all_flows: List[np.ndarray] = []
    for k, a in enumerate(dims):
        if a <= 1:
            continue
        act = np.flatnonzero(hops[:, k] > 0)
        if act.shape[0]:
            s = cur[act, k]
            base_vflat = cur[act] @ strides - s * strides[k]
            plane = np.where(fwd[act, k], 2 * k, 2 * k + 1) * n
            links, flows = _segment_links(
                a, int(strides[k]), plane, base_vflat, s, hops[act, k], fwd[act, k], act
            )
            all_links.append(links)
            all_flows.append(flows)
        cur[:, k] = dst[:, k]
    empty = np.zeros(0, dtype=np.int64)
    return (
        np.concatenate(all_links) if all_links else empty,
        np.concatenate(all_flows) if all_flows else empty.copy(),
    )


def dor_paths(
    dims: Sequence[int],
    src: np.ndarray,
    dst: np.ndarray,
    vol,
    split_ties: bool = True,
) -> FlowPaths:
    """Dimension-ordered paths for a batch of messages.

    Link-for-link identical to what :func:`repro.network.routing.route_dor`
    accumulates (property-pinned): dimension k routes at coordinate
    ``(dst[:k], src[k:])``, ties split into half-volume subflows.
    """
    dims = tuple(int(a) for a in dims)
    src, dst, vol, msg, fwd = _expand_tie_flows(dims, src, dst, np.asarray(vol), split_ties)
    n_messages = int(msg.max()) + 1 if msg.shape[0] else 0
    d_arr = np.asarray(dims, dtype=np.int64)
    hops = np.minimum((dst - src) % d_arr, (src - dst) % d_arr)
    link_ids, flow_ids = _dor_links(dims, src, dst, fwd, hops)
    return FlowPaths(
        dims=dims,
        n_messages=n_messages,
        msg=msg,
        vol=vol,
        link_ids=link_ids,
        flow_ids=flow_ids,
        mode="dor",
    )


def _cyclic_prefixes(dims: Tuple[int, ...], loads: np.ndarray) -> List[List[np.ndarray]]:
    """Per-(dimension, direction) cumulative sums of a load tensor along
    its own axis (flattened C-order), so any cyclic segment sum reduces
    to two gathers plus an optional full-ring term."""
    out: List[List[np.ndarray]] = []
    for k in range(len(dims)):
        out.append(
            [np.ascontiguousarray(np.cumsum(loads[k, d], axis=k)).ravel() for d in (0, 1)]
        )
    return out


def _cyclic_segment_sums(
    prefix: List[List[np.ndarray]],
    k: int,
    a: int,
    stride: int,
    base_vflat: np.ndarray,
    start: np.ndarray,
    hops: np.ndarray,
    fwd: np.ndarray,
) -> np.ndarray:
    """Load-field sum over the cyclic segment ``[start, start + hops)`` of
    each flow's candidate ring, per the flow's direction plane."""
    end = start + hops - 1
    out = np.empty(start.shape[0])
    for d in (0, 1):
        m = fwd if d == 0 else ~fwd
        if not m.any():
            continue
        cs = prefix[k][d]
        b = base_vflat[m]
        s = start[m]
        e = end[m]
        t_end = cs[b + (e % a) * stride]
        t_sm1 = np.where(s > 0, cs[b + np.maximum(s - 1, 0) * stride], 0.0)
        ring = cs[b + (a - 1) * stride]
        out[m] = t_end - t_sm1 + np.where(e >= a, ring, 0.0)
    return out


def adaptive_paths(
    dims: Sequence[int],
    src: np.ndarray,
    dst: np.ndarray,
    vol,
    split_ties: bool = True,
    divert_margin: float = 0.75,
) -> FlowPaths:
    """Minimal-adaptive paths: per-flow least-loaded dimension order.

    Two passes.  Pass 1 routes everything with DOR and accumulates the
    steady link-load field the pattern would produce.  Pass 2 re-routes
    every flow against that frozen field: at each step the flow compares
    the *mean load along the whole candidate segment* of each unrouted
    dimension (cyclic prefix sums — no per-hop loops) and leaves DOR's
    lowest-dimension-first order only when some dimension is cheaper than
    the default by more than the ``divert_margin`` factor.  All decisions
    are simultaneous, so a translation-invariant pattern — whose load
    field, hence whose decisions, are translation-invariant — keeps
    exactly DOR's uniform loads and makespan: minimal-adaptive routing
    recovers *nothing* of the paper's geometry-induced contention, while
    genuinely skewed patterns (hotspot rows, bad permutations) do
    rebalance.  Directions stay minimal and ties still split, so the
    total hop volume always equals DOR's.
    """
    dims = tuple(int(a) for a in dims)
    src, dst, vol, msg, fwd = _expand_tie_flows(dims, src, dst, np.asarray(vol), split_ties)
    n_messages = int(msg.max()) + 1 if msg.shape[0] else 0
    d_arr = np.asarray(dims, dtype=np.int64)
    strides = _strides(dims)
    n = volume(dims)
    D = len(dims)
    hops = np.minimum((dst - src) % d_arr, (src - dst) % d_arr)

    # Pass 1: the steady DOR field of the expanded flows, held as
    # per-(dim, direction) cyclic prefix sums so pass 2 prices any
    # candidate segment with two gathers.
    links0, flows0 = _dor_links(dims, src, dst, fwd, hops)
    field = np.bincount(
        links0, weights=vol[flows0], minlength=2 * D * n
    ).reshape((D, 2) + dims)
    prefix = _cyclic_prefixes(dims, field)

    cur = src.copy()
    remaining = hops > 0
    all_links: List[np.ndarray] = []
    all_flows: List[np.ndarray] = []
    for _ in range(D):
        act = np.flatnonzero(remaining.any(axis=1))
        if not act.shape[0]:
            break
        cost = np.full((src.shape[0], D), np.inf)
        for k, a in enumerate(dims):
            rows = np.flatnonzero(remaining[:, k])
            if not rows.shape[0]:
                continue
            h = hops[rows, k]
            s = cur[rows, k]
            fw = fwd[rows, k]
            start = np.where(fw, s, (s - h + 1) % a)
            base_vflat = cur[rows] @ strides - s * strides[k]
            seg = _cyclic_segment_sums(
                prefix, k, a, int(strides[k]), base_vflat, start, h, fw
            )
            cost[rows, k] = seg / h
        best = np.argmin(cost, axis=1)
        default = np.argmax(remaining, axis=1)  # lowest remaining dim index
        rowsel = np.arange(src.shape[0])
        divert = cost[rowsel, best] < divert_margin * cost[rowsel, default]
        choice = np.where(divert, best, default)
        for k, a in enumerate(dims):
            g = act[np.flatnonzero((choice[act] == k) & remaining[act, k])]
            if not g.shape[0]:
                continue
            s = cur[g, k]
            base_vflat = cur[g] @ strides - s * strides[k]
            plane = np.where(fwd[g, k], 2 * k, 2 * k + 1) * n
            links, flows = _segment_links(
                a, int(strides[k]), plane, base_vflat, s, hops[g, k], fwd[g, k], g
            )
            all_links.append(links)
            all_flows.append(flows)
            cur[g, k] = dst[g, k]
            remaining[g, k] = False
    empty = np.zeros(0, dtype=np.int64)
    return FlowPaths(
        dims=dims,
        n_messages=n_messages,
        msg=msg,
        vol=vol,
        link_ids=np.concatenate(all_links) if all_links else empty,
        flow_ids=np.concatenate(all_flows) if all_flows else empty.copy(),
        mode="adaptive",
    )


def build_paths(
    dims: Sequence[int],
    traffic: Traffic,
    mode: str = "dor",
    split_ties: bool = True,
) -> FlowPaths:
    """Route a ``(src, dst, vol)`` pattern with the named router
    (``"dor"`` or ``"adaptive"``)."""
    src, dst, vol = traffic
    if mode == "dor":
        return dor_paths(dims, src, dst, vol, split_ties=split_ties)
    if mode == "adaptive":
        return adaptive_paths(dims, src, dst, vol, split_ties=split_ties)
    raise ValueError(f"unknown routing mode {mode!r}; expected 'dor' or 'adaptive'")


# ---------------------------------------------------------------------------
# Link capacities and max-min fair sharing.
# ---------------------------------------------------------------------------
def link_capacities(
    dims: Sequence[int], link_bw: float = 1.0, double_link_on_2: bool = True
) -> np.ndarray:
    """Per-directed-link bandwidth, shaped ``(D, 2, *dims)``.

    A length-2 dimension has two parallel physical links per vertex pair
    under the BG/Q convention, doubling its capacity; TPU-style fabrics
    pass ``double_link_on_2=False``.
    """
    dims = tuple(int(a) for a in dims)
    cap = np.full((len(dims), 2) + dims, float(link_bw))
    if double_link_on_2:
        for k, a in enumerate(dims):
            if a == 2:
                cap[k] *= 2.0
    return cap


def _max_min_rates(
    flow_of_entry: np.ndarray,
    link_of_entry: np.ndarray,
    n_flows: int,
    n_links: int,
    cap: np.ndarray,
    active: np.ndarray,
) -> np.ndarray:
    """Max-min fair rates by progressive filling, fully vectorized.

    All unfrozen flows grow at a common rate; each iteration finds the
    bottleneck links (least remaining capacity per crossing flow),
    saturates them, and freezes their flows — at least one link saturates
    per iteration, so the loop runs at most ``n_links`` times with
    O(entries) array work each.
    """
    rate = np.zeros(n_flows)
    growing = active.copy()
    cap_rem = cap.astype(np.float64).copy()
    for _ in range(n_links + 1):
        e = growing[flow_of_entry]
        cnt = np.bincount(link_of_entry[e], minlength=n_links)
        open_links = cnt > 0
        if not open_links.any():
            break
        share = np.full(n_links, np.inf)
        share[open_links] = cap_rem[open_links] / cnt[open_links]
        inc = share.min()
        rate[growing] += inc
        cap_rem[open_links] -= inc * cnt[open_links]
        saturated = open_links & (share <= inc * (1.0 + 1e-9))
        hit = saturated[link_of_entry] & e
        growing[flow_of_entry[hit]] = False
        if not growing.any():
            break
    return rate


# ---------------------------------------------------------------------------
# The simulator.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class UtilizationSample:
    """One step of the link-utilization timeline: the interval
    ``[start, end)``, the max/mean utilization over links carrying any
    active flow, the active subflow count, and (when the simulator is
    asked to record it) the full per-link utilization tensor."""

    start: float
    end: float
    max_utilization: float
    mean_utilization: float
    active_flows: int
    utilization: Optional[np.ndarray] = None  # (D, 2, *dims) when recorded


@dataclass(frozen=True)
class FlowSimResult:
    """Outcome of one flow-level simulation.

    ``completion[m]`` is the finish time of message m (the last of its
    subflows), ``makespan`` the overall finish, ``ideal_time`` the
    zero-contention bound (largest message at line rate) and ``slowdown``
    their ratio — the measured contention multiplier the static engine
    predicts as ``max_link_load``.  ``timeline`` holds the per-step
    utilization samples when the simulation ran with
    ``record_utilization=True`` (empty otherwise).
    """

    dims: Tuple[int, ...]
    mode: str
    completion: np.ndarray  # (n_messages,) per-message finish times
    flow_completion: np.ndarray  # (F,) per-subflow finish times
    makespan: float
    steps: int
    ideal_time: float
    link_loads: np.ndarray  # (D, 2, *dims) total routed volume
    timeline: List[UtilizationSample] = field(default_factory=list)

    @property
    def slowdown(self) -> float:
        """Makespan over the zero-contention bound (>= 1 whenever any
        message moves; 1.0 for empty traffic)."""
        if self.ideal_time <= 0.0:
            return 1.0
        return self.makespan / self.ideal_time


def _package_result(
    paths: FlowPaths,
    flow_completion: np.ndarray,
    steps: int,
    timeline: List[UtilizationSample],
    link_bw: float,
) -> FlowSimResult:
    """Assemble a :class:`FlowSimResult` from per-subflow finish times —
    shared tail of the numpy and xla simulation paths."""
    F = paths.n_flows
    vol = paths.vol
    completion = np.zeros(paths.n_messages)
    if F:
        np.maximum.at(completion, paths.msg, flow_completion)
    msg_vol = (
        np.bincount(paths.msg, weights=vol, minlength=paths.n_messages)
        if F
        else np.zeros(paths.n_messages)
    )
    return FlowSimResult(
        dims=paths.dims,
        mode=paths.mode,
        completion=completion,
        flow_completion=flow_completion,
        makespan=float(flow_completion.max()) if F else 0.0,
        steps=steps,
        ideal_time=float(msg_vol.max()) / link_bw if msg_vol.shape[0] else 0.0,
        link_loads=paths.link_loads(),
        timeline=timeline,
    )


def simulate_flows(
    paths: FlowPaths,
    link_bw: float = 1.0,
    double_link_on_2: bool = True,
    record_utilization: bool = False,
    max_steps: int = 100_000,
    backend: Optional[str] = None,
) -> FlowSimResult:
    """Drain a routed pattern under max-min fair link sharing.

    With tracing enabled (:mod:`repro.obs`) the drain records a
    ``netsim.drain`` span annotated with the flow count, step count, and
    makespan; results are bit-identical either way (spans only measure).

    Each step computes fair rates over the link x flow incidence
    (:func:`_max_min_rates`), advances time to the next subflow
    completion, and removes drained subflows; the step count is therefore
    bounded by the number of distinct completion times.  Raises
    ``RuntimeError`` after ``max_steps`` steps (a guard, not a tick
    width).  ``record_utilization=True`` additionally keeps the per-step
    link-utilization timeline (stats plus the full per-link tensor) —
    off by default, since the extra per-step sweep is pure overhead for
    callers that only need completion times.

    ``backend="xla"`` drains through the compiled fixed-shape simulator
    (:mod:`repro.network.backend`): same completion order, makespans
    within 1e-9 relative of the numpy engine.  The timeline sweep is a
    host-side diagnostic, so ``record_utilization=True`` is numpy-only.
    """
    if not _TRACER.enabled:
        return _simulate_flows_impl(
            paths, link_bw, double_link_on_2, record_utilization, max_steps, backend
        )
    with _TRACER.span(
        "netsim.drain",
        flows=paths.n_flows,
        mode=paths.mode,
        backend=resolve_backend(backend),
    ) as span:
        res = _simulate_flows_impl(
            paths, link_bw, double_link_on_2, record_utilization, max_steps, backend
        )
        span.annotate(steps=res.steps, makespan=res.makespan)
        return res


def _simulate_flows_impl(
    paths: FlowPaths,
    link_bw: float,
    double_link_on_2: bool,
    record_utilization: bool,
    max_steps: int,
    backend: Optional[str],
) -> FlowSimResult:
    if link_bw <= 0.0:
        raise ValueError("link_bw must be positive")
    if resolve_backend(backend) == "xla":
        if record_utilization:
            raise ValueError(
                "record_utilization is a numpy-only diagnostic; "
                "use backend='numpy' to capture the timeline"
            )
        from .backend import drain, prepare_drain

        plan = prepare_drain(paths, link_bw, double_link_on_2)
        flow_completion, steps = drain(plan, max_steps=max_steps)
        return _package_result(paths, flow_completion, steps, [], link_bw)
    dims = paths.dims
    F = paths.n_flows
    vol = paths.vol
    if paths.capacities is not None:
        cap = paths.capacities * link_bw
    else:
        cap = link_capacities(dims, link_bw, double_link_on_2).ravel()
    n_links = cap.shape[0]  # flat ids are already compact: 2 * D * N
    link_of_entry = paths.link_ids
    flow_of_entry = paths.flow_ids

    has_links = np.zeros(F, dtype=bool)
    has_links[flow_of_entry] = True
    remaining = vol.astype(np.float64).copy()
    flow_completion = np.zeros(F)
    active = has_links & (remaining > _EPS)

    timeline: List[UtilizationSample] = []
    t = 0.0
    steps = 0
    while active.any():
        steps += 1
        if steps > max_steps:
            raise RuntimeError(f"flow simulation exceeded {max_steps} steps")
        rates = _max_min_rates(flow_of_entry, link_of_entry, F, n_links, cap, active)
        act_idx = np.flatnonzero(active)
        ratio = remaining[act_idx] / rates[act_idx]
        dt = float(ratio.min())
        t += dt
        remaining[act_idx] -= rates[act_idx] * dt
        remaining[act_idx[np.argmin(ratio)]] = 0.0
        finished = active & (remaining <= np.maximum(vol, 1.0) * _EPS)
        flow_completion[finished] = t
        active &= ~finished
        if record_utilization:
            used = np.bincount(
                link_of_entry, weights=rates[flow_of_entry], minlength=n_links
            )
            util = np.divide(used, cap, out=np.zeros_like(used), where=cap > 0.0)
            busy = util[used > 0.0]
            timeline.append(
                UtilizationSample(
                    start=t - dt,
                    end=t,
                    max_utilization=float(busy.max()) if busy.shape[0] else 0.0,
                    mean_utilization=float(busy.mean()) if busy.shape[0] else 0.0,
                    active_flows=int(act_idx.shape[0]),
                    utilization=(
                        util
                        if paths.capacities is not None
                        else util.reshape((len(dims), 2) + dims)
                    ),
                )
            )

    return _package_result(paths, flow_completion, steps, timeline, link_bw)


def simulate_traffic(
    dims: Sequence[int],
    traffic: Traffic,
    mode: str = "dor",
    split_ties: bool = True,
    link_bw: float = 1.0,
    double_link_on_2: bool = True,
    record_utilization: bool = False,
    backend: Optional[str] = None,
) -> FlowSimResult:
    """Route and drain a ``(src, dst, vol)`` pattern in one call."""
    paths = build_paths(dims, traffic, mode=mode, split_ties=split_ties)
    return simulate_flows(
        paths,
        link_bw=link_bw,
        double_link_on_2=double_link_on_2,
        record_utilization=record_utilization,
        backend=backend,
    )


# ---------------------------------------------------------------------------
# The paper's validation experiment as an API.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PredictionValidation:
    """Static prediction vs simulated makespan for one pattern.

    ``predicted_time`` is the engine's ``max_link_load / link_bw``;
    ``simulated_time`` the flow simulator's makespan.  For steady
    (translation-invariant) patterns the two coincide; no pattern can
    ever finish faster (conservation through the most loaded link).
    """

    dims: Tuple[int, ...]
    predicted_time: float
    simulated_time: float
    rtol: float

    @property
    def ratio(self) -> float:
        """Simulated over predicted (1.0 when both are zero)."""
        if self.predicted_time <= 0.0:
            return 1.0
        return self.simulated_time / self.predicted_time

    @property
    def matched(self) -> bool:
        """Whether simulation confirms the prediction within ``rtol``."""
        return abs(self.simulated_time - self.predicted_time) <= (
            self.rtol * max(self.predicted_time, _EPS)
        )

    @property
    def bounded(self) -> bool:
        """Whether the simulation respects the prediction as a lower
        bound (it always should; False flags a simulator bug)."""
        return self.simulated_time >= self.predicted_time * (1.0 - self.rtol) - _EPS


def validate_prediction(
    dims: Sequence[int],
    traffic: Traffic,
    link_bw: float = 1.0,
    split_ties: bool = True,
    double_link_on_2: bool = True,
    rtol: float = 1e-6,
    backend: Optional[str] = None,
) -> PredictionValidation:
    """Run the paper's §7 validation experiment for one pattern.

    Routes the traffic with DOR, simulates the drain, and packages the
    static prediction next to the measured makespan:

    >>> from repro.network.patterns import bisection_pairing
    >>> v = validate_prediction((4, 4), bisection_pairing((4, 4)))
    >>> v.predicted_time, v.simulated_time, v.matched
    (1.0, 1.0, True)
    """
    dims = tuple(int(a) for a in dims)
    paths = dor_paths(dims, traffic[0], traffic[1], traffic[2], split_ties=split_ties)
    predicted = paths.max_link_load(double_link_on_2) / link_bw
    res = simulate_flows(
        paths, link_bw=link_bw, double_link_on_2=double_link_on_2, backend=backend
    )
    return PredictionValidation(
        dims=dims,
        predicted_time=predicted,
        simulated_time=res.makespan,
        rtol=rtol,
    )


# ---------------------------------------------------------------------------
# Phased collective schedules.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PhasedSimResult:
    """Outcome of a dependent-phase schedule: per-phase results and the
    serial total (phase k+1 starts when phase k drains)."""

    phases: Tuple[FlowSimResult, ...]
    total_time: float


def simulate_phases(
    dims: Sequence[int],
    phases: Sequence[Traffic],
    mode: str = "dor",
    split_ties: bool = True,
    link_bw: float = 1.0,
    double_link_on_2: bool = True,
    backend: Optional[str] = None,
) -> PhasedSimResult:
    """Simulate a sequence of dependent communication phases.

    Each phase is a full ``(src, dst, vol)`` pattern that must drain
    before the next begins — the shape of a ring collective (ring
    all-reduce over an axis of size n is ``2(n-1)`` neighbour-shift
    phases; see :func:`repro.network.patterns.ring_all_reduce_phases`).
    The serial total cross-checks the closed forms in
    :mod:`repro.network.collectives` dynamically.  Repeated occurrences
    of the *same* traffic tuple (identity, the shape the phase builders
    emit) are simulated once and their result reused.
    """
    results = []
    total = 0.0
    memo: dict = {}
    for traffic in phases:
        key = id(traffic)
        res = memo.get(key)
        if res is None:
            res = simulate_traffic(
                dims,
                traffic,
                mode=mode,
                split_ties=split_ties,
                link_bw=link_bw,
                double_link_on_2=double_link_on_2,
                backend=backend,
            )
            memo[key] = res
        results.append(res)
        total += res.makespan
    return PhasedSimResult(phases=tuple(results), total_time=total)


# ---------------------------------------------------------------------------
# Routing-mode comparison (what routing alone can recover).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class RoutingComparison:
    """DOR vs minimal-adaptive makespans for one pattern on one fabric."""

    dims: Tuple[int, ...]
    dor_makespan: float
    adaptive_makespan: float

    @property
    def recovered_fraction(self) -> float:
        """Fraction of the DOR makespan the adaptive router removed
        (0.0 when routing cannot help — e.g. any translation-invariant
        pattern, whose load field is already uniform)."""
        if self.dor_makespan <= 0.0:
            return 0.0
        return (self.dor_makespan - self.adaptive_makespan) / self.dor_makespan


def compare_routing(
    dims: Sequence[int],
    traffic: Traffic,
    split_ties: bool = True,
    link_bw: float = 1.0,
    double_link_on_2: bool = True,
    backend: Optional[str] = None,
) -> RoutingComparison:
    """Quantify how much of a pattern's contention routing alone recovers.

    Runs the same traffic under DOR and under the minimal-adaptive router
    and reports both makespans.  The paper's argument is geometric: for
    the contention its partition geometries avoid, the recovered fraction
    here is ~0 — no minimal router can spread a uniform load field any
    flatter — whereas geometry changes the field itself.
    """
    dims = tuple(int(a) for a in dims)
    t_dor = simulate_traffic(
        dims, traffic, mode="dor", split_ties=split_ties,
        link_bw=link_bw, double_link_on_2=double_link_on_2, backend=backend,
    ).makespan
    t_adp = simulate_traffic(
        dims, traffic, mode="adaptive", split_ties=split_ties,
        link_bw=link_bw, double_link_on_2=double_link_on_2, backend=backend,
    ).makespan
    return RoutingComparison(dims=dims, dor_makespan=t_dor, adaptive_makespan=t_adp)


# ---------------------------------------------------------------------------
# Fabric-dispatching entry points (torus or HyperX through one API).
# ---------------------------------------------------------------------------
def _fabric_dims(fabric) -> Tuple[int, ...]:
    if isinstance(fabric, (TorusFabric, Torus, HyperXFabric)):
        return fabric.dims
    return tuple(int(a) for a in fabric)


def fabric_paths(
    fabric,
    traffic: Traffic,
    mode: Optional[str] = None,
    split_ties: bool = True,
) -> FlowPaths:
    """Route a ``(src, dst, vol)`` pattern on any fabric.

    Torus fabrics (or plain dims) dispatch to :func:`build_paths` with the
    torus routers (``mode`` ``"dor"``/``"adaptive"``, default ``"dor"``) —
    the returned paths are identical to the historical API.  HyperX
    fabrics route with :func:`repro.network.routing.route_hyperx`'s flow
    expansion (``mode`` ``"minimal"``/``"dal"``, default ``"minimal"``)
    and carry the fabric's dense per-slot capacities so the same
    max-min-fair drain prices trunked clique links correctly.
    """
    if isinstance(fabric, HyperXFabric):
        src, dst, vol = traffic
        M = np.atleast_2d(np.asarray(src)).shape[0]
        volb = np.broadcast_to(np.asarray(vol, dtype=np.float64), (M,))
        msg, fvol, link_ids, flow_ids = _hyperx_flows(
            fabric, src, dst, volb, mode or "minimal"
        )
        _, n_slots = _hyperx_blocks(fabric.dims)
        return FlowPaths(
            dims=fabric.dims,
            n_messages=M,
            msg=msg,
            vol=fvol,
            link_ids=link_ids,
            flow_ids=flow_ids,
            mode=mode or "minimal",
            capacities=fabric.links().dense_capacities() / fabric.link_bw,
        )
    return build_paths(_fabric_dims(fabric), traffic, mode=mode or "dor", split_ties=split_ties)


def simulate_fabric_traffic(
    fabric,
    traffic: Traffic,
    mode: Optional[str] = None,
    split_ties: bool = True,
    link_bw: float = 1.0,
    double_link_on_2: bool = True,
    record_utilization: bool = False,
    backend: Optional[str] = None,
) -> FlowSimResult:
    """Route and drain a pattern on any fabric in one call — the
    fabric-generic form of :func:`simulate_traffic` (to which it is
    bit-identical on a torus)."""
    paths = fabric_paths(fabric, traffic, mode=mode, split_ties=split_ties)
    return simulate_flows(
        paths,
        link_bw=link_bw,
        double_link_on_2=double_link_on_2,
        record_utilization=record_utilization,
        backend=backend,
    )


def compare_fabric_routing(
    fabric,
    traffic: Traffic,
    split_ties: bool = True,
    link_bw: float = 1.0,
    double_link_on_2: bool = True,
    backend: Optional[str] = None,
) -> RoutingComparison:
    """Baseline vs adaptive routing on any fabric.

    Torus: DOR vs minimal-adaptive (== :func:`compare_routing`).  HyperX:
    minimal dimension-ordered vs DAL.  Either way ``recovered_fraction``
    answers the paper's question — how much of the pattern's contention
    can routing alone remove?  ~0 for steady translation-invariant
    patterns on both topologies; positive only for skewed fields.
    """
    base_mode, adp_mode = (
        ("minimal", "dal") if isinstance(fabric, HyperXFabric) else ("dor", "adaptive")
    )
    t_base = simulate_fabric_traffic(
        fabric, traffic, mode=base_mode, split_ties=split_ties,
        link_bw=link_bw, double_link_on_2=double_link_on_2, backend=backend,
    ).makespan
    t_adp = simulate_fabric_traffic(
        fabric, traffic, mode=adp_mode, split_ties=split_ties,
        link_bw=link_bw, double_link_on_2=double_link_on_2, backend=backend,
    ).makespan
    return RoutingComparison(
        dims=_fabric_dims(fabric), dor_makespan=t_base, adaptive_makespan=t_adp
    )


__all__ = [
    "FlowPaths",
    "FlowSimResult",
    "PhasedSimResult",
    "PredictionValidation",
    "RoutingComparison",
    "UtilizationSample",
    "adaptive_paths",
    "build_paths",
    "compare_fabric_routing",
    "compare_routing",
    "dor_paths",
    "fabric_paths",
    "link_capacities",
    "simulate_fabric_traffic",
    "simulate_flows",
    "simulate_phases",
    "simulate_traffic",
    "validate_prediction",
]
