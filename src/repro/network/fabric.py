"""Unified torus fabric model: one class for BG/Q- and TPU-style tori.

This merges what used to be two separate models:

* ``repro.core.torus.Torus`` — the paper's fully-wrapped Blue Gene/Q torus
  with double links on length-2 dimensions, used by the isoperimetric
  analysis; and
* ``repro.core.collectives.TorusFabric`` — the TPU-adapted fabric with
  per-dimension wrap flags and single links on length-2 dimensions.

Both are now parameterisations of :class:`TorusFabric`; the thin
:class:`Torus` wrapper keeps the historical geometry-only API and delegates
every computation to :mod:`repro.network.geometry`.

Hardware conventions (see DESIGN.md):

* Blue Gene/Q: a partition *always* retains wrap-around links (a partition of
  midplane geometry g is itself a torus), and a dimension of length 2 has two
  parallel physical links — ``TorusFabric.bgq(dims)``.
* TPU ICI: a slice gets wrap-around links in a dimension only when it spans
  that full pod dimension, and a length-2 dimension has a single link —
  ``TorusFabric.tpu(dims, wrap)`` / :func:`slice_fabric`.
"""

from __future__ import annotations

import abc
import math
from dataclasses import dataclass
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from . import geometry, hamming
from .geometry import Geometry, canonical, volume

# TPU v5e-class constants (per chip / per link, bytes per second).
DEFAULT_LINK_BW = 50e9  # ~50 GB/s per ICI link per direction (prompt spec)
POD_DCI_BW = 12.5e9  # inter-pod (data-center network) per-chip share, est.


# ---------------------------------------------------------------------------
# The fabric interface: explicit link incidence + per-dimension structure.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class LinkTable:
    """Explicit directed-link incidence of a fabric.

    Parallel arrays: ``link[i]`` is the flat link id (an index into the
    fabric's dense id space of ``n_slots`` slots — some slots may be
    unused, e.g. length-1 torus dimensions), ``src[i]``/``dst[i]`` the
    endpoint cells as flat C-order indices into the cell grid, and
    ``capacity[i]`` the link bandwidth in bytes/s (parallel physical
    links — BG/Q double links, HyperX trunking — fold into capacity).
    """

    link: np.ndarray  # (L,) int64 flat link ids, unique
    src: np.ndarray  # (L,) int64 source cell (flat C-order)
    dst: np.ndarray  # (L,) int64 destination cell (flat C-order)
    capacity: np.ndarray  # (L,) float bytes/s
    n_slots: int  # size of the dense link-id space

    def __len__(self) -> int:
        return int(self.link.shape[0])

    def dense_capacities(self) -> np.ndarray:
        """Per-slot capacities (bytes/s), zero on unused slots — the
        vector :func:`repro.network.netsim.fabric_paths` waterfills over."""
        cap = np.zeros(self.n_slots, dtype=np.float64)
        cap[self.link] = self.capacity
        return cap

    def neighbors_of(self, cell: int) -> np.ndarray:
        """Sorted unique flat cell indices one link away from ``cell``."""
        return np.unique(self.dst[self.src == int(cell)])


class Fabric(abc.ABC):
    """Abstract interconnect fabric: cells joined by capacitated links.

    The contract every engine above routing programs against: a dense
    cell grid of per-dimension sizes ``dims`` (cuboid placement and the
    occupancy machinery need per-dim structure), an explicit link
    incidence (:meth:`links` — netsim builds its link x flow waterfilling
    from it), neighbor queries, and an internal-bisection figure.
    Implementations: :class:`TorusFabric` (rings per dimension) and
    :class:`HyperXFabric` (a clique per dimension — the Hamming graph).
    """

    dims: Tuple[int, ...]
    link_bw: float

    @property
    def num_cells(self) -> int:
        """Number of cells (allocation units) in the fabric."""
        return volume(self.dims)

    @property
    def dim_sizes(self) -> Tuple[int, ...]:
        """Per-dimension cell counts (the placement grid's shape)."""
        return tuple(self.dims)

    @abc.abstractmethod
    def links(self) -> LinkTable:
        """The explicit ``(link, src_cell, dst_cell, capacity)`` table."""

    @abc.abstractmethod
    def bisection_links(self) -> int:
        """Internal bisection of the fabric in (unit-capacity) links."""

    def neighbors(self, cell: int) -> np.ndarray:
        """Flat cell indices adjacent to ``cell`` (sorted, unique)."""
        return self.links().neighbors_of(cell)


@dataclass(frozen=True)
class TorusFabric(Fabric):
    """A physical torus (or mesh) fabric: a machine, a pod, or a slice.

    ``dims`` are chip/midplane counts per dimension, ``wrap`` flags the
    presence of the wrap-around link per dimension, ``link_bw`` is bytes/s
    per link per direction, and ``double_link_on_2`` selects the Blue
    Gene/Q convention (two parallel links on a length-2 dimension) vs the
    TPU ICI single link.

    >>> bgq = TorusFabric.bgq((4, 4, 4))
    >>> bgq.num_chips, bgq.bisection_links()
    (64, 32)
    >>> chain = TorusFabric.tpu((4, 2), wrap=(True, False))
    >>> chain.bisection_links()  # unwrapped dim is cut once, not twice
    4
    """

    dims: Tuple[int, ...]
    wrap: Tuple[bool, ...]  # wrap-around link present per dimension
    link_bw: float = DEFAULT_LINK_BW  # bytes/s per link per direction
    double_link_on_2: bool = False  # Blue Gene/Q: True, TPU: False

    def __post_init__(self):
        if len(self.dims) != len(self.wrap):
            raise ValueError("dims and wrap must have equal length")

    # -- constructors ----------------------------------------------------------
    @classmethod
    def bgq(cls, dims: Sequence[int], link_bw: float = DEFAULT_LINK_BW) -> "TorusFabric":
        """Blue Gene/Q convention: fully wrapped, double links on a==2."""
        d = tuple(int(a) for a in dims)
        return cls(d, (True,) * len(d), link_bw, double_link_on_2=True)

    @classmethod
    def tpu(
        cls,
        dims: Sequence[int],
        wrap: Optional[Sequence[bool]] = None,
        link_bw: float = DEFAULT_LINK_BW,
    ) -> "TorusFabric":
        """TPU ICI convention: explicit wrap flags, single links on a==2."""
        d = tuple(int(a) for a in dims)
        w = tuple(bool(x) for x in wrap) if wrap is not None else (True,) * len(d)
        return cls(d, w, link_bw, double_link_on_2=False)

    # -- basic quantities ------------------------------------------------------
    @property
    def num_chips(self) -> int:
        """Number of allocation units (chips / midplanes) in the fabric."""
        return volume(self.dims)

    @property
    def num_vertices(self) -> int:
        """Alias of :attr:`num_chips` for graph-flavoured callers."""
        return self.num_chips

    @property
    def is_fully_wrapped(self) -> bool:
        """Whether every non-trivial dimension keeps its wrap-around link."""
        return all(self.wrap[k] for k, a in enumerate(self.dims) if a > 1)

    def links_across_dim(self, k: int) -> int:
        """Links crossing a perpendicular plane of dimension k (per plane)."""
        return self.num_chips // self.dims[k]

    def bisection_links(self) -> int:
        """Internal bisection in links.

        For fully-wrapped double-link fabrics (the paper's BG/Q convention)
        this is the exact edge-isoperimetric computation, including the
        cuboid search for odd longest dimensions.  For partially-wrapped or
        single-link fabrics it is the min-over-dimensions halving cut: a
        wrapped dimension is cut in two places, an unwrapped (chain)
        dimension in one; a length-2 wrapped dimension with double links
        contributes 2 parallel links.
        """
        if self.is_fully_wrapped and self.double_link_on_2:
            return geometry.bisection_links(self.dims)
        best = None
        for k, a in enumerate(self.dims):
            if a == 1:
                continue
            planes = 2 if (self.wrap[k] and a > 2) else 1
            if a == 2 and self.wrap[k] and self.double_link_on_2:
                planes = 2
            cut = planes * self.links_across_dim(k)
            best = cut if best is None else min(best, cut)
        return 0 if best is None else best

    def bisection_bandwidth(self) -> float:
        """Bytes/s across the bisection, both directions of each link."""
        return 2.0 * self.bisection_links() * self.link_bw

    # -- geometry delegation ---------------------------------------------------
    def contains_cuboid(self, cuboid: Sequence[int]) -> bool:
        """Whether the cuboid geometry fits this fabric (up to rotation)."""
        return geometry.contains_cuboid(self.dims, cuboid)

    def sub_cuboids(self, size: int) -> Iterator[Geometry]:
        """All canonical cuboid geometries of ``size`` units that fit."""
        return geometry.sub_cuboids(self.dims, size)

    # -- the Fabric interface --------------------------------------------------
    def links(self) -> LinkTable:
        """Directed ring links, ids matching the flattened ``(D, 2, *dims)``
        load-tensor layout of :func:`repro.network.routing.route_dor` (slot
        ``(k * 2 + direction) * N + cell``).  Length-1 dimensions carry no
        links (their slots stay unused); a length-2 dimension's two
        parallel physical links (BG/Q) fold into doubled capacity, exactly
        mirroring :func:`repro.network.netsim.link_capacities`.  ``wrap``
        affects bisection accounting, not the routed incidence — DOR
        always routes the full ring, matching ``route_dor``.
        """
        dims = self.dims
        n = self.num_cells
        d = len(dims)
        cells = np.arange(n, dtype=np.int64)
        coords = np.stack(np.unravel_index(cells, dims), axis=1) if d else cells[:, None]
        link, src, dst, cap = [], [], [], []
        for k, a in enumerate(dims):
            if a <= 1:
                continue
            c = 2.0 * self.link_bw if (a == 2 and self.double_link_on_2) else self.link_bw
            for direction, step in ((0, 1), (1, -1)):
                nb = coords.copy()
                nb[:, k] = (nb[:, k] + step) % a
                link.append((k * 2 + direction) * n + cells)
                src.append(cells)
                dst.append(np.ravel_multi_index(tuple(nb.T), dims))
                cap.append(np.full(n, c))
        empty = np.zeros(0, dtype=np.int64)
        return LinkTable(
            link=np.concatenate(link) if link else empty,
            src=np.concatenate(src) if src else empty.copy(),
            dst=np.concatenate(dst) if dst else empty.copy(),
            capacity=np.concatenate(cap) if cap else np.zeros(0),
            n_slots=2 * d * n,
        )


@dataclass(frozen=True)
class Torus:
    """A fully-wrapped D-dimensional torus graph (the paper's object).

    Thin compatibility wrapper over :mod:`repro.network.geometry`; all edge
    counting follows the Blue Gene/Q double-link convention.  Prefer
    ``TorusFabric.bgq(dims)`` for new bandwidth-aware code.
    """

    dims: Geometry

    def __init__(self, dims: Iterable[int]):
        object.__setattr__(self, "dims", canonical(dims))

    @property
    def D(self) -> int:
        return len(self.dims)

    @property
    def num_vertices(self) -> int:
        return volume(self.dims)

    @property
    def degree(self) -> int:
        return geometry.degree(self.dims)

    @property
    def num_edges(self) -> int:
        return geometry.num_edges(self.dims)

    def fabric(self, link_bw: float = DEFAULT_LINK_BW) -> TorusFabric:
        """The equivalent bandwidth-aware fabric (BG/Q convention)."""
        return TorusFabric.bgq(self.dims, link_bw)

    def contains_cuboid(self, cuboid: Sequence[int]) -> bool:
        return geometry.contains_cuboid(self.dims, cuboid)

    def cuboid_cut(self, cuboid: Sequence[int]) -> int:
        return geometry.cuboid_cut(self.dims, cuboid)

    def cuboid_cut_aligned(self, sides: Sequence[int]) -> int:
        return geometry.cuboid_cut_aligned(self.dims, sides)

    def cuboid_interior(self, cuboid: Sequence[int]) -> int:
        return geometry.cuboid_interior(self.dims, cuboid)

    def sub_cuboids(self, size: int) -> Iterator[Geometry]:
        return geometry.sub_cuboids(self.dims, size)

    def bisection_links(self) -> int:
        return geometry.bisection_links(self.dims)


# ---------------------------------------------------------------------------
# HyperX: a clique per dimension (the Hamming graph H(S_1, ..., S_D)).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class HyperXFabric(Fabric):
    """A HyperX fabric: per-dimension diameter-1 all-to-all wiring.

    Every cell connects directly to every other cell of each of its
    dimension lines (the Hamming graph — Ahn et al.'s HyperX; Cano et
    al.'s resource-allocation setting), with an optional per-dimension
    link multiplicity ``K_k`` (trunked parallel links fold into
    capacity).  Cut structure is the *opposite* of a torus: covering a
    dimension removes its whole cut contribution, so elongated boxes have
    the largest internal bisection (see :mod:`repro.network.hamming`).

    >>> hx = HyperXFabric((16, 4))
    >>> hx.num_cells, hx.degree, hx.bisection_links()
    (64, 18, 64)
    >>> hx.sub_fabric((4, 4)).bisection_links()  # compact box: 4x worse
    16
    """

    dims: Tuple[int, ...]
    link_multiplicity: Optional[Tuple[int, ...]] = None  # K_k, default all 1
    link_bw: float = DEFAULT_LINK_BW  # bytes/s per (single) link per direction

    def __post_init__(self):
        object.__setattr__(self, "dims", tuple(int(a) for a in self.dims))
        if any(a < 1 for a in self.dims):
            raise ValueError(f"dims must be >= 1, got {self.dims}")
        mult = self.link_multiplicity
        mult = (1,) * len(self.dims) if mult is None else tuple(int(k) for k in mult)
        if len(mult) != len(self.dims) or any(k < 1 for k in mult):
            raise ValueError(
                f"link_multiplicity {self.link_multiplicity} must be one "
                f"positive entry per dim of {self.dims}"
            )
        object.__setattr__(self, "link_multiplicity", mult)

    # -- basic quantities ------------------------------------------------------
    @property
    def num_chips(self) -> int:
        """Alias of :attr:`Fabric.num_cells` (fabric-API symmetry)."""
        return self.num_cells

    @property
    def num_vertices(self) -> int:
        """Alias of :attr:`Fabric.num_cells` for graph-flavoured callers."""
        return self.num_cells

    @property
    def degree(self) -> int:
        """Links per cell: ``sum_k K_k * (S_k - 1)``."""
        return hamming.hamming_degree(self.dims, self.link_multiplicity)

    def bisection_links(self) -> int:
        """Exact internal bisection: the Lindsey lex half-set's cut (see
        :func:`repro.network.hamming.hamming_bisection_links`)."""
        return hamming.hamming_bisection_links(self.dims, self.link_multiplicity)

    def bisection_bandwidth(self) -> float:
        """Bytes/s across the bisection, both directions of each link."""
        return 2.0 * self.bisection_links() * self.link_bw

    def contains_cuboid(self, cuboid: Sequence[int]) -> bool:
        """Whether an aligned box with these sides fits (up to rotation) —
        any ``c_k <= S_k`` subset of coordinates spans a valid sub-box in
        a clique dimension, so this is the sorted-containment test."""
        return geometry.contains_cuboid(self.dims, cuboid)

    # -- the Fabric interface --------------------------------------------------
    def links(self) -> LinkTable:
        """Directed clique links.  Dense id layout: dimension k occupies
        the slot block ``N * sum_{i<k} S_i``, and the link from cell
        ``u`` to destination coordinate ``j`` in dim k has slot
        ``block_k + flat(u) * S_k + j`` — the ``j == u_k`` self-slots
        stay unused.  Capacity is ``K_k * link_bw`` (trunking folds in).
        """
        dims = self.dims
        n = self.num_cells
        cells = np.arange(n, dtype=np.int64)
        coords = np.stack(np.unravel_index(cells, dims), axis=1)
        link, src, dst, cap = [], [], [], []
        base = 0
        for k, a in enumerate(dims):
            if a > 1:
                for j in range(a):
                    take = coords[:, k] != j
                    nb = coords[take].copy()
                    nb[:, k] = j
                    link.append(base + cells[take] * a + j)
                    src.append(cells[take])
                    dst.append(np.ravel_multi_index(tuple(nb.T), dims))
                    cap.append(
                        np.full(int(take.sum()), self.link_multiplicity[k] * self.link_bw)
                    )
            base += n * a
        empty = np.zeros(0, dtype=np.int64)
        return LinkTable(
            link=np.concatenate(link) if link else empty,
            src=np.concatenate(src) if src else empty.copy(),
            dst=np.concatenate(dst) if dst else empty.copy(),
            capacity=np.concatenate(cap) if cap else np.zeros(0),
            n_slots=n * sum(dims),
        )

    def sub_fabric(self, sides: Sequence[int]) -> "HyperXFabric":
        """The fabric of an aligned sub-box: any ``c_k``-subset of a
        clique dimension is itself a ``K_{c_k}`` clique, so a HyperX
        sub-box is the Hamming graph ``H(c)`` — wrap semantics never
        enter (contrast :func:`slice_fabric`).  Sides match machine
        dimensions tightest-fit and inherit their multiplicities.
        """
        g = canonical(sides)
        g = g + (1,) * (len(self.dims) - len(g))
        if len(g) > len(self.dims):
            raise ValueError(f"sub-box {g} has more dims than fabric {self.dims}")
        avail = sorted(range(len(self.dims)), key=lambda i: self.dims[i])
        used = set()
        out_dims, out_mult = [], []
        for side in g:
            pick = None
            for i in avail:
                if i not in used and self.dims[i] >= side:
                    pick = i
                    break
            if pick is None:
                raise ValueError(f"sub-box {g} does not fit in fabric {self.dims}")
            used.add(pick)
            out_dims.append(side)
            out_mult.append(self.link_multiplicity[pick])
        return HyperXFabric(tuple(out_dims), tuple(out_mult), self.link_bw)


# ---------------------------------------------------------------------------
# Slice planning (the paper's technique at the job level).
# ---------------------------------------------------------------------------
def _require_ring_fabric(pod, where: str) -> None:
    """Slice planning computes wrap-aware torus bisections; anything
    without per-dim ring structure (e.g. :class:`HyperXFabric`) would get
    silently wrong geometries, so fail loudly instead."""
    if not isinstance(pod, TorusFabric):
        raise TypeError(
            f"{where} requires a TorusFabric (per-dimension ring structure with "
            f"wrap semantics); got {type(pod).__name__} — for HyperX fabrics use "
            f"HyperXFabric.sub_fabric / repro.network.isoperimetry.ranked_geometries"
        )


def slice_fabric(pod: TorusFabric, geometry_: Sequence[int]) -> TorusFabric:
    """The fabric of a cuboid slice allocated from a pod.

    TPU semantics: wrap in a dimension only where the slice covers the full
    (wrapped) pod dimension.  Slice sides are matched to pod dims tightest-fit.
    Raises ``TypeError`` for fabrics without per-dim ring structure.
    """
    _require_ring_fabric(pod, "slice_fabric")
    g = canonical(geometry_)
    g = g + (1,) * (len(pod.dims) - len(g))
    if len(g) > len(pod.dims):
        raise ValueError(f"slice {g} has more dims than pod {pod.dims}")
    avail = sorted(range(len(pod.dims)), key=lambda i: pod.dims[i])
    dims, wrap = [], []
    used = set()
    for side in g:
        pick = None
        for i in avail:
            if i not in used and pod.dims[i] >= side:
                pick = i
                break
        if pick is None:
            raise ValueError(f"slice {g} does not fit in pod {pod.dims}")
        used.add(pick)
        dims.append(side)
        wrap.append(pod.wrap[pick] and side == pod.dims[pick])
    return TorusFabric(tuple(dims), tuple(wrap), pod.link_bw, pod.double_link_on_2)


def ranked_slice_geometries(pod: TorusFabric, chips: int) -> List[Tuple[Geometry, int]]:
    """All cuboid slice geometries of the requested size that fit the pod,
    as (geometry, bisection_links) pairs, best first (max bisection, ties
    broken toward the lexicographically-smallest canonical geometry).  This
    single ranking backs both the geometry-only planner
    (:func:`best_slice_geometry`) and the occupancy-aware planner
    (``repro.launch.mesh.plan_slice``), so they cannot drift apart.
    Candidates come from the isoperimetry engine's batched enumeration
    (:func:`repro.network.isoperimetry.fitting_geometries`); each slice's
    bisection stays the exact wrap-aware :func:`slice_fabric` computation.
    Raises ``TypeError`` for fabrics without per-dim ring structure."""
    _require_ring_fabric(pod, "ranked_slice_geometries")
    from .isoperimetry import fitting_geometries

    candidates = [
        tuple(int(x) for x in row) for row in fitting_geometries(pod.dims, chips)
    ]
    ranked = sorted(
        ((g, slice_fabric(pod, g).bisection_links()) for g in candidates),
        key=lambda t: (-t[1], t[0]),
    )
    if not ranked:
        raise ValueError(f"no cuboid slice of {chips} chips fits in pod {pod.dims}")
    return ranked


def best_slice_geometry(pod: TorusFabric, chips: int) -> Tuple[Geometry, int]:
    """Among all cuboid slices of the requested size that fit the pod, return
    the geometry with maximal internal bisection (links)."""
    return ranked_slice_geometries(pod, chips)[0]


def worst_slice_geometry(pod: TorusFabric, chips: int) -> Tuple[Geometry, int]:
    """The fitting cuboid slice with *minimal* internal bisection (links) —
    the adversarial baseline of the avoidable-contention ratio."""
    _require_ring_fabric(pod, "worst_slice_geometry")
    worst: Optional[Tuple[Geometry, int]] = None
    for g in geometry.sub_cuboids(pod.dims, chips):
        fab = slice_fabric(pod, g)
        b = fab.bisection_links()
        if worst is None or b < worst[1] or (b == worst[1] and g > worst[0]):
            worst = (g, b)
    if worst is None:
        raise ValueError(f"no cuboid slice of {chips} chips fits in pod {pod.dims}")
    return worst
