"""Unified torus fabric model: one class for BG/Q- and TPU-style tori.

This merges what used to be two separate models:

* ``repro.core.torus.Torus`` — the paper's fully-wrapped Blue Gene/Q torus
  with double links on length-2 dimensions, used by the isoperimetric
  analysis; and
* ``repro.core.collectives.TorusFabric`` — the TPU-adapted fabric with
  per-dimension wrap flags and single links on length-2 dimensions.

Both are now parameterisations of :class:`TorusFabric`; the thin
:class:`Torus` wrapper keeps the historical geometry-only API and delegates
every computation to :mod:`repro.network.geometry`.

Hardware conventions (see DESIGN.md):

* Blue Gene/Q: a partition *always* retains wrap-around links (a partition of
  midplane geometry g is itself a torus), and a dimension of length 2 has two
  parallel physical links — ``TorusFabric.bgq(dims)``.
* TPU ICI: a slice gets wrap-around links in a dimension only when it spans
  that full pod dimension, and a length-2 dimension has a single link —
  ``TorusFabric.tpu(dims, wrap)`` / :func:`slice_fabric`.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple

from . import geometry
from .geometry import Geometry, canonical, volume

# TPU v5e-class constants (per chip / per link, bytes per second).
DEFAULT_LINK_BW = 50e9  # ~50 GB/s per ICI link per direction (prompt spec)
POD_DCI_BW = 12.5e9  # inter-pod (data-center network) per-chip share, est.


@dataclass(frozen=True)
class TorusFabric:
    """A physical torus (or mesh) fabric: a machine, a pod, or a slice.

    ``dims`` are chip/midplane counts per dimension, ``wrap`` flags the
    presence of the wrap-around link per dimension, ``link_bw`` is bytes/s
    per link per direction, and ``double_link_on_2`` selects the Blue
    Gene/Q convention (two parallel links on a length-2 dimension) vs the
    TPU ICI single link.

    >>> bgq = TorusFabric.bgq((4, 4, 4))
    >>> bgq.num_chips, bgq.bisection_links()
    (64, 32)
    >>> chain = TorusFabric.tpu((4, 2), wrap=(True, False))
    >>> chain.bisection_links()  # unwrapped dim is cut once, not twice
    4
    """

    dims: Tuple[int, ...]
    wrap: Tuple[bool, ...]  # wrap-around link present per dimension
    link_bw: float = DEFAULT_LINK_BW  # bytes/s per link per direction
    double_link_on_2: bool = False  # Blue Gene/Q: True, TPU: False

    def __post_init__(self):
        if len(self.dims) != len(self.wrap):
            raise ValueError("dims and wrap must have equal length")

    # -- constructors ----------------------------------------------------------
    @classmethod
    def bgq(cls, dims: Sequence[int], link_bw: float = DEFAULT_LINK_BW) -> "TorusFabric":
        """Blue Gene/Q convention: fully wrapped, double links on a==2."""
        d = tuple(int(a) for a in dims)
        return cls(d, (True,) * len(d), link_bw, double_link_on_2=True)

    @classmethod
    def tpu(
        cls,
        dims: Sequence[int],
        wrap: Optional[Sequence[bool]] = None,
        link_bw: float = DEFAULT_LINK_BW,
    ) -> "TorusFabric":
        """TPU ICI convention: explicit wrap flags, single links on a==2."""
        d = tuple(int(a) for a in dims)
        w = tuple(bool(x) for x in wrap) if wrap is not None else (True,) * len(d)
        return cls(d, w, link_bw, double_link_on_2=False)

    # -- basic quantities ------------------------------------------------------
    @property
    def num_chips(self) -> int:
        """Number of allocation units (chips / midplanes) in the fabric."""
        return volume(self.dims)

    @property
    def num_vertices(self) -> int:
        """Alias of :attr:`num_chips` for graph-flavoured callers."""
        return self.num_chips

    @property
    def is_fully_wrapped(self) -> bool:
        """Whether every non-trivial dimension keeps its wrap-around link."""
        return all(self.wrap[k] for k, a in enumerate(self.dims) if a > 1)

    def links_across_dim(self, k: int) -> int:
        """Links crossing a perpendicular plane of dimension k (per plane)."""
        return self.num_chips // self.dims[k]

    def bisection_links(self) -> int:
        """Internal bisection in links.

        For fully-wrapped double-link fabrics (the paper's BG/Q convention)
        this is the exact edge-isoperimetric computation, including the
        cuboid search for odd longest dimensions.  For partially-wrapped or
        single-link fabrics it is the min-over-dimensions halving cut: a
        wrapped dimension is cut in two places, an unwrapped (chain)
        dimension in one; a length-2 wrapped dimension with double links
        contributes 2 parallel links.
        """
        if self.is_fully_wrapped and self.double_link_on_2:
            return geometry.bisection_links(self.dims)
        best = None
        for k, a in enumerate(self.dims):
            if a == 1:
                continue
            planes = 2 if (self.wrap[k] and a > 2) else 1
            if a == 2 and self.wrap[k] and self.double_link_on_2:
                planes = 2
            cut = planes * self.links_across_dim(k)
            best = cut if best is None else min(best, cut)
        return 0 if best is None else best

    def bisection_bandwidth(self) -> float:
        """Bytes/s across the bisection, both directions of each link."""
        return 2.0 * self.bisection_links() * self.link_bw

    # -- geometry delegation ---------------------------------------------------
    def contains_cuboid(self, cuboid: Sequence[int]) -> bool:
        """Whether the cuboid geometry fits this fabric (up to rotation)."""
        return geometry.contains_cuboid(self.dims, cuboid)

    def sub_cuboids(self, size: int) -> Iterator[Geometry]:
        """All canonical cuboid geometries of ``size`` units that fit."""
        return geometry.sub_cuboids(self.dims, size)


@dataclass(frozen=True)
class Torus:
    """A fully-wrapped D-dimensional torus graph (the paper's object).

    Thin compatibility wrapper over :mod:`repro.network.geometry`; all edge
    counting follows the Blue Gene/Q double-link convention.  Prefer
    ``TorusFabric.bgq(dims)`` for new bandwidth-aware code.
    """

    dims: Geometry

    def __init__(self, dims: Iterable[int]):
        object.__setattr__(self, "dims", canonical(dims))

    @property
    def D(self) -> int:
        return len(self.dims)

    @property
    def num_vertices(self) -> int:
        return volume(self.dims)

    @property
    def degree(self) -> int:
        return geometry.degree(self.dims)

    @property
    def num_edges(self) -> int:
        return geometry.num_edges(self.dims)

    def fabric(self, link_bw: float = DEFAULT_LINK_BW) -> TorusFabric:
        """The equivalent bandwidth-aware fabric (BG/Q convention)."""
        return TorusFabric.bgq(self.dims, link_bw)

    def contains_cuboid(self, cuboid: Sequence[int]) -> bool:
        return geometry.contains_cuboid(self.dims, cuboid)

    def cuboid_cut(self, cuboid: Sequence[int]) -> int:
        return geometry.cuboid_cut(self.dims, cuboid)

    def cuboid_cut_aligned(self, sides: Sequence[int]) -> int:
        return geometry.cuboid_cut_aligned(self.dims, sides)

    def cuboid_interior(self, cuboid: Sequence[int]) -> int:
        return geometry.cuboid_interior(self.dims, cuboid)

    def sub_cuboids(self, size: int) -> Iterator[Geometry]:
        return geometry.sub_cuboids(self.dims, size)

    def bisection_links(self) -> int:
        return geometry.bisection_links(self.dims)


# ---------------------------------------------------------------------------
# Slice planning (the paper's technique at the job level).
# ---------------------------------------------------------------------------
def slice_fabric(pod: TorusFabric, geometry_: Sequence[int]) -> TorusFabric:
    """The fabric of a cuboid slice allocated from a pod.

    TPU semantics: wrap in a dimension only where the slice covers the full
    (wrapped) pod dimension.  Slice sides are matched to pod dims tightest-fit.
    """
    g = canonical(geometry_)
    g = g + (1,) * (len(pod.dims) - len(g))
    if len(g) > len(pod.dims):
        raise ValueError(f"slice {g} has more dims than pod {pod.dims}")
    avail = sorted(range(len(pod.dims)), key=lambda i: pod.dims[i])
    dims, wrap = [], []
    used = set()
    for side in g:
        pick = None
        for i in avail:
            if i not in used and pod.dims[i] >= side:
                pick = i
                break
        if pick is None:
            raise ValueError(f"slice {g} does not fit in pod {pod.dims}")
        used.add(pick)
        dims.append(side)
        wrap.append(pod.wrap[pick] and side == pod.dims[pick])
    return TorusFabric(tuple(dims), tuple(wrap), pod.link_bw, pod.double_link_on_2)


def ranked_slice_geometries(pod: TorusFabric, chips: int) -> List[Tuple[Geometry, int]]:
    """All cuboid slice geometries of the requested size that fit the pod,
    as (geometry, bisection_links) pairs, best first (max bisection, ties
    broken toward the lexicographically-smallest canonical geometry).  This
    single ranking backs both the geometry-only planner
    (:func:`best_slice_geometry`) and the occupancy-aware planner
    (``repro.launch.mesh.plan_slice``), so they cannot drift apart.
    Candidates come from the isoperimetry engine's batched enumeration
    (:func:`repro.network.isoperimetry.fitting_geometries`); each slice's
    bisection stays the exact wrap-aware :func:`slice_fabric` computation."""
    from .isoperimetry import fitting_geometries

    candidates = [
        tuple(int(x) for x in row) for row in fitting_geometries(pod.dims, chips)
    ]
    ranked = sorted(
        ((g, slice_fabric(pod, g).bisection_links()) for g in candidates),
        key=lambda t: (-t[1], t[0]),
    )
    if not ranked:
        raise ValueError(f"no cuboid slice of {chips} chips fits in pod {pod.dims}")
    return ranked


def best_slice_geometry(pod: TorusFabric, chips: int) -> Tuple[Geometry, int]:
    """Among all cuboid slices of the requested size that fit the pod, return
    the geometry with maximal internal bisection (links)."""
    return ranked_slice_geometries(pod, chips)[0]


def worst_slice_geometry(pod: TorusFabric, chips: int) -> Tuple[Geometry, int]:
    """The fitting cuboid slice with *minimal* internal bisection (links) —
    the adversarial baseline of the avoidable-contention ratio."""
    worst: Optional[Tuple[Geometry, int]] = None
    for g in geometry.sub_cuboids(pod.dims, chips):
        fab = slice_fabric(pod, g)
        b = fab.bisection_links()
        if worst is None or b < worst[1] or (b == worst[1] and g > worst[0]):
            worst = (g, b)
    if worst is None:
        raise ValueError(f"no cuboid slice of {chips} chips fits in pod {pod.dims}")
    return worst
