"""Edge-isoperimetry on Hamming graphs — the HyperX analogue of Section 3.

A HyperX fabric (Ahn et al.; Cano et al., *Resource Allocation in HyperX
Networks*) is the Hamming graph ``H(S_1, ..., S_D)``: the product of
complete graphs, one clique per dimension, optionally with a per-dimension
link multiplicity ``K_k`` (parallel links / trunking).  Every cut and
bound the torus engine computes for :mod:`repro.network.geometry` has a
Hamming counterpart here:

* the **exact cut of any vertex set** decomposes per dimension line
  (each line is a clique): a line holding ``m`` of the set's vertices
  contributes ``K_k * m * (S_k - m)`` crossing edges
  (:func:`hamming_cut_of_set`);
* an **aligned box** with sides ``c_k`` has the closed-form cut
  ``t * sum_k K_k (S_k - c_k)`` (:func:`hamming_cut_aligned`) — note the
  opposite monotonicity to tori: *longer* sides mean *smaller* cuts,
  because covering a clique dimension removes its whole contribution;
* the **lower bound** on any size-``t`` set's cut comes through the edge
  identity ``cut(S) = t * degree - 2 * E(S)``: maximising induced edges
  minimises the cut.  For uniform multiplicity, **Lindsey's lemma** says
  the lexicographic initial segment with coordinates ordered by
  *decreasing* dimension size (largest dimension varying fastest)
  maximises ``E(S)`` — :func:`lex_max_edges` evaluates it by a
  divide-out recursion, making :func:`lindsey_bound` the exact
  isoperimetric minimum.  With non-uniform multiplicities lex order is
  *not* optimal (small counterexamples exist), so the bound falls back
  to the sound per-dimension packing relaxation
  (:func:`packed_edges_bound`) and is a floor rather than the optimum.

Both the recursion and the closed forms are brute-force-verified against
explicit subset enumeration on small Hamming graphs in
``tests/test_hyperx.py`` — an unsound bound here would *falsely certify*
partition geometries, so the test suite treats soundness as tier-1.

>>> lindsey_bound((16, 4), 16)   # one full 16-line: cut = 16 * (18 - 2*15)/...
48
>>> hamming_cut_aligned((16, 4), (16, 1))
48
>>> hamming_bisection_links((16, 1))
64
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import numpy as np

from .geometry import volume

__all__ = [
    "hamming_bisection_links",
    "hamming_cut_aligned",
    "hamming_cut_of_set",
    "hamming_degree",
    "hamming_num_edges",
    "hamming_subset_bound",
    "lex_cells",
    "lex_max_edges",
    "lindsey_bound",
    "packed_edges_bound",
]


def _mult(dims: Sequence[int], mult: Optional[Sequence[int]]) -> Tuple[int, ...]:
    """Normalise a per-dimension link multiplicity (default: all ones)."""
    d = tuple(int(a) for a in dims)
    if mult is None:
        return (1,) * len(d)
    m = tuple(int(k) for k in mult)
    if len(m) != len(d):
        raise ValueError(f"multiplicity {m} must have one entry per dim of {d}")
    if any(k < 1 for k in m):
        raise ValueError(f"multiplicities must be >= 1, got {m}")
    return m


def hamming_degree(dims: Sequence[int], mult: Optional[Sequence[int]] = None) -> int:
    """Vertex degree of ``H(dims)``: every other vertex of each dimension
    line is one hop away, ``sum_k K_k * (S_k - 1)``.

    >>> hamming_degree((16, 4))
    18
    """
    m = _mult(dims, mult)
    return sum(k * (a - 1) for a, k in zip(dims, m))


def hamming_num_edges(dims: Sequence[int], mult: Optional[Sequence[int]] = None) -> int:
    """Total edge count: ``N / S_k`` lines per dimension, each a clique.

    >>> hamming_num_edges((4, 4))
    48
    """
    d = tuple(int(a) for a in dims)
    m = _mult(d, mult)
    n = volume(d)
    return sum(k * (n // a) * (a * (a - 1) // 2) for a, k in zip(d, m))


def hamming_cut_aligned(
    dims: Sequence[int], sides: Sequence[int], mult: Optional[Sequence[int]] = None
) -> int:
    """Exact cut of an aligned box with ``sides[k]`` coordinates in dim k.

    Each of the box's ``t`` vertices sees ``S_k - c_k`` vertices outside
    its dim-k line segment, so the cut is ``t * sum_k K_k (S_k - c_k)`` —
    monotone *decreasing* in every side (cover a dimension, kill its term).

    >>> hamming_cut_aligned((4, 4), (4, 1)), hamming_cut_aligned((4, 4), (2, 2))
    (12, 16)
    """
    d = tuple(int(a) for a in dims)
    c = tuple(int(x) for x in sides)
    if len(c) != len(d):
        raise ValueError(f"sides {c} must have one entry per dim of {d}")
    if any(x < 1 or x > a for x, a in zip(c, d)):
        raise ValueError(f"sides {c} must satisfy 1 <= side <= dim for dims {d}")
    m = _mult(d, mult)
    t = volume(c)
    return t * sum(k * (a - x) for a, x, k in zip(d, c, m))


def hamming_cut_of_set(
    dims: Sequence[int], cells: np.ndarray, mult: Optional[Sequence[int]] = None
) -> int:
    """Exact cut of an arbitrary vertex set, by per-line occupancy.

    ``cells`` is a (t, D) int array of coordinates.  Within each dimension
    the vertex set partitions into lines (cliques); a line holding ``m``
    members contributes ``K_k * m * (S_k - m)`` crossing edges.  One
    ``bincount`` per dimension — no pairwise enumeration.

    >>> import numpy as np
    >>> hamming_cut_of_set((4, 4), np.array([[0, 0], [0, 1], [1, 0], [1, 1]]))
    16
    """
    d = tuple(int(a) for a in dims)
    m = _mult(d, mult)
    cells = np.atleast_2d(np.asarray(cells, dtype=np.int64))
    if cells.shape[0] == 0:
        return 0
    if cells.shape[1] != len(d):
        raise ValueError(f"cells must have shape (t, {len(d)}); got {cells.shape}")
    total = 0
    for k, a in enumerate(d):
        other = [cells[:, j] for j in range(len(d)) if j != k]
        other_dims = tuple(x for j, x in enumerate(d) if j != k)
        if other:
            line = np.ravel_multi_index(other, other_dims)
        else:
            line = np.zeros(cells.shape[0], dtype=np.int64)
        occ = np.bincount(line)
        total += int(m[k] * (occ * (a - occ)).sum())
    return total


# ---------------------------------------------------------------------------
# Lindsey's lemma: the lex initial segment maximises induced edges.
# ---------------------------------------------------------------------------
def _desc(dims: Sequence[int], mult: Optional[Sequence[int]]):
    """Dims (with matching multiplicities) sorted by decreasing size —
    the Lindsey order: the largest dimension varies fastest (innermost)."""
    d = tuple(int(a) for a in dims)
    m = _mult(d, mult)
    order = sorted(range(len(d)), key=lambda k: (-d[k], k))
    return tuple(d[k] for k in order), tuple(m[k] for k in order)


def lex_cells(dims: Sequence[int], t: int) -> np.ndarray:
    """Coordinates of the first ``t`` cells in Lindsey lex order, as a
    (t, D) array in the *original* dimension order.

    The segment fills the largest dimension first (it varies fastest), so
    e.g. the first 16 cells of ``H(16, 4)`` are one full 16-line — the
    elongated box that minimises the Hamming cut, the exact opposite of
    the torus' compact optimum.

    >>> lex_cells((2, 3), 4).tolist()   # dim of size 3 varies fastest
    [[0, 0], [0, 1], [0, 2], [1, 0]]
    """
    d = tuple(int(a) for a in dims)
    n = volume(d)
    if not 0 <= t <= n:
        raise ValueError(f"t must be in [0, {n}], got {t}")
    order = sorted(range(len(d)), key=lambda k: (-d[k], k))
    sorted_dims = tuple(d[k] for k in order)
    # Unravel 0..t-1 with the largest dim as the last (fastest) axis, i.e.
    # C-order over dims sorted ascending-outer / descending-inner.
    idx = np.arange(t, dtype=np.int64)
    coords_sorted = np.stack(
        np.unravel_index(idx, sorted_dims[::-1]), axis=1
    )[:, ::-1]  # now column j corresponds to sorted_dims[j]
    out = np.empty((t, len(d)), dtype=np.int64)
    for j, k in enumerate(order):
        out[:, k] = coords_sorted[:, j]
    return out


def lex_max_edges(
    dims: Sequence[int], t: int, mult: Optional[Sequence[int]] = None
) -> int:
    """Induced edges of the Lindsey lex initial segment of size ``t``.

    Divide-out recursion on the outermost (smallest) dimension: with
    ``m`` cells per inner block and ``t = q*m + r``, the segment is ``q``
    full inner copies plus the lex-first ``r`` cells of the next copy;
    outer-dimension lines then hold ``q+1`` members at ``r`` inner
    positions and ``q`` at the rest.  For uniform multiplicity this *is*
    the maximum over all size-``t`` sets (Lindsey's lemma; brute-force
    verified in the test suite) — with non-uniform multiplicities it is
    only the lex segment's own edge count.

    >>> lex_max_edges((16, 4), 16)   # one full 16-clique
    120
    """
    d, m = _desc(dims, mult)
    n = volume(d)
    if not 0 <= t <= n:
        raise ValueError(f"t must be in [0, {n}], got {t}")

    def rec(ds: Tuple[int, ...], ms: Tuple[int, ...], size: int) -> int:
        if size <= 1:
            return 0
        if len(ds) == 1:
            return ms[0] * size * (size - 1) // 2
        inner_ds, inner_ms = ds[:-1], ms[:-1]
        k_outer = ms[-1]
        block = math.prod(inner_ds)
        q, r = divmod(size, block)
        return (
            q * hamming_num_edges(inner_ds, inner_ms)
            + rec(inner_ds, inner_ms, r)
            + k_outer * (r * (q * (q + 1) // 2) + (block - r) * (q * (q - 1) // 2))
        )

    return rec(d, m, t)


def packed_edges_bound(
    dims: Sequence[int], t: int, mult: Optional[Sequence[int]] = None
) -> int:
    """Sound upper bound on induced edges for *any* multiplicities.

    Per dimension independently, ``t`` vertices induce the most dim-k
    edges by packing whole lines: ``q`` full ``S_k``-cliques plus one
    ``r``-clique (``q, r = divmod(t, S_k)``).  Summing the per-dimension
    maxima relaxes the joint constraint, so this dominates the true
    maximum (and the Lindsey value); it is what keeps
    :func:`lindsey_bound` sound when multiplicities differ per dimension,
    where lex segments are provably not optimal.
    """
    d = tuple(int(a) for a in dims)
    m = _mult(d, mult)
    total = 0
    for a, k in zip(d, m):
        q, r = divmod(t, a)
        total += k * (q * (a * (a - 1) // 2) + r * (r - 1) // 2)
    return total


def lindsey_bound(
    dims: Sequence[int], t: int, mult: Optional[Sequence[int]] = None
) -> int:
    """Lower bound on the cut of *any* ``t``-subset of ``H(dims)``.

    Via the edge identity ``cut(S) = t * degree - 2 * E(S)``: an upper
    bound on induced edges is a lower bound on the cut.  Uniform
    multiplicity uses the exact Lindsey maximum (:func:`lex_max_edges`),
    making this the exact isoperimetric minimum; otherwise the packing
    relaxation (:func:`packed_edges_bound`) keeps it sound.

    >>> lindsey_bound((4, 4), 8)     # two full lines: 8 * 6 - 2 * 16
    16
    """
    d = tuple(int(a) for a in dims)
    m = _mult(d, mult)
    if not 0 <= t <= volume(d):
        raise ValueError(f"t must be in [0, {volume(d)}], got {t}")
    if len(set(m)) <= 1:
        e_max = lex_max_edges(d, t, m)
    else:
        e_max = packed_edges_bound(d, t, m)
    return max(0, t * hamming_degree(d, m) - 2 * e_max)


def hamming_subset_bound(
    dims: Sequence[int], t: int, mult: Optional[Sequence[int]] = None
) -> int:
    """:func:`lindsey_bound` with complement symmetry: every edge leaving
    ``S`` enters its complement, so the bound at ``min(t, n - t)``
    applies to sets of either size."""
    n = volume(tuple(int(a) for a in dims))
    return lindsey_bound(dims, min(t, n - t), mult)


def hamming_bisection_links(
    dims: Sequence[int], mult: Optional[Sequence[int]] = None
) -> int:
    """Internal bisection (links) of ``H(dims)``: the minimum cut over all
    ``floor(n/2)``-subsets, evaluated as the *explicit* cut of the Lindsey
    lex segment via per-line occupancy (:func:`hamming_cut_of_set`) — an
    achievable construction, certified optimal against the independent
    closed-form recursion by :func:`lindsey_bound` (exact for uniform
    multiplicity; for non-uniform fabrics the construction is still
    achievable but only floor-certified).

    >>> hamming_bisection_links((16, 1)), hamming_bisection_links((4, 4))
    (64, 16)
    """
    d = tuple(int(a) for a in dims)
    n = volume(d)
    if n <= 1:
        return 0
    return hamming_cut_of_set(d, lex_cells(d, n // 2), mult)
