"""Vectorized edge-isoperimetric analysis of torus graphs (paper Section 3).

This is the engine behind the paper's central tool — certifying whether a
partition geometry has optimal internal bisection — promoted from the
historical per-cuboid Python loops (kept as the property-test oracle in
``tests/reference_isoperimetry.py``) to one batched NumPy pass:

* ``cut_table``             — every cuboid geometry of a given volume that
  fits a torus, with its exact minimum cut, from a single divisor-meshgrid
  enumeration (no per-cuboid loop, no per-permutation loop).
* ``bollobas_leader_bound`` — Theorem 2.1 (cubic tori, Bollobás & Leader).
* ``theorem31_bound``       — Theorem 3.1, the paper's generalisation to
  arbitrary dimension sizes (re-exported from `repro.network.geometry`).
* ``lemma32_cut``           — the explicit optimal-cuboid construction S_r
  of Lemma 3.2 and its exact cut size.
* ``optimal_cuboid`` / ``worst_cuboid`` — exact min-/max-cut cuboids with a
  Theorem 3.1 tightness certificate.  For ``t > n/2`` the bound uses
  complement symmetry (``cut(S) == cut(S̄)``, so the Theorem 3.1 bound at
  ``n - t`` applies) — the historical code set ``bound = cut`` there,
  making ``CuboidOptimum.tight`` vacuously True.
* ``small_set_expansion``   — h_t(G) over cuboid witnesses via the
  regularity identity (Eq. 1), so only the batched min-cuts are needed.
* ``bisection_table`` / ``ranked_geometries`` / ``best_bisection_geometry``
  / ``worst_bisection_geometry`` — internal bisection of every same-volume
  geometry (node-level when ``unit_node_dims`` is given, the paper's
  tables), backing the allocation policies' preference ranking.
* ``is_isoperimetrically_optimal`` / ``advise_partition`` /
  ``advise_policy_table`` — the partition advisor: rank an allocation
  policy's admissible geometries by internal bisection, certify the
  optimum with Theorem 3.1, predict the contention-bound speedup of
  switching (paper Tables 4-6) and optionally cross-check it against the
  flow-level simulator (:mod:`repro.network.netsim`).

All cut sizes are in links with unit capacity ("normalized bisection
bandwidth"), under the fully-wrapped Blue Gene/Q double-link convention of
:mod:`repro.network.geometry`.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Mapping, Optional, Sequence, Tuple

import numpy as np

from . import hamming
from .backend import resolve_backend
from .fabric import HyperXFabric
from .geometry import (
    Geometry,
    canonical,
    cuboid_cut,
    degree,
    theorem31_bound,
    volume,
)

__all__ = [
    "BisectionTable",
    "CuboidOptimum",
    "CutTable",
    "PartitionAdvice",
    "advise_partition",
    "advise_policy_table",
    "best_bisection_geometry",
    "bisection_of_geometry",
    "bisection_table",
    "bollobas_leader_bound",
    "cut_table",
    "fitting_geometries",
    "is_isoperimetrically_optimal",
    "lemma32_cut",
    "optimal_cuboid",
    "ranked_geometries",
    "scaled_node_dims",
    "small_set_expansion",
    "theorem31_bound",
    "worst_bisection_geometry",
    "worst_cuboid",
]


def _dims_of(torus_or_dims) -> Geometry:
    """Canonical dims of a ``Torus``/``TorusFabric``-like object or a tuple.

    :class:`~repro.network.fabric.HyperXFabric` also carries ``.dims`` but
    has clique (not ring) lines — the public entry points dispatch on the
    type *before* reaching this helper, so a HyperX fabric is never
    silently flattened into torus dims.
    """
    if isinstance(torus_or_dims, HyperXFabric):
        raise TypeError(
            "HyperXFabric reached a torus-only code path; use the fabric-"
            "dispatching entry points (cut_table, optimal_cuboid, "
            "bisection_table, advise_partition, ...)"
        )
    return canonical(getattr(torus_or_dims, "dims", torus_or_dims))


def _divisors(t: int, cap: Optional[int] = None) -> np.ndarray:
    """Divisors of t, optionally only those <= cap (a side can never exceed
    the longest torus dimension, so the enumeration caps there)."""
    hi = t if cap is None else min(t, cap)
    d = np.arange(1, hi + 1, dtype=np.int64)
    return d[t % d == 0]


def _aligned_assignments(a: Geometry, t: int) -> np.ndarray:
    """All aligned side assignments of volume t into torus dims ``a``.

    Row k is ``(s_1, ..., s_D)`` with ``s_i | t``, ``s_i <= a_i`` and
    ``prod s_i == t`` — every feasible embedding of every fitting cuboid
    geometry, built dimension by dimension as a pruned divisor meshgrid
    (each step crosses the surviving partial assignments with the divisor
    list, keeping rows whose remaining volume divides out and still fits
    in the remaining dimensions).  Empty (shape (0, D)) when nothing fits.
    """
    D = len(a)
    divs = _divisors(t, cap=max(a, default=0))
    suffix = [1] * (D + 1)  # suffix[i] = prod(a[i:])
    for i in range(D - 1, -1, -1):
        suffix[i] = suffix[i + 1] * a[i]
    rows = np.zeros((1, 0), dtype=np.int64)
    rem = np.array([t], dtype=np.int64)
    for i, ai in enumerate(a):
        cand = divs[divs <= ai]
        ok = (rem[:, None] % cand[None, :]) == 0
        nrem = rem[:, None] // cand[None, :]
        ok &= nrem <= suffix[i + 1]
        r, c = np.nonzero(ok)
        rows = np.concatenate([rows[r], cand[c][:, None]], axis=1)
        rem = nrem[r, c]
    return rows[rem == 1]


# ---------------------------------------------------------------------------
# The batched cut engine.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CutTable:
    """Every canonical cuboid geometry of volume ``t`` fitting ``dims``,
    with its exact minimum cut (links, double-link convention).

    ``geometries`` is a (G, D) int array of canonical (sorted-descending)
    rows in ascending lexicographic order; ``cuts`` the matching (G,)
    minimum cut per geometry (minimised over all feasible embeddings).
    """

    dims: Geometry
    t: int
    geometries: np.ndarray
    cuts: np.ndarray

    def __len__(self) -> int:
        return len(self.geometries)

    def geometry(self, i: int) -> Geometry:
        """The i-th canonical geometry as a plain tuple."""
        return tuple(int(x) for x in self.geometries[i])

    def items(self) -> List[Tuple[Geometry, int]]:
        """(geometry, cut) pairs in the table's lexicographic row order."""
        return [(self.geometry(i), int(self.cuts[i])) for i in range(len(self))]

    def min_cut_geometry(self) -> Tuple[Geometry, int]:
        """Lexicographically-smallest geometry attaining the minimum cut."""
        i = int(np.nonzero(self.cuts == self.cuts.min())[0][0])
        return self.geometry(i), int(self.cuts[i])

    def max_cut_geometry(self) -> Tuple[Geometry, int]:
        """Lexicographically-largest geometry attaining the maximum cut."""
        i = int(np.nonzero(self.cuts == self.cuts.max())[0][-1])
        return self.geometry(i), int(self.cuts[i])


def cut_table(torus_or_dims, t: int, backend: Optional[str] = None) -> CutTable:
    """Batched exact cuts of *all* cuboid geometries of volume ``t``.

    One divisor-meshgrid enumeration of every aligned embedding, one
    vectorized closed-form cut evaluation (a side ``s`` embedded in torus
    dimension ``a`` contributes ``0`` if ``s == a`` else ``2 t / s``), one
    group-by-canonical-geometry minimisation — no per-cuboid Python loop.
    The per-geometry values equal :func:`repro.network.geometry.cuboid_cut`
    exactly (property-pinned against the reference oracle).
    ``backend="xla"`` evaluates the closed-form cut scores in the compiled
    backend (int64 arithmetic — identical values); the divisor enumeration
    and group-by stay host-side.

    On a :class:`~repro.network.fabric.HyperXFabric` the same enumeration
    runs with the Hamming aligned-box cut closed form
    (:func:`repro.network.hamming.hamming_cut_aligned`, evaluated
    host-side — the xla scorer is the torus closed form):

    >>> from .fabric import HyperXFabric
    >>> cut_table(HyperXFabric((4, 4)), 4).items()
    [((2, 2), 16), ((4, 1), 12)]

    >>> cut_table((4, 4, 2), 8).items()
    [((2, 2, 2), 16), ((4, 2, 1), 16)]
    """
    if t < 1:
        raise ValueError(f"t must be >= 1, got {t}")
    if isinstance(torus_or_dims, HyperXFabric):
        fab = torus_or_dims
        a = fab.dims
        S = _aligned_assignments(a, t)
        if S.shape[0] == 0:
            return CutTable(a, t, S.reshape(0, len(a)), np.zeros(0, dtype=np.int64))
        av = np.array(a, dtype=np.int64)
        mult = np.array(fab.link_multiplicity, dtype=np.int64)
        # cut of an aligned box = t * sum_k K_k (S_k - c_k): a covered
        # dimension contributes nothing, so cuts *decrease* with side.
        cuts = (t * mult[None, :] * (av[None, :] - S)).sum(axis=1)
    else:
        a = _dims_of(torus_or_dims)
        S = _aligned_assignments(a, t)
        if S.shape[0] == 0:
            return CutTable(a, t, S.reshape(0, len(a)), np.zeros(0, dtype=np.int64))
        av = np.array(a, dtype=np.int64)
        if resolve_backend(backend) == "xla":
            from .backend import xla_cut_scores

            cuts = xla_cut_scores(a, S, t)
        else:
            cuts = np.where(S == av[None, :], 0, (2 * t) // S).sum(axis=1)
    G = -np.sort(-S, axis=1)  # canonical (descending) rows
    # Group by geometry via a positional integer key (base max(a)+1): a 1-D
    # unique on int64 keys, much cheaper than np.unique(axis=0)'s row-view
    # argsort, with the identical ascending-lexicographic row order.
    base = int(av.max()) + 1
    key = G[:, 0].copy()
    for j in range(1, G.shape[1]):
        key = key * base + G[:, j]
    _, index, inv = np.unique(key, return_index=True, return_inverse=True)
    uniq = G[index]
    best = np.full(len(index), np.iinfo(np.int64).max, dtype=np.int64)
    np.minimum.at(best, inv.ravel(), cuts)
    return CutTable(a, t, uniq, best)


def fitting_geometries(torus_or_dims, units: int) -> np.ndarray:
    """All canonical cuboid geometries of ``units`` vertices that fit, as a
    (G, D) int array in ascending lexicographic row order (the batched
    counterpart of :func:`repro.network.geometry.sub_cuboids`)."""
    return cut_table(torus_or_dims, units).geometries


# ---------------------------------------------------------------------------
# Bounds and constructions (paper Theorems 2.1/3.1, Lemma 3.2).
# ---------------------------------------------------------------------------
def bollobas_leader_bound(n: int, D: int, t: int) -> float:
    """Theorem 2.1: lower bound on |E(S, S̄)| for |S| = t in the cubic torus [n]^D."""
    if t < 0 or t > n**D // 2:
        raise ValueError("t must satisfy 0 <= t <= |V|/2")
    if t == 0:
        return 0.0
    best = math.inf
    for r in range(D):
        val = 2.0 * (D - r) * n ** (r / (D - r)) * t ** ((D - r - 1) / (D - r))
        best = min(best, val)
    return best


# theorem31_bound is implemented once in repro.network.geometry (it also
# backs the odd-dimension bisection fallback there) and re-exported here.


def lemma32_cut(dims: Sequence[int], t: int, r: int) -> Optional[Tuple[Geometry, int]]:
    """Lemma 3.2: the explicit cuboid S_r and its exact cut, if it exists.

    S_r fully covers the r smallest dimensions and is a cube of side
    s = (t / k)^(1/(D-r)) in the remaining D-r dimensions, where k is the
    product of the r smallest dims.  Returns ``None`` when s is not an
    integer or S_r does not fit.
    """
    a = canonical(dims)
    D = len(a)
    if not 0 <= r < D:
        raise ValueError(f"r must be in [0, {D}), got {r}")
    k = math.prod(a[D - r:]) if r > 0 else 1
    if t % k != 0:
        return None
    q = t // k
    s = round(q ** (1.0 / (D - r)))
    if s ** (D - r) != q:
        return None
    if s > min(a[: D - r]):
        return None  # the cube side must fit in each uncovered dimension
    geometry = canonical((s,) * (D - r) + tuple(a[D - r:]))
    return geometry, cuboid_cut(a, geometry)


# ---------------------------------------------------------------------------
# Optimal / worst cuboids with the Theorem 3.1 certificate.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CuboidOptimum:
    """A min- or max-cut cuboid with its Theorem 3.1 lower bound; ``tight``
    certifies that the cut meets the bound exactly."""

    geometry: Geometry
    cut: int
    bound: float

    @property
    def tight(self) -> bool:
        """Whether the cut achieves the Theorem 3.1 bound (certificate)."""
        return math.isclose(self.cut, self.bound, rel_tol=1e-9)


def _subset_bound(a: Geometry, n: int, t: int) -> float:
    """Theorem 3.1 bound on any size-t subset's cut, via complement symmetry
    for t > n/2: every edge leaving S enters S̄, so cut(S) == cut(S̄) and
    the bound at min(t, n - t) applies."""
    return theorem31_bound(a, min(t, n - t))


def _any_subset_bound(torus_or_dims, n: int, t: int) -> float:
    """Per-fabric lower bound on any size-t subset's cut: Theorem 3.1 on a
    torus, the Lindsey/edge-identity bound on a Hamming graph (exact for
    uniform link multiplicity) — both with complement symmetry built in."""
    if isinstance(torus_or_dims, HyperXFabric):
        return float(
            hamming.hamming_subset_bound(
                torus_or_dims.dims, t, torus_or_dims.link_multiplicity
            )
        )
    return _subset_bound(_dims_of(torus_or_dims), n, t)


def optimal_cuboid(torus_or_dims, t: int) -> Optional[CuboidOptimum]:
    """Exact minimum-cut cuboid of size t inside the torus (Lemma 3.3 optimum).

    Accepts a ``Torus``/``TorusFabric`` or a plain dims tuple.  Returns
    ``None`` when no cuboid of exactly ``t`` vertices fits; raises
    ``ValueError`` for t outside (0, n].  Ties break toward the
    lexicographically-smallest canonical geometry.

    On a :class:`~repro.network.fabric.HyperXFabric` the certificate is
    the Lindsey/edge-identity bound of :mod:`repro.network.hamming`
    (exact under uniform link multiplicity, so ``tight`` still certifies
    against *all* subsets, not just boxes):

    >>> opt = optimal_cuboid((4, 4, 2), 8)
    >>> opt.geometry, opt.cut, opt.tight
    ((2, 2, 2), 16, True)
    """
    a = torus_or_dims if isinstance(torus_or_dims, HyperXFabric) else _dims_of(torus_or_dims)
    n = volume(a.dims if isinstance(a, HyperXFabric) else a)
    if t <= 0 or t > n:
        raise ValueError(f"t must be in (0, {n}], got {t}")
    tbl = cut_table(a, t)
    if len(tbl) == 0:
        return None
    geom, cut = tbl.min_cut_geometry()
    return CuboidOptimum(geom, cut, _any_subset_bound(a, n, t))


def worst_cuboid(torus_or_dims, t: int) -> Optional[CuboidOptimum]:
    """Maximum-cut cuboid of size t — the adversarial partition geometry.

    Validation matches :func:`optimal_cuboid` (``ValueError`` outside
    (0, n]; the historical version silently returned ``None``), and the
    bound uses complement symmetry for t > n/2, so ``tight`` is a real
    certificate instead of being vacuously True there.
    """
    a = torus_or_dims if isinstance(torus_or_dims, HyperXFabric) else _dims_of(torus_or_dims)
    n = volume(a.dims if isinstance(a, HyperXFabric) else a)
    if t <= 0 or t > n:
        raise ValueError(f"t must be in (0, {n}], got {t}")
    tbl = cut_table(a, t)
    if len(tbl) == 0:
        return None
    geom, cut = tbl.max_cut_geometry()
    return CuboidOptimum(geom, cut, _any_subset_bound(a, n, t))


def small_set_expansion(torus_or_dims, t: int) -> float:
    """h_t(G) over cuboid witnesses: min_{|A|<=t} cut(A) / (interior(A)+cut(A)).

    By the regularity identity (Eq. 1), interior(A) = (k|A| - cut(A)) / 2,
    so the witness expansion 2·cut / (k|A| + cut) is monotone in the cut and
    only the batched per-size *minimum* cuts are needed — the historical
    version walked every cuboid of every size.
    """
    a = _dims_of(torus_or_dims)
    if t < 1:
        raise ValueError(f"t must be >= 1, got {t}")
    k = degree(a)
    best = math.inf
    for size in range(1, t + 1):
        tbl = cut_table(a, size)
        if len(tbl) == 0:
            continue
        cut = int(tbl.cuts.min())
        denom = k * size + cut
        if denom == 0:
            continue
        best = min(best, 2.0 * cut / denom)
    return best


# ---------------------------------------------------------------------------
# Internal bisection of same-volume geometries (the allocator's ranking).
# ---------------------------------------------------------------------------
def bisection_of_geometry(dims: Sequence[int]) -> int:
    """Internal bisection (links) of a fully-wrapped torus partition with the
    given dims — engine-backed, exactly equal to
    :func:`repro.network.geometry.bisection_links` (property-pinned)."""
    a = canonical(dims)
    n = volume(a)
    if n == 1:
        return 0
    L = a[0]
    if L % 2 == 0:
        return 2 * n // L
    if L == 1:
        return 0
    tbl = cut_table(a, n // 2)
    if len(tbl) == 0:
        # No cuboid of size exactly floor(n/2); analytic fallback, matching
        # geometry.bisection_links.
        return math.ceil(theorem31_bound(a, n // 2))
    return int(tbl.cuts.min())


def scaled_node_dims(
    geometry: Sequence[int], unit_node_dims: Optional[Sequence[int]] = None
) -> Geometry:
    """Node-level torus dims of a partition: each allocation-unit dimension
    scales the node torus; extra unit dims (e.g. the Blue Gene/Q internal
    length-2 fifth dimension) are appended.  Identity when
    ``unit_node_dims`` is None; a unit with *fewer* dims than the geometry
    is an error (it would silently drop allocation dimensions)."""
    g = canonical(geometry)
    if unit_node_dims is None:
        return g
    unit = tuple(int(u) for u in unit_node_dims)
    if len(unit) < len(g):
        raise ValueError(
            f"unit_node_dims {unit} has fewer dims than geometry {g}; every "
            f"allocation-unit dimension needs a node-scale factor"
        )
    scaled = tuple(gi * u for gi, u in zip(g, unit[: len(g)]))
    return canonical(scaled + unit[len(g):])


@dataclass(frozen=True)
class BisectionTable:
    """Internal bisection of every cuboid geometry of one volume fitting a
    machine torus — the quantity the paper's allocation policies rank by.

    ``geometries`` is the (G, D) canonical row array of
    :func:`fitting_geometries`; ``bisections`` the matching internal
    bisection in links of each geometry as its own fully-wrapped torus,
    evaluated at node level when the table was built with
    ``unit_node_dims`` (the paper's Tables 4-7 convention).
    """

    dims: Geometry
    units: int
    geometries: np.ndarray
    bisections: np.ndarray
    unit_node_dims: Optional[Geometry] = None

    def __len__(self) -> int:
        return len(self.geometries)

    def _geometry(self, i: int) -> Geometry:
        return tuple(int(x) for x in self.geometries[i])

    def best(self) -> Tuple[Geometry, int]:
        """Max-bisection geometry (lexicographically smallest on ties —
        the :meth:`repro.core.bgq.BlueGeneQ.best_partition` tie-break)."""
        i = int(np.nonzero(self.bisections == self.bisections.max())[0][0])
        return self._geometry(i), int(self.bisections[i])

    def worst(self) -> Tuple[Geometry, int]:
        """Min-bisection geometry (lexicographically largest on ties —
        the adversarial baseline)."""
        i = int(np.nonzero(self.bisections == self.bisections.min())[0][-1])
        return self._geometry(i), int(self.bisections[i])

    def bisection_of(self, geometry: Sequence[int]) -> int:
        """Bisection of one geometry in the table; ValueError if absent.
        Unit dims are normalised away, so ``(2, 2, 1)`` on a 2-D machine
        matches the ``(2, 2)`` row."""
        g = tuple(x for x in canonical(geometry) if x > 1)
        if len(g) > len(self.dims):
            raise ValueError(
                f"geometry {tuple(geometry)} is not a fitting {self.units}-unit "
                f"cuboid of {self.dims}"
            )
        row = np.array(g + (1,) * (len(self.dims) - len(g)), dtype=np.int64)
        hits = np.nonzero((self.geometries == row[None, :]).all(axis=1))[0]
        if len(hits) == 0:
            raise ValueError(
                f"geometry {tuple(geometry)} is not a fitting {self.units}-unit "
                f"cuboid of {self.dims}"
            )
        return int(self.bisections[hits[0]])

    def ranked(self) -> List[Tuple[Geometry, int]]:
        """(geometry, bisection) pairs, best bisection first, ties toward
        the lexicographically-smallest geometry."""
        pairs = [(self._geometry(i), int(self.bisections[i])) for i in range(len(self))]
        pairs.sort(key=lambda p: (-p[1], p[0]))
        return pairs


def bisection_table(
    torus_or_dims,
    units: int,
    unit_node_dims: Optional[Sequence[int]] = None,
) -> BisectionTable:
    """Batched internal bisections of every ``units``-sized geometry.

    Even-longest-dimension geometries (after node scaling, every Blue
    Gene/Q partition) are closed-form ``2N/L`` in one vectorized pass; odd
    longest dimensions fall back to the engine's exact cuboid search per
    geometry.  Raises ``ValueError`` when no cuboid of that size fits.

    On a :class:`~repro.network.fabric.HyperXFabric` each box is its own
    Hamming graph (:meth:`HyperXFabric.sub_fabric` — multiplicities
    inherited tightest-fit), so its internal bisection comes from the
    exact Lindsey half-set cut; ``unit_node_dims`` node scaling is the
    BG/Q torus convention and is rejected there.

    >>> from .fabric import HyperXFabric
    >>> bisection_table(HyperXFabric((16, 4)), 16).ranked()
    [((16, 1), 64), ((4, 4), 16), ((8, 2), 8)]
    """
    if isinstance(torus_or_dims, HyperXFabric):
        if unit_node_dims is not None:
            raise ValueError(
                "unit_node_dims is the BG/Q torus node-scaling convention; "
                "HyperX fabrics rank allocation-unit boxes directly"
            )
        fab = torus_or_dims
        geoms = cut_table(fab, units).geometries
        if geoms.shape[0] == 0:
            raise ValueError(f"no box of {units} units fits in H{fab.dims}")
        bis = np.array(
            [
                fab.sub_fabric(tuple(int(x) for x in g)).bisection_links()
                for g in geoms
            ],
            dtype=np.int64,
        )
        return BisectionTable(fab.dims, units, geoms, bis, None)
    a = _dims_of(torus_or_dims)
    geoms = fitting_geometries(a, units)
    if geoms.shape[0] == 0:
        raise ValueError(f"no cuboid of {units} units fits in {a}")
    unit = None if unit_node_dims is None else tuple(int(u) for u in unit_node_dims)
    if unit is not None and len(unit) < len(a):
        raise ValueError(
            f"unit_node_dims {unit} has fewer dims than the machine {a}; every "
            f"allocation-unit dimension needs a node-scale factor"
        )
    if unit is None:
        node = geoms
        n_total = units
        extras_max = 0
    else:
        uvec = np.array(unit[: geoms.shape[1]], dtype=np.int64)
        node = geoms * uvec[None, :]
        extras = unit[geoms.shape[1]:]
        extras_max = max(extras, default=0)
        n_total = units * math.prod(unit)
    L = np.maximum(node.max(axis=1), extras_max)
    bis = np.zeros(len(geoms), dtype=np.int64)
    even = (L % 2 == 0) & (L > 1)
    bis[even] = 2 * n_total // L[even]
    odd = (~even) & (L > 1)
    for i in np.nonzero(odd)[0]:
        if unit is None:
            bis[i] = bisection_of_geometry(tuple(int(x) for x in geoms[i]))
        else:
            bis[i] = bisection_of_geometry(
                scaled_node_dims(tuple(int(x) for x in geoms[i]), unit)
            )
    return BisectionTable(a, units, geoms, bis, unit)


def ranked_geometries(
    torus_or_dims,
    units: int,
    unit_node_dims: Optional[Sequence[int]] = None,
) -> List[Tuple[Geometry, int]]:
    """All fitting geometries of a size as (geometry, bisection_links)
    pairs, best internal bisection first — the batched replacement for
    sorting :func:`repro.network.geometry.sub_cuboids` by per-geometry
    ``bisection_links`` calls (identical ordering, property-pinned)."""
    return bisection_table(torus_or_dims, units, unit_node_dims).ranked()


def best_bisection_geometry(
    torus_or_dims, units: int, unit_node_dims: Optional[Sequence[int]] = None
) -> Tuple[Geometry, int]:
    """The fitting geometry with maximal internal bisection (links)."""
    return bisection_table(torus_or_dims, units, unit_node_dims).best()


def worst_bisection_geometry(
    torus_or_dims, units: int, unit_node_dims: Optional[Sequence[int]] = None
) -> Tuple[Geometry, int]:
    """The fitting geometry with minimal internal bisection — the
    adversarial baseline of the avoidable-contention ratio."""
    return bisection_table(torus_or_dims, units, unit_node_dims).worst()


def is_isoperimetrically_optimal(
    torus_or_dims,
    geometry: Sequence[int],
    unit_node_dims: Optional[Sequence[int]] = None,
) -> bool:
    """Theorem 3.1 optimality check: does this partition geometry attain the
    maximal internal bisection among all same-volume cuboids that fit the
    machine?  (The paper's criterion for a scheduler's geometry table.)"""
    tbl = bisection_table(torus_or_dims, volume(geometry), unit_node_dims)
    return tbl.bisection_of(geometry) == tbl.best()[1]


# ---------------------------------------------------------------------------
# The partition advisor (paper Tables 4-6 as a decision aid).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PartitionAdvice:
    """Current-policy vs isoperimetric-optimal geometry for one job size.

    Bisections are in links (node-level when the advisor was given
    ``unit_node_dims``); ``predicted_speedup`` is the pairing-benchmark
    time ratio current/optimal (the paper's Tables 4-6 / Figures 3-4
    quantity), ``simulated_speedup`` the flow-simulated makespan ratio
    when the advisor ran with ``simulate=True``; ``bound`` is the Theorem
    3.1 floor on the optimal geometry's bisection *cut*, so ``certified``
    means the optimum's bisection is pinned analytically, not only by
    exhaustive search.
    """

    units: int
    current_geometry: Geometry
    current_bisection: int
    optimal_geometry: Geometry
    optimal_bisection: int
    bound: float
    predicted_speedup: float
    simulated_speedup: Optional[float] = None

    @property
    def bisection_efficiency(self) -> float:
        """current / optimal internal bisection (1.0 when already optimal)."""
        if self.optimal_bisection == 0:
            return 1.0
        return self.current_bisection / self.optimal_bisection

    @property
    def is_current_optimal(self) -> bool:
        """Whether the current geometry already attains the optimum."""
        return self.current_bisection == self.optimal_bisection

    @property
    def certified(self) -> bool:
        """Whether Theorem 3.1 certifies the optimum's bisection exactly."""
        return math.isclose(self.optimal_bisection, self.bound, rel_tol=1e-9)


def advise_partition(
    torus_or_dims,
    units: int,
    current_geometry: Optional[Sequence[int]] = None,
    *,
    unit_node_dims: Optional[Sequence[int]] = None,
    simulate: bool = False,
    backend: Optional[str] = None,
) -> PartitionAdvice:
    """Advise one job size: current (or worst, when None) vs optimal geometry.

    The predicted speedup is the static pairing-benchmark ratio
    (:func:`repro.network.routing.pairing_speedup` on the node-level dims);
    ``simulate=True`` additionally drains the pairing benchmark of both
    geometries through the flow-level simulator and reports the measured
    makespan ratio — for these translation-invariant patterns the two
    agree exactly (the §7 validation property), so a divergence flags a
    modeling bug rather than a worse prediction.

    On a :class:`~repro.network.fabric.HyperXFabric` the contention
    benchmark is all-to-all inside the box rather than bisection pairing
    — HyperX dimensions have diameter 1, so pairing never contends and
    cannot separate geometries; all-to-all stresses the internal
    bisection exactly as the paper's benchmark does on a torus.  The
    certificate is the Lindsey bound on the optimum's half-set cut.

    >>> from .fabric import HyperXFabric
    >>> adv = advise_partition(HyperXFabric((16, 4)), 16, (4, 4))
    >>> adv.optimal_geometry, adv.current_bisection, adv.optimal_bisection
    ((16, 1), 16, 64)
    >>> adv.predicted_speedup, adv.is_current_optimal, adv.certified
    (4.0, False, True)

    >>> adv = advise_partition((4, 4, 3, 2), 4, (4, 1, 1, 1),
    ...                        unit_node_dims=(4, 4, 4, 4, 2))
    >>> adv.optimal_geometry, adv.current_bisection, adv.optimal_bisection
    ((2, 2, 1, 1), 256, 512)
    >>> round(adv.predicted_speedup, 2), adv.is_current_optimal, adv.certified
    (2.0, False, True)
    """
    if isinstance(torus_or_dims, HyperXFabric):
        return _advise_hyperx(
            torus_or_dims,
            units,
            current_geometry,
            unit_node_dims=unit_node_dims,
            simulate=simulate,
            backend=backend,
        )
    from .routing import pairing_speedup  # lazy: keeps this module geometry-only

    a = _dims_of(torus_or_dims)
    tbl = bisection_table(a, units, unit_node_dims)
    opt_geom, opt_bis = tbl.best()
    if current_geometry is None:
        cur_geom, cur_bis = tbl.worst()
    else:
        cur_geom = canonical(
            tuple(current_geometry) + (1,) * (len(a) - len(tuple(current_geometry)))
        )
        if volume(cur_geom) != units:
            raise ValueError(
                f"current geometry {cur_geom} has volume {volume(cur_geom)}, "
                f"expected {units}"
            )
        cur_bis = tbl.bisection_of(cur_geom)
    nd_cur = scaled_node_dims(cur_geom, unit_node_dims)
    nd_opt = scaled_node_dims(opt_geom, unit_node_dims)
    predicted = pairing_speedup(nd_cur, nd_opt)
    simulated: Optional[float] = None
    if simulate:
        from .netsim import simulate_traffic
        from .patterns import bisection_pairing

        t_cur = simulate_traffic(
            nd_cur, bisection_pairing(nd_cur), backend=backend
        ).makespan
        t_opt = simulate_traffic(
            nd_opt, bisection_pairing(nd_opt), backend=backend
        ).makespan
        simulated = t_cur / t_opt
    n_nodes = volume(nd_opt)
    return PartitionAdvice(
        units=units,
        current_geometry=cur_geom,
        current_bisection=cur_bis,
        optimal_geometry=opt_geom,
        optimal_bisection=opt_bis,
        bound=theorem31_bound(nd_opt, n_nodes // 2),
        predicted_speedup=predicted,
        simulated_speedup=simulated,
    )


def _advise_hyperx(
    fab: HyperXFabric,
    units: int,
    current_geometry: Optional[Sequence[int]],
    *,
    unit_node_dims: Optional[Sequence[int]],
    simulate: bool,
    backend: Optional[str],
) -> PartitionAdvice:
    """HyperX body of :func:`advise_partition`: rank boxes by internal
    Hamming bisection, predict the all-to-all contention ratio with the
    closed form, certify with the Lindsey half-set bound."""
    from .routing import hyperx_all_to_all_max_load

    tbl = bisection_table(fab, units, unit_node_dims)  # rejects node scaling
    opt_geom, opt_bis = tbl.best()
    if current_geometry is None:
        cur_geom, cur_bis = tbl.worst()
    else:
        cur_geom = canonical(
            tuple(current_geometry) + (1,) * (len(fab.dims) - len(tuple(current_geometry)))
        )
        if volume(cur_geom) != units:
            raise ValueError(
                f"current geometry {cur_geom} has volume {volume(cur_geom)}, "
                f"expected {units}"
            )
        cur_bis = tbl.bisection_of(cur_geom)
    sub_cur = fab.sub_fabric(cur_geom)
    sub_opt = fab.sub_fabric(opt_geom)
    load_cur = hyperx_all_to_all_max_load(sub_cur)
    load_opt = hyperx_all_to_all_max_load(sub_opt)
    predicted = load_cur / load_opt if load_opt > 0.0 else 1.0
    simulated: Optional[float] = None
    if simulate:
        from .netsim import simulate_fabric_traffic
        from .patterns import all_to_all

        t_cur = simulate_fabric_traffic(
            sub_cur, all_to_all(sub_cur.dims), backend=backend
        ).makespan
        t_opt = simulate_fabric_traffic(
            sub_opt, all_to_all(sub_opt.dims), backend=backend
        ).makespan
        simulated = t_cur / t_opt if t_opt > 0.0 else 1.0
    return PartitionAdvice(
        units=units,
        current_geometry=cur_geom,
        current_bisection=cur_bis,
        optimal_geometry=opt_geom,
        optimal_bisection=opt_bis,
        bound=float(
            hamming.hamming_subset_bound(
                sub_opt.dims, units // 2, sub_opt.link_multiplicity
            )
        ),
        predicted_speedup=predicted,
        simulated_speedup=simulated,
    )


def advise_policy_table(
    torus_or_dims,
    policy_table: Mapping[int, Sequence[int]],
    *,
    unit_node_dims: Optional[Sequence[int]] = None,
    simulate: bool = False,
    sizes: Optional[Sequence[int]] = None,
    backend: Optional[str] = None,
) -> List[PartitionAdvice]:
    """Advise every size of an allocation policy's admissible geometry table
    (e.g. Mira's scheduler partition list from :mod:`repro.core.bgq`):
    one :class:`PartitionAdvice` per size, ascending."""
    chosen = sorted(policy_table) if sizes is None else [s for s in sizes if s in policy_table]
    return [
        advise_partition(
            torus_or_dims,
            size,
            policy_table[size],
            unit_node_dims=unit_node_dims,
            simulate=simulate,
            backend=backend,
        )
        for size in chosen
    ]
