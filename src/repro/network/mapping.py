"""Topology-aware rank mapping inside an allocated placement.

PR 1–2 model what the *allocator* controls: which cuboid geometry a job
gets and where it lands.  This module models what the *mapping* controls:
which rank of the job's logical process grid runs on which cell of the
allocated cuboid.  Every consumer historically assumed row-major rank
order; Glantz et al. (grid/torus process mapping) and Ahrens (contiguous
partitioning for bottleneck communication) show that congestion- and
dilation-aware embeddings recover much of the bottleneck that remains
after a good (or is forced by a bad) partition geometry.

Objects and conventions
-----------------------
* A **mapping** is an (n, D) int array ``coords``: machine-torus
  coordinates of each rank, rank index = row.  Ranks of a logical process
  grid are raveled row-major (C order) over ``logical_dims``.
* **Traffic** is rank-space: ``(src_rank, dst_rank, vol)`` arrays, volumes
  in the same abstract bytes-per-phase units the routing engine uses
  (:mod:`repro.network.routing`).  :func:`pattern_traffic` builds the
  standard workloads from :mod:`repro.network.patterns` in rank space.
* Two scores, both computed batched in NumPy (no per-hop Python):

  - **congestion** — max per-physical-link load of the mapped traffic
    routed on the *machine* torus by the DOR engine (links, not
    bandwidth; double links halve under the BG/Q convention);
  - **dilation** — total volume-weighted hop count
    ``sum_m vol_m * hops(src_m, dst_m)`` (minimal toroidal distance —
    exactly the hops DOR takes).

  Candidates are ranked lexicographically: congestion first (the
  completion-time bound), dilation second (total fabric energy/occupancy).

Strategy catalogue (:func:`map_ranks` evaluates all and picks the best):

* ``identity``          — row-major rank order over the oriented cuboid:
  the implicit status quo of every consumer, kept as the baseline.
* ``axis-permutation``  — all axis orders x orientations (reversals) of
  the cuboid's enumeration, deduplicated over unit dims.  Recovers e.g.
  a logical (8, 2) halo grid laid across a physical (2, 8) slice.
* ``gray-snake``        — boustrophedon (reflected-Gray-code) cell order:
  consecutive ranks are physically adjacent, the right order for ring
  collectives on slices without wrap.
* ``greedy``            — a congestion-refinement pass seeded from the
  best of the above: steepest-descent rank swaps among the heaviest
  communicators, loads delta-updated per swap.

The per-hop oracle lives in ``tests/reference_mapping.py``; property tests
pin the vectorized scorer to it, and ``benchmarks/bench_mapping.py``
anchors the speedup claim (emits ``BENCH_mapping.json``).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .backend import resolve_backend
from .geometry import volume
from .routing import max_link_load, route_dor

Coord = Tuple[int, ...]
RankTraffic = Tuple[np.ndarray, np.ndarray, np.ndarray]

#: Patterns understood by :func:`pattern_traffic`, in rank space.
MAPPING_PATTERNS = ("halo", "pairing", "ring", "all-to-all")


# ---------------------------------------------------------------------------
# Rank-space traffic.
# ---------------------------------------------------------------------------
def pattern_traffic(
    logical_dims: Sequence[int], pattern: str = "halo", vol: float = 1.0
) -> RankTraffic:
    """Named workload on the logical process grid, in rank space.

    ``(src_rank, dst_rank, vol)`` with ranks raveled row-major over
    ``logical_dims``.  Patterns: ``"halo"`` (nearest-neighbour exchange on
    the logical grid), ``"pairing"`` (the paper's antipodal benchmark),
    ``"ring"`` (each rank exchanges with rank +-1 mod n — ring-collective
    step traffic, defined on rank order, not logical coordinates), and
    ``"all-to-all"`` (mapping-invariant by construction; useful as a
    sanity control).  Volumes are uniform, ``vol`` per message.
    """
    logical_dims = tuple(int(a) for a in logical_dims)
    n = volume(logical_dims)
    if pattern == "ring":
        if n <= 1:
            empty = np.zeros(0, dtype=np.int64)
            return empty, empty.copy(), np.zeros(0)
        r = np.arange(n, dtype=np.int64)
        src = np.concatenate([r, r])
        dst = np.concatenate([(r + 1) % n, (r - 1) % n])
        return src, dst, np.full(2 * n, float(vol))
    from . import patterns

    builders = {
        "halo": patterns.nearest_neighbor_halo,
        "pairing": patterns.bisection_pairing,
        "all-to-all": patterns.all_to_all,
    }
    if pattern not in builders:
        raise ValueError(
            f"unknown mapping pattern {pattern!r}; expected one of {MAPPING_PATTERNS}"
        )
    s, d, v = builders[pattern](logical_dims, vol)
    if s.shape[0] == 0:
        empty = np.zeros(0, dtype=np.int64)
        return empty, empty.copy(), np.zeros(0)
    src = np.ravel_multi_index(tuple(s.T), logical_dims).astype(np.int64)
    dst = np.ravel_multi_index(tuple(d.T), logical_dims).astype(np.int64)
    return src, dst, np.asarray(v, dtype=np.float64)


# ---------------------------------------------------------------------------
# Scoring (the vectorized engine; oracle: tests/reference_mapping.py).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MappingScore:
    """(congestion, dilation) of one mapping under one traffic pattern.

    ``congestion`` — max per-physical-link load (the phase-time bound,
    in traffic-volume units; BG/Q double links halve).  ``dilation`` —
    total volume-weighted hop count over all messages.
    """

    congestion: float
    dilation: float

    def key(self) -> Tuple[float, float]:
        """Lexicographic ranking key, rounded so float noise cannot flip
        the congestion-first comparison (mirrors placement scoring)."""
        return (round(self.congestion, 9), round(self.dilation, 9))


def toroidal_hops(
    dims: Sequence[int],
    src: np.ndarray,
    dst: np.ndarray,
    wrap: Optional[Sequence[bool]] = None,
) -> np.ndarray:
    """Minimal hop count per message: wrap-aware Manhattan distance —
    exactly the links a minimal DOR route traverses on the torus.

    ``wrap`` marks which machine dimensions actually have their
    wrap-around link (default: all, the torus the routing engine models);
    an unwrapped dimension contributes the plain ``|src - dst|`` chain
    distance, since the short way around does not physically exist."""
    d = np.asarray(tuple(int(a) for a in dims), dtype=np.int64)
    delta = np.abs(np.atleast_2d(src) - np.atleast_2d(dst))
    around = np.minimum(delta, d - delta)
    if wrap is not None:
        w = np.asarray(tuple(bool(x) for x in wrap), dtype=bool)
        around = np.where(w, around, delta)
    return around.sum(axis=1)


def mapping_traffic(coords: np.ndarray, traffic: RankTraffic) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Rank-space traffic translated into machine coordinates by a mapping.

    ``(src, dst, vol)`` with endpoints ``coords[src_rank]`` /
    ``coords[dst_rank]`` — the message-level counterpart of
    :func:`mapping_loads`, ready for the flow simulator
    (:mod:`repro.network.netsim`) or any other consumer that needs
    concrete endpoints rather than a routed load tensor."""
    rsrc, rdst, vol = traffic
    if rsrc.shape[0] == 0:
        empty = np.zeros((0, coords.shape[1]), dtype=np.int64)
        return empty, empty.copy(), np.zeros(0)
    return coords[rsrc], coords[rdst], np.asarray(vol, dtype=np.float64)


def mapping_loads(
    dims: Sequence[int],
    coords: np.ndarray,
    traffic: RankTraffic,
    split_ties: bool = True,
) -> np.ndarray:
    """(D, 2, *dims) link-load tensor of the mapped rank traffic on the
    machine torus (the mapped analogue of
    :func:`repro.network.placement.placement_loads`)."""
    dims = tuple(int(a) for a in dims)
    rsrc, rdst, vol = traffic
    if rsrc.shape[0] == 0:
        return np.zeros((len(dims), 2) + dims)
    return route_dor(dims, coords[rsrc], coords[rdst], vol, split_ties=split_ties)


def score_mapping(
    dims: Sequence[int],
    coords: np.ndarray,
    traffic: RankTraffic,
    split_ties: bool = True,
    double_link_on_2: bool = True,
    backend: Optional[str] = None,
) -> MappingScore:
    """Score one mapping: route the rank traffic on the machine torus with
    the vectorized DOR engine and reduce to (congestion, dilation).

    ``coords`` is the (n, D) rank->cell array; ``traffic`` is rank-space
    ``(src_rank, dst_rank, vol)``.  One ``route_dor`` call — O(M + N)
    array work for M messages on an N-cell machine — plus an O(M)
    closed-form dilation; the per-hop oracle in
    ``tests/reference_mapping.py`` pins both numbers.
    """
    dims = tuple(int(a) for a in dims)
    rsrc, rdst, vol = traffic
    if rsrc.shape[0] == 0:
        return MappingScore(0.0, 0.0)
    src = coords[rsrc]
    dst = coords[rdst]
    loads = route_dor(dims, src, dst, vol, split_ties=split_ties, backend=backend)
    congestion = max_link_load(dims, loads, double_link_on_2)
    dilation = float((np.asarray(vol) * toroidal_hops(dims, src, dst)).sum())
    return MappingScore(congestion, dilation)


# ---------------------------------------------------------------------------
# Cell enumerations (the structured strategies).
# ---------------------------------------------------------------------------
def placement_cell_coords(
    dims: Sequence[int], oriented: Sequence[int], offset: Coord
) -> np.ndarray:
    """(n, D) machine coordinates of the placement's cells in row-major
    (C) order over ``oriented`` — the identity mapping's coords."""
    dims = tuple(int(a) for a in dims)
    oriented = tuple(int(w) for w in oriented)
    n = volume(oriented)
    rel = np.stack(np.unravel_index(np.arange(n), oriented), axis=1).astype(np.int64)
    off = np.asarray(offset, dtype=np.int64)
    return (rel + off) % np.asarray(dims, dtype=np.int64)


def identity_mapping(
    dims: Sequence[int], oriented: Sequence[int], offset: Coord
) -> np.ndarray:
    """Row-major rank order over the oriented cuboid — the implicit status
    quo of every consumer before this module, kept as the baseline."""
    return placement_cell_coords(dims, oriented, offset)


def axis_permutation_orders(
    oriented: Sequence[int],
) -> Iterator[Tuple[Tuple[int, ...], Tuple[bool, ...]]]:
    """All distinct (axis order, per-axis reversal) enumerations of the
    cuboid, deduplicated: unit dims neither reorder nor reverse, so a
    (1, 4, 1) cuboid yields exactly 2 candidates, not 48."""
    oriented = tuple(int(w) for w in oriented)
    D = len(oriented)
    seen = set()
    for perm in itertools.permutations(range(D)):
        for rev in itertools.product((False, True), repeat=D):
            key = tuple((p, rev[p]) for p in perm if oriented[p] > 1)
            if key in seen:
                continue
            seen.add(key)
            yield perm, rev


def axis_order_coords(
    dims: Sequence[int],
    oriented: Sequence[int],
    offset: Coord,
    perm: Sequence[int],
    reverse: Sequence[bool],
) -> np.ndarray:
    """Cells enumerated with axis ``perm[0]`` slowest / ``perm[-1]``
    fastest, axis k reversed where ``reverse[k]``; rank r gets the r-th
    cell.  ``perm = (0, 1, ..)`` with no reversal is the identity."""
    dims = tuple(int(a) for a in dims)
    oriented = tuple(int(w) for w in oriented)
    n = volume(oriented)
    shape = tuple(oriented[p] for p in perm)
    in_perm = np.stack(np.unravel_index(np.arange(n), shape), axis=1).astype(np.int64)
    rel = np.empty((n, len(dims)), dtype=np.int64)
    for i, p in enumerate(perm):
        c = in_perm[:, i]
        if reverse[p]:
            c = oriented[p] - 1 - c
        rel[:, p] = c
    off = np.asarray(offset, dtype=np.int64)
    return (rel + off) % np.asarray(dims, dtype=np.int64)


def snake_mapping(
    dims: Sequence[int], oriented: Sequence[int], offset: Coord
) -> np.ndarray:
    """Boustrophedon (reflected-Gray-code) cell order: each axis reverses
    direction whenever the parity of the preceding snaked coordinates is
    odd, so consecutive ranks always occupy physically adjacent cells — a
    Hamiltonian path through the cuboid, the right enumeration for ring
    collectives on slices without wrap-around."""
    dims = tuple(int(a) for a in dims)
    oriented = tuple(int(w) for w in oriented)
    n = volume(oriented)
    rel = np.stack(np.unravel_index(np.arange(n), oriented), axis=1).astype(np.int64)
    out = rel.copy()
    parity = np.zeros(n, dtype=np.int64)
    for k, w in enumerate(oriented):
        flip = parity % 2 == 1
        out[:, k] = np.where(flip, w - 1 - rel[:, k], rel[:, k])
        parity = parity + out[:, k]
    off = np.asarray(offset, dtype=np.int64)
    return (out + off) % np.asarray(dims, dtype=np.int64)


# ---------------------------------------------------------------------------
# Greedy congestion refinement.
# ---------------------------------------------------------------------------
def greedy_refine(
    dims: Sequence[int],
    coords: np.ndarray,
    traffic: RankTraffic,
    split_ties: bool = True,
    double_link_on_2: bool = True,
    max_rounds: int = 3,
    max_ranks: int = 12,
    backend: Optional[str] = None,
) -> Tuple[np.ndarray, MappingScore, bool]:
    """Steepest-descent rank-swap refinement of a seed mapping.

    Per round: take the ``max_ranks`` ranks with the largest
    volume-weighted incident hop count (the heaviest communicators), try
    every unordered swap among them, and apply the single best swap that
    lexicographically lowers (congestion, dilation).  Load tensors are
    delta-updated — only the swapped ranks' incident messages are
    re-routed — so one round is O(max_ranks^2 * (N + m_inc)), not a full
    re-score per candidate.  Deterministic; returns
    ``(coords, score, improved)``.

    ``backend`` applies to the full-pattern route and the final re-score;
    the inner delta updates are small irregular calls that always run in
    NumPy (dispatch overhead would dominate — see DESIGN.md).
    """
    dims = tuple(int(a) for a in dims)
    rsrc, rdst, vol = traffic
    coords = np.array(coords, dtype=np.int64)
    if rsrc.shape[0] == 0 or coords.shape[0] < 2:
        return coords, score_mapping(
            dims, coords, traffic, split_ties, double_link_on_2, backend=backend
        ), False

    vol = np.asarray(vol, dtype=np.float64)
    loads = route_dor(
        dims, coords[rsrc], coords[rdst], vol, split_ties=split_ties, backend=backend
    )
    hops = toroidal_hops(dims, coords[rsrc], coords[rdst])
    score = MappingScore(
        max_link_load(dims, loads, double_link_on_2),
        float((vol * hops).sum()),
    )

    n = coords.shape[0]
    improved_any = False
    for _ in range(max_rounds):
        # Heaviest communicators: volume-weighted incident hops per rank.
        whops = vol * toroidal_hops(dims, coords[rsrc], coords[rdst])
        per_rank = np.bincount(rsrc, weights=whops, minlength=n) + np.bincount(
            rdst, weights=whops, minlength=n
        )
        cand = np.argsort(-per_rank, kind="stable")[: min(max_ranks, n)]
        best_swap = None
        for i, j in itertools.combinations(sorted(int(c) for c in cand), 2):
            inc = (rsrc == i) | (rdst == i) | (rsrc == j) | (rdst == j)
            if not inc.any():
                continue
            old = route_dor(
                dims, coords[rsrc[inc]], coords[rdst[inc]], vol[inc],
                split_ties=split_ties,
            )
            swapped = coords.copy()
            swapped[[i, j]] = swapped[[j, i]]
            new = route_dor(
                dims, swapped[rsrc[inc]], swapped[rdst[inc]], vol[inc],
                split_ties=split_ties,
            )
            trial_loads = np.maximum(loads - old + new, 0.0)
            trial = MappingScore(
                max_link_load(dims, trial_loads, double_link_on_2),
                score.dilation
                - float((vol[inc] * toroidal_hops(dims, coords[rsrc[inc]], coords[rdst[inc]])).sum())
                + float((vol[inc] * toroidal_hops(dims, swapped[rsrc[inc]], swapped[rdst[inc]])).sum()),
            )
            if trial.key() < score.key() and (
                best_swap is None or trial.key() < best_swap[0].key()
            ):
                best_swap = (trial, (i, j), trial_loads)
        if best_swap is None:
            break
        score, (i, j), loads = best_swap
        coords[[i, j]] = coords[[j, i]]
        improved_any = True
    # Re-score from scratch: the delta-updated tensor carries float noise.
    final = score_mapping(
        dims, coords, traffic, split_ties, double_link_on_2, backend=backend
    )
    return coords, final, improved_any


# ---------------------------------------------------------------------------
# The engine's front door.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class RankMapping:
    """A chosen rank->cell embedding and its predicted cost.

    ``coords[r]`` is the machine-torus coordinate of rank r;
    ``logical_dims`` is the logical process grid (ranks raveled row-major
    over it); ``score`` is the winning strategy's (congestion, dilation)
    and ``identity_score`` the row-major baseline's, so
    ``identity_score.congestion - score.congestion`` is the contention
    the mapping recovered without touching the allocation.
    """

    dims: Tuple[int, ...]
    oriented: Tuple[int, ...]
    offset: Coord
    logical_dims: Tuple[int, ...]
    pattern: str
    strategy: str
    coords: np.ndarray
    score: MappingScore
    identity_score: MappingScore
    #: Wrap-around link present per machine dimension (None = fully
    #: wrapped).  The congestion/dilation scores always model the
    #: fully-wrapped torus (the routing engine's domain); these flags make
    #: the *physical* measurements — :func:`mesh_axis_hops` and the
    #: collective pricing built on it — honest about links that do not
    #: exist on partially-wrapped fabrics.
    wrap: Optional[Tuple[bool, ...]] = None
    #: (D, 2, *dims) link-load tensor of the chosen mapping's traffic on
    #: the machine torus (write-locked; what the congestion score reduces)
    #: — consumers reuse it instead of re-routing the pattern.
    loads: Optional[np.ndarray] = None
    #: The scored rank-space traffic itself (``src_rank, dst_rank, vol``)
    #: — kept so message-level consumers (:meth:`machine_traffic`) never
    #: have to reconstruct it, which would be impossible for explicit
    #: traffic (``pattern == "explicit"``).
    rank_traffic: Optional[RankTraffic] = None

    @property
    def num_ranks(self) -> int:
        """Number of ranks (== cells of the placement)."""
        return int(self.coords.shape[0])

    def machine_traffic(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The mapping's scored traffic as machine-coordinate messages
        (``src, dst, vol``) — the message-level counterpart of
        :attr:`loads`, ready for the flow simulator."""
        if self.rank_traffic is None:
            empty = np.zeros((0, len(self.dims)), dtype=np.int64)
            return empty, empty.copy(), np.zeros(0)
        return mapping_traffic(self.coords, self.rank_traffic)

    @property
    def recovered_congestion(self) -> float:
        """Max-link-load reduction vs the row-major baseline (>= 0)."""
        return self.identity_score.congestion - self.score.congestion

    def cell_of_rank(self, rank: int) -> Coord:
        """Machine coordinate of one rank."""
        return tuple(int(x) for x in self.coords[rank])


def map_ranks(
    dims: Sequence[int],
    oriented: Sequence[int],
    offset: Optional[Coord] = None,
    logical_dims: Optional[Sequence[int]] = None,
    pattern: str = "halo",
    traffic: Optional[RankTraffic] = None,
    split_ties: bool = True,
    double_link_on_2: bool = True,
    refine: bool = True,
    wrap: Optional[Sequence[bool]] = None,
    backend: Optional[str] = None,
) -> RankMapping:
    """Choose the best rank->cell embedding for a placed cuboid.

    Evaluates the full strategy catalogue — row-major ``identity``,
    ``axis-permutation`` (all dim orders/orientations, unit dims
    deduplicated), ``gray-snake``, and (with ``refine=True``) a ``greedy``
    congestion-refinement pass seeded from the best of the others — and
    returns the lexicographic (congestion, dilation) winner; ties keep
    the earlier strategy, so identity wins unless something strictly
    helps.

    ``logical_dims`` is the job's logical process grid (default: the
    oriented extents, i.e. a literal relabeling of the cuboid); its
    volume must equal the placement's.  ``traffic`` overrides ``pattern``
    with explicit rank-space ``(src_rank, dst_rank, vol)`` arrays.
    ``wrap`` records which machine dimensions physically have their
    wrap-around link (default: all) — it does not change the DOR-torus
    congestion/dilation scores, but flows to :func:`mesh_axis_hops` so the
    collective pricing never assumes a wrap link that is not there.
    ``backend="xla"`` scores the whole strategy catalogue in one
    ``vmap``-batched compiled call (:func:`repro.network.backend.score_candidates`) —
    scores are exactly those of the sequential loop, so the chosen
    strategy is identical.

    Example — a logical (8, 2) halo grid laid across a (2, 8) slice of a
    (4, 8) torus: row-major rank order folds the logical 8-ring onto the
    short physical axis, stacking its traffic on the row links; the
    axis-permutation search restores the aligned embedding and halves the
    max link load:

    >>> m = map_ranks((4, 8), (2, 8), (0, 0), logical_dims=(8, 2), pattern="halo")
    >>> m.identity_score.congestion, m.score.congestion
    (4.0, 2.0)
    >>> m.strategy
    'axis-permutation'
    """
    dims = tuple(int(a) for a in dims)
    oriented = tuple(int(w) for w in oriented)
    if offset is None:
        offset = (0,) * len(dims)
    offset = tuple(int(o) for o in offset)
    if len(oriented) != len(dims) or any(
        w < 1 or w > a for w, a in zip(oriented, dims)
    ):
        raise ValueError(f"orientation {oriented} does not fit machine {dims}")
    logical = (
        tuple(int(a) for a in logical_dims) if logical_dims is not None else oriented
    )
    if volume(logical) != volume(oriented):
        raise ValueError(
            f"logical grid {logical} has {volume(logical)} ranks; placement "
            f"{oriented} has {volume(oriented)} cells"
        )
    if traffic is None:
        traffic = pattern_traffic(logical, pattern)
    else:
        pattern = "explicit"

    ident = identity_mapping(dims, oriented, offset)
    cand_list: List[Tuple[str, np.ndarray]] = [("identity", ident)]
    for perm, rev in axis_permutation_orders(oriented):
        if all(p == i for i, p in enumerate(perm)) and not any(rev):
            continue  # the identity enumeration, already scored
        coords = axis_order_coords(dims, oriented, offset, perm, rev)
        cand_list.append(("axis-permutation", coords))
    snake = snake_mapping(dims, oriented, offset)
    cand_list.append(("gray-snake", snake))

    if resolve_backend(backend) == "xla" and traffic[0].shape[0]:
        # One vmap-batched compiled call over the whole strategy catalogue;
        # scores are row-identical to the sequential loop (property-pinned),
        # so the lexicographic winner cannot change.
        from .backend import score_candidates

        cong, dil = score_candidates(
            dims,
            np.stack([c for _, c in cand_list]),
            traffic,
            split_ties,
            double_link_on_2,
            backend="xla",
        )
        candidates = [
            (name, c, MappingScore(float(cg), float(dl)))
            for (name, c), cg, dl in zip(cand_list, cong, dil)
        ]
    else:
        candidates = [
            (name, c, score_mapping(dims, c, traffic, split_ties, double_link_on_2))
            for name, c in cand_list
        ]
    identity_score = candidates[0][2]

    best = min(candidates, key=lambda t: t[2].key())
    strategy, coords, score = best
    if refine:
        refined, rscore, improved = greedy_refine(
            dims, coords, traffic, split_ties, double_link_on_2, backend=backend
        )
        if improved and rscore.key() < score.key():
            strategy, coords, score = f"greedy({strategy})", refined, rscore
    coords = np.ascontiguousarray(coords)
    coords.setflags(write=False)
    loads = mapping_loads(dims, coords, traffic, split_ties)
    loads.setflags(write=False)
    return RankMapping(
        dims=dims,
        oriented=oriented,
        offset=offset,
        logical_dims=logical,
        pattern=pattern,
        strategy=strategy,
        coords=coords,
        score=score,
        identity_score=identity_score,
        wrap=tuple(bool(x) for x in wrap) if wrap is not None else None,
        loads=loads,
        rank_traffic=traffic,
    )


# ---------------------------------------------------------------------------
# Mesh-axis measurement (the collectives/launch bridge).
# ---------------------------------------------------------------------------
def mesh_axis_hops(
    dims: Sequence[int],
    coords: np.ndarray,
    mesh_shape: Sequence[int],
    axis: int,
    wrap: Optional[Sequence[bool]] = None,
) -> Tuple[int, int]:
    """Measured neighbour distances of one logical mesh axis under a
    mapping: ``(interior, wrap)`` — the max hop count between
    consecutive-rank pairs along the axis, and between its last and first
    rank (the ring-closing step).  Ranks are raveled row-major over
    ``mesh_shape``; a size-1 axis measures ``(0, 0)``.  ``wrap`` marks
    which machine dimensions physically have their wrap-around link
    (default: all); distances never use a missing wrap link.

    This is what :func:`repro.network.collectives.assign_axes` uses to
    replace the assumed stride-1/wrapped embedding with the mapping's
    actual geometry.
    """
    dims = tuple(int(a) for a in dims)
    shape = tuple(int(s) for s in mesh_shape)
    n = int(np.prod(shape))
    if coords.shape[0] != n:
        raise ValueError(f"mapping has {coords.shape[0]} ranks; mesh {shape} needs {n}")
    size = shape[axis]
    if size <= 1:
        return 0, 0
    stride = int(np.prod(shape[axis + 1:])) if axis + 1 < len(shape) else 1
    idx = np.arange(n)
    coord_k = (idx // stride) % size
    interior = idx[coord_k < size - 1]
    wrap_src = idx[coord_k == size - 1]
    interior_max = int(
        toroidal_hops(dims, coords[interior], coords[interior + stride], wrap).max()
    )
    wrap_max = int(
        toroidal_hops(
            dims, coords[wrap_src], coords[wrap_src - (size - 1) * stride], wrap
        ).max()
    )
    return interior_max, wrap_max
