"""Collective cost model on torus fabrics — the paper's analysis, adapted to TPU.

Hardware adaptation (see DESIGN.md): the paper analyses Blue Gene/Q, where a
partition *always* retains wrap-around links (a partition of midplane geometry
g is itself a torus).  TPU ICI differs in two ways:

* a slice of a pod gets wrap-around links in a dimension only when it spans
  that full dimension (no "partial wrap") — so partition geometry affects not
  only face area but also *ring vs chain* topology per dimension;
* a dimension of length 2 has a single link between the two chips, not the
  Blue Gene/Q double link.

Both are parameters of :class:`repro.network.fabric.TorusFabric`.  The
edge-isoperimetric insight is unchanged: the internal bisection of an
allocated cuboid bounds the throughput of any bisection-crossing traffic,
and elongated slices waste it.

The model prices jax.lax collectives (all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute) for a mesh axis embedded in
the physical fabric, including the contention penalty of *strided* (folded)
embeddings — this is what the roofline's collective term uses, and what the
axis-assignment optimizer minimizes.  The ring closed forms agree with
routing the equivalent traffic through :mod:`repro.network.routing` (see the
test suite).
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .fabric import TorusFabric


@dataclass(frozen=True)
class AxisEmbedding:
    """How a logical mesh axis of size n is laid out on the fabric.

    ``rings``   — number of independent bidirectional rings/chains the axis
                  decomposes into (a 2D-embedded axis of size 16 on a 4x4
                  face uses 1 snaked ring; an axis aligned with a physical
                  dimension of size 16 across 16 rows uses 16 parallel rings
                  is *not* how mesh axes work — each axis instance is one
                  ring; parallelism across the other axes is implicit).
    ``stride``  — physical hops per logical neighbour step (1 = contiguous;
                  2 = every other chip, halving effective bandwidth).
    ``wrapped`` — whether the embedded ring closes (torus ring) or is a chain.
    """

    size: int
    stride: int = 1
    wrapped: bool = True

    @property
    def ring_bw_factor(self) -> float:
        """Effective per-direction bandwidth multiplier of the embedding."""
        base = 1.0 / self.stride
        return base

    @classmethod
    def from_mapping(cls, mapping, mesh_shape: Sequence[int], axis: int) -> "AxisEmbedding":
        """Embedding measured from an explicit rank mapping.

        ``mapping`` is a :class:`repro.network.mapping.RankMapping` (or
        anything with ``dims``, ``coords`` and optional per-dimension
        ``wrap`` flags); ranks are raveled row-major over ``mesh_shape``.
        ``stride`` is the *max* physical hop count between consecutive
        ranks along the axis (conservative: the slowest neighbour step
        paces a ring collective), and the embedding counts as ``wrapped``
        only when the ring-closing step is no longer than the interior
        ones — a cheap wrap is what lets both directions be used.  Hop
        counts honour the mapping's ``wrap`` flags, so a closing step
        never rides a wrap link the fabric does not have.
        """
        from .mapping import mesh_axis_hops

        size = int(mesh_shape[axis])
        if size <= 1:
            return cls(size=size, stride=1, wrapped=True)
        interior, wrap = mesh_axis_hops(
            mapping.dims, mapping.coords, mesh_shape, axis,
            getattr(mapping, "wrap", None),
        )
        return cls(
            size=size,
            stride=max(1, interior),
            wrapped=0 < wrap <= max(1, interior),
        )


def ring_all_gather_time(bytes_out: float, emb: AxisEmbedding, link_bw: float) -> float:
    """Time to all-gather so each chip ends with ``bytes_out`` total
    (each chip contributes bytes_out / n)."""
    n = emb.size
    if n <= 1:
        return 0.0
    shard = bytes_out / n
    steps_bytes = shard * (n - 1)
    directions = 2.0 if emb.wrapped else 1.0  # bidirectional exchange on a ring
    return steps_bytes / (directions * link_bw * emb.ring_bw_factor)


def ring_reduce_scatter_time(bytes_in: float, emb: AxisEmbedding, link_bw: float) -> float:
    """Time to reduce-scatter a per-chip buffer of ``bytes_in``."""
    n = emb.size
    if n <= 1:
        return 0.0
    shard = bytes_in / n
    steps_bytes = shard * (n - 1)
    directions = 2.0 if emb.wrapped else 1.0
    return steps_bytes / (directions * link_bw * emb.ring_bw_factor)


def ring_all_reduce_time(bytes_in: float, emb: AxisEmbedding, link_bw: float) -> float:
    """Bandwidth-optimal all-reduce = reduce-scatter + all-gather."""
    return ring_reduce_scatter_time(bytes_in, emb, link_bw) + ring_all_gather_time(
        bytes_in, emb, link_bw
    )


def ring_all_to_all_time(bytes_in: float, emb: AxisEmbedding, link_bw: float) -> float:
    """All-to-all of a per-chip buffer of ``bytes_in`` over the axis.

    Ring all-to-all is bisection-bound: max directed-link load is
    bytes_in/n * n^2/8 (ties split) on a wrapped ring, n^2/4 on a chain.
    """
    n = emb.size
    if n <= 1:
        return 0.0
    per_peer = bytes_in / n
    if emb.wrapped:
        load = per_peer * n * n / 8.0
    else:
        load = per_peer * n * n / 4.0
    return load / (link_bw * emb.ring_bw_factor)


def collective_permute_time(bytes_in: float, emb: AxisEmbedding, link_bw: float) -> float:
    """Neighbour shift along the axis (pipelining / ring matmul step)."""
    return bytes_in * emb.stride / link_bw


COLLECTIVE_TIME = {
    "all-reduce": ring_all_reduce_time,
    "all-gather": ring_all_gather_time,
    "reduce-scatter": ring_reduce_scatter_time,
    "all-to-all": ring_all_to_all_time,
    "collective-permute": collective_permute_time,
}


def simulated_ring_all_reduce_time(
    dims: Sequence[int],
    axis: int,
    bytes_in: float,
    link_bw: float = 1.0,
    double_link_on_2: bool = False,
    backend: Optional[str] = None,
) -> float:
    """Dynamic cross-check of :func:`ring_all_reduce_time`.

    Builds the ``2(n-1)`` neighbour-shift phases of a bidirectional ring
    all-reduce over physical dimension ``axis``
    (:func:`repro.network.patterns.ring_all_reduce_phases`) and drains
    them through the flow simulator.  For a contiguous wrapped ring the
    result equals the closed form exactly — the test suite pins it — so
    the prices :func:`assign_axes` hands to the roofline are *derived*
    from dynamics, not only asserted.
    """
    from .netsim import simulate_phases
    from .patterns import ring_all_reduce_phases

    phases = ring_all_reduce_phases(dims, axis, bytes_in)
    return simulate_phases(
        dims,
        phases,
        link_bw=link_bw,
        double_link_on_2=double_link_on_2,
        backend=backend,
    ).total_time


# ---------------------------------------------------------------------------
# Axis assignment: mapping logical mesh axes onto physical torus dimensions.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class AxisAssignment:
    """Assignment of each logical axis to an ordered group of physical dims."""

    axis_names: Tuple[str, ...]
    axis_sizes: Tuple[int, ...]
    phys_groups: Tuple[Tuple[int, ...], ...]  # indices into fabric.dims
    embeddings: Tuple[AxisEmbedding, ...]

    def embedding(self, axis: str) -> AxisEmbedding:
        """The embedding of one logical axis, looked up by name."""
        return self.embeddings[self.axis_names.index(axis)]


def assign_axes(
    fabric: TorusFabric,
    axis_sizes: Dict[str, int],
    order_hint: Optional[Sequence[str]] = None,
    mapping=None,
) -> AxisAssignment:
    """Greedy optimal-by-construction assignment of mesh axes to physical dims.

    Each axis must occupy a set of whole physical dimensions whose product is
    the axis size (the jax device-mesh reshape constraint).  Axes earlier in
    ``order_hint`` (default: larger collective pressure ≈ larger axis first)
    get contiguous, wrapped dimensions first.  An axis spanning multiple
    physical dims is embedded as a snake: wrapped iff all its dims wrap, and
    contiguous (stride 1) because the snake traverses physically adjacent
    chips.

    ``mapping`` (a :class:`repro.network.mapping.RankMapping` over the same
    rank count, ranks raveled row-major over ``axis_sizes`` in insertion
    order) replaces each axis's *assumed* stride-1/wrapped embedding with
    the measured one (:meth:`AxisEmbedding.from_mapping`): a mapping that
    folds an axis pays its real stride, and a ring only counts as wrapped
    when its closing step is as cheap as its interior steps.  The
    dimension grouping itself stays geometric.
    """
    names = list(order_hint) if order_hint else sorted(
        axis_sizes, key=lambda a: -axis_sizes[a]
    )
    if set(names) != set(axis_sizes):
        raise ValueError("order_hint must cover exactly the axis names")
    remaining = list(range(len(fabric.dims)))
    groups: Dict[str, Tuple[int, ...]] = {}
    for name in names:
        size = axis_sizes[name]
        if size == 1:
            groups[name] = ()
            continue
        got = _find_dim_group(fabric, remaining, size)
        if got is None:
            raise ValueError(
                f"axis {name}={size} cannot be embedded in remaining dims "
                f"{[fabric.dims[i] for i in remaining]} of fabric {fabric.dims}"
            )
        groups[name] = got
        for i in got:
            remaining.remove(i)
    ordered = tuple(axis_sizes.keys())
    mesh_shape = tuple(axis_sizes[n] for n in ordered)
    embeddings = {}
    for name in names:
        size = axis_sizes[name]
        dims = groups[name]
        if mapping is not None:
            embeddings[name] = AxisEmbedding.from_mapping(
                mapping, mesh_shape, ordered.index(name)
            )
        else:
            wrapped = all(fabric.wrap[i] for i in dims) if dims else True
            embeddings[name] = AxisEmbedding(size=size, stride=1, wrapped=wrapped)
    return AxisAssignment(
        axis_names=ordered,
        axis_sizes=tuple(axis_sizes[n] for n in ordered),
        phys_groups=tuple(groups[n] for n in ordered),
        embeddings=tuple(embeddings[n] for n in ordered),
    )


def _find_dim_group(
    fabric: TorusFabric, remaining: List[int], size: int
) -> Optional[Tuple[int, ...]]:
    """Smallest group of remaining physical dims whose product equals size,
    preferring wrapped dims (ring > chain for collectives)."""
    for k in range(1, len(remaining) + 1):
        candidates = []
        for combo in itertools.combinations(remaining, k):
            if math.prod(fabric.dims[i] for i in combo) == size:
                n_wrapped = sum(bool(fabric.wrap[i]) for i in combo)
                candidates.append((-n_wrapped, combo))
        if candidates:
            return min(candidates)[1]
    return None


@dataclass
class CollectiveCostModel:
    """Prices collectives for a mesh built on a fabric with an assignment."""

    fabric: TorusFabric
    assignment: AxisAssignment

    def time(self, collective: str, axis: str, bytes_in: float) -> float:
        """Seconds for one collective (:data:`COLLECTIVE_TIME` key) of
        ``bytes_in`` per-chip bytes over the named logical axis."""
        emb = self.assignment.embedding(axis)
        fn = COLLECTIVE_TIME[collective]
        return fn(bytes_in, emb, self.fabric.link_bw)

    def effective_axis_bandwidth(self, axis: str) -> float:
        """Algorithmic bandwidth of an all-gather over the axis (bytes/s)."""
        emb = self.assignment.embedding(axis)
        if emb.size <= 1:
            return math.inf
        t = ring_all_gather_time(1.0, emb, self.fabric.link_bw)
        return 1.0 / t
