"""Vectorized dimension-ordered routing (DOR) link-load engine.

Models minimal dimension-ordered routing on a torus and computes per-directed
-link loads for arbitrary batches of ``(src, dst, vol)`` traffic with NumPy
array operations — no per-hop Python loops.  The completion time of a
bulk-synchronous communication phase is estimated as

    T = max_link_load / link_bandwidth

which is exact for the bisection-pairing benchmark of the paper (each node
exchanges fixed-size messages with the node at maximal hop distance) and a
good model for any contention-bound pattern.

Three levels of machinery:

* :func:`route_dor` — the vectorized engine.  For each dimension it reduces
  every message to a cyclic link segment ``(ring, start, hops, direction)``
  and accumulates all segments at once via a difference-array + bincount +
  cumsum sweep: O(M + N) array work total instead of O(M * hops) Python
  steps.
* :class:`LinkLoads` — the historical accumulate-then-query API, now backed
  by the vectorized engine (the old per-hop walker survives only as a test
  reference under ``tests/reference_dor.py``).
* ``uniform_offset_max_load`` / ``all_to_all_max_load`` — O(D) closed forms
  for translation-invariant patterns, exact by symmetry, cross-checked
  against the engine in the test suite.

Tie-breaking: when the hop distance along a ring is exactly half the ring
length, minimal routing may use either direction.  ``split_ties=True``
(default) splits the volume evenly — this models BG/Q's and TPU ICI's
adaptive/balanced routing and is what the paper's predictions assume.

Dimensions of length 2 have *two* physical links between each vertex pair
under the Blue Gene/Q convention; traffic is balanced across them, halving
the effective load (``double_link_on_2`` in :func:`max_link_load`).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .backend import resolve_backend
from .geometry import canonical, volume
from .fabric import HyperXFabric, Torus, TorusFabric

Coord = Tuple[int, ...]

__all__ = [
    "Coord",
    "LinkLoads",
    "PairingPrediction",
    "all_to_all_max_load",
    "hyperx_all_to_all_max_load",
    "hyperx_max_link_load",
    "max_link_load",
    "pairing_speedup",
    "predict_pairing_time",
    "route_dor",
    "route_hyperx",
    "route_pattern",
    "simulate_pattern",
    "uniform_offset_max_load",
]


# ---------------------------------------------------------------------------
# The vectorized engine.
# ---------------------------------------------------------------------------
def route_dor(
    dims: Sequence[int],
    src: np.ndarray,
    dst: np.ndarray,
    vol: np.ndarray,
    split_ties: bool = True,
    backend: Optional[str] = None,
) -> np.ndarray:
    """Per-directed-link loads for a batch of messages under DOR routing.

    Arguments
    ---------
    dims : torus dimension lengths (length D)
    src, dst : int arrays of shape (M, D) — message endpoints
    vol : float array of shape (M,) (or scalar) — message volumes
    split_ties : split exactly-antipodal ring traffic across both directions
    backend : ``"numpy"`` (default) or ``"xla"`` — see
        :func:`repro.network.backend.resolve_backend`; both produce the
        identical load tensor (exactly, for integer/dyadic volumes)

    Returns
    -------
    loads : float array of shape (D, 2, *dims); ``loads[k, d, *v]`` is the
        volume on the link leaving vertex v in dimension k, direction d
        (0: +1, 1: -1).  Raw link loads — double-link normalisation is a
        query-time concern (:func:`max_link_load`).
    """
    dims = tuple(int(a) for a in dims)
    D = len(dims)
    src = np.atleast_2d(np.asarray(src, dtype=np.int64))
    dst = np.atleast_2d(np.asarray(dst, dtype=np.int64))
    if src.shape != dst.shape or src.shape[1] != D:
        raise ValueError(f"src/dst must have shape (M, {D}); got {src.shape}/{dst.shape}")
    M = src.shape[0]
    vol = np.broadcast_to(np.asarray(vol, dtype=np.float64), (M,))
    loads = np.zeros((D, 2) + dims, dtype=np.float64)
    if M == 0:
        return loads
    if resolve_backend(backend) == "xla":
        from .backend import xla_route_loads

        return xla_route_loads(dims, src, dst, vol, split_ties)

    for k, a in enumerate(dims):
        if a == 1:
            continue
        # DOR: dims < k already routed (current coord = dst), dims > k still
        # at the source coordinate.
        other_coords = [dst[:, j] for j in range(k)] + [src[:, j] for j in range(k + 1, D)]
        other_dims = dims[:k] + dims[k + 1:]
        if other_coords:
            line = np.ravel_multi_index(other_coords, other_dims)
        else:
            line = np.zeros(M, dtype=np.int64)
        n_lines = volume(other_dims) if other_dims else 1

        s = src[:, k]
        delta = dst[:, k] - s
        np.add(delta, a, out=delta, where=delta < 0)  # delta mod a, branch-free
        rev = a - delta
        hops = np.minimum(delta, rev)
        tie = delta * 2 == a
        fwd = delta <= rev  # ties route forward in the primary segment

        # Primary segment: every message contributes exactly one cyclic link
        # segment (start, hops, direction); ties carry half volume when split,
        # and delta == 0 messages carry zero (hops == 0 would otherwise leave
        # a stray +v when the em != 0 cancellation test coincides with ring
        # position 0).
        v1 = np.where(tie, vol * (0.5 if split_ties else 1.0), vol)
        v1[hops == 0] = 0.0
        # forward: links leaving s, s+1, ..., s+hops-1; backward: links
        # leaving s, s-1, ..., s-hops+1 == the cyclic segment of length hops
        # starting at (s - hops + 1) mod a in the '-' load plane.
        bstart = s - hops + 1
        np.add(bstart, a, out=bstart, where=bstart < 0)
        start = np.where(fwd, s, bstart)
        base = line * a
        np.add(base, n_lines * a, out=base, where=~fwd)  # '-' plane offset

        seg_start = [start]
        seg_hops = [hops]
        seg_vol = [v1]
        seg_base = [base]
        if split_ties and tie.any():
            # Secondary segment: the backward half of each split tie.
            seg_start.append(bstart[tie])
            seg_hops.append(hops[tie])
            seg_vol.append(vol[tie] * 0.5)
            seg_base.append(n_lines * a + line[tie] * a)

        if len(seg_start) > 1:
            start = np.concatenate(seg_start)
            hops = np.concatenate(seg_hops)
            v = np.concatenate(seg_vol)
            base = np.concatenate(seg_base)
        else:
            v = v1

        # Difference-array accumulation over (direction, line, ring position):
        # a segment [start, start+hops) on the ring adds +v at start and -v at
        # end (mod a); a wrapped segment additionally covers the ring prefix,
        # handled by a +v at position 0 (weight-zeroed otherwise).  A single
        # cumsum then recovers the loads.  hops <= floor(a/2) < a, so no
        # segment covers the whole ring.
        end = start + hops
        wrapped = end > a  # segment covers the ring prefix [0, end - a)
        em = end
        np.subtract(em, a, out=em, where=end >= a)  # end mod a (end < 2a)
        n_seg = start.shape[0]
        idx = np.empty(3 * n_seg, dtype=np.int64)
        w = np.empty(3 * n_seg, dtype=np.float64)
        np.add(base, start, out=idx[:n_seg])
        w[:n_seg] = v
        np.add(base, em, out=idx[n_seg: 2 * n_seg])
        np.negative(v, out=w[n_seg: 2 * n_seg])
        w[n_seg: 2 * n_seg][em == 0] = 0.0
        idx[2 * n_seg:] = base
        w2 = w[2 * n_seg:]
        w2[:] = 0.0
        np.copyto(w2, v, where=wrapped)
        diff = np.bincount(idx, weights=w, minlength=2 * n_lines * a)
        ring_loads = np.cumsum(diff.reshape(2, n_lines, a), axis=-1)
        # Clamp accumulated float error on positions after all segments ended.
        np.maximum(ring_loads, 0.0, out=ring_loads)
        # Reshape (n_lines, a) back to the torus layout with axis k last,
        # then move it home.
        full = ring_loads.reshape((2,) + other_dims + (a,))
        loads[k] = np.moveaxis(full, -1, 1 + k)
    return loads


def max_link_load(
    dims: Sequence[int], loads: np.ndarray, double_link_on_2: bool = True
) -> float:
    """Maximum per-physical-link load of a :func:`route_dor` result.

    Under the Blue Gene/Q convention a dimension of length 2 has two parallel
    links per vertex pair and traffic balances across them, halving the
    effective load; TPU-style fabrics pass ``double_link_on_2=False``.
    """
    dims = tuple(dims)
    m = 0.0
    for k, a in enumerate(dims):
        if a == 1:
            continue
        scale = 0.5 if (a == 2 and double_link_on_2) else 1.0
        m = max(m, scale * float(loads[k].max()))
    return m


@dataclass
class LinkLoads:
    """Directed-link load accounting on a torus under DOR routing.

    API-compatible with the historical per-hop walker, but batched: paths are
    buffered and routed in one vectorized sweep on first query.  Use
    :meth:`add_batch` to feed array traffic directly (preferred).
    """

    dims: Tuple[int, ...]
    split_ties: bool = True
    double_link_on_2: bool = True
    _src: List[np.ndarray] = field(default_factory=list, repr=False)
    _dst: List[np.ndarray] = field(default_factory=list, repr=False)
    _vol: List[np.ndarray] = field(default_factory=list, repr=False)
    _loads: Optional[np.ndarray] = field(default=None, repr=False)

    def __post_init__(self):
        self.dims = tuple(int(a) for a in self.dims)

    def add_path(self, src: Coord, dst: Coord, vol: float) -> None:
        """Route vol from src to dst (buffered; computed lazily)."""
        self.add_batch([src], [dst], [vol])

    def add_batch(
        self,
        src: Sequence[Sequence[int]],
        dst: Sequence[Sequence[int]],
        vol,
    ) -> None:
        src = np.atleast_2d(np.asarray(src, dtype=np.int64))
        dst = np.atleast_2d(np.asarray(dst, dtype=np.int64))
        vol = np.broadcast_to(np.asarray(vol, dtype=np.float64), (src.shape[0],))
        self._src.append(src)
        self._dst.append(dst)
        self._vol.append(np.array(vol))
        self._loads = None

    def _compute(self) -> np.ndarray:
        if self._loads is None:
            if self._src:
                self._loads = route_dor(
                    self.dims,
                    np.concatenate(self._src),
                    np.concatenate(self._dst),
                    np.concatenate(self._vol),
                    split_ties=self.split_ties,
                )
            else:
                self._loads = np.zeros((len(self.dims), 2) + self.dims)
        return self._loads

    @property
    def loads(self) -> List[List[np.ndarray]]:
        """Historical layout: loads[k][d] has the torus shape.

        Unlike the old per-hop walker these are *snapshots* of the lazily
        computed load tensor, not live accumulators: a later ``add_path`` /
        ``add_batch`` triggers a fresh routing pass and previously returned
        arrays do not update (and must not be mutated).  Re-read the
        property (or :meth:`load_array`) after adding traffic.
        """
        arr = self._compute()
        return [[arr[k, d] for d in range(2)] for k in range(len(self.dims))]

    def load_array(self) -> np.ndarray:
        """The (D, 2, *dims) load tensor."""
        return self._compute()

    def max_load(self) -> float:
        """Maximum load on any directed physical link (double links halve)."""
        return max_link_load(self.dims, self._compute(), self.double_link_on_2)

    def total_hop_volume(self) -> float:
        return float(self._compute().sum())


def simulate_pattern(
    dims: Sequence[int],
    traffic: Iterable[Tuple[Coord, Coord, float]],
    split_ties: bool = True,
) -> LinkLoads:
    """Route explicit (src, dst, vol) traffic; accepts any iterable of triples."""
    ll = LinkLoads(tuple(dims), split_ties=split_ties)
    triples = list(traffic)
    if triples:
        srcs, dsts, vols = zip(*triples)
        ll.add_batch(np.asarray(srcs), np.asarray(dsts), np.asarray(vols, dtype=np.float64))
    return ll


# ---------------------------------------------------------------------------
# Closed forms for translation-invariant patterns.
# ---------------------------------------------------------------------------
def uniform_offset_max_load(
    dims: Sequence[int],
    offset: Sequence[int],
    vol: float = 1.0,
    split_ties: bool = True,
    double_link_on_2: bool = True,
) -> float:
    """Max directed-link load when every vertex sends vol to vertex+offset.

    By translation symmetry the load is uniform per (dimension, direction):
    an offset of delta on a ring of length a loads each link of the chosen
    direction with ``vol * min(delta, a-delta)`` (halved when the tie is
    split, and halved again on BG/Q double links, a == 2; TPU single-link
    fabrics pass ``double_link_on_2=False``).
    """
    m = 0.0
    for a, off in zip(dims, offset):
        if a == 1:
            continue
        delta = off % a
        if delta == 0:
            continue
        d = min(delta, a - delta)
        load = vol * d
        if 2 * d == a and split_ties:
            load /= 2.0
        if a == 2 and double_link_on_2:
            load /= 2.0  # double link
        m = max(m, load)
    return m


def all_to_all_max_load(
    dims: Sequence[int],
    vol_per_pair: float = 1.0,
    split_ties: bool = True,
    double_link_on_2: bool = True,
) -> float:
    """Max link load of a full all-to-all (every ordered pair exchanges
    vol_per_pair), computed analytically for DOR routing.

    Under DOR every message routes its whole dim-k distance on exactly one
    dim-k ring, and by translation symmetry each ring sees every (start,
    offset) combination equally often: with N = prod(dims), each of the N/a_k
    rings carries N*a_k messages, N per ordered ring offset delta.  The
    per-direction hop volumes are counted *explicitly* (an offset delta
    strictly below a/2 walks delta forward links; strictly above, a - delta
    backward links; the exact-half tie is split or sent forward), rather than
    assuming the two directions balance — on every torus the reflection
    delta <-> a - delta makes them equal when ties are split, but with
    ``split_ties=False`` the forward direction carries the whole antipodal
    volume and the directions genuinely differ.  Cross-checked against the
    exact simulator (including small odd tori) in the test suite.
    """
    dims = tuple(dims)
    n = volume(dims)
    worst = 0.0
    for k, a in enumerate(dims):
        if a == 1:
            continue
        fwd_hop_vol = 0.0  # per-ring hop volume in the + direction
        bwd_hop_vol = 0.0
        for delta in range(1, a):
            d = min(delta, a - delta)
            if 2 * delta == a:  # antipodal tie
                if split_ties:
                    fwd_hop_vol += n * d / 2.0
                    bwd_hop_vol += n * d / 2.0
                else:
                    fwd_hop_vol += n * d
            elif delta < a - delta:
                fwd_hop_vol += n * d
            else:
                bwd_hop_vol += n * d
        # Uniform over the a links of each direction of the ring.
        load = max(fwd_hop_vol, bwd_hop_vol) * vol_per_pair / a
        if a == 2 and double_link_on_2:
            load /= 2.0
        worst = max(worst, load)
    return worst


# ---------------------------------------------------------------------------
# Paper experiment A: the bisection-pairing benchmark.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PairingPrediction:
    dims: Tuple[int, ...]
    max_link_load: float  # per unit message volume
    time_per_volume: float  # seconds per byte of per-pair message volume
    bisection_links: int


def predict_pairing_time(
    dims: Sequence[int],
    message_bytes: float,
    link_bw_bytes_s: float,
    split_ties: bool = True,
    double_link_on_2: bool = True,
) -> PairingPrediction:
    """Predicted completion time of one round of the pairing benchmark."""
    from .patterns import furthest_offset

    dims = canonical(dims)
    off = furthest_offset(dims)
    load = uniform_offset_max_load(
        dims, off, 1.0, split_ties=split_ties, double_link_on_2=double_link_on_2
    )
    return PairingPrediction(
        dims=dims,
        max_link_load=load,
        time_per_volume=load / link_bw_bytes_s,
        bisection_links=Torus(dims).bisection_links(),
    )


def pairing_speedup(
    dims_a: Sequence[int], dims_b: Sequence[int], split_ties: bool = True
) -> float:
    """Predicted execution-time ratio T(a) / T(b) of the pairing benchmark
    between two equal-size partition geometries (paper Figures 3-4)."""
    a = predict_pairing_time(dims_a, 1.0, 1.0, split_ties)
    b = predict_pairing_time(dims_b, 1.0, 1.0, split_ties)
    return a.max_link_load / b.max_link_load


# ---------------------------------------------------------------------------
# HyperX routing: minimal (dimension-ordered direct hops) and DAL.
# ---------------------------------------------------------------------------
def _hyperx_blocks(dims: Tuple[int, ...]) -> Tuple[List[int], int]:
    """Per-dimension slot-block starts of the HyperX link-id layout
    (matching :meth:`repro.network.fabric.HyperXFabric.links`) and the
    total dense slot count ``N * sum(S_k)``."""
    n = volume(dims)
    bases: List[int] = []
    b = 0
    for a in dims:
        bases.append(b)
        b += n * a
    return bases, b


def _hyperx_order_links(
    dims: Tuple[int, ...],
    src: np.ndarray,
    dst: np.ndarray,
    order: Sequence[int],
) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Per-hop link incidence of every message under one dimension order.

    In HyperX each dimension correction is a single direct hop within the
    current cell's dim-k clique, so a message's path visits one link per
    differing coordinate.  Returns ``(link_ids, message_idx)`` pairs, one
    per dimension that any message hops in.
    """
    bases, _ = _hyperx_blocks(dims)
    cur = src.copy()
    out: List[Tuple[np.ndarray, np.ndarray]] = []
    for k in order:
        a = dims[k]
        if a > 1:
            idx = np.flatnonzero(cur[:, k] != dst[:, k])
            if idx.shape[0]:
                flat = np.ravel_multi_index(tuple(cur[idx].T), dims)
                out.append((bases[k] + flat * a + dst[idx, k], idx))
        cur[:, k] = dst[:, k]
    return out


def _hyperx_candidate_orders(D: int) -> List[Tuple[int, ...]]:
    """DAL's candidate dimension orders: the D cyclic rotations of the
    canonical order (rotation 0 *is* minimal routing).  Rotations reach
    every dimension as the first correction while keeping the candidate
    count linear in D."""
    base = tuple(range(D))
    return [base[r:] + base[:r] for r in range(max(D, 1))]


def _hyperx_flows(
    fabric: HyperXFabric,
    src: np.ndarray,
    dst: np.ndarray,
    vol: np.ndarray,
    mode: str = "minimal",
    rounds: int = 2,
    balance_rtol: float = 1e-9,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Expand messages into routed subflows on a HyperX fabric.

    Returns ``(msg, fvol, link_ids, flow_ids)`` in the shape
    :class:`repro.network.netsim.FlowPaths` consumes.  ``mode="minimal"``
    routes canonical dimension order (one subflow per message).
    ``mode="dal"`` is dimensionally-adaptive load-balanced routing:
    every message may *split* its volume across the candidate dimension
    orders, weighted by the inverse of each order's bottleneck link load
    under the current field, iterated ``rounds`` times from the minimal
    field.  Messages whose candidate bottlenecks are balanced (within
    ``balance_rtol``) keep the canonical minimal order exactly — so on a
    steady translation-invariant pattern (uniform field) DAL *is*
    minimal routing, bit for bit, mirroring the torus
    ``compare_routing`` finding; only genuinely skewed fields trigger
    splitting.  Fractional splitting (rather than 0/1 re-ordering) makes
    the iteration stable — simultaneous all-or-nothing switches
    oscillate on hotspots.
    """
    dims = fabric.dims
    D = len(dims)
    src = np.atleast_2d(np.asarray(src, dtype=np.int64))
    dst = np.atleast_2d(np.asarray(dst, dtype=np.int64))
    if src.shape != dst.shape or src.shape[1] != D:
        raise ValueError(f"src/dst must have shape (M, {D}); got {src.shape}/{dst.shape}")
    M = src.shape[0]
    vol = np.array(np.broadcast_to(np.asarray(vol, dtype=np.float64), (M,)))
    _, n_slots = _hyperx_blocks(dims)
    empty = np.zeros(0, dtype=np.int64)
    if M == 0:
        return empty, np.zeros(0), empty.copy(), empty.copy()

    orders = _hyperx_candidate_orders(D)
    per_order = [_hyperx_order_links(dims, src, dst, o) for o in orders]

    if mode == "minimal":
        weights = np.zeros((M, len(orders)))
        weights[:, 0] = 1.0
    elif mode == "dal":
        weights = np.zeros((M, len(orders)))
        weights[:, 0] = 1.0
        tiny = 1e-300
        for _ in range(max(rounds, 1)):
            loads = np.zeros(n_slots)
            for r, hops in enumerate(per_order):
                w = weights[:, r] * vol
                for ids, idx in hops:
                    np.add.at(loads, ids, w[idx])
            cost = np.zeros((M, len(orders)))
            for r, hops in enumerate(per_order):
                for ids, idx in hops:
                    np.maximum.at(cost[:, r], idx, loads[ids])
            cmax = cost.max(axis=1)
            cmin = cost.min(axis=1)
            skewed = cmax - cmin > balance_rtol * np.maximum(cmax, tiny)
            inv = 1.0 / np.maximum(cost, tiny)
            frac = inv / inv.sum(axis=1, keepdims=True)
            weights[skewed] = frac[skewed]
            keep = ~skewed
            weights[keep] = 0.0
            weights[keep, 0] = 1.0
    else:
        raise ValueError(f"unknown HyperX routing mode {mode!r}; expected 'minimal' or 'dal'")

    msg_l: List[np.ndarray] = []
    fvol_l: List[np.ndarray] = []
    link_l: List[np.ndarray] = []
    flow_l: List[np.ndarray] = []
    f_base = 0
    for r, hops in enumerate(per_order):
        live = np.flatnonzero(weights[:, r] > 0.0)
        if not live.shape[0]:
            continue
        pos = np.full(M, -1, dtype=np.int64)
        pos[live] = f_base + np.arange(live.shape[0])
        msg_l.append(live)
        fvol_l.append(weights[live, r] * vol[live])
        for ids, idx in hops:
            sel = pos[idx] >= 0
            link_l.append(ids[sel])
            flow_l.append(pos[idx][sel])
        f_base += live.shape[0]
    return (
        np.concatenate(msg_l) if msg_l else empty,
        np.concatenate(fvol_l) if fvol_l else np.zeros(0),
        np.concatenate(link_l) if link_l else empty.copy(),
        np.concatenate(flow_l) if flow_l else empty.copy(),
    )


def route_hyperx(
    fabric: HyperXFabric,
    src: np.ndarray,
    dst: np.ndarray,
    vol,
    mode: str = "minimal",
    rounds: int = 2,
) -> np.ndarray:
    """Per-directed-link loads of a message batch on a HyperX fabric.

    Returns a flat ``(N * sum(S_k),)`` load vector in the dense link-id
    layout of :meth:`repro.network.fabric.HyperXFabric.links` (unused
    self-slots stay zero).  ``mode="minimal"`` corrects coordinates in
    canonical dimension order — every hop is direct, path length equals
    Hamming distance; ``mode="dal"`` additionally load-balances across
    dimension orders (see :func:`_hyperx_flows`).  The per-hop reference
    oracle lives in ``tests``/``benchmarks/bench_hyperx.py``; loads are
    exact sums, so engine and oracle agree bit-for-bit.

    >>> import numpy as np
    >>> hx = HyperXFabric((4, 4))
    >>> loads = route_hyperx(hx, np.array([[0, 0]]), np.array([[2, 3]]), 1.0)
    >>> float(loads.sum())   # two direct hops: dim 0 then dim 1
    2.0
    """
    M = np.atleast_2d(np.asarray(src)).shape[0]
    vol = np.broadcast_to(np.asarray(vol, dtype=np.float64), (M,))
    msg, fvol, link_ids, flow_ids = _hyperx_flows(fabric, src, dst, vol, mode, rounds)
    _, n_slots = _hyperx_blocks(fabric.dims)
    if not link_ids.shape[0]:
        return np.zeros(n_slots)
    return np.bincount(link_ids, weights=fvol[flow_ids], minlength=n_slots)


def hyperx_max_link_load(fabric: HyperXFabric, loads: np.ndarray) -> float:
    """Max per-physical-link load of a :func:`route_hyperx` vector —
    dimension k's trunked ``K_k`` parallel links share their dim's
    traffic, dividing the effective load (the HyperX analogue of the
    torus double-link halving)."""
    dims = fabric.dims
    n = volume(dims)
    m = 0.0
    base = 0
    for k, a in enumerate(dims):
        block = loads[base: base + n * a]
        if block.shape[0]:
            m = max(m, float(block.max()) / fabric.link_multiplicity[k])
        base += n * a
    return m


def hyperx_all_to_all_max_load(fabric: HyperXFabric, vol_per_pair: float = 1.0) -> float:
    """Exact max effective link load of all-to-all on a HyperX fabric.

    Under minimal dimension-ordered routing the load field of all-to-all
    is uniform within each dimension: the dim-k link out of any cell is
    shared by exactly ``N / S_k`` ordered pairs (the pairs whose
    intermediate cell sits there), each contributing ``vol_per_pair``, so

        max load = vol_per_pair * N / min_k (S_k * K_k).

    This is the HyperX analogue of :func:`all_to_all_max_load` and the
    closed form behind the allocation study's geometry ranking: covering
    a dimension fully (``c_k == S_k`` — impossible to beat) maximises
    ``min_k c_k``'s denominator, so *elongated* boxes minimise all-to-all
    contention on HyperX, the exact opposite of the torus preference.
    Cross-checked against :func:`route_hyperx` in the test suite.

    >>> hyperx_all_to_all_max_load(HyperXFabric((4, 4)))
    4.0
    >>> hyperx_all_to_all_max_load(HyperXFabric((16, 1)))
    1.0
    """
    n = volume(fabric.dims)
    denom = min(
        a * k for a, k in zip(fabric.dims, fabric.link_multiplicity) if a > 1
    ) if any(a > 1 for a in fabric.dims) else None
    if denom is None:
        return 0.0
    return vol_per_pair * n / denom


# ---------------------------------------------------------------------------
# The single fabric-dispatch entry point.
# ---------------------------------------------------------------------------
def route_pattern(
    fabric,
    src: np.ndarray,
    dst: np.ndarray,
    vol,
    *,
    mode: Optional[str] = None,
    split_ties: bool = True,
    backend: Optional[str] = None,
) -> np.ndarray:
    """Route a message batch on any fabric — one dispatch for the stack.

    * :class:`~repro.network.fabric.TorusFabric` / :class:`Torus` / plain
      dims: dimension-ordered torus routing, returning :func:`route_dor`'s
      ``(D, 2, *dims)`` tensor **bit-for-bit** (``mode`` must be ``"dor"``
      or ``None``; the adaptive torus router lives in
      :mod:`repro.network.netsim`, where path state exists).
    * :class:`~repro.network.fabric.HyperXFabric`: the flat HyperX load
      vector of :func:`route_hyperx` (``mode`` ``"minimal"`` (default) or
      ``"dal"``; ``split_ties``/``backend`` do not apply — clique hops
      have no antipodal ties).

    >>> import numpy as np
    >>> from .fabric import TorusFabric
    >>> t = route_pattern(TorusFabric.bgq((4, 4)), np.array([[0, 0]]),
    ...                   np.array([[2, 0]]), 1.0)
    >>> t.shape
    (2, 2, 4, 4)
    """
    if isinstance(fabric, HyperXFabric):
        if backend not in (None, "numpy"):
            raise ValueError("HyperX routing is numpy-only; backend must be None/'numpy'")
        return route_hyperx(fabric, src, dst, vol, mode=mode or "minimal")
    if isinstance(fabric, (TorusFabric, Torus)):
        dims = fabric.dims
    else:
        dims = tuple(int(a) for a in fabric)
    if mode not in (None, "dor"):
        raise ValueError(
            f"torus route_pattern supports mode='dor' only (got {mode!r}); "
            f"adaptive torus routing lives in repro.network.netsim"
        )
    return route_dor(dims, src, dst, vol, split_ties=split_ties, backend=backend)
