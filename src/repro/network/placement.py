"""Vectorized cuboid-placement engine over occupancy grids.

The allocation problem of the paper — where does a cuboid partition land in
the host torus — reduces to: given a boolean occupancy grid over the machine
torus and an oriented cuboid extent, find every free *translate* of the
cuboid, then pick one.  The historical implementation scanned every
orientation x every torus offset in Python with a per-candidate meshgrid
check; it survives verbatim as the test oracle in
``tests/reference_placement.py``.  This module replaces it with array work:

* :func:`free_offset_mask` — all free translates of one orientation in one
  shot.  The number of occupied cells covered by the cuboid placed at offset
  ``j`` is the circular correlation of the occupancy grid with the cuboid's
  indicator kernel; free offsets are exactly its zeros.  The correlation is
  computed as a separable per-dimension *circular windowed sum* (append the
  first ``w-1`` slices, one cumsum, one subtraction per dimension — integer
  exact, no FFT round-off), so torus wraparound falls out naturally and the
  total work is O(D * N) regardless of cuboid size.
* :func:`first_fit` — bit-identical to the reference walker's choice: try
  orientations in ``sorted(set(permutations(g)))`` order and offsets in
  C (row-major lexicographic) order, which is precisely
  ``itertools.product(*(range(a) for a in dims))``.
* :func:`candidate_scores` / :func:`best_placement` — scored selection.
  Candidates are ranked by
    1. internal bisection of the (canonical) geometry — a property of the
       geometry, so it orders *which* cuboid to request (the isoperimetric
       policy), not where it lands;
    2. predicted neighbour contention: the job's traffic routed on the
       *machine* torus with the PR-1 DOR engine, summed over links already
       carrying existing placements' traffic.  The job's load field
       translates with its offset, so the score for *every* offset is a
       circular cross-correlation of the base load field with the
       background-usage mask (:func:`contention_field`).  Pairing traffic
       is provably isolated between disjoint cuboids under minimal DOR
       (spans never exceed half a ring), so the score uses intra-job
       all-to-all, whose beyond-half-span routes genuinely transit foreign
       territory — the shared-fabric model (TPU ICI without slice
       isolation; 0 for BG/Q-style electrically-isolated partitions);
    3. contact (anti-fragmentation): occupied cells in the one-cell shell
       around the candidate — placing against existing allocations keeps the
       remaining free set contiguous.  Computed for *all* candidates at once
       with the same windowed-sum trick on a dilated window.

Everything here operates on raw grids; :class:`repro.network.allocation.
MachineState` is the stateful wrapper.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from functools import lru_cache
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..obs.trace import TRACER as _TRACER
from .backend import resolve_backend
from .geometry import Geometry, bisection_links, canonical

Coord = Tuple[int, ...]


# ---------------------------------------------------------------------------
# Geometry normalisation (the truncation-bug fix lives here).
# ---------------------------------------------------------------------------
def pad_geometry(geometry: Sequence[int], ndim: int) -> Geometry:
    """Canonicalise and pad a requested geometry to the machine's rank.

    Trailing 1s beyond the machine rank are harmless and stripped; a
    geometry with more *non-trivial* dimensions than the machine is an
    error.  (The historical scan silently truncated it instead — the
    ``g + (1,) * (len(dims) - len(g))`` pad is a no-op for negative counts
    and the subsequent ``zip`` dropped the extra axes, allocating fewer
    cells than the requested volume.)
    """
    g = canonical(geometry)
    while len(g) > ndim and g[-1] == 1:
        g = g[:-1]
    if len(g) > ndim:
        raise ValueError(
            f"geometry {canonical(geometry)} has {len(g)} non-trivial dims; "
            f"machine has only {ndim}"
        )
    return g + (1,) * (ndim - len(g))


def orientations(geometry: Sequence[int], dims: Sequence[int]) -> List[Tuple[int, ...]]:
    """Distinct axis-assignments of the cuboid that fit the machine, in the
    reference scan's order: ``sorted(set(permutations(padded)))``."""
    dims = tuple(dims)
    g = pad_geometry(geometry, len(dims))
    return [
        perm
        for perm in sorted(set(itertools.permutations(g)))
        if all(s <= a for s, a in zip(perm, dims))
    ]


# ---------------------------------------------------------------------------
# The correlation core.
# ---------------------------------------------------------------------------
def _circular_window_sums(occ: np.ndarray, extents: Sequence[int]) -> np.ndarray:
    """S[j] = number of occupied cells in the axis-aligned box of the given
    extents whose lowest corner sits at offset j (circular in every dim).

    Separable: one pass per dimension, each a cumsum over the grid extended
    by its own first ``w - 1`` slices.  Integer arithmetic throughout.
    """
    s = occ.astype(np.int64, copy=False)
    for k, w in enumerate(extents):
        w = int(w)
        a = s.shape[k]
        if w == 1:
            continue
        if not 1 <= w <= a:
            raise ValueError(f"window {w} exceeds grid extent {a} in dim {k}")
        ext = np.concatenate([s, np.take(s, range(w - 1), axis=k)], axis=k)
        c = np.cumsum(ext, axis=k)
        upper = np.take(c, range(w - 1, a + w - 1), axis=k)
        lower = np.concatenate(
            [np.zeros_like(np.take(c, [0], axis=k)), np.take(c, range(a - 1), axis=k)],
            axis=k,
        )
        s = upper - lower
    return s


def free_offset_mask(grid: np.ndarray, oriented: Sequence[int]) -> np.ndarray:
    """Boolean mask over all torus offsets: True where the oriented cuboid
    placed at that offset covers only free cells."""
    return _circular_window_sums(grid, tuple(oriented)) == 0


def shell_contact(grid: np.ndarray, oriented: Sequence[int]) -> np.ndarray:
    """Occupied-cell count in the one-cell shell around the cuboid at every
    offset (valid wherever the offset itself is free).

    Computed as the windowed sum over the cuboid dilated by one cell on each
    side (window ``w + 2`` starting one cell earlier), clipped to the full
    ring where the dilated window would wrap onto itself; for a free
    placement the interior contributes zero, so the dilated sum *is* the
    shell occupancy.
    """
    dims = grid.shape
    extents = tuple(min(w + 2, a) for w, a in zip(oriented, dims))
    sums = _circular_window_sums(grid, extents)
    shift = [1 if e == w + 2 else 0 for e, w in zip(extents, oriented)]
    if any(shift):
        sums = np.roll(sums, shift, axis=tuple(range(len(dims))))
    return sums


# ---------------------------------------------------------------------------
# Enumeration and first-fit.
# ---------------------------------------------------------------------------
def iter_free_placements(
    grid: np.ndarray, geometry: Sequence[int]
) -> Iterator[Tuple[Tuple[int, ...], np.ndarray]]:
    """Yield ``(oriented, free_mask)`` per fitting orientation, in reference
    order.  ``free_mask`` has the grid's shape."""
    for perm in orientations(geometry, grid.shape):
        yield perm, free_offset_mask(grid, perm)


def first_fit(
    grid: np.ndarray, geometry: Sequence[int]
) -> Optional[Tuple[Tuple[int, ...], Coord]]:
    """First free translate of any orientation — identical choice to the
    brute-force reference scan (orientation order, then C-order offsets)."""
    for perm, free in iter_free_placements(grid, geometry):
        flat = np.flatnonzero(free.ravel(order="C"))
        if flat.size:
            return perm, tuple(int(x) for x in np.unravel_index(flat[0], grid.shape))
    return None


def placement_cells(
    dims: Sequence[int], oriented: Sequence[int], offset: Coord
) -> Tuple[np.ndarray, ...]:
    """Open-mesh index (``np.ix_``) of the cells covered by the placement —
    usable directly for grid assignment and reads."""
    return np.ix_(
        *[
            (int(offset[k]) + np.arange(int(oriented[k]))) % int(a)
            for k, a in enumerate(dims)
        ]
    )


# ---------------------------------------------------------------------------
# Traffic-aware scoring.
# ---------------------------------------------------------------------------
def _relative_cells(oriented: Tuple[int, ...]) -> np.ndarray:
    n = int(np.prod(oriented))
    return np.stack(np.unravel_index(np.arange(n), oriented), axis=1).astype(np.int64)


def placement_pairing_traffic(
    dims: Sequence[int], oriented: Sequence[int], offset: Coord
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The job's bisection-pairing traffic in *machine* coordinates: every
    cell of the placed cuboid sends unit volume to its cuboid-antipode
    (offset by ``oriented // 2`` within the cuboid, wrapped cuboid-locally).

    Note this pattern cannot interfere across placements: pairing distances
    are at most ``ceil(w/2) <= ceil(a/2)`` per ring, so under minimal DOR
    the traffic never leaves the cuboid's own cells except via exact-half
    ties on a ``w == a - 1`` span — and the single foreign ring position
    that touches is too narrow for any neighbour to route over (pinned in
    ``tests/test_placement.py::test_pairing_traffic_is_isolated``).  It is
    the *intra*-partition contention model; use all-to-all for the
    cross-placement score.
    """
    dims = tuple(int(a) for a in dims)
    oriented = tuple(int(w) for w in oriented)
    rel = _relative_cells(oriented)
    half = np.asarray([w // 2 for w in oriented], dtype=np.int64)
    dst_rel = (rel + half) % np.asarray(oriented, dtype=np.int64)
    off = np.asarray(offset, dtype=np.int64)
    d = np.asarray(dims, dtype=np.int64)
    src = (rel + off) % d
    dst = (dst_rel + off) % d
    keep = ~(src == dst).all(axis=1)
    return src[keep], dst[keep], np.ones(int(keep.sum()), dtype=np.float64)


def placement_all_to_all_traffic(
    dims: Sequence[int], oriented: Sequence[int], offset: Coord
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Intra-job all-to-all in machine coordinates, volume ``1/n`` per
    ordered pair so every cell injects ~unit volume regardless of job size.

    This is the contention-scoring pattern: messages between cells at
    within-span distance beyond half the ring route the short way around —
    *through* foreign territory — so placements with long spans genuinely
    load links that other placements use.
    """
    dims = tuple(int(a) for a in dims)
    oriented = tuple(int(w) for w in oriented)
    n = int(np.prod(oriented))
    rel = _relative_cells(oriented)
    off = np.asarray(offset, dtype=np.int64)
    d = np.asarray(dims, dtype=np.int64)
    cells = (rel + off) % d
    si = np.repeat(np.arange(n), n)
    di = np.tile(np.arange(n), n)
    keep = si != di
    si, di = si[keep], di[keep]
    vol = np.full(si.shape[0], 1.0 / n, dtype=np.float64)
    return cells[si], cells[di], vol


def placement_loads(
    dims: Sequence[int],
    oriented: Sequence[int],
    offset: Coord,
    pattern: str = "all-to-all",
) -> np.ndarray:
    """Link loads of the placement's traffic on the machine torus.

    ``pattern``: ``"all-to-all"`` (the cross-placement contention model,
    default) or ``"pairing"`` (the paper's intra-partition benchmark).

    Loads are translation-covariant, so the all-to-all path rolls the
    memoised origin field instead of re-routing — do not mutate the
    returned array (it may be the cache itself, which is write-locked).
    """
    dims = tuple(int(a) for a in dims)
    if pattern == "all-to-all":
        base = base_loads(dims, tuple(oriented))
        off = tuple(int(o) % a for o, a in zip(offset, dims))
        if not any(off):
            return base
        return np.roll(base, off, axis=tuple(range(2, 2 + len(dims))))
    if pattern != "pairing":
        raise ValueError(f"unknown traffic pattern {pattern!r}")
    from .routing import route_dor

    src, dst, vol = placement_pairing_traffic(dims, oriented, offset)
    if src.shape[0] == 0:
        return np.zeros((len(dims), 2) + dims)
    return route_dor(dims, src, dst, vol)


def shared_link_contention(job_loads: np.ndarray, background: np.ndarray) -> float:
    """Traffic volume the job routes over links already carrying neighbour
    traffic — the avoidable-interference proxy used for scoring."""
    return float(job_loads[background > 0.0].sum())


@dataclass(frozen=True)
class ScoredPlacement:
    """One scored candidate; :func:`best_placement` picks the minimum of
    (contention, -contact, orientation, offset)."""

    oriented: Tuple[int, ...]
    offset: Coord
    bisection: int  # of the canonical geometry (orientation-invariant)
    contact: int  # occupied cells touching the placement's shell
    contention: float  # job traffic on links shared with neighbours

    spilling: bool = False  # some span routes beyond its own cells


def is_spilling(oriented: Sequence[int], dims: Sequence[int]) -> bool:
    """Whether any span of the orientation routes all-to-all traffic outside
    its own cells: within-span distances reach ``w - 1``, which routes the
    short way around — through foreign territory — when it exceeds half the
    ring (``2w - 2 > a``), and *also* at exactly half (``2w - 2 == a``)
    because the default split-tie routing sends half that volume backward.
    A span covering the full ring wraps internally and never spills."""
    return any(2 * w - 2 >= a and w < a for w, a in zip(oriented, dims))


def fabric_can_interfere(dims: Sequence[int]) -> bool:
    """Whether any two *disjoint* cuboid placements can share a link on this
    machine.  Sharing needs a spilling span (``2w - 2 >= a``, ``w < a``)
    *and* a partner with its own dim-k traffic inside the spill corridor —
    extent >= 2 over the ``a - w`` free positions, so ``w <= a - 2``.  Both
    hold for some ``w`` iff the ring has length >= 6: Mira-class tori
    (rings <= 4) are contention-isolated for every cuboid workload, while
    JUQUEEN's 7-ring and 16-wide TPU pods are not.  (Single spans can still
    *spill* on rings of 4 and 5 — ``is_spilling`` — but no disjoint
    neighbour can route over the corridor.)"""
    return any(a >= 6 for a in dims)


def base_loads(dims: Geometry, oriented: Tuple[int, ...]) -> np.ndarray:
    """The job's all-to-all load field for a placement at the origin.

    Loads translate with the placement (torus translation invariance), so
    this one field serves every offset of the orientation.  Memoised —
    callers must not mutate the returned array.
    """
    return _base_loads_cached(tuple(int(a) for a in dims), tuple(int(w) for w in oriented))


@lru_cache(maxsize=512)
def _base_loads_cached(dims: Geometry, oriented: Tuple[int, ...]) -> np.ndarray:
    from .routing import route_dor

    src, dst, vol = placement_all_to_all_traffic(dims, oriented, (0,) * len(dims))
    if src.shape[0] == 0:
        arr = np.zeros((len(dims), 2) + dims)
    else:
        arr = route_dor(dims, src, dst, vol)
    arr.setflags(write=False)
    return arr


def int_base_loads(dims: Geometry, oriented: Tuple[int, ...]) -> np.ndarray:
    """The placement's all-to-all load field at the origin, scaled by
    ``2 * n`` (n = cells in the placement) so every value is an exact
    ``int64``.

    :func:`placement_loads` routes volume ``1/n`` per ordered pair, so raw
    per-link loads are multiples of ``1/(2n)`` (the ``1/2`` from antipodal
    tie splitting) — not exactly representable when ``n`` is not a power of
    two, which is why float accumulation across placements of different
    sizes can never be subtracted back out bit-exactly.  Routing the same
    messages with volume ``2`` instead makes every contribution — including
    split ties — an integer, so the field is exact and placement sums live
    in int64 where addition *and subtraction* are lossless:
    ``placement_loads(...) == int_base_loads(...) / (2 * n)`` up to one
    float rounding, with identical support.  This is the representation
    :class:`repro.network.allocation.MachineState` maintains incrementally.
    Memoised — callers must not mutate the returned array.
    """
    return _int_base_loads_cached(
        tuple(int(a) for a in dims), tuple(int(w) for w in oriented)
    )


@lru_cache(maxsize=512)
def _int_base_loads_cached(dims: Geometry, oriented: Tuple[int, ...]) -> np.ndarray:
    from .routing import route_dor

    src, dst, _ = placement_all_to_all_traffic(dims, oriented, (0,) * len(dims))
    if src.shape[0] == 0:
        arr = np.zeros((len(dims), 2) + dims, dtype=np.int64)
    else:
        # Volume 2 per ordered pair: whole messages contribute 2 per link,
        # split antipodal ties 1 per direction — every partial sum is an
        # integer-valued float (exact below 2**53), so rint is a no-op
        # safeguard rather than a rounding step.
        raw = route_dor(dims, src, dst, np.full(src.shape[0], 2.0))
        arr = np.rint(raw).astype(np.int64)
    arr.setflags(write=False)
    return arr


def int_placement_loads(
    dims: Sequence[int], oriented: Sequence[int], offset: Coord
) -> np.ndarray:
    """:func:`int_base_loads` translated to ``offset`` (loads are
    translation-covariant, so this is a roll of the memoised origin field).
    Do not mutate the returned array — at the origin it *is* the cache."""
    dims = tuple(int(a) for a in dims)
    base = int_base_loads(dims, tuple(int(w) for w in oriented))
    off = tuple(int(o) % a for o, a in zip(offset, dims))
    if not any(off):
        return base
    return np.roll(base, off, axis=tuple(range(2, 2 + len(dims))))


def interference_mask(
    grid: np.ndarray, background_loads: Optional[np.ndarray] = None
) -> np.ndarray:
    """(D, 2, *dims) boolean mask of links a new job should avoid loading:
    links leaving an occupied cell (transit through foreign territory —
    interference whether or not the owner is routing there *right now*),
    plus links already carrying background traffic (e.g. a neighbour's
    spill over free corridor cells)."""
    D = len(grid.shape)
    mask = np.broadcast_to(grid.astype(bool), (D, 2) + grid.shape).copy()
    if background_loads is not None:
        mask |= background_loads > 0.0
    return mask


def _mask_plane_ffts(mask: np.ndarray) -> List[List[Optional[np.ndarray]]]:
    """FFTs of each (dimension, direction) mask plane (None where the plane
    is empty) — hoisted out of :func:`contention_field` so a multi-
    orientation search transforms the mask once, not once per orientation."""
    D = mask.shape[0]
    return [
        [
            np.fft.fftn(mask[k, d].astype(np.float64)) if mask[k, d].any() else None
            for d in range(2)
        ]
        for k in range(D)
    ]


def contention_field(
    dims: Sequence[int],
    oriented: Sequence[int],
    mask: np.ndarray,
    mask_ffts: Optional[List[List[Optional[np.ndarray]]]] = None,
    backend: Optional[str] = None,
) -> np.ndarray:
    """Predicted interference for *every* offset of an orientation in one
    shot: the job's traffic volume over masked links
    (:func:`interference_mask`).

    The job's load field translates with its offset, so its overlap with the
    mask is a circular cross-correlation, evaluated per (dimension,
    direction) load plane with FFTs:

        C[o] = sum_{k,d,v} J[k,d][(v - o) mod dims] * mask[k,d][v]

    O(D * N log N) for all N offsets — the same trick that finds the free
    translates, applied to the score.  A candidate's own cells are free in
    the pre-commit grid, so its internal links never self-count.  Values
    carry FFT round-off (~1e-12); rank with a tolerance
    (:func:`best_placement` rounds to 9 decimals).

    ``backend="xla"`` computes all (dimension, direction) planes in one
    compiled batched FFT (``mask_ffts`` is ignored there — the compiled
    path transforms the mask in the same call); both backends agree to
    FFT round-off, below the 9-decimal ranking tolerance.
    """
    dims = tuple(int(a) for a in dims)
    if resolve_backend(backend) == "xla":
        from .backend import xla_contention_field

        return xla_contention_field(dims, tuple(oriented), mask)
    if mask_ffts is None:
        mask_ffts = _mask_plane_ffts(mask)
    J = base_loads(dims, tuple(oriented))
    out = np.zeros(dims, dtype=np.float64)
    for k in range(len(dims)):
        for d in range(2):
            F = mask_ffts[k][d]
            plane = J[k, d]
            if F is None or not plane.any():
                continue
            corr = np.fft.ifftn(F * np.conj(np.fft.fftn(plane)))
            out += np.real(corr)
    return np.maximum(out, 0.0)


def best_placement(
    grid: np.ndarray,
    geometry: Sequence[int],
    background_loads: Optional[np.ndarray] = None,
    backend: Optional[str] = None,
) -> Optional[ScoredPlacement]:
    """Scored placement of one geometry: among all free translates of all
    orientations, minimise predicted interference (the job's all-to-all
    traffic over links leaving occupied cells or already carrying the
    existing placements' traffic, evaluated for every candidate via
    :func:`contention_field`).  Ties break toward the snuggest candidate
    (max :func:`shell_contact`) on spill-free fabrics, then the reference
    scan order, so the choice is fully deterministic.

    ``background_loads`` is the (D, 2, *dims) load tensor of the existing
    placements' traffic (see :func:`placement_loads`); None or all-zero
    makes contention vanish and the choice purely contact-driven.

    With tracing enabled (:mod:`repro.obs`) the search records a
    ``placement.search`` span annotated with the winning orientation /
    offset / contention; the choice is identical either way.
    """
    if not _TRACER.enabled:
        return _best_placement_impl(grid, geometry, background_loads, backend)
    with _TRACER.span(
        "placement.search", geometry=tuple(int(g) for g in geometry)
    ) as span:
        out = _best_placement_impl(grid, geometry, background_loads, backend)
        if out is not None:
            span.annotate(
                oriented=out.oriented, offset=out.offset, contention=out.contention
            )
        else:
            span.annotate(placed=False)
        return out


def _best_placement_impl(
    grid: np.ndarray,
    geometry: Sequence[int],
    background_loads: Optional[np.ndarray],
    backend: Optional[str],
) -> Optional[ScoredPlacement]:
    dims = grid.shape
    bis = bisection_links(pad_geometry(geometry, len(dims)))
    mask = interference_mask(grid, background_loads)
    have_bg = bool(mask.any())
    mask_ffts = _mask_plane_ffts(mask) if have_bg else None
    # Snug (max-contact) tie-breaking keeps the free set contiguous, but on
    # fabrics where placements can share links it measurably *increases*
    # realised interference: snug-packed layouts drift away from the
    # origin-aligned packings that stack spill corridors on the same ring
    # positions, and later strips get forced through neighbours.  On
    # interference-free fabrics (every ring <= 5, e.g. Mira's midplane
    # torus) adjacency is provably free, so the anti-fragmentation
    # tie-break is enabled exactly there.
    use_contact = not fabric_can_interfere(dims)
    best: Optional[Tuple[tuple, ScoredPlacement]] = None
    for perm, free in iter_free_placements(grid, geometry):
        flat = np.flatnonzero(free.ravel(order="C"))
        if not flat.size:
            continue
        contact = shell_contact(grid, perm).ravel(order="C")[flat]
        if have_bg:
            cont = contention_field(
                dims, perm, mask, mask_ffts, backend=backend
            ).ravel(order="C")[flat]
        else:
            cont = np.zeros(flat.shape[0])
        rank_contact = contact if use_contact else np.zeros_like(contact)
        # argmin over (contention, -contact, C-order offset) within this
        # orientation, without materialising Python tuples per candidate.
        order = np.lexsort((flat, -rank_contact, np.round(cont, 9)))
        i = order[0]
        offset = tuple(int(x) for x in np.unravel_index(flat[i], dims))
        # Report the same rounded value used for ranking, so FFT round-off
        # (~1e-16) never surfaces as spurious nonzero contention.
        contention = round(float(cont[i]), 9)
        key = (contention, -int(rank_contact[i]), perm, offset)
        if best is None or key < best[0]:
            best = (
                key,
                ScoredPlacement(
                    oriented=perm,
                    offset=offset,
                    bisection=bis,
                    contact=int(contact[i]),
                    contention=contention,
                    spilling=is_spilling(perm, dims),
                ),
            )
    return best[1] if best else None
