"""Compiled (``jax.jit``) backends for the hot network engines.

Every analysis layer of :mod:`repro.network` runs on exact NumPy — the
default and the oracle.  This module ports the four hot inner passes to
XLA behind a ``KernelType``-style dispatch (mirroring the kernel layers'
reference/compiled idiom), so consumers can score thousands of
(geometry, mapping, traffic) candidates per compiled call instead of one
per Python-loop iteration:

=============  =============================================================
``numpy``      The existing exact engines (default).  Always available.
``xla``        ``jax.jit`` ports: the DOR difference-array link-load tensor
               (:func:`xla_route_loads`), max-min progressive filling as a
               fixed-shape masked ``lax.while_loop`` (:func:`prepare_drain`
               / :func:`drain`), the FFT contention cross-correlation
               (:func:`xla_contention_field`), the closed-form cut scoring
               (:func:`xla_cut_scores`), and the ``vmap``-batched candidate
               scorer (:func:`score_candidates`).
``pallas``     Reserved slot for a Pallas port of the bincount/segment-sum
               inner loop of progressive filling; raises
               ``NotImplementedError`` until it lands.
=============  =============================================================

Selection: every threaded entry point takes ``backend=None``, resolved by
:func:`resolve_backend` — an explicit argument wins, else the
``REPRO_NETWORK_BACKEND`` environment variable, else ``numpy``.

Exactness contract.  The xla backend pins ``jax_enable_x64`` (via
:mod:`repro.utils.env`) on first use, because parity is bit-meaningful:
link loads are sums of integer (or tie-halved dyadic) volumes, so the
``numpy`` and ``xla`` load tensors are **equal exactly**, not merely
close.  Max-min rates and makespans agree to <= 1e-9 relative (XLA's
multiply-add fusion reorders a handful of float ops); the property suite
in ``tests/test_backend.py`` pins both, and
``benchmarks/bench_backend.py`` gates the >= 10x throughput claims.

What stays NumPy and why: host-side path building and ELL compaction
(irregular ``np.unique``/argsort prep), greedy refinement and first-fit
(small irregular calls where dispatch overhead dominates), and every
result-packaging step.  See DESIGN.md "Compiled backends".

>>> resolve_backend(None) if "REPRO_NETWORK_BACKEND" not in __import__("os").environ else "numpy"
'numpy'
>>> resolve_backend("numpy")
'numpy'
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from functools import lru_cache
from typing import Optional, Sequence, Tuple

import numpy as np

from ..obs.metrics import REGISTRY as _METRICS
from ..obs.trace import TRACER as _TRACER
from .geometry import volume
from ..utils.env import have_jax

__all__ = [
    "BACKENDS",
    "HAVE_JAX",
    "DrainPlan",
    "drain",
    "drain_batch",
    "prepare_drain",
    "resolve_backend",
    "score_candidates",
    "xla_contention_field",
    "xla_cut_scores",
    "xla_route_loads",
]

#: Recognised backend names, in preference order.
BACKENDS = ("numpy", "xla", "pallas")

#: Whether jax is importable (spec lookup only; importing this module never
#: imports jax).
HAVE_JAX = have_jax()

_EPS = 1e-12

_JAX: Optional[tuple] = None


def _jax():
    """Import jax lazily, enabling x64 first (the exactness contract)."""
    global _JAX
    if _JAX is None:
        from ..utils.env import jax_enable_x64

        jax_enable_x64(True)
        import jax
        import jax.numpy as jnp

        _JAX = (jax, jnp)
    return _JAX


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve a backend name: explicit argument, else the
    ``REPRO_NETWORK_BACKEND`` environment variable, else ``"numpy"``.

    Raises ``ValueError`` for unknown names, ``NotImplementedError`` for
    the reserved ``"pallas"`` slot, and ``RuntimeError`` for ``"xla"``
    when jax is not installed — so a mis-set environment variable fails
    loudly at the first dispatch, not with silent numpy fallback.

    >>> resolve_backend("numpy")
    'numpy'
    """
    if backend is None:
        backend = os.environ.get("REPRO_NETWORK_BACKEND") or "numpy"
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if backend == "pallas":
        raise NotImplementedError(
            "the pallas backend is a reserved slot for the progressive-filling "
            "inner loop; use 'numpy' or 'xla'"
        )
    if backend == "xla" and not HAVE_JAX:
        raise RuntimeError(
            "backend 'xla' requires jax; install jax[cpu] or use backend='numpy'"
        )
    return backend


def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


# First-touch jit signatures, for the compile-vs-execute telemetry split:
# a dispatch whose (function, static args, padded shapes) signature is new
# triggers an XLA compile, so its span is annotated phase="compile" and
# the ``backend.jit_compiles`` counter increments; repeat signatures are
# phase="execute".  (lru_cache eviction can re-compile a signature seen
# long ago — the counter tracks first touches, the steady-state measure.)
_JIT_SIGNATURES: set = set()


def _dispatch(name: str, sig: tuple, call, **annotations):
    """Run one compiled-backend dispatch with telemetry: jit-compile /
    dispatch counters in :data:`repro.obs.REGISTRY` (always on — one dict
    update per coarse call) and a ``backend.<name>`` span with the
    compile-vs-execute phase when tracing is enabled."""
    compiling = sig not in _JIT_SIGNATURES
    if compiling:
        _JIT_SIGNATURES.add(sig)
        _METRICS.counter("backend.jit_compiles", fn=name).incr()
    _METRICS.counter("backend.dispatches", fn=name).incr()
    if not _TRACER.enabled:
        return call()
    with _TRACER.span(
        f"backend.{name}",
        phase="compile" if compiling else "execute",
        **annotations,
    ):
        return call()


# ---------------------------------------------------------------------------
# (1) DOR link loads — the difference-array/bincount tensor, jitted.
# ---------------------------------------------------------------------------
@lru_cache(maxsize=128)
def _route_loads_fn(dims: Tuple[int, ...], split_ties: bool):
    """Jitted mirror of :func:`repro.network.routing.route_dor` for one
    (dims, split_ties) signature; recompiles per padded message count."""
    jax, jnp = _jax()
    D = len(dims)

    def fn(src, dst, vol):
        per_dim = []
        for k, a in enumerate(dims):
            if a == 1:
                per_dim.append(jnp.zeros((2,) + dims, dtype=jnp.float64))
                continue
            other_dims = dims[:k] + dims[k + 1:]
            n_lines = volume(other_dims) if other_dims else 1
            strides = []
            acc = 1
            for w in reversed(other_dims):
                strides.append(acc)
                acc *= w
            strides = list(reversed(strides))
            line = jnp.zeros(src.shape[0], dtype=jnp.int64)
            pos_i = 0
            for j in range(D):
                if j == k:
                    continue
                cj = dst[:, j] if j < k else src[:, j]
                line = line + cj * strides[pos_i]
                pos_i += 1

            s = src[:, k]
            delta = (dst[:, k] - s) % a
            rev = a - delta
            hops = jnp.minimum(delta, rev)
            tie = delta * 2 == a
            fwd = delta <= rev
            v1 = jnp.where(tie, vol * (0.5 if split_ties else 1.0), vol)
            v1 = jnp.where(hops == 0, 0.0, v1)
            bstart = (s - hops + 1) % a
            start_p = jnp.where(fwd, s, bstart)
            base_p = line * a + jnp.where(fwd, 0, n_lines * a)
            segments = [(start_p, v1, base_p)]
            if split_ties:
                # Secondary segment: the backward half of each split tie
                # (zero-weight for every non-tie message — shapes stay
                # static, the loads do not change).
                v2 = jnp.where(tie, vol * 0.5, 0.0)
                segments.append((bstart, v2, n_lines * a + line * a))
            idx_parts, w_parts = [], []
            for start, v, base in segments:
                end = start + hops
                em = jnp.where(end >= a, end - a, end)
                wrapped = end > a
                idx_parts += [base + start, base + em, base]
                w_parts += [v, jnp.where(em == 0, 0.0, -v), jnp.where(wrapped, v, 0.0)]
            idx = jnp.concatenate(idx_parts)
            w = jnp.concatenate(w_parts)
            diff = jnp.zeros(2 * n_lines * a, dtype=jnp.float64).at[idx].add(w)
            ring = jnp.cumsum(diff.reshape(2, n_lines, a), axis=-1)
            ring = jnp.maximum(ring, 0.0)
            full = ring.reshape((2,) + other_dims + (a,))
            per_dim.append(jnp.moveaxis(full, -1, 1 + k))
        return jnp.stack(per_dim, axis=0)

    return jax.jit(fn)


def xla_route_loads(
    dims: Sequence[int],
    src: np.ndarray,
    dst: np.ndarray,
    vol,
    split_ties: bool = True,
) -> np.ndarray:
    """XLA port of :func:`repro.network.routing.route_dor`: the
    ``(D, 2, *dims)`` per-directed-link load tensor of a message batch.

    Message counts are padded to the next power of two with zero-volume
    messages (which route nowhere), so the number of distinct compilations
    is bounded by ``dims x log2(M)`` rather than one per batch size.  For
    integer (or tie-halved dyadic) volumes the result equals the NumPy
    engine's tensor **exactly**; arbitrary float volumes agree to float64
    summation order.
    """
    dims = tuple(int(a) for a in dims)
    D = len(dims)
    src = np.atleast_2d(np.asarray(src, dtype=np.int64))
    dst = np.atleast_2d(np.asarray(dst, dtype=np.int64))
    if src.shape != dst.shape or src.shape[1] != D:
        raise ValueError(
            f"src/dst must have shape (M, {D}); got {src.shape}/{dst.shape}"
        )
    M = src.shape[0]
    vol = np.broadcast_to(np.asarray(vol, dtype=np.float64), (M,))
    if M == 0:
        return np.zeros((D, 2) + dims, dtype=np.float64)
    Mp = _next_pow2(M)
    if Mp != M:
        pad = Mp - M
        src = np.concatenate([src, np.zeros((pad, D), dtype=np.int64)])
        dst = np.concatenate([dst, np.zeros((pad, D), dtype=np.int64)])
        vol = np.concatenate([vol, np.zeros(pad)])
    fn = _route_loads_fn(dims, bool(split_ties))
    _METRICS.counter("backend.padding_bucket", bucket=Mp).incr()
    return _dispatch(
        "route_loads",
        ("route_loads", dims, bool(split_ties), Mp),
        lambda: np.asarray(fn(src, dst, vol)),
        messages=M,
        bucket=Mp,
    )


# ---------------------------------------------------------------------------
# (2) Max-min progressive filling — fixed-shape ELL drain, jitted.
# ---------------------------------------------------------------------------
@dataclass
class DrainPlan:
    """Compiled-drain form of one routed scenario: the link x flow
    incidence compacted to ELL (fixed-width padded index lists) so the
    progressive-filling loop has static shapes.

    ``lf[l]`` lists the flows crossing used link ``l`` (padded with the
    dummy flow ``n_flows``); ``fl[f]`` the used links of flow ``f``
    (padded with the dummy link ``n_links_used``).  ``vol`` is the
    original scenario's subflow volumes — :func:`drain` accepts per-lane
    overrides, so one plan serves every translate of a
    translation-invariant scenario family (same incidence structure,
    different volumes).
    """

    dims: Tuple[int, ...]
    n_flows: int
    n_links_used: int
    lf: object  # (Lu, d) int32 device array
    fl: object  # (F, h) int32 device array
    cap: object  # (Lu,) float64 device array
    has_links: np.ndarray  # (F,) bool
    vol: np.ndarray  # (F,) float64 — the plan's own scenario volumes
    max_iters: int


def prepare_drain(paths, link_bw: float = 1.0, double_link_on_2: bool = True) -> DrainPlan:
    """Compact a :class:`repro.network.netsim.FlowPaths` into a
    :class:`DrainPlan` (host-side ``np.unique``/argsort work — the
    irregular prep that stays NumPy by design)."""
    from .netsim import link_capacities

    if link_bw <= 0.0:
        raise ValueError("link_bw must be positive")
    _, jnp = _jax()
    if getattr(paths, "capacities", None) is not None:
        # Explicit-capacity fabrics (HyperX) carry their own dense slot
        # capacities in units of link_bw; the torus double-link rule does
        # not apply to them.
        capfull = np.asarray(paths.capacities, dtype=np.float64) * link_bw
    else:
        capfull = link_capacities(paths.dims, link_bw, double_link_on_2).ravel()
    F = paths.n_flows
    link = paths.link_ids
    flow = paths.flow_ids
    uniq, inv = np.unique(link, return_inverse=True)
    Lu = int(uniq.shape[0])
    cap = capfull[uniq]
    order = np.argsort(inv, kind="stable")
    li = inv[order]
    fi = flow[order]
    starts = np.searchsorted(li, np.arange(Lu))
    pos = np.arange(li.shape[0]) - starts[li]
    d = int(pos.max()) + 1 if li.shape[0] else 0
    lf = np.full((Lu, max(d, 1)), F, dtype=np.int32)
    if li.shape[0]:
        lf[li, pos] = fi
    order2 = np.argsort(flow, kind="stable")
    fi2 = flow[order2]
    li2 = inv[order2]
    s2 = np.searchsorted(fi2, np.arange(F))
    pos2 = np.arange(fi2.shape[0]) - s2[fi2]
    h = int(pos2.max()) + 1 if fi2.shape[0] else 0
    fl = np.full((F, max(h, 1)), Lu, dtype=np.int32)
    if fi2.shape[0]:
        fl[fi2, pos2] = li2
    has_links = np.zeros(F, dtype=bool)
    has_links[flow] = True
    return DrainPlan(
        dims=paths.dims,
        n_flows=F,
        n_links_used=Lu,
        lf=jnp.asarray(lf),
        fl=jnp.asarray(fl),
        cap=jnp.asarray(cap),
        has_links=has_links,
        vol=np.asarray(paths.vol, dtype=np.float64),
        max_iters=Lu + 1,
    )


_DRAIN = None


def _drain_fn():
    """The jitted single-scenario drain (built once; specialises per
    (F, Lu, d, h, max_iters, max_steps) signature)."""
    global _DRAIN
    if _DRAIN is not None:
        return _DRAIN
    jax, jnp = _jax()

    def _drain_one(lf, fl, cap, vol, active0, max_iters, max_steps):
        F = vol.shape[0]
        tolv = jnp.maximum(vol, 1.0) * _EPS

        def rates_of(growing0):
            # Progressive filling with masked convergence: every unfrozen
            # flow grows at the common increment, bottleneck links saturate
            # and freeze their flows; `done` masks out iterations after
            # convergence so the fixed loop bound compiles cleanly.
            def cond(s):
                return (s[0] < max_iters) & (~s[4])

            def body(s):
                it, growing, cap_rem, rate, done = s
                gpad = jnp.concatenate([growing, jnp.zeros(1, bool)])
                cnt = gpad[lf].sum(axis=1).astype(jnp.float64)
                open_ = cnt > 0
                openany = open_.any()
                share = jnp.where(open_, cap_rem / jnp.where(open_, cnt, 1.0), jnp.inf)
                inc = share.min()
                rate2 = jnp.where(growing, rate + inc, rate)
                cap2 = jnp.where(open_, cap_rem - inc * cnt, cap_rem)
                sat = open_ & (share <= inc * (1.0 + 1e-9))
                spad = jnp.concatenate([sat, jnp.zeros(1, bool)])
                growing2 = growing & ~spad[fl].any(axis=1)
                done2 = (~openany) | (~growing2.any())
                return (
                    it + 1,
                    jnp.where(openany, growing2, growing),
                    jnp.where(openany, cap2, cap_rem),
                    jnp.where(openany, rate2, rate),
                    done2,
                )

            s0 = (0, growing0, cap, jnp.zeros(F), ~growing0.any())
            return jax.lax.while_loop(cond, body, s0)[3]

        def cond(s):
            return s[3].any() & (s[4] < max_steps)

        def body(s):
            t, remaining, fc, active, steps = s
            rates = rates_of(active)
            ratio = jnp.where(active, remaining / jnp.where(active, rates, 1.0), jnp.inf)
            amin = jnp.argmin(ratio)
            dt = ratio[amin]
            t2 = t + dt
            rem2 = jnp.where(active, remaining - rates * dt, remaining).at[amin].set(0.0)
            finished = active & (rem2 <= tolv)
            return (t2, rem2, jnp.where(finished, t2, fc), active & ~finished, steps + 1)

        s0 = (0.0, vol + 0.0, jnp.zeros(F), active0, 0)
        _, _, fc, active, steps = jax.lax.while_loop(cond, body, s0)
        return fc, steps, active.any()

    _DRAIN = jax.jit(_drain_one, static_argnames=("max_iters", "max_steps"))
    return _DRAIN


def drain(
    plan: DrainPlan,
    vol: Optional[np.ndarray] = None,
    max_steps: int = 100_000,
) -> Tuple[np.ndarray, int]:
    """Drain one scenario through the compiled max-min simulator.

    Returns ``(flow_completion, steps)`` matching
    :func:`repro.network.netsim.simulate_flows` (makespans agree to
    <= 1e-9 relative; the outer loop's completion order is identical).
    ``vol`` overrides the plan's subflow volumes (same flow ordering) —
    the batched-scenario idiom.  Raises ``RuntimeError`` past
    ``max_steps``, mirroring the NumPy engine.
    """
    v = plan.vol if vol is None else np.asarray(vol, dtype=np.float64)
    if v.shape != (plan.n_flows,):
        raise ValueError(f"vol must have shape ({plan.n_flows},); got {v.shape}")
    active0 = plan.has_links & (v > _EPS)
    if plan.n_flows == 0 or plan.n_links_used == 0 or not active0.any():
        return np.zeros(plan.n_flows), 0
    fn = _drain_fn()
    fc, steps, unfinished = _dispatch(
        "drain",
        (
            "drain",
            plan.n_flows,
            plan.n_links_used,
            tuple(int(s) for s in plan.lf.shape),
            tuple(int(s) for s in plan.fl.shape),
            plan.max_iters,
            int(max_steps),
        ),
        lambda: fn(
            plan.lf, plan.fl, plan.cap, v, active0,
            max_iters=plan.max_iters, max_steps=int(max_steps),
        ),
        flows=plan.n_flows,
        links=plan.n_links_used,
    )
    if bool(unfinished):
        raise RuntimeError(f"flow simulation exceeded {max_steps} steps")
    return np.asarray(fc), int(steps)


def drain_batch(
    plan: DrainPlan,
    vols: np.ndarray,
    max_steps: int = 100_000,
) -> Tuple[np.ndarray, np.ndarray]:
    """Drain a batch of volume lanes through one plan: ``vols`` is
    ``(B, F)``, one scenario per row, all sharing the plan's incidence
    structure (e.g. translates of one job geometry).

    Lanes run through the jitted single-scenario drain in a host loop —
    measured faster than any ``vmap``/batched layout on CPU, because the
    per-scenario working set stays cache-resident and batched lanes all
    pay the slowest lane's iteration count.  Returns
    ``(flow_completion (B, F), steps (B,))``.
    """
    vols = np.asarray(vols, dtype=np.float64)
    if vols.ndim != 2 or vols.shape[1] != plan.n_flows:
        raise ValueError(f"vols must have shape (B, {plan.n_flows}); got {vols.shape}")
    B = vols.shape[0]
    fc = np.zeros((B, plan.n_flows))
    steps = np.zeros(B, dtype=np.int64)
    for i in range(B):
        fc[i], steps[i] = drain(plan, vols[i], max_steps=max_steps)
    return fc, steps


# ---------------------------------------------------------------------------
# (vmap entry point) batched candidate scoring.
# ---------------------------------------------------------------------------
@lru_cache(maxsize=128)
def _score_fn(dims: Tuple[int, ...], split_ties: bool, double_link_on_2: bool):
    """Jitted, vmapped (congestion, dilation) scorer for one machine
    signature; specialises per (B, n_ranks, M) shape."""
    jax, jnp = _jax()
    D = len(dims)

    def one(c, rsrc, rdst, vol):
        src = c[rsrc]
        dst = c[rdst]
        cong = jnp.zeros(())
        dil = jnp.zeros(())
        for k, a in enumerate(dims):
            s = src[:, k]
            delta = (dst[:, k] - s) % a
            hops = jnp.minimum(delta, a - delta)
            dil = dil + (vol * hops).sum()
            if a == 1:
                continue
            other_dims = dims[:k] + dims[k + 1:]
            n_lines = volume(other_dims) if other_dims else 1
            strides = []
            acc = 1
            for w in reversed(other_dims):
                strides.append(acc)
                acc *= w
            strides = list(reversed(strides))
            line = jnp.zeros(rsrc.shape[0], dtype=jnp.int64)
            pos_i = 0
            for j in range(D):
                if j == k:
                    continue
                cj = dst[:, j] if j < k else src[:, j]
                line = line + cj * strides[pos_i]
                pos_i += 1
            tie = delta * 2 == a
            fwd = delta <= a - delta
            v1 = jnp.where(tie, vol * (0.5 if split_ties else 1.0), vol)
            v1 = jnp.where(hops == 0, 0.0, v1)
            bstart = (s - hops + 1) % a
            wp = jnp.where(fwd, v1, 0.0)
            wm = jnp.where(~fwd, v1, 0.0)
            if split_ties:
                wm = wm + jnp.where(tie, vol * 0.5, 0.0)
            pos = jnp.arange(a)
            covp = ((pos[None, :] - s[:, None]) % a) < hops[:, None]
            covm = ((pos[None, :] - bstart[:, None]) % a) < hops[:, None]
            onehot = (line[:, None] == jnp.arange(n_lines)[None, :]).astype(jnp.float64)
            pp = onehot.T @ (wp[:, None] * covp)
            pm = onehot.T @ (wm[:, None] * covm)
            scale = 0.5 if (a == 2 and double_link_on_2) else 1.0
            cong = jnp.maximum(cong, scale * jnp.maximum(pp.max(), pm.max()))
        return cong, dil

    return jax.jit(jax.vmap(one, in_axes=(0, None, None, None)))


def score_candidates(
    dims: Sequence[int],
    coords: np.ndarray,
    traffic,
    split_ties: bool = True,
    double_link_on_2: bool = True,
    backend: Optional[str] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Score a batch of candidate rank mappings in one compiled call.

    ``coords`` is ``(B, n_ranks, D)`` — B candidate rank->cell embeddings
    on the ``dims`` machine — and ``traffic`` the shared rank-space
    ``(src_rank, dst_rank, vol)``.  Returns ``(congestion, dilation)``
    arrays of shape ``(B,)``, row-identical to calling
    :func:`repro.network.mapping.score_mapping` per candidate (exactly —
    the property suite pins it).  The ``xla`` backend evaluates all B
    candidates under one ``jax.vmap``-of-``jit``; ``numpy`` runs the
    sequential oracle loop.  Memory for the xla path is
    O(B * M * n_lines) per dimension — sized for advisor-scale jobs
    (hundreds of ranks), not full-machine permutations.
    """
    backend = resolve_backend(backend)
    dims = tuple(int(a) for a in dims)
    coords = np.asarray(coords, dtype=np.int64)
    if coords.ndim == 2:
        coords = coords[None]
    if coords.ndim != 3 or coords.shape[2] != len(dims):
        raise ValueError(
            f"coords must have shape (B, n_ranks, {len(dims)}); got {coords.shape}"
        )
    B = coords.shape[0]
    rsrc, rdst, vol = traffic
    rsrc = np.asarray(rsrc, dtype=np.int64)
    rdst = np.asarray(rdst, dtype=np.int64)
    if B == 0 or rsrc.shape[0] == 0:
        return np.zeros(B), np.zeros(B)
    vol = np.broadcast_to(np.asarray(vol, dtype=np.float64), rsrc.shape)
    if backend == "numpy":
        from .mapping import score_mapping

        cong = np.zeros(B)
        dil = np.zeros(B)
        for i in range(B):
            s = score_mapping(
                dims, coords[i], (rsrc, rdst, vol), split_ties, double_link_on_2
            )
            cong[i] = s.congestion
            dil[i] = s.dilation
        return cong, dil
    fn = _score_fn(dims, bool(split_ties), bool(double_link_on_2))
    cong, dil = _dispatch(
        "score_candidates",
        (
            "score_candidates",
            dims,
            bool(split_ties),
            bool(double_link_on_2),
            B,
            coords.shape[1],
            int(rsrc.shape[0]),
        ),
        lambda: fn(coords, rsrc, rdst, vol),
        candidates=B,
    )
    return np.asarray(cong), np.asarray(dil)


# ---------------------------------------------------------------------------
# (3) FFT contention cross-correlation.
# ---------------------------------------------------------------------------
@lru_cache(maxsize=64)
def _contention_fn(D: int):
    jax, jnp = _jax()
    axes = tuple(range(2, 2 + D))

    def fn(mask, J):
        FM = jnp.fft.fftn(mask, axes=axes)
        FJ = jnp.fft.fftn(J, axes=axes)
        corr = jnp.fft.ifftn(FM * jnp.conj(FJ), axes=axes)
        return jnp.maximum(jnp.real(corr).sum(axis=(0, 1)), 0.0)

    return jax.jit(fn)


def xla_contention_field(
    dims: Sequence[int], oriented: Sequence[int], mask: np.ndarray
) -> np.ndarray:
    """XLA port of :func:`repro.network.placement.contention_field`: the
    predicted interference of one orientation at every torus offset, as
    one batched FFT cross-correlation over all (dimension, direction)
    load planes.  Values agree with the NumPy engine to FFT round-off
    (~1e-12) — both sides rank with a 9-decimal rounding, so placement
    choices are identical."""
    dims = tuple(int(a) for a in dims)
    from .placement import base_loads

    J = base_loads(dims, tuple(int(w) for w in oriented))
    fn = _contention_fn(len(dims))
    return _dispatch(
        "contention_field",
        ("contention_field", dims),
        lambda: np.asarray(fn(np.asarray(mask, dtype=np.float64), J)),
    )


# ---------------------------------------------------------------------------
# (4) Closed-form cut scoring.
# ---------------------------------------------------------------------------
_CUT = None


def _cut_fn():
    global _CUT
    if _CUT is None:
        jax, jnp = _jax()

        def fn(S, av, two_t):
            return jnp.where(S == av[None, :], 0, two_t // S).sum(axis=1)

        _CUT = jax.jit(fn)
    return _CUT


def xla_cut_scores(dims: Sequence[int], assignments: np.ndarray, t: int) -> np.ndarray:
    """XLA port of the isoperimetry engine's closed-form cut evaluation:
    for each aligned side assignment ``S`` of a volume-``t`` cuboid, the
    exact cut ``sum_k (0 if S_k == dims_k else 2t / S_k)`` — int64
    arithmetic under x64, so the scores equal the NumPy engine's
    **exactly**."""
    av = np.asarray(tuple(int(a) for a in dims), dtype=np.int64)
    S = np.asarray(assignments, dtype=np.int64)
    if S.shape[0] == 0:
        return np.zeros(0, dtype=np.int64)
    out = _cut_fn()(S, av, np.int64(2 * int(t)))
    return np.asarray(out, dtype=np.int64)
