"""Event-sourced scheduler service over the allocation engine.

:func:`repro.network.allocation.simulate_queue` replayed a job list in one
batch loop; this module promotes that loop into an always-on service so
the paper's allocation policies can run online.  One
:class:`SchedulerService` owns a :class:`~repro.network.allocation.
MachineState`, a priority waiting queue, and a pending-event heap, and
exposes *events* as the only way state changes:

``Arrival``   a job enters the waiting queue (or is shed, see below).
``Start``     the policy placed a job; the record carries the placement.
``Complete``  a running job's duration elapsed; its cells free.
``Fail``      cells die: jobs on them are evacuated (a derived ``Preempt``
              per victim) and requeued with their remaining duration, and
              the cells leave the free pool until repaired.
``Preempt``   a running job is suspended (cells free, remaining duration
              retained) until an explicit ``Reclaim`` resumes it.
``Reclaim``   repairs failed cells and/or requeues a suspended job.
``Reject``    a request that cannot be placed even on an empty (degraded)
              machine, or an arrival shed by backpressure.

Every processed event is appended to :attr:`SchedulerService.log` — an
append-only, deterministically ordered record.  Replaying the ``input``
records of a log through a fresh service (:func:`replay_events`)
reproduces the run event-for-event, which is also how the batch
``simulate_queue`` is now implemented: it submits the sorted job list and
runs the service to quiescence — one event loop, not two.

**Event ordering.**  The event clock is float time, so "simultaneous" is a
tolerance question.  Events are processed in deterministic
``(time, kind, seq)`` order: the pending heap pops the earliest cluster of
events closer together than :func:`time_eps` — a *scale-aware* tolerance
(64 machine epsilons at the magnitude of the times involved, replacing
the historical fixed ``1e-12`` that goes vacuous once the clock exceeds
~1e4) — and processes the cluster sorted by kind rank (grid-freeing
events first: Complete, Fail, Preempt, then Reclaim, then Arrival) and
submission sequence.  Two genuinely distinct instants must therefore be
separated by more than ~128 ulp of their magnitude; anything closer is
one scheduling instant by design.

**Exact delta updates.**  The service never recomputes the background
traffic field from scratch: :class:`~repro.network.allocation.
MachineState` maintains per-size int64 accumulators of the integer-scaled
placement fields (:func:`repro.network.placement.int_base_loads`), so a
release *subtracts* its field losslessly.  ``BENCH_scheduler.json`` gates
the resulting per-event speedup vs. the historical full recompute.

Example — two jobs on a 2×2×2 machine, the second must wait:

>>> from repro.network.allocation import IsoperimetricPolicy, JobRequest
>>> svc = SchedulerService((2, 2, 2), IsoperimetricPolicy())
>>> svc.submit(JobRequest(0, 8, duration=2.0))
>>> svc.submit(JobRequest(1, 4, duration=1.0, arrival=0.5))
>>> res = svc.run().result()
>>> [(j.request.job_id, j.start) for j in res.jobs]
[(0, 0.0), (1, 2.0)]
>>> [(e.kind, e.job_id) for e in svc.log]  # doctest: +NORMALIZE_WHITESPACE
[('arrival', 0), ('start', 0), ('arrival', 1), ('complete', 0),
 ('start', 1), ('complete', 1)]
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..obs.trace import TRACER as _TRACER
from ..runtime.fault_tolerance import HeartbeatMonitor, failure_cells
from .allocation import (
    AllocationPolicy,
    JobRequest,
    MachineState,
    Placement,
    ScheduledJob,
    SimulationResult,
)
from .fabric import HyperXFabric
from .geometry import Geometry
from .isoperimetry import best_bisection_geometry, scaled_node_dims
from .placement import first_fit, placement_cells
from .routing import hyperx_all_to_all_max_load, predict_pairing_time

Coord = Tuple[int, ...]

# Event kinds.  _RANK is the processing order inside one scheduling
# instant: grid-freeing events first (they can unblock the head), then
# repairs/resumptions, then arrivals; Start/Reject are derived by the
# scheduling pass that follows, never queued.
ARRIVAL = "arrival"
START = "start"
COMPLETE = "complete"
FAIL = "fail"
PREEMPT = "preempt"
RECLAIM = "reclaim"
REJECT = "reject"
_RANK = {COMPLETE: 0, FAIL: 1, PREEMPT: 2, RECLAIM: 3, ARRIVAL: 4}

#: Relative width of one scheduling instant: 64 machine epsilons.
EPS_REL = 64.0 * float(np.finfo(np.float64).eps)


def time_eps(*times: float) -> float:
    """Scale-aware tolerance of the event clock: ``64 · eps_machine`` at
    the magnitude of the largest argument (floored at 1.0, so tiny clocks
    keep an absolute ~1.4e-14 guard).  Events closer than this are one
    scheduling instant; the contract is that genuinely distinct instants
    are separated by more than ~128 ulp of their magnitude.  The
    historical fixed ``1e-12`` is ~67x *below* one ulp at t = 1e5, where
    accumulated arrival/duration rounding made tie ordering seed-dependent.
    """
    scale = 1.0
    for t in times:
        a = abs(float(t))
        if a > scale:
            scale = a
    return EPS_REL * scale


def time_close(a: float, b: float) -> bool:
    """True when ``a`` and ``b`` are the same scheduling instant."""
    return abs(a - b) <= time_eps(a, b)


def time_le(a: float, b: float) -> bool:
    """Scale-aware ``a <= b`` (true also when the two are one instant)."""
    return a <= b or time_close(a, b)


def time_lt(a: float, b: float) -> bool:
    """Scale-aware strict ``a < b`` (false when the two are one instant)."""
    return a < b and not time_close(a, b)


@dataclass(frozen=True)
class Event:
    """One record of the append-only scheduler log.

    ``seq`` is the record's position in the log (dense, deterministic).
    ``source`` is ``"input"`` for externally injected records (arrivals,
    failures, preemptions, reclaims) and ``"derived"`` for everything the
    service concluded on its own — replaying only the input records
    through a fresh service reproduces the derived ones exactly
    (:func:`replay_events`)."""

    time: float
    kind: str
    seq: int
    job_id: Optional[int] = None
    cells: Optional[Tuple[Coord, ...]] = None
    request: Optional[JobRequest] = None  # arrival records carry the job
    placement: Optional[Placement] = None  # start records carry the decision
    priority: int = 0
    reason: Optional[str] = None  # reject/preempt annotations
    source: str = "derived"


@dataclass
class _Queued:
    request: JobRequest
    priority: int
    order: int  # enqueue sequence: FIFO within a priority level


@dataclass
class _Live:
    gen: int  # start generation: stale Complete events are discarded
    job: ScheduledJob
    priority: int


class SchedulerService:
    """Event-sourced online scheduler wrapping one
    :class:`~repro.network.allocation.MachineState`.

    The scheduling pass after each event cluster reproduces the historical
    ``simulate_queue`` loop exactly: the head of the waiting queue is
    tried first (FCFS within a priority level), a blocked head caches its
    reservation — the earliest time it is guaranteed to fit, by replaying
    pending frees on a scratch grid — until *any* grid-freeing event
    (Complete, Fail, Preempt, priority eviction or a cell repair)
    invalidates it, and with ``backfill=True`` later jobs may jump a
    blocked head only if they finish by the reservation (EASY backfill).

    Beyond the batch simulator it adds:

    * ``max_waiting`` — backpressure: an arrival that would grow the
      waiting queue past the bound is shed (logged as a Reject with
      reason ``"backpressure"`` and listed in both ``shed`` and
      ``rejected``); requeued victims of failures/preemptions are never
      shed.
    * ``preempt_priority=True`` — a blocked head may evict strictly
      lower-priority running jobs (lowest priority, youngest first) when
      doing so frees enough cells; victims requeue with their remaining
      duration.
    * failure semantics — ``inject_failure`` evacuates the jobs on the
      failed cells (requeued with remaining duration — the idealised
      checkpoint-at-failure model matching
      :mod:`repro.runtime.fault_tolerance`'s restore) and removes the
      cells from the free pool until ``inject_reclaim`` repairs them.

    ``on_start(service, job)`` / ``on_release(service, job_id)`` hooks run
    synchronously at placement/free time; ``simulate_queue`` uses them to
    attach its contention measurements without a second event loop.
    """

    def __init__(
        self,
        machine_dims: Sequence[int],
        policy: AllocationPolicy,
        *,
        unit_node_dims: Optional[Sequence[int]] = None,
        link_bw: float = 1.0,
        backfill: bool = False,
        max_waiting: Optional[int] = None,
        preempt_priority: bool = False,
        backend: Optional[str] = None,
        on_start: Optional[Callable[["SchedulerService", ScheduledJob], None]] = None,
        on_release: Optional[Callable[["SchedulerService", int], None]] = None,
    ):
        self.machine = MachineState(machine_dims, backend=backend)
        self.policy = policy
        if unit_node_dims is not None and isinstance(self.machine.fabric, HyperXFabric):
            raise ValueError(
                "unit_node_dims is the BG/Q torus node-scaling convention; "
                "HyperX machines schedule allocation-unit boxes directly"
            )
        self.unit_node_dims = unit_node_dims
        self.link_bw = float(link_bw)
        self.backfill = bool(backfill)
        self.max_waiting = max_waiting if max_waiting is None else int(max_waiting)
        self.preempt_priority = bool(preempt_priority)
        self.on_start = on_start
        self.on_release = on_release

        self.now = 0.0
        self.log: List[Event] = []
        self.scheduled: List[ScheduledJob] = []
        self.rejected: List[int] = []
        self.shed: List[int] = []
        self.failed_cells: Set[Coord] = set()

        self._pending: List[Tuple[float, int, int, str, tuple]] = []
        self._push_seq = itertools.count()
        self._waiting: List[_Queued] = []
        self._enqueue_seq = itertools.count()
        self._live: Dict[int, _Live] = {}
        self._gen = itertools.count()
        self._suspended: Dict[int, Tuple[JobRequest, int]] = {}
        # (job_id, t_res) of a blocked head: reused until a grid-freeing
        # event or a head change invalidates it (arrival-only wakes cannot
        # newly fit the head — the grid only changes on frees).
        self._blocked: Optional[Tuple[int, float]] = None
        self._opt_bisection: Dict[int, int] = {}

    # -- event intake -------------------------------------------------------
    def _push(self, time: float, kind: str, data: tuple) -> None:
        heapq.heappush(
            self._pending,
            (float(time), _RANK[kind], next(self._push_seq), kind, data),
        )

    def submit(self, request: JobRequest, priority: int = 0) -> None:
        """Queue an Arrival for ``request.arrival`` (processed at the
        current time if that is already past).  Higher ``priority`` jobs
        sit ahead of lower ones; FCFS within a level."""
        self._push(request.arrival, ARRIVAL, (request, int(priority), "input"))

    def inject_failure(self, time: float, cells: Iterable[Sequence[int]]) -> None:
        """Queue a Fail event: at ``time`` the given cells die — jobs on
        them are evacuated and requeued, the cells leave the free pool."""
        self._push(
            float(time), FAIL, (tuple(tuple(int(c) for c in cell) for cell in cells),)
        )

    def inject_preempt(self, time: float, job_id: int) -> None:
        """Queue a Preempt: suspend the running job (remaining duration is
        retained) until a Reclaim with its id requeues it.  A no-op if the
        job is not running when the event fires."""
        self._push(float(time), PREEMPT, (int(job_id),))

    def inject_reclaim(
        self,
        time: float,
        job_id: Optional[int] = None,
        cells: Optional[Iterable[Sequence[int]]] = None,
    ) -> None:
        """Queue a Reclaim: repair ``cells`` (returning them to the free
        pool) and/or requeue the suspended job ``job_id``."""
        self._push(
            float(time),
            RECLAIM,
            (
                None if job_id is None else int(job_id),
                None
                if cells is None
                else tuple(tuple(int(c) for c in cell) for cell in cells),
            ),
        )

    # -- log ----------------------------------------------------------------
    def _log(self, kind: str, **fields) -> None:
        self.log.append(Event(time=self.now, kind=kind, seq=len(self.log), **fields))

    @property
    def events_processed(self) -> int:
        """Number of records in the event log."""
        return len(self.log)

    # -- the event loop -----------------------------------------------------
    def run(self, until: Optional[float] = None) -> "SchedulerService":
        """Process pending events in deterministic ``(time, kind, seq)``
        order until the heap is empty (or past ``until``).  Returns self.

        Events within :func:`time_eps` of each other form one scheduling
        instant: the whole cluster is applied — sorted by kind rank, then
        submission sequence — before the scheduling pass runs, so a
        completion and an arrival at the "same" float time always resolve
        as completion first regardless of which float is a few ulp ahead.
        """
        while self._pending:
            t0 = self._pending[0][0]
            if until is not None and time_lt(until, t0):
                break
            if t0 > self.now:
                self.now = t0
            while True:
                batch = []
                while self._pending and time_le(self._pending[0][0], self.now):
                    batch.append(heapq.heappop(self._pending))
                if not batch:
                    break
                batch.sort(key=lambda e: (e[1], e[2]))
                # Spans only *measure* — the event application and the
                # scheduling pass are identical either way (non-perturbation
                # is pinned in tests/test_obs.py).
                if _TRACER.enabled:
                    with _TRACER.span(
                        "scheduler.step", t=self.now, events=len(batch)
                    ):
                        for _, _, _, kind, data in batch:
                            self._apply(kind, data)
                        self._schedule()
                else:
                    for _, _, _, kind, data in batch:
                        self._apply(kind, data)
                    self._schedule()
        if until is not None and until > self.now:
            self.now = until
        return self

    def result(self) -> SimulationResult:
        """Batch view of the run so far — the same
        :class:`~repro.network.allocation.SimulationResult` the historical
        ``simulate_queue`` returned (``rejected`` includes backpressure
        sheds; see :attr:`shed`)."""
        return SimulationResult(
            policy=self.policy.name,
            jobs=list(self.scheduled),
            rejected=list(self.rejected),
        )

    # -- event application --------------------------------------------------
    def _apply(self, kind: str, data: tuple) -> None:
        if kind == ARRIVAL:
            request, priority, source = data
            if (
                source == "input"
                and self.max_waiting is not None
                and len(self._waiting) >= self.max_waiting
            ):
                self._log(ARRIVAL, job_id=request.job_id, request=request,
                          priority=priority, source="input")
                self._log(REJECT, job_id=request.job_id, reason="backpressure")
                self.shed.append(request.job_id)
                self.rejected.append(request.job_id)
                return
            self._enqueue(request, priority, source)
        elif kind == COMPLETE:
            job_id, gen = data
            live = self._live.get(job_id)
            if live is None or live.gen != gen:
                return  # stale: the job was evacuated/preempted meanwhile
            del self._live[job_id]
            self.machine.release(job_id)
            if self.on_release is not None:
                self.on_release(self, job_id)
            self._log(COMPLETE, job_id=job_id)
            self._blocked = None  # freed cells: the head is worth retrying
        elif kind == FAIL:
            (cells,) = data
            self._log(FAIL, cells=cells, source="input")
            mask = np.zeros(self.machine.dims, dtype=bool)
            for cell in cells:
                mask[cell] = True
            victims = sorted(
                (
                    jid
                    for jid, live in self._live.items()
                    if mask[
                        placement_cells(
                            self.machine.dims,
                            live.job.placement.oriented,
                            live.job.placement.offset,
                        )
                    ].any()
                ),
                key=lambda jid: self._live[jid].gen,
            )
            for jid in victims:
                self._evict(jid, reason="failure", requeue=True)
            for cell in cells:
                if cell not in self.failed_cells:
                    self.failed_cells.add(cell)
                    self.machine.grid[cell] = True
            self._blocked = None
        elif kind == PREEMPT:
            (job_id,) = data
            if job_id in self._live:
                self._evict(job_id, reason="external", requeue=False, source="input")
            else:
                # Nothing to suspend — log the input so replay stays faithful.
                self._log(PREEMPT, job_id=job_id, reason="not-running", source="input")
        elif kind == RECLAIM:
            job_id, cells = data
            self._log(RECLAIM, job_id=job_id, cells=cells, source="input")
            if cells:
                repaired = False
                for cell in cells:
                    if cell in self.failed_cells:
                        self.failed_cells.discard(cell)
                        self.machine.grid[cell] = False
                        repaired = True
                if repaired:
                    self._blocked = None
            if job_id is not None and job_id in self._suspended:
                request, priority = self._suspended.pop(job_id)
                self._enqueue(
                    dataclasses.replace(request, arrival=self.now),
                    priority,
                    "derived",
                )
        else:  # pragma: no cover - _push only accepts the kinds above
            raise ValueError(f"unknown event kind {kind!r}")

    def _enqueue(self, request: JobRequest, priority: int, source: str) -> None:
        queued = _Queued(request, priority, next(self._enqueue_seq))
        key = (-priority, queued.order)
        lo, hi = 0, len(self._waiting)
        while lo < hi:
            mid = (lo + hi) // 2
            w = self._waiting[mid]
            if (-w.priority, w.order) <= key:
                lo = mid + 1
            else:
                hi = mid
        self._waiting.insert(lo, queued)
        self._log(
            ARRIVAL,
            job_id=request.job_id,
            request=request,
            priority=priority,
            source="input" if source == "input" else "derived",
        )

    def _evict(
        self, job_id: int, *, reason: str, requeue: bool, source: str = "derived"
    ) -> None:
        live = self._live.pop(job_id)
        self.machine.release(job_id)
        if self.on_release is not None:
            self.on_release(self, job_id)
        remaining = max(0.0, live.job.end - self.now)
        live.job.end = self.now  # the recorded segment ends here
        request = dataclasses.replace(
            live.job.request, duration=remaining, arrival=self.now
        )
        self._log(PREEMPT, job_id=job_id, reason=reason, source=source)
        self._blocked = None
        if requeue:
            self._enqueue(request, live.priority, "derived")
        else:
            self._suspended[job_id] = (request, live.priority)

    # -- the scheduling pass ------------------------------------------------
    def _schedule(self) -> None:
        while self._waiting:
            head = self._waiting[0]
            if self._blocked is not None and self._blocked[0] == head.request.job_id:
                t_res = self._blocked[1]
            else:
                if self._try_start(head):
                    self._waiting.pop(0)
                    continue
                if self.preempt_priority and self._preempt_for(head):
                    self._waiting.pop(0)
                    continue
                prefs = self.policy.preferences_for(self.machine, head.request)
                t_res = self._reservation(prefs)
                if t_res is None:
                    self._log(
                        REJECT, job_id=head.request.job_id, reason="impossible"
                    )
                    self.rejected.append(head.request.job_id)
                    self._waiting.pop(0)
                    continue
                self._blocked = (head.request.job_id, t_res)
            if self.backfill:
                kept: List[_Queued] = []
                for queued in self._waiting[1:]:
                    if not (
                        time_le(self.now + queued.request.duration, t_res)
                        and self._try_start(queued)
                    ):
                        kept.append(queued)
                self._waiting[1:] = kept
            break

    def _try_start(self, queued: _Queued) -> bool:
        request = queued.request
        if request.job_id in self._live:
            raise ValueError(f"job {request.job_id} is already running")
        if _TRACER.enabled:
            with _TRACER.span(
                "scheduler.place", job=request.job_id, units=request.units
            ) as _sp:
                placed = self.policy.allocate(self.machine, request)
                _sp.annotate(placed=placed is not None)
        else:
            placed = self.policy.allocate(self.machine, request)
        if placed is None:
            return False
        if isinstance(self.machine.fabric, HyperXFabric):
            # HyperX dimensions have diameter 1, so bisection pairing never
            # contends; the geometry-sensitive benchmark is the box's
            # internal all-to-all (closed form, exact).
            pred_time = (
                hyperx_all_to_all_max_load(
                    self.machine.fabric.sub_fabric(placed.geometry)
                )
                / self.link_bw
            )
        else:
            node_dims = scaled_node_dims(placed.geometry, self.unit_node_dims)
            pred_time = predict_pairing_time(
                node_dims, 1.0, self.link_bw
            ).time_per_volume
        opt_bis = self._optimal_bisection(request.units)
        job = ScheduledJob(
            request=request,
            placement=placed,
            start=self.now,
            end=self.now + request.duration,
            predicted_comm_time=pred_time,
            bisection_efficiency=(
                placed.bisection_links / opt_bis if opt_bis else 1.0
            ),
        )
        gen = next(self._gen)
        self._live[request.job_id] = _Live(gen=gen, job=job, priority=queued.priority)
        if self.on_start is not None:
            self.on_start(self, job)  # may refine job.placement (measurements)
        self.scheduled.append(job)
        self._log(
            START,
            job_id=request.job_id,
            placement=job.placement,
            priority=queued.priority,
        )
        self._push(job.end, COMPLETE, (request.job_id, gen))
        return True

    def _preempt_for(self, head: _Queued) -> bool:
        """Evict strictly lower-priority running jobs (lowest priority
        first, youngest first within a level) until the head fits; jobs
        are only evicted if freeing every eligible victim would fit the
        head at all.  Returns True when the head started."""
        victims = sorted(
            (jid for jid, live in self._live.items() if live.priority < head.priority),
            key=lambda jid: (self._live[jid].priority, -self._live[jid].gen),
        )
        if not victims:
            return False
        prefs = self.policy.preferences_for(self.machine, head.request)
        scratch = self.machine.grid.copy()
        for jid in victims:
            p = self._live[jid].job.placement
            scratch[placement_cells(self.machine.dims, p.oriented, p.offset)] = False
        if not any(first_fit(scratch, g) is not None for g in prefs):
            return False
        for jid in victims:
            self._evict(jid, reason="priority", requeue=True)
            if self._try_start(head):
                return True
        return False  # pragma: no cover - the scratch check guarantees a fit

    def _reservation(self, prefs: List[Geometry]) -> Optional[float]:
        """Earliest time the blocked head is guaranteed to fit: replay
        every pending free — running jobs' completions *and* scheduled
        repairs of failed cells — on a scratch grid in time order until a
        preferred geometry fits.  None: never fits, not even with every
        pending free applied — the request is impossible on the (possibly
        degraded) machine."""
        if not prefs:
            return None
        frees: List[Tuple[float, int, object]] = []
        for live in self._live.values():
            frees.append((live.job.end, live.gen, live.job.placement))
        for time, _, seq, kind, data in self._pending:
            if kind == RECLAIM and data[1]:
                frees.append((time, seq, tuple(data[1])))
        scratch = self.machine.grid.copy()
        for time, _, freed in sorted(frees, key=lambda f: (f[0], f[1])):
            if isinstance(freed, Placement):
                scratch[
                    placement_cells(self.machine.dims, freed.oriented, freed.offset)
                ] = False
            else:
                for cell in freed:
                    if tuple(cell) in self.failed_cells:
                        scratch[tuple(cell)] = False
            if any(first_fit(scratch, g) is not None for g in prefs):
                return time
        if any(first_fit(scratch, g) is not None for g in prefs):
            return self.now  # defensive: only asked after a failed allocate
        return None

    def _optimal_bisection(self, units: int) -> int:
        if units not in self._opt_bisection:
            try:
                self._opt_bisection[units] = best_bisection_geometry(
                    self.machine.fabric_or_dims, units
                )[1]
            except ValueError:
                self._opt_bisection[units] = 0
        return self._opt_bisection[units]


def replay_events(
    machine_dims: Sequence[int],
    policy: AllocationPolicy,
    log: Iterable[Event],
    **service_kwargs,
) -> SchedulerService:
    """Re-drive a fresh service from the ``source == "input"`` records of
    an event log and run it to quiescence.  With the same policy and
    service options the returned service's log equals the original
    record-for-record (event-log replay determinism — pinned in tests)."""
    service = SchedulerService(machine_dims, policy, **service_kwargs)
    for event in log:
        if event.source != "input":
            continue
        if event.kind == ARRIVAL:
            service.submit(event.request, priority=event.priority)
        elif event.kind == FAIL:
            service.inject_failure(event.time, event.cells)
        elif event.kind == PREEMPT:
            service.inject_preempt(event.time, event.job_id)
        elif event.kind == RECLAIM:
            service.inject_reclaim(event.time, job_id=event.job_id, cells=event.cells)
    service.run()
    return service


def apply_monitor_failures(
    service: SchedulerService,
    monitor: HeartbeatMonitor,
    worker_cells: Dict[str, Tuple[int, ...]],
    time: Optional[float] = None,
) -> List[Tuple[int, ...]]:
    """Poll a :class:`repro.runtime.fault_tolerance.HeartbeatMonitor` and
    inject a Fail event for the cells of newly-dead workers (at ``time``,
    default the service clock).  Returns the failed cells so callers can
    schedule the matching repair Reclaim once the workers rejoin."""
    cells = failure_cells(monitor, worker_cells)
    if cells:
        service.inject_failure(service.now if time is None else time, cells)
    return cells


# ---------------------------------------------------------------------------
# Scenario generation.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Scenario:
    """A reproducible workload for the service: a job stream plus timed
    failure / repair injections (see :func:`generate_scenario`)."""

    machine_dims: Tuple[int, ...]
    jobs: Tuple[JobRequest, ...]
    failures: Tuple[Tuple[float, Tuple[Coord, ...]], ...] = ()
    repairs: Tuple[Tuple[float, Tuple[Coord, ...]], ...] = ()


def _axis_divisors(extent: int) -> List[int]:
    return [d for d in range(1, extent + 1) if extent % d == 0]


def generate_scenario(
    machine_dims: Sequence[int],
    n_jobs: int,
    *,
    seed: int = 0,
    burst_gap: float = 40.0,
    burst_size: int = 6,
    tail_index: float = 1.4,
    mean_duration: float = 60.0,
    max_fraction: float = 0.25,
    failure_rate: float = 0.0,
    repair_delay: float = 200.0,
) -> Scenario:
    """Seeded synthetic workload: bursty arrivals (exponential gaps between
    bursts of ~``burst_size`` jobs), heavy-tailed job sizes (Pareto with
    ``tail_index``, snapped down to the nearest axis-divisor cuboid volume
    ≤ ``max_fraction`` of the machine), log-normal durations around
    ``mean_duration``, and optionally Poisson cell failures (rate per unit
    time) each repaired ``repair_delay`` later.  Deterministic per seed.
    """
    dims = tuple(int(d) for d in machine_dims)
    rng = np.random.default_rng(seed)
    total = int(np.prod(dims))
    cap = max(1, int(max_fraction * total))
    divisor_volumes = sorted(
        {
            int(np.prod(combo))
            for combo in itertools.product(*(_axis_divisors(d) for d in dims))
            if int(np.prod(combo)) <= cap
        }
    )
    volumes = np.asarray(divisor_volumes)

    jobs: List[JobRequest] = []
    now = 0.0
    job_id = 0
    while len(jobs) < n_jobs:
        now += float(rng.exponential(burst_gap))
        for k in range(int(rng.poisson(burst_size)) + 1):
            if len(jobs) >= n_jobs:
                break
            raw = float(rng.pareto(tail_index)) + 1.0  # Pareto >= 1
            size = int(volumes[np.searchsorted(volumes, raw, side="right") - 1])
            duration = float(
                rng.lognormal(np.log(mean_duration), 0.75)
            )
            jobs.append(
                JobRequest(
                    job_id=job_id,
                    units=size,
                    duration=duration,
                    arrival=now + 1e-3 * k,  # stable intra-burst order
                )
            )
            job_id += 1

    failures: List[Tuple[float, Tuple[Coord, ...]]] = []
    repairs: List[Tuple[float, Tuple[Coord, ...]]] = []
    if failure_rate > 0.0 and jobs:
        horizon = max(j.arrival for j in jobs)
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / failure_rate))
            if t >= horizon:
                break
            cell = tuple(int(rng.integers(d)) for d in dims)
            failures.append((t, (cell,)))
            repairs.append((t + repair_delay, (cell,)))
    return Scenario(
        machine_dims=dims,
        jobs=tuple(jobs),
        failures=tuple(failures),
        repairs=tuple(repairs),
    )


def run_scenario(
    scenario: Scenario, policy: AllocationPolicy, **service_kwargs
) -> SchedulerService:
    """Drive a fresh service with a :class:`Scenario` (jobs submitted in
    arrival order, failures/repairs injected) and run it to quiescence."""
    service = SchedulerService(scenario.machine_dims, policy, **service_kwargs)
    for request in sorted(scenario.jobs, key=lambda r: (r.arrival, r.job_id)):
        service.submit(request)
    for time, cells in scenario.failures:
        service.inject_failure(time, cells)
    for time, cells in scenario.repairs:
        service.inject_reclaim(time, cells=cells)
    service.run()
    return service


def scheduler_throughput(
    scenario: Scenario, policy: AllocationPolicy, **service_kwargs
) -> Tuple[SchedulerService, float]:
    """Run a scenario and return ``(service, events_per_second)`` — the
    benchmarked quantity of ``BENCH_scheduler.json``.  Timed through an
    :class:`repro.obs.Timer`, so with tracing enabled the scenario's
    wall clock lands in the trace stream alongside the per-event spans."""
    with _TRACER.timer(
        "scheduler.scenario", jobs=len(scenario.jobs), dims=scenario.machine_dims
    ) as t:
        service = run_scenario(scenario, policy, **service_kwargs)
    return service, service.events_processed / max(t.elapsed, 1e-9)
