"""Traffic-pattern library for the routing engine.

Each builder returns ``(src, dst, vol)`` — integer arrays of shape (M, D)
and a float array of shape (M,) — ready to feed
:func:`repro.network.routing.route_dor` or ``LinkLoads.add_batch``.  The
patterns cover the paper's benchmark (bisection pairing) plus the standard
workloads used for policy evaluation: all-to-all, nearest-neighbour halo
exchange, ring collectives (neighbour shifts), random permutations, and
transpose/shift patterns.

All builders are fully vectorized; none enumerate vertices in Python loops.
"""

from __future__ import annotations

import itertools
from typing import List, Optional, Sequence, Tuple

import numpy as np

Coord = Tuple[int, ...]
Traffic = Tuple[np.ndarray, np.ndarray, np.ndarray]


def vertices(dims: Sequence[int]) -> np.ndarray:
    """All vertex coordinates as an (N, D) int array (C order)."""
    dims = tuple(int(a) for a in dims)
    n = int(np.prod(dims))
    idx = np.arange(n)
    return np.stack(np.unravel_index(idx, dims), axis=1).astype(np.int64)


def _traffic(src: np.ndarray, dst: np.ndarray, vol) -> Traffic:
    vol = np.broadcast_to(np.asarray(vol, dtype=np.float64), (src.shape[0],))
    return src, dst, np.array(vol)


# ---------------------------------------------------------------------------
# Offsets and shifts.
# ---------------------------------------------------------------------------
def furthest_offset(dims: Sequence[int]) -> Tuple[int, ...]:
    """The maximal-hop-distance offset (pairs each node with its antipode)."""
    return tuple(a // 2 for a in dims)


def uniform_shift(dims: Sequence[int], offset: Sequence[int], vol: float = 1.0) -> Traffic:
    """Every vertex sends vol to vertex + offset (translation invariant)."""
    dims = tuple(int(a) for a in dims)
    v = vertices(dims)
    off = np.asarray(offset, dtype=np.int64)
    dst = (v + off) % np.asarray(dims, dtype=np.int64)
    return _traffic(v, dst, vol)


def ring_shift(dims: Sequence[int], axis: int, steps: int = 1, vol: float = 1.0) -> Traffic:
    """Neighbour shift along one axis — the collective-permute / ring-matmul
    step pattern (one hop per logical step when ``steps == 1``)."""
    off = [0] * len(tuple(dims))
    off[axis] = steps
    return uniform_shift(dims, off, vol)


# ---------------------------------------------------------------------------
# Paper experiment A: the bisection-pairing benchmark.
# ---------------------------------------------------------------------------
def pairing_pairs(dims: Sequence[int]) -> List[Tuple[Coord, Coord]]:
    """Explicit furthest-node pairing (each unordered pair listed once)."""
    dims = tuple(dims)
    off = furthest_offset(dims)
    pairs = []
    seen = set()
    for v in itertools.product(*(range(a) for a in dims)):
        w = tuple((v[k] + off[k]) % a for k, a in enumerate(dims))
        key = frozenset((v, w))
        if key in seen:
            continue
        seen.add(key)
        pairs.append((v, w))
    return pairs


def bisection_pairing(dims: Sequence[int], vol: float = 1.0) -> Traffic:
    """Every node exchanges vol with its antipode (both directions).

    This is the paper's contention benchmark: the full antipodal shift is a
    translation-invariant pattern, so the traffic is simply the furthest
    offset applied to every vertex — each unordered pair appears once per
    direction.
    """
    return uniform_shift(dims, furthest_offset(dims), vol)


# ---------------------------------------------------------------------------
# Dense patterns.
# ---------------------------------------------------------------------------
def all_to_all(
    dims: Sequence[int], vol_per_pair: float = 1.0, include_self: bool = False
) -> Traffic:
    """Every ordered vertex pair exchanges vol_per_pair."""
    v = vertices(dims)
    n = v.shape[0]
    si = np.repeat(np.arange(n), n)
    di = np.tile(np.arange(n), n)
    if not include_self:
        keep = si != di
        si, di = si[keep], di[keep]
    return _traffic(v[si], v[di], vol_per_pair)


def nearest_neighbor_halo(dims: Sequence[int], vol: float = 1.0) -> Traffic:
    """Halo exchange: every vertex sends vol to its +1 and -1 neighbour in
    every dimension of length > 1 (stencil / spatial-decomposition traffic).

    On a length-2 dimension the two neighbours coincide; both messages are
    kept, matching the two faces a halo exchange actually transmits.
    """
    dims = tuple(int(a) for a in dims)
    srcs, dsts = [], []
    for k, a in enumerate(dims):
        if a == 1:
            continue
        for step in (+1, -1):
            s, d, _ = ring_shift(dims, k, step, vol)
            srcs.append(s)
            dsts.append(d)
    if not srcs:
        empty = np.zeros((0, len(dims)), dtype=np.int64)
        return empty, empty.copy(), np.zeros(0)
    return _traffic(np.concatenate(srcs), np.concatenate(dsts), vol)


def random_permutation(
    dims: Sequence[int], vol: float = 1.0, seed: Optional[int] = None
) -> Traffic:
    """Each vertex sends vol to a distinct random destination (a permutation
    of the vertex set) — the classic adversarial-average routing workload."""
    v = vertices(dims)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(v.shape[0])
    return _traffic(v, v[perm], vol)


def transpose(dims: Sequence[int], vol: float = 1.0) -> Traffic:
    """2D matrix-transpose traffic: (x, y) -> (y, x) on a square 2D torus
    (higher dims must pair off equal lengths; the first two axes swap)."""
    dims = tuple(int(a) for a in dims)
    if len(dims) < 2 or dims[0] != dims[1]:
        raise ValueError(f"transpose needs the first two dims equal, got {dims}")
    v = vertices(dims)
    dst = v.copy()
    dst[:, 0], dst[:, 1] = v[:, 1], v[:, 0]
    keep = ~(v == dst).all(axis=1)
    return _traffic(v[keep], dst[keep], vol)


# ---------------------------------------------------------------------------
# Ring collectives as explicit traffic.
# ---------------------------------------------------------------------------
def ring_all_reduce_phases(
    dims: Sequence[int], axis: int, bytes_in: float
) -> List[Traffic]:
    """Bidirectional ring all-reduce over one physical axis as its
    ``2(n-1)`` dependent phases (reduce-scatter then all-gather).

    Each phase is one neighbour-shift step: every chip forwards half of a
    ``bytes_in / n`` shard to each ring direction.  Feeding the list to
    :func:`repro.network.netsim.simulate_phases` cross-checks the closed
    form :func:`repro.network.collectives.ring_all_reduce_time`
    dynamically (the phases share one traffic tuple — treat it as
    read-only).
    """
    dims = tuple(int(a) for a in dims)
    n = dims[axis]
    if n <= 1:
        return []
    shard = bytes_in / n
    s1, d1, v1 = ring_shift(dims, axis, +1, shard / 2.0)
    s2, d2, v2 = ring_shift(dims, axis, -1, shard / 2.0)
    phase = (
        np.concatenate([s1, s2]),
        np.concatenate([d1, d2]),
        np.concatenate([v1, v2]),
    )
    return [phase] * (2 * (n - 1))


def hotspot_line(dims: Sequence[int], axis: int = 0, vol: float = 1.0) -> Traffic:
    """A deliberately skewed two-class workload for routing studies.

    The vertices of one line (all coordinates 0 except ``axis``) run a
    ring shift among themselves *and* send the same shift to the parallel
    line halfway across the next non-trivial dimension.  Dimension-ordered
    routing stacks both classes on the hotspot line's links; a least-loaded
    dimension order routes the second class around them — the pattern where
    ``repro.network.netsim.compare_routing`` shows what adaptive routing
    *can* recover (unlike the geometry-induced contention of balanced
    patterns, where it recovers nothing).
    """
    dims = tuple(int(a) for a in dims)
    a = dims[axis]
    partner = next(
        (k for k in range(len(dims)) if k != axis and dims[k] > 1), None
    )
    if a < 4 or partner is None:
        raise ValueError(
            f"hotspot_line needs dims[{axis}] >= 4 and a second non-trivial "
            f"dimension, got {dims}"
        )
    shift = max(1, a // 2 - 1)  # long but tie-free shift along the line
    line = np.zeros((a, len(dims)), dtype=np.int64)
    line[:, axis] = np.arange(a)
    near = line.copy()
    near[:, axis] = (np.arange(a) + shift) % a
    far = near.copy()
    far[:, partner] = dims[partner] // 2
    src = np.concatenate([line, line])
    dst = np.concatenate([near, far])
    return _traffic(src, dst, vol)


def ring_all_gather(dims: Sequence[int], axis: int, bytes_out: float) -> Traffic:
    """Bidirectional ring all-gather over one physical axis, expressed as the
    total per-step neighbour traffic: each chip forwards (n-1)/n of the
    result, split across both directions.

    This is the traffic-level counterpart of
    :func:`repro.network.collectives.ring_all_gather_time`; routing it
    through the engine reproduces the closed-form link load.
    """
    dims = tuple(int(a) for a in dims)
    n = dims[axis]
    if n <= 1:
        empty = np.zeros((0, len(dims)), dtype=np.int64)
        return empty, empty.copy(), np.zeros(0)
    shard = bytes_out / n
    per_dir = shard * (n - 1) / 2.0
    s1, d1, v1 = ring_shift(dims, axis, +1, per_dir)
    s2, d2, v2 = ring_shift(dims, axis, -1, per_dir)
    return (
        np.concatenate([s1, s2]),
        np.concatenate([d1, d2]),
        np.concatenate([v1, v2]),
    )
